"""Engine speedup benchmark: vectorized SoA engine vs object-based path.

Methodology (recorded so BENCH_*.json entries stay comparable across PRs):
  * Workload: Poisson steady-state, ``n_txs`` submitted transactions over a
    fixed 20 s simulated window (rate = n_txs / 20), seed 0, default block
    gas limit — i.e. the Fig. 4 configuration scaled up, chain saturated.
  * Timed region: workload generation + submission + ``run_until`` over the
    full window, for each engine on the SAME drawn arrival times.
  * Metric: wall-clock ratio object/vector at equal ``n_txs`` (full mode
    runs BOTH engines at n_txs = 1,000,000; quick mode shrinks both and the
    ratio is reported as measured, never extrapolated).
  * Correctness cross-check: both engines must report identical
    confirmed/throughput/latency metrics before the ratio is accepted.

Also sweeps the scenario workload catalog through the vector engine so each
profile's cost appears in the BENCH record.
"""
from __future__ import annotations

import time
from typing import Dict

from repro.api import WorkloadSpec, preset
from repro.core.ledger import simulate_load, simulate_workload
from repro.core.workloads import SCENARIOS

FULL_N_TXS = 1_000_000
# quick mode keeps the vector side >=10ms so the reported ratio is not
# dominated by timer noise; the >=50x floor is only asserted in full mode
QUICK_N_TXS = 200_000
DURATION = 20.0


def _timed_load(chain_spec, n_txs: int) -> Dict:
    rate = n_txs / DURATION
    t0 = time.perf_counter()
    m = simulate_load("submitLocalModel", rate, duration=DURATION,
                      spec=chain_spec)
    m["wall_s"] = time.perf_counter() - t0
    return m


def run(quick: bool = False) -> Dict:
    n_txs = QUICK_N_TXS if quick else FULL_N_TXS
    vec = _timed_load(preset("l1-vector").chain, n_txs)
    obj = _timed_load(preset("l1-object").chain, n_txs)
    for k in ("confirmed", "submitted", "throughput"):
        assert vec[k] == obj[k], (k, vec[k], obj[k])
    assert abs(vec["latency"] - obj["latency"]) < 1e-9
    speedup = obj["wall_s"] / vec["wall_s"]
    if not quick:
        assert speedup >= 50.0, \
            f"vectorized engine must be >=50x at 1M txs, got {speedup:.1f}x"

    scenarios = {}
    s_rate = 200.0 if quick else 2000.0
    for name in sorted(SCENARIOS):
        wl = WorkloadSpec.make(name, s_rate, duration=10.0, seed=0).build()
        t0 = time.perf_counter()
        m = simulate_workload(wl)
        scenarios[name] = {"submitted": m.get("submitted", 0),
                           "confirmed": m.get("confirmed", 0),
                           "throughput": round(m["throughput"], 1),
                           "wall_s": round(time.perf_counter() - t0, 4)}
    return {"n_txs": n_txs, "quick": quick,
            "vector_wall_s": round(vec["wall_s"], 4),
            "object_wall_s": round(obj["wall_s"], 4),
            "speedup": round(speedup, 1),
            "confirmed": vec["confirmed"],
            "scenarios": scenarios}


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1))
