"""Paper Table I: gas consumption L1 vs L2 (commit/verify/execute).

Replays the table's call counts through the calibrated gas model AND through
the live Rollup engine (core/rollup.py), checking both against the paper's
published numbers and the 'up to 20x' headline claim.
"""
from __future__ import annotations

from repro.api import build_stack, preset
from repro.core.gas import FUNCTIONS, gas_reduction, l1_gas, l2_gas
from repro.core.ledger import Tx

# Table I ground truth (Total column), for tolerance checks.
PAPER_L2_TOTAL = {
    ("publishTask", 5): 112536, ("publishTask", 20): 183908,
    ("publishTask", 50): 416384, ("publishTask", 100): 742115,
    ("submitLocalModel", 5): 95824, ("submitLocalModel", 20): 123552,
    ("submitLocalModel", 50): 241568, ("submitLocalModel", 100): 408824,
    ("calculateObjectiveRep", 5): 88886, ("calculateObjectiveRep", 20): 97676,
    ("calculateObjectiveRep", 50): 182360,
    ("calculateObjectiveRep", 100): 273212,
    ("calculateSubjectiveRep", 5): 87280, ("calculateSubjectiveRep", 20): 93044,
    ("calculateSubjectiveRep", 50): 165728,
    ("calculateSubjectiveRep", 100): 238020,
}
PAPER_L1_TOTAL = {
    ("publishTask", 5): 910931, ("publishTask", 100): 17736655,
    ("submitLocalModel", 100): 4135650,
    ("calculateObjectiveRep", 100): 4299248,
    ("calculateSubjectiveRep", 100): 3523732,
}


def run_live_rollup(fn: str, n_calls: int) -> int:
    """Push n_calls through the live Rollup engine; sum settled gas."""
    chain, ru = build_stack(preset("rollup-object"))
    for i in range(n_calls):
        ru.submit(Tx(fn, f"c{i}", {}, 0, i * 0.01))
    ru.flush()
    return sum(b["total"] for b in ru.gas_log)


def run():
    rows = []
    max_red = 0.0
    for fn in FUNCTIONS:
        for n in (5, 20, 50, 100):
            model_l2 = l2_gas(fn, n)["total"]
            live_l2 = run_live_rollup(fn, n)
            l1 = l1_gas(fn, n)
            red = gas_reduction(fn, n)
            max_red = max(max_red, red)
            paper = PAPER_L2_TOTAL[(fn, n)]
            rel = abs(model_l2 - paper) / paper
            assert rel < 0.15, (fn, n, model_l2, paper, rel)
            assert abs(live_l2 - model_l2) / model_l2 < 0.1, \
                (fn, n, live_l2, model_l2)
            if (fn, n) in PAPER_L1_TOTAL:
                rel1 = abs(l1 - PAPER_L1_TOTAL[(fn, n)]) / PAPER_L1_TOTAL[(fn, n)]
                assert rel1 < 0.05, (fn, n, l1, rel1)
            rows.append({"fn": fn, "n": n, "L1": l1, "L2_model": model_l2,
                         "L2_live": live_l2, "paper_L2": paper,
                         "reduction": round(red, 1)})
    assert max_red >= 20.0, f"paper claims up to 20x, got {max_red}"
    return {"max_reduction": round(max_red, 1), "rows": rows}


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1))
