"""Paper Fig. 4: L1 throughput and latency vs transaction send rate.

Sweeps send rates for each of the four main functions on the QBFT chain
simulator; asserts the paper's saturation phenomenology (submitLocalModel
peaks near ~180 TPS around a 320 TPS send rate; heavier functions saturate
lower; latency rises sharply past saturation).
"""
from __future__ import annotations

from repro.api import WorkloadSpec, preset
from repro.core.gas import FUNCTIONS
from repro.core.ledger import simulate_load, simulate_workload
from repro.core.workloads import SCENARIOS

SEND_RATES = (20, 40, 80, 160, 320, 640)


def run(duration: float = 20.0, spec=None):
    chain = (spec or preset("l1-vector")).chain
    table = {}
    for fn in FUNCTIONS:
        rows = []
        for rate in SEND_RATES:
            m = simulate_load(fn, rate, duration=duration, spec=chain)
            rows.append({"send_rate": rate,
                         "throughput": round(m["throughput"], 1),
                         "latency_s": round(m["latency"], 3)})
        table[fn] = rows
    # beyond-Fig.-4: the scenario catalog at one aggregate rate
    scenario_rows = []
    for name in sorted(SCENARIOS):
        m = simulate_workload(WorkloadSpec.make(name, 160.0,
                                                duration=duration),
                              spec=chain)
        scenario_rows.append({"scenario": name,
                              "submitted": m.get("submitted", 0),
                              "throughput": round(m["throughput"], 1),
                              "latency_s": round(m["latency"], 3)})

    sub = {r["send_rate"]: r for r in table["submitLocalModel"]}
    assert 160 <= sub[320]["throughput"] <= 200, \
        f"submitLocalModel should peak ~180 TPS, got {sub[320]['throughput']}"
    assert sub[640]["latency_s"] > 4 * sub[80]["latency_s"], \
        "latency must rise sharply past saturation"
    pub = {r["send_rate"]: r for r in table["publishTask"]}
    assert pub[320]["throughput"] < sub[320]["throughput"], \
        "heavier publishTask saturates below submitLocalModel"
    peak = max(r["throughput"] for r in table["submitLocalModel"])
    return {"peak_tps_submitLocalModel": peak, "table": table,
            "scenarios": scenario_rows}


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1))
