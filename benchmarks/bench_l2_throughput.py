"""Paper Fig. 5: average throughput, single-layered BFL vs AutoDFL.

Uses the paper's own calculation method: L2 TPS = rollup batch size x L1
TPS at saturation; asserts the '>3000 TPS average' headline claim.
"""
from __future__ import annotations

import numpy as np

from repro.api import preset
from repro.core.gas import FUNCTIONS, ROLLUP_BATCH
from repro.core.ledger import simulate_load


def run(duration: float = 20.0, spec=None):
    chain = (spec or preset("l1-vector")).chain
    rows = []
    for fn in FUNCTIONS:
        peak = max(simulate_load(fn, rate, duration=duration,
                                 spec=chain)["throughput"]
                   for rate in (160, 320, 640))
        l2 = ROLLUP_BATCH * peak
        rows.append({"fn": fn, "l1_peak_tps": round(peak, 1),
                     "l2_tps": round(l2, 1)})
    avg_l2 = float(np.mean([r["l2_tps"] for r in rows]))
    # paper: "with a batch size of 20 and L1 throughput of 150 TPS,
    #         AutoDFL can achieve 20 x 150 = 3000 TPS"
    assert avg_l2 > 1500, avg_l2
    best = max(r["l2_tps"] for r in rows)
    assert best > 3000, f"paper: >3000 TPS; got best {best}"
    return {"avg_l2_tps": round(avg_l2, 1), "best_l2_tps": best, "rows": rows}


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1))
