"""Paper Table II: end-to-end L2 latency for batched function calls.

Model: per-batch proving latency + per-call sequencing latency, calibrated
per function against Table II; checks shape (few seconds at 100 calls) and
per-row tolerance.
"""
from __future__ import annotations

PAPER_TABLE_II = {
    "publishTask": {1: 1.145, 5: 1.564, 10: 2.452, 20: 3.201, 50: 7.514,
                    100: 14.785},
    "submitLocalModel": {1: 0.176, 5: 0.731, 10: 1.285, 20: 2.297, 50: 6.524,
                         100: 14.280},
    "calcObjectiveRep": {1: 0.214, 5: 0.686, 10: 1.304, 20: 2.627, 50: 6.756,
                         100: 14.660},
    "calcSubjectiveRep": {1: 0.221, 5: 1.037, 10: 1.495, 20: 3.784, 50: 8.726,
                          100: 17.075},
}

# least-squares (base, per_call) fits per function
CALIB = {
    "publishTask": (1.05, 0.1385),
    "submitLocalModel": (0.18, 0.1408),
    "calcObjectiveRep": (0.22, 0.1440),
    "calcSubjectiveRep": (0.35, 0.1655),
}


def latency_model(fn: str, n_calls: int) -> float:
    base, per = CALIB[fn]
    return base + per * n_calls


def run():
    rows = []
    worst = 0.0
    for fn, points in PAPER_TABLE_II.items():
        for n, paper_t in points.items():
            got = latency_model(fn, n)
            rel = abs(got - paper_t) / paper_t
            worst = max(worst, rel if n >= 10 else 0.0)
            rows.append({"fn": fn, "n": n, "model_s": round(got, 3),
                         "paper_s": paper_t, "rel_err": round(rel, 3)})
    assert worst < 0.35, f"latency model off by {worst}"
    assert latency_model("publishTask", 100) < 20.0, \
        "processing 100 txs must take only seconds (paper claim)"
    # beyond-Table-II: multi-lane sequencer latency (engine.VectorRollup);
    # lanes seal concurrently, so session latency falls with lane count
    import dataclasses

    from repro.api import build_ledger, preset
    lane_rows = []
    base = preset("rollup-vector")
    for lanes in (1, 2, 4, 8):
        ru = build_ledger(dataclasses.replace(
            base, rollup=dataclasses.replace(base.rollup, n_lanes=lanes)))
        lane_rows.append({"lanes": lanes,
                          "latency_100_calls_s": round(ru.latency(100), 3)})
    lats = [r["latency_100_calls_s"] for r in lane_rows]
    assert all(a > b for a, b in zip(lats, lats[1:])), \
        f"multi-lane latency must strictly improve: {lats}"
    return {"worst_rel_err_n>=10": round(worst, 3), "rows": rows,
            "multi_lane": lane_rows}


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1))
