"""Protocol-layer benchmark: concurrent multi-task scheduler TPS + gas.

Reproduces the paper's congestion/gas story at scale: many FL tasks emitting
lifecycle/reputation transactions into ONE shared ledger, L2 (zk-rollup)
batching vs the L1-equivalent cost.

Methodology (recorded so BENCH_protocol.json entries stay comparable):
  * Model: a tiny MLP on a gaussian-cluster classification task.  This is a
    PROTOCOL benchmark — per-trainer FL compute is deliberately minimized so
    scheduling/ledger costs dominate, mirroring the paper's own TPS
    experiments (Caliper transaction floods, not model training).  FL
    fidelity on the paper's LeNet-5 workload is covered by tests/.
  * Sequential baseline: ``AutoDFL.run_task`` per task — per-trainer
    TrainingAgent Python loop, object engine (the paper-faithful harness).
  * Scheduler: ``fl/scheduler.Scheduler`` interleaving all tasks with
    VectorCohorts (one vmapped dispatch per cohort round) over the vector
    engine, rollup lane batches sealed every 2 windows.
  * Both paths run a full jit warmup at the measured shapes first; the
    timed region is publish -> rounds -> settle for ALL tasks, end to end.
  * TPS = protocol txs emitted / wall seconds.  Gas: L1-equivalent total
    (Table-I per-call gas x call counts) vs the rollup's
    commit+verify+execute total from its gas_log.

Acceptance (asserted here, full mode): the scheduler with 16 concurrent
tasks x 64 trainers sustains >= 10x the protocol throughput of sequential
``run_task`` calls over the same work.  Quick mode (CI smoke) asserts the
8-task x 32-trainer point against a reduced >= 3x floor (timer noise on
shared runners; the measured ratio is recorded either way).
"""
from __future__ import annotations

import os
import sys
import time
from typing import Dict

# invokable as a script from any cwd (the repro imports below need src/ on
# the path BEFORE they run; the same insertion is a no-op under run.py)
_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(
    __file__))), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

import jax.numpy as jnp
import numpy as np

from repro.api import FLTaskSpec, preset
from repro.core.gas import DEFAULT_GAS
from repro.data.synthetic import gaussian_clusters
from repro.fl.client import ClientConfig, TrainingAgent
from repro.fl.cohort import CohortKernels, VectorCohort
from repro.fl.dp import DPConfig
from repro.fl.scheduler import Scheduler
from repro.fl.server import AutoDFL
from repro.models.mlp import TinyMLP
from repro.optim.optimizers import OptimizerSpec, make_optimizer

D_IN, D_H, N_CLS = 64, 32, 10
ROUNDS, LOCAL_STEPS, BATCH = 3, 2, 8


def _protocol_world():
    model = TinyMLP(D_IN, D_H, N_CLS, name="bench-mlp")
    opt = make_optimizer(OptimizerSpec(name="sgdm", lr=0.1, grad_clip=5.0))
    tr_x, tr_y = gaussian_clusters(4096, D_IN, N_CLS, seed=1)
    vx, vy = gaussian_clusters(250, D_IN, N_CLS, seed=2)
    val = {"x": jnp.asarray(vx), "labels": jnp.asarray(vy)}
    eval_fn = model.accuracy_fn()
    dp = DPConfig(noise_multiplier=0.05)

    def bf(c, r):
        g = np.random.default_rng((c * 9973 + r) % 2**31)
        idx = g.integers(0, len(tr_x), BATCH)
        return {"x": jnp.asarray(tr_x[idx]),
                "labels": jnp.asarray(tr_y[idx])}

    def vbf(sel, rnd):
        g = np.random.default_rng(int(rnd) * 131 + 7)
        idx = g.integers(0, len(tr_x), (len(sel), LOCAL_STEPS, BATCH))
        return {"x": jnp.asarray(tr_x[idx]),
                "labels": jnp.asarray(tr_y[idx])}
    return model, opt, val, eval_fn, dp, bf, vbf


def _l1_equivalent(calls: Dict[str, int]) -> int:
    return sum(DEFAULT_GAS.l1_per_call.get(fn, 30000) * n
               for fn, n in calls.items())


def _run_sequential(world, n_tasks: int, n_trainers: int) -> Dict:
    model, opt, val, eval_fn, dp, bf, _ = world
    spec = preset("protocol-sequential",
                  trainer_funds=10.0 * (n_tasks + 2),
                  publisher_funds=100.0 * (n_tasks + 2))
    node = AutoDFL(model, opt, n_trainers, eval_fn, val, spec=spec)
    agents = [TrainingAgent(
        ClientConfig(f"trainer{i}", "good", dp=dp,
                     local_steps=LOCAL_STEPS),
        model, opt, node.store, bf, seed=i) for i in range(n_trainers)]
    # per-agent jits must warm on the SAME agent objects (per-instance
    # closures), so the warmup task runs on the measured node; the timed
    # region counts call deltas only
    node.run_task(FLTaskSpec("warmup", rounds=1), agents, bf)
    calls0 = dict(node.protocol_calls)
    t0 = time.perf_counter()
    for t in range(n_tasks):
        node.run_task(FLTaskSpec(f"task{t}", rounds=ROUNDS), agents, bf)
    wall = time.perf_counter() - t0
    delta = {fn: n - calls0.get(fn, 0)
             for fn, n in node.protocol_calls.items()}
    n_txs = sum(delta.values())
    return {"wall_s": round(wall, 4), "protocol_txs": n_txs,
            "tps": round(n_txs / wall, 1),
            "l1_equivalent_gas": int(_l1_equivalent(delta))}


def _run_scheduler(world, n_tasks: int, n_trainers: int,
                   kernels: CohortKernels) -> Dict:
    model, opt, val, eval_fn, dp, _, vbf = world

    def build():
        spec = preset("protocol-scheduler",
                      trainer_funds=10.0 * (n_tasks + 2),
                      publisher_funds=100.0 * (n_tasks + 2))
        node = AutoDFL(model, opt, n_trainers, eval_fn, val, spec=spec)
        sch = Scheduler(node, seal_every=2)
        return node, sch

    # jit warmup at the measured shapes (incl. the K-task fused settlement
    # window) on a THROWAWAY node; the compile caches live in the shared
    # kernels / module-level jits, not the node
    wnode, wsch = build()
    for t in range(n_tasks):
        wsch.add_task(FLTaskSpec(f"warm{t}", rounds=ROUNDS), VectorCohort(
            model, opt, vbf, wnode.store, n_trainers=n_trainers,
            local_steps=LOCAL_STEPS, dp=dp, seed=100 + t,
            kernels=kernels))
    wsch.run()

    node, sch = build()
    for t in range(n_tasks):
        sch.add_task(FLTaskSpec(f"task{t}", rounds=ROUNDS), VectorCohort(
            model, opt, vbf, node.store, n_trainers=n_trainers,
            local_steps=LOCAL_STEPS, dp=dp, seed=t, kernels=kernels))
    t0 = time.perf_counter()
    out = sch.run()
    wall = time.perf_counter() - t0
    n_txs = sum(node.protocol_calls.values())
    acc = float(eval_fn(out["task0"].global_params, val))
    l1_equiv = _l1_equivalent(node.protocol_calls)
    l2 = sum(r["total"] for r in node.rollup.gas_log)
    return {"wall_s": round(wall, 4), "protocol_txs": n_txs,
            "tps": round(n_txs / wall, 1), "task0_val_acc": round(acc, 3),
            "l1_equivalent_gas": int(l1_equiv), "l2_gas": int(l2),
            "gas_reduction": round(l1_equiv / l2, 1)}


def run(quick: bool = False) -> Dict:
    world = _protocol_world()
    model, opt = world[0], world[1]
    kernels = CohortKernels(model, opt, world[4])
    assert_tasks, assert_trainers = (8, 32) if quick else (16, 64)
    sweep = ([(1, 16), (4, 32), (8, 32)] if quick else
             [(1, 32), (4, 32), (8, 32), (8, 64), (16, 64)])
    grid = {}
    for n_tasks, n_trainers in sweep:
        m = _run_scheduler(world, n_tasks, n_trainers, kernels)
        grid[f"tasks={n_tasks},trainers={n_trainers}"] = m

    seq = _run_sequential(world, assert_tasks, assert_trainers)
    sch = grid[f"tasks={assert_tasks},trainers={assert_trainers}"]
    speedup = sch["tps"] / max(seq["tps"], 1e-9)
    floor = 3.0 if quick else 10.0
    assert speedup >= floor, (
        f"scheduler with {assert_tasks} concurrent tasks must be >= "
        f"{floor}x sequential run_task throughput, got {speedup:.1f}x")
    return {"quick": quick, "rounds": ROUNDS, "local_steps": LOCAL_STEPS,
            "batch": BATCH,
            "assert_point": {"n_tasks": assert_tasks,
                             "n_trainers": assert_trainers},
            "sequential": seq, "scheduler_grid": grid,
            "speedup": round(speedup, 1), "speedup_floor": floor}


if __name__ == "__main__":
    import json
    quick = os.environ.get("BENCH_QUICK", "") not in ("", "0", "false")
    out = run(quick=quick)
    path = os.environ.get(
        "BENCH_PROTOCOL_JSON",
        os.path.join(os.path.dirname(os.path.abspath(__file__)),
                     "BENCH_protocol.json"))
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    print(json.dumps(out, indent=1))
    print(f"# wrote {path}", file=sys.stderr)
