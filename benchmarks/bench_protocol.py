"""Protocol-layer benchmark: concurrent multi-task scheduler TPS + gas.

Reproduces the paper's congestion/gas story at scale: many FL tasks emitting
lifecycle/reputation transactions into ONE shared ledger, L2 (zk-rollup)
batching vs the L1-equivalent cost.

Methodology (recorded so BENCH_protocol.json entries stay comparable):
  * Model: a tiny MLP on a gaussian-cluster classification task.  This is a
    PROTOCOL benchmark — per-trainer FL compute is deliberately minimized so
    scheduling/ledger costs dominate, mirroring the paper's own TPS
    experiments (Caliper transaction floods, not model training).  FL
    fidelity on the paper's LeNet-5 workload is covered by tests/.
  * Sequential baseline: ``AutoDFL.run_task`` per task — per-trainer
    TrainingAgent Python loop, object engine (the paper-faithful harness).
  * Scheduler: ``fl/scheduler.Scheduler`` interleaving all tasks with
    VectorCohorts over the vector engine, rollup lane batches sealed every
    2 windows.  With ``megabatch="auto"`` (the default, measured here) an
    all-round window runs as ONE (tasks, trainers) double-vmapped
    train/score/aggregate megastep plus one megabatched tx emission; the
    per-task path is re-measured at the assert point (``mega_reference``)
    and both are pinned bit-identical (state roots via the incremental
    dirty-chunk commitment, gas logs, events, scores) before any timing.
  * Both paths run a full jit warmup at the measured shapes first; the
    timed region is publish -> rounds -> settle for ALL tasks, end to end.
  * TPS = protocol txs emitted / wall seconds.  Gas: L1-equivalent total
    (Table-I per-call gas x call counts) vs the rollup's
    commit+verify+execute total from its gas_log.

  * Window loop: the fused plan-then-execute driver (core/fused.py +
    kernels/block_pack + kernels/batch_seal) vs the Python-stepped window
    loop on a pure-ledger protocol workload (pre-generated tx traffic, no
    FL compute) — isolates the scheduling/ledger hot path the fused loop
    compiles.  Both paths are asserted BIT-IDENTICAL (events + gas) before
    timing; best-of-3 walls after a per-shape warmup.

Acceptance (asserted here, full mode): the scheduler with 16 concurrent
tasks x 64 trainers sustains >= 10x the protocol throughput of sequential
``run_task`` calls over the same work.  Quick mode (CI smoke) asserts the
8-task x 32-trainer point against a reduced >= 3x floor (timer noise on
shared runners; the measured ratio is recorded either way).

Megastep acceptance: ``mega_speedup`` (auto vs megabatch=False at the
assert point) is floored at 0.6x — a parity band, not a speedup claim.
The megastep's win is structural: ~96 per-task jit dispatches per window
collapse into ~6 (one (tasks, trainers) vmapped train step, one
triple-vmapped score table, one vmapped weighted aggregation) plus ONE
megabatched tx emission per window.  On a single-core CPU host those
fused programs execute the same FLOPs serially, so wall-clock lands at
~1.1x (8x32) to ~0.8x (32x64); the multiplicative gain needs a backend
with parallel lanes (the vmapped task axis maps onto accelerator cores).
Bit-exactness against the per-task path is asserted before timing, and
``fl_per_task_flatness`` guards against collapse beyond the
serial-compute 1/T bound.

Fused window-loop acceptance: at the largest task count the fused loop
must be >= 1.2x the stepped wall (quick: >= 1.0x; measured ~1.4-2.1x on
an unloaded machine) and its per-task TPS at 32 tasks must stay >= 0.3x
the 4-task value (measured ~0.45x vs the stepped path's ~0.23x — the
fused loop halves the per-task collapse; the residual slope is the
per-batch prover/event protocol work both paths must emit identically).
PR-5 baselines for cross-PR comparison are recorded in the JSON under
``baseline_pr5`` (same machine, seed revision 544a4e2): FL scheduler
32 tasks x 64 trainers = 229 per-task TPS (this revision: ~330), stepped
pure-ledger window loop at 32 tasks = 13.8k per-task TPS.
"""
from __future__ import annotations

import os
import sys
import time
from typing import Dict

# invokable as a script from any cwd (the repro imports below need src/ on
# the path BEFORE they run; the same insertion is a no-op under run.py)
_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(
    __file__))), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import FLTaskSpec, preset
from repro.core.gas import DEFAULT_GAS
from repro.data.synthetic import gaussian_clusters
from repro.fl.client import ClientConfig, TrainingAgent
from repro.fl.cohort import CohortKernels, VectorCohort
from repro.fl.dp import DPConfig
from repro.fl.scheduler import Scheduler
from repro.fl.server import AutoDFL
from repro.models.mlp import TinyMLP
from repro.optim.optimizers import OptimizerSpec, make_optimizer

D_IN, D_H, N_CLS = 64, 32, 10
ROUNDS, LOCAL_STEPS, BATCH = 3, 2, 8


def _protocol_world():
    model = TinyMLP(D_IN, D_H, N_CLS, name="bench-mlp")
    opt = make_optimizer(OptimizerSpec(name="sgdm", lr=0.1, grad_clip=5.0))
    tr_x, tr_y = gaussian_clusters(4096, D_IN, N_CLS, seed=1)
    vx, vy = gaussian_clusters(250, D_IN, N_CLS, seed=2)
    val = {"x": jnp.asarray(vx), "labels": jnp.asarray(vy)}
    eval_fn = model.accuracy_fn()
    dp = DPConfig(noise_multiplier=0.05)

    def bf(c, r):
        g = np.random.default_rng((c * 9973 + r) % 2**31)
        idx = g.integers(0, len(tr_x), BATCH)
        return {"x": jnp.asarray(tr_x[idx]),
                "labels": jnp.asarray(tr_y[idx])}

    def vbf(sel, rnd):
        g = np.random.default_rng(int(rnd) * 131 + 7)
        idx = g.integers(0, len(tr_x), (len(sel), LOCAL_STEPS, BATCH))
        return {"x": jnp.asarray(tr_x[idx]),
                "labels": jnp.asarray(tr_y[idx])}
    return model, opt, val, eval_fn, dp, bf, vbf


def _l1_equivalent(calls: Dict[str, int]) -> int:
    return sum(DEFAULT_GAS.l1_per_call.get(fn, 30000) * n
               for fn, n in calls.items())


def _run_sequential(world, n_tasks: int, n_trainers: int) -> Dict:
    model, opt, val, eval_fn, dp, bf, _ = world
    spec = preset("protocol-sequential",
                  trainer_funds=10.0 * (n_tasks + 2),
                  publisher_funds=100.0 * (n_tasks + 2))
    node = AutoDFL(model, opt, n_trainers, eval_fn, val, spec=spec)
    agents = [TrainingAgent(
        ClientConfig(f"trainer{i}", "good", dp=dp,
                     local_steps=LOCAL_STEPS),
        model, opt, node.store, bf, seed=i) for i in range(n_trainers)]
    # per-agent jits must warm on the SAME agent objects (per-instance
    # closures), so the warmup task runs on the measured node; the timed
    # region counts call deltas only
    node.run_task(FLTaskSpec("warmup", rounds=1), agents, bf)
    calls0 = dict(node.protocol_calls)
    t0 = time.perf_counter()
    for t in range(n_tasks):
        node.run_task(FLTaskSpec(f"task{t}", rounds=ROUNDS), agents, bf)
    wall = time.perf_counter() - t0
    delta = {fn: n - calls0.get(fn, 0)
             for fn, n in node.protocol_calls.items()}
    n_txs = sum(delta.values())
    return {"wall_s": round(wall, 4), "protocol_txs": n_txs,
            "tps": round(n_txs / wall, 1),
            "l1_equivalent_gas": int(_l1_equivalent(delta))}


def _run_scheduler(world, n_tasks: int, n_trainers: int,
                   kernels: CohortKernels, megabatch="auto") -> Dict:
    model, opt, val, eval_fn, dp, _, vbf = world

    def build():
        spec = preset("protocol-scheduler",
                      trainer_funds=10.0 * (n_tasks + 2),
                      publisher_funds=100.0 * (n_tasks + 2))
        node = AutoDFL(model, opt, n_trainers, eval_fn, val, spec=spec)
        sch = Scheduler(node, seal_every=2, megabatch=megabatch)
        return node, sch

    # jit warmup at the measured shapes (incl. the K-task fused settlement
    # window) on a THROWAWAY node; the compile caches live in the shared
    # kernels / module-level jits, not the node
    wnode, wsch = build()
    for t in range(n_tasks):
        wsch.add_task(FLTaskSpec(f"warm{t}", rounds=ROUNDS), VectorCohort(
            model, opt, vbf, wnode.store, n_trainers=n_trainers,
            local_steps=LOCAL_STEPS, dp=dp, seed=100 + t,
            kernels=kernels))
    wsch.run()

    node, sch = build()
    for t in range(n_tasks):
        sch.add_task(FLTaskSpec(f"task{t}", rounds=ROUNDS), VectorCohort(
            model, opt, vbf, node.store, n_trainers=n_trainers,
            local_steps=LOCAL_STEPS, dp=dp, seed=t, kernels=kernels))
    t0 = time.perf_counter()
    out = sch.run()
    wall = time.perf_counter() - t0
    n_txs = sum(node.protocol_calls.values())
    acc = float(eval_fn(out["task0"].global_params, val))
    l1_equiv = _l1_equivalent(node.protocol_calls)
    l2 = sum(r["total"] for r in node.rollup.gas_log)
    return {"wall_s": round(wall, 4), "protocol_txs": n_txs,
            "tps": round(n_txs / wall, 1),
            "per_task_tps": round(n_txs / wall / n_tasks, 1),
            "mega_windows": sch.mega_windows,
            "task0_val_acc": round(acc, 3),
            "l1_equivalent_gas": int(l1_equiv), "l2_gas": int(l2),
            "gas_reduction": round(l1_equiv / l2, 1)}


def _assert_mega_equivalent(world, kernels: CohortKernels) -> Dict:
    """Equivalence gate BEFORE any timing is trusted: a small multi-task
    run driven by the cross-task megastep must be BIT-IDENTICAL to the
    per-task reference path (state roots via the incremental dirty-chunk
    commitment vs a cold full refold, gas logs, typed events, quorum
    scores, global params)."""
    model, opt, val, eval_fn, dp, _, vbf = world

    def once(megabatch):
        spec = preset("protocol-scheduler", trainer_funds=50.0,
                      publisher_funds=500.0)
        node = AutoDFL(model, opt, 16, eval_fn, val, spec=spec)
        sch = Scheduler(node, seal_every=2, megabatch=megabatch)
        for t in range(3):
            sch.add_task(FLTaskSpec(f"eq{t}", rounds=2), VectorCohort(
                model, opt, vbf, node.store, n_trainers=16,
                local_steps=LOCAL_STEPS, dp=dp, seed=t, kernels=kernels))
        out = sch.run()
        return node, sch, out

    na, sa, oa = once(False)
    nb, sb, ob = once("auto")
    assert sa.mega_windows == 0 and sb.mega_windows > 0
    # incremental dirty-chunk roots == full refold on an untracked copy
    for n in (na, nb):
        arrs = n.rollup.state_arrays
        assert arrs.root() == arrs.copy().root()
    assert na.chain.state_root() == nb.chain.state_root()
    assert na.rollup.state_root() == nb.rollup.state_root()
    assert na.chain.total_gas == nb.chain.total_gas
    assert na.rollup.gas_log == nb.rollup.gas_log
    assert na.protocol_calls == nb.protocol_calls
    assert na.chain.events._events == nb.chain.events._events
    for tid in oa:
        np.testing.assert_array_equal(oa[tid].scores, ob[tid].scores)
        for la, lb in zip(jax.tree.leaves(oa[tid].global_params),
                          jax.tree.leaves(ob[tid].global_params)):
            np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    return {"tasks": 3, "trainers": 16, "rounds": 2, "pinned": True}


# -- fused window loop: stepped vs plan-then-execute on the raw ledger ---------

WINDOW_TXS_PER_TASK, WINDOW_COUNT, WINDOW_SEED = 6, 48, 0


def _window_traffic(n_tasks: int, fns) -> list:
    """Protocol-shaped pre-generated traffic: per window, one small SoA
    batch per task (the ``_tx_batch`` shape), clock-stamped like the
    scheduler stamps them."""
    from repro.core.engine import TxArrays
    for f in ("publishTask", "submitLocalModel", "calculateObjectiveRep",
              "calculateSubjectiveRep"):
        fns.id(f)
    rng = np.random.default_rng(WINDOW_SEED)
    out, t = [], 0.0
    for w in range(WINDOW_COUNT):
        row = []
        for _m in range(n_tasks):
            k = WINDOW_TXS_PER_TASK
            times = t + 0.01 * np.arange(1, k + 1)
            t = float(times[-1])
            row.append(TxArrays(times, np.full(k, 30000, np.int64),
                                rng.integers(0, 4, k).astype(np.int32),
                                rng.integers(0, 64, k).astype(np.int32),
                                fns))
        out.append(row)
        t = max(t, (w + 1) * 1.0)
    return out


def _window_loop_once(n_tasks: int, fused: bool):
    """One window-loop run (seal+pump+pack per window, flush+settle at
    the end); returns (chain, rollup, wall_seconds)."""
    from repro.core.engine import VectorChain, VectorRollup
    from repro.core.fused import FusedWindowLoop
    chain = VectorChain()
    rollup = VectorRollup(chain, n_lanes=4, agg_width=4, prover_capacity=2)
    traffic = _window_traffic(n_tasks, rollup.fns)
    t0 = time.perf_counter()
    face = FusedWindowLoop(chain, rollup) if fused else rollup
    t = 0.0
    for row in traffic:
        for b in row:
            face.submit(rollup, b) if fused else rollup.submit_arrays(b)
        face.seal()
        t_end = max(t + 1.0, float(row[-1].submit_time[-1]))
        face.pump(t_end)
        (face if fused else chain).run_until(t_end)
        t = t_end
    face.flush()
    (face if fused else chain).run_until(t + 5.0)
    if fused:
        face.execute()
    return chain, rollup, time.perf_counter() - t0


def _run_window_loop(quick: bool) -> Dict:
    task_sweep = [4, 8] if quick else [4, 8, 16, 32]
    grid = {}
    for m in task_sweep:
        _window_loop_once(m, fused=True)         # warm this shape bucket
        best_s = best_f = float("inf")
        for _rep in range(3):
            ca, ra, ds = _window_loop_once(m, fused=False)
            cb, rb, df = _window_loop_once(m, fused=True)
            best_s, best_f = min(best_s, ds), min(best_f, df)
        # equivalence gate before any timing is trusted
        assert ca.events._events == cb.events._events
        assert ca.total_gas == cb.total_gas and ca.blocks == cb.blocks
        assert ra.gas_log == rb.gas_log
        assert ra.update_digest == rb.update_digest
        n_txs = m * WINDOW_COUNT * WINDOW_TXS_PER_TASK
        grid[f"tasks={m}"] = {
            "n_txs": n_txs, "stepped_wall_s": round(best_s, 4),
            "fused_wall_s": round(best_f, 4),
            "fused_speedup": round(best_s / best_f, 2),
            "fused_tps": round(n_txs / best_f, 0),
            "fused_per_task_tps": round(n_txs / best_f / m, 0),
            "stepped_per_task_tps": round(n_txs / best_s / m, 0)}
    top = grid[f"tasks={task_sweep[-1]}"]
    ratio_floor = 1.0 if quick else 1.2
    assert top["fused_speedup"] >= ratio_floor, (
        f"fused window loop at {task_sweep[-1]} tasks must be >= "
        f"{ratio_floor}x the stepped wall, got {top['fused_speedup']}x")
    flat = top["fused_per_task_tps"] / grid[
        f"tasks={task_sweep[0]}"]["fused_per_task_tps"]
    flat_floor = 0.2 if quick else 0.3
    assert flat >= flat_floor, (
        f"fused per-task TPS at {task_sweep[-1]} tasks fell to {flat:.2f}x "
        f"the {task_sweep[0]}-task value (floor {flat_floor})")
    return {"windows": WINDOW_COUNT, "txs_per_task": WINDOW_TXS_PER_TASK,
            "seed": WINDOW_SEED, "task_sweep": task_sweep, "grid": grid,
            "fused_speedup": top["fused_speedup"],
            "fused_speedup_floor": ratio_floor,
            "per_task_flatness": round(flat, 3),
            "per_task_flatness_floor": flat_floor}


def run(quick: bool = False) -> Dict:
    world = _protocol_world()
    model, opt = world[0], world[1]
    kernels = CohortKernels(model, opt, world[4])
    # gate first: the megastep + incremental-commitment paths must be
    # bit-exact to the stepped references before their timings mean a thing
    mega_equiv = _assert_mega_equivalent(world, kernels)
    assert_tasks, assert_trainers = (8, 32) if quick else (16, 64)
    sweep = ([(1, 16), (4, 32), (8, 32)] if quick else
             [(1, 32), (4, 32), (8, 32), (8, 64), (16, 64), (32, 64)])
    grid = {}
    for n_tasks, n_trainers in sweep:
        m = _run_scheduler(world, n_tasks, n_trainers, kernels)
        grid[f"tasks={n_tasks},trainers={n_trainers}"] = m

    seq = _run_sequential(world, assert_tasks, assert_trainers)
    sch = grid[f"tasks={assert_tasks},trainers={assert_trainers}"]
    speedup = sch["tps"] / max(seq["tps"], 1e-9)
    floor = 3.0 if quick else 10.0
    assert speedup >= floor, (
        f"scheduler with {assert_tasks} concurrent tasks must be >= "
        f"{floor}x sequential run_task throughput, got {speedup:.1f}x")
    # megastep speedup at the assert point: same shape, per-task reference
    # path (its own warmup — the mega warm run compiles different programs)
    ref = _run_scheduler(world, assert_tasks, assert_trainers, kernels,
                         megabatch=False)
    mega_speedup = sch["tps"] / max(ref["tps"], 1e-9)
    # Floor encodes "parity band with the per-task path", not the headline
    # speedup: the megastep trades T separate jit dispatches per window for
    # one vmapped program, which only pays off when the backend can run the
    # task lanes in parallel.  On a single-core CPU host (this container's
    # CI runner) the fused program does identical FLOPs serially, so the
    # honest expectation is ~1x at small shapes and a mild vmap penalty at
    # the largest ones; the dispatch-count and batched-emission wins are
    # asserted structurally via mega_windows + the equivalence gate above.
    mega_floor = 0.6
    assert mega_speedup >= mega_floor, (
        f"megabatched scheduler at {assert_tasks}x{assert_trainers} must "
        f"be >= {mega_floor}x the per-task path, got {mega_speedup:.2f}x")
    assert sch["mega_windows"] > 0 and ref["mega_windows"] == 0, (
        "assert-point runs must exercise the megastep (auto) and the "
        "per-task reference (megabatch=False) respectively")
    # per-task TPS flatness: megabatching is the scaling story — doubling
    # the task count must not collapse per-task throughput
    flat_num, flat_den = ((8, 32), (4, 32)) if quick else ((32, 64),
                                                           (16, 64))
    fl_flat = (grid[f"tasks={flat_num[0]},trainers={flat_num[1]}"]
               ["per_task_tps"] /
               grid[f"tasks={flat_den[0]},trainers={flat_den[1]}"]
               ["per_task_tps"])
    # single-core bound again: per-task TPS at T tasks approaches 1/T of
    # the 1-task value once the host is compute-saturated, so the floor
    # asserts "no collapse beyond the serial-compute bound", not the
    # accelerator-parallel flatness the megastep is designed for
    fl_flat_floor = 0.35 if quick else 0.4
    assert fl_flat >= fl_flat_floor, (
        f"per-task TPS at {flat_num[0]} tasks fell to {fl_flat:.2f}x the "
        f"{flat_den[0]}-task value (floor {fl_flat_floor})")
    window_loop = _run_window_loop(quick)
    return {"quick": quick, "rounds": ROUNDS, "local_steps": LOCAL_STEPS,
            "batch": BATCH, "data_seeds": {"train": 1, "val": 2},
            "assert_point": {"n_tasks": assert_tasks,
                             "n_trainers": assert_trainers},
            "sequential": seq, "scheduler_grid": grid,
            "speedup": round(speedup, 1), "speedup_floor": floor,
            "mega_equivalence": mega_equiv,
            "mega_reference": ref,
            "mega_speedup": round(mega_speedup, 2),
            "mega_speedup_floor": mega_floor,
            "fl_per_task_flatness": round(fl_flat, 3),
            "fl_per_task_flatness_floor": fl_flat_floor,
            "window_loop": window_loop,
            "baseline_pr7": {
                "revision": "d71eb2d",
                "fl_32x64_tps": 12626.8,
                "fl_32x64_per_task_tps": 394.6,
                "fl_16x64_per_task_tps": 796.3,
                "note": "same-machine pre-megastep scheduler grid at the "
                        "PR-7 revision (per-task window loop); this host "
                        "is a single CPU core, so the 32x64 point is "
                        "compute-bound at ~12k tps and the megastep runs "
                        "at parity there — its dispatch-count win (~96 -> "
                        "~6 jit dispatches per window, one batched tx "
                        "emission) needs parallel lanes to show up as "
                        "wall-clock"},
            "baseline_pr5": {
                "revision": "544a4e2",
                "fl_32x64_per_task_tps": 229.0,
                "fl_4x64_per_task_tps": 1817.0,
                "stepped_ledger_32task_per_task_tps": 13836.0,
                "note": "same-machine measurements at the PR-5 seed "
                        "revision; see README Performance"}}


if __name__ == "__main__":
    import json
    quick = os.environ.get("BENCH_QUICK", "") not in ("", "0", "false")
    out = run(quick=quick)
    path = os.environ.get(
        "BENCH_PROTOCOL_JSON",
        os.path.join(os.path.dirname(os.path.abspath(__file__)),
                     "BENCH_protocol.json"))
    with open(path, "w") as f:
        json.dump(out, f, indent=1, sort_keys=True)
    print(json.dumps(out, indent=1, sort_keys=True))
    print(f"# wrote {path}", file=sys.stderr)
