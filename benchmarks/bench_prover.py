"""Proof-aggregation benchmark: per-tx L1 verify gas vs aggregation width.

Methodology (recorded so BENCH_prover.json entries stay comparable):
  * Fixed workload: the Table-I ``mixed`` blend (seed 0), the SAME
    transaction set at every width, submitted in ``N_SESSIONS``
    time-chunks; each chunk seals and closes one settle session (the
    scheduler's window cadence).
  * Each point builds the ``prover-pipeline`` preset at one aggregation
    width: the prover pipeline folds ``width`` session proofs into one
    aggregate whose SINGLE verify+execute posts to the L1 — per-tx
    verify gas drops ~width-fold (the paper's 20X amortization lever,
    now tunable; see core/prover.py).
  * Width 1 IS the pre-pipeline settlement path (one verify per
    session) — bit-equivalence is pinned row-level by
    tests/test_prover.py on all three rollup backends; here the width-1
    point additionally asserts one posted aggregate per session.
  * The committed state root must be IDENTICAL across widths and
    backends — settlement grouping must never move state; asserted
    every run, every mode.

Acceptance (both modes): per-tx L1 verify gas at width 8 is reduced
>= 4x vs width 1 on every swept backend.
"""
from __future__ import annotations

import dataclasses
import os
import sys
import time
from typing import Dict

_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(
    __file__))), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

import numpy as np

from repro.api import (ChainSpec, NodeSpec, ProverSpec, ShardSpec,
                       build_ledger, l1_of, preset)
from repro.core.engine import TxArrays
from repro.core.state import default_state_handlers

N_SESSIONS = 32
VERIFY_FLOOR = 4.0          # min per-tx verify-gas reduction at width 8

BACKEND_SPECS = {
    "vector": lambda base: base,
    "fabric-2": lambda base: dataclasses.replace(
        base, shards=ShardSpec(count=2)),
    "object": lambda base: dataclasses.replace(
        base, chain=ChainSpec(backend="object")),
}


def _run_point(spec: NodeSpec, wl, width: int) -> Dict:
    spec = dataclasses.replace(spec, prover=ProverSpec(agg_width=width))
    target = build_ledger(spec, fns=wl.txs.fns
                          if spec.chain.backend == "vector" else None)
    chain = l1_of(target)
    for fn, handler in default_state_handlers().items():
        target.register_state(fn, handler)
    txs = wl.txs
    n = len(txs)
    bounds = np.linspace(0, n, N_SESSIONS + 1).astype(int)
    n_chunks = int(np.sum(bounds[1:] > bounds[:-1]))   # non-empty sessions
    t0 = time.perf_counter()
    for k in range(N_SESSIONS):
        lo, hi = int(bounds[k]), int(bounds[k + 1])
        if hi > lo:
            target.submit_arrays(TxArrays(
                txs.submit_time[lo:hi], txs.gas[lo:hi], txs.fn_id[lo:hi],
                txs.sender_id[lo:hi], txs.fns))
        target.seal()
        target.settle_session()
    target.flush()
    wall = time.perf_counter() - t0
    chain.run_until(wl.duration + 5.0)
    rows = target.gas_log
    assert sum(r["n_txs"] for r in rows) == n, "every tx seals exactly once"
    verify = float(sum(r["verify"] for r in rows))
    execute = float(sum(r["execute"] for r in rows))
    commit = float(sum(r["commit"] for r in rows))
    prover = target.prover
    return {
        "width": width,
        "n_txs": n,
        "n_chunks": n_chunks,
        "n_batches": len(rows),
        "n_aggregates": len(prover.aggregates),
        "n_sessions": int(sum(len(a.sessions) for a in prover.aggregates)),
        "commit_gas": int(commit),
        "verify_gas": int(verify),
        "execute_gas": int(execute),
        "l2_total_gas": int(commit + verify + execute),
        "per_tx_verify_gas": round(verify / n, 3),
        "seal_wall_s": round(wall, 4),
        "state_root": target.state_root(),
    }


def run(quick: bool = False) -> Dict:
    base = preset("prover-pipeline")
    wspec = base.workload
    if quick:
        wspec = dataclasses.replace(wspec, rate=800.0)
    wl = wspec.build()
    widths = [1, 8] if quick else [1, 2, 4, 8]
    backends = ["vector", "fabric-2"] if quick else \
        ["vector", "fabric-2", "object"]
    out: Dict[str, Dict] = {}
    reductions = {}
    for backend in backends:
        spec = BACKEND_SPECS[backend](base)
        # the object path lowers every SoA row to a Tx: keep its sweep
        # to the cheap endpoint widths
        bw = [1, 8] if backend == "object" else widths
        points = {f"width={w}": _run_point(spec, wl, w) for w in bw}
        roots = {k: p["state_root"] for k, p in points.items()}
        assert len(set(roots.values())) == 1, \
            f"state root must not depend on the aggregation width: {roots}"
        w1 = points["width=1"]
        # width 1 == one posted aggregate per non-empty submission chunk
        # (a shard multiplies the session count) — the pre-pipeline
        # settle cadence (row-level pin: tests/test_prover.py)
        n_shards = 2 if backend == "fabric-2" else 1
        assert w1["n_chunks"] <= w1["n_aggregates"] \
            <= w1["n_chunks"] * n_shards, \
            (backend, w1["n_aggregates"], w1["n_chunks"])
        red = w1["per_tx_verify_gas"] / \
            max(points["width=8"]["per_tx_verify_gas"], 1e-9)
        assert red >= VERIFY_FLOOR, (
            f"{backend}: width-8 aggregation must cut per-tx verify gas "
            f">= {VERIFY_FLOOR}x, got {red:.2f}x")
        reductions[backend] = round(red, 2)
        out[backend] = {"points": points, "reduction": reductions[backend],
                        "state_root": w1["state_root"]}
    assert len({b["state_root"] for b in out.values()}) == 1, \
        "all backends must commit the same state for the same workload"
    return {"quick": quick, "workload": wspec.scenario, "rate": wspec.rate,
            "duration": wspec.duration, "n_sessions": N_SESSIONS,
            "widths": widths, "backends": out,
            "reduction": min(reductions.values()),
            "reduction_floor": VERIFY_FLOOR}


if __name__ == "__main__":
    import json
    quick = os.environ.get("BENCH_QUICK", "") not in ("", "0", "false")
    out = run(quick=quick)
    path = os.environ.get(
        "BENCH_PROVER_JSON",
        os.path.join(os.path.dirname(os.path.abspath(__file__)),
                     "BENCH_prover.json"))
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    print(json.dumps(out, indent=1))
    print(f"# wrote {path}", file=sys.stderr)
