"""Paper Fig. 3: reputation dynamics of good / malicious / lazy profiles.

Simulates 20 tasks for three trainer profiles and reports the trajectories;
asserts the paper's qualitative claims (good rises steadily, malicious
collapses sharply, lazy declines in proportion to missed rounds).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.reputation import end_of_task_update, init_book


def run(n_tasks: int = 20, rounds: int = 10, seed: int = 0):
    book = init_book(3)
    rng = np.random.default_rng(seed)
    traj = [np.asarray(book.reputation).copy()]
    for _ in range(n_tasks):
        score = jnp.array([0.9 + 0.05 * rng.random(),      # good
                           0.05 * rng.random(),            # malicious
                           0.7 + 0.1 * rng.random()])      # lazy (when present)
        completed = jnp.array([float(rounds), float(rounds),
                               float(rng.integers(int(0.4 * rounds),
                                                  int(0.6 * rounds) + 1))])
        dist = jnp.array([0.5 + 0.1 * rng.random(),
                          5.0 + rng.random(),
                          1.0 + 0.2 * rng.random()])
        book, _ = end_of_task_update(book, score, completed,
                                     jnp.full(3, float(rounds)), dist,
                                     jnp.ones(3))
        traj.append(np.asarray(book.reputation).copy())
    traj = np.stack(traj)

    good, mal, lazy = traj[-1]
    assert good > 0.7, f"good should rise steadily, got {good}"
    assert mal < 0.25, f"malicious should collapse, got {mal}"
    assert mal < lazy < good, "lazy must sit between malicious and good"
    # "gradual but steady increase": strong net rise, no meaningful dips
    # (score_auto carries small stochastic noise, so allow hairline dips)
    assert traj[-1, 0] >= traj[0, 0] + 0.2, "good must rise substantially"
    assert np.all(np.diff(traj[:, 0]) > -0.02), "good must not meaningfully dip"
    drop_rate_mal = traj[0, 1] - traj[3, 1]
    drop_rate_lazy = traj[0, 2] - traj[3, 2]
    assert drop_rate_mal > drop_rate_lazy, "malicious drops faster than lazy"
    return {
        "good_final": float(good), "malicious_final": float(mal),
        "lazy_final": float(lazy),
        "good_t5": float(traj[5, 0]), "malicious_t5": float(traj[5, 1]),
        "trajectory": traj.tolist(),
    }


if __name__ == "__main__":
    import json
    print(json.dumps({k: v for k, v in run().items() if k != "trajectory"},
                     indent=1))
