"""Roofline report: reads the dry-run JSONs (results/dryrun/) and renders the
per-(arch x shape x mesh) table for EXPERIMENTS.md §Roofline."""
from __future__ import annotations

import glob
import json
import os

RESULTS = os.environ.get("REPRO_DRYRUN_DIR", "results/dryrun")


def load_cells():
    cells = []
    for f in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        try:
            cells.append(json.load(open(f)))
        except Exception:
            pass
    return cells


def run(require_all_ok: bool = False):
    cells = load_cells()
    ok = [c for c in cells if c.get("status") == "ok"]
    err = [c for c in cells if c.get("status") == "error"]
    rows = []
    for c in ok:
        if "roofline" not in c:
            continue
        r, w = c["roofline"], c["walk"]
        rows.append({
            "arch": c["arch"], "shape": c["shape"], "mesh": c["mesh"],
            "compute_ms": round(r["compute_s"] * 1e3, 2),
            "memory_ms": round(r["memory_s"] * 1e3, 2),
            "collective_ms": round(r["collective_s"] * 1e3, 2),
            "dominant": r["dominant"].replace("_s", ""),
            "roofline_frac": round(r.get("roofline_fraction", 0.0), 4),
            "useful_flops_ratio": round(r.get("useful_flops_ratio", 0.0), 3),
            "peak_GiB": round(c["memory"]["peak_bytes_est"] / 2 ** 30, 2),
            "fits_hbm": c.get("fits_hbm"),
        })
    if require_all_ok:
        assert not err, [f"{c['arch']}/{c['shape']}/{c['mesh']}" for c in err]
    summary = {
        "n_ok": len(ok), "n_error": len(err),
        "n_skipped": len([c for c in cells if c.get("status") == "skipped"]),
        "dominant_histogram": {},
    }
    for r in rows:
        d = r["dominant"]
        summary["dominant_histogram"][d] = \
            summary["dominant_histogram"].get(d, 0) + 1
    return {"summary": summary, "rows": rows}


def markdown_table(rows, mesh="single"):
    hdr = ("| arch | shape | compute ms | memory ms | collective ms | "
           "dominant | frac | useful | peak GiB | fits |")
    sep = "|" + "---|" * 10
    lines = [hdr, sep]
    for r in rows:
        if r["mesh"] != mesh:
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_ms']} | "
            f"{r['memory_ms']} | {r['collective_ms']} | {r['dominant']} | "
            f"{r['roofline_frac']} | {r['useful_flops_ratio']} | "
            f"{r['peak_GiB']} | {'Y' if r['fits_hbm'] else 'N'} |")
    return "\n".join(lines)


if __name__ == "__main__":
    out = run()
    print(json.dumps(out["summary"], indent=1))
    print(markdown_table(out["rows"]))
