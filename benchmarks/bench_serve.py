"""Node-service load harness: the scenario catalog as concurrent clients.

Methodology (recorded so BENCH_serve.json entries stay comparable):
  * Each point replays one PR-1 workload scenario (``core/workloads.py``,
    seed 0) against a live ``repro.serve.NodeService`` — every distinct
    sender in the workload becomes ONE asyncio client task that submits
    its own transactions in modeled-time order and yields between
    submissions, so thousands of clients genuinely interleave on the
    writer's op queue (full mode drives >= 1000 concurrent clients).
  * Admission runs the default rule ladder with a pool cap sized BELOW
    the spam scenario's per-window arrivals, so the cap and the
    lowest-fee-first eviction actually bite: spam targets the cheapest
    function (lower intrinsic fee), honest traffic the dearer one, and
    an honest arrival at a full pool displaces spam — the mempool's
    economic defense, measured rather than asserted from code reading.
  * ``honest_retention`` is the headline: admitted honest transactions
    under spam divided by admitted honest transactions with the
    identical honest traffic alone (same seed draws both).  The
    acceptance floor is >= 0.8 in every mode (``check_regression.py``
    gates it).
  * ``admitted_tps`` is modeled throughput (admitted / workload
    duration) — deterministic, no timer in the loop; wall times are
    recorded per scenario but never gated.
  * The poisson point re-runs its recorded op log serially through
    ``replay_ops`` and asserts state-root + L1 gas equality — the
    concurrency-safety oracle, live in the harness, not only in tests.

``BENCH_QUICK=1`` runs a reduced smoke mode (CI): ~200 clients, shorter
workloads, same assertions except the 1000-client floor.
"""
from __future__ import annotations

import asyncio
import os
import sys
import time
from typing import Dict, List

_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(
    __file__))), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)


from repro.api import AdmissionSpec, NodeSpec, ServeSpec
from repro.core.workloads import (Workload, adversarial_spam_workload,
                                  make_workload)
from repro.serve import NodeService, replay_ops

HONEST_FN = "submitLocalModel"          # dearer intrinsic gas
SPAM_FN = "calculateSubjectiveRep"      # the cheapest target


def _serve_spec(n_clients: int, pool_cap: int) -> ServeSpec:
    # queue >= one in-flight op per client: backpressure is a scenario
    # under test only via the pool cap here, not the writer queue
    return ServeSpec(
        node=NodeSpec(),
        admission=AdmissionSpec(pool_cap=pool_cap),
        queue_cap=n_clients + 64, window=1.0)


def _drive(wl: Workload, spec: ServeSpec) -> Dict:
    """Replay ``wl`` as one asyncio client task per distinct sender.

    Clients advance in lockstep epochs of one serve window: every client
    races its transactions for the current modeled window concurrently
    (genuine interleaving on the writer's op queue), then all clients
    barrier before the next window — modeled time passes coherently
    instead of the fastest-scheduled client dragging the service clock
    (and every window boundary) to the end of the run on arrival."""
    txs = wl.txs
    n_epochs = int(wl.duration / spec.window) + 2
    # sender -> per-epoch index lists (each already submit-time sorted)
    by_sender: Dict[int, List[List[int]]] = {}
    for i in range(len(txs)):
        epoch = min(int(txs.submit_time[i] / spec.window), n_epochs - 1)
        by_sender.setdefault(int(txs.sender_id[i]),
                             [[] for _ in range(n_epochs)])[epoch].append(i)

    async def run():
        svc = await NodeService(spec).start()
        ref_sender: Dict[int, int] = {}

        async def one_client(sid: int, idxs: List[int]) -> None:
            for i in idxs:
                r = await svc.submit(txs.fns.names[int(txs.fn_id[i])],
                                     f"c{sid}",
                                     at=float(txs.submit_time[i]))
                if "ref" in r:
                    ref_sender[r["ref"]] = sid
                await asyncio.sleep(0)          # interleave with peers
        for k in range(n_epochs):
            await asyncio.gather(*(one_client(s, per_epoch[k])
                                   for s, per_epoch in sorted(
                                       by_sender.items())
                                   if per_epoch[k]))
        await svc.close()
        return svc, ref_sender

    t0 = time.perf_counter()
    svc, ref_sender = asyncio.run(run())
    wall = time.perf_counter() - t0

    committed_by_sender: Dict[int, int] = {}
    for ref, rec in svc.receipts.items():
        if rec.get("status") == "submitted" and ref in ref_sender:
            sid = ref_sender[ref]
            committed_by_sender[sid] = committed_by_sender.get(sid, 0) + 1
    return {"svc": svc, "n_clients": len(by_sender),
            "committed_by_sender": committed_by_sender,
            "counters": svc.admission.counters(),
            "stats": {"submitted": svc.metrics.submitted,
                      "flushed": svc.metrics.flushed,
                      "windows": svc.metrics.windows,
                      "wall_s": round(wall, 3)}}


def _point(res: Dict, duration: float) -> Dict:
    flushed = res["stats"]["flushed"]
    return {"n_clients": res["n_clients"], **res["stats"],
            **res["counters"],
            "admitted_tps": round(flushed / duration, 1)}


def run(quick: bool = False) -> Dict:
    if quick:
        n_honest, n_spammers = 200, 8
        honest_rate, spam_rate, duration, pool_cap = 60.0, 240.0, 15.0, 128
    else:
        n_honest, n_spammers = 1000, 24
        honest_rate, spam_rate, duration, pool_cap = 300.0, 1200.0, 30.0, 512
    points: Dict[str, Dict] = {}

    # -- poisson: steady state + the replay-equivalence oracle ----------------
    wl = make_workload("poisson", honest_rate, duration=duration, seed=0,
                       fn=HONEST_FN, n_senders=n_honest)
    res = _drive(wl, _serve_spec(n_honest, pool_cap))
    points["poisson"] = _point(res, duration)
    svc = res["svc"]
    serial = replay_ops(svc.spec.node, svc.ops)
    assert svc.client.state_root() == serial.state_root(), \
        "concurrent service diverged from its serial op-log replay"
    assert svc.client.chain.total_gas == serial.chain.total_gas, \
        "concurrent service gas total diverged from serial replay"
    points["poisson"]["replay_match"] = True

    # -- bursty: flash crowd through the same admission ladder ----------------
    wl = make_workload("bursty", honest_rate, duration=duration, seed=0,
                       fn=HONEST_FN, n_senders=n_honest)
    points["bursty"] = _point(_drive(wl, _serve_spec(n_honest, pool_cap)),
                              duration)

    # -- spam: honest retention vs the identical honest traffic alone --------
    # (spam_rate=0 draws the SAME honest times/senders — honest draws
    # come first from the seeded rng in adversarial_spam_workload)
    common = dict(duration=duration, fn=HONEST_FN, spam_fn=SPAM_FN,
                  n_spammers=n_spammers, seed=0, n_senders=n_honest)
    wl_alone = adversarial_spam_workload(honest_rate, 0.0, **common)
    wl_spam = adversarial_spam_workload(honest_rate, spam_rate, **common)
    n_clients = n_honest + n_spammers
    res_alone = _drive(wl_alone, _serve_spec(n_clients, pool_cap))
    res_spam = _drive(wl_spam, _serve_spec(n_clients, pool_cap))

    def honest_committed(res):
        return sum(n for sid, n in res["committed_by_sender"].items()
                   if sid >= n_spammers)
    h_alone, h_spam = honest_committed(res_alone), honest_committed(res_spam)
    retention = h_spam / max(h_alone, 1)
    points["spam_control"] = _point(res_alone, duration)
    points["spam"] = _point(res_spam, duration)
    points["spam"].update({
        "honest_committed": h_spam, "honest_committed_alone": h_alone,
        "spam_committed": sum(
            n for sid, n in res_spam["committed_by_sender"].items()
            if sid < n_spammers)})

    n_clients_spam = points["spam"]["n_clients"]
    if not quick:
        assert n_clients_spam >= 1000, (
            f"full mode must drive >= 1000 concurrent clients, got "
            f"{n_clients_spam}")
    assert retention >= 0.8, (
        f"honest traffic must keep >= 80% of its spam-free admitted "
        f"throughput, got {retention:.3f} ({h_spam}/{h_alone})")

    return {"quick": quick, "seed": 0,
            "honest_rate": honest_rate, "spam_rate": spam_rate,
            "duration": duration, "pool_cap": pool_cap,
            "window": 1.0, "n_spammers": n_spammers,
            "n_clients": n_clients_spam,
            "honest_retention": round(retention, 4),
            "admitted_tps": points["spam"]["admitted_tps"],
            "points": points}


if __name__ == "__main__":
    import json
    quick = os.environ.get("BENCH_QUICK", "") not in ("", "0", "false")
    out = run(quick=quick)
    path = os.environ.get(
        "BENCH_SERVE_JSON",
        os.path.join(os.path.dirname(os.path.abspath(__file__)),
                     "BENCH_serve.json"))
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    print(json.dumps(out, indent=1))
    print(f"# wrote {path}", file=sys.stderr)
