"""Sharded rollup fabric benchmark: sealed-batch throughput vs shard count.

Methodology (recorded so BENCH_shards.json entries stay comparable):
  * Fixed workload: the Table-I ``mixed`` function blend (seed 0), the SAME
    transaction set submitted to every shard count.
  * Each point builds one shared L1 ``VectorChain`` + a ``ShardedRollup``
    with K shards (hash routing, default lanes/batch size) and the default
    protocol state handlers wired, then seals + settles everything.
  * ``sealed_batch_throughput`` is the MODELED fabric throughput at this
    workload: txs / fabric session latency from the Table-II-calibrated
    latency model (shards sequence concurrently, so the fabric latency is
    the slowest shard's even-split share) — deterministic, so CI can
    assert on it.  Wall-clock seal time is recorded alongside for context
    but never asserted (shared runners are noisy).
  * The flat array state root must reproduce bit-for-bit across shard
    counts AND across two independent runs — the fabric's correctness
    story; asserted every run, every mode.

Acceptance (full mode): modeled sealed-batch throughput at 8 shards is
>= 3x the 1-shard fabric on the same workload.  Quick mode (CI smoke)
runs the reduced 2-shard config and asserts >= 1.5x plus the root pins.
"""
from __future__ import annotations

import os
import sys
import time
from typing import Dict

_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(
    __file__))), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

import numpy as np

from repro.api import ShardSpec, build_stack, preset
from repro.core.state import default_state_handlers


def _run_point(wl, k: int) -> Dict:
    spec = preset("shard-fabric", shards=ShardSpec(count=k, fabric=True))
    chain, fabric = build_stack(spec, fns=wl.txs.fns)
    for fn, handler in default_state_handlers().items():
        fabric.register_state(fn, handler)
    t0 = time.perf_counter()
    fabric.submit_arrays(wl.txs)
    fabric.flush()
    seal_wall = time.perf_counter() - t0
    chain.run_until(wl.duration + 5.0)
    n = len(wl)
    assert sum(r["n_txs"] for r in fabric.gas_log) == n, \
        "every tx must seal in exactly one shard"
    return {
        "n_shards": k,
        "n_txs": n,
        "n_batches": fabric.n_batches,
        "seal_wall_s": round(seal_wall, 4),
        "fabric_latency_s": round(fabric.latency(n), 2),
        "sealed_batch_tps": round(fabric.sealed_batch_throughput(n), 1),
        "l2_gas": int(sum(r["total"] for r in fabric.gas_log)),
        "l1_total_gas": int(chain.total_gas),
        "state_root": fabric.state_root(),
        "fabric_root": fabric.fabric_root(),
    }


def run(quick: bool = False) -> Dict:
    import dataclasses
    wspec = preset("shard-fabric").workload
    if quick:
        wspec = dataclasses.replace(wspec, rate=2_000.0)
    rate, duration = wspec.rate, wspec.duration
    shard_counts = [1, 2] if quick else [1, 2, 4, 8]
    wl = wspec.build()
    points = {f"shards={k}": _run_point(wl, k) for k in shard_counts}

    roots = {k: p["state_root"] for k, p in points.items()}
    assert len(set(roots.values())) == 1, \
        f"array state root must not depend on the shard count: {roots}"
    rerun = _run_point(wl, shard_counts[-1])
    assert rerun["state_root"] == points[
        f"shards={shard_counts[-1]}"]["state_root"], "root must reproduce"
    assert rerun["fabric_root"] == points[
        f"shards={shard_counts[-1]}"]["fabric_root"]

    hi, lo = shard_counts[-1], shard_counts[0]
    scaling = points[f"shards={hi}"]["sealed_batch_tps"] / \
        max(points[f"shards={lo}"]["sealed_batch_tps"], 1e-9)
    floor = 1.5 if quick else 3.0
    assert scaling >= floor, (
        f"{hi}-shard fabric must sustain >= {floor}x the {lo}-shard "
        f"sealed-batch throughput, got {scaling:.2f}x")
    return {"quick": quick, "workload": wspec.scenario,
            "rate": rate, "duration": duration,
            "shard_counts": shard_counts, "points": points,
            "state_root": roots[f"shards={lo}"],
            "scaling": round(scaling, 2), "scaling_floor": floor}


if __name__ == "__main__":
    import json
    quick = os.environ.get("BENCH_QUICK", "") not in ("", "0", "false")
    out = run(quick=quick)
    path = os.environ.get(
        "BENCH_SHARDS_JSON",
        os.path.join(os.path.dirname(os.path.abspath(__file__)),
                     "BENCH_shards.json"))
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    print(json.dumps(out, indent=1))
    print(f"# wrote {path}", file=sys.stderr)
