"""Sharded rollup fabric benchmark: sealed-batch throughput vs shard count.

Methodology (recorded so BENCH_shards.json entries stay comparable):
  * Fixed workload: the Table-I ``mixed`` function blend (seed 0), the SAME
    transaction set submitted to every shard count.
  * Each point builds one shared L1 ``VectorChain`` + a ``ShardedRollup``
    with K shards (hash routing, default lanes/batch size) and the default
    protocol state handlers wired, then seals + settles everything.
  * ``sealed_batch_throughput`` is the MODELED fabric throughput at this
    workload: txs / fabric session latency from the Table-II-calibrated
    latency model (shards sequence concurrently, so the fabric latency is
    the slowest shard's even-split share) — deterministic, so CI can
    assert on it.
  * ``wall`` is the MEASURED sealed-batch wall-clock per point: each
    shard's seal runs (and is timed) one lane at a time, so a one-core
    runner still measures what each of K concurrent sequencers would
    spend, and the fabric window wall composes the way the fabric
    overlaps work — ``max(lane seal walls)`` plus the modeled
    interconnect costs (core/interconnect.py: root gather to L1 +
    cross-shard settlement scatter).  Every point carries the full
    latency decomposition so the headline ``wall_scaling`` is auditable.
  * A discarded warmup point runs FIRST so jit compilation and kernel
    caches never land inside a timed region (the historical ``shards=1``
    seal-wall anomaly was exactly that warmup cost).
  * The flat array state root must reproduce bit-for-bit across shard
    counts AND across two independent runs — the fabric's correctness
    story; asserted every run, every mode.

Acceptance (full mode): modeled sealed-batch throughput at 8 shards is
>= 3x the 1-shard fabric on the same workload, and the measured
wall-clock scaling clears >= 3x too.  Quick mode (CI smoke) runs the
reduced 2-shard config and asserts >= 1.5x modeled / >= 1.1x measured
plus the root pins.  ``check_regression.py`` gates both headlines.
"""
from __future__ import annotations

import os
import sys
import time
from typing import Dict

_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(
    __file__))), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)


from repro.api import ShardSpec, build_stack, preset
from repro.core.state import default_state_handlers


def _run_point(wl, k: int) -> Dict:
    spec = preset("shard-fabric", shards=ShardSpec(count=k, fabric=True))
    chain, fabric = build_stack(spec, fns=wl.txs.fns)
    for fn, handler in default_state_handlers().items():
        fabric.register_state(fn, handler)
    run_t0 = time.perf_counter()
    fabric.submit_arrays(wl.txs)
    submit_wall = time.perf_counter() - run_t0
    # per-lane seal walls: the K shards seal one at a time, each timed
    # alone, so a one-core runner measures what each of K CONCURRENT
    # sequencers would spend; the window wall then composes the way the
    # fabric overlaps work (max over lanes + the modeled wire costs)
    ic = fabric.interconnect
    lane_walls, nbs = [], []
    for s in fabric.shards:
        t0 = time.perf_counter()
        nbs.append(s.seal())
        lane_walls.append(time.perf_counter() - t0)
    gather_before = ic.totals["root_gather_s"]
    fabric._finish_window(nbs)
    root_gather_s = ic.totals["root_gather_s"] - gather_before
    fabric.settle_session()
    fabric.prover.drain()
    # one representative cross-shard settlement scatter: the full state
    # table fans out over the shard<->shard mesh once per sync
    settle_scatter_s = ic.record_settle_scatter(fabric.state.n)
    seal_wall = time.perf_counter() - run_t0
    chain.run_until(wl.duration + 5.0)
    n = len(wl)
    assert sum(r["n_txs"] for r in fabric.gas_log) == n, \
        "every tx must seal in exactly one shard"
    wall_window_s = max(lane_walls) + root_gather_s + settle_scatter_s
    return {
        "n_shards": k,
        "n_txs": n,
        "n_batches": fabric.n_batches,
        "seal_wall_s": round(seal_wall, 4),
        "fabric_latency_s": round(fabric.latency(n), 2),
        "sealed_batch_tps": round(fabric.sealed_batch_throughput(n), 1),
        "wall": {
            "submit_wall_s": round(submit_wall, 4),
            "lane_seal_s": [round(w, 4) for w in lane_walls],
            "max_lane_seal_s": round(max(lane_walls), 4),
            "sum_lane_seal_s": round(sum(lane_walls), 4),
            "root_gather_s": round(root_gather_s, 6),
            "settle_scatter_s": round(settle_scatter_s, 6),
            "wall_window_s": round(wall_window_s, 4),
            "wall_tps": round(n / wall_window_s, 1),
        },
        "interconnect": ic.summary(),
        "l2_gas": int(sum(r["total"] for r in fabric.gas_log)),
        "l1_total_gas": int(chain.total_gas),
        "state_root": fabric.state_root(),
        "fabric_root": fabric.fabric_root(),
    }


def run(quick: bool = False) -> Dict:
    import dataclasses
    wspec = preset("shard-fabric").workload
    if quick:
        wspec = dataclasses.replace(wspec, rate=2_000.0)
    rate, duration = wspec.rate, wspec.duration
    shard_counts = [1, 2] if quick else [1, 2, 4, 8]
    wl = wspec.build()
    # discarded warmup: jit compilation + kernel/digest caches must never
    # land inside a timed point (the old shards=1 seal-wall anomaly)
    _run_point(wl, shard_counts[0])
    # best-of-N per point: the roots/gas/model fields are deterministic
    # across reps, so repeating only de-noises the measured walls (shared
    # runners jitter 2x on a 100ms seal)
    reps = 2 if quick else 3
    points = {
        f"shards={k}": max((_run_point(wl, k) for _ in range(reps)),
                           key=lambda p: p["wall"]["wall_tps"])
        for k in shard_counts}

    roots = {k: p["state_root"] for k, p in points.items()}
    assert len(set(roots.values())) == 1, \
        f"array state root must not depend on the shard count: {roots}"
    rerun = _run_point(wl, shard_counts[-1])
    assert rerun["state_root"] == points[
        f"shards={shard_counts[-1]}"]["state_root"], "root must reproduce"
    assert rerun["fabric_root"] == points[
        f"shards={shard_counts[-1]}"]["fabric_root"]

    hi, lo = shard_counts[-1], shard_counts[0]
    scaling = points[f"shards={hi}"]["sealed_batch_tps"] / \
        max(points[f"shards={lo}"]["sealed_batch_tps"], 1e-9)
    floor = 1.5 if quick else 3.0
    assert scaling >= floor, (
        f"{hi}-shard fabric must sustain >= {floor}x the {lo}-shard "
        f"sealed-batch throughput, got {scaling:.2f}x")
    # measured wall-clock scaling: the per-lane seal walls + modeled
    # interconnect decomposition, NOT the Table-II model
    wall_scaling = points[f"shards={hi}"]["wall"]["wall_tps"] / \
        max(points[f"shards={lo}"]["wall"]["wall_tps"], 1e-9)
    wall_floor = 1.1 if quick else 3.0
    assert wall_scaling >= wall_floor, (
        f"{hi}-shard fabric must measure >= {wall_floor}x the {lo}-shard "
        f"sealed-batch wall-clock throughput, got {wall_scaling:.2f}x")
    return {"quick": quick, "workload": wspec.scenario,
            "rate": rate, "duration": duration,
            "shard_counts": shard_counts, "points": points,
            "state_root": roots[f"shards={lo}"],
            "scaling": round(scaling, 2), "scaling_floor": floor,
            "wall_scaling": round(wall_scaling, 2),
            "wall_scaling_floor": wall_floor}


if __name__ == "__main__":
    import json
    quick = os.environ.get("BENCH_QUICK", "") not in ("", "0", "false")
    out = run(quick=quick)
    path = os.environ.get(
        "BENCH_SHARDS_JSON",
        os.path.join(os.path.dirname(os.path.abspath(__file__)),
                     "BENCH_shards.json"))
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    print(json.dumps(out, indent=1))
    print(f"# wrote {path}", file=sys.stderr)
