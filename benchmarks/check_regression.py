"""Bench regression gate: fresh BENCH_summary.json vs the committed one.

CI (the ``bench-regression`` job) copies the committed summary aside,
re-runs the reduced benchmarks, rebuilds ``BENCH_summary.json`` with
``run.py --all``, and calls this script:

    python benchmarks/check_regression.py \
        --baseline /tmp/baseline_summary.json \
        --fresh benchmarks/BENCH_summary.json \
        --diff-out bench_regression_diff.json [--tolerance 0.25]

Two kinds of checks, both configurable:

  * **absolute floors** (``FLOORS``): headline metrics that must clear a
    hard minimum in ANY mode — these encode the acceptance criteria the
    benchmarks themselves assert, so the gate still bites when the
    baseline file is missing or was produced in a different mode;
  * **relative tolerance** (``--tolerance``, default 0.25): when baseline
    and fresh entries were produced in the SAME mode (quick vs full), a
    higher-is-better metric may not drop more than ``tolerance * 100``%
    below the committed value.  Per-metric overrides live in ``TOLERANCE``
    (timing-derived metrics on shared CI runners get a looser band than
    deterministic ones like gas reduction).

Every compared metric lands in the ``--diff-out`` JSON artifact with its
before/after values and verdict, regressions first; exit status is the
number of regressions (0 == gate passes).

Dry run (verified): degrading any committed headline, e.g.

    jq '.BENCH_protocol.headline.speedup = 99' \
        benchmarks/BENCH_summary.json > /tmp/degraded.json
    python benchmarks/check_regression.py --baseline /tmp/degraded.json \
        --fresh benchmarks/BENCH_summary.json --diff-out /tmp/d.json

exits 1 and reports ``BENCH_protocol.speedup`` as the regression.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, List, Optional

# metric path -> hard floor in any mode (mirrors the in-bench asserts at
# their quick/reduced values, so a quick CI run can still be gated)
FLOORS: Dict[str, float] = {
    "BENCH_protocol.speedup": 3.0,
    "BENCH_protocol.mega_speedup": 0.6,
    "BENCH_protocol.fl_per_task_flatness": 0.35,
    "BENCH_protocol.window_loop_speedup": 1.0,
    "BENCH_engine.speedup": 1.0,
    "BENCH_shards.scaling": 1.5,
    "BENCH_shards.wall_scaling": 1.1,
    "BENCH_prover.verify_gas_reduction": 4.0,
    # serving: honest traffic must keep >= 80% of its spam-free admitted
    # throughput under the spam scenario (ISSUE-10 acceptance)
    "BENCH_serve.honest_retention": 0.8,
}

# per-metric relative-drop overrides (fraction of the baseline value);
# anything not listed uses --tolerance
TOLERANCE: Dict[str, float] = {
    # pure gas accounting: deterministic, no timer in the loop
    "BENCH_prover.verify_gas_reduction": 0.01,
    # wall-clock ratios on shared runners: looser
    "BENCH_protocol.speedup": 0.4,
    "BENCH_protocol.mega_speedup": 0.35,
    "BENCH_protocol.fl_per_task_flatness": 0.35,
    "BENCH_protocol.window_loop_speedup": 0.3,
    "BENCH_engine.speedup": 0.4,
    "BENCH_shards.scaling": 0.4,
    # measured per-lane seal walls: most timer-noise-exposed headline
    "BENCH_shards.wall_scaling": 0.45,
    # admission outcomes: deterministic workload draws, but the asyncio
    # interleaving within a window is scheduler-dependent — small band
    "BENCH_serve.honest_retention": 0.1,
    "BENCH_serve.admitted_tps": 0.2,
}


def _metrics(summary: Dict[str, Any]) -> Dict[str, Dict[str, Any]]:
    """Flatten a BENCH_summary dict into {path: {value, quick}} for every
    numeric headline metric."""
    out: Dict[str, Dict[str, Any]] = {}
    for stem, entry in summary.items():
        if not isinstance(entry, dict):
            continue
        headline = entry.get("headline")
        if not isinstance(headline, dict):
            continue
        quick = bool(entry.get("quick", False))
        for key, val in headline.items():
            if isinstance(val, bool) or not isinstance(val, (int, float)):
                continue
            out[f"{stem}.{key}"] = {"value": float(val), "quick": quick}
    return out


def check(baseline: Optional[Dict[str, Any]], fresh: Dict[str, Any],
          tolerance: float) -> List[Dict[str, Any]]:
    """Compare summaries; returns one row per checked metric."""
    rows: List[Dict[str, Any]] = []
    fresh_m = _metrics(fresh)
    base_m = _metrics(baseline) if baseline else {}
    for path, fm in sorted(fresh_m.items()):
        row: Dict[str, Any] = {"metric": path, "fresh": fm["value"],
                               "checks": []}
        ok = True
        floor = FLOORS.get(path)
        if floor is not None:
            passed = fm["value"] >= floor
            row["checks"].append({"kind": "floor", "floor": floor,
                                  "passed": passed})
            ok &= passed
        bm = base_m.get(path)
        if bm is not None:
            row["baseline"] = bm["value"]
            if bm["quick"] == fm["quick"] and bm["value"] > 0:
                tol = TOLERANCE.get(path, tolerance)
                lo = bm["value"] * (1.0 - tol)
                passed = fm["value"] >= lo
                row["checks"].append({
                    "kind": "relative", "tolerance": tol,
                    "min_allowed": round(lo, 4), "passed": passed})
                ok &= passed
            else:
                row["checks"].append({"kind": "relative",
                                      "skipped": "mode mismatch"})
        row["verdict"] = "ok" if ok else "REGRESSION"
        rows.append(row)
    # baseline metrics that vanished from the fresh run are regressions
    # too (a silently dropped benchmark must not pass the gate)
    for path in sorted(set(base_m) - set(fresh_m)):
        rows.append({"metric": path, "baseline": base_m[path]["value"],
                     "fresh": None, "checks": [{"kind": "presence",
                                                "passed": False}],
                     "verdict": "REGRESSION"})
    rows.sort(key=lambda r: (r["verdict"] != "REGRESSION", r["metric"]))
    return rows


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", required=True,
                    help="committed BENCH_summary.json (pre-run copy)")
    ap.add_argument("--fresh", required=True,
                    help="freshly rebuilt BENCH_summary.json")
    ap.add_argument("--tolerance", type=float, default=0.25,
                    help="default max relative drop (fraction)")
    ap.add_argument("--diff-out", default=None,
                    help="write the before/after diff artifact here")
    args = ap.parse_args(argv)

    with open(args.fresh) as f:
        fresh = json.load(f)
    try:
        with open(args.baseline) as f:
            baseline = json.load(f)
    except (OSError, json.JSONDecodeError) as err:
        print(f"# no usable baseline ({err}); floors only", file=sys.stderr)
        baseline = None

    rows = check(baseline, fresh, args.tolerance)
    regressions = [r for r in rows if r["verdict"] == "REGRESSION"]
    diff = {"tolerance_default": args.tolerance,
            "n_regressions": len(regressions), "rows": rows}
    if args.diff_out:
        with open(args.diff_out, "w") as f:
            json.dump(diff, f, indent=1, sort_keys=True)
    for r in rows:
        base = r.get("baseline", "-")
        print(f"{r['verdict']:>10}  {r['metric']}: "
              f"baseline={base} fresh={r['fresh']}")
    if regressions:
        print(f"# {len(regressions)} regression(s); see "
              f"{args.diff_out or 'rows above'}", file=sys.stderr)
    return len(regressions)


if __name__ == "__main__":
    sys.exit(main())
