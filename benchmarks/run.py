"""Benchmark driver — one entry per paper table/figure (+ roofline, engine).

Prints ``name,us_per_call,derived`` CSV and writes the structured results to
a BENCH JSON file (default ``benchmarks/BENCH.json``, override with
``BENCH_JSON=path``) so CI can upload it as an artifact and entries stay
comparable across PRs (see README "Benchmark methodology").

  * name        — paper artifact the benchmark reproduces
  * us_per_call — wall time of one benchmark unit (microseconds)
  * derived     — the headline metric(s) the paper reports

``BENCH_QUICK=1`` runs a reduced smoke mode (CI): smaller tx counts, same
assertions except the 1M-tx speedup floor (which needs the full run).

``python benchmarks/run.py --all`` runs NO benchmarks: it aggregates every
``BENCH_*.json`` already in ``benchmarks/`` into one summary table (stdout)
and writes ``BENCH_summary.json`` — the cross-PR comparison view CI
artifacts are diffed against.  The summary embeds the ``repro.api``
NodeSpec preset catalog (``_presets``): each bench declares its node
scenario as data there, so a PR that changes a scenario shows up as a
spec diff in the artifact.
"""
from __future__ import annotations

import json
import os
import sys
import time


def _timed(fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) * 1e6


# headline metric extractors per BENCH file stem (best-effort: files from
# older PRs may miss keys; the aggregator records what it finds)
_HEADLINES = {
    "BENCH_engine": lambda d: {
        "speedup": d["out"]["speedup"], "n_txs": d["out"]["n_txs"]},
    "BENCH_protocol": lambda d: {
        "speedup": d["speedup"],
        "mega_speedup": d["mega_speedup"],
        "fl_per_task_flatness": d["fl_per_task_flatness"],
        "window_loop_speedup": d["window_loop"]["fused_speedup"],
        "window_loop_flatness": d["window_loop"]["per_task_flatness"],
        "assert_point": d["assert_point"]},
    "BENCH_shards": lambda d: {
        "scaling": d["scaling"],
        "wall_scaling": d["wall_scaling"],
        "shard_counts": d["shard_counts"],
        "state_root": d["state_root"]},
    "BENCH_prover": lambda d: {
        "verify_gas_reduction": d["reduction"],
        "widths": d["widths"],
        "backends": sorted(d["backends"])},
    "BENCH_serve": lambda d: {
        "honest_retention": d["honest_retention"],
        "admitted_tps": d["admitted_tps"],
        "n_clients": d["n_clients"]},
    "BENCH": lambda d: {
        "entries": sorted(d["results"])},
}


def aggregate_all(bench_dir: str) -> dict:
    """Fold every BENCH_*.json (and BENCH.json) into one summary dict."""
    summary = {}
    for fname in sorted(os.listdir(bench_dir)):
        stem, ext = os.path.splitext(fname)
        if ext != ".json" or not stem.startswith("BENCH") \
                or stem == "BENCH_summary":
            continue
        path = os.path.join(bench_dir, fname)
        try:
            with open(path) as f:
                data = json.load(f)
        except (OSError, json.JSONDecodeError) as err:
            summary[stem] = {"error": str(err)}
            continue
        entry = {"file": fname, "quick": bool(data.get("quick", False))}
        extractor = _HEADLINES.get(stem)
        if extractor is not None:
            try:
                entry["headline"] = extractor(data)
            except (KeyError, TypeError) as err:
                entry["headline_error"] = repr(err)
        # record any seed config the bench declares, so two summaries are
        # comparable only when they measured the same draw
        seeds = {k: v for k, v in data.items()
                 if isinstance(k, str) and "seed" in k.lower()}
        if seeds:
            entry["seeds"] = seeds
        summary[stem] = entry
    return summary


def run_all(bench_dir: str) -> None:
    summary = aggregate_all(bench_dir)
    print("bench,quick,headline")
    for stem, entry in summary.items():
        headline = entry.get("headline", entry.get("headline_error",
                                                   entry.get("error", "")))
        hl = "|".join(f"{k}={v}" for k, v in headline.items()) \
            if isinstance(headline, dict) else str(headline)
        print(f"{stem},{int(entry.get('quick', False))},{hl}")
    # the scenario catalog every bench builds its nodes from, as data
    from repro.api import describe_presets
    summary["_presets"] = describe_presets()
    print(f"# node presets: {','.join(sorted(summary['_presets']))}",
          file=sys.stderr)
    # deterministic artifact: stable key order, no timestamps — two runs
    # over identical BENCH_*.json inputs produce byte-identical output
    path = os.path.join(bench_dir, "BENCH_summary.json")
    with open(path, "w") as f:
        json.dump(summary, f, indent=1, default=str, sort_keys=True)
    print(f"# wrote {path}", file=sys.stderr)


def main() -> None:
    # invokable from anywhere: python benchmarks/run.py | python -m benchmarks.run
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for p in (os.path.join(root, "src"), root):
        if p not in sys.path:
            sys.path.insert(0, p)
    if "--all" in sys.argv[1:]:
        run_all(os.path.dirname(os.path.abspath(__file__)))
        return
    from benchmarks import (bench_engine_speedup, bench_gas,
                            bench_l1_throughput, bench_l2_throughput,
                            bench_latency, bench_protocol, bench_prover,
                            bench_reputation, bench_roofline, bench_shards)

    quick = os.environ.get("BENCH_QUICK", "") not in ("", "0", "false")
    results = {}
    print("name,us_per_call,derived")

    out, us = _timed(bench_reputation.run)
    results["fig3_reputation_dynamics"] = {"us_per_call": us, "out": out}
    print(f"fig3_reputation_dynamics,{us:.0f},"
          f"good={out['good_final']:.3f}|malicious={out['malicious_final']:.3f}"
          f"|lazy={out['lazy_final']:.3f}")

    out, us = _timed(bench_l1_throughput.run)
    results["fig4_l1_throughput_latency"] = {"us_per_call": us, "out": out}
    print(f"fig4_l1_throughput_latency,{us:.0f},"
          f"peak_tps_submitLocalModel={out['peak_tps_submitLocalModel']:.0f}")

    out, us = _timed(bench_gas.run)
    n_rows = len(out["rows"])
    results["table1_gas_l1_vs_l2"] = {"us_per_call": us / max(n_rows, 1),
                                      "out": out}
    print(f"table1_gas_l1_vs_l2,{us / max(n_rows, 1):.0f},"
          f"max_gas_reduction={out['max_reduction']}x")

    out, us = _timed(bench_l2_throughput.run)
    results["fig5_l2_vs_l1_throughput"] = {"us_per_call": us, "out": out}
    print(f"fig5_l2_vs_l1_throughput,{us:.0f},"
          f"avg_l2_tps={out['avg_l2_tps']:.0f}|best_l2_tps={out['best_l2_tps']:.0f}")

    out, us = _timed(bench_latency.run)
    results["table2_l2_latency"] = {
        "us_per_call": us / max(len(out["rows"]), 1), "out": out}
    print(f"table2_l2_latency,{us / max(len(out['rows']), 1):.0f},"
          f"worst_rel_err={out['worst_rel_err_n>=10']}")

    out, us = _timed(bench_engine_speedup.run, quick=quick)
    results["engine_vector_speedup"] = {"us_per_call": us, "out": out}
    print(f"engine_vector_speedup,{us:.0f},"
          f"speedup={out['speedup']}x|n_txs={out['n_txs']}"
          f"|quick={int(out['quick'])}")

    if not quick:
        # quick/CI mode skips this one: the dedicated bench-shards-smoke
        # CI job already runs the reduced 2-shard config (running it here
        # too would duplicate the compute and the artifact)
        out, us = _timed(bench_shards.run, quick=False)
        results["shard_fabric_scaling"] = {"us_per_call": us, "out": out}
        print(f"shard_fabric_scaling,{us:.0f},"
              f"scaling={out['scaling']}x|shards={out['shard_counts'][-1]}"
              f"|state_root={out['state_root']}|quick=0")

    if not quick:
        # quick/CI mode skips this one: the dedicated bench-prover-smoke
        # CI job already runs the reduced width sweep (running it here too
        # would duplicate the compute and the artifact)
        out, us = _timed(bench_prover.run, quick=False)
        results["prover_aggregation_sweep"] = {"us_per_call": us, "out": out}
        print(f"prover_aggregation_sweep,{us:.0f},"
              f"verify_gas_reduction={out['reduction']}x"
              f"|widths={out['widths'][-1]}|quick=0")

    if not quick:
        # quick/CI mode skips this one: the dedicated bench-protocol-smoke
        # CI job already runs the reduced sweep (running it here too would
        # duplicate the compute and double the timing-assert flake surface)
        out, us = _timed(bench_protocol.run, quick=False)
        results["protocol_multitask_scheduler"] = {"us_per_call": us,
                                                   "out": out}
        sch_point = out["scheduler_grid"][
            "tasks={n_tasks},trainers={n_trainers}".format(
                **out["assert_point"])]
        print(f"protocol_multitask_scheduler,{us:.0f},"
              f"speedup={out['speedup']}x|tps={sch_point['tps']}"
              f"|gas_reduction={sch_point['gas_reduction']}x|quick=0")

    out, us = _timed(bench_roofline.run)
    s = out["summary"]
    results["roofline_dryrun_cells"] = {"us_per_call": us, "summary": s}
    print(f"roofline_dryrun_cells,{us:.0f},"
          f"ok={s['n_ok']}|err={s['n_error']}|skip={s['n_skipped']}"
          f"|dominant={s['dominant_histogram']}")

    path = os.environ.get(
        "BENCH_JSON",
        os.path.join(os.path.dirname(os.path.abspath(__file__)),
                     "BENCH.json"))
    with open(path, "w") as f:
        json.dump({"quick": quick, "results": results}, f, indent=1,
                  default=str, sort_keys=True)
    print(f"# wrote {path}", file=sys.stderr)


if __name__ == '__main__':
    main()
