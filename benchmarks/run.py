"""Benchmark driver — one entry per paper table/figure (+ roofline).

Prints ``name,us_per_call,derived`` CSV:
  * name        — paper artifact the benchmark reproduces
  * us_per_call — wall time of one benchmark unit (microseconds)
  * derived     — the headline metric(s) the paper reports
"""
from __future__ import annotations

import sys
import time


def _timed(fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) * 1e6


def main() -> None:
    sys.path.insert(0, "src")
    from benchmarks import (bench_gas, bench_l1_throughput,
                            bench_l2_throughput, bench_latency,
                            bench_reputation, bench_roofline)

    print("name,us_per_call,derived")

    out, us = _timed(bench_reputation.run)
    print(f"fig3_reputation_dynamics,{us:.0f},"
          f"good={out['good_final']:.3f}|malicious={out['malicious_final']:.3f}"
          f"|lazy={out['lazy_final']:.3f}")

    out, us = _timed(bench_l1_throughput.run)
    print(f"fig4_l1_throughput_latency,{us:.0f},"
          f"peak_tps_submitLocalModel={out['peak_tps_submitLocalModel']:.0f}")

    out, us = _timed(bench_gas.run)
    n_rows = len(out["rows"])
    print(f"table1_gas_l1_vs_l2,{us / max(n_rows, 1):.0f},"
          f"max_gas_reduction={out['max_reduction']}x")

    out, us = _timed(bench_l2_throughput.run)
    print(f"fig5_l2_vs_l1_throughput,{us:.0f},"
          f"avg_l2_tps={out['avg_l2_tps']:.0f}|best_l2_tps={out['best_l2_tps']:.0f}")

    out, us = _timed(bench_latency.run)
    print(f"table2_l2_latency,{us / max(len(out['rows']), 1):.0f},"
          f"worst_rel_err={out['worst_rel_err_n>=10']}")

    out, us = _timed(bench_roofline.run)
    s = out["summary"]
    print(f"roofline_dryrun_cells,{us:.0f},"
          f"ok={s['n_ok']}|err={s['n_error']}|skip={s['n_skipped']}"
          f"|dominant={s['dominant_histogram']}")


if __name__ == '__main__':
    main()
