"""The paper's own end-to-end workload (§VI): LeNet-5 federated training with
good / malicious / lazy trainers, DON evaluation, reputation-weighted
aggregation (Eq. 1), zk-rollup settlement, escrow payouts.

This is the Fig. 3 experiment as a runnable script.

Usage:
    PYTHONPATH=src python examples/fl_mnist.py --tasks 5 --rounds 4
"""
import argparse

import jax
import jax.numpy as jnp

from repro.api import ChainSpec, FLTaskSpec, NodeSpec, RollupSpec
from repro.configs.registry import get_config
from repro.data.pipeline import client_batch_fn
from repro.data.synthetic import make_mnist_like
from repro.fl.client import ClientConfig, TrainingAgent
from repro.fl.dp import DPConfig
from repro.fl.partition import dirichlet_partition, skew_report
from repro.fl.server import AutoDFL
from repro.models import lenet
from repro.models.model import build_model
from repro.optim.optimizers import OptimizerSpec, make_optimizer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tasks", type=int, default=5)
    ap.add_argument("--rounds", type=int, default=4)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--no-rollup", action="store_true",
                    help="single-layer L1 baseline (paper Fig. 5 comparison)")
    args = ap.parse_args()

    cfg = get_config("lenet5")
    model = build_model(cfg)
    opt = make_optimizer(OptimizerSpec(name="sgdm", lr=0.05, grad_clip=5.0))

    xs, ys = make_mnist_like(2048, seed=1)
    val = {"images": jnp.asarray(xs[:256]), "labels": jnp.asarray(ys[:256])}
    parts = dirichlet_partition(ys[256:], args.clients, alpha=0.8, seed=0)
    print("non-IID partition:", skew_report(ys[256:], parts)["sizes"])
    raw = client_batch_fn(xs[256:], ys[256:], parts, 64)
    bf = lambda c, r: {k: jnp.asarray(v) for k, v in raw(c, r).items()}
    eval_fn = jax.jit(lambda p, b: lenet.accuracy(cfg, p, b))

    # public API: the node is described by a spec — the paper-faithful
    # object engine, with the L2 rollup unless --no-rollup asked for the
    # single-layer baseline
    spec = NodeSpec(chain=ChainSpec(backend="object"),
                    rollup=None if args.no_rollup else RollupSpec())
    sys = AutoDFL(model, opt, args.clients, eval_fn, val, spec=spec)
    behaviors = (["good", "good", "malicious", "lazy"] * 8)[: args.clients]
    agents = [TrainingAgent(
        ClientConfig(f"trainer{i}", behaviors[i],
                     dp=DPConfig(noise_multiplier=0.05)),
        model, opt, sys.store, bf, seed=i) for i in range(args.clients)]

    print(f"{'task':>5s} | " + " | ".join(
        f"{b[:4]}{i}" for i, b in enumerate(behaviors)))
    res = None
    for t in range(args.tasks):
        res = sys.run_task(FLTaskSpec(f"task{t}", rounds=args.rounds),
                           agents, bf)
        reps = " | ".join(f"{r:5.3f}" for r in res.reputations)
        print(f"{t:5d} | {reps}")

    acc = float(eval_fn(res.global_params, val))
    print(f"\nglobal model accuracy: {acc:.3f}")
    print(f"payouts (last task): "
          f"{ {k: round(v, 2) for k, v in res.payouts.items()} }")
    if sys.rollup is not None:
        total_l2 = sum(b['total'] for b in sys.rollup.gas_log)
        print(f"rollup: {len(sys.rollup.batches)} batches, "
              f"settled gas={total_l2:.0f}")
    print(f"L1 chain: {len(sys.chain.blocks)} blocks, "
          f"gas={sys.chain.total_gas:.0f}")


if __name__ == "__main__":
    main()
