"""Quickstart: the AutoDFL reproduction in ~80 lines.

1. Drive the public node API: NodeSpec -> NodeClient -> tx receipts,
   account views, state root (the zk-rollup RPC surface).
2. Build any assigned architecture from the registry (--arch).
3. Run a few training steps on CPU with a reduced config.
4. Run one reputation-weighted rollup round (the paper's technique).

Usage:
    PYTHONPATH=src python examples/quickstart.py --arch qwen2-0.5b --steps 3
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import NodeClient, NodeSpec, ShardSpec
from repro.configs.registry import REGISTRY, reduced_config
from repro.fl.round import FLRoundSpec, build_fl_round
from repro.models.model import build_model
from repro.optim.optimizers import OptimizerSpec, make_optimizer


def api_demo():
    """The public API path: typed spec -> client -> receipts + events."""
    spec = NodeSpec(shards=ShardSpec(count=2))    # 2-shard L2 over one L1
    client = NodeClient.from_spec(spec)
    receipts = [client.submit("submitLocalModel", f"trainer{i % 4}")
                for i in range(25)]
    client.flush()                                 # seal + prove + settle
    client.run_until(5.0)                          # L1 blocks to t=5s
    r = client.refresh(receipts[0])
    print(f"tx receipt: status={r.status} shard={r.shard} batch={r.batch} "
          f"aggregate={r.aggregate_ref} l1_block={r.block} "
          f"gas={r.gas_breakdown['batch_total']:.0f} "
          f"verify_share={r.gas_breakdown['verify_share']:.1f}")
    acct = client.get_account("trainer0")
    print(f"account trainer0: submissions={acct.submissions} "
          f"reputation={acct.reputation:.2f}")
    events = client.events()                       # typed, pull-based
    kinds = sorted({e.kind for e in events})
    windows = [e for e in events if e.kind == "window_settled"]
    print(f"state root: {client.state_root()}  "
          f"(events: {kinds}, windows: {len(windows)})")
    assert r.status == "finalized" and acct.submissions > 0 and windows
    assert windows[-1].fabric_root
    assert "block_packed" in client.capabilities()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b", choices=sorted(REGISTRY))
    ap.add_argument("--steps", type=int, default=3)
    args = ap.parse_args()

    api_demo()

    cfg = reduced_config(REGISTRY[args.arch])
    print(f"arch={cfg.name} family={cfg.family} (reduced config for CPU)")
    model = build_model(cfg)
    opt = make_optimizer(OptimizerSpec(name="sgdm", lr=0.05))
    params = model.init_params(jax.random.key(0))
    state = opt.init(params)

    rng = np.random.default_rng(0)
    B, S = 2, 16

    def batch(seed):
        toks = rng.integers(0, cfg.vocab_size, (B, S + 1))
        b = {"tokens": jnp.asarray(toks[:, :-1], jnp.int32),
             "labels": jnp.asarray(toks[:, 1:], jnp.int32)}
        if cfg.input_mode == "embeds":
            b = {"embeds": jnp.asarray(
                     rng.normal(0, 0.02, (B, S, cfg.d_model)), jnp.bfloat16),
                 "positions": jnp.broadcast_to(
                     jnp.arange(S, dtype=jnp.int32), (3, B, S)),
                 "labels": b["labels"]}
        elif cfg.input_mode == "audio":
            b["audio_embeds"] = jnp.asarray(
                rng.normal(0, 0.02, (B, cfg.enc_seq, cfg.d_model)),
                jnp.bfloat16)
        elif cfg.family == "conv":
            b = {"images": jnp.asarray(rng.normal(size=(B, 32, 32, 1)),
                                       jnp.float32),
                 "labels": jnp.zeros((B,), jnp.int32)}
        return b

    @jax.jit
    def step(p, o, b):
        loss, g = jax.value_and_grad(lambda pp: model.loss(pp, b))(p)
        p, o, _ = opt.update(g, o, p)
        return p, o, loss

    for i in range(args.steps):
        params, state, loss = step(params, state, batch(i))
        print(f"step {i}: loss={float(loss):.4f}")

    if cfg.family != "conv" and cfg.input_mode == "tokens":
        # one rollup round with 2 virtual trainers (the paper's technique)
        T, H = 2, 2
        fl_round = build_fl_round(model, opt, FLRoundSpec(T, H, B))
        params_T = jax.tree.map(lambda l: jnp.stack([l] * T), params)
        opt_T = jax.tree.map(lambda l: jnp.stack([l] * T), state)
        toks = rng.integers(0, cfg.vocab_size, (T, H, B, S + 1))
        batches = {"tokens": jnp.asarray(toks[..., :-1], jnp.int32),
                   "labels": jnp.asarray(toks[..., 1:], jnp.int32)}
        scores = jnp.array([0.9, 0.6])
        params_T, opt_T, m = jax.jit(fl_round)(params_T, opt_T, scores,
                                               batches)
        print(f"rollup round: loss={float(m['loss']):.4f} "
              f"distances={np.asarray(m['distances']).round(3)} "
              f"digest=0x{int(m['digest']):08x}")
    print("done.")


if __name__ == "__main__":
    main()
