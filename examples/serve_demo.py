"""Distributed serving demo: prefill + batched decode with a KV cache,
including a reputation-gated request path (requests from clients below the
trust line are rejected — the serving-side use of the on-chain reputation).

Usage:
    PYTHONPATH=src python examples/serve_demo.py --arch yi-6b --tokens 12
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import REGISTRY, reduced_config
from repro.core.reputation import ReputationParams, init_book
from repro.models.model import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--tokens", type=int, default=12)
    args = ap.parse_args()

    cfg = reduced_config(REGISTRY[args.arch])
    assert cfg.input_mode == "tokens" and not cfg.enc_dec, \
        "demo drives the token-LM serve path"
    model = build_model(cfg)
    params = model.init_params(jax.random.key(0))

    # -- reputation gate: only requests from trusted identities are served --
    book = init_book(args.batch)
    rp = ReputationParams()
    trusted = np.asarray(book.reputation) >= rp.r_min
    print(f"request gate: {int(trusted.sum())}/{args.batch} clients >= "
          f"R_min={rp.r_min} (newcomers start at {rp.r_init})")

    rng = np.random.default_rng(0)
    B, P = args.batch, args.prompt_len
    prompts = rng.integers(0, cfg.vocab_size, (B, P))
    max_len = P + args.tokens + 1

    # -- prefill: batch forward, build the KV cache via teacher forcing ------
    state = model.init_decode_state(B, max_len)
    decode = jax.jit(model.decode)
    t0 = time.perf_counter()
    logits = None
    for t in range(P):
        logits, state = decode(params, state,
                               {"tokens": jnp.asarray(prompts[:, t:t + 1],
                                                      jnp.int32),
                                "pos": jnp.int32(t)})
    t_prefill = time.perf_counter() - t0

    # -- batched greedy decode ------------------------------------------------
    out_tokens = []
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    t0 = time.perf_counter()
    for t in range(P, P + args.tokens):
        out_tokens.append(np.asarray(tok)[:, 0])
        logits, state = decode(params, state,
                               {"tokens": tok, "pos": jnp.int32(t)})
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    t_decode = time.perf_counter() - t0

    out = np.stack(out_tokens, 1)
    print(f"prefill: {P} steps in {t_prefill:.2f}s "
          f"({B * P / max(t_prefill, 1e-9):.1f} tok/s)")
    print(f"decode:  {args.tokens} steps in {t_decode:.2f}s "
          f"({B * args.tokens / max(t_decode, 1e-9):.1f} tok/s)")
    for b in range(min(B, 2)):
        print(f"seq{b}: prompt={prompts[b, :6].tolist()}... "
              f"generated={out[b, :8].tolist()}...")


if __name__ == "__main__":
    main()
