"""Serving quickstart: boot the node service, drive it over real HTTP.

Boots ``repro.serve``'s admission-controlled node service on an
ephemeral port (in-process, stdlib only), then walks the whole client
flow on the wire — submit -> receipt polling -> finalize -> account /
state-root / event reads — asserting each step so CI can run this file
as the serving smoke test.

Usage:
    PYTHONPATH=src python examples/serve_quickstart.py
"""
import asyncio

from repro.api import AdmissionSpec, NodeSpec, ServeSpec
from repro.serve import HttpNodeServer, NodeService, http_rpc


async def main() -> None:
    spec = ServeSpec(node=NodeSpec(), port=0,
                     admission=AdmissionSpec(rate_limit=200.0, burst=50.0))
    server = HttpNodeServer(NodeService(spec))
    host, port = await server.start()
    print(f"node service on http://{host}:{port}/rpc")

    # 1. submit a few transactions from two trainers
    refs = []
    for i in range(6):
        status, body = await http_rpc(host, port, "submit", {
            "fn": "submitLocalModel", "sender": f"trainer{i % 2}",
            "at": 0.1 * i})
        assert status == 200, (status, body)
        assert body["result"]["status"] == "queued", body
        refs.append(body["result"]["ref"])
    print(f"submitted {len(refs)} txs, refs {refs[0]}..{refs[-1]}")

    # 2. a queued tx has a pollable receipt before it lands on-ledger
    _, body = await http_rpc(host, port, "receipt", {"ref": refs[0]})
    assert body["result"]["status"] in ("queued", "submitted"), body

    # 3. finalize: drain the pool, settle the open session
    _, body = await http_rpc(host, port, "flush")
    assert body["result"]["status"] == "finalized", body
    print(f"finalized: {body['result']['flushed']} txs on-ledger")

    # 4. receipts now resolve against the ledger with a proof lifecycle
    _, body = await http_rpc(host, port, "receipt", {"ref": refs[0]})
    rcpt = body["result"]
    assert rcpt["status"] in ("finalized", "confirmed"), rcpt
    print(f"receipt {refs[0]}: {rcpt['status']}, "
          f"gas breakdown keys {sorted(rcpt['gas_breakdown'])}")

    # 5. account view + state root + cursor-paged events
    _, body = await http_rpc(host, port, "get_account",
                             {"address": "trainer0"})
    assert body["result"]["submissions"] == 3, body
    _, body = await http_rpc(host, port, "state_root")
    root = body["result"]["state_root"]
    assert root
    _, body = await http_rpc(host, port, "events", {"cursor": 0})
    events = body["result"]["events"]
    assert events and body["result"]["dropped"] == 0
    kinds = sorted({e["kind"] for e in events})
    print(f"state root {root}; {len(events)} events, kinds {kinds}")

    # 6. admission metrics are live counters
    _, body = await http_rpc(host, port, "metrics")
    assert body["result"]["admitted"] == len(refs), body
    print(f"metrics: {body['result']}")

    await server.close()
    print("serving quickstart OK")


if __name__ == "__main__":
    asyncio.run(main())
