"""End-to-end driver: rollup-FL training of an LM across the production mesh.

This is the launch/train.py entry exercised end-to-end: on real hardware it
runs the full pipeline on the 16x16 (or 2x16x16) mesh; on this CPU container
pass --host-mesh to run the REAL sharded code path on a 1x1 mesh, or use
launch/dryrun.py for the 256/512-chip compile proof.

Usage:
    PYTHONPATH=src python examples/train_multi_pod.py \
        --arch qwen2-0.5b --rounds 3 --local-steps 2 --host-mesh --reduced
"""

from repro.launch.train import main as train_main


if __name__ == "__main__":
    train_main()
