"""Invariant-aware static checker + runtime sanitizer for the stack.

Two enforcement layers over ONE shared invariant catalog
(``analysis/invariants.py``):

- ``analysis/lint.py`` — AST-based static pass (``python -m
  repro.analysis.lint src/repro``) with repo-specific rules R001-R005.
- ``analysis/sanitize.py`` — runtime sanitizer (``REPRO_SANITIZE=1``)
  that wraps the engine faces and cross-checks the same invariants
  dynamically (R001/R005-R007).

See docs/ANALYSIS.md for the rule catalog and suppression syntax.
"""
from repro.analysis.invariants import CATALOG, Invariant  # noqa: F401
