"""HLO cost walker: loop-aware FLOP / byte / collective accounting.

``compiled.cost_analysis()`` counts a ``lax.scan`` body exactly ONCE (no
trip-count multiplier) — measured on this container, a scanned 8-step matmul
reports 1/8 of the unrolled FLOPs.  Since every layer stack in this framework
is scanned (HLO-size hygiene), we walk the post-SPMD HLO text ourselves:

  * while loops  -> body cost x trip count (trip parsed from the condition)
  * fusions      -> internal FLOPs counted, internal bytes NOT (VMEM-local)
  * collectives  -> payload bytes per kind, loop-multiplied, with group size
  * dots         -> 2 * prod(out) * prod(contracting)

The walker is validated against cost_analysis() on loop-free programs
(tests/test_hlo_cost.py) and against analytic 6*N*D model FLOPs.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
    "f8e4m3fnuz": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "and", "or", "xor", "not", "negate", "abs", "sign", "compare", "select",
    "exponential", "exponential-minus-one", "log", "log-plus-one", "tanh",
    "logistic", "sqrt", "rsqrt", "cbrt", "sine", "cosine", "tan", "atan2",
    "erf", "floor", "ceil", "round-nearest-afz", "round-nearest-even",
    "clamp", "remainder", "shift-left", "shift-right-arithmetic",
    "shift-right-logical", "is-finite", "expm1", "log1p",
}

_NO_BYTES = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "add-dependency", "partition-id", "replica-id", "iota",
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "collective-broadcast", "ragged-all-to-all")


@dataclasses.dataclass
class Shape:
    dtype: str
    dims: Tuple[int, ...]

    @property
    def elems(self) -> int:
        n = 1
        for d in self.dims:
            n *= d
        return n

    @property
    def bytes(self) -> int:
        return self.elems * _DTYPE_BYTES.get(self.dtype, 4)


@dataclasses.dataclass
class Op:
    name: str
    opcode: str
    shapes: List[Shape]          # output shapes (tuple flattened)
    operands: List[str]
    attrs: str                   # raw attr text after the operand list
    line: str

    @property
    def out_bytes(self) -> int:
        return sum(s.bytes for s in self.shapes)

    @property
    def out_elems(self) -> int:
        return sum(s.elems for s in self.shapes)


@dataclasses.dataclass
class CompCost:
    flops: float = 0.0
    transcendentals: float = 0.0
    bytes: float = 0.0
    convert_bytes: float = 0.0   # bf16<->f32 converts (CPU-backend artifact)
    collective_bytes: float = 0.0
    collective_wire_bytes: float = 0.0
    collectives: Dict[str, float] = dataclasses.field(default_factory=dict)
    collective_counts: Dict[str, float] = dataclasses.field(default_factory=dict)
    custom_calls: List[str] = dataclasses.field(default_factory=list)
    warnings: List[str] = dataclasses.field(default_factory=list)

    def add(self, other: "CompCost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.transcendentals += other.transcendentals * mult
        self.bytes += other.bytes * mult
        self.convert_bytes += other.convert_bytes * mult
        self.collective_bytes += other.collective_bytes * mult
        self.collective_wire_bytes += other.collective_wire_bytes * mult
        for k, v in other.collectives.items():
            self.collectives[k] = self.collectives.get(k, 0.0) + v * mult
        for k, v in other.collective_counts.items():
            self.collective_counts[k] = self.collective_counts.get(k, 0.0) + v * mult
        self.custom_calls.extend(other.custom_calls)
        self.warnings.extend(other.warnings)


_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def parse_shapes(type_str: str) -> List[Shape]:
    out = []
    for m in _SHAPE_RE.finditer(type_str):
        dtype = m.group(1)
        if dtype not in _DTYPE_BYTES:
            continue
        dims = tuple(int(x) for x in m.group(2).split(",") if x)
        out.append(Shape(dtype, dims))
    return out


_OP_HEAD_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->.*\{\s*$")


def _parse_op_line(line: str):
    """Parse '%name = TYPE opcode(operands), attrs'.  TYPE may be a tuple
    containing /*index=N*/ comments, so scan balanced parens manually."""
    m = _OP_HEAD_RE.match(line)
    if not m:
        return None
    name = m.group(1)
    rest = line[m.end():]
    if rest.startswith("("):           # tuple type: find matching close paren
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        else:
            return None
        type_str, rest = rest[:i + 1], rest[i + 1:]
    else:                               # plain type token
        sp = rest.find(" ")
        if sp < 0:
            return None
        type_str, rest = rest[:sp], rest[sp:]
    rest = rest.lstrip()
    m2 = re.match(r"([\w\-]+)\(", rest)
    if not m2:
        return None
    opcode = m2.group(1)
    return name, type_str, opcode, rest[m2.end():]


def _split_operands_attrs(rest: str) -> Tuple[str, str]:
    """rest starts after the opening '(' of the op."""
    depth = 1
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return rest[:i], rest[i + 1:]
    return rest, ""


class HloModule:
    def __init__(self, text: str):
        self.computations: Dict[str, List[Op]] = {}
        self.entry: Optional[str] = None
        self._parse(text)
        self._symbol: Dict[str, Dict[str, List[Shape]]] = {}
        for cname, ops in self.computations.items():
            self._symbol[cname] = {op.name: op.shapes for op in ops}
        self._memo: Dict[str, CompCost] = {}

    def _parse(self, text: str):
        current = None
        is_entry = False
        for line in text.splitlines():
            if not line.strip():
                continue
            if line.startswith("HloModule"):
                continue
            hdr = _COMP_HDR_RE.match(line)
            if hdr and ("->" in line) and line.rstrip().endswith("{"):
                current = hdr.group(1)
                self.computations[current] = []
                if line.lstrip().startswith("ENTRY"):
                    self.entry = current
                continue
            if line.strip() == "}":
                current = None
                continue
            if current is None:
                continue
            parsed = _parse_op_line(line)
            if not parsed:
                continue
            name, type_str, opcode, rest = parsed
            operands_str, attrs = _split_operands_attrs(rest)
            operands = re.findall(r"%([\w.\-]+)", operands_str)
            self.computations[current].append(
                Op(name, opcode, parse_shapes(type_str), operands, attrs, line))

    # -- helpers ----------------------------------------------------------------
    def _operand_shapes(self, comp: str, op: Op) -> List[Shape]:
        table = self._symbol[comp]
        shapes = []
        for o in op.operands:
            shapes.extend(table.get(o, []))
        return shapes

    def _trip_count(self, cond_comp: str) -> Tuple[float, Optional[str]]:
        ops = self.computations.get(cond_comp, [])
        consts = []
        for op in ops:
            if op.opcode == "constant":
                m = re.search(r"constant\((-?\d+)\)", op.line)
                if m:
                    consts.append(int(m.group(1)))
        if consts:
            return float(max(consts)), None
        return 1.0, f"unparseable trip count in {cond_comp}"

    @staticmethod
    def _group_size(attrs: str, default: float = 2.0) -> float:
        # replica_groups=[8,4]<=[32]  -> groups of 4;  or explicit {{0,1},{2,3}}
        m = re.search(r"replica_groups=\[(\d+),(\d+)\]", attrs)
        if m:
            return float(m.group(2))
        m = re.search(r"replica_groups=\{\{([\d,]+)\}", attrs)
        if m:
            return float(len(m.group(1).split(",")))
        return default

    def _fusion_param_bytes(self, comp: str, operand_shapes) -> float:
        """Sum effective read bytes across a fused computation's parameters.

        A param consumed only through slicing ops reads just the windows; a
        param consumed only as the DESTINATION (operand 0) of
        dynamic-update-slice is aliased in place (0 bytes)."""
        ops = self.computations.get(comp, [])
        params: Dict[int, str] = {}
        for op in ops:
            if op.opcode == "parameter":
                m = re.search(r"parameter\((\d+)\)", op.line)
                if m:
                    params[int(m.group(1))] = op.name
        total = 0.0
        for idx, pname in params.items():
            if idx >= len(operand_shapes):
                continue
            full = operand_shapes[idx].bytes
            consumers = [o for o in ops if pname in o.operands]
            if not consumers:
                continue
            eff = 0.0
            cheap = True
            for o in consumers:
                if o.opcode in ("slice", "dynamic-slice", "gather"):
                    eff += o.out_bytes
                elif (o.opcode == "dynamic-update-slice"
                      and o.operands and o.operands[0] == pname):
                    eff += 0.0          # aliased in-place destination
                elif o.opcode in ("bitcast", "get-tuple-element"):
                    cheap = False       # view feeding unknown uses: be safe
                    break
                else:
                    cheap = False
                    break
            total += eff if cheap else full
        if not params:
            return sum(s.bytes for s in operand_shapes)
        return total

    def _fusion_out_bytes(self, comp: str, op: Op) -> float:
        ops = self.computations.get(comp, [])
        by_name = {o.name: o for o in ops}
        root = None
        for o in ops:
            if o.line.lstrip().startswith("ROOT"):
                root = o
                break
        # unwrap bitcast/tuple around a dynamic-update-slice root
        seen = 0
        while root is not None and root.opcode in ("bitcast", "tuple") \
                and root.operands and seen < 4:
            root = by_name.get(root.operands[0])
            seen += 1
        if root is not None and root.opcode == "dynamic-update-slice":
            upd = self._operand_shapes(comp, root)
            if len(upd) > 1:
                return float(upd[1].bytes)
        return float(op.out_bytes)

    # -- cost walk ---------------------------------------------------------------
    def cost(self, comp: Optional[str] = None, _fused: bool = False) -> CompCost:
        comp = comp or self.entry
        key = (comp, _fused)
        if key in self._memo:
            return self._memo[key]
        total = CompCost()
        for op in self.computations.get(comp, []):
            total.add(self._op_cost(comp, op, _fused))
        self._memo[key] = total
        return total

    def _op_cost(self, comp: str, op: Op, fused: bool) -> CompCost:
        c = CompCost()
        oc = op.opcode
        operand_shapes = self._operand_shapes(comp, op)
        in_bytes = sum(s.bytes for s in operand_shapes)

        if oc == "while":
            m = re.search(r"condition=%?([\w.\-]+)", op.attrs)
            b = re.search(r"body=%?([\w.\-]+)", op.attrs)
            trip, warn = self._trip_count(m.group(1)) if m else (1.0, "no cond")
            if warn:
                c.warnings.append(warn)
            if b:
                c.add(self.cost(b.group(1)), trip)
            return c
        if oc == "fusion":
            m = re.search(r"calls=%?([\w.\-]+)", op.attrs)
            called = m.group(1) if m else None
            if called:
                sub = self.cost(called, _fused=True)
                c.flops += sub.flops
                c.transcendentals += sub.transcendentals
                c.collective_bytes += sub.collective_bytes
                c.collective_wire_bytes += sub.collective_wire_bytes
                for k, v in sub.collectives.items():
                    c.collectives[k] = c.collectives.get(k, 0) + v
                c.custom_calls.extend(sub.custom_calls)
                c.warnings.extend(sub.warnings)
            if not fused:
                # Effective boundary traffic: a param consumed ONLY through
                # slicing ops inside the fusion reads just the window; a
                # root dynamic-update-slice writes just the update (aliased).
                eff_in = self._fusion_param_bytes(called, operand_shapes) \
                    if called else in_bytes
                eff_out = self._fusion_out_bytes(called, op) if called \
                    else op.out_bytes
                c.bytes += eff_in + eff_out
            return c
        if oc in ("call", "conditional"):
            for target in re.findall(
                    r"(?:to_apply|branch_computations=\{|true_computation"
                    r"|false_computation)=?%?([\w.\-]+)",
                    op.attrs):
                c.add(self.cost(target))
            if not fused:
                c.bytes += in_bytes + op.out_bytes
            return c
        base = oc
        for suf in ("-start", "-done"):
            if base.endswith(suf):
                base = base[: -len(suf)]
        if base in _COLLECTIVES:
            if oc.endswith("-done"):
                return c
            payload = max(in_bytes, op.out_bytes)
            n = self._group_size(op.attrs)
            if base == "all-reduce":
                wire = 2.0 * payload * (n - 1) / n
            elif base in ("all-gather", "reduce-scatter", "all-to-all"):
                wire = payload * (n - 1) / n
            else:  # collective-permute / broadcast
                wire = payload
            c.collective_bytes += payload
            c.collective_wire_bytes += wire
            c.collectives[base] = c.collectives.get(base, 0) + payload
            c.collective_counts[base] = c.collective_counts.get(base, 0) + 1
            if not fused:
                c.bytes += in_bytes + op.out_bytes
            return c
        if oc == "custom-call":
            c.custom_calls.append(op.line.strip()[:160])
            if not fused:
                c.bytes += in_bytes + op.out_bytes
            return c
        if oc == "dot":
            out_elems = op.out_elems
            lhs = operand_shapes[0] if operand_shapes else Shape("f32", ())
            m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.attrs)
            contract = 1
            if m and m.group(1):
                for d in m.group(1).split(","):
                    contract *= lhs.dims[int(d)]
            c.flops += 2.0 * out_elems * contract
            if not fused:
                c.bytes += in_bytes + op.out_bytes
            return c
        if oc == "convolution":
            out_elems = op.out_elems
            # kernel = operand 1: prod(all dims except output-feature dim)
            ker = operand_shapes[1] if len(operand_shapes) > 1 else Shape("f32", (1,))
            m = re.search(r"dim_labels=\w+_(\w+)->", op.attrs)
            ker_prod = ker.elems
            if m:
                lbl = m.group(1)
                o_idx = lbl.index("o")
                ker_prod = ker.elems // max(ker.dims[o_idx], 1)
            c.flops += 2.0 * out_elems * ker_prod
            if not fused:
                c.bytes += in_bytes + op.out_bytes
            return c
        if oc in ("reduce", "reduce-window"):
            half = max(1, len(operand_shapes) // 2)
            c.flops += float(sum(s.elems for s in operand_shapes[:half]))
            if not fused:
                c.bytes += in_bytes + op.out_bytes
            return c
        if oc == "convert":
            if not fused:
                c.bytes += in_bytes + op.out_bytes
                c.convert_bytes += in_bytes + op.out_bytes
            return c
        if oc in _ELEMENTWISE:
            c.flops += float(op.out_elems)
            if oc in ("exponential", "log", "tanh", "logistic", "sqrt", "rsqrt",
                      "sine", "cosine", "power", "erf", "expm1", "log1p"):
                c.transcendentals += float(op.out_elems)
            if not fused:
                c.bytes += in_bytes + op.out_bytes
            return c
        if oc in _NO_BYTES:
            return c
        # slicing/gather ops only touch the selected window, not the full
        # operand (and DUS/scatter alias their buffer in place): count the
        # moved window, not the whole array.
        if oc in ("slice", "dynamic-slice", "gather"):
            if not fused:
                c.bytes += 2.0 * op.out_bytes
            return c
        if oc in ("dynamic-update-slice", "scatter"):
            upd = operand_shapes[1].bytes if len(operand_shapes) > 1 else op.out_bytes
            if not fused:
                c.bytes += 2.0 * upd
            if oc == "scatter":
                c.flops += float(operand_shapes[-1].elems if operand_shapes else 0)
            return c
        if oc == "broadcast":
            if not fused:
                c.bytes += in_bytes + op.out_bytes
            return c
        # data movement (copy, transpose, reshape, concatenate, reverse, pad...)
        if not fused:
            c.bytes += in_bytes + op.out_bytes
        return c


def analyze(compiled_text: str) -> CompCost:
    return HloModule(compiled_text).cost()
