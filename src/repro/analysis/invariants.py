"""THE shared invariant catalog for the static checker and the sanitizer.

Every rule the stack enforces lives here exactly once: the AST pass
(analysis/lint.py) and the runtime sanitizer (analysis/sanitize.py) are
two enforcement layers over this one table, so a rule id printed by
either layer resolves to the same contract, rationale and fix hint.

R001-R005 and R008 have a static form; R001 and R005-R007 have a
dynamic form (some contracts — gas conservation, receipt lifecycle —
only exist at run time, so the sanitizer carries rules the AST pass
cannot).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

#: StateArrays columns whose writes must be paired with ``mark_dirty``
#: (mirrors core.state.STATE_SCHEMA; kept literal so the linter does not
#: import numpy-heavy modules to analyze source text).
STATE_COLUMNS: Tuple[str, ...] = (
    "balances", "stake", "reputation",
    "tasks_published", "submissions", "rep_events",
)

#: kernel-registry contract: the NumPy mirror is semantics-of-record and
#: every op must carry at least these impl families (R002).
REQUIRED_MIRROR_IMPL = "numpy"
MIN_IMPLS_PER_OP = 3

#: determinism sweep seeds (R003): classes whose methods anchor the
#: reachability walk, plus the free functions on the digest path.
DETERMINISM_SEED_CLASSES: Tuple[str, ...] = ("FusedWindowLoop", "StateArrays")
DETERMINISM_SEED_FUNCS: Tuple[str, ...] = (
    "canonical_bytes", "chunked_root", "chunk_fold_digests",
    "_fold_digests", "_seal_digests",
)

#: the one module allowed to mutate EventLog internals (R005).
EVENTLOG_OWNER_MODULE = "core/events.py"

#: admission-purity sweep seeds (R008): the mempool admission layer —
#: every method of these classes (and everything they reach) must
#: decide on modeled time alone, never the wall clock.
ADMISSION_SEED_CLASSES: Tuple[str, ...] = ("AdmissionController",
                                           "PendingPool")
ADMISSION_SEED_FUNCS: Tuple[str, ...] = ()


@dataclasses.dataclass(frozen=True)
class Invariant:
    """One contract: id, what it protects, and how each layer enforces it."""

    rule: str            # "R001"
    title: str
    rationale: str       # why the contract exists (one paragraph)
    fix_hint: str        # the canonical remediation, shown with findings
    static: bool         # enforced by analysis/lint.py
    dynamic: bool        # enforced by analysis/sanitize.py


CATALOG: Dict[str, Invariant] = {inv.rule: inv for inv in (
    Invariant(
        rule="R001",
        title="StateArrays writes must be paired with mark_dirty",
        rationale=(
            "The incremental dirty-chunk commitment (core/state.py) only "
            "refolds chunks covered by mark_dirty; a column write without "
            "it silently diverges the cached root from the full refold."),
        fix_hint=(
            "call state.mark_dirty(ids) after the write (same function, "
            "same id set), or route through a Tx handler that does"),
        static=True, dynamic=True,
    ),
    Invariant(
        rule="R002",
        title="kernel registry ops carry numpy mirror + >=3 impls + parity test",
        rationale=(
            "kernels/factory.py's contract is that the NumPy mirror is the "
            "semantics-of-record and jax/pallas/shard_map impls are pinned "
            "bit-exact against it by a tests/test_kernels.py-family test; "
            "an op missing an impl or a parity pin can drift per backend."),
        fix_hint=(
            "register a 'numpy' mirror plus at least two device impls for "
            "the op, and add a parity test mentioning the op name under "
            "tests/"),
        static=True, dynamic=False,
    ),
    Invariant(
        rule="R003",
        title="no wall-clock/RNG/id() nondeterminism on replay or digest paths",
        rationale=(
            "FusedWindowLoop replays a recorded plan and the state digest "
            "canonicalizes bytes; time.time, datetime.now, unseeded "
            "np.random and id()-keyed ordering make replay != stepped or "
            "digest != digest across processes."),
        fix_hint=(
            "thread the window clock / a seeded Generator through the call "
            "instead, and key orderings by the object (identity hash), "
            "never by id()"),
        static=True, dynamic=False,
    ),
    Invariant(
        rule="R004",
        title="jit hygiene: no host sync or traced-value branching in traced fns",
        rationale=(
            ".item()/float()/int() on traced values forces a device sync "
            "per call and Python if/while on traced values throws a "
            "ConcretizationTypeError only on the traced path; reusing a "
            "buffer donated via donate_argnums reads freed memory."),
        fix_hint=(
            "use jnp.where/lax.cond for branching, keep host pulls outside "
            "the jitted function, and never read an array after donating "
            "it"),
        static=True, dynamic=False,
    ),
    Invariant(
        rule="R005",
        title="EventLog emissions only through the owning append path",
        rationale=(
            "The log's total order (seq == position) backs cursors, fused "
            "replay equality and receipt status; mutating _events or an "
            "event's seq outside core/events.py breaks every consumer."),
        fix_hint=(
            "emit through EventLog.emit, and splice/renumber through "
            "EventLog.splice — never touch _events or seq directly"),
        static=True, dynamic=True,
    ),
    Invariant(
        rule="R008",
        title="admission decisions are pure functions of spec/sender/pool state",
        rationale=(
            "The serving layer's admission log is the determinism anchor "
            "under concurrency: replaying it must reproduce the admitted "
            "set exactly, and receipts/benchmarks compare runs by it.  A "
            "wall-clock read (time.time and friends) reachable from the "
            "admission path makes the decision depend on host scheduling "
            "instead of the modeled window clock — the one time source "
            "the ledgers run on."),
        fix_hint=(
            "pass the transaction's modeled submit time into the decision "
            "and derive every rate/refill computation from it; wall-clock "
            "timing belongs in the benchmarks, never in admission"),
        static=True, dynamic=False,
    ),
    # -- dynamic-only contracts (no useful AST form) ----------------------------
    Invariant(
        rule="R006",
        title="gas conservation: chain totals equal the sum of their parts",
        rationale=(
            "total_gas is the L1 settlement meter; if it drifts from the "
            "per-block / per-tx sums (or a rollup gas row's total from its "
            "commit+verify+execute parts) the paper's gas accounting is "
            "fiction."),
        fix_hint=(
            "only produce_block/seal may advance gas totals; never adjust "
            "total_gas or gas_log rows out of band"),
        static=False, dynamic=True,
    ),
    Invariant(
        rule="R007",
        title="receipt lifecycle legality: sealed -> proved -> aggregated",
        rationale=(
            "Receipt status is derived from the typed event stream; a "
            "ProofGenerated for a never-sealed batch or a double-proof "
            "makes client receipts lie about finality."),
        fix_hint=(
            "route batches through ProverPipeline.enqueue/pump/"
            "close_session only; never emit proof events by hand"),
        static=False, dynamic=True,
    ),
)}


def fix_hint(rule: str) -> str:
    """The catalog's canonical remediation line for ``rule`` ("" if unknown)."""
    inv = CATALOG.get(rule)
    return inv.fix_hint if inv is not None else ""
