"""repro-lint: AST-based static checker for the repo's cross-file contracts.

Ordinary linters cannot see the stack's real invariants — that a
``StateArrays`` column write must be paired with ``mark_dirty`` (R001,
the PR-8 incremental-root contract), that every kernel-factory op ships
a NumPy semantics-of-record mirror plus device impls pinned by a parity
test (R002), that nothing reachable from the fused record/execute or
digest paths reads the wall clock or unseeded RNG (R003), that jitted
functions stay free of host syncs and traced-value branching (R004),
that ``EventLog`` internals are mutated only by their owner (R005), and
that mempool admission decisions never read the wall clock (R008).
This pass does.

Usage::

    PYTHONPATH=src python -m repro.analysis.lint src/repro [--json out.json]

Findings are machine-readable (file, line, col, rule id, fix hint);
exit status is nonzero iff any unsuppressed finding remains.  Suppress a
line with ``# repro-lint: disable=R001`` (comma-separate several rules)
or a whole file with ``# repro-lint: disable-file=R003``.  The rule
catalog — shared with the runtime sanitizer — lives in
``analysis/invariants.py``; docs/ANALYSIS.md is the human-facing form.

Pure stdlib on purpose: the linter never imports the modules it checks,
so it runs in any environment (CI's repro-lint job) without jax/numpy.
"""
from __future__ import annotations

import argparse
import ast
import dataclasses
import json
import os
import re
import sys
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from repro.analysis.invariants import (
    ADMISSION_SEED_CLASSES, ADMISSION_SEED_FUNCS, DETERMINISM_SEED_CLASSES,
    DETERMINISM_SEED_FUNCS, EVENTLOG_OWNER_MODULE, MIN_IMPLS_PER_OP,
    REQUIRED_MIRROR_IMPL, STATE_COLUMNS, fix_hint)

# ---------------------------------------------------------------------------
# findings + suppressions


@dataclasses.dataclass(frozen=True)
class Finding:
    """One machine-readable violation."""

    file: str
    line: int
    col: int
    rule: str
    message: str
    hint: str

    def to_dict(self) -> Dict[str, object]:
        return dataclasses.asdict(self)

    def render(self) -> str:
        return (f"{self.file}:{self.line}:{self.col}: {self.rule} "
                f"{self.message} (hint: {self.hint})")


_SUPPRESS_RE = re.compile(r"#\s*repro-lint:\s*disable=([A-Z0-9, ]+)")
_SUPPRESS_FILE_RE = re.compile(r"#\s*repro-lint:\s*disable-file=([A-Z0-9, ]+)")


@dataclasses.dataclass
class Module:
    """One parsed source file plus its suppression tables."""

    path: str                      # as given (for findings)
    rel: str                       # posix path, for owner-module checks
    tree: ast.Module
    lines: List[str]
    line_suppress: Dict[int, Set[str]]
    file_suppress: Set[str]

    def suppressed(self, rule: str, line: int) -> bool:
        return (rule in self.file_suppress
                or rule in self.line_suppress.get(line, ()))


def _parse_module(path: str) -> Tuple[Optional[Module], Optional[Finding]]:
    with open(path, "r", encoding="utf-8") as fh:
        src = fh.read()
    lines = src.splitlines()
    try:
        tree = ast.parse(src, filename=path)
    except SyntaxError as e:
        return None, Finding(path, e.lineno or 1, e.offset or 0, "R000",
                             f"syntax error: {e.msg}", "fix the parse error")
    line_sup: Dict[int, Set[str]] = {}
    file_sup: Set[str] = set()
    for i, text in enumerate(lines, start=1):
        m = _SUPPRESS_RE.search(text)
        if m:
            line_sup[i] = {r.strip() for r in m.group(1).split(",") if r.strip()}
        m = _SUPPRESS_FILE_RE.search(text)
        if m:
            file_sup |= {r.strip() for r in m.group(1).split(",") if r.strip()}
    return Module(path, path.replace(os.sep, "/"), tree, lines,
                  line_sup, file_sup), None


# ---------------------------------------------------------------------------
# shared AST helpers


def _iter_functions(tree: ast.Module):
    """Yield (function_node, enclosing_class_name_or_None), including
    nested functions (tagged with their outermost class, if any)."""
    def walk(node, cls):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.ClassDef):
                yield from walk(child, child.name)
            elif isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                yield child, cls
                yield from walk(child, cls)
            else:
                yield from walk(child, cls)
    yield from walk(tree, None)


def _safe_unparse(node: ast.AST) -> str:
    try:
        return ast.unparse(node)
    except Exception:
        return ""


def _const_str(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    return None


# ---------------------------------------------------------------------------
# R001: StateArrays writes paired with mark_dirty

#: ufuncs whose ``.at`` form scatters into a column in place
_SCATTER_UFUNCS = {"add", "subtract", "maximum", "minimum", "multiply"}
#: parameter/local names treated as StateArrays by convention
_STATE_NAMES = {"state", "state_arrays"}


def _r001_state_vars(fn: ast.AST) -> Set[str]:
    """Names bound to a StateArrays inside ``fn`` (annotation or
    construction/attribute provenance), beyond the conventional names."""
    out: Set[str] = set()
    args = fn.args
    for a in (args.posonlyargs + args.args + args.kwonlyargs):
        if a.annotation is not None and "StateArrays" in _safe_unparse(a.annotation):
            out.add(a.arg)
    for node in ast.walk(fn):
        if not isinstance(node, ast.Assign) or len(node.targets) != 1:
            continue
        tgt = node.targets[0]
        if not isinstance(tgt, ast.Name):
            continue
        val = node.value
        if isinstance(val, ast.Call):
            f = val.func
            name = f.id if isinstance(f, ast.Name) else (
                f.attr if isinstance(f, ast.Attribute) else "")
            if name == "StateArrays":
                out.add(tgt.id)
        elif isinstance(val, ast.Attribute) and val.attr == "state_arrays":
            out.add(tgt.id)
    return out


def _r001_is_state_base(node: ast.AST, state_vars: Set[str]) -> bool:
    if isinstance(node, ast.Name):
        return node.id in _STATE_NAMES or node.id in state_vars
    if isinstance(node, ast.Attribute):
        return node.attr in ("state_arrays", "state")
    return False


def _r001_column_write(node: ast.AST, state_vars: Set[str]):
    """If ``node`` (an assignment target) writes a StateArrays column,
    return (base_key, column); else None."""
    tgt = node
    if isinstance(tgt, ast.Subscript):
        tgt = tgt.value
    if (isinstance(tgt, ast.Attribute) and tgt.attr in STATE_COLUMNS
            and _r001_is_state_base(tgt.value, state_vars)):
        return _safe_unparse(tgt.value), tgt.attr
    return None


def check_r001(mod: Module) -> List[Finding]:
    findings: List[Finding] = []
    for fn, cls in _iter_functions(mod.tree):
        if cls == "StateArrays":        # the class owns its own caches
            continue
        state_vars = _r001_state_vars(fn)
        writes: List[Tuple[str, str, int, int]] = []   # base, col, line, col
        marks: List[Tuple[str, int]] = []              # base, line
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign):
                for t in node.targets:
                    hit = _r001_column_write(t, state_vars)
                    if hit:
                        writes.append((*hit, node.lineno, node.col_offset))
            elif isinstance(node, ast.AugAssign):
                hit = _r001_column_write(node.target, state_vars)
                if hit:
                    writes.append((*hit, node.lineno, node.col_offset))
            elif isinstance(node, ast.Call):
                f = node.func
                if (isinstance(f, ast.Attribute) and f.attr == "mark_dirty"):
                    marks.append((_safe_unparse(f.value), node.lineno))
                elif (isinstance(f, ast.Attribute) and f.attr == "at"
                      and isinstance(f.value, ast.Attribute)
                      and f.value.attr in _SCATTER_UFUNCS and node.args):
                    # np.add.at(<col expr>, ids, x) scatter form
                    a0 = node.args[0]
                    base = None
                    if (isinstance(a0, ast.Attribute)
                            and a0.attr in STATE_COLUMNS
                            and _r001_is_state_base(a0.value, state_vars)):
                        base = a0.value
                    elif (isinstance(a0, ast.Call)
                          and isinstance(a0.func, ast.Name)
                          and a0.func.id == "getattr" and a0.args
                          and _r001_is_state_base(a0.args[0], state_vars)):
                        base = a0.args[0]
                    if base is not None:
                        writes.append((_safe_unparse(base), "<scatter>",
                                       node.lineno, node.col_offset))
        for base, col, line, colno in writes:
            if any(mb == base and ml >= line for mb, ml in marks):
                continue
            findings.append(Finding(
                mod.path, line, colno, "R001",
                f"write to StateArrays column {col!r} via {base!r} has no "
                f"matching {base}.mark_dirty(...) later in this function",
                fix_hint("R001")))
    return findings


# ---------------------------------------------------------------------------
# R002: kernel-registry completeness


def _repo_root_of(path: str) -> Optional[str]:
    d = os.path.dirname(os.path.abspath(path))
    while True:
        if os.path.isdir(os.path.join(d, "tests")):
            return d
        parent = os.path.dirname(d)
        if parent == d:
            return None
        d = parent


_TEST_TEXT_CACHE: Dict[str, str] = {}


def _test_corpus(repo_root: str) -> str:
    """Concatenated text of tests/test_*.py (the parity-test family)."""
    if repo_root in _TEST_TEXT_CACHE:
        return _TEST_TEXT_CACHE[repo_root]
    chunks: List[str] = []
    tdir = os.path.join(repo_root, "tests")
    for base, _dirs, files in os.walk(tdir):
        for f in sorted(files):
            if f.startswith("test_") and f.endswith(".py"):
                with open(os.path.join(base, f), encoding="utf-8") as fh:
                    chunks.append(fh.read())
    _TEST_TEXT_CACHE[repo_root] = "\n".join(chunks)
    return _TEST_TEXT_CACHE[repo_root]


def check_r002(mods: Sequence[Module]) -> List[Finding]:
    # op -> (impls, first registration site)
    regs: Dict[str, Tuple[Set[str], Tuple[Module, int, int]]] = {}
    for mod in mods:
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            f = node.func
            name = f.id if isinstance(f, ast.Name) else (
                f.attr if isinstance(f, ast.Attribute) else "")
            if name != "register_kernel" or len(node.args) < 2:
                continue
            op, impl = _const_str(node.args[0]), _const_str(node.args[1])
            if op is None or impl is None:
                continue
            impls, site = regs.setdefault(
                op, (set(), (mod, node.lineno, node.col_offset)))
            impls.add(impl)
    findings: List[Finding] = []
    for op, (impls, (mod, line, col)) in sorted(regs.items()):
        if REQUIRED_MIRROR_IMPL not in impls:
            findings.append(Finding(
                mod.path, line, col, "R002",
                f"kernel op {op!r} has no {REQUIRED_MIRROR_IMPL!r} "
                f"semantics-of-record mirror (impls: {sorted(impls)})",
                fix_hint("R002")))
        if len(impls) < MIN_IMPLS_PER_OP:
            findings.append(Finding(
                mod.path, line, col, "R002",
                f"kernel op {op!r} registers only {sorted(impls)}; the "
                f"factory contract is >= {MIN_IMPLS_PER_OP} impls per op",
                fix_hint("R002")))
        root = _repo_root_of(mod.path)
        if root is not None and op not in _test_corpus(root):
            findings.append(Finding(
                mod.path, line, col, "R002",
                f"kernel op {op!r} has no parity test: no tests/test_*.py "
                f"file mentions it",
                fix_hint("R002")))
    return findings


# ---------------------------------------------------------------------------
# R003: determinism on fused-replay / digest paths


def _called_names(fn: ast.AST) -> Set[str]:
    out: Set[str] = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            f = node.func
            if isinstance(f, ast.Name):
                out.add(f.id)
            elif isinstance(f, ast.Attribute):
                out.add(f.attr)
    return out


def _reach(mods: Sequence[Module], seed_classes: Sequence[str],
           seed_funcs: Sequence[str]) -> List[Tuple[Module, ast.AST]]:
    """Functions reachable from the seeds, BFS over simple-name call
    edges (conservative: a matching name anywhere in the scan set counts
    as an edge).  Shared by the R003 and R008 sweeps."""
    index: Dict[str, List[Tuple[Module, ast.AST, Optional[str]]]] = {}
    seeds: List[Tuple[Module, ast.AST]] = []
    for mod in mods:
        for fn, cls in _iter_functions(mod.tree):
            index.setdefault(fn.name, []).append((mod, fn, cls))
            if cls in seed_classes or fn.name in seed_funcs:
                seeds.append((mod, fn))
    # AST nodes hash by identity, so plain node sets give the identity
    # bookkeeping without id() (rule R003 applies to this file too)
    reachable: Set[ast.AST] = set()
    frontier = list(seeds)
    reach_list: List[Tuple[Module, ast.AST]] = []
    while frontier:
        mod, fn = frontier.pop()
        if fn in reachable:
            continue
        reachable.add(fn)
        reach_list.append((mod, fn))
        for name in _called_names(fn):
            for tmod, tfn, _cls in index.get(name, ()):
                if tfn not in reachable:
                    frontier.append((tmod, tfn))
    return reach_list


def _wallclock_findings(mod: Module, fn: ast.AST, rule: str) -> List[Finding]:
    """time.time/datetime.now-family calls inside ``fn``, as ``rule``."""
    findings: List[Finding] = []
    for node in ast.walk(fn):
        if not isinstance(node, ast.Call):
            continue
        f = node.func
        if not isinstance(f, ast.Attribute):
            continue
        chain = _safe_unparse(f)
        base = f.value
        where = f"on a path reachable from {fn.name!r}"
        if (isinstance(base, ast.Name) and base.id == "time"
                and f.attr in ("time", "time_ns", "perf_counter",
                               "monotonic", "clock")):
            findings.append(Finding(
                mod.path, node.lineno, node.col_offset, rule,
                f"wall-clock read {chain}() {where}", fix_hint(rule)))
        elif f.attr in ("now", "utcnow", "today") and "datetime" in chain:
            findings.append(Finding(
                mod.path, node.lineno, node.col_offset, rule,
                f"wall-clock read {chain}() {where}", fix_hint(rule)))
    return findings


def check_r003(mods: Sequence[Module]) -> List[Finding]:
    findings: List[Finding] = []
    reach_list = _reach(mods, DETERMINISM_SEED_CLASSES,
                        DETERMINISM_SEED_FUNCS)
    for mod, fn in reach_list:
        findings.extend(_wallclock_findings(mod, fn, "R003"))
        has_stdlib_random = any(
            isinstance(n, ast.Import) and any(a.name == "random" for a in n.names)
            for n in ast.walk(mod.tree))
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            f = node.func
            where = f"on a path reachable from {fn.name!r}"
            if isinstance(f, ast.Attribute):
                chain = _safe_unparse(f)
                base = f.value
                if chain.startswith(("np.random.", "numpy.random.")):
                    if f.attr != "default_rng":
                        findings.append(Finding(
                            mod.path, node.lineno, node.col_offset, "R003",
                            f"unseeded global RNG {chain}() {where}",
                            fix_hint("R003")))
                    elif not node.args and not node.keywords:
                        findings.append(Finding(
                            mod.path, node.lineno, node.col_offset, "R003",
                            f"{chain}() without a seed {where}",
                            fix_hint("R003")))
                elif (has_stdlib_random and isinstance(base, ast.Name)
                      and base.id == "random"):
                    findings.append(Finding(
                        mod.path, node.lineno, node.col_offset, "R003",
                        f"stdlib random.{f.attr}() {where}", fix_hint("R003")))
            elif isinstance(f, ast.Name) and f.id == "id" and len(node.args) == 1:
                findings.append(Finding(
                    mod.path, node.lineno, node.col_offset, "R003",
                    f"id()-based keying/ordering {where} is process-"
                    f"nondeterministic", fix_hint("R003")))
    return findings


# ---------------------------------------------------------------------------
# R008: admission-path purity (no wall clock in mempool decisions)


def check_r008(mods: Sequence[Module]) -> List[Finding]:
    """Admission decisions are pure functions of (spec, sender state,
    pool state) on MODELED time: nothing reachable from the admission
    seeds (``AdmissionController``/``PendingPool``) may read the wall
    clock — the recorded admission log would stop replaying to the same
    admitted set."""
    findings: List[Finding] = []
    for mod, fn in _reach(mods, ADMISSION_SEED_CLASSES,
                          ADMISSION_SEED_FUNCS):
        findings.extend(_wallclock_findings(mod, fn, "R008"))
    return findings


# ---------------------------------------------------------------------------
# R004: jit hygiene

#: attribute reads on a traced array that are static metadata, not values
_STATIC_ATTRS = {"dtype", "shape", "ndim", "size"}


def _jit_like(call: ast.Call) -> Optional[str]:
    """'jit'/'vmap'/'scan' if ``call`` wraps a function for tracing."""
    f = call.func
    name = f.id if isinstance(f, ast.Name) else (
        f.attr if isinstance(f, ast.Attribute) else "")
    if name in ("jit", "vmap"):
        return name
    if name == "scan" and isinstance(f, ast.Attribute) and \
            _safe_unparse(f).endswith("lax.scan"):
        return "scan"
    if name == "partial" and call.args:
        inner = _safe_unparse(call.args[0])
        if inner in ("jit", "jax.jit", "vmap", "jax.vmap"):
            return "jit"
    return None


@dataclasses.dataclass
class _TracedFn:
    node: ast.AST                       # FunctionDef or Lambda
    static_names: Set[str]              # params excluded via static_arg*
    skip_branch_check: bool             # static spec we could not resolve


def _static_param_names(fn: ast.AST, call: Optional[ast.Call]):
    """Resolve static_argnums/static_argnames of ``call`` against ``fn``'s
    positional params.  Returns (names, unresolvable)."""
    if call is None:
        return set(), False
    names: Set[str] = set()
    pos = [a.arg for a in fn.args.posonlyargs + fn.args.args] \
        if not isinstance(fn, ast.Lambda) else [a.arg for a in fn.args.args]
    for kw in call.keywords:
        if kw.arg not in ("static_argnums", "static_argnames"):
            continue
        vals = kw.value.elts if isinstance(kw.value, ast.Tuple) else [kw.value]
        for v in vals:
            if isinstance(v, ast.Constant) and isinstance(v.value, int):
                if 0 <= v.value < len(pos):
                    names.add(pos[v.value])
            elif isinstance(v, ast.Constant) and isinstance(v.value, str):
                names.add(v.value)
            else:
                return names, True
    return names, False


def _collect_traced(mod: Module) -> List[_TracedFn]:
    fns: Dict[str, ast.AST] = {}
    for fn, _cls in _iter_functions(mod.tree):
        fns[fn.name] = fn
    traced: List[_TracedFn] = []
    seen: Set[ast.AST] = set()

    def add(fn, call):
        if fn in seen:
            return
        seen.add(fn)
        static, unresolved = _static_param_names(fn, call)
        traced.append(_TracedFn(fn, static, unresolved))

    for fn, _cls in _iter_functions(mod.tree):
        for dec in fn.decorator_list:
            text = _safe_unparse(dec)
            if re.search(r"\b(jit|vmap)\b", text):
                add(fn, dec if isinstance(dec, ast.Call) else None)
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        kind = _jit_like(node)
        if kind is None:
            continue
        # partial(jit, ...)(f) or jit(f)/vmap(f)/lax.scan(f, ...): the
        # wrapped callable is the first positional arg that is not the
        # inner `jit` of a partial
        args = node.args[1:] if (isinstance(node.func, ast.Name)
                                 and node.func.id == "partial") else node.args
        if not args:
            continue
        target = args[0]
        if isinstance(target, ast.Name) and target.id in fns:
            add(fns[target.id], node)
        elif isinstance(target, ast.Lambda):
            add(target, node)
    return traced


def check_r004(mod: Module) -> List[Finding]:
    findings: List[Finding] = []
    for tf in _collect_traced(mod):
        fn = tf.node
        if isinstance(fn, ast.Lambda):
            params = {a.arg for a in fn.args.args}
            body_nodes = [fn.body]
            fname = "<lambda>"
        else:
            params = {a.arg for a in
                      fn.args.posonlyargs + fn.args.args + fn.args.kwonlyargs}
            body_nodes = fn.body
            fname = fn.name
        params -= tf.static_names
        traced_params = params - {"self", "cls"}

        def traced_use(expr) -> Optional[ast.Name]:
            """A bare load of a traced param that is not static metadata."""
            static_heads: Set[ast.AST] = set()
            for n in ast.walk(expr):
                if (isinstance(n, ast.Attribute) and n.attr in _STATIC_ATTRS
                        and isinstance(n.value, ast.Name)):
                    static_heads.add(n.value)
                elif (isinstance(n, ast.Call) and isinstance(n.func, ast.Name)
                      and n.func.id in ("len", "isinstance", "getattr",
                                        "hasattr", "type")):
                    for sub in ast.walk(n):
                        if isinstance(sub, ast.Name):
                            static_heads.add(sub)
            for n in ast.walk(expr):
                if (isinstance(n, ast.Name) and n.id in traced_params
                        and n not in static_heads):
                    return n
            return None

        for body in body_nodes:
            for node in ast.walk(body):
                if (isinstance(node, ast.Call)
                        and isinstance(node.func, ast.Attribute)
                        and node.func.attr == "item" and not node.args):
                    findings.append(Finding(
                        mod.path, node.lineno, node.col_offset, "R004",
                        f".item() host sync inside traced function {fname!r}",
                        fix_hint("R004")))
                elif (isinstance(node, ast.Call)
                      and isinstance(node.func, ast.Name)
                      and node.func.id in ("float", "int", "bool")
                      and len(node.args) == 1):
                    hit = traced_use(node.args[0])
                    if hit is not None:
                        findings.append(Finding(
                            mod.path, node.lineno, node.col_offset, "R004",
                            f"{node.func.id}({hit.id}) concretizes a traced "
                            f"value inside {fname!r}", fix_hint("R004")))
                elif (isinstance(node, (ast.If, ast.While))
                      and not tf.skip_branch_check):
                    hit = traced_use(node.test)
                    if hit is not None:
                        findings.append(Finding(
                            mod.path, node.lineno, node.col_offset, "R004",
                            f"Python branching on traced value {hit.id!r} "
                            f"inside {fname!r}", fix_hint("R004")))
    findings.extend(_check_donated_reuse(mod))
    return findings


def _check_donated_reuse(mod: Module) -> List[Finding]:
    """Reuse of a buffer after passing it at a donate_argnums position."""
    donated: Dict[str, Set[int]] = {}       # jitted-callable name -> positions
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Assign) or len(node.targets) != 1:
            continue
        tgt, val = node.targets[0], node.value
        if not (isinstance(tgt, ast.Name) and isinstance(val, ast.Call)
                and _jit_like(val) == "jit"):
            continue
        for kw in val.keywords:
            if kw.arg != "donate_argnums":
                continue
            vals = kw.value.elts if isinstance(kw.value, ast.Tuple) \
                else [kw.value]
            pos = {v.value for v in vals
                   if isinstance(v, ast.Constant) and isinstance(v.value, int)}
            if pos:
                donated[tgt.id] = pos
    if not donated:
        return []
    findings: List[Finding] = []
    for fn, _cls in _iter_functions(mod.tree):
        # names rebound by an assignment, per line: `x, s = f(p, s)` with s
        # donated is the legal donate-and-rebind idiom, not a reuse
        rebound: Dict[int, Set[str]] = {}
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign):
                for t in node.targets:
                    for n in ast.walk(t):
                        if isinstance(n, ast.Name):
                            rebound.setdefault(node.lineno, set()).add(n.id)
        handed: List[Tuple[str, int]] = []  # (buffer name, donation line)
        for node in ast.walk(fn):
            if (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
                    and node.func.id in donated):
                for i in donated[node.func.id]:
                    if (i < len(node.args)
                            and isinstance(node.args[i], ast.Name)
                            and node.args[i].id
                            not in rebound.get(node.lineno, ())):
                        handed.append((node.args[i].id, node.lineno))
        for buf, after in handed:
            for node in ast.walk(fn):
                if (isinstance(node, ast.Name) and node.id == buf
                        and isinstance(node.ctx, ast.Load)
                        and node.lineno > after):
                    findings.append(Finding(
                        mod.path, node.lineno, node.col_offset, "R004",
                        f"buffer {buf!r} used after being donated at line "
                        f"{after} (donate_argnums)", fix_hint("R004")))
                    break
    return findings


# ---------------------------------------------------------------------------
# R005: EventLog internals owned by core/events.py

_LIST_MUTATORS = {"append", "extend", "insert", "pop", "remove",
                  "clear", "sort", "reverse"}


def check_r005(mod: Module) -> List[Finding]:
    if mod.rel.endswith(EVENTLOG_OWNER_MODULE):
        return []
    findings: List[Finding] = []
    for fn, _cls in _iter_functions(mod.tree):
        aliases: Set[str] = set()
        for node in ast.walk(fn):
            if (isinstance(node, ast.Assign) and len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Name)
                    and isinstance(node.value, ast.Attribute)
                    and node.value.attr == "_events"):
                aliases.add(node.targets[0].id)

        def events_obj(expr) -> bool:
            if isinstance(expr, ast.Attribute) and expr.attr == "_events":
                return True
            return isinstance(expr, ast.Name) and expr.id in aliases

        for node in ast.walk(fn):
            if isinstance(node, (ast.Assign, ast.AugAssign)):
                targets = node.targets if isinstance(node, ast.Assign) \
                    else [node.target]
                for t in targets:
                    base = t.value if isinstance(t, ast.Subscript) else t
                    if events_obj(base) and not (
                            isinstance(t, ast.Name) and isinstance(
                                node, ast.Assign)):
                        findings.append(Finding(
                            mod.path, node.lineno, node.col_offset, "R005",
                            "direct mutation of EventLog._events outside "
                            "core/events.py", fix_hint("R005")))
            elif isinstance(node, ast.Call):
                f = node.func
                if (isinstance(f, ast.Attribute)
                        and f.attr in _LIST_MUTATORS and events_obj(f.value)):
                    findings.append(Finding(
                        mod.path, node.lineno, node.col_offset, "R005",
                        f"_events.{f.attr}(...) outside core/events.py",
                        fix_hint("R005")))
                elif (isinstance(f, ast.Attribute) and f.attr == "__setattr__"
                      and isinstance(f.value, ast.Name)
                      and f.value.id == "object" and len(node.args) >= 2
                      and _const_str(node.args[1]) in ("seq", "time")):
                    findings.append(Finding(
                        mod.path, node.lineno, node.col_offset, "R005",
                        f"object.__setattr__(_, {_const_str(node.args[1])!r}, "
                        f"...) renumbers an event outside core/events.py",
                        fix_hint("R005")))
    return findings


# ---------------------------------------------------------------------------
# driver


def _collect_files(paths: Iterable[str]) -> List[str]:
    out: List[str] = []
    for p in paths:
        if os.path.isfile(p):
            out.append(p)
            continue
        for base, dirs, files in os.walk(p):
            dirs[:] = [d for d in dirs if d not in ("__pycache__", ".git")]
            out.extend(os.path.join(base, f)
                       for f in sorted(files) if f.endswith(".py"))
    return sorted(set(out))


def scan(paths: Sequence[str]) -> Tuple[List[Finding], int]:
    """Lint ``paths``; returns (unsuppressed findings, n_suppressed)."""
    mods: List[Module] = []
    findings: List[Finding] = []
    for path in _collect_files(paths):
        mod, err = _parse_module(path)
        if err is not None:
            findings.append(err)
            continue
        mods.append(mod)
    by_path = {m.path: m for m in mods}
    for mod in mods:
        findings.extend(check_r001(mod))
        findings.extend(check_r004(mod))
        findings.extend(check_r005(mod))
    findings.extend(check_r002(mods))
    findings.extend(check_r003(mods))
    findings.extend(check_r008(mods))
    # dedupe by site+rule (several R003 seeds can reach one call site)
    seen_sites: Set[Tuple[str, int, int, str]] = set()
    unique: List[Finding] = []
    for f in findings:
        site = (f.file, f.line, f.col, f.rule)
        if site not in seen_sites:
            seen_sites.add(site)
            unique.append(f)
    findings = unique
    kept: List[Finding] = []
    n_sup = 0
    for f in findings:
        mod = by_path.get(f.file)
        if f.rule != "R000" and mod is not None and mod.suppressed(f.rule, f.line):
            n_sup += 1
        else:
            kept.append(f)
    kept.sort(key=lambda f: (f.file, f.line, f.rule))
    return kept, n_sup


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro-lint",
        description="invariant-aware static checker (rules R001-R005 + "
                    "R008; see docs/ANALYSIS.md)")
    ap.add_argument("paths", nargs="+", help="files or directories to lint")
    ap.add_argument("--json", metavar="FILE", default=None,
                    help="also write machine-readable findings to FILE")
    ap.add_argument("--quiet", action="store_true",
                    help="suppress per-finding output (exit status only)")
    ns = ap.parse_args(argv)
    findings, n_sup = scan(ns.paths)
    if not ns.quiet:
        for f in findings:
            print(f.render())
        print(f"repro-lint: {len(findings)} finding(s)"
              f" ({n_sup} suppressed)", file=sys.stderr)
    if ns.json:
        with open(ns.json, "w", encoding="utf-8") as fh:
            json.dump({"version": 1,
                       "n_findings": len(findings),
                       "n_suppressed": n_sup,
                       "findings": [f.to_dict() for f in findings]}, fh,
                      indent=2)
            fh.write("\n")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
