"""Analytic MODEL_FLOPS per step: the 'useful work' yardstick for the
roofline ratio MODEL_FLOPS / HLO_FLOPs.

Conventions (per roofline spec):
  dense train        6 * N * D          (N params, D tokens)
  MoE train          6 * N_active * D
  prefill            2 * N(_active) * D
  decode             2 * N(_active) * B  (one token per sequence)
plus the attention quadratic term (not captured by 6ND):
  causal train       ~12 * L_attn * H * dh * S^2/2 * B   (fwd 4*, bwd 8*, causal /2)
  prefill            ~4  * L_attn * H * dh * S^2/2 * B
  decode             ~4  * L_attn * H * dh * S * B
"""
from __future__ import annotations

from repro.configs.base import ATTN, ModelConfig, ShapeConfig


def n_attn_layers(cfg: ModelConfig) -> int:
    if cfg.enc_dec:
        return cfg.n_layers + cfg.n_enc_layers
    return sum(1 for k in cfg.pattern if k == ATTN) * cfg.n_periods


def exact_param_counts(params_shape, cfg: ModelConfig):
    """(N_total, N_active) from the real params tree: excludes the input
    embedding table (gather, not matmul) and counts only top_k/E of each
    MoE expert stack as active."""
    total = active = 0

    def walk(path, node):
        nonlocal total, active
        if isinstance(node, dict):
            for k, v in node.items():
                walk(path + (k,), v)
            return
        if path and path[-1] == "table":
            return                      # input embedding: no matmul flops
        n = 1
        for d in node.shape:
            n *= d
        total += n
        if cfg.moe is not None and path and path[-1].startswith("moe_w"):
            active += n * cfg.moe.top_k / cfg.moe.n_experts
        else:
            active += n
    walk((), params_shape)
    return total, active


def model_flops(cfg: ModelConfig, shape: ShapeConfig,
                params_shape=None) -> dict:
    if params_shape is not None:
        _, n_act = exact_param_counts(params_shape, cfg)
        n = n_act
    else:
        n = cfg.param_count()
        n_act = cfg.active_param_count()
    B, S = shape.global_batch, shape.seq_len
    D = B * S
    L = n_attn_layers(cfg)
    attn_inner = cfg.n_heads * cfg.head_dim

    if shape.kind == "train":
        base = 6.0 * n_act * D
        # 12 * L * (H*dh) * (S/2) per token, over D tokens
        attn = 12.0 * L * attn_inner * (S / 2.0) * D
    elif shape.kind == "prefill":
        base = 2.0 * n_act * D
        attn = 4.0 * L * attn_inner * (S / 2.0) * D
    else:  # decode: one token per sequence, full-depth KV read
        D = B
        base = 2.0 * n_act * B
        attn = 4.0 * L * attn_inner * S * B
    return {"model_flops": base, "attn_flops": attn,
            "model_flops_total": base + attn, "tokens": D}
