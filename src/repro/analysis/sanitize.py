"""Runtime sanitizer: dynamic enforcement of the invariant catalog.

``REPRO_SANITIZE=1`` makes ``repro.api.factory.build_stack`` install a
``StackSanitizer`` on every stack it builds (tests can also call
``install_stack`` directly).  The sanitizer wraps the stack's ONE
``EventLog`` — every lifecycle event on both the stepped and fused paths
flows through ``emit``/``splice``, so one observation point cross-checks
the same contracts the static pass (analysis/lint.py) enforces at the
AST level, plus the dynamic-only ones:

- **R001** after every ``WindowSettled``: the committed (incremental,
  dirty-chunk) state root must equal a full refold of the live arrays —
  a column write that skipped ``mark_dirty`` diverges them.
- **R005** event seq integrity: every emission extends the total order
  by exactly one; splices leave ``seq == position`` across the stream.
- **R006** gas conservation: on every ``BlockPacked`` the chain's
  ``total_gas`` equals the sum of its blocks (and, on a vector chain,
  the confirmed cumsum); on every ``BatchSealed`` the fresh gas rows
  satisfy ``total == commit + verify + execute``.
- **R007** receipt lifecycle legality: batches move strictly
  sealed -> proved -> aggregated, windows count up contiguously.

Violations raise ``SanitizeViolation`` (an AssertionError subclass
carrying ``.rule``) at the emission site, so the offending transition is
on the stack when it fires.  Overhead is dominated by the per-window
full refold — numbers in docs/ANALYSIS.md.
"""
from __future__ import annotations

import os
from typing import Any, Dict, Optional, Set, Tuple

from repro.analysis.invariants import CATALOG

#: the env flag build_stack consults ("" / "0" mean off)
ENV_FLAG = "REPRO_SANITIZE"


def enabled() -> bool:
    return os.environ.get(ENV_FLAG, "") not in ("", "0")


class SanitizeViolation(AssertionError):
    """An invariant-catalog violation observed at run time."""

    def __init__(self, rule: str, message: str):
        self.rule = rule
        inv = CATALOG.get(rule)
        title = f" [{inv.title}]" if inv is not None else ""
        super().__init__(f"{rule}{title}: {message}")


class StackSanitizer:
    """Wraps one stack's EventLog and cross-checks every emission."""

    def __init__(self, chain, rollup=None):
        self.chain = chain
        self.rollup = rollup
        self.log = getattr(chain, "events", None)
        self.n_checks = 0                 # emissions validated (tests pin >0)
        self._last_seq = (self.log.next_cursor - 1
                          if self.log is not None else -1)
        self._sealed: Set[Tuple[Any, int]] = set()     # (shard, batch)
        self._proved: Set[Tuple[Any, int]] = set()
        self._aggregated: Set[Tuple[Any, int]] = set()
        self._windows: Dict[Any, int] = {}             # shard -> next window
        if self.log is not None:
            self._install_log(self.log)

    # -- wiring -----------------------------------------------------------------
    def _install_log(self, log) -> None:
        orig_emit, orig_splice = log.emit, log.splice

        def emit(cls, **kw):
            ev = orig_emit(cls, **kw)
            self._on_event(ev)
            return ev

        def splice(inserts):
            orig_splice(inserts)
            # seq == base + position: on an unbounded log base is 0 and
            # this is the classic seq == position contract
            for i, e in enumerate(log._events):
                if e.seq != log.base + i:
                    raise SanitizeViolation(
                        "R005", f"post-splice stream has seq {e.seq} at "
                                f"position {log.base + i}")
            self._last_seq = log.next_cursor - 1
            self._check_gas("splice")
            self.n_checks += 1

        log.emit = emit
        log.splice = splice
        log._sanitizer = self

    def _face(self, shard: Optional[int]):
        """The rollup face owning ``shard``'s gas rows/batch counters."""
        ru = self.rollup
        if ru is None:
            return None
        shards = getattr(ru, "shards", None)
        if shards is not None and shard is not None:
            return shards[shard]
        return ru

    def _state(self):
        ru = self.rollup
        if ru is None:
            return None
        st = getattr(ru, "state_arrays", None)
        if st is None:
            # the fabric's StateArrays lives at .state — but on object
            # faces .state is the plain dict book, not the array state
            cand = getattr(ru, "state", None)
            if hasattr(cand, "root") and hasattr(cand, "copy"):
                st = cand
        return st

    # -- checks -----------------------------------------------------------------
    def _on_event(self, ev) -> None:
        if ev.seq != self._last_seq + 1:
            raise SanitizeViolation(
                "R005", f"event {ev.kind!r} emitted with seq {ev.seq}, "
                        f"expected {self._last_seq + 1} — something mutated "
                        f"the log out of band")
        self._last_seq = ev.seq
        kind = ev.kind
        if kind == "batch_sealed":
            for b in range(ev.first_batch, ev.first_batch + ev.n_batches):
                self._sealed.add((ev.shard, b))
            self._check_gas_rows(ev)
        elif kind == "proof_generated":
            key = (ev.shard, ev.batch)
            if key not in self._sealed:
                raise SanitizeViolation(
                    "R007", f"ProofGenerated for batch {ev.batch} "
                            f"(shard {ev.shard}) that was never sealed")
            if key in self._proved:
                raise SanitizeViolation(
                    "R007", f"batch {ev.batch} (shard {ev.shard}) proved "
                            f"twice")
            self._proved.add(key)
        elif kind == "aggregate_verified":
            for b in ev.batches:
                key = (ev.shard, b)
                if key not in self._proved:
                    raise SanitizeViolation(
                        "R007", f"aggregate {ev.aggregate} covers batch {b} "
                                f"(shard {ev.shard}) with no proof")
                if key in self._aggregated:
                    raise SanitizeViolation(
                        "R007", f"batch {b} (shard {ev.shard}) aggregated "
                                f"twice")
                self._aggregated.add(key)
        elif kind == "window_settled":
            want = self._windows.get(ev.shard, 0)
            if ev.window != want:
                raise SanitizeViolation(
                    "R007", f"WindowSettled window {ev.window} out of order "
                            f"(shard {ev.shard}, expected {want})")
            self._windows[ev.shard] = want + 1
            self._check_root(ev)
        elif kind == "block_packed":
            self._check_gas("BlockPacked")
        self.n_checks += 1

    def _check_root(self, ev) -> None:
        """R001 dynamic form: committed incremental root == full refold."""
        st = self._state()
        if st is None or not ev.state_root:
            return
        # copy() drops dirty tracking, so root() on it is a full refold of
        # the live arrays; a write that skipped mark_dirty leaves the
        # committed (cached + dirty-chunk patched) root stale
        full = st.copy().root()
        if ev.state_root != full:
            raise SanitizeViolation(
                "R001", f"window {ev.window} committed state root "
                        f"{ev.state_root} != full refold {full} — a "
                        f"StateArrays write skipped mark_dirty")
        ru = self.rollup
        if ev.fabric_root and hasattr(ru, "_merge_roots"):
            fab = ru._merge_roots(
                st.copy().partition_roots(ru.n_shards))
            if ev.fabric_root != fab:
                raise SanitizeViolation(
                    "R001", f"window {ev.window} fabric root "
                            f"{ev.fabric_root} != refolded {fab}")

    def _check_gas_rows(self, ev) -> None:
        face = self._face(ev.shard)
        gas_log = getattr(face, "gas_log", None)
        if not gas_log or ev.n_batches <= 0:
            return
        for row in gas_log[-ev.n_batches:]:
            want = row["commit"] + row["verify"] + row["execute"]
            if abs(row["total"] - want) > 1e-6:
                raise SanitizeViolation(
                    "R006", f"gas row for batch {row.get('batch')} has "
                            f"total {row['total']} != commit+verify+execute "
                            f"{want}")

    def _check_gas(self, where: str) -> None:
        chain = self.chain
        total = getattr(chain, "total_gas", None)
        blocks = getattr(chain, "blocks", None)
        if total is None or blocks is None:
            return
        by_blocks = sum(b.gas_used for b in blocks)
        if total != by_blocks:
            raise SanitizeViolation(
                "R006", f"[{where}] chain.total_gas {total} != sum of "
                        f"block gas {by_blocks} — gas leaked out of band")
        ptr = getattr(chain, "_ptr", None)
        gcum = getattr(chain, "_gcum", None)
        if ptr and gcum is not None and ptr <= len(gcum):
            confirmed = int(gcum[ptr - 1])
            if total != confirmed:
                raise SanitizeViolation(
                    "R006", f"[{where}] chain.total_gas {total} != confirmed "
                            f"tx gas cumsum {confirmed}")


def install_stack(chain, rollup=None) -> StackSanitizer:
    """Install (or fetch) the sanitizer for ``chain``'s event log."""
    log = getattr(chain, "events", None)
    existing = getattr(log, "_sanitizer", None) if log is not None else None
    if existing is not None:
        if rollup is not None and existing.rollup is None:
            existing.rollup = rollup
        return existing
    return StackSanitizer(chain, rollup)
