"""Public node API: typed specs, one ledger factory, an RPC-style client.

This package is the supported entry point for building and driving
nodes; everything else under ``repro.core``/``repro.fl`` is
implementation.  See README "Public API" and docs/MIGRATION.md for the
old-kwarg -> spec mapping.

    from repro.api import ChainSpec, NodeSpec, NodeClient, build_ledger

    client = NodeClient.from_spec(NodeSpec())      # vector L1 + rollup
    rcpt = client.submit("submitLocalModel", "trainer0")
    client.flush(); client.run_until(10.0)
    rcpt = client.refresh(rcpt)                    # batch, gas, L1 block
"""
from repro.api.client import AccountView, NodeClient, TxReceipt
from repro.api.factory import (build_chain, build_ledger, build_node,
                               build_stack, l1_of)
from repro.api.presets import PRESETS, describe_presets, preset
from repro.api.specs import (ChainSpec, DONSpec, FLTaskSpec, NodeSpec,
                             ReputationSpec, RollupSpec, ShardSpec,
                             WorkloadSpec, as_task_spec)

__all__ = [
    "AccountView", "NodeClient", "TxReceipt",
    "build_chain", "build_ledger", "build_node", "build_stack", "l1_of",
    "PRESETS", "describe_presets", "preset",
    "ChainSpec", "DONSpec", "FLTaskSpec", "NodeSpec", "ReputationSpec",
    "RollupSpec", "ShardSpec", "WorkloadSpec", "as_task_spec",
]
