"""Public node API: typed specs, one ledger factory, an RPC-style client.

This package is the supported entry point for building and driving
nodes; everything else under ``repro.core``/``repro.fl`` is
implementation.  See README "Public API" and docs/MIGRATION.md for the
old-kwarg -> spec mapping and the subscribe -> events() migration.

    from repro.api import ChainSpec, NodeSpec, NodeClient, build_ledger

    client = NodeClient.from_spec(NodeSpec())      # vector L1 + rollup
    rcpt = client.submit("submitLocalModel", "trainer0")
    client.flush(); client.run_until(10.0)
    rcpt = client.refresh(rcpt)      # finalized: batch, gas, L1 block,
    for ev in client.events():       # proof/aggregate refs + the typed
        ...                          # BatchSealed/ProofGenerated/... feed
"""
from repro.api.client import (RECEIPT_STATUSES, AccountView, NodeClient,
                              TxReceipt)
from repro.api.factory import (build_chain, build_ledger, build_node,
                               build_stack, l1_of)
from repro.api.presets import PRESETS, describe_presets, preset
from repro.api.specs import (AdmissionSpec, ChainSpec, DONSpec, FLTaskSpec,
                             NodeSpec, ProverSpec, ReputationSpec,
                             RollupSpec, ServeSpec, ShardSpec, WorkloadSpec,
                             as_task_spec)
from repro.core.events import (AggregateVerified, BatchSealed, BlockPacked,
                               EventsDropped, LedgerEvent, ProofGenerated,
                               WindowSettled)

__all__ = [
    "AccountView", "NodeClient", "TxReceipt", "RECEIPT_STATUSES",
    "build_chain", "build_ledger", "build_node", "build_stack", "l1_of",
    "PRESETS", "describe_presets", "preset",
    "AdmissionSpec", "ChainSpec", "DONSpec", "FLTaskSpec", "NodeSpec",
    "ProverSpec", "ReputationSpec", "RollupSpec", "ServeSpec", "ShardSpec",
    "WorkloadSpec", "as_task_spec",
    "LedgerEvent", "BatchSealed", "ProofGenerated", "AggregateVerified",
    "WindowSettled", "BlockPacked", "EventsDropped",
]
