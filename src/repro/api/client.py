"""zk-rollup-style node client: submit -> TxReceipt, accounts, events.

``NodeClient`` is the RPC-shaped façade over any ledger built by
``repro.api.build_ledger`` (or owned by an ``AutoDFL`` node): it hides
which of the five backends is underneath and speaks the vocabulary a
rollup RPC would —

  * ``submit(fn, sender) -> TxReceipt``: a receipt with status, gas
    breakdown, L2 batch id / L1 block, proof/aggregate refs and the L1
    settlement ref of the aggregate that finalized the transaction.
    ``refresh(receipt)`` re-resolves it against the live ledger
    (receipts are cheap provenance handles, not snapshots).
  * ``get_account(addr) -> AccountView``: balance / stake / reputation /
    protocol counters straight from the array-native account state
    (core/state.StateArrays).
  * ``state_root()``: the chunked state commitment.
  * ``events()``: pull-drain of the stack's typed event stream
    (core/events.py — ``BatchSealed`` / ``ProofGenerated`` /
    ``AggregateVerified`` / ``WindowSettled`` on rollup nodes,
    ``BlockPacked`` everywhere including chain-only nodes);
    ``capabilities()`` reports which event kinds the backend emits.
    The string-keyed callback ``subscribe`` is kept one release as a
    deprecation shim.

Receipt statuses (proof lifecycle, see ``RECEIPT_STATUSES``):
``pending`` (submitted, not sealed/confirmed) -> ``sealed`` (in a
committed L2 batch, proof job in flight) -> ``proved`` (the batch's
proof drained through the modeled prover, aggregate not yet posted) ->
``finalized`` (the aggregate's amortized verify/execute posted to the
L1).  On a chain-only node the ladder is ``pending`` -> ``confirmed``
(packed into a block).

Gas accounting contract (pinned by tests/test_api.py): a receipt's
``batch_*`` breakdown equals the ledger's own ``gas_log`` row, the
``amortized`` per-tx share sums back to the ledger's accounted L2 gas
over any full batch, and ``verify_share`` is the transaction's slice of
the ONE L1 verify its aggregate posted (the tunable amortization lever,
``repro.api.ProverSpec``).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from repro.api.factory import build_ledger, l1_of
from repro.api.specs import NodeSpec
from repro.core.events import LedgerEvent
from repro.core.gas import DEFAULT_GAS, L1_DEFAULT_GAS, GasTable

#: the proof lifecycle a receipt walks (chain-only nodes use
#: ``pending`` -> ``confirmed``)
RECEIPT_STATUSES = ("pending", "sealed", "proved", "finalized", "confirmed")


@dataclasses.dataclass
class TxReceipt:
    """Provenance handle for one submitted transaction."""

    fn: str
    sender: str
    gas: int                       # intrinsic (L1-schedule) gas of the tx
    submit_time: float
    status: str = "pending"        # see RECEIPT_STATUSES
    seq: Optional[int] = None      # provenance in the target's namespace
    shard: Optional[int] = None    # owning shard (fabric only)
    batch: Optional[int] = None    # global L2 batch id
    block: Optional[int] = None    # L1 block height (commit tx / own tx)
    block_hash: Optional[str] = None
    l1_ref: Optional[Any] = None   # L1 settlement ref of the commit
    confirm_time: Optional[float] = None
    proof_ref: Optional[int] = None      # the batch's proof job id
    aggregate_ref: Optional[int] = None  # the posted aggregate proof id
    gas_breakdown: Dict[str, float] = dataclasses.field(default_factory=dict)
    # object-path handle (the submitted Tx); excluded from equality so
    # receipts compare by provenance, not object identity
    tx: Optional[Any] = dataclasses.field(default=None, repr=False,
                                          compare=False)


@dataclasses.dataclass(frozen=True)
class AccountView:
    """One StateArrays row, by address (zeros for unknown accounts)."""

    address: str
    account_id: Optional[int]
    balance: float = 0.0
    stake: float = 0.0
    reputation: float = 0.0
    tasks_published: int = 0
    submissions: int = 0
    rep_events: int = 0


class NodeClient:
    """RPC-shaped façade over one ledger stack (L1 + optional L2)."""

    def __init__(self, target, chain=None,
                 gas_table: GasTable = DEFAULT_GAS, clock_start: float = 0.0):
        self.target = target
        self.chain = chain if chain is not None else l1_of(target)
        self.gas_table = gas_table
        self._clock = clock_start
        self._event_cursor = 0          # per-client typed-event cursor

    @classmethod
    def from_spec(cls, spec: NodeSpec, wire_state: bool = True,
                  **build_kw) -> "NodeClient":
        """Build the ledger from a spec and wrap it.  ``wire_state``
        attaches the default Table-I account-state handlers so
        ``get_account``/``state_root`` report live protocol counters."""
        target = build_ledger(spec, **build_kw)
        if wire_state and hasattr(target, "register_state"):
            from repro.core.state import default_state_handlers
            for fn, handler in default_state_handlers().items():
                target.register_state(fn, handler)
        gas = spec.chain.gas_table if isinstance(spec, NodeSpec) else \
            spec.gas_table
        return cls(target, gas_table=gas)

    # -- submission ------------------------------------------------------------
    def _stamp(self, at: Optional[float]) -> float:
        if at is None:
            self._clock += 0.01
            return self._clock
        self._clock = max(self._clock, float(at))
        return float(at)

    def submit(self, fn: str, sender: str, payload: Optional[Dict] = None,
               gas: Optional[int] = None,
               at: Optional[float] = None) -> TxReceipt:
        """Submit one transaction; returns its receipt (initially
        ``pending`` — call ``refresh`` after blocks/seals advance).

        ``payload`` rides only on the object backends; the SoA engines
        drop payloads by design, so passing one there is an error rather
        than a silent per-backend divergence."""
        gas = int(gas if gas is not None else
                  self.gas_table.l1_per_call.get(fn, L1_DEFAULT_GAS))
        t = self._stamp(at)
        target = self.target
        if getattr(target, "soa_native", False):
            if payload:
                raise ValueError(
                    "payloads need ChainSpec(backend='object'); the SoA "
                    "engines carry (time, gas, fn, sender) only")
            from repro.core.engine import TxArrays
            batch = TxArrays(np.array([t], np.float64),
                             np.array([gas], np.int64),
                             np.array([target.fns.id(fn)], np.int32),
                             np.array([target.sender_id(sender)], np.int32),
                             target.fns)
            prov = target.submit_arrays(batch)
            if isinstance(prov, tuple) and isinstance(prov[0], np.ndarray):
                rcpt = TxReceipt(fn, sender, gas, t, shard=int(prov[0][0]),
                                 seq=int(prov[1][0]))
            else:
                rcpt = TxReceipt(fn, sender, gas, t, seq=int(prov[0]))
        else:
            from repro.core.ledger import Tx
            tx = Tx(fn, sender, dict(payload or {}), gas, t)
            target.submit(tx)
            rcpt = TxReceipt(fn, sender, gas, t, tx=tx)
        return self.refresh(rcpt)

    def submit_arrays(self, batch) -> List[TxReceipt]:
        """Submit a SoA TxArrays batch; returns one receipt per tx."""
        fns = batch.fns
        names = [fns.names[int(f)] for f in batch.fn_id]
        prov = self.target.submit_arrays(batch)
        out = []
        if isinstance(prov, tuple) and isinstance(prov[0], np.ndarray):
            shard_of, seq_of = prov                   # sharded fabric
            for i in range(len(batch)):
                out.append(TxReceipt(
                    names[i], f"acct{int(batch.sender_id[i])}",
                    int(batch.gas[i]), float(batch.submit_time[i]),
                    shard=int(shard_of[i]), seq=int(seq_of[i])))
        elif isinstance(prov, tuple):                 # (lo, hi) range
            lo, _hi = prov
            for i in range(len(batch)):
                out.append(TxReceipt(
                    names[i], f"acct{int(batch.sender_id[i])}",
                    int(batch.gas[i]), float(batch.submit_time[i]),
                    seq=lo + i))
        else:                                         # object faces: Tx list
            for i, tx in enumerate(prov):
                out.append(TxReceipt(names[i], tx.sender, int(batch.gas[i]),
                                     float(batch.submit_time[i]), tx=tx))
        self._clock = max(self._clock,
                          float(batch.submit_time[-1]) if len(batch) else 0.0)
        return out

    # -- receipt resolution ----------------------------------------------------
    def refresh(self, rcpt: TxReceipt) -> TxReceipt:
        """Re-resolve a receipt against the live ledger (in place)."""
        t = self.target
        if hasattr(t, "shards"):                      # sharded fabric
            self._refresh_rollup(rcpt, t.shards[rcpt.shard])
        elif hasattr(t, "batch_size"):                # rollup face
            self._refresh_rollup(rcpt, t)
        else:                                         # chain-only
            self._refresh_chain(rcpt)
        return rcpt

    def _refresh_rollup(self, r: TxReceipt, ru) -> None:
        if r.tx is not None:                          # object Rollup
            batch = ru.tx_batch.get(r.tx.tx_id)
        else:
            batch = ru.batch_of_seq(r.seq)
        if batch is None:
            r.status = "pending"
            return
        r.batch = int(batch)
        row = ru.gas_log[batch] if (batch < len(ru.gas_log) and
                                    ru.gas_log[batch]["batch"] == batch) \
            else next(x for x in ru.gas_log if x["batch"] == batch)
        n_txs = max(1, int(row["n_txs"]))
        r.gas_breakdown = {
            "intrinsic": float(r.gas),
            "batch_commit": float(row["commit"]),
            "batch_verify": float(row["verify"]),
            "batch_execute": float(row["execute"]),
            "batch_total": float(row["total"]),
            "batch_n_txs": float(row["n_txs"]),
            "amortized": float(row["total"]) / n_txs,
            # per-tx slice of the ONE L1 verify the batch's aggregate
            # posted (0 until finalized) — the ProverSpec.agg_width lever
            "verify_share": float(row["verify"]) / n_txs,
        }
        r.proof_ref = row.get("job")
        r.aggregate_ref = row.get("aggregate")
        if batch in ru.batch_settle_ref:
            r.status = "finalized"
        else:
            prover = getattr(ru, "prover", None)
            phase = prover.phase_of(ru, batch) if prover is not None \
                else None
            r.status = phase if phase is not None else "sealed"
        ref = ru.batch_commit_ref.get(batch)
        r.l1_ref = getattr(ref, "tx_id", ref)
        if isinstance(ref, (int, np.integer)):        # VectorChain L1 index
            blk = self.chain.block_of(int(ref))
            if blk is not None:
                r.block, r.block_hash = blk.height, blk.block_hash
                r.confirm_time = self.chain.confirm_time_of(int(ref))
        elif ref is not None:                         # object Chain Tx
            r.block, r.confirm_time = ref.block_height, ref.confirm_time
            if ref.block_height is not None:
                r.block_hash = \
                    self.chain.blocks[ref.block_height].block_hash

    def _refresh_chain(self, r: TxReceipt) -> None:
        r.gas_breakdown = {"intrinsic": float(r.gas)}
        if r.tx is not None:                          # object Chain
            if r.tx.confirm_time is None:
                r.status = "pending"
                return
            r.status = "confirmed"
            r.block, r.confirm_time = r.tx.block_height, r.tx.confirm_time
            if r.tx.block_height is not None:
                r.block_hash = self.chain.blocks[r.tx.block_height].block_hash
        else:                                         # VectorChain
            blk = self.chain.block_of(r.seq)
            if blk is None:
                r.status = "pending"
                return
            r.status = "confirmed"
            r.block, r.block_hash = blk.height, blk.block_hash
            r.confirm_time = self.chain.confirm_time_of(r.seq)
        r.l1_ref = r.block_hash

    # -- state queries ---------------------------------------------------------
    def _state_arrays(self):
        st = getattr(self.target, "state", None)
        from repro.core.state import StateArrays
        if isinstance(st, StateArrays):               # fabric keeps it here
            return st
        return getattr(self.target, "state_arrays", None)

    def get_account(self, addr: str) -> AccountView:
        """Balance/stake/reputation + protocol counters for an address
        (a read: unknown addresses are NOT minted into the namespace)."""
        sid = getattr(self.target, "_sender_ids", {}).get(addr)
        st = self._state_arrays()
        if sid is None or st is None or sid >= st.n:
            return AccountView(addr, sid)
        return AccountView(
            addr, sid, balance=float(st.balances[sid]),
            stake=float(st.stake[sid]), reputation=float(st.reputation[sid]),
            tasks_published=int(st.tasks_published[sid]),
            submissions=int(st.submissions[sid]),
            rep_events=int(st.rep_events[sid]))

    def state_root(self) -> str:
        return self.target.state_root()

    # -- events ----------------------------------------------------------------
    def _event_log(self):
        log = getattr(self.target, "events", None)
        return log if log is not None else getattr(self.chain, "events")

    def capabilities(self) -> frozenset:
        """Typed-event kinds this backend emits through ``events()``,
        plus the execution-path marker ``"fused_window_loop"`` when the
        stack can run the core/fused.py plan-then-execute loop (what
        ``Scheduler(fused="auto")`` will pick — a non-capable stack falls
        back to the Python-stepped loop, with a one-time log).

        Every node emits ``block_packed`` (L1 block production); rollup
        nodes add the proof lifecycle.  Use this instead of probing —
        chain-only nodes are a smaller surface, not an error."""
        from repro.core.fused import supports_fused
        caps = {"block_packed"}
        if getattr(self.target, "prover", None) is not None:
            caps |= {"batch_sealed", "proof_generated",
                     "aggregate_verified", "window_settled"}
        rollup = None if self.target is self.chain else self.target
        if supports_fused(self.chain, rollup):
            caps.add("fused_window_loop")
        return frozenset(caps)

    def events(self, kinds=None,
               cursor: Optional[int] = None) -> List[LedgerEvent]:
        """Drain the typed events emitted since this client's last call
        (pull-based; cursors are per client, so independent consumers
        see the full stream).  ``kinds``: optional iterable of event
        kinds to keep — filtering still advances the cursor past
        everything drained.

        ``cursor`` switches to explicit multi-consumer mode: read from
        that position WITHOUT touching this client's own cursor (use
        ``events_page`` when you also need the resume cursor — the
        serving layer's events endpoint is built on it).  On a bounded
        (ring-buffer) log a stale cursor yields a leading
        ``EventsDropped`` marker rather than a silent skip."""
        log = self._event_log()
        if cursor is None:
            new = log.since(self._event_cursor)
            self._event_cursor = log.next_cursor
        else:
            new = log.since(int(cursor))
        if kinds is not None:
            kinds = frozenset(kinds)
            new = [e for e in new if e.kind in kinds]
        return new

    def events_page(self, cursor: int = 0, kinds=None,
                    limit: Optional[int] = None):
        """One page of the typed event stream for an explicit consumer:
        ``(events, next_cursor, n_dropped)``.  ``next_cursor`` resumes
        after the last event the page covered (pass it back on the next
        call); ``n_dropped`` counts events a bounded log evicted before
        ``cursor`` (0 on unbounded logs — the default everywhere outside
        serving).  ``kinds`` filters the returned events but never what
        the cursor advances past."""
        log = self._event_log()
        n_dropped = log.dropped(int(cursor))
        new = log.since(int(cursor))
        if n_dropped:
            new = new[1:]                 # drop the synthesized marker;
        if limit is not None:             # n_dropped reports the gap
            new = new[:int(limit)]
        next_cursor = (new[-1].seq + 1 if new
                       else max(int(cursor), log.base))
        if kinds is not None:
            kinds = frozenset(kinds)
            new = [e for e in new if e.kind in kinds]
        return new, next_cursor, n_dropped

    def subscribe(self, event: str, callback: Callable) -> None:
        """DEPRECATED one-release shim over the string-keyed callback
        hooks (``batch_sealed``/``session_settled`` on rollup faces,
        ``window_settled`` on the fabric, ``block_packed`` on the L1) —
        drain typed events via ``events()`` instead."""
        warnings.warn(
            "NodeClient.subscribe is deprecated; drain typed events via "
            "client.events() (see docs/MIGRATION.md)", DeprecationWarning,
            stacklevel=2)
        if event == "block_packed":
            self.chain.subscribe(event, callback)
            return
        target = self.target
        sub = getattr(target, "subscribe", None)
        legacy = set(getattr(target, "EVENTS", ()))
        if hasattr(target, "shards"):
            legacy |= {"batch_sealed", "session_settled", "window_settled"}
        if sub is None or event not in legacy:
            raise ValueError(
                f"event {event!r} is not a callback hook of this backend; "
                f"typed stream capabilities: {sorted(self.capabilities())} "
                f"(use client.events())")
        sub(event, callback)

    # -- lifecycle passthroughs ------------------------------------------------
    def seal(self) -> int:
        """Seal pending L2 batches (no-op count on chain-only nodes)."""
        seal = getattr(self.target, "seal", None)
        return seal() if seal is not None else 0

    def flush(self) -> None:
        """Seal + settle the open L2 session (chain-only: no-op)."""
        flush = getattr(self.target, "flush", None)
        if flush is not None:
            flush()

    def run_until(self, t_end: float) -> None:
        """Drive the modeled prover's drain — and then L1 block
        production — to ``t_end`` simulated seconds (the shared window
        clock).  The prover pumps FIRST so that window-finalized
        settlement transactions (stamped at their drain times <= t_end)
        land in the mempool before the blocks that should pack them."""
        pump = getattr(self.target, "pump", None)
        if pump is not None:
            pump(t_end)
        self.chain.run_until(t_end)
        self._clock = max(self._clock, t_end)
