"""One ledger factory: ``build_ledger(spec) -> LedgerBackend``.

Every backend the repo grew — ``Chain``/``Rollup`` (object path),
``VectorChain``/``VectorRollup`` (SoA path), ``ShardedRollup`` (fabric) —
is constructed here from a typed spec instead of string flags scattered
over call sites.  The factory is the only place that knows which class
each spec combination maps to:

    ChainSpec alone (or NodeSpec(rollup=None))   -> VectorChain | Chain
    + RollupSpec                                 -> VectorRollup | Rollup
    + ShardSpec(count>1 or fabric=True)          -> ShardedRollup

``build_ledger`` returns the SUBMISSION target (the L2 face when a
rollup is configured, else the L1 itself); the rollup faces keep their
L1 on ``.l1``, and ``l1_of`` resolves it uniformly.
"""
from __future__ import annotations

from typing import Optional, Tuple, Union

from repro.api.specs import ChainSpec, NodeSpec
from repro.core.ledger import LedgerBackend

LedgerSpec = Union[NodeSpec, ChainSpec]


def _as_node_spec(spec: LedgerSpec) -> NodeSpec:
    if isinstance(spec, ChainSpec):
        return NodeSpec(chain=spec, rollup=None)
    if isinstance(spec, NodeSpec):
        return spec
    raise TypeError(f"expected NodeSpec or ChainSpec, got {type(spec)!r}")


def build_chain(spec: ChainSpec, *, fns=None):
    """Build just the L1 from a ChainSpec.

    ``fns``: optional engine FnRegistry to share (vector backend only) —
    a runtime handle, deliberately NOT part of the spec data.
    """
    if spec.backend == "vector":
        from repro.core.engine import VectorChain
        return VectorChain(n_validators=spec.n_validators,
                           block_time=spec.block_time,
                           block_gas_limit=spec.block_gas_limit,
                           gas_table=spec.gas_table, fns=fns)
    from repro.core.ledger import Chain
    return Chain(n_validators=spec.n_validators, block_time=spec.block_time,
                 block_gas_limit=spec.block_gas_limit,
                 gas_table=spec.gas_table)


def build_stack(spec: LedgerSpec, *, fns=None, state=None
                ) -> Tuple[object, Optional[object]]:
    """Build (l1_chain, rollup_or_None) from a spec.

    ``state``: optional pre-built StateArrays for the sharded fabric.
    """
    from repro.api.specs import ProverSpec
    node = _as_node_spec(spec)
    chain = build_chain(node.chain, fns=fns)
    ru = node.rollup
    if ru is None:
        return _sanitized(chain, None)
    pv = node.prover if node.prover is not None else ProverSpec()
    prove_time = ru.prove_time if pv.prove_time is None else pv.prove_time
    prover_kw = dict(agg_width=pv.agg_width, prover_capacity=pv.capacity,
                     finalize=pv.finalize)
    if node.shards is not None and node.shards.wants_fabric:
        from repro.core.shards import ShardedRollup
        return _sanitized(chain, ShardedRollup(
            chain, n_shards=node.shards.count, batch_size=ru.batch_size,
            gas_table=node.chain.gas_table, prove_time=prove_time,
            per_tx_time=ru.per_tx_time, n_lanes=ru.n_lanes,
            digest_backend=ru.digest_backend, route=node.shards.route,
            state=state, interconnect=node.shards.interconnect,
            mesh=node.shards.mesh, **prover_kw))
    if node.chain.backend == "vector":
        from repro.core.engine import VectorRollup
        return _sanitized(chain, VectorRollup(
            chain, batch_size=ru.batch_size, gas_table=node.chain.gas_table,
            prove_time=prove_time, per_tx_time=ru.per_tx_time,
            n_lanes=ru.n_lanes, digest_backend=ru.digest_backend,
            **prover_kw))
    from repro.core.rollup import Rollup
    return _sanitized(chain, Rollup(chain, batch_size=ru.batch_size,
                                    gas_table=node.chain.gas_table,
                                    prove_time=prove_time,
                                    per_tx_time=ru.per_tx_time, **prover_kw))


def _sanitized(chain, rollup):
    """REPRO_SANITIZE=1 installs the runtime sanitizer on every stack
    this factory builds (see analysis/sanitize.py; a no-op otherwise)."""
    from repro.analysis import sanitize
    if sanitize.enabled():
        sanitize.install_stack(chain, rollup)
    return chain, rollup


def build_ledger(spec: LedgerSpec, *, fns=None, state=None) -> LedgerBackend:
    """THE ledger factory: spec -> the LedgerBackend you submit to.

    When the spec configures a rollup, the returned backend is the L2
    face and its L1 is reachable as ``.l1``; otherwise the L1 itself is
    returned.  Use ``l1_of`` to resolve the chain either way.
    """
    chain, rollup = build_stack(spec, fns=fns, state=state)
    return rollup if rollup is not None else chain


def l1_of(backend) -> object:
    """The L1 chain behind any backend built by ``build_ledger``."""
    return getattr(backend, "l1", backend)


def build_node(spec: NodeSpec, model, opt, eval_fn, val_batch, **kw):
    """Build a full protocol node (fl/server.AutoDFL) from a NodeSpec.

    ``spec.n_trainers`` is required here (the ledger-only factories
    don't need it).  Extra ``kw`` are forwarded to AutoDFL.
    """
    if spec.n_trainers is None:
        raise ValueError("build_node needs spec.n_trainers")
    from repro.fl.server import AutoDFL
    return AutoDFL(model, opt, spec.n_trainers, eval_fn, val_batch,
                   spec=spec, **kw)
