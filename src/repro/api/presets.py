"""Named NodeSpec presets — each benchmark's scenario as data.

``benchmarks/*.py`` fetch their node/ledger configuration here instead of
hand-wiring constructors, and ``benchmarks/run.py --all`` folds the
catalog into ``BENCH_summary.json`` so a PR diff shows scenario changes
as spec diffs, not code reading.

``preset(name, **overrides)`` returns a copy with replaced fields, e.g.
``preset("shard-fabric", shards=ShardSpec(count=2))`` for the CI smoke
configuration.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

from repro.api.specs import (ChainSpec, NodeSpec, ProverSpec, ShardSpec,
                             WorkloadSpec)

#: the benchmark scenario catalog (immutable specs; override per point)
PRESETS: Dict[str, NodeSpec] = {
    # Fig. 4 / Fig. 5: bare L1 saturation sweeps, one per engine path
    "l1-vector": NodeSpec(rollup=None),
    "l1-object": NodeSpec(chain=ChainSpec(backend="object"), rollup=None),
    # Table I / Table II: the paper-faithful object rollup over an object L1
    "rollup-object": NodeSpec(chain=ChainSpec(backend="object")),
    # the SoA rollup (multi-lane latency sweeps override n_lanes)
    "rollup-vector": NodeSpec(),
    # bench_protocol: sequential paper-faithful baseline vs the vectorized
    # scheduler node (funds are scaled per point via preset overrides)
    "protocol-sequential": NodeSpec(chain=ChainSpec(backend="object")),
    "protocol-scheduler": NodeSpec(),
    # bench_shards: the fabric point (shard count overridden per point)
    "shard-fabric": NodeSpec(shards=ShardSpec(count=8),
                             workload=WorkloadSpec.make(
                                 "mixed", 20_000.0, duration=10.0, seed=0)),
    # bench_prover: the proof-aggregation sweep (agg_width overridden per
    # point; the workload is settled in window-sized sessions)
    "prover-pipeline": NodeSpec(prover=ProverSpec(agg_width=8),
                                workload=WorkloadSpec.make(
                                    "mixed", 4_000.0, duration=10.0,
                                    seed=0)),
}


def preset(name: str, **overrides: Any) -> NodeSpec:
    """Fetch a preset, optionally replacing spec fields."""
    try:
        spec = PRESETS[name]
    except KeyError:
        raise KeyError(f"unknown preset {name!r}; "
                       f"catalog: {sorted(PRESETS)}") from None
    return dataclasses.replace(spec, **overrides) if overrides else spec


def describe_presets() -> Dict[str, Dict]:
    """JSON-friendly catalog (BENCH_summary.json's ``presets`` section)."""
    return {name: spec.describe() for name, spec in sorted(PRESETS.items())}
