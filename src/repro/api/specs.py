"""Typed node-construction specs — the ONE public way to describe a node.

The reproduction grew five ledger backends (``Chain``/``Rollup`` on the
object path, ``VectorChain``/``VectorRollup`` on the SoA path, plus the
``ShardedRollup`` fabric) selected through scattered string flags
(``engine="object"``, ``use_rollup=``, ``n_shards=``, ``shard_route=``)
and a 13-kwarg ``AutoDFL.__init__``.  This module replaces that wiring
with small frozen dataclasses, composed into a ``NodeSpec``:

  * ``ChainSpec``       — the L1 (QBFT parameters + which engine path)
  * ``RollupSpec``      — the L2 sequencer (batch size, lanes, timing)
  * ``ProverSpec``      — the proof pipeline (aggregation width, prover
    capacity/latency, eager vs. windowed finalization)
  * ``ShardSpec``       — the sharded fabric (shard count, routing)
  * ``ReputationSpec``  — paper Eq. 2-10 constants
  * ``DONSpec``         — decentralized-oracle-network quorum config
  * ``WorkloadSpec``    — a core/workloads.py scenario, as data
  * ``FLTaskSpec``      — one FL task's lifecycle parameters

Specs are *data*: frozen, comparable, serializable (``asdict``) — a
benchmark or example declares its scenario as a spec and hands it to
``repro.api.build_ledger`` / ``AutoDFL(..., spec=...)`` instead of
hand-wiring constructors.  ``repro/api/presets.py`` catalogs the specs
the benchmarks run.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

from repro.core.gas import DEFAULT_GAS, ROLLUP_BATCH, GasTable
from repro.core.interconnect import InterconnectSpec
from repro.core.oracle import DONConfig
from repro.core.reputation import ReputationParams

#: engine paths a ChainSpec can select (the old ``engine=`` string flag)
CHAIN_BACKENDS = ("vector", "object")


@dataclasses.dataclass(frozen=True)
class ChainSpec:
    """L1 permissioned chain: QBFT quorum + gas-limited FIFO blocks.

    ``backend="vector"`` is the SoA hot path (core/engine.VectorChain);
    ``"object"`` the per-Tx simulator (core/ledger.Chain) for
    handler-rich small-N debugging.  Both are bit-identical in
    semantics (tests/test_engine.py).
    """

    backend: str = "vector"
    n_validators: int = 4
    block_time: float = 1.0
    block_gas_limit: int = 9_000_000
    gas_table: GasTable = DEFAULT_GAS

    def __post_init__(self):
        if self.backend not in CHAIN_BACKENDS:
            raise ValueError(f"unknown chain backend {self.backend!r}; "
                             f"choose from {CHAIN_BACKENDS}")


@dataclasses.dataclass(frozen=True)
class RollupSpec:
    """L2 zk-rollup sequencer (paper §III-C.3).

    Presence of a RollupSpec in a NodeSpec IS the old ``use_rollup=True``;
    ``NodeSpec(rollup=None)`` is the single-layer L1 baseline.
    """

    batch_size: int = ROLLUP_BATCH
    n_lanes: int = 1
    prove_time: float = 0.9
    per_tx_time: float = 0.14
    digest_backend: str = "auto"        # "auto" | "pallas" | "numpy"

    def __post_init__(self):
        if self.n_lanes < 1:
            raise ValueError("n_lanes must be >= 1")


@dataclasses.dataclass(frozen=True)
class ProverSpec:
    """Proof pipeline (core/prover.py): how sealed batches become one
    verified L1 posting.

    ``agg_width``: settle-sessions folded into one aggregate proof — the
    single L1 verify amortizes across every batch of the aggregate (the
    paper's 20X gas lever, tunable).  Width 1 posts at every
    ``settle_session`` — bit-equivalent to the pre-pipeline settlement
    path (pinned by tests/test_prover.py).

    ``capacity``/``prove_time``: the modeled prover — ``capacity``
    concurrent workers, ``prove_time`` seconds per batch proof
    (``None`` inherits ``RollupSpec.prove_time``).  Jobs drain on the
    shared window clock (``pump``/``NodeClient.run_until``).

    ``finalize``: ``"eager"`` posts as soon as ``agg_width`` sessions
    close; ``"window"`` defers posting to window-clock pumps, releasing
    only aggregates whose proofs have fully drained (``flush`` always
    forces the remainder).
    """

    agg_width: int = 1
    capacity: int = 1
    prove_time: Optional[float] = None
    finalize: str = "eager"             # "eager" | "window"

    def __post_init__(self):
        from repro.core.prover import FINALIZE_MODES
        if self.agg_width < 1:
            raise ValueError("agg_width must be >= 1")
        if self.capacity < 1:
            raise ValueError("prover capacity must be >= 1")
        if self.finalize not in FINALIZE_MODES:
            raise ValueError(f"unknown finalize mode {self.finalize!r}; "
                             f"choose from {FINALIZE_MODES}")


@dataclasses.dataclass(frozen=True)
class ShardSpec:
    """Sharded rollup fabric (core/shards.py): K sequencers, one L1.

    ``count=1`` without ``fabric=True`` means a plain (unsharded) rollup;
    ``fabric=True`` forces the ``ShardedRollup`` wrapper even at one
    shard — bit-equivalent to ``VectorRollup`` (pinned by tests) but with
    fabric roots and per-shard receipts.

    ``mesh`` governs whether the fused window loop folds the K shard
    lanes' seal digests through the mesh-mapped ``shard_seal`` kernel
    (kernels/shard_lanes.py over launch/mesh.make_shard_mesh): ``"auto"``
    uses the device mesh exactly when more than one local device exists,
    ``"on"``/``"off"`` force it.  A pure performance choice — every impl
    is bit-exact (pinned by tests/test_shard_lanes.py).

    ``interconnect`` (core/interconnect.InterconnectSpec) overrides the
    fabric's modeled per-link wire costs — shard->L1 root gathering,
    shard<->shard settlement scatter, cohort->shard submission.  ``None``
    means the default single-datacenter links; the model only feeds the
    benchmark latency decomposition, never the Table-II numbers.
    """

    count: int = 1
    route: str = "hash"                 # "hash" | "least_loaded"
    fabric: bool = False
    mesh: str = "auto"                  # "auto" | "on" | "off"
    interconnect: Optional[InterconnectSpec] = None

    def __post_init__(self):
        if self.count < 1:
            raise ValueError("shard count must be >= 1")
        if self.route not in ("hash", "least_loaded"):
            raise ValueError(f"unknown shard route {self.route!r}")
        if self.mesh not in ("auto", "on", "off"):
            raise ValueError(f"unknown shard mesh mode {self.mesh!r}; "
                             "choose from ('auto', 'on', 'off')")

    @property
    def wants_fabric(self) -> bool:
        return self.fabric or self.count > 1


@dataclasses.dataclass(frozen=True)
class ReputationSpec(ReputationParams):
    """Paper Eq. 2-10 constants, as a spec (field docs on
    core/reputation.ReputationParams)."""

    def to_params(self) -> ReputationParams:
        return ReputationParams(**dataclasses.asdict(self))

    @classmethod
    def from_params(cls, p: ReputationParams) -> "ReputationSpec":
        return cls(**dataclasses.asdict(p))


@dataclasses.dataclass(frozen=True)
class DONSpec(DONConfig):
    """Decentralized oracle network quorum config (core/oracle.DONConfig)."""

    def to_config(self) -> DONConfig:
        return DONConfig(**dataclasses.asdict(self))

    @classmethod
    def from_config(cls, c: DONConfig) -> "DONSpec":
        return cls(**dataclasses.asdict(c))


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    """A core/workloads.py scenario, as data.

    ``options`` are the scenario factory's extra kwargs, stored as a
    sorted item tuple so the spec stays hashable/frozen.
    """

    scenario: str = "poisson"
    rate: float = 100.0
    duration: float = 30.0
    seed: int = 0
    options: Tuple[Tuple[str, Any], ...] = ()

    @classmethod
    def make(cls, scenario: str, rate: float, duration: float = 30.0,
             seed: int = 0, **options) -> "WorkloadSpec":
        return cls(scenario, rate, duration, seed,
                   tuple(sorted(options.items())))

    def build(self):
        """Materialize the Workload (time-sorted TxArrays + metadata)."""
        from repro.core.workloads import make_workload
        return make_workload(self.scenario, self.rate,
                             duration=self.duration, seed=self.seed,
                             **dict(self.options))


@dataclasses.dataclass(frozen=True)
class FLTaskSpec:
    """One FL task's lifecycle parameters (paper Fig. 1 steps 1-16).

    Consumed by ``AutoDFL.run_task`` and ``Scheduler.add_task`` in place
    of their loose kwargs.
    """

    task_id: str
    rounds: int = 5
    reward: float = 10.0
    n_select: Optional[int] = None
    start_window: int = 0
    init_seed: int = 0


def as_task_spec(task, **kw) -> FLTaskSpec:
    """Back-compat shim shared by ``AutoDFL.run_task`` and
    ``Scheduler.add_task``: a task-id string plus loose kwargs becomes an
    FLTaskSpec (defaults live on FLTaskSpec alone); an FLTaskSpec passes
    through, rejecting extra kwargs it would otherwise shadow."""
    if isinstance(task, str):
        return FLTaskSpec(task, **{k: v for k, v in kw.items()
                                   if v is not None})
    if not isinstance(task, FLTaskSpec):
        raise TypeError(f"expected task id or FLTaskSpec, got {task!r}")
    extra = {k for k, v in kw.items() if v is not None}
    if extra:
        raise ValueError(f"kwargs {sorted(extra)} conflict with the "
                         f"FLTaskSpec; set them on the spec")
    return task


@dataclasses.dataclass(frozen=True)
class NodeSpec:
    """The full node: L1 + optional L2 (+ optional fabric) + protocol
    constants.  ``build_ledger(spec)`` turns the ledger part into a
    LedgerBackend; ``AutoDFL(..., spec=spec)`` builds the protocol node.

    ``n_trainers=None`` defers the cohort size to the caller
    (``AutoDFL``'s positional argument); ``build_node`` requires it.
    """

    chain: ChainSpec = dataclasses.field(default_factory=ChainSpec)
    rollup: Optional[RollupSpec] = dataclasses.field(
        default_factory=RollupSpec)
    prover: Optional[ProverSpec] = None     # None = default proof pipeline
    shards: Optional[ShardSpec] = None
    reputation: ReputationSpec = dataclasses.field(
        default_factory=ReputationSpec)
    don: DONSpec = dataclasses.field(default_factory=DONSpec)
    n_trainers: Optional[int] = None
    trainer_funds: float = 10.0
    publisher_funds: float = 1000.0
    seed: int = 0
    use_pallas_agg: bool = False
    workload: Optional[WorkloadSpec] = None     # background traffic
    tasks: Tuple[FLTaskSpec, ...] = ()          # declarative task set

    def __post_init__(self):
        if self.prover is not None and self.rollup is None:
            raise ValueError("a ProverSpec needs a RollupSpec (the proof "
                             "pipeline settles sealed L2 batches)")
        if self.shards is not None and self.shards.wants_fabric:
            if self.rollup is None:
                raise ValueError("a sharded fabric needs a RollupSpec")
            if self.chain.backend != "vector":
                raise ValueError("sharding needs the vector chain backend")
        if self.rollup is not None and self.chain.backend == "object":
            # the object Rollup has no lane striping or digest routing —
            # reject rather than silently build a single-lane rollup
            if self.rollup.n_lanes != 1:
                raise ValueError("n_lanes > 1 needs the vector backend")
            if self.rollup.digest_backend != "auto":
                raise ValueError("digest_backend is a vector-backend knob")

    # -- legacy flag mapping (the deprecation shim's single source) --------
    @classmethod
    def from_legacy(cls, *, engine: str = "object", use_rollup: bool = True,
                    n_shards: int = 1, shard_route: str = "hash",
                    rep_params: Optional[ReputationParams] = None,
                    don: Optional[DONConfig] = None,
                    trainer_funds: float = 10.0,
                    publisher_funds: float = 1000.0, seed: int = 0,
                    use_pallas_agg: bool = False) -> "NodeSpec":
        """Map the old AutoDFL kwargs onto a NodeSpec (one release shim).

        The mapping is pinned against the legacy constructor path by
        tests/test_api.py: same state root, same gas totals.
        """
        shards = (ShardSpec(count=n_shards, route=shard_route)
                  if n_shards > 1 else None)
        return cls(
            chain=ChainSpec(backend=engine),
            rollup=RollupSpec() if use_rollup else None,
            shards=shards,
            reputation=(ReputationSpec.from_params(rep_params)
                        if rep_params is not None else ReputationSpec()),
            don=(DONSpec.from_config(don) if don is not None else DONSpec()),
            trainer_funds=trainer_funds, publisher_funds=publisher_funds,
            seed=seed, use_pallas_agg=use_pallas_agg)

    def describe(self) -> Dict[str, Any]:
        """JSON-friendly summary (used by benchmarks/run.py --all)."""
        d = dataclasses.asdict(self)
        d["chain"].pop("gas_table", None)       # calibration table, not data
        return d


#: reputation-gate policies an AdmissionSpec can select
REP_GATES = ("off", "surcharge", "reject")


@dataclasses.dataclass(frozen=True)
class AdmissionSpec:
    """Mempool admission rules for the node service (repro/serve).

    Every rule is a pure function of (this spec, the sender's modeled
    state, the pending pool) — no wall clock anywhere on the decision
    path (rule R008); the token bucket refills on the MODELED submit
    time, the same window clock the ledgers run on.

      * ``rate_limit``/``burst`` — per-sender token bucket: ``burst``
        tokens deep, refilling ``rate_limit`` tokens per modeled second;
        each transaction consumes one token.
      * ``fee_floor`` — minimum offered fee (gas) for any transaction.
      * ``rep_gate`` — senders whose reputation is below the trust line
        (``ReputationParams.r_min``; unknown senders start at ``r_init``)
        are ``"reject"``-ed outright, or under ``"surcharge"`` must
        offer at least ``rep_surcharge`` x the function's intrinsic gas;
        ``"off"`` disables the gate.
      * ``pool_cap``/``evict`` — the pending pool holds at most
        ``pool_cap`` admitted transactions per flush window; at cap,
        ``evict=True`` drops the lowest-fee entry to make room for a
        strictly higher-fee arrival (spam eviction — spam floods the
        cheapest function, so it drains first), ``evict=False`` rejects
        the arrival as overloaded instead.
    """

    rate_limit: float = 50.0
    burst: float = 20.0
    fee_floor: int = 0
    rep_gate: str = "surcharge"
    rep_surcharge: float = 1.5
    pool_cap: int = 4096
    evict: bool = True

    def __post_init__(self):
        if self.rate_limit <= 0 or self.burst < 1:
            raise ValueError("rate_limit must be > 0 and burst >= 1")
        if self.rep_gate not in REP_GATES:
            raise ValueError(f"unknown rep_gate {self.rep_gate!r}; "
                             f"choose from {REP_GATES}")
        if self.rep_surcharge < 1.0:
            raise ValueError("rep_surcharge must be >= 1.0")
        if self.pool_cap < 1:
            raise ValueError("pool_cap must be >= 1")


@dataclasses.dataclass(frozen=True)
class ServeSpec:
    """One concurrent node service (repro/serve.NodeService): the node it
    fronts, its admission rules, and the serving knobs.

      * ``queue_cap`` — bound of the single-writer op queue; a submit
        arriving while the queue is full gets an explicit
        ``overloaded``/HTTP-429 response (the backpressure contract).
      * ``window`` — modeled seconds between pool flushes: the service
        drains the admitted pool into the ledger, seals, and pumps
        ``run_until`` at every window boundary the modeled clock
        crosses.
      * ``event_cap`` — bounds the stack's EventLog as a ring buffer so
        long-lived multi-consumer serving cannot grow it without limit
        (``None`` keeps the default unbounded log).
    """

    node: NodeSpec = dataclasses.field(default_factory=NodeSpec)
    admission: AdmissionSpec = dataclasses.field(
        default_factory=AdmissionSpec)
    host: str = "127.0.0.1"
    port: int = 8545
    queue_cap: int = 1024
    window: float = 1.0
    event_cap: Optional[int] = None

    def __post_init__(self):
        if self.queue_cap < 1:
            raise ValueError("queue_cap must be >= 1")
        if self.window <= 0:
            raise ValueError("window must be > 0 modeled seconds")
        if self.event_cap is not None and self.event_cap < 1:
            raise ValueError("event_cap must be >= 1 (or None)")
