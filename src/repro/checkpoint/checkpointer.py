"""Content-addressed, atomic, sharded checkpointing (the IPFS analogue).

Layout:
    <dir>/step_000123/
        manifest.json        # leaf paths, shapes, dtypes, blob cids, hash
        blobs/<cid>.npy      # one blob per leaf (content-addressed)
    <dir>/LATEST             # atomic pointer file

Guarantees:
  * atomic publish (manifest written last, LATEST renamed last);
  * integrity: every blob re-hashed on restore (tamper/corruption check);
  * dedup: unchanged leaves (same cid) are not rewritten across steps;
  * async save (background thread) keeps the train loop hot.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _leaf_paths(tree) -> Dict[str, Any]:
    flat = {}

    def walk(path, node):
        if isinstance(node, dict):
            for k, v in node.items():
                walk(path + (str(k),), v)
        elif hasattr(node, "shape") or np.isscalar(node):
            flat["/".join(path)] = node
        else:
            raise TypeError(
                f"checkpointer stores dict-of-array pytrees; got "
                f"{type(node).__name__} at {'/'.join(path)!r} — convert "
                f"dataclass nodes to dicts first (see launch/train.py)")
    walk((), tree)
    return flat


def _unflatten(flat: Dict[str, Any]):
    root: Dict[str, Any] = {}
    for path, leaf in flat.items():
        node = root
        parts = path.split("/")
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = leaf
    return root


def _cid(arr: np.ndarray) -> str:
    return hashlib.sha256(arr.tobytes()).hexdigest()[:32]


def _np_dtype(name: str) -> np.dtype:
    """np.dtype that understands ml_dtypes names (bfloat16, float8_*...)."""
    try:
        dt = np.dtype(name)
        if dt != np.dtype(object):
            return dt
    except TypeError:
        pass
    import ml_dtypes
    return np.dtype(getattr(ml_dtypes, name))


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(os.path.join(self.dir, "blobs"), exist_ok=True)
        self._async_thread: Optional[threading.Thread] = None

    # -- save --------------------------------------------------------------------
    def save(self, step: int, tree, extra: Optional[Dict] = None):
        flat = {k: np.asarray(v) for k, v in _leaf_paths(tree).items()}
        manifest = {"step": step, "extra": extra or {}, "leaves": {}}
        for path, arr in flat.items():
            # npy round-trips bfloat16 poorly; store raw bytes + dtype str
            raw = arr.tobytes()
            cid = hashlib.sha256(raw).hexdigest()[:32]
            blob = os.path.join(self.dir, "blobs", cid + ".bin")
            if not os.path.exists(blob):
                tmp = blob + f".tmp{os.getpid()}"
                with open(tmp, "wb") as f:
                    f.write(raw)
                os.replace(tmp, blob)
            manifest["leaves"][path] = {
                "cid": cid, "shape": list(arr.shape), "dtype": str(arr.dtype)}
        step_dir = os.path.join(self.dir, f"step_{step:09d}")
        os.makedirs(step_dir, exist_ok=True)
        mtmp = os.path.join(step_dir, "manifest.json.tmp")
        with open(mtmp, "w") as f:
            json.dump(manifest, f)
        os.replace(mtmp, os.path.join(step_dir, "manifest.json"))
        # atomic LATEST pointer
        ltmp = os.path.join(self.dir, "LATEST.tmp")
        with open(ltmp, "w") as f:
            f.write(f"step_{step:09d}")
        os.replace(ltmp, os.path.join(self.dir, "LATEST"))
        self._gc()
        return manifest

    def save_async(self, step: int, tree, extra: Optional[Dict] = None):
        # snapshot to host BEFORE backgrounding (device buffers may be donated)
        host_tree = jax.tree.map(np.asarray, tree)
        self.wait()
        self._async_thread = threading.Thread(
            target=self.save, args=(step, host_tree, extra), daemon=True)
        self._async_thread.start()

    def wait(self):
        if self._async_thread is not None:
            self._async_thread.join()
            self._async_thread = None

    # -- restore -------------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        ptr = os.path.join(self.dir, "LATEST")
        if not os.path.exists(ptr):
            return None
        with open(ptr) as f:
            return int(f.read().strip().split("_")[-1])

    def restore(self, step: Optional[int] = None) -> Tuple[Any, Dict]:
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError("no checkpoint found")
        step_dir = os.path.join(self.dir, f"step_{step:09d}")
        with open(os.path.join(step_dir, "manifest.json")) as f:
            manifest = json.load(f)
        flat = {}
        for path, meta in manifest["leaves"].items():
            blob = os.path.join(self.dir, "blobs", meta["cid"] + ".bin")
            with open(blob, "rb") as fb:
                raw = fb.read()
            if hashlib.sha256(raw).hexdigest()[:32] != meta["cid"]:
                raise IOError(f"checkpoint blob corrupted: {path}")
            arr = np.frombuffer(raw, dtype=_np_dtype(meta["dtype"]))
            flat[path] = arr.reshape(meta["shape"]).copy()
        return _unflatten(flat), manifest["extra"]

    # -- retention -------------------------------------------------------------------
    def _gc(self):
        steps = sorted(d for d in os.listdir(self.dir)
                       if d.startswith("step_"))
        for d in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, d), ignore_errors=True)
        # drop unreferenced blobs
        live = set()
        for d in steps[-self.keep:]:
            mf = os.path.join(self.dir, d, "manifest.json")
            if os.path.exists(mf):
                with open(mf) as f:
                    live.update(m["cid"] for m in
                                json.load(f)["leaves"].values())
        blob_dir = os.path.join(self.dir, "blobs")
        for b in os.listdir(blob_dir):
            if b.split(".")[0] not in live:
                os.remove(os.path.join(blob_dir, b))
