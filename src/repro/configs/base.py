"""Config system for the AutoDFL reproduction framework.

Every assigned architecture is expressed as a :class:`ModelConfig`; every
assigned input shape as a :class:`ShapeConfig`.  The cross product (minus the
documented skips) defines the dry-run / roofline cells.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Block kinds used by hybrid / mixed stacks.
# ---------------------------------------------------------------------------
ATTN = "attn"
MAMBA = "mamba"
MLSTM = "mlstm"
SLSTM = "slstm"


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts settings (None on dense archs)."""

    n_experts: int
    top_k: int
    expert_d_ff: int
    # Apply MoE FFN every `period` layers (Jamba uses 2: alternating MoE/dense).
    period: int = 1
    # Capacity factor for the dispatch (dropping) path.
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    """How this architecture is laid out on the (pod, data, model) mesh."""

    fsdp: bool = True           # shard params / opt state over the data axis
    tensor_parallel: bool = True  # shard matmul dims over the model axis
    sequence_parallel: bool = False  # shard the residual stream's seq dim
    expert_parallel: bool = True  # shard MoE experts over the model axis
    remat: str = "full"         # none | dots | full
    # Decode-time KV-cache sharding: shard cache seq dim over model axis when
    # kv heads < model axis (GQA small-kv archs, long-context decode).
    kv_seq_shard: bool = False


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One assigned architecture."""

    name: str
    family: str                   # dense | moe | ssm | hybrid | audio | vlm | conv
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: int = 0             # 0 -> d_model // n_heads
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_variant: str = "rope"    # rope | mrope | none
    rope_theta: float = 10_000.0
    norm: str = "rmsnorm"         # rmsnorm | layernorm
    moe: Optional[MoEConfig] = None
    # Repeating block pattern; None => all ATTN.  The full stack is
    # n_layers // len(pattern) repetitions of the pattern (scan over periods).
    block_pattern: Optional[Tuple[str, ...]] = None

    # Encoder-decoder (whisper): encoder layer count and fixed frame count.
    enc_dec: bool = False
    n_enc_layers: int = 0
    enc_seq: int = 0

    # Modality frontend stub: tokens | embeds (vlm) | audio (enc-dec frames)
    input_mode: str = "tokens"

    # Mamba block hyperparameters (hybrid family).
    mamba_expand: int = 2
    mamba_d_state: int = 16
    mamba_d_conv: int = 4

    # xLSTM projection factors.
    mlstm_proj_factor: float = 2.0
    slstm_proj_factor: float = 1.3333333333333333

    optimizer: str = "adamw"      # adamw | adafactor | sgdm
    dtype: str = "bfloat16"
    sharding: ShardingPolicy = dataclasses.field(default_factory=ShardingPolicy)

    # Sub-quadratic story: archs whose every token-mixing layer is full
    # attention cannot run the 500k-context cell.
    subquadratic: bool = False

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.block_pattern is not None:
            assert self.n_layers % len(self.block_pattern) == 0, (
                self.name, self.n_layers, self.block_pattern)

    # -- derived ------------------------------------------------------------
    @property
    def pattern(self) -> Tuple[str, ...]:
        return self.block_pattern or (ATTN,)

    @property
    def n_periods(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks), for MODEL_FLOPS."""
        d, v = self.d_model, self.vocab_size
        total = v * d  # input embedding
        total += v * d  # lm head (untied)
        counts = {
            ATTN: self._attn_params() + self._ffn_params_dense(),
            MAMBA: self._mamba_params() + 0,
            MLSTM: self._mlstm_params(),
            SLSTM: self._slstm_params(),
        }
        n_rep = self.n_periods
        for i, kind in enumerate(self.pattern):
            c = counts[kind]
            if kind in (ATTN, MAMBA) and self.moe is not None:
                # layers alternate MoE / dense FFN with the MoE period
                per = self.moe.period
                if per == 1 or (i % per) == (per - 1):
                    c = (self._attn_params() if kind == ATTN else self._mamba_params())
                    c += self._ffn_params_moe()
            total += c * n_rep
        if self.enc_dec:
            # encoder blocks (self-attn + ffn) + decoder cross-attn
            enc = (self._attn_params() + self._ffn_params_dense()) * self.n_enc_layers
            cross = self._attn_params() * self.n_layers
            total += enc + cross
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top_k experts count)."""
        if self.moe is None:
            return self.param_count()
        full = self.param_count()
        moe_layers = self._n_moe_layers()
        per_expert = 3 * self.d_model * self.moe.expert_d_ff
        inactive = moe_layers * (self.moe.n_experts - self.moe.top_k) * per_expert
        return full - inactive

    def _n_moe_layers(self) -> int:
        if self.moe is None:
            return 0
        n = 0
        for i, kind in enumerate(self.pattern):
            if kind in (ATTN, MAMBA):
                per = self.moe.period
                if per == 1 or (i % per) == (per - 1):
                    n += 1
        return n * self.n_periods

    def _attn_params(self) -> int:
        d = self.d_model
        return d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d

    def _ffn_params_dense(self) -> int:
        if self.d_ff == 0:
            return 0
        return 3 * self.d_model * self.d_ff  # SwiGLU: gate, up, down

    def _ffn_params_moe(self) -> int:
        m = self.moe
        router = self.d_model * m.n_experts
        return router + m.n_experts * 3 * self.d_model * m.expert_d_ff

    def _mamba_params(self) -> int:
        d = self.d_model
        di = d * self.mamba_expand
        ds = self.mamba_d_state
        # in_proj (x and z), conv, ssm params (dt, B, C proj), out_proj
        return (d * 2 * di + di * self.mamba_d_conv
                + di * (ds * 2 + di // 16 + 1) + di * d)

    def _mlstm_params(self) -> int:
        d = self.d_model
        di = int(d * self.mlstm_proj_factor)
        # up (x,z), qkv from di, gates, out
        return d * 2 * di + 3 * di * di + 2 * di + di * d

    def _slstm_params(self) -> int:
        d = self.d_model
        df = int(d * self.slstm_proj_factor)
        # 4 gates (recurrent + input) + ffn up/down
        return 8 * d * d + 2 * d * df


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input shape."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


# The four assigned LM shapes -------------------------------------------------
SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

SHAPE_ORDER = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def cell_is_skipped(cfg: ModelConfig, shape: ShapeConfig) -> Optional[str]:
    """Return a skip-reason string, or None if the (arch, shape) cell runs."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return ("pure full-attention arch: 500k-context decode requires "
                "sub-quadratic token mixing (see DESIGN.md shape/skip matrix)")
    if cfg.family == "conv":
        if shape.name != "train_4k":
            return "paper's own LeNet-5 config: FL training example only"
    return None


def live_cells(configs, shapes=None):
    shapes = shapes or [SHAPES[s] for s in SHAPE_ORDER]
    out = []
    for cfg in configs:
        for shape in shapes:
            if cell_is_skipped(cfg, shape) is None:
                out.append((cfg, shape))
    return out
