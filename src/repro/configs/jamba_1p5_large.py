"""jamba-1.5-large-398b — Mamba+attn 1:7 interleave, MoE [arXiv:2403.19887; hf].

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536, MoE 16e top-2.
Stack = 9 repetitions of an 8-layer period (attention at index 4, Mamba
elsewhere); MoE FFN every 2nd layer (Jamba recipe).  Hybrid recurrence =>
sub-quadratic => long_500k runs (the sparse attention layers hold an
SP-sharded 500k KV cache).
"""
from repro.configs.base import ATTN, MAMBA, MoEConfig, ModelConfig, ShardingPolicy

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    moe=MoEConfig(n_experts=16, top_k=2, expert_d_ff=24576, period=2),
    block_pattern=(MAMBA, MAMBA, MAMBA, MAMBA, ATTN, MAMBA, MAMBA, MAMBA),
    optimizer="adafactor",
    subquadratic=True,
    sharding=ShardingPolicy(fsdp=True, tensor_parallel=True,
                            expert_parallel=True, sequence_parallel=True,
                            remat="full", kv_seq_shard=True),
)
