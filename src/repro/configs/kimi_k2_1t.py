"""kimi-k2-1t-a32b — trillion-param MoE (paper-table) [arXiv:2501.kimi2].

61L d_model=7168 64H (GQA kv=8) per-expert d_ff=2048 vocab=163840,
MoE 384e top-8.  ~1.03T total params, ~32B active.  Training states use
adafactor (factored second moment) — see DESIGN.md memory notes; the
single-pod train_4k cell exceeds v5e HBM by construction and is reported
honestly in EXPERIMENTS.md (fits on the 2-pod mesh).
"""
from repro.configs.base import MoEConfig, ModelConfig, ShardingPolicy

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_ff=2048,
    vocab_size=163840,
    moe=MoEConfig(n_experts=384, top_k=8, expert_d_ff=2048),
    optimizer="adafactor",
    sharding=ShardingPolicy(fsdp=True, tensor_parallel=True,
                            expert_parallel=True, sequence_parallel=True,
                            remat="full", kv_seq_shard=True),
)
