"""lenet5 — the paper's own FL workload (LeNet-5 on MNIST, §VI-B).

Used by the paper-faithful federated-learning example (examples/fl_mnist.py)
and the reputation-dynamics benchmark (Fig. 3).  Not part of the LM dry-run
grid; exercised end-to-end on CPU.
"""
from repro.configs.base import ModelConfig, ShardingPolicy

CONFIG = ModelConfig(
    name="lenet5",
    family="conv",
    n_layers=5,
    d_model=84,        # final FC width (kept for interface uniformity)
    n_heads=1,
    n_kv_heads=1,
    d_ff=120,
    vocab_size=10,     # 10 classes
    rope_variant="none",
    norm="layernorm",
    input_mode="image",
    sharding=ShardingPolicy(fsdp=False, tensor_parallel=False, remat="none"),
)
