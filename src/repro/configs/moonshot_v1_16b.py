"""moonshot-v1-16b-a3b — kimi/moonlight 64-expert top-6 MoE
[hf:moonshotai/Moonlight-16B-A3B].

48L d_model=2048 16H (kv=16) per-expert d_ff=1408 vocab=163840, MoE 64e top-6.
"""
from repro.configs.base import MoEConfig, ModelConfig, ShardingPolicy

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=163840,
    moe=MoEConfig(n_experts=64, top_k=6, expert_d_ff=1408),
    sharding=ShardingPolicy(fsdp=True, tensor_parallel=True,
                            expert_parallel=True, sequence_parallel=True, remat="full",
                            kv_seq_shard=True),
)
