"""qwen2-vl-72b — M-RoPE, dynamic resolution [arXiv:2409.12191; hf].

80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064.  Backbone only: the
vision frontend is a STUB — input_specs() provides precomputed patch/token
embeddings plus 3-section M-RoPE position ids (temporal, height, width).
"""
from repro.configs.base import ModelConfig, ShardingPolicy

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    qkv_bias=True,
    rope_variant="mrope",
    rope_theta=1_000_000.0,
    input_mode="embeds",
    sharding=ShardingPolicy(fsdp=True, tensor_parallel=True,
                            sequence_parallel=True, remat="full",
                            kv_seq_shard=True),
)
