"""Architecture registry: maps ``--arch`` ids to ModelConfigs.

All 10 assigned architectures + the paper's own LeNet-5.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

from repro.configs import (jamba_1p5_large, kimi_k2_1t, lenet5,
                           moonshot_v1_16b, qwen1p5_0p5b, qwen2_0p5b,
                           qwen2_vl_72b, qwen3_32b, whisper_medium, xlstm_1p3b,
                           yi_6b)
from repro.configs.base import (SHAPE_ORDER, SHAPES, ModelConfig, ShapeConfig,
                                cell_is_skipped)

_MODULES = (
    xlstm_1p3b, yi_6b, qwen1p5_0p5b, qwen2_0p5b, qwen3_32b, whisper_medium,
    qwen2_vl_72b, moonshot_v1_16b, kimi_k2_1t, jamba_1p5_large, lenet5,
)

REGISTRY: Dict[str, ModelConfig] = {m.CONFIG.name: m.CONFIG for m in _MODULES}

# The 10 assigned archs (lenet5 is the paper's own, outside the dry-run grid).
ASSIGNED: List[str] = [m.CONFIG.name for m in _MODULES if m.CONFIG.name != "lenet5"]


def get_config(name: str) -> ModelConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[name]


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(SHAPES)}")
    return SHAPES[name]


def reduced_config(cfg: ModelConfig) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests.

    Keeps every structural feature (pattern, MoE, GQA ratio, biases, norms,
    enc-dec) while shrinking widths/depths/embedding tables.
    """
    pattern = cfg.block_pattern
    if pattern is not None:
        n_layers = len(pattern)          # one period
    else:
        n_layers = 2
    moe = cfg.moe
    if moe is not None:
        moe = dataclasses.replace(moe, n_experts=min(8, moe.n_experts),
                                  top_k=min(2, moe.top_k), expert_d_ff=64)
    # preserve the GQA ratio where possible
    n_heads = 4
    ratio = max(1, cfg.n_heads // max(1, cfg.n_kv_heads))
    n_kv = max(1, n_heads // min(ratio, n_heads))
    return dataclasses.replace(
        cfg,
        n_layers=n_layers,
        d_model=64,
        head_dim=16,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        d_ff=0 if cfg.d_ff == 0 else 128,
        vocab_size=256,
        moe=moe,
        n_enc_layers=2 if cfg.enc_dec else 0,
        enc_seq=16 if cfg.enc_dec else 0,
        sharding=dataclasses.replace(cfg.sharding, remat="none"),
    )


def grid_cells(include_skipped: bool = False):
    """Yield (cfg, shape, skip_reason) across the 10x4 assigned grid."""
    for arch in ASSIGNED:
        cfg = REGISTRY[arch]
        for sname in SHAPE_ORDER:
            shape = SHAPES[sname]
            reason = cell_is_skipped(cfg, shape)
            if reason is None or include_skipped:
                yield cfg, shape, reason
