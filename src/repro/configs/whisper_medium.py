"""whisper-medium — enc-dec, conv frontend (stub) [arXiv:2212.04356].

24L d_model=1024 16H d_ff=4096 vocab=51865.  24 encoder + 24 decoder layers;
the audio conv frontend is a STUB: input_specs() provides precomputed frame
embeddings of shape (batch, 1500, d_model).
"""
from repro.configs.base import ModelConfig, ShardingPolicy

CONFIG = ModelConfig(
    name="whisper-medium",
    family="audio",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    norm="layernorm",
    rope_variant="none",   # whisper uses learned absolute positions
    enc_dec=True,
    n_enc_layers=24,
    enc_seq=1500,
    input_mode="audio",
    sharding=ShardingPolicy(fsdp=True, tensor_parallel=True, remat="dots",
                            kv_seq_shard=True),
)
