"""xlstm-1.3b — sLSTM + mLSTM blocks [arXiv:2405.04517].

48L d_model=2048 4H (GQA kv=4) d_ff=0 vocab=50304.  d_ff=0: the xLSTM blocks
carry their own pre-up/post-down projections.  Block ratio mLSTM:sLSTM = 7:1
(the xLSTM[7:1] recipe), expressed as a repeating 8-block period so the stack
scans over 6 periods.  Recurrent state => sub-quadratic => long_500k runs.
"""
from repro.configs.base import MLSTM, SLSTM, ModelConfig, ShardingPolicy

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    rope_variant="none",
    norm="layernorm",
    block_pattern=(MLSTM,) * 7 + (SLSTM,),
    subquadratic=True,
    sharding=ShardingPolicy(fsdp=True, tensor_parallel=True, remat="dots"),
)
