"""AutoDFL core: the paper's contribution as composable JAX modules.

reputation  — Eq. 2-10 reputation model (objective/subjective/local/update)
aggregation — Eq. 1 reputation-weighted FedAvg (stacked / mesh-psum paths)
rollup      — zk-Rollup L2 batching engine + TPU rollup-round analogue
shards      — sharded rollup fabric: K L2 sequencers, one L1, fabric root
state       — array-native account state + chunked Merkle-style commitment
ledger      — L1 permissioned chain simulator (QBFT, mempool, gas blocks)
              + the LedgerBackend protocol unifying all ledger faces
gas         — Table-I-calibrated gas cost model
oracle      — DON quorum evaluation / aggregation cross-verification
tasks       — TSC task lifecycle (publishTask / selectTrainers / submit)
escrow      — DSC deposits, rewards, slashing
storage     — IPFS-style content-addressed blob store
"""
