"""Reputation-weighted aggregation (paper Eq. 1): w_g = sum(s_i w_i) / sum(s_i).

Three call paths:
  * stacked        — trainers on a leading axis (oracle / CPU FL path);
                     optionally dispatched to the Pallas `weighted_agg` kernel.
  * mesh-sharded   — trainers mapped to the mesh `data`(x`pod`) axes; the
                     aggregation is a weighted psum (the rollup commit).
  * pytree         — convenience wrapper over full parameter pytrees.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def weighted_average_flat(stacked: jnp.ndarray, scores: jnp.ndarray,
                          use_pallas: bool = False) -> jnp.ndarray:
    """stacked: (n, P) trainer weights; scores: (n,) -> (P,)."""
    if use_pallas:
        from repro.kernels.ops import weighted_agg
        return weighted_agg(stacked, scores)
    s = scores.astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(s), 1e-12)
    return (jnp.einsum("np,n->p", stacked.astype(jnp.float32), s)
            / denom).astype(stacked.dtype)


def weighted_average_tree(stacked_tree, scores, use_pallas: bool = False):
    """Pytree whose leaves carry a leading trainer axis."""
    def leaf(x):
        flat = x.reshape(x.shape[0], -1)
        out = weighted_average_flat(flat, scores, use_pallas)
        return out.reshape(x.shape[1:])
    return jax.tree.map(leaf, stacked_tree)


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def weighted_average_tree_jit(stacked_tree, scores, use_pallas: bool = False):
    """Fused form of ``weighted_average_tree`` (one dispatch per round
    instead of ~3 eager ops per leaf) — the scheduler hot path."""
    return weighted_average_tree(stacked_tree, scores, use_pallas)


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def weighted_average_tree_mega(stacked_trees, scores,
                               use_pallas: bool = False):
    """T Eq. 1 aggregations as ONE dispatch: leaves carry (T, n, ...) and
    ``scores`` is (T, n).  Row t is bit-exact equal to
    ``weighted_average_tree_jit`` on task t alone — each task's reduction
    is element-wise independent along the new axis (the cross-task
    megastep path; see fl/scheduler.py)."""
    return jax.vmap(lambda t, s: weighted_average_tree(t, s, use_pallas))(
        stacked_trees, scores)


def weighted_psum_tree(local_tree, score, axis_names):
    """Mesh path: each `data`-axis group holds ONE trainer's params.

    local_tree: this trainer's params; score: this trainer's scalar score.
    Returns the Eq. 1 average, identical on all groups (one weighted
    all-reduce over ``axis_names`` — this is the rollup 'commit').
    """
    denom = jax.lax.psum(score.astype(jnp.float32), axis_names)

    def leaf(x):
        num = jax.lax.psum(x.astype(jnp.float32) * score.astype(jnp.float32),
                           axis_names)
        return (num / jnp.maximum(denom, 1e-12)).astype(x.dtype)
    return jax.tree.map(leaf, local_tree)


def tree_sub(a, b):
    return jax.tree.map(lambda x, y: x - y, a, b)


def tree_add(a, b):
    return jax.tree.map(lambda x, y: x + y, a, b)


def tree_flat(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in leaves])


def tree_flat_stacked(tree):
    """Flatten a pytree whose leaves carry a leading trainer axis to (n, P)
    — the batched counterpart of ``tree_flat`` (one Eq. 4 distance pass for
    a whole cohort instead of per-trainer flattens)."""
    leaves = jax.tree.leaves(tree)
    return jnp.concatenate(
        [l.reshape(l.shape[0], -1).astype(jnp.float32) for l in leaves],
        axis=1)
