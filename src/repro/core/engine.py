"""Vectorized L1/L2 transaction engine (structure-of-arrays hot path).

The object-based simulator (core/ledger.py, core/rollup.py) processes every
transaction as a Python ``Tx`` in a FIFO loop — faithful to the paper's
Fig. 4 / Table I experiments but O(submitted txs) in Python bytecode.  This
module re-implements the same discrete-event semantics over NumPy arrays so
that one simulated block costs O(log n) (two ``searchsorted`` calls against
precomputed running-max/cumsum arrays) instead of O(txs in block) Python
iterations, and one rollup session costs a handful of vectorized passes.

Semantics contract (enforced by tests/test_engine.py):

  * ``VectorChain`` produces blocks with EXACTLY the same tx counts,
    gas_used, confirm times and total gas as ``ledger.Chain`` on the same
    workload — including the head-of-line FIFO rule: block packing walks
    the mempool in submission order and stops at the first transaction
    whose ``submit_time`` is in the future OR whose gas would overflow the
    block, without skipping ahead.  A future-timestamped tx at the head of
    an out-of-order mempool therefore stalls everything behind it (in both
    engines); ``simulate_load``/``Workload`` guard against accidental skew
    by always submitting in sorted time order.
  * ``VectorRollup`` with ``n_lanes=1`` writes the same ``gas_log`` rows
    (commit / amortized verify / execute per batch) as ``rollup.Rollup``.

Digests: each seal folds the batch's transaction words through the same
xor-mix used by the Pallas ``rollup_digest`` kernel.  On TPU the merged
buffer is routed through the kernel itself; on CPU a bit-exact NumPy mirror
(``xor_fold_digest``) is used (equality pinned by tests/test_engine.py).
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core.events import BatchSealed, BlockPacked, EventLog
from repro.core.gas import DEFAULT_GAS, ROLLUP_BATCH, GasTable
from repro.core.ledger import EventHooks
from repro.core.prover import ProverFace, ProverPipeline, session_latency
from repro.core.state import MIX_MULT as DIGEST_MULT
from repro.core.state import MIX_SEED as DIGEST_SEED
from repro.core.state import Registry


def _mix(words: np.ndarray) -> np.ndarray:
    """THE xor-mix (bit-exact mirror of kernels.rollup_digest); every fold
    in the repo — scalar, per-batch segments, chunked state commitment —
    routes through this one implementation so the Pallas-pin test covers
    all call sites (rollup.Rollup, VectorRollup.seal, core/state.py)."""
    w = np.ascontiguousarray(words, dtype=np.uint32)
    return (w ^ (w >> np.uint32(16))) * DIGEST_MULT


def xor_fold_digest(words: np.ndarray) -> int:
    """Bit-exact NumPy mirror of kernels.rollup_digest (xor-mix fold).

    ``rollup_digest`` pads to a block multiple with zeros; zero words mix to
    zero and xor-fold away, so no explicit padding is needed here.
    """
    if np.size(words) == 0:
        return int(DIGEST_SEED)
    return int(DIGEST_SEED ^ np.bitwise_xor.reduce(_mix(words)))


def xor_fold_digest_segments(words: np.ndarray,
                             starts: np.ndarray) -> np.ndarray:
    """Segmented fold: one digest per ``[starts[i], starts[i+1])`` word
    range (u32 array) — the multi-batch form VectorRollup.seal uses.
    Routed through the kernel factory (op ``"batch_seal"``): the NumPy
    mirror on CPU, the Pallas segment kernel on TPU, overridable via
    ``REPRO_KERNEL_IMPL`` (see kernels/factory.py)."""
    from repro.kernels.factory import get_kernel
    return get_kernel("batch_seal")(words, starts)


def pallas_or_numpy_digest(words: np.ndarray, backend: str = "auto") -> int:
    """Route the merged word buffer through the kernel factory (op
    ``"rollup_digest"``): Pallas on TPU, the NumPy mirror on CPU.
    backend: "auto" | "pallas" | "numpy" (an explicit choice maps to the
    factory impl of the same name)."""
    from repro.kernels.factory import get_kernel
    impl = None if backend == "auto" else backend
    return int(get_kernel("rollup_digest", impl)(words))


class FnRegistry(Registry):
    """Stable fn-name <-> integer-id mapping shared across SoA batches
    (the function-namespace face of core/state.py's generic Registry)."""


@dataclasses.dataclass
class TxArrays:
    """Structure-of-arrays transaction batch (the vector engine's Tx)."""

    submit_time: np.ndarray          # float64 (N,)
    gas: np.ndarray                  # int64   (N,)
    fn_id: np.ndarray                # int32   (N,)
    sender_id: np.ndarray            # int32   (N,)
    fns: FnRegistry

    def __post_init__(self):
        self.submit_time = np.asarray(self.submit_time, np.float64)
        self.gas = np.asarray(self.gas, np.int64)
        self.fn_id = np.asarray(self.fn_id, np.int32)
        self.sender_id = np.asarray(self.sender_id, np.int32)

    def __len__(self) -> int:
        return self.submit_time.shape[0]

    @classmethod
    def homogeneous(cls, fn: str, times: np.ndarray, gas: int,
                    n_senders: int = 64,
                    fns: Optional[FnRegistry] = None) -> "TxArrays":
        """One function type at fixed per-call gas (the Fig. 4 workload)."""
        fns = fns or FnRegistry()
        n = len(times)
        return cls(np.asarray(times, np.float64),
                   np.full(n, gas, np.int64),
                   np.full(n, fns.id(fn), np.int32),
                   (np.arange(n) % max(1, n_senders)).astype(np.int32), fns)

    @classmethod
    def from_txs(cls, txs: Sequence[Any],
                 fns: Optional[FnRegistry] = None) -> "TxArrays":
        """Compatibility shim: lift object ``Tx`` lists into SoA form."""
        fns = fns or FnRegistry()
        senders: Dict[str, int] = {}
        sid = np.empty(len(txs), np.int32)
        fid = np.empty(len(txs), np.int32)
        for i, t in enumerate(txs):
            fid[i] = fns.id(t.fn)
            sid[i] = senders.setdefault(t.sender, len(senders))
        return cls(np.array([t.submit_time for t in txs], np.float64),
                   np.array([t.gas for t in txs], np.int64), fid, sid, fns)

    def word_buffer(self) -> np.ndarray:
        """Interleaved u32 words (time bits, gas, fn, sender) for digests."""
        n = len(self)
        w = np.empty(4 * n, np.uint32)
        w[0::4] = self.submit_time.astype(np.float32).view(np.uint32)
        w[1::4] = (self.gas & 0xFFFFFFFF).astype(np.uint32)
        w[2::4] = self.fn_id.astype(np.uint32)
        w[3::4] = self.sender_id.astype(np.uint32)
        return w


@dataclasses.dataclass
class BlockStats:
    """Vector-engine block record (counts + gas, not per-tx objects)."""
    height: int
    time: float
    n_txs: int
    gas_used: int
    start: int                 # [start, stop) tx index range in arrival order
    stop: int
    parent: str = ""
    block_hash: str = ""

    def __post_init__(self):
        if not self.block_hash:
            h = hashlib.sha256(
                (self.parent + ":" + str(self.height) + ":" +
                 str(self.start) + ":" + str(self.stop) + ":" +
                 str(self.gas_used)).encode()).hexdigest()
            self.block_hash = h[:16]


class VectorChain(EventHooks):
    """Vectorized mirror of ``ledger.Chain``: QBFT quorum, gas-limited FIFO
    block packing over SoA arrays, O(log n) per block."""

    EVENTS = ("block_packed",)

    # SoA is this face's NATIVE path (emitters dispatch batched emission on
    # this flag, not on submit_arrays presence — the object faces expose a
    # lowering submit_arrays adapter too, but drop nothing when fed Txs)
    soa_native = True
    # the SoA L1 can run under the core/fused.py plan-then-execute loop
    fused_capable = True

    def __init__(self, n_validators: int = 4, block_time: float = 1.0,
                 block_gas_limit: int = 9_000_000,
                 gas_table: GasTable = DEFAULT_GAS,
                 fns: Optional[FnRegistry] = None):
        assert n_validators >= 4, "QBFT needs >= 3f+1 with f >= 1"
        self.n_validators = n_validators
        self.block_time = block_time
        self.block_gas_limit = block_gas_limit
        self.gas_table = gas_table
        self.fns = fns or FnRegistry()
        self.blocks: List[BlockStats] = [BlockStats(0, 0.0, 0, 0, 0, 0,
                                                    "genesis")]
        self.state: Dict[str, Any] = {}
        self.total_gas = 0
        self._batch_handlers: Dict[int, Callable] = {}
        # LedgerBackend face: handlers written against (StateArrays,
        # TxArrays-view), called once per (block, fn) with the fn-filtered
        # confirmed slice (see ledger.LedgerBackend.register_state)
        self.state_arrays = None
        self._state_handlers: Dict[int, Callable] = {}
        self._sender_ids: Dict[str, int] = {}    # submit(tx) shim namespace
        # consolidated mempool arrays (arrival order, never reordered).
        # Geometric (doubling) capacity growth + incremental running-max /
        # cumsum extension keep consolidation amortized O(new txs), so the
        # O(log n)-per-block contract holds for interleaved submit/produce
        # producers, not just submit-everything-then-run ones.
        self._n = 0                              # filled prefix of buffers
        self._t = np.empty(0, np.float64)
        self._g = np.empty(0, np.int64)
        self._f = np.empty(0, np.int32)
        self._s = np.empty(0, np.int32)
        self._confirm = np.empty(0, np.float64)
        self._tmax = np.empty(0, np.float64)    # running max of _t
        self._gcum = np.empty(0, np.int64)      # running cumsum of _g
        self._ptr = 0                            # first unconfirmed index
        self._staged: List[TxArrays] = []
        self._staged_n = 0
        self._block_stops = np.empty(0, np.int64)   # block_of lookup cache
        # the stack-wide typed event stream (L1-owned; L2 faces adopt it)
        self.events = EventLog()
        self._init_events()

    # -- contract surface ------------------------------------------------------
    def register_batch(self, fn: str, handler: Callable):
        """Batched handler: handler(state, n_calls, tx_slice: TxArrays-view).
        Called once per (block, fn) instead of once per tx."""
        self._batch_handlers[self.fns.id(fn)] = handler

    def register_state(self, fn: str, handler: Callable):
        """StateArrays handler (LedgerBackend): handler(state_arrays, view)
        with ``view`` holding only ``fn``'s confirmed txs, block order."""
        if self.state_arrays is None:
            from repro.core.state import StateArrays
            self.state_arrays = StateArrays()
            self.state_arrays.enable_dirty_tracking()
        self._state_handlers[self.fns.id(fn)] = handler

    def state_root(self) -> str:
        return self.state_arrays.root() if self.state_arrays is not None \
            else ""

    def submit_arrays(self, batch: TxArrays):
        """Stage a SoA batch; returns the ``[lo, hi)`` global arrival-index
        range assigned to it (tx provenance: the index is stable across
        consolidation and is what ``block_of``/receipts resolve)."""
        if batch.fns is not self.fns:
            # remap fn ids into this chain's registry
            remap = np.array([self.fns.id(n) for n in batch.fns.names],
                             np.int32)
            batch = TxArrays(batch.submit_time, batch.gas,
                             remap[batch.fn_id] if len(batch) else
                             batch.fn_id, batch.sender_id, self.fns)
        lo = self._n + self._staged_n
        self._staged.append(batch)
        self._staged_n += len(batch)
        return lo, lo + len(batch)

    def sender_id(self, sender: str) -> int:
        """Stable sender-name -> id mapping for the object-Tx shim."""
        return self._sender_ids.setdefault(sender, len(self._sender_ids))

    def submit(self, tx):
        """Object-Tx compatibility shim (small-N debugging)."""
        batch = TxArrays.from_txs([tx], self.fns)
        batch.sender_id = np.array([self.sender_id(tx.sender)], np.int32)
        return self.submit_arrays(batch)

    # -- provenance (receipts) -------------------------------------------------
    def block_of(self, tx_index: int) -> Optional[BlockStats]:
        """The block that confirmed arrival index ``tx_index`` (None while
        unconfirmed).  O(log blocks) against a cached stop array."""
        if tx_index >= self._ptr:
            return None
        if self._block_stops.shape[0] != len(self.blocks):
            self._block_stops = np.array([b.stop for b in self.blocks],
                                         np.int64)
        h = int(np.searchsorted(self._block_stops, tx_index, side="right"))
        blk = self.blocks[h]
        assert blk.start <= tx_index < blk.stop
        return blk

    def confirm_time_of(self, tx_index: int) -> Optional[float]:
        if tx_index >= self._ptr:
            return None
        return float(self._confirm[tx_index])

    def quorum(self, approvals: int) -> bool:
        return 3 * approvals >= 2 * self.n_validators

    def _grow(self, need: int):
        cap = self._t.shape[0]
        if self._n + need <= cap:
            return
        new_cap = max(1024, self._n + need, 2 * cap)

        def grow(a, dtype):
            out = np.empty(new_cap, dtype)
            out[: self._n] = a[: self._n]
            return out
        self._t = grow(self._t, np.float64)
        self._g = grow(self._g, np.int64)
        self._f = grow(self._f, np.int32)
        self._s = grow(self._s, np.int32)
        self._confirm = grow(self._confirm, np.float64)
        self._tmax = grow(self._tmax, np.float64)
        self._gcum = grow(self._gcum, np.int64)

    def _consolidate(self):
        if not self._staged:
            return
        new, m = self._staged, self._staged_n
        self._staged, self._staged_n = [], 0
        self._grow(m)
        lo, hi = self._n, self._n + m
        at = lo
        for b in new:
            k = len(b)
            self._t[at:at + k] = b.submit_time
            self._g[at:at + k] = b.gas
            self._f[at:at + k] = b.fn_id
            self._s[at:at + k] = b.sender_id
            at += k
        self._confirm[lo:hi] = np.nan
        # extend the running max (head-of-line eligibility) and gas cumsum
        # (packing) over the new tail only — amortized O(new txs)
        tmax_tail = np.maximum.accumulate(self._t[lo:hi])
        if lo:
            np.maximum(tmax_tail, self._tmax[lo - 1], out=tmax_tail)
        self._tmax[lo:hi] = tmax_tail
        self._gcum[lo:hi] = (np.cumsum(self._g[lo:hi])
                             + (self._gcum[lo - 1] if lo else 0))
        self._n = hi

    # -- block production ------------------------------------------------------
    def produce_block(self, now: float) -> BlockStats:
        """Pack the next block at time ``now``.

        FIFO head-of-line semantics (identical to ``Chain.produce_block``):
        eligible txs are the longest mempool *prefix* whose running-max
        submit_time is <= now — ``searchsorted`` on the precomputed running
        max; the gas cap is then the longest prefix of that whose gas cumsum
        fits the block limit — ``searchsorted`` on the gas cumsum.  A stuck
        head tx (future-timestamped, or gas > block limit by itself) blocks
        the queue in both engines; that is the documented intent.
        """
        self._consolidate()
        ptr = self._ptr
        hi = int(np.searchsorted(self._tmax[: self._n], now, side="right"))
        hi = max(hi, ptr)
        base = int(self._gcum[ptr - 1]) if ptr > 0 else 0
        k = int(np.searchsorted(self._gcum[ptr:hi],
                                base + self.block_gas_limit, side="right"))
        stop = ptr + k
        gas_used = (int(self._gcum[stop - 1]) - base) if stop > ptr else 0
        if stop > ptr:
            self._confirm[ptr:stop] = now
            if self._batch_handlers or self._state_handlers:
                counts = np.bincount(self._f[ptr:stop],
                                     minlength=len(self.fns))
                view = TxArrays(self._t[ptr:stop], self._g[ptr:stop],
                                self._f[ptr:stop], self._s[ptr:stop],
                                self.fns)
                for fid, h in self._batch_handlers.items():
                    if fid < counts.shape[0] and counts[fid]:
                        h(self.state, int(counts[fid]), view)
                for fid, h in self._state_handlers.items():
                    if fid < counts.shape[0] and counts[fid]:
                        m = view.fn_id == fid
                        h(self.state_arrays,
                          TxArrays(view.submit_time[m], view.gas[m],
                                   view.fn_id[m], view.sender_id[m],
                                   self.fns))
        assert self.quorum(self.n_validators - self.n_validators // 3)
        blk = BlockStats(len(self.blocks), now, stop - ptr, gas_used,
                         ptr, stop, self.blocks[-1].block_hash)
        self.blocks.append(blk)
        self.total_gas += gas_used
        self._ptr = stop
        self.events.emit(BlockPacked, time=now, height=blk.height,
                         n_txs=blk.n_txs, gas_used=gas_used,
                         block_hash=blk.block_hash)
        self._emit("block_packed", {"height": blk.height, "n_txs": blk.n_txs,
                                    "gas_used": gas_used,
                                    "block_hash": blk.block_hash})
        return blk

    def run_until(self, t_end: float):
        t = self.blocks[-1].time
        while t < t_end:
            t += self.block_time
            self.produce_block(t)

    # -- metrics ---------------------------------------------------------------
    @property
    def n_confirmed(self) -> int:
        return self._ptr

    @property
    def n_submitted(self) -> int:
        return self._n + self._staged_n

    def confirm_times(self) -> np.ndarray:
        return self._confirm[: self._ptr]

    def load_metrics(self, send_rate: float,
                     duration: float) -> Dict[str, float]:
        """Fig. 4 metrics, numerically identical to the object path."""
        n_conf = self._ptr
        if n_conf == 0:
            return {"send_rate": send_rate, "throughput": 0.0, "latency": 0.0,
                    "confirmed": 0, "submitted": self.n_submitted}
        lat = float(np.mean(self._confirm[:n_conf] - self._t[:n_conf]))
        return {"send_rate": send_rate,
                "throughput": n_conf / duration,
                "latency": lat,
                "confirmed": n_conf,
                "submitted": self.n_submitted}


class VectorRollup(ProverFace, EventHooks):
    """Vectorized mirror of ``rollup.Rollup`` with a multi-lane sequencer.

    Transactions stripe round-robin across ``n_lanes`` lanes; each lane cuts
    FIFO batches of ``batch_size`` which all seal concurrently (commit gas +
    per-batch tx xor-roots computed in one vectorized pass), then ONE
    amortized verify/execute settles the whole session — zkSync-style proof
    aggregation, now across lanes as well as batches.  ``n_lanes=1``
    reproduces ``Rollup``'s gas_log exactly (tests/test_engine.py).
    """

    soa_native = True
    fused_capable = True

    def __init__(self, l1, batch_size: int = ROLLUP_BATCH,
                 gas_table: GasTable = DEFAULT_GAS,
                 prove_time: float = 0.9, per_tx_time: float = 0.14,
                 n_lanes: int = 1, digest_backend: str = "auto",
                 agg_width: int = 1, prover_capacity: int = 1,
                 finalize: str = "eager",
                 prover: Optional[ProverPipeline] = None):
        assert n_lanes >= 1
        self.l1 = l1
        self.batch_size = batch_size
        self.gas_table = gas_table
        self.prove_time = prove_time
        self.per_tx_time = per_tx_time
        self.n_lanes = n_lanes
        self.digest_backend = digest_backend
        # event-log adoption + settlement-pipeline wiring (ONE copy for
        # both rollup faces — see prover.ProverFace)
        self._init_prover_face(l1, gas_table, prove_time, agg_width,
                               prover_capacity, finalize, prover)
        # share the L1's registry when it has one (`or` would discard an
        # empty-but-present registry: FnRegistry defines __len__)
        l1_fns = getattr(l1, "fns", None)
        self.fns: FnRegistry = l1_fns if l1_fns is not None else FnRegistry()
        self._sender_ids: Dict[str, int] = {}
        self.gas_log: List[Dict[str, Any]] = []
        # LedgerBackend face: StateArrays handlers applied at seal time
        # over the sealed txs in ARRIVAL order (pre-lane-sort), fn-filtered
        self.state_arrays = None
        self._state_handlers: Dict[int, Callable] = {}
        self.batch_digests: List[int] = []      # per-batch tx xor-roots
        self.update_digest: int = int(DIGEST_SEED)  # merged-buffer digest
        self.n_batches = 0
        self._pending: List[TxArrays] = []
        self._pending_n = 0
        self._last_time = 0.0
        # tx->batch provenance: submission order IS seal order, so the
        # seq->batch map extends chunk-wise at each seal (receipts resolve
        # a sequence number to its global batch id via batch_of_seq)
        self._next_seq = 0
        self._sealed_seq = 0
        self._prov_starts: List[int] = []        # chunk start seq per seal
        self._prov_batches: List[np.ndarray] = []  # per-tx global batch ids
        # per-batch L1 settlement refs: commit tx + (verify, execute) txs —
        # arrival indices on a VectorChain L1, Tx objects on an object L1
        self.batch_commit_ref: Dict[int, Any] = {}
        self.batch_settle_ref: Dict[int, Any] = {}
        self._init_events()

    # -- sequencing ------------------------------------------------------------
    def submit_arrays(self, batch: TxArrays):
        """Queue a SoA batch; returns the ``[lo, hi)`` sequence-number
        range assigned to it (this rollup's tx provenance namespace)."""
        if batch.fns is not self.fns:
            remap = np.array([self.fns.id(n) for n in batch.fns.names],
                             np.int32)
            batch = TxArrays(batch.submit_time, batch.gas,
                             remap[batch.fn_id] if len(batch) else
                             batch.fn_id, batch.sender_id, self.fns)
        lo = self._next_seq
        self._pending.append(batch)
        self._pending_n += len(batch)
        self._next_seq += len(batch)
        return lo, lo + len(batch)

    def sender_id(self, sender: str) -> int:
        """Stable sender-name -> id mapping for this rollup's SoA stream
        (same contract as VectorChain.sender_id; batched emitters must use
        the TARGET's namespace so ids stay consistent within one stream)."""
        return self._sender_ids.setdefault(sender, len(self._sender_ids))

    def register_state(self, fn: str, handler: Callable):
        """StateArrays handler (LedgerBackend): handler(state_arrays, view)
        with ``view`` holding only ``fn``'s sealed txs, arrival order."""
        if self.state_arrays is None:
            from repro.core.state import StateArrays
            self.state_arrays = StateArrays()
            self.state_arrays.enable_dirty_tracking()
        self._state_handlers[self.fns.id(fn)] = handler

    def state_root(self) -> str:
        return self.state_arrays.root() if self.state_arrays is not None \
            else ""

    def _apply_state(self, txs: "TxArrays"):
        for fid, h in self._state_handlers.items():
            m = txs.fn_id == fid
            if m.any():
                h(self.state_arrays,
                  TxArrays(txs.submit_time[m], txs.gas[m], txs.fn_id[m],
                           txs.sender_id[m], self.fns))

    def submit(self, tx):
        """Object-Tx compatibility shim."""
        batch = TxArrays.from_txs([tx], self.fns)
        batch.sender_id = np.array([self.sender_id(tx.sender)], np.int32)
        return self.submit_arrays(batch)

    def batch_of_seq(self, seq: int) -> Optional[int]:
        """Global batch id that sealed sequence number ``seq`` (None while
        still pending).  Chunk-indexed: one bisect over seal chunks."""
        if seq >= self._sealed_seq or seq < 0:
            return None
        import bisect
        c = bisect.bisect_right(self._prov_starts, seq) - 1
        return int(self._prov_batches[c][seq - self._prov_starts[c]])

    def _commit_gas_vectors(self):
        from repro.core.gas import commit_gas_vectors
        return commit_gas_vectors(self.fns.names, self.gas_table)

    def seal(self) -> int:
        """Seal every pending tx into lane batches; returns #batches sealed.

        One vectorized pass computes, for all batches at once: per-batch
        (fn -> count) histograms (commit gas), per-batch max submit_time
        (the L1 commit timestamp), and per-batch xor-roots; the merged word
        buffer of the whole seal is folded through the rollup_digest kernel
        path (Pallas on TPU, bit-exact NumPy mirror on CPU).  Sealed
        batches enqueue proof jobs on the prover pipeline; settlement
        (verify/execute) happens there (core/prover.py).
        """
        if not self._pending:
            self._emit_window(0)
            return 0
        txs = (self._pending[0] if len(self._pending) == 1 else
               TxArrays(np.concatenate([b.submit_time for b in self._pending]),
                        np.concatenate([b.gas for b in self._pending]),
                        np.concatenate([b.fn_id for b in self._pending]),
                        np.concatenate([b.sender_id for b in self._pending]),
                        self.fns))
        self._pending, self._pending_n = [], 0
        if self._state_handlers:
            # execute against the SoA account state in arrival order —
            # shard/lane layout must not change the committed state
            self._apply_state(txs)
        n = len(txs)
        idx = np.arange(n)
        lane = idx % self.n_lanes
        pos = idx // self.n_lanes                 # FIFO position within lane
        batch_in_lane = pos // self.batch_size
        # order (lane-major, FIFO within lane) so batches are contiguous
        order = np.lexsort((pos, lane))
        lane_o, bil_o = lane[order], batch_in_lane[order]
        # compact global batch ids in (lane, batch_in_lane) order
        seg_new = np.empty(n, bool)
        seg_new[0] = True
        seg_new[1:] = (lane_o[1:] != lane_o[:-1]) | (bil_o[1:] != bil_o[:-1])
        batch_id = np.cumsum(seg_new) - 1
        nb = int(batch_id[-1]) + 1
        starts = np.flatnonzero(seg_new)

        fn_o = txs.fn_id[order]
        t_o = txs.submit_time[order]
        counts = np.zeros((nb, len(self.fns)), np.int64)
        np.add.at(counts, (batch_id, fn_o), 1)
        base, percall = self._commit_gas_vectors()
        commit = (counts > 0) @ base + counts @ percall
        n_txs = counts.sum(axis=1)
        now = np.maximum.reduceat(t_o, starts)
        # per-batch xor-roots over the interleaved word buffer (the same
        # fold family as xor_fold_digest, segmented per batch)
        words = TxArrays(t_o, txs.gas[order], fn_o, txs.sender_id[order],
                         self.fns).word_buffer()
        roots = xor_fold_digest_segments(words, starts * 4)
        self.batch_digests.extend(int(r) for r in roots)
        # merged update-buffer digest through the kernel path
        self.update_digest = pallas_or_numpy_digest(words,
                                                    self.digest_backend)

        first = self.n_batches
        # tx->batch provenance: map each sealed tx (arrival order == seq
        # order) to its global batch id, extending the seq->batch chunks
        arrival_batch = np.empty(n, np.int64)
        arrival_batch[order] = first + batch_id
        self._prov_starts.append(self._sealed_seq)
        self._prov_batches.append(arrival_batch)
        self._sealed_seq += n

        # L1 commits: one tx per batch, Table-I-calibrated gas.  Lanes can
        # finish out of global time order; post commits time-sorted so the
        # L1's FIFO head-of-line rule never stalls on a later lane's commit
        # (stable sort -> no-op for n_lanes=1, preserving Rollup parity).
        post = np.argsort(now, kind="stable")
        commit_batch = TxArrays(
            now[post].astype(np.float64), commit[post].astype(np.int64),
            np.full(nb, self.fns.id("rollup_commit"), np.int32),
            np.zeros(nb, np.int32), self.fns)
        refs = self._l1_submit(commit_batch)
        inv_post = np.empty(nb, np.int64)
        inv_post[post] = np.arange(nb)
        rows = []
        for j in range(nb):
            self.batch_commit_ref[first + j] = refs[int(inv_post[j])]
            rows.append({
                "batch": first + j, "lane": int(lane_o[starts[j]]),
                "n_txs": int(n_txs[j]), "commit": int(commit[j]),
                "verify": 0, "execute": 0, "total": int(commit[j])})
        self.gas_log.extend(rows)
        self.n_batches += nb
        self._last_time = float(now.max())
        self.prover.enqueue(self, first, roots, n_txs, now, rows)
        self.events.emit(BatchSealed, time=self._last_time,
                         shard=self._event_shard, first_batch=first,
                         n_batches=nb, n_txs=n, digest=self.update_digest)
        self._emit("batch_sealed", {
            "first_batch": first, "n_batches": nb, "n_txs": n,
            "digest": self.update_digest})
        self._emit_window(nb)
        return nb

    def _l1_submit(self, batch: TxArrays) -> List[Any]:
        """Submit to the L1; returns one settlement ref per tx — the L1
        arrival index on a VectorChain, the submitted Tx on an object
        Chain (both resolve to a block through the NodeClient)."""
        if getattr(self.l1, "soa_native", False):
            lo, hi = self.l1.submit_arrays(batch)
            return list(range(lo, hi))
        from repro.core.ledger import Tx                # object Chain
        txs = [Tx(batch.fns.names[batch.fn_id[i]], "sequencer", {},
                  int(batch.gas[i]), float(batch.submit_time[i]))
               for i in range(len(batch))]
        for tx in txs:
            self.l1.submit(tx)
        return txs

    # -- settlement (routed through the shared prover pipeline) -----------------
    def flush(self):
        self.seal()
        self.settle_session()
        self.prover.drain(self)

    def _post_settlement(self, verify: int, execute: int, at: float,
                         n_batches: int):
        """Prover callback: post one verify + execute pair to the L1."""
        settle = TxArrays(
            np.full(2, at),
            np.array([verify, execute], np.int64),
            np.array([self.fns.id("rollup_verify"),
                      self.fns.id("rollup_execute")], np.int32),
            np.zeros(2, np.int32), self.fns)
        return tuple(self._l1_submit(settle))

    # -- metrics ---------------------------------------------------------------
    def throughput(self, l1_tps: float) -> float:
        """Paper's method, scaled by concurrent lanes."""
        return self.n_lanes * self.batch_size * l1_tps

    def latency(self, n_calls: int) -> float:
        """Table-II latency model (prover.session_latency — ONE formula
        shared with the object face); lanes sequence concurrently, so
        the session latency is the slowest lane's (ceil-split) share."""
        return session_latency(n_calls, batch_size=self.batch_size,
                               prove_time=self.prove_time,
                               per_tx_time=self.per_tx_time,
                               n_lanes=self.n_lanes,
                               capacity=self.prover.capacity)
