"""Deposit/escrow smart contract (DSC): locked rewards, trainer collateral,
score-proportional settlement, slashing (paper §III-D, false-reporting and
free-riding guards)."""
from __future__ import annotations

import dataclasses
from typing import Dict


class InsufficientFunds(Exception):
    pass


@dataclasses.dataclass
class Escrow:
    balances: Dict[str, float] = dataclasses.field(default_factory=dict)
    locked: Dict[str, Dict[str, float]] = dataclasses.field(default_factory=dict)
    collateral: Dict[str, Dict[str, float]] = dataclasses.field(default_factory=dict)
    slashed_pool: float = 0.0

    def fund(self, who: str, amount: float):
        assert amount >= 0
        self.balances[who] = self.balances.get(who, 0.0) + amount

    def deposit(self, publisher: str, task_id: str, amount: float):
        """Reward lock at publishTask (false-reporting guard: the publisher
        cannot repudiate payment after the fact)."""
        if self.balances.get(publisher, 0.0) < amount:
            raise InsufficientFunds(publisher)
        self.balances[publisher] -= amount
        self.locked.setdefault(task_id, {})[publisher] = amount

    def lock_collateral(self, trainer: str, task_id: str, amount: float):
        if self.balances.get(trainer, 0.0) < amount:
            raise InsufficientFunds(trainer)
        self.balances[trainer] -= amount
        self.collateral.setdefault(task_id, {})[trainer] = amount

    def settle(self, task_id: str, scores: Dict[str, float],
               min_score: float = 1e-6) -> Dict[str, float]:
        """Score-proportional payout; zero-score (free-riding) trainers lose
        their collateral to the slash pool."""
        pot = sum(self.locked.pop(task_id, {}).values())
        total = sum(s for s in scores.values() if s > min_score)
        payouts: Dict[str, float] = {}
        for trainer, score in scores.items():
            coll = self.collateral.get(task_id, {}).pop(trainer, 0.0)
            if score > min_score and total > 0:
                pay = pot * score / total
                payouts[trainer] = pay
                self.balances[trainer] = self.balances.get(trainer, 0.0) \
                    + pay + coll
            else:
                payouts[trainer] = 0.0
                self.slashed_pool += coll
        return payouts
