"""Typed ledger events + the shared per-stack event log.

The PR-4 event surface was a string-keyed callback ``subscribe`` that
existed only on the rollup faces and pushed loose dict payloads.  This
module replaces it with

  * small frozen **event dataclasses** — one per lifecycle stage of the
    proof pipeline (``BatchSealed`` -> ``ProofGenerated`` ->
    ``AggregateVerified``), plus the window commitment
    (``WindowSettled``) and L1 block production (``BlockPacked``), and
  * an ``EventLog`` — ONE append-only, totally ordered stream per ledger
    stack.  The L1 chain owns the log; every rollup face built on top of
    it (``VectorRollup``, ``Rollup``, the sharded fabric and its shards)
    adopts the same instance, so L1 and L2 events interleave in emission
    order under a single monotonic ``seq``.

Consumption is pull-based: readers keep a cursor and drain
``log.since(cursor)`` (the public face is ``repro.api.NodeClient.
events()``).  Events are plain data — safe to hold, compare and
serialize; ``shard`` tags fabric-side events with the owning shard and
stays ``None`` on unsharded faces.  The callback ``subscribe`` API is
kept for one release as a deprecation shim over the same emission sites
(see repro.api.NodeClient.subscribe).
"""
from __future__ import annotations

import dataclasses
from typing import ClassVar, List, Optional, Tuple, Type


@dataclasses.dataclass(frozen=True)
class LedgerEvent:
    """Base event: total order (``seq``), simulated time, shard tag."""

    seq: int
    time: float
    shard: Optional[int]

    kind: ClassVar[str] = "event"


@dataclasses.dataclass(frozen=True)
class BatchSealed(LedgerEvent):
    """One seal pass committed ``n_batches`` L2 batches to the L1."""

    first_batch: int
    n_batches: int
    n_txs: int
    digest: int                  # merged update-buffer xor-mix digest

    kind: ClassVar[str] = "batch_sealed"


@dataclasses.dataclass(frozen=True)
class ProofGenerated(LedgerEvent):
    """A batch's proof job completed (modeled prover drain).

    ``time`` is the modeled completion time (``sealed_at`` + queueing
    under the prover's capacity + prove latency).
    """

    job: int
    batch: int
    n_txs: int
    digest: int                  # the batch's tx xor-root
    sealed_at: float

    kind: ClassVar[str] = "proof_generated"


@dataclasses.dataclass(frozen=True)
class AggregateVerified(LedgerEvent):
    """An aggregate proof's single verify+execute posted to the L1.

    The recursive-aggregation product: ``n_sessions`` session proofs
    (each folding its batches' digests) folded into one digest, whose L1
    verify gas is amortized across every batch in ``batches``.
    """

    aggregate: int
    n_sessions: int
    batches: Tuple[int, ...]
    n_txs: int
    verify: int
    execute: int
    digest: int                  # recursive fold of the session digests

    kind: ClassVar[str] = "aggregate_verified"


@dataclasses.dataclass(frozen=True)
class WindowSettled(LedgerEvent):
    """A window boundary sealed: the backend's state commitment record.

    Emitted once per ``seal()`` on every rollup face.  On the sharded
    fabric it carries the merged fabric root and the per-shard partition
    roots; on unsharded faces those fields stay empty.
    """

    window: int
    n_batches: int
    state_root: str
    fabric_root: str = ""
    shard_roots: Tuple[str, ...] = ()

    kind: ClassVar[str] = "window_settled"


@dataclasses.dataclass(frozen=True)
class BlockPacked(LedgerEvent):
    """The L1 packed one block (chain-only nodes' event stream)."""

    height: int
    n_txs: int
    gas_used: int
    block_hash: str

    kind: ClassVar[str] = "block_packed"


class EventLog:
    """Append-only, totally ordered typed event stream for one stack.

    ``emit`` assigns the next ``seq`` and returns the constructed event;
    readers drain with ``since(cursor)`` + ``next_cursor`` (cursors live
    with the reader, so independent consumers never steal each other's
    events).
    """

    def __init__(self):
        self._events: List[LedgerEvent] = []

    def emit(self, cls: Type[LedgerEvent], *, time: float,
             shard: Optional[int] = None, **fields) -> LedgerEvent:
        ev = cls(seq=len(self._events), time=float(time), shard=shard,
                 **fields)
        self._events.append(ev)
        return ev

    def splice(self, inserts) -> None:
        """Insert event runs at recorded positions and renumber ``seq ==
        position`` across the whole stream — THE one sanctioned bulk-
        mutation path (rule R005: only this module touches ``_events``).

        ``inserts`` is a sequence of ``(position, events)`` pairs with
        positions relative to the pre-splice stream, ascending; the
        inserted events' ``seq`` values are ignored and rewritten.  The
        fused window loop uses this to land deferred ``BlockPacked``
        events exactly where the stepped path emitted them; callers must
        not have handed out cursors past the first splice point.
        """
        merged: List[LedgerEvent] = []
        prev = 0
        for pos, evs in inserts:
            if pos < prev:
                raise ValueError("splice positions must be ascending")
            merged.extend(self._events[prev:pos])
            merged.extend(evs)
            prev = pos
        merged.extend(self._events[prev:])
        # in-place renumber: the log owns its event objects, so rewriting
        # seq on the frozen dataclasses is unobservable to drained readers
        for i, e in enumerate(merged):
            if e.seq != i:
                object.__setattr__(e, "seq", i)
        self._events[:] = merged

    def since(self, cursor: int) -> List[LedgerEvent]:
        return self._events[cursor:]

    @property
    def next_cursor(self) -> int:
        return len(self._events)
