"""Typed ledger events + the shared per-stack event log.

The PR-4 event surface was a string-keyed callback ``subscribe`` that
existed only on the rollup faces and pushed loose dict payloads.  This
module replaces it with

  * small frozen **event dataclasses** — one per lifecycle stage of the
    proof pipeline (``BatchSealed`` -> ``ProofGenerated`` ->
    ``AggregateVerified``), plus the window commitment
    (``WindowSettled``) and L1 block production (``BlockPacked``), and
  * an ``EventLog`` — ONE append-only, totally ordered stream per ledger
    stack.  The L1 chain owns the log; every rollup face built on top of
    it (``VectorRollup``, ``Rollup``, the sharded fabric and its shards)
    adopts the same instance, so L1 and L2 events interleave in emission
    order under a single monotonic ``seq``.

Consumption is pull-based: readers keep a cursor and drain
``log.since(cursor)`` (the public face is ``repro.api.NodeClient.
events()``).  Events are plain data — safe to hold, compare and
serialize; ``shard`` tags fabric-side events with the owning shard and
stays ``None`` on unsharded faces.  The callback ``subscribe`` API is
kept for one release as a deprecation shim over the same emission sites
(see repro.api.NodeClient.subscribe).
"""
from __future__ import annotations

import dataclasses
from typing import ClassVar, List, Optional, Tuple, Type


@dataclasses.dataclass(frozen=True)
class LedgerEvent:
    """Base event: total order (``seq``), simulated time, shard tag."""

    seq: int
    time: float
    shard: Optional[int]

    kind: ClassVar[str] = "event"


@dataclasses.dataclass(frozen=True)
class BatchSealed(LedgerEvent):
    """One seal pass committed ``n_batches`` L2 batches to the L1."""

    first_batch: int
    n_batches: int
    n_txs: int
    digest: int                  # merged update-buffer xor-mix digest

    kind: ClassVar[str] = "batch_sealed"


@dataclasses.dataclass(frozen=True)
class ProofGenerated(LedgerEvent):
    """A batch's proof job completed (modeled prover drain).

    ``time`` is the modeled completion time (``sealed_at`` + queueing
    under the prover's capacity + prove latency).
    """

    job: int
    batch: int
    n_txs: int
    digest: int                  # the batch's tx xor-root
    sealed_at: float

    kind: ClassVar[str] = "proof_generated"


@dataclasses.dataclass(frozen=True)
class AggregateVerified(LedgerEvent):
    """An aggregate proof's single verify+execute posted to the L1.

    The recursive-aggregation product: ``n_sessions`` session proofs
    (each folding its batches' digests) folded into one digest, whose L1
    verify gas is amortized across every batch in ``batches``.
    """

    aggregate: int
    n_sessions: int
    batches: Tuple[int, ...]
    n_txs: int
    verify: int
    execute: int
    digest: int                  # recursive fold of the session digests

    kind: ClassVar[str] = "aggregate_verified"


@dataclasses.dataclass(frozen=True)
class WindowSettled(LedgerEvent):
    """A window boundary sealed: the backend's state commitment record.

    Emitted once per ``seal()`` on every rollup face.  On the sharded
    fabric it carries the merged fabric root and the per-shard partition
    roots; on unsharded faces those fields stay empty.
    """

    window: int
    n_batches: int
    state_root: str
    fabric_root: str = ""
    shard_roots: Tuple[str, ...] = ()

    kind: ClassVar[str] = "window_settled"


@dataclasses.dataclass(frozen=True)
class BlockPacked(LedgerEvent):
    """The L1 packed one block (chain-only nodes' event stream)."""

    height: int
    n_txs: int
    gas_used: int
    block_hash: str

    kind: ClassVar[str] = "block_packed"


@dataclasses.dataclass(frozen=True)
class EventsDropped(LedgerEvent):
    """Overflow marker: a reader's cursor fell behind a bounded log.

    Never stored in the log — ``since`` synthesizes one (``seq`` is the
    stale cursor, ``time`` the first retained event's time) when a
    cursor points below the ring-buffer base, so long-poll consumers see
    the gap explicitly instead of a silent skip.  ``resume_cursor`` is
    the oldest cursor that still resolves to retained events.
    """

    n_dropped: int
    resume_cursor: int

    kind: ClassVar[str] = "events_dropped"


class EventLog:
    """Append-only, totally ordered typed event stream for one stack.

    ``emit`` assigns the next ``seq`` and returns the constructed event;
    readers drain with ``since(cursor)`` + ``next_cursor`` (cursors live
    with the reader, so independent consumers never steal each other's
    events).

    ``cap`` (settable any time; ``None`` = unbounded, the default every
    stack is built with) turns the log into a bounded ring: emissions
    past the cap evict the oldest events, ``seq`` keeps counting from
    process start (``_base`` tracks the seq of the oldest retained
    event), and a cursor that fell below the base gets an explicit
    ``EventsDropped`` marker from ``since`` instead of silently reading
    a shifted window.  Multi-consumer serving (repro/serve) is the one
    user that sets a cap.
    """

    def __init__(self, cap: Optional[int] = None):
        self._events: List[LedgerEvent] = []
        self._base = 0                  # seq of _events[0]
        self.cap = cap
        self.n_dropped = 0              # lifetime evictions (monitoring)

    def emit(self, cls: Type[LedgerEvent], *, time: float,
             shard: Optional[int] = None, **fields) -> LedgerEvent:
        ev = cls(seq=self._base + len(self._events), time=float(time),
                 shard=shard, **fields)
        self._events.append(ev)
        self._evict()
        return ev

    def _evict(self) -> None:
        if self.cap is not None and len(self._events) > self.cap:
            n = len(self._events) - int(self.cap)
            del self._events[:n]
            self._base += n
            self.n_dropped += n

    def splice(self, inserts) -> None:
        """Insert event runs at recorded positions and renumber ``seq ==
        position`` across the whole stream — THE one sanctioned bulk-
        mutation path (rule R005: only this module touches ``_events``).

        ``inserts`` is a sequence of ``(position, events)`` pairs with
        positions in seq coordinates of the pre-splice stream, ascending
        (callers record ``next_cursor``); the inserted events' ``seq``
        values are ignored and rewritten.  The fused window loop uses
        this to land deferred ``BlockPacked`` events exactly where the
        stepped path emitted them; callers must not have handed out
        cursors past the first splice point, and on a bounded log the
        positions must not predate the ring base.
        """
        merged: List[LedgerEvent] = []
        prev = 0
        for pos, evs in inserts:
            pos -= self._base
            if pos < 0:
                raise ValueError("splice position predates the ring base")
            if pos < prev:
                raise ValueError("splice positions must be ascending")
            merged.extend(self._events[prev:pos])
            merged.extend(evs)
            prev = pos
        merged.extend(self._events[prev:])
        # in-place renumber: the log owns its event objects, so rewriting
        # seq on the frozen dataclasses is unobservable to drained readers
        for i, e in enumerate(merged):
            if e.seq != self._base + i:
                object.__setattr__(e, "seq", self._base + i)
        self._events[:] = merged
        self._evict()

    def since(self, cursor: int) -> List[LedgerEvent]:
        lo = cursor - self._base
        if lo >= 0:
            return self._events[lo:]
        marker = EventsDropped(
            seq=cursor, time=self._events[0].time if self._events else 0.0,
            shard=None, n_dropped=-lo, resume_cursor=self._base)
        return [marker] + self._events

    def dropped(self, cursor: int) -> int:
        """Events a reader at ``cursor`` can no longer see (0 if none)."""
        return max(0, self._base - cursor)

    @property
    def base(self) -> int:
        """Seq of the oldest retained event (0 on an unbounded log)."""
        return self._base

    @property
    def next_cursor(self) -> int:
        return self._base + len(self._events)
