"""Fused compiled window loop over the SoA ledger hot path.

The Python-stepped scheduler drives every window through four separate
round-trips — pump the prover, seal lane batches, settle, and pack L1
blocks (``fl/scheduler.Scheduler.run``).  At small per-window tx counts
the vector engine's per-call Python overhead (not the array math)
dominates, so per-task throughput collapses as task count grows.

``FusedWindowLoop`` is a plan-then-execute driver for the same loop:

  * during the window loop, ledger calls append cheap **plan entries**
    (chain staging, seal/pump/settle points, block-production edges)
    instead of executing eagerly;
  * ``execute()`` then replays the plan once:

      1. every seal point's lane/batch structure, commit gas, timestamps
         and digests are computed in ONE vectorized pass over all
         windows (the per-batch xor-roots and per-window update digests
         both route through the ``batch_seal`` kernel — one call each
         for the whole run);
      2. the plan is walked in order, applying the precomputed seal
         slices, pumping the prover and staging L1 traffic exactly as
         the stepped path would — so event order, arrival indices, gas
         rows and state-handler application order are bit-identical;
      3. every deferred ``run_until`` edge becomes rows of one block
         grid, packed by a single ``block_pack`` kernel call (a jitted
         ``lax.scan`` over blocks with donated SoA buffers — N windows
         of blocks as one XLA program instead of N Python round-trips),
         and the resulting ``BlockPacked`` events are spliced back into
         the typed stream at the positions the stepped path would have
         emitted them.

Equivalence contract (pinned by tests/test_fused.py): a fused run and a
stepped run of the same schedule produce identical typed event streams,
state roots, gas logs, blocks, confirm times and results.  The only
visible difference is legacy ``EventHooks`` callback TIMING: string-key
subscribers see ``block_packed`` callbacks at ``execute()`` instead of
mid-run (relative order among block_packed callbacks is preserved).

Scope: ``VectorChain`` alone, ``VectorChain`` + ``VectorRollup``, or
``VectorChain`` + ``ShardedRollup`` — the fabric runs as K shard
**lanes**: routing decisions (hash split / least-loaded argmin / task
pins) are taken once at record time against the live ``_submitted``
counters, each lane's seal groups run through the same one-concat/
lexsort precompute, the K lanes' digest folds batch into the
``shard_seal`` kernel (kernels/shard_lanes.py — optionally
``shard_map``-ped over a ``"shard"`` device mesh), and every window
closes through ``ShardedRollup._finish_window`` exactly like a stepped
seal.  The object engines keep the stepped path
(``Scheduler(fused="auto")`` falls back automatically, with a one-time
log).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.engine import (BlockStats, TxArrays, VectorChain,
                               VectorRollup)
from repro.core.events import BatchSealed, BlockPacked


def supports_fused(chain, rollup) -> bool:
    """True when the (chain, rollup) pair can run the fused loop: a SoA
    L1 and (optionally) a SoA rollup face.  Backends declare themselves
    via a ``fused_capable`` class marker (VectorChain, VectorRollup and
    ShardedRollup set it True; the object engines lack it and fall back
    to the stepped path)."""
    if not getattr(chain, "fused_capable", False):
        return False
    return rollup is None or getattr(rollup, "fused_capable", False)


@dataclasses.dataclass
class _SealPrep:
    """One seal point, fully precomputed (None group -> empty seal).

    Everything the stepped ``seal()`` derives per call — batch structure,
    commit gas, timestamps, digests, gas rows, even the commit TxArrays —
    is built in the one bulk pass; applying a seal is pure bookkeeping."""

    txs: TxArrays                # the group's txs, arrival order
    n_txs: np.ndarray            # per-batch tx counts
    now: np.ndarray              # per-batch max submit_time
    roots: np.ndarray            # per-batch tx xor-roots (u32)
    update_digest: int           # whole-group merged-buffer digest
    arrival_batch: np.ndarray    # per-tx GLOBAL batch id (arrival order)
    first: int                   # global id of the group's first batch
    rows: List[Dict[str, Any]]   # prebuilt gas_log rows
    commit_batch: TxArrays       # time-sorted L1 commit txs
    inv_post: np.ndarray         # batch j -> its commit's index in post


class FusedWindowLoop:
    """Plan-then-execute driver for one stepped scheduler run.

    Record phase (the window loop): ``submit`` / ``seal`` / ``pump`` /
    ``run_until`` / ``flush``.  Rollup-bound txs stage into the real
    pending queue immediately (their order only matters relative to seal
    points, which the plan tracks by watermark); chain-bound txs are
    journaled so their arrival indices interleave correctly with the
    seal commits and settlement txs replayed later.  ``execute()`` runs
    the whole plan; afterwards the ledger state is indistinguishable
    from a stepped run.
    """

    def __init__(self, chain: VectorChain,
                 rollup: Optional[VectorRollup] = None):
        assert supports_fused(chain, rollup), \
            "fused loop needs a VectorChain (+ optional SoA rollup face)"
        self.chain = chain
        self.rollup = rollup
        # the sharded fabric runs as K shard LANES; a plain VectorRollup
        # is the one-lane case of the same machinery
        self.fabric = rollup if hasattr(rollup, "shards") else None
        self._lanes: List[VectorRollup] = (
            list(rollup.shards) if self.fabric is not None
            else ([rollup] if rollup is not None else []))
        self._plan: List[Tuple] = []
        # journaled per-lane rollup staging; adopt anything already
        # pending so the first planned seal covers it, like a stepped
        # seal would
        self._r_batches: List[List[TxArrays]] = [[] for _ in self._lanes]
        for k, lane in enumerate(self._lanes):
            if lane._pending:
                self._r_batches[k].extend(lane._pending)
                lane._pending, lane._pending_n = [], 0
        self._executed = False

    # -- record phase ----------------------------------------------------------
    def _stage(self, k: int, batch: TxArrays) -> Tuple[int, int]:
        """Journal one batch into lane ``k``, assigning its seq range now
        (receipts hold [lo, hi) before execute, same as a live submit)."""
        lane = self._lanes[k]
        lo = lane._next_seq
        lane._next_seq += len(batch)
        self._r_batches[k].append(batch)
        return lo, lo + len(batch)

    def submit(self, target, batch: TxArrays, shard=None):
        """Route one SoA batch: journaled, not staged — rollup txs only
        order relative to seal points (watermarked), chain txs replay
        in-order so arrival indices interleave with commits exactly.

        On the fabric the routing decision itself happens NOW (vectorized
        hash split / least-loaded argmin over the live ``_submitted``
        counters / a task-pinned ``shard``), exactly as the stepped
        ``ShardedRollup.submit_arrays`` would take it, and the per-tx
        ``(shard, seq)`` provenance is returned immediately."""
        if target is self.rollup and self.rollup is not None:
            rollup = self.rollup
            if batch.fns is not rollup.fns:
                remap = np.array([rollup.fns.id(n)
                                  for n in batch.fns.names], np.int32)
                batch = TxArrays(batch.submit_time, batch.gas,
                                 remap[batch.fn_id] if len(batch) else
                                 batch.fn_id, batch.sender_id, rollup.fns)
            if self.fabric is None:
                return self._stage(0, batch)
            return self._route_fabric(batch, shard)
        assert target is self.chain, "unknown fused submit target"
        if batch.fns is not self.chain.fns:
            # same remap submit_arrays would do — at RECORD time, so fn
            # names register in the stepped path's order
            remap = np.array([self.chain.fns.id(n)
                              for n in batch.fns.names], np.int32)
            batch = TxArrays(batch.submit_time, batch.gas,
                             remap[batch.fn_id] if len(batch) else
                             batch.fn_id, batch.sender_id, self.chain.fns)
        self._plan.append(("tx", batch))
        return None

    def _route_fabric(self, batch: TxArrays, shard):
        """The stepped ``ShardedRollup.submit_arrays`` routing, replayed
        at record time: same ``_submitted`` bookkeeping, same wire-cost
        accounting, same ``(shard_of, seq_of)`` provenance — the only
        difference is that the sub-batches journal into lanes instead of
        landing in shard pending queues."""
        fab = self.fabric
        n = len(batch)
        if shard is None and fab.route == "least_loaded":
            shard = int(np.argmin(fab._submitted))
        if shard is not None or fab.n_shards == 1:
            k = int(shard or 0)
            fab._submitted[k] += n
            pinned = np.zeros(fab.n_shards, np.int64)
            pinned[k] = n
            fab._wire_submit(pinned)
            lo, hi = self._stage(k, batch)
            return (np.full(n, k, np.int64),
                    np.arange(lo, hi, dtype=np.int64))
        from repro.core.shards import _hash_route
        lanes = _hash_route(batch.sender_id, fab.n_shards)
        fab._wire_submit(np.bincount(lanes, minlength=fab.n_shards))
        seq_of = np.empty(n, np.int64)
        for k in range(fab.n_shards):
            m = lanes == k
            if m.any():
                fab._submitted[k] += int(m.sum())
                lo, hi = self._stage(k, TxArrays(
                    batch.submit_time[m], batch.gas[m], batch.fn_id[m],
                    batch.sender_id[m], fab.fns))
                seq_of[m] = np.arange(lo, hi, dtype=np.int64)
        return lanes.astype(np.int64), seq_of

    def covers(self, target) -> bool:
        return target is self.chain or (self.rollup is not None
                                        and target is self.rollup)

    def seal(self):
        """Plan a seal point at the current per-lane staging watermarks."""
        assert self.rollup is not None
        # the stepped path registers the commit fn at its first seal —
        # keep the registry's id order identical
        self.rollup.fns.id("rollup_commit")
        self._plan.append(("seal",
                           tuple(len(rb) for rb in self._r_batches)))

    def pump(self, t_end: float):
        self._plan.append(("pump", float(t_end)))

    def run_until(self, t_end: float):
        self._plan.append(("blocks", float(t_end)))

    def flush(self):
        """Plan the stepped ``rollup.flush()``: tail seal + session close
        + forced drain."""
        self.seal()
        self._plan.append(("settle",))

    def sync_state(self, state, ids: np.ndarray, reputation: np.ndarray,
                   balances, stake):
        """Plan a cross-window state scatter (the node's fabric-state
        sync) so it lands between the seal points exactly where the
        stepped path wrote it — per-window state roots depend on it."""
        self._plan.append(("sync", state, np.asarray(ids, np.int64),
                           np.asarray(reputation, np.float32),
                           np.asarray(balances, np.float64),
                           np.asarray(stake, np.float64)))

    # -- execute: one pass over the plan ---------------------------------------
    def execute(self) -> None:
        assert not self._executed, "fused plan already executed"
        self._executed = True
        chain, rollup = self.chain, self.rollup
        preps = self._prepare_seals()
        chain_buf: List[TxArrays] = []

        def flush_chain():
            if not chain_buf:
                return
            if len(chain_buf) == 1:
                chain.submit_arrays(chain_buf[0])
            else:
                chain.submit_arrays(TxArrays(
                    np.concatenate([b.submit_time for b in chain_buf]),
                    np.concatenate([b.gas for b in chain_buf]),
                    np.concatenate([b.fn_id for b in chain_buf]),
                    np.concatenate([b.sender_id for b in chain_buf]),
                    chain.fns))
            chain_buf.clear()

        times: List[float] = []
        n_vis: List[int] = []
        # (event position, first deferred block, #blocks) per blocks edge
        markers: List[Tuple[int, int, int]] = []
        cursor = chain.blocks[-1].time
        seal_i = 0
        for entry in self._plan:
            op = entry[0]
            if op == "tx":
                chain_buf.append(entry[1])
            elif op == "seal":
                flush_chain()
                if self.fabric is not None:
                    # lanes seal in shard order, then the fabric merges
                    # the window — the stepped ShardedRollup.seal()
                    self.fabric._finish_window(
                        [self._apply_seal(preps[k][seal_i], lane)
                         for k, lane in enumerate(self._lanes)])
                else:
                    self._apply_seal(preps[0][seal_i], rollup)
                seal_i += 1
            elif op == "pump":
                flush_chain()
                rollup.pump(entry[1])
            elif op == "settle":
                flush_chain()
                rollup.settle_session()
                if self.fabric is not None:
                    rollup.prover.drain()      # fabric-wide forced drain
                else:
                    rollup.prover.drain(rollup)
            elif op == "sync":
                _, state, ids, rep, bal, stake = entry
                state.ensure_ids(ids)
                state.reputation[ids] = rep
                state.balances[ids] = bal
                state.stake[ids] = stake
                state.mark_dirty(ids)
            elif op == "blocks":
                flush_chain()
                t_end = entry[1]
                lo = len(times)
                while cursor < t_end:
                    cursor += chain.block_time
                    times.append(cursor)
                    n_vis.append(chain.n_submitted)
                if len(times) > lo:
                    markers.append((chain.events.next_cursor, lo,
                                    len(times) - lo))
            else:                                       # pragma: no cover
                raise AssertionError(f"unknown plan op {op!r}")
        flush_chain()
        self._pack_blocks(np.asarray(times, np.float64),
                          np.asarray(n_vis, np.int64), markers)

    # -- seal precompute + per-point application -------------------------------
    def _collect_groups(self, k: int) -> List[List[TxArrays]]:
        """Split lane ``k``'s journaled staging at the planned watermarks;
        batches past the last watermark return to the lane's real pending
        queue (they are what a stepped run would leave unsealed)."""
        groups, prev = [], 0
        for entry in self._plan:
            if entry[0] == "seal":
                groups.append(self._r_batches[k][prev:entry[1][k]])
                prev = entry[1][k]
        tail = self._r_batches[k][prev:]
        if tail:
            lane = self._lanes[k]
            lane._pending.extend(tail)
            lane._pending_n += sum(len(b) for b in tail)
        return groups

    def _prepare_seals(self) -> List[List[Optional[_SealPrep]]]:
        """One vectorized pass per lane computing every seal point's
        batch structure, commit gas, timestamps, gas rows and commit txs
        (the stepped ``VectorRollup.seal`` math, all windows at once —
        applying a seal afterwards is pure bookkeeping), followed by ONE
        batched digest fold across all lanes: on the fabric the K lanes'
        segmented xor-folds stack into the ``shard_seal`` kernel's
        ``(K, W)`` word grid (two calls for the whole run — per-batch tx
        roots and per-window update digests), optionally ``shard_map``-ped
        over the ``"shard"`` device mesh.  Indexed ``[lane][seal_i]``."""
        if self.rollup is None:
            return []
        structs = [self._lane_struct(lane, self._collect_groups(k))
                   for k, lane in enumerate(self._lanes)]
        self._fold_digests(structs)
        return [self._lane_preps(lane, structs[k])
                for k, lane in enumerate(self._lanes)]

    def _lane_struct(self, rollup: VectorRollup,
                     groups: List[List[TxArrays]]) -> Optional[Dict]:
        """Everything the stepped ``seal()`` derives for one lane's
        groups EXCEPT the digest folds (those batch across lanes)."""
        sizes = [sum(len(b) for b in g) for g in groups]
        live = [i for i, s in enumerate(sizes) if s > 0]
        if not live:
            return None
        cat = [b for i in live for b in groups[i]]
        t = np.concatenate([b.submit_time for b in cat])
        g = np.concatenate([b.gas for b in cat])
        f = np.concatenate([b.fn_id for b in cat])
        s = np.concatenate([b.sender_id for b in cat])
        n = t.shape[0]
        gsz = np.array([sizes[i] for i in live], np.int64)
        gstart = np.concatenate([[0], np.cumsum(gsz)[:-1]])
        gidx = np.repeat(np.arange(len(live)), gsz)
        within = np.arange(n) - gstart[gidx]
        lane = within % rollup.n_lanes
        pos = within // rollup.n_lanes
        bil = pos // rollup.batch_size
        # group-major lane-major order: identical within-group order to
        # the stepped seal's lexsort((pos, lane))
        order = np.lexsort((pos, lane, gidx))
        lane_o, bil_o, g_o = lane[order], bil[order], gidx[order]
        seg_new = np.empty(n, bool)
        seg_new[0] = True
        seg_new[1:] = ((g_o[1:] != g_o[:-1]) | (lane_o[1:] != lane_o[:-1])
                       | (bil_o[1:] != bil_o[:-1]))
        batch_id = np.cumsum(seg_new) - 1           # global across groups
        nb = int(batch_id[-1]) + 1
        starts = np.flatnonzero(seg_new)
        fn_o, t_o = f[order], t[order]
        counts = np.zeros((nb, len(rollup.fns)), np.int64)
        np.add.at(counts, (batch_id, fn_o), 1)
        base, percall = rollup._commit_gas_vectors()
        commit = (counts > 0) @ base + counts @ percall
        n_txs = counts.sum(axis=1)
        now = np.maximum.reduceat(t_o, starts)
        words = TxArrays(t_o, g[order], fn_o, s[order],
                         rollup.fns).word_buffer()
        # global batch ids: groups seal in plan order, so ids continue
        # from the lane's current count exactly like consecutive seals
        first0 = rollup.n_batches
        arrival_batch = np.empty(n, np.int64)
        arrival_batch[order] = first0 + batch_id
        batch_group = g_o[starts]                   # group of each batch
        # per-batch commit ordering, grouped: the stepped seal posts each
        # group's commits time-sorted (stable)
        post = np.lexsort((np.arange(nb), now, batch_group))
        inv_post = np.empty(nb, np.int64)
        inv_post[post] = np.arange(nb)
        return {"live": live, "t": t, "g": g, "f": f, "s": s,
                "gsz": gsz, "gstart": gstart, "nb": nb, "starts": starts,
                "n_txs": n_txs, "now": now, "commit": commit,
                "words": words, "first0": first0,
                "arrival_batch": arrival_batch,
                "batch_group": batch_group, "post": post,
                "inv_post": inv_post, "lane_b": lane_o[starts],
                "roots": None, "gdigest": None}

    def _fold_digests(self, structs: List[Optional[Dict]]) -> None:
        """Fill every lane's per-batch tx roots and per-group update
        digests.  Single lane: the two ``batch_seal`` segmented folds of
        the stepped path.  Fabric: the K lanes' folds stack into the
        ``shard_seal`` kernel — two calls total, each folding every
        lane's segments at once over the lane-rows word grid."""
        live = [st for st in structs if st is not None]
        if not live:
            return
        if self.fabric is None:
            from repro.core.engine import xor_fold_digest_segments
            st = live[0]
            st["roots"] = xor_fold_digest_segments(
                st["words"], st["starts"] * 4)
            # per-GROUP merged-buffer digests: groups are word-contiguous
            # in lane-major order, so one more segmented fold covers all
            # the stepped path's per-seal update digests
            st["gdigest"] = xor_fold_digest_segments(
                st["words"], st["gstart"] * 4)
            return
        from repro.kernels.factory import get_kernel
        fn = get_kernel("shard_seal", self._shard_seal_impl())
        k_live = len(live)
        n_words = np.array([st["words"].shape[0] for st in live], np.int64)
        words2d = np.zeros((k_live, int(n_words.max())), np.uint32)
        for i, st in enumerate(live):
            words2d[i, : n_words[i]] = st["words"]

        def fold(key, scale):
            segs = [np.asarray(st[key], np.int64) * scale for st in live]
            n_seg = np.array([len(sg) for sg in segs], np.int64)
            starts2d = np.repeat(n_words[:, None], int(n_seg.max()), 1)
            for i, sg in enumerate(segs):
                starts2d[i, : n_seg[i]] = sg
            out = fn(words2d, starts2d, n_seg, n_words)
            return [out[i, : n_seg[i]] for i in range(k_live)]

        roots = fold("starts", 4)
        gdigs = fold("gstart", 4)
        for i, st in enumerate(live):
            st["roots"] = roots[i]
            st["gdigest"] = gdigs[i]

    def _shard_seal_impl(self) -> str:
        """Map the fabric's mesh knob to a ``shard_seal`` impl: ``"on"``
        forces the mesh-mapped kernel, ``"off"`` the NumPy mirror, and
        ``"auto"`` takes the mesh exactly when more than one local device
        exists (the NumPy mirror otherwise — at CPU lane counts the fold
        is memory-bound and the mirror wins without a real mesh)."""
        mode = getattr(self.fabric, "mesh_mode", "off")
        if mode == "on":
            return "shard_map"
        if mode == "off":
            return "numpy"
        from repro.launch.mesh import n_local_devices
        return "shard_map" if n_local_devices() > 1 else "numpy"

    def _lane_preps(self, rollup: VectorRollup,
                    st: Optional[Dict]) -> List[Optional[_SealPrep]]:
        """Assemble one lane's per-seal-point ``_SealPrep`` list from its
        structure + filled digests."""
        n_groups = sum(1 for e in self._plan if e[0] == "seal")
        preps: List[Optional[_SealPrep]] = [None] * n_groups
        if st is None:
            return preps
        live, gstart, gsz = st["live"], st["gstart"], st["gsz"]
        n_txs, now, commit = st["n_txs"], st["now"], st["commit"]
        nb, first0 = st["nb"], st["first0"]
        now_p = st["now"][st["post"]]
        commit_p = st["commit"][st["post"]]
        commit_fn = rollup.fns.id("rollup_commit")
        lane_b = st["lane_b"]
        bstart = np.searchsorted(st["batch_group"], np.arange(len(live)))
        bstop = np.concatenate([bstart[1:], [nb]])
        t, g, f, s = st["t"], st["g"], st["f"], st["s"]
        for k, i in enumerate(live):
            b0, b1 = int(bstart[k]), int(bstop[k])
            # group k is contiguous both in arrival order (concat) and in
            # the group-major sorted order, at the same slice
            tsel = slice(int(gstart[k]), int(gstart[k] + gsz[k]))
            rows = [{"batch": first0 + j, "lane": int(lane_b[j]),
                     "n_txs": int(n_txs[j]), "commit": int(commit[j]),
                     "verify": 0, "execute": 0, "total": int(commit[j])}
                    for j in range(b0, b1)]
            nb_g = b1 - b0
            commit_batch = TxArrays(
                now_p[b0:b1].astype(np.float64),
                commit_p[b0:b1].astype(np.int64),
                np.full(nb_g, commit_fn, np.int32),
                np.zeros(nb_g, np.int32), rollup.fns)
            preps[i] = _SealPrep(
                TxArrays(t[tsel], g[tsel], f[tsel], s[tsel], rollup.fns),
                n_txs[b0:b1], now[b0:b1], st["roots"][b0:b1],
                int(st["gdigest"][k]), st["arrival_batch"][tsel],
                first0 + b0, rows, commit_batch,
                st["inv_post"][b0:b1] - b0)
        return preps

    def _apply_seal(self, prep: Optional[_SealPrep],
                    rollup: VectorRollup) -> int:
        """Apply one precomputed seal point to one lane — the stepped
        ``seal()``'s bookkeeping, with all the array math already done in
        bulk.  Returns the number of batches sealed (the stepped return)."""
        if prep is None:                       # empty seal: window event
            rollup._emit_window(0)
            return 0
        n = len(prep.txs)
        if rollup._state_handlers:
            rollup._apply_state(prep.txs)
        first, nb = prep.first, len(prep.n_txs)
        rollup.batch_digests.extend(int(r) for r in prep.roots)
        rollup.update_digest = prep.update_digest
        rollup._prov_starts.append(rollup._sealed_seq)
        rollup._prov_batches.append(prep.arrival_batch)
        rollup._sealed_seq += n
        refs = rollup._l1_submit(prep.commit_batch)
        rollup.batch_commit_ref.update(
            (first + j, refs[int(prep.inv_post[j])]) for j in range(nb))
        rollup.gas_log.extend(prep.rows)
        rollup.n_batches += nb
        rollup._last_time = float(prep.now.max())
        rollup.prover.enqueue(rollup, first, prep.roots, prep.n_txs,
                              prep.now, prep.rows)
        rollup.events.emit(BatchSealed, time=rollup._last_time,
                           shard=rollup._event_shard, first_batch=first,
                           n_batches=nb, n_txs=n,
                           digest=rollup.update_digest)
        rollup._emit("batch_sealed", {
            "first_batch": first, "n_batches": nb, "n_txs": n,
            "digest": rollup.update_digest})
        rollup._emit_window(nb)
        return nb

    # -- deferred block production ---------------------------------------------
    def _pack_blocks(self, times: np.ndarray, n_vis: np.ndarray,
                     markers: List[Tuple[int, int, int]]) -> None:
        """Pack every deferred block in one ``block_pack`` kernel call
        and splice the BlockPacked events to their stepped positions."""
        chain = self.chain
        if times.shape[0] == 0:
            return
        from repro.kernels.factory import get_kernel
        chain._consolidate()
        nblk = times.shape[0]
        ptr0 = chain._ptr
        stops = np.asarray(get_kernel("block_pack")(
            chain._tmax[: chain._n], chain._gcum[: chain._n], times,
            n_vis, chain.block_gas_limit, ptr0), np.int64)
        starts = np.concatenate([[ptr0], stops[:-1]])
        if chain._n:
            gend = np.where(stops > 0,
                            chain._gcum[np.maximum(stops - 1, 0)], 0)
            gprev = np.where(starts > 0,
                             chain._gcum[np.maximum(starts - 1, 0)], 0)
            gas_used = np.where(stops > starts, gend - gprev, 0)
        else:                                  # empty mempool: empty blocks
            gas_used = np.zeros(nblk, np.int64)
        ntx = stops - starts
        final = int(stops[-1])
        if final > ptr0:
            chain._confirm[ptr0:final] = np.repeat(times, ntx)
        dispatch = bool(chain._batch_handlers or chain._state_handlers)
        assert chain.quorum(chain.n_validators - chain.n_validators // 3)
        height0 = len(chain.blocks)
        parent = chain.blocks[-1].block_hash
        for b in range(nblk):
            lo, hi = int(starts[b]), int(stops[b])
            if dispatch and hi > lo:
                self._dispatch_handlers(lo, hi)
            blk = BlockStats(height0 + b, float(times[b]), int(ntx[b]),
                             int(gas_used[b]), lo, hi, parent)
            parent = blk.block_hash
            chain.blocks.append(blk)
        chain.total_gas += int(gas_used.sum())
        chain._ptr = final
        self._splice_block_events(times, ntx, gas_used, height0, markers)

    def _dispatch_handlers(self, lo: int, hi: int) -> None:
        """Per-(block, fn) handler dispatch — produce_block's contract,
        on one deferred block's confirmed slice."""
        chain = self.chain
        counts = np.bincount(chain._f[lo:hi], minlength=len(chain.fns))
        view = TxArrays(chain._t[lo:hi], chain._g[lo:hi],
                        chain._f[lo:hi], chain._s[lo:hi], chain.fns)
        for fid, h in chain._batch_handlers.items():
            if fid < counts.shape[0] and counts[fid]:
                h(chain.state, int(counts[fid]), view)
        for fid, h in chain._state_handlers.items():
            if fid < counts.shape[0] and counts[fid]:
                m = view.fn_id == fid
                h(chain.state_arrays,
                  TxArrays(view.submit_time[m], view.gas[m],
                           view.fn_id[m], view.sender_id[m], chain.fns))

    def _splice_block_events(self, times, ntx, gas_used, height0,
                             markers) -> None:
        """Land BlockPacked events at the positions the stepped path
        emitted them via ``EventLog.splice`` (the one sanctioned bulk-
        mutation path — rule R005; the log renumbers ``seq``)."""
        chain = self.chain
        inserts: List[Any] = []
        for pos, blo, bn in markers:
            run: List[Any] = []
            for b in range(blo, blo + bn):
                blk = chain.blocks[height0 + b]
                run.append(BlockPacked(
                    seq=-1, time=float(times[b]), shard=None,
                    height=blk.height, n_txs=int(ntx[b]),
                    gas_used=int(gas_used[b]), block_hash=blk.block_hash))
                chain._emit("block_packed", {
                    "height": blk.height, "n_txs": int(ntx[b]),
                    "gas_used": int(gas_used[b]),
                    "block_hash": blk.block_hash})
            inserts.append((pos, run))
        chain.events.splice(inserts)
