"""Fused compiled window loop over the SoA ledger hot path.

The Python-stepped scheduler drives every window through four separate
round-trips — pump the prover, seal lane batches, settle, and pack L1
blocks (``fl/scheduler.Scheduler.run``).  At small per-window tx counts
the vector engine's per-call Python overhead (not the array math)
dominates, so per-task throughput collapses as task count grows.

``FusedWindowLoop`` is a plan-then-execute driver for the same loop:

  * during the window loop, ledger calls append cheap **plan entries**
    (chain staging, seal/pump/settle points, block-production edges)
    instead of executing eagerly;
  * ``execute()`` then replays the plan once:

      1. every seal point's lane/batch structure, commit gas, timestamps
         and digests are computed in ONE vectorized pass over all
         windows (the per-batch xor-roots and per-window update digests
         both route through the ``batch_seal`` kernel — one call each
         for the whole run);
      2. the plan is walked in order, applying the precomputed seal
         slices, pumping the prover and staging L1 traffic exactly as
         the stepped path would — so event order, arrival indices, gas
         rows and state-handler application order are bit-identical;
      3. every deferred ``run_until`` edge becomes rows of one block
         grid, packed by a single ``block_pack`` kernel call (a jitted
         ``lax.scan`` over blocks with donated SoA buffers — N windows
         of blocks as one XLA program instead of N Python round-trips),
         and the resulting ``BlockPacked`` events are spliced back into
         the typed stream at the positions the stepped path would have
         emitted them.

Equivalence contract (pinned by tests/test_fused.py): a fused run and a
stepped run of the same schedule produce identical typed event streams,
state roots, gas logs, blocks, confirm times and results.  The only
visible difference is legacy ``EventHooks`` callback TIMING: string-key
subscribers see ``block_packed`` callbacks at ``execute()`` instead of
mid-run (relative order among block_packed callbacks is preserved).

Scope: ``VectorChain`` alone or ``VectorChain`` + ``VectorRollup``.
The sharded fabric and the object engines keep the stepped path
(``Scheduler(fused="auto")`` falls back automatically).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.engine import (BlockStats, TxArrays, VectorChain,
                               VectorRollup)
from repro.core.events import BatchSealed, BlockPacked


def supports_fused(chain, rollup) -> bool:
    """True when the (chain, rollup) pair can run the fused loop: a SoA
    L1 and (optionally) an unsharded SoA rollup face.  Backends declare
    themselves via a ``fused_capable`` class marker (VectorChain and
    VectorRollup set it True; the object engines lack it; ShardedRollup
    sets it False — its per-shard seals with cross-shard routing state
    cannot replay as one plan)."""
    if not getattr(chain, "fused_capable", False):
        return False
    return rollup is None or getattr(rollup, "fused_capable", False)


@dataclasses.dataclass
class _SealPrep:
    """One seal point, fully precomputed (None group -> empty seal).

    Everything the stepped ``seal()`` derives per call — batch structure,
    commit gas, timestamps, digests, gas rows, even the commit TxArrays —
    is built in the one bulk pass; applying a seal is pure bookkeeping."""

    txs: TxArrays                # the group's txs, arrival order
    n_txs: np.ndarray            # per-batch tx counts
    now: np.ndarray              # per-batch max submit_time
    roots: np.ndarray            # per-batch tx xor-roots (u32)
    update_digest: int           # whole-group merged-buffer digest
    arrival_batch: np.ndarray    # per-tx GLOBAL batch id (arrival order)
    first: int                   # global id of the group's first batch
    rows: List[Dict[str, Any]]   # prebuilt gas_log rows
    commit_batch: TxArrays       # time-sorted L1 commit txs
    inv_post: np.ndarray         # batch j -> its commit's index in post


class FusedWindowLoop:
    """Plan-then-execute driver for one stepped scheduler run.

    Record phase (the window loop): ``submit`` / ``seal`` / ``pump`` /
    ``run_until`` / ``flush``.  Rollup-bound txs stage into the real
    pending queue immediately (their order only matters relative to seal
    points, which the plan tracks by watermark); chain-bound txs are
    journaled so their arrival indices interleave correctly with the
    seal commits and settlement txs replayed later.  ``execute()`` runs
    the whole plan; afterwards the ledger state is indistinguishable
    from a stepped run.
    """

    def __init__(self, chain: VectorChain,
                 rollup: Optional[VectorRollup] = None):
        assert supports_fused(chain, rollup), \
            "fused loop needs a VectorChain (+ optional VectorRollup)"
        self.chain = chain
        self.rollup = rollup
        self._plan: List[Tuple] = []
        # journaled rollup staging; adopt anything already pending so the
        # first planned seal covers it, like a stepped seal would
        self._r_batches: List[TxArrays] = []
        if rollup is not None and rollup._pending:
            self._r_batches.extend(rollup._pending)
            rollup._pending, rollup._pending_n = [], 0
        self._executed = False

    # -- record phase ----------------------------------------------------------
    def submit(self, target, batch: TxArrays):
        """Route one SoA batch: journaled, not staged — rollup txs only
        order relative to seal points (watermarked), chain txs replay
        in-order so arrival indices interleave with commits exactly."""
        if target is self.rollup and self.rollup is not None:
            rollup = self.rollup
            if batch.fns is not rollup.fns:
                remap = np.array([rollup.fns.id(n)
                                  for n in batch.fns.names], np.int32)
                batch = TxArrays(batch.submit_time, batch.gas,
                                 remap[batch.fn_id] if len(batch) else
                                 batch.fn_id, batch.sender_id, rollup.fns)
            # assign the seq range now (receipts hold [lo, hi) before
            # execute, same as a live submit)
            lo = rollup._next_seq
            rollup._next_seq += len(batch)
            self._r_batches.append(batch)
            return lo, lo + len(batch)
        assert target is self.chain, "unknown fused submit target"
        if batch.fns is not self.chain.fns:
            # same remap submit_arrays would do — at RECORD time, so fn
            # names register in the stepped path's order
            remap = np.array([self.chain.fns.id(n)
                              for n in batch.fns.names], np.int32)
            batch = TxArrays(batch.submit_time, batch.gas,
                             remap[batch.fn_id] if len(batch) else
                             batch.fn_id, batch.sender_id, self.chain.fns)
        self._plan.append(("tx", batch))
        return None

    def covers(self, target) -> bool:
        return target is self.chain or (self.rollup is not None
                                        and target is self.rollup)

    def seal(self):
        """Plan a seal point at the current rollup staging watermark."""
        assert self.rollup is not None
        # the stepped path registers the commit fn at its first seal —
        # keep the registry's id order identical
        self.rollup.fns.id("rollup_commit")
        self._plan.append(("seal", len(self._r_batches)))

    def pump(self, t_end: float):
        self._plan.append(("pump", float(t_end)))

    def run_until(self, t_end: float):
        self._plan.append(("blocks", float(t_end)))

    def flush(self):
        """Plan the stepped ``rollup.flush()``: tail seal + session close
        + forced drain."""
        self.seal()
        self._plan.append(("settle",))

    def sync_state(self, state, ids: np.ndarray, reputation: np.ndarray,
                   balances, stake):
        """Plan a cross-window state scatter (the node's fabric-state
        sync) so it lands between the seal points exactly where the
        stepped path wrote it — per-window state roots depend on it."""
        self._plan.append(("sync", state, np.asarray(ids, np.int64),
                           np.asarray(reputation, np.float32),
                           np.asarray(balances, np.float64),
                           np.asarray(stake, np.float64)))

    # -- execute: one pass over the plan ---------------------------------------
    def execute(self) -> None:
        assert not self._executed, "fused plan already executed"
        self._executed = True
        chain, rollup = self.chain, self.rollup
        preps = self._prepare_seals()
        chain_buf: List[TxArrays] = []

        def flush_chain():
            if not chain_buf:
                return
            if len(chain_buf) == 1:
                chain.submit_arrays(chain_buf[0])
            else:
                chain.submit_arrays(TxArrays(
                    np.concatenate([b.submit_time for b in chain_buf]),
                    np.concatenate([b.gas for b in chain_buf]),
                    np.concatenate([b.fn_id for b in chain_buf]),
                    np.concatenate([b.sender_id for b in chain_buf]),
                    chain.fns))
            chain_buf.clear()

        times: List[float] = []
        n_vis: List[int] = []
        # (event position, first deferred block, #blocks) per blocks edge
        markers: List[Tuple[int, int, int]] = []
        cursor = chain.blocks[-1].time
        seal_i = 0
        for entry in self._plan:
            op = entry[0]
            if op == "tx":
                chain_buf.append(entry[1])
            elif op == "seal":
                flush_chain()
                self._apply_seal(preps[seal_i])
                seal_i += 1
            elif op == "pump":
                flush_chain()
                rollup.pump(entry[1])
            elif op == "settle":
                flush_chain()
                rollup.settle_session()
                rollup.prover.drain(rollup)
            elif op == "sync":
                _, state, ids, rep, bal, stake = entry
                state.ensure_ids(ids)
                state.reputation[ids] = rep
                state.balances[ids] = bal
                state.stake[ids] = stake
            elif op == "blocks":
                flush_chain()
                t_end = entry[1]
                lo = len(times)
                while cursor < t_end:
                    cursor += chain.block_time
                    times.append(cursor)
                    n_vis.append(chain.n_submitted)
                if len(times) > lo:
                    markers.append((chain.events.next_cursor, lo,
                                    len(times) - lo))
            else:                                       # pragma: no cover
                raise AssertionError(f"unknown plan op {op!r}")
        flush_chain()
        self._pack_blocks(np.asarray(times, np.float64),
                          np.asarray(n_vis, np.int64), markers)

    # -- seal precompute + per-point application -------------------------------
    def _collect_groups(self) -> List[List[TxArrays]]:
        """Split the journaled rollup staging at the planned watermarks;
        batches past the last watermark return to the real pending queue
        (they are what a stepped run would leave unsealed)."""
        groups, prev = [], 0
        for entry in self._plan:
            if entry[0] == "seal":
                groups.append(self._r_batches[prev:entry[1]])
                prev = entry[1]
        tail = self._r_batches[prev:]
        if tail:
            self.rollup._pending.extend(tail)
            self.rollup._pending_n += sum(len(b) for b in tail)
        return groups

    def _prepare_seals(self) -> List[Optional[_SealPrep]]:
        """One vectorized pass computing every seal point's batch
        structure, commit gas, timestamps, digests, gas rows and commit
        txs (the stepped ``VectorRollup.seal`` math, all windows at
        once — applying a seal afterwards is pure bookkeeping)."""
        if self.rollup is None:
            return []
        from repro.core.engine import xor_fold_digest_segments
        rollup = self.rollup
        groups = self._collect_groups()
        sizes = [sum(len(b) for b in g) for g in groups]
        live = [i for i, s in enumerate(sizes) if s > 0]
        preps: List[Optional[_SealPrep]] = [None] * len(groups)
        if not live:
            return preps
        cat = [b for i in live for b in groups[i]]
        t = np.concatenate([b.submit_time for b in cat])
        g = np.concatenate([b.gas for b in cat])
        f = np.concatenate([b.fn_id for b in cat])
        s = np.concatenate([b.sender_id for b in cat])
        n = t.shape[0]
        gsz = np.array([sizes[i] for i in live], np.int64)
        gstart = np.concatenate([[0], np.cumsum(gsz)[:-1]])
        gidx = np.repeat(np.arange(len(live)), gsz)
        within = np.arange(n) - gstart[gidx]
        lane = within % rollup.n_lanes
        pos = within // rollup.n_lanes
        bil = pos // rollup.batch_size
        # group-major lane-major order: identical within-group order to
        # the stepped seal's lexsort((pos, lane))
        order = np.lexsort((pos, lane, gidx))
        lane_o, bil_o, g_o = lane[order], bil[order], gidx[order]
        seg_new = np.empty(n, bool)
        seg_new[0] = True
        seg_new[1:] = ((g_o[1:] != g_o[:-1]) | (lane_o[1:] != lane_o[:-1])
                       | (bil_o[1:] != bil_o[:-1]))
        batch_id = np.cumsum(seg_new) - 1           # global across groups
        nb = int(batch_id[-1]) + 1
        starts = np.flatnonzero(seg_new)
        fn_o, t_o = f[order], t[order]
        counts = np.zeros((nb, len(rollup.fns)), np.int64)
        np.add.at(counts, (batch_id, fn_o), 1)
        base, percall = rollup._commit_gas_vectors()
        commit = (counts > 0) @ base + counts @ percall
        n_txs = counts.sum(axis=1)
        now = np.maximum.reduceat(t_o, starts)
        words = TxArrays(t_o, g[order], fn_o, s[order],
                         rollup.fns).word_buffer()
        roots = xor_fold_digest_segments(words, starts * 4)
        # per-GROUP merged-buffer digests: groups are word-contiguous in
        # lane-major order, so one more segmented fold covers all the
        # stepped path's per-seal update digests
        gdigest = xor_fold_digest_segments(words, gstart * 4)
        # global batch ids: groups seal in plan order, so ids continue
        # from the rollup's current count exactly like consecutive seals
        first0 = rollup.n_batches
        arrival_batch = np.empty(n, np.int64)
        arrival_batch[order] = first0 + batch_id
        batch_group = g_o[starts]                   # group of each batch
        # per-batch commit ordering, grouped: the stepped seal posts each
        # group's commits time-sorted (stable)
        post = np.lexsort((np.arange(nb), now, batch_group))
        inv_post = np.empty(nb, np.int64)
        inv_post[post] = np.arange(nb)
        now_p, commit_p = now[post], commit[post]
        commit_fn = rollup.fns.id("rollup_commit")
        lane_b = lane_o[starts]
        bstart = np.searchsorted(batch_group, np.arange(len(live)))
        bstop = np.concatenate([bstart[1:], [nb]])
        for k, i in enumerate(live):
            b0, b1 = int(bstart[k]), int(bstop[k])
            # group k is contiguous both in arrival order (concat) and in
            # the group-major sorted order, at the same slice
            tsel = slice(int(gstart[k]), int(gstart[k] + gsz[k]))
            rows = [{"batch": first0 + j, "lane": int(lane_b[j]),
                     "n_txs": int(n_txs[j]), "commit": int(commit[j]),
                     "verify": 0, "execute": 0, "total": int(commit[j])}
                    for j in range(b0, b1)]
            nb_g = b1 - b0
            commit_batch = TxArrays(
                now_p[b0:b1].astype(np.float64),
                commit_p[b0:b1].astype(np.int64),
                np.full(nb_g, commit_fn, np.int32),
                np.zeros(nb_g, np.int32), rollup.fns)
            preps[i] = _SealPrep(
                TxArrays(t[tsel], g[tsel], f[tsel], s[tsel], rollup.fns),
                n_txs[b0:b1], now[b0:b1], roots[b0:b1], int(gdigest[k]),
                arrival_batch[tsel], first0 + b0, rows, commit_batch,
                inv_post[b0:b1] - b0)
        return preps

    def _apply_seal(self, prep: Optional[_SealPrep]) -> None:
        """Apply one precomputed seal point — the stepped ``seal()``'s
        bookkeeping, with all the array math already done in bulk."""
        rollup = self.rollup
        if prep is None:                       # empty seal: window event
            rollup._emit_window(0)
            return
        n = len(prep.txs)
        if rollup._state_handlers:
            rollup._apply_state(prep.txs)
        first, nb = prep.first, len(prep.n_txs)
        rollup.batch_digests.extend(int(r) for r in prep.roots)
        rollup.update_digest = prep.update_digest
        rollup._prov_starts.append(rollup._sealed_seq)
        rollup._prov_batches.append(prep.arrival_batch)
        rollup._sealed_seq += n
        refs = rollup._l1_submit(prep.commit_batch)
        rollup.batch_commit_ref.update(
            (first + j, refs[int(prep.inv_post[j])]) for j in range(nb))
        rollup.gas_log.extend(prep.rows)
        rollup.n_batches += nb
        rollup._last_time = float(prep.now.max())
        rollup.prover.enqueue(rollup, first, prep.roots, prep.n_txs,
                              prep.now, prep.rows)
        rollup.events.emit(BatchSealed, time=rollup._last_time,
                           shard=rollup._event_shard, first_batch=first,
                           n_batches=nb, n_txs=n,
                           digest=rollup.update_digest)
        rollup._emit("batch_sealed", {
            "first_batch": first, "n_batches": nb, "n_txs": n,
            "digest": rollup.update_digest})
        rollup._emit_window(nb)

    # -- deferred block production ---------------------------------------------
    def _pack_blocks(self, times: np.ndarray, n_vis: np.ndarray,
                     markers: List[Tuple[int, int, int]]) -> None:
        """Pack every deferred block in one ``block_pack`` kernel call
        and splice the BlockPacked events to their stepped positions."""
        chain = self.chain
        if times.shape[0] == 0:
            return
        from repro.kernels.factory import get_kernel
        chain._consolidate()
        nblk = times.shape[0]
        ptr0 = chain._ptr
        stops = np.asarray(get_kernel("block_pack")(
            chain._tmax[: chain._n], chain._gcum[: chain._n], times,
            n_vis, chain.block_gas_limit, ptr0), np.int64)
        starts = np.concatenate([[ptr0], stops[:-1]])
        if chain._n:
            gend = np.where(stops > 0,
                            chain._gcum[np.maximum(stops - 1, 0)], 0)
            gprev = np.where(starts > 0,
                             chain._gcum[np.maximum(starts - 1, 0)], 0)
            gas_used = np.where(stops > starts, gend - gprev, 0)
        else:                                  # empty mempool: empty blocks
            gas_used = np.zeros(nblk, np.int64)
        ntx = stops - starts
        final = int(stops[-1])
        if final > ptr0:
            chain._confirm[ptr0:final] = np.repeat(times, ntx)
        dispatch = bool(chain._batch_handlers or chain._state_handlers)
        assert chain.quorum(chain.n_validators - chain.n_validators // 3)
        height0 = len(chain.blocks)
        parent = chain.blocks[-1].block_hash
        for b in range(nblk):
            lo, hi = int(starts[b]), int(stops[b])
            if dispatch and hi > lo:
                self._dispatch_handlers(lo, hi)
            blk = BlockStats(height0 + b, float(times[b]), int(ntx[b]),
                             int(gas_used[b]), lo, hi, parent)
            parent = blk.block_hash
            chain.blocks.append(blk)
        chain.total_gas += int(gas_used.sum())
        chain._ptr = final
        self._splice_block_events(times, ntx, gas_used, height0, markers)

    def _dispatch_handlers(self, lo: int, hi: int) -> None:
        """Per-(block, fn) handler dispatch — produce_block's contract,
        on one deferred block's confirmed slice."""
        chain = self.chain
        counts = np.bincount(chain._f[lo:hi], minlength=len(chain.fns))
        view = TxArrays(chain._t[lo:hi], chain._g[lo:hi],
                        chain._f[lo:hi], chain._s[lo:hi], chain.fns)
        for fid, h in chain._batch_handlers.items():
            if fid < counts.shape[0] and counts[fid]:
                h(chain.state, int(counts[fid]), view)
        for fid, h in chain._state_handlers.items():
            if fid < counts.shape[0] and counts[fid]:
                m = view.fn_id == fid
                h(chain.state_arrays,
                  TxArrays(view.submit_time[m], view.gas[m],
                           view.fn_id[m], view.sender_id[m], chain.fns))

    def _splice_block_events(self, times, ntx, gas_used, height0,
                             markers) -> None:
        """Rebuild the typed stream with BlockPacked events at the
        positions the stepped path emitted them, renumbering ``seq``."""
        chain = self.chain
        evs = chain.events._events
        merged: List[Any] = []
        prev = 0
        for pos, blo, bn in markers:
            merged.extend(evs[prev:pos])
            prev = pos
            for b in range(blo, blo + bn):
                blk = chain.blocks[height0 + b]
                merged.append(BlockPacked(
                    seq=-1, time=float(times[b]), shard=None,
                    height=blk.height, n_txs=int(ntx[b]),
                    gas_used=int(gas_used[b]), block_hash=blk.block_hash))
                chain._emit("block_packed", {
                    "height": blk.height, "n_txs": int(ntx[b]),
                    "gas_used": int(gas_used[b]),
                    "block_hash": blk.block_hash})
        merged.extend(evs[prev:])
        # in-place seq renumber: the log owns its event objects and no
        # cursor has advanced past a splice point (clients drained before
        # the run started), so mutating seq is unobservable
        for i, e in enumerate(merged):
            if e.seq != i:
                object.__setattr__(e, "seq", i)
        evs[:] = merged
