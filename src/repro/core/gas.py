"""EVM-style gas cost model, calibrated against paper Table I.

L1: every call costs a fixed per-function gas (storage writes + compute).
L2 (zk-rollup): per batch of up to ROLLUP_BATCH calls,
    commit  = base_f + n_calls * percall_f     (calldata posted to L1)
    verify  ~ constant (one SNARK verification per submission)
    execute ~ constant (state-root update)

Calibration (least-squares on Table I rows):
    function              L1/call   commit_base  commit/call
    publishTask           182186       39385        4383
    submitLocalModel       50222       37078        1502
    calculateObjectiveRep  53163       36495         233
    calculateSubjectiveRep 39259       35850          34
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict

ROLLUP_BATCH = 20

FUNCTIONS = ("publishTask", "submitLocalModel",
             "calculateObjectiveRep", "calculateSubjectiveRep")


@dataclasses.dataclass(frozen=True)
class GasTable:
    # L1 is affine in n (cold-storage premium on the first call, then a
    # constant marginal cost — fits Table I's 5-call and 100-call rows):
    #   l1_total(n) = l1_first_extra + n * l1_marginal
    l1_per_call: Dict[str, int]      # 5-call average (drives the chain sim)
    l1_marginal: Dict[str, int]
    l1_first_extra: Dict[str, int]
    commit_base: Dict[str, int]
    commit_per_call: Dict[str, int]
    verify_single: int = 27272
    verify_multi: int = 29900
    execute_single: int = 23964
    execute_multi: int = 26600


DEFAULT_GAS = GasTable(
    l1_per_call={
        "publishTask": 182186,
        "submitLocalModel": 50222,
        "calculateObjectiveRep": 53163,
        "calculateSubjectiveRep": 39259,
    },
    l1_marginal={
        "publishTask": 177113,
        "submitLocalModel": 40890,
        "calculateObjectiveRep": 42457,
        "calculateSubjectiveRep": 35025,
    },
    l1_first_extra={
        "publishTask": 25366,
        "submitLocalModel": 46658,
        "calculateObjectiveRep": 53530,
        "calculateSubjectiveRep": 21171,
    },
    commit_base={
        "publishTask": 39385,
        "submitLocalModel": 37078,
        "calculateObjectiveRep": 36495,
        "calculateSubjectiveRep": 35850,
    },
    commit_per_call={
        "publishTask": 4383,
        "submitLocalModel": 1502,
        "calculateObjectiveRep": 233,
        "calculateSubjectiveRep": 34,
    },
)


def l1_gas(fn: str, n_calls: int, table: GasTable = DEFAULT_GAS) -> int:
    return table.l1_first_extra[fn] + table.l1_marginal[fn] * n_calls


def n_batches(n_calls: int) -> int:
    return max(1, math.ceil(n_calls / ROLLUP_BATCH))


def l2_gas(fn: str, n_calls: int, table: GasTable = DEFAULT_GAS) -> Dict[str, int]:
    nb = n_batches(n_calls)
    commit = nb * table.commit_base[fn] + n_calls * table.commit_per_call[fn]
    verify = table.verify_single if nb == 1 and n_calls <= 5 else table.verify_multi
    execute = table.execute_single if nb == 1 and n_calls <= 5 else table.execute_multi
    return {"batches": nb, "commit": commit, "verify": verify,
            "execute": execute, "total": commit + verify + execute}


def gas_reduction(fn: str, n_calls: int, table: GasTable = DEFAULT_GAS) -> float:
    return l1_gas(fn, n_calls, table) / l2_gas(fn, n_calls, table)["total"]


# -- vectorized views (SoA engine, core/engine.py) ------------------------------
L1_DEFAULT_GAS = 30_000          # unknown-fn fallback, matches fl/server.py
COMMIT_BASE_DEFAULT = 37_000     # unknown-fn fallbacks, match Rollup._settle
COMMIT_PER_CALL_DEFAULT = 500


def l1_gas_vector(fn_names, table: GasTable = DEFAULT_GAS):
    """Per-fn L1 gas as an int64 array indexable by engine fn_id."""
    import numpy as np
    return np.array([table.l1_per_call.get(n, L1_DEFAULT_GAS)
                     for n in fn_names], np.int64)


def commit_gas_vectors(fn_names, table: GasTable = DEFAULT_GAS):
    """(commit_base, commit_per_call) int64 arrays indexable by fn_id."""
    import numpy as np
    base = np.array([table.commit_base.get(n, COMMIT_BASE_DEFAULT)
                     for n in fn_names], np.int64)
    percall = np.array([table.commit_per_call.get(n, COMMIT_PER_CALL_DEFAULT)
                        for n in fn_names], np.int64)
    return base, percall
