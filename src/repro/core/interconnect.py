"""Interconnect model: per-link latency/bandwidth for the sharded fabric.

The ``ShardedRollup`` fabric (core/shards.py) moves three kinds of bytes
between participants that, on real deployments, sit on different machines:

  * **shard -> L1**: per-window root gathering — each shard ships its
    partition root (and its sealed-batch commit metadata) to the L1
    aggregator that merges the fabric root;
  * **shard <-> shard**: cross-shard settlement — the end-of-window
    ``sync_book_to_state`` scatter writes reputation/balance/stake rows
    that span every shard's state partition;
  * **cohort -> shard**: trainer cohorts submitting protocol transactions
    into their task's pinned shard.

A single host simulates all of that with memcpy, so the modeled fabric
wall-clock would silently pretend wires are free.  ``Interconnect``
makes the wire cost explicit: every link is a ``LinkSpec`` (fixed
latency + bandwidth), every logical transfer is accounted as

    transfer_time(bytes) = latency_s + bytes / bandwidth_Bps

and concurrent same-window transfers over DISTINCT links overlap (the
fabric charges the max, mirroring how shard lanes overlap in
``ShardedRollup.latency``), while transfers over one link serialize
(sum).  ``benchmarks/bench_shards.py`` folds these costs into the
measured wall-clock scaling section as the honest latency decomposition:
``root_gather_s`` + ``settle_scatter_s`` per window on top of the
measured per-lane seal walls.

The accounting is deterministic — byte counts derive from tx/row counts,
never from timers — so fused and stepped runs of one schedule record the
same transfers (per-kind sequences and totals match bit-for-bit; only
the interleaving differs, because the fused loop defers window merges to
``execute()``), and CI can assert on the decomposition.  The model
NEVER feeds back into ``ShardedRollup.latency`` / ``throughput`` (the
Table-II modeled numbers stay calibrated against the paper); it is a
parallel ledger of what crossing the fabric would cost.

Defaults approximate a single-datacenter deployment (100us, 10 Gbit/s
links); ``repro.api.ShardSpec(interconnect=...)`` overrides per node.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Tuple

#: bytes per transaction on the wire: the SoA word buffer's 4 u32 words
#: (time, gas, fn, sender — core/engine.TxArrays.word_buffer)
TX_WIRE_BYTES = 16
#: bytes per shipped root: a 32-hex-char commitment + framing
ROOT_WIRE_BYTES = 64
#: bytes per scattered state row: ids + reputation + balance + stake
#: (i64 + f32 + f64 + f64, padded to a wire word)
STATE_ROW_WIRE_BYTES = 32


@dataclasses.dataclass(frozen=True)
class LinkSpec:
    """One directed link class: fixed latency + bandwidth."""

    latency_s: float = 100e-6           # same-DC RTT/2
    bandwidth_Bps: float = 1.25e9       # 10 Gbit/s

    def __post_init__(self):
        if self.latency_s < 0:
            raise ValueError("link latency must be >= 0")
        if self.bandwidth_Bps <= 0:
            raise ValueError("link bandwidth must be > 0")

    def transfer_time(self, n_bytes: int) -> float:
        """Seconds to move ``n_bytes`` over this link."""
        return self.latency_s + n_bytes / self.bandwidth_Bps


@dataclasses.dataclass(frozen=True)
class InterconnectSpec:
    """The fabric's three link classes (see module docstring)."""

    shard_l1: LinkSpec = LinkSpec()
    shard_shard: LinkSpec = LinkSpec()
    cohort_shard: LinkSpec = LinkSpec()

    def build(self, n_shards: int) -> "Interconnect":
        return Interconnect(self, n_shards)


class Interconnect:
    """Deterministic wire-cost accumulator for one fabric instance.

    Three recording entry points, one per traffic class; each returns the
    modeled seconds the transfer would take, and appends a wire-log row.
    ``window_cost`` folds one window's transfers the way the fabric
    overlaps them: per-shard transfers over distinct links take the max,
    the L1-side merge serializes after the slowest gather.
    """

    def __init__(self, spec: InterconnectSpec, n_shards: int):
        self.spec = spec
        self.n_shards = n_shards
        self.log: List[Dict[str, Any]] = []
        self.totals = {"root_gather_s": 0.0, "settle_scatter_s": 0.0,
                       "submit_s": 0.0, "bytes": 0}

    # -- per-transfer recording ------------------------------------------------
    def record_root_gather(self, window: int,
                           shard_batches: List[int]) -> float:
        """One window's root gather: every shard ships its partition root
        plus one commit record per sealed batch to the L1 merger over its
        own shard->L1 link (distinct links overlap -> max), and the L1
        folds the K roots serially (K * latency on the merge side)."""
        link = self.spec.shard_l1
        per_shard = [link.transfer_time(
            ROOT_WIRE_BYTES + ROOT_WIRE_BYTES * int(nb))
            for nb in shard_batches]
        gather = max(per_shard, default=0.0)
        merge = self.n_shards * link.latency_s
        cost = gather + merge
        n_bytes = sum(ROOT_WIRE_BYTES + ROOT_WIRE_BYTES * int(nb)
                      for nb in shard_batches)
        self.log.append({"kind": "root_gather", "window": window,
                         "bytes": n_bytes, "cost_s": cost})
        self.totals["root_gather_s"] += cost
        self.totals["bytes"] += n_bytes
        return cost

    def record_settle_scatter(self, n_rows: int) -> float:
        """Cross-shard settlement scatter: ``n_rows`` state rows fan out
        over the shard<->shard mesh.  Rows split evenly across the K
        destination partitions (account_owner is uniform over ids); the
        K per-destination writes overlap -> the cost is the slowest
        (ceil) share's transfer."""
        link = self.spec.shard_shard
        share = -(-int(n_rows) // max(self.n_shards, 1))
        cost = link.transfer_time(STATE_ROW_WIRE_BYTES * share) \
            if n_rows else 0.0
        n_bytes = STATE_ROW_WIRE_BYTES * int(n_rows)
        self.log.append({"kind": "settle_scatter", "rows": int(n_rows),
                         "bytes": n_bytes, "cost_s": cost})
        self.totals["settle_scatter_s"] += cost
        self.totals["bytes"] += n_bytes
        return cost

    def record_submit(self, shard_tx_counts) -> float:
        """Cohort->shard submission: per-tx wire bytes over each target
        shard's cohort link; distinct shard links overlap -> max."""
        link = self.spec.cohort_shard
        costs = [link.transfer_time(TX_WIRE_BYTES * int(c))
                 for c in shard_tx_counts if c]
        cost = max(costs, default=0.0)
        n_bytes = TX_WIRE_BYTES * int(sum(int(c) for c in shard_tx_counts))
        self.log.append({"kind": "submit", "bytes": n_bytes,
                         "cost_s": cost})
        self.totals["submit_s"] += cost
        self.totals["bytes"] += n_bytes
        return cost

    # -- summaries ---------------------------------------------------------------
    def window_costs(self) -> List[Tuple[int, float]]:
        """(window, root_gather cost) per recorded window, in order."""
        return [(r["window"], r["cost_s"]) for r in self.log
                if r["kind"] == "root_gather"]

    def summary(self) -> Dict[str, Any]:
        """JSON-friendly totals for the benchmark decomposition."""
        return {
            "n_transfers": len(self.log),
            "total_bytes": int(self.totals["bytes"]),
            "root_gather_s": round(self.totals["root_gather_s"], 6),
            "settle_scatter_s": round(self.totals["settle_scatter_s"], 6),
            "submit_s": round(self.totals["submit_s"], 6),
            "wire_s": round(self.totals["root_gather_s"]
                            + self.totals["settle_scatter_s"]
                            + self.totals["submit_s"], 6),
        }
