"""L1 permissioned-chain simulator: accounts/roles, mempool, QBFT quorum,
gas-limited blocks.  Drives the paper's Fig. 4 (throughput/latency vs send
rate) and backs the FL task lifecycle (core/tasks.py).

The simulation is discrete-event over block boundaries: transactions arrive
with timestamps, wait in the mempool, and are packed FIFO into blocks subject
to the block gas limit.  Latency = confirmation_time - submit_time.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from collections import deque
from typing import (Any, Callable, Dict, List, Optional, Protocol,
                    runtime_checkable)

import numpy as np

from repro.core.events import BlockPacked, EventLog
from repro.core.gas import DEFAULT_GAS, GasTable

ROLES = ("admin", "task_publisher", "trainer", "evaluator", "aggregator",
         "validator", "oracle")


@runtime_checkable
class LedgerBackend(Protocol):
    """The one surface all four ledger faces share.

    ``Chain``/``Rollup`` (object path, this module + core/rollup.py) and
    ``VectorChain``/``VectorRollup`` (SoA path, core/engine.py) — plus the
    sharded fabric (core/shards.py) — all satisfy this protocol, so
    protocol code (fl/server.py, fl/scheduler.py) is written once:

      * ``submit(tx)`` / ``submit_arrays(batch)`` — object-Tx and SoA
        ingestion (the object faces lift ``TxArrays`` row-by-row; the SoA
        faces lift single ``Tx`` objects through a shim).
      * ``sender_id(name)`` — the backend's stable sender namespace;
        account ids index ``StateArrays`` rows directly.
      * ``register_state(fn, handler)`` — attach a handler written against
        ``(StateArrays, TxArrays-view)``; each backend adapts its own
        execution granularity (per block, per batch, or per tx — the
        object path is a thin 1-row-view adapter), with the view holding
        only the registered function's transactions in confirmation order.
      * ``state_root()`` — the chunked array-native commitment over the
        attached ``StateArrays`` (core/state.py), or "" when no SoA state
        is attached.
    """

    def submit(self, tx) -> None: ...
    def submit_arrays(self, batch) -> None: ...
    def sender_id(self, sender: str) -> int: ...
    def register_state(self, fn: str, handler: Callable) -> None: ...
    def state_root(self) -> str: ...


class EventHooks:
    """Legacy string-keyed callback plumbing (``Rollup``,
    ``engine.VectorRollup``; the sharded fabric overrides ``subscribe``
    to forward per-shard but reuses ``_emit``; the chains override
    ``EVENTS`` with their block vocabulary).

    One-release deprecation shim: the supported surface is the typed
    event stream (core/events.py) drained through
    ``repro.api.NodeClient.events()`` — the emission sites feed both.

    Subclasses call ``_init_events()`` from ``__init__`` and ``_emit``
    at the event sites; the event vocabulary lives here once.
    """

    EVENTS = ("batch_sealed", "session_settled")

    def _init_events(self):
        self._subs: Dict[str, List[Callable]] = {}

    def subscribe(self, event: str, callback: Callable) -> None:
        """Register ``callback(payload)`` for ``"batch_sealed"`` (once
        per seal, covering all batches sealed together) or
        ``"session_settled"`` (once per amortized verify/execute)."""
        assert event in self.EVENTS, event
        self._subs.setdefault(event, []).append(callback)

    def _emit(self, event: str, payload: Dict[str, Any]) -> None:
        for cb in self._subs.get(event, ()):
            cb(payload)


def lift_tx_rows(txs, fns, sender_ids: List[int]):
    """Object->SoA adapter: one ``TxArrays`` over object ``Tx`` rows, with
    sender ids resolved in the TARGET's namespace (``TxArrays.from_txs``
    would mint a private namespace and misalign ``StateArrays`` rows)."""
    from repro.core.engine import TxArrays
    return TxArrays(
        np.array([t.submit_time for t in txs], np.float64),
        np.array([t.gas for t in txs], np.int64),
        np.array([fns.id(t.fn) for t in txs], np.int32),
        np.array(sender_ids, np.int32), fns)


class ObjectLedgerFace:
    """Shared object-face LedgerBackend plumbing for ``Chain`` and
    ``rollup.Rollup``: ONE sender/account namespace, the id-pinning
    SoA-lowering adapter, and the StateArrays handler bootstrap — the
    invariants live here exactly once, so the two faces cannot diverge.

    Subclasses provide ``submit(tx)`` and call ``_init_object_face()``
    from ``__init__``."""

    def _init_object_face(self):
        # SoA state + handlers written once against (StateArrays,
        # TxArrays-view); the object faces are thin adapters that lift
        # each executed/confirmed Tx into a 1-row view.
        self.state_arrays = None
        self._state_handlers: Dict[str, Callable] = {}
        self._sender_ids: Dict[str, int] = {}
        self._sender_names: Dict[int, str] = {}

    def sender_id(self, sender: str) -> int:
        """Stable sender-name -> id mapping (StateArrays row index)."""
        sid = self._sender_ids.setdefault(sender, len(self._sender_ids))
        self._sender_names.setdefault(sid, sender)
        return sid

    def _sender_name(self, sid: int) -> str:
        """Reverse id -> name, PINNING unknown ids so that a later
        ``sender_id`` round-trips to the same id — lowering a SoA batch
        must not re-mint ids or state handlers would scatter to the wrong
        StateArrays rows (same-root-on-every-face contract)."""
        name = self._sender_names.get(sid)
        if name is None:
            name = f"__acct{sid}"
            assert self._sender_ids.setdefault(name, sid) == sid
            self._sender_names[sid] = name
        return name

    def register_state(self, fn: str, handler: Callable):
        """Attach a StateArrays handler (see LedgerBackend).  Lazily
        creates the SoA state on first registration."""
        if self.state_arrays is None:
            from repro.core.state import StateArrays
            self.state_arrays = StateArrays()
            self.state_arrays.enable_dirty_tracking()
        self._state_handlers[fn] = handler

    def state_root(self) -> str:
        return self.state_arrays.root() if self.state_arrays is not None \
            else ""

    def _state_fns(self):
        from repro.core.engine import FnRegistry
        if not hasattr(self, "_fns_cache"):
            self._fns_cache = FnRegistry()
        return self._fns_cache

    def _apply_state_tx(self, tx: Tx):
        """1-row-view adapter: run the fn's StateArrays handler for one
        executed/confirmed object Tx."""
        handler = self._state_handlers.get(tx.fn)
        if handler is not None:
            handler(self.state_arrays,
                    lift_tx_rows([tx], self._state_fns(),
                                 [self.sender_id(tx.sender)]))

    def submit_arrays(self, batch):
        """SoA ingestion adapter: lower a TxArrays batch to object txs
        (small-N only — the vector engine is the path at scale).  Sender
        ids are preserved, not re-minted (see ``_sender_name``).  Returns
        the lowered ``Tx`` objects (the object path's provenance handles,
        the analogue of the vector faces' index/sequence ranges)."""
        txs = [Tx(batch.fns.names[batch.fn_id[i]],
                  self._sender_name(int(batch.sender_id[i])), {},
                  int(batch.gas[i]), float(batch.submit_time[i]))
               for i in range(len(batch))]
        for tx in txs:
            self.submit(tx)
        return txs


@dataclasses.dataclass
class Tx:
    fn: str
    sender: str
    payload: Dict[str, Any]
    gas: int
    submit_time: float
    tx_id: str = ""
    confirm_time: Optional[float] = None
    block_height: Optional[int] = None    # set when packed into an L1 block

    def __post_init__(self):
        if not self.tx_id:
            h = hashlib.sha256(
                json.dumps([self.fn, self.sender, self.submit_time,
                            sorted(self.payload.items(), key=str)],
                           default=str).encode()).hexdigest()
            self.tx_id = h[:16]


@dataclasses.dataclass
class Block:
    height: int
    time: float
    txs: List[Tx]
    gas_used: int
    parent: str
    block_hash: str = ""

    def __post_init__(self):
        if not self.block_hash:
            h = hashlib.sha256(
                (self.parent + str(self.height) +
                 "".join(t.tx_id for t in self.txs)).encode()).hexdigest()
            self.block_hash = h[:16]


class AccessControl:
    """ASC: role-based permissioning with admin majority voting (Sybil /
    whitewashing mitigation — only the consortium can add or re-add users)."""

    def __init__(self, admins: List[str]):
        self.admins = set(admins)
        self.roles: Dict[str, set] = {a: {"admin"} for a in admins}
        self.banned: set = set()
        self._votes: Dict[str, set] = {}

    def grant(self, admin: str, user: str, role: str):
        assert admin in self.admins, "only admins grant roles"
        assert role in ROLES, role
        if user in self.banned:
            raise PermissionError("banned identity: consortium vote required")
        self.roles.setdefault(user, set()).add(role)

    def has_role(self, user: str, role: str) -> bool:
        return role in self.roles.get(user, ())

    def ban(self, admin: str, user: str):
        assert admin in self.admins
        self.banned.add(user)
        self.roles.pop(user, None)

    def vote_readmit(self, admin: str, user: str) -> bool:
        """Whitewashing guard: majority admin vote to re-admit.

        Self-votes are rejected: a banned admin (ban removes roles but not
        consortium membership) must not count toward their own quorum.
        Votes are a set per user, so double-voting is idempotent; the
        quorum is a strict majority (2-of-3 passes, 2-of-4 does not).
        """
        assert admin in self.admins
        if admin == user:
            raise PermissionError("self-readmission vote rejected")
        self._votes.setdefault(user, set()).add(admin)
        if len(self._votes[user]) * 2 > len(self.admins):
            self.banned.discard(user)
            del self._votes[user]
            return True
        return False


class Chain(ObjectLedgerFace, EventHooks):
    """Gas-limited block production with a QBFT-style quorum check."""

    EVENTS = ("block_packed",)

    def __init__(self, n_validators: int = 4, block_time: float = 1.0,
                 block_gas_limit: int = 9_000_000,
                 gas_table: GasTable = DEFAULT_GAS):
        assert n_validators >= 4, "QBFT needs >= 3f+1 with f >= 1"
        self.n_validators = n_validators
        self.block_time = block_time
        self.block_gas_limit = block_gas_limit
        self.gas_table = gas_table
        self.mempool: deque[Tx] = deque()
        self.blocks: List[Block] = [Block(0, 0.0, [], 0, "genesis")]
        self.state: Dict[str, Any] = {}
        self._handlers: Dict[str, Callable] = {}
        self.total_gas = 0
        # the stack-wide typed event stream: the L1 owns it, every L2
        # face built on this chain adopts the same log (core/events.py)
        self.events = EventLog()
        self._init_events()
        self._init_object_face()

    # -- contract surface ------------------------------------------------------
    def register(self, fn: str, handler: Callable):
        self._handlers[fn] = handler

    def submit(self, tx: Tx):
        self.mempool.append(tx)

    def quorum(self, approvals: int) -> bool:
        return 3 * approvals >= 2 * self.n_validators

    # -- block production ---------------------------------------------------------
    def produce_block(self, now: float) -> Block:
        """Pack one block at time ``now``.

        FIFO head-of-line semantics (intentional, mirrored bit-for-bit by
        engine.VectorChain): the mempool is walked in *submission* order and
        packing stops at the first tx whose ``submit_time`` is in the future
        or whose gas would overflow the block — later txs are never skipped
        ahead.  A future-timestamped tx submitted out of order therefore
        stalls everything behind it; producers (simulate_load, Workload)
        guard against that skew by submitting in sorted time order.
        """
        txs, gas_used = [], 0
        height = len(self.blocks)
        while self.mempool:
            tx = self.mempool[0]
            if tx.submit_time > now:
                break
            if gas_used + tx.gas > self.block_gas_limit:
                break
            self.mempool.popleft()
            handler = self._handlers.get(tx.fn)
            if handler is not None:
                handler(self.state, tx)
            if self._state_handlers:
                self._apply_state_tx(tx)
            tx.confirm_time = now
            tx.block_height = height
            txs.append(tx)
            gas_used += tx.gas
        # QBFT: 2/3 of validators sign; honest-majority assumption of the paper
        assert self.quorum(self.n_validators - self.n_validators // 3)
        blk = Block(height, now, txs, gas_used,
                    self.blocks[-1].block_hash)
        self.blocks.append(blk)
        self.total_gas += gas_used
        self.events.emit(BlockPacked, time=now, height=blk.height,
                         n_txs=len(txs), gas_used=gas_used,
                         block_hash=blk.block_hash)
        self._emit("block_packed", {"height": blk.height, "n_txs": len(txs),
                                    "gas_used": gas_used,
                                    "block_hash": blk.block_hash})
        return blk

    def run_until(self, t_end: float):
        t = self.blocks[-1].time
        while t < t_end:
            t += self.block_time
            self.produce_block(t)


def _resolve_chain_spec(spec, engine, block_time, block_gas_limit,
                        gas_table):
    """spec wins and is exclusive; the loose kwargs (incl. the deprecated
    ``engine=`` string flag) fold into a ChainSpec otherwise."""
    from repro.api.specs import ChainSpec
    if spec is not None:
        if not (engine is None and block_time is None
                and block_gas_limit is None and gas_table is None):
            raise ValueError(
                "pass either spec= or the loose chain kwargs, not both")
        return spec
    if engine is not None:
        import warnings
        warnings.warn("engine= is deprecated; pass "
                      "spec=repro.api.ChainSpec(backend=...) "
                      "(see docs/MIGRATION.md)", DeprecationWarning,
                      stacklevel=3)
    return ChainSpec(backend=engine or "vector",
                     block_time=1.0 if block_time is None else block_time,
                     block_gas_limit=(9_000_000 if block_gas_limit is None
                                      else block_gas_limit),
                     gas_table=gas_table if gas_table is not None
                     else DEFAULT_GAS)


def simulate_load(fn: str, send_rate: float, duration: float = 30.0,
                  gas_table: Optional[GasTable] = None, seed: int = 0,
                  block_time: Optional[float] = None,
                  block_gas_limit: Optional[int] = None,
                  engine: Optional[str] = None, *,
                  spec=None) -> Dict[str, float]:
    """Fig. 4 experiment: constant send rate of one function type.

    The chain is described by ``spec`` (an ``repro.api.ChainSpec``;
    defaults to the vector backend).  ``spec.backend="vector"`` runs the
    SoA engine (engine.VectorChain); ``"object"`` this module's per-Tx
    path.  Both draw the same arrival times from the same rng stream and
    implement identical FIFO packing semantics, so the metrics are
    numerically identical (pinned by tests/test_engine.py); times are
    pre-sorted as the head-of-line guard documented on
    ``Chain.produce_block``.  ``engine=`` is the deprecated string form.
    """
    spec = _resolve_chain_spec(spec, engine, block_time, block_gas_limit,
                               gas_table)
    from repro.api.factory import build_chain
    rng = np.random.default_rng(seed)
    n = int(send_rate * duration)
    times = np.sort(rng.uniform(0.0, duration, n))
    gas = spec.gas_table.l1_per_call[fn]
    chain = build_chain(spec)
    if spec.backend == "vector":
        from repro.core.engine import TxArrays
        chain.submit_arrays(TxArrays.homogeneous(fn, times, gas))
        chain.run_until(duration)
        return chain.load_metrics(send_rate, duration)
    for i, t in enumerate(times):
        chain.submit(Tx(fn, f"client{i % 64}", {}, gas, float(t)))
    # run long enough to drain what can be drained, then measure
    chain.run_until(duration)
    confirmed = [t for b in chain.blocks for t in b.txs
                 if t.confirm_time is not None]
    if not confirmed:
        return {"send_rate": send_rate, "throughput": 0.0, "latency": 0.0,
                "confirmed": 0, "submitted": n}
    thr = len(confirmed) / duration
    lat = float(np.mean([t.confirm_time - t.submit_time for t in confirmed]))
    return {"send_rate": send_rate, "throughput": thr, "latency": lat,
            "confirmed": len(confirmed), "submitted": n}


def simulate_workload(workload, block_time: Optional[float] = None,
                      block_gas_limit: Optional[int] = None,
                      gas_table: Optional[GasTable] = None,
                      engine: Optional[str] = None, *,
                      spec=None) -> Dict[str, float]:
    """Run a workloads.Workload scenario (or an ``repro.api.WorkloadSpec``)
    through the spec'd chain and report the Fig. 4-style metrics."""
    spec = _resolve_chain_spec(spec, engine, block_time, block_gas_limit,
                               gas_table)
    if hasattr(workload, "build"):          # WorkloadSpec -> Workload
        workload = workload.build()
    duration = workload.duration
    from repro.api.factory import build_chain
    if spec.backend == "vector":
        chain = build_chain(spec, fns=workload.txs.fns)
        chain.submit_arrays(workload.txs)
        chain.run_until(duration)
        m = chain.load_metrics(len(workload) / max(duration, 1e-9), duration)
    else:
        chain = build_chain(spec)
        for t in workload.to_txs():
            chain.submit(t)
        chain.run_until(duration)
        confirmed = [t for b in chain.blocks for t in b.txs
                     if t.confirm_time is not None]
        lat = (float(np.mean([t.confirm_time - t.submit_time
                              for t in confirmed])) if confirmed else 0.0)
        m = {"send_rate": len(workload) / max(duration, 1e-9),
             "throughput": len(confirmed) / duration, "latency": lat,
             "confirmed": len(confirmed), "submitted": len(workload)}
    m["scenario"] = workload.name
    return m
