"""L1 permissioned-chain simulator: accounts/roles, mempool, QBFT quorum,
gas-limited blocks.  Drives the paper's Fig. 4 (throughput/latency vs send
rate) and backs the FL task lifecycle (core/tasks.py).

The simulation is discrete-event over block boundaries: transactions arrive
with timestamps, wait in the mempool, and are packed FIFO into blocks subject
to the block gas limit.  Latency = confirmation_time - submit_time.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from collections import deque
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from repro.core.gas import DEFAULT_GAS, GasTable

ROLES = ("admin", "task_publisher", "trainer", "evaluator", "aggregator",
         "validator", "oracle")


@dataclasses.dataclass
class Tx:
    fn: str
    sender: str
    payload: Dict[str, Any]
    gas: int
    submit_time: float
    tx_id: str = ""
    confirm_time: Optional[float] = None

    def __post_init__(self):
        if not self.tx_id:
            h = hashlib.sha256(
                json.dumps([self.fn, self.sender, self.submit_time,
                            sorted(self.payload.items(), key=str)],
                           default=str).encode()).hexdigest()
            self.tx_id = h[:16]


@dataclasses.dataclass
class Block:
    height: int
    time: float
    txs: List[Tx]
    gas_used: int
    parent: str
    block_hash: str = ""

    def __post_init__(self):
        if not self.block_hash:
            h = hashlib.sha256(
                (self.parent + str(self.height) +
                 "".join(t.tx_id for t in self.txs)).encode()).hexdigest()
            self.block_hash = h[:16]


class AccessControl:
    """ASC: role-based permissioning with admin majority voting (Sybil /
    whitewashing mitigation — only the consortium can add or re-add users)."""

    def __init__(self, admins: List[str]):
        self.admins = set(admins)
        self.roles: Dict[str, set] = {a: {"admin"} for a in admins}
        self.banned: set = set()
        self._votes: Dict[str, set] = {}

    def grant(self, admin: str, user: str, role: str):
        assert admin in self.admins, "only admins grant roles"
        assert role in ROLES, role
        if user in self.banned:
            raise PermissionError("banned identity: consortium vote required")
        self.roles.setdefault(user, set()).add(role)

    def has_role(self, user: str, role: str) -> bool:
        return role in self.roles.get(user, ())

    def ban(self, admin: str, user: str):
        assert admin in self.admins
        self.banned.add(user)
        self.roles.pop(user, None)

    def vote_readmit(self, admin: str, user: str) -> bool:
        """Whitewashing guard: majority admin vote to re-admit."""
        assert admin in self.admins
        self._votes.setdefault(user, set()).add(admin)
        if len(self._votes[user]) * 2 > len(self.admins):
            self.banned.discard(user)
            del self._votes[user]
            return True
        return False


class Chain:
    """Gas-limited block production with a QBFT-style quorum check."""

    def __init__(self, n_validators: int = 4, block_time: float = 1.0,
                 block_gas_limit: int = 9_000_000,
                 gas_table: GasTable = DEFAULT_GAS):
        assert n_validators >= 4, "QBFT needs >= 3f+1 with f >= 1"
        self.n_validators = n_validators
        self.block_time = block_time
        self.block_gas_limit = block_gas_limit
        self.gas_table = gas_table
        self.mempool: deque[Tx] = deque()
        self.blocks: List[Block] = [Block(0, 0.0, [], 0, "genesis")]
        self.state: Dict[str, Any] = {}
        self._handlers: Dict[str, Callable] = {}
        self.total_gas = 0

    # -- contract surface ------------------------------------------------------
    def register(self, fn: str, handler: Callable):
        self._handlers[fn] = handler

    def submit(self, tx: Tx):
        self.mempool.append(tx)

    def quorum(self, approvals: int) -> bool:
        return 3 * approvals >= 2 * self.n_validators

    # -- block production ---------------------------------------------------------
    def produce_block(self, now: float) -> Block:
        """Pack one block at time ``now``.

        FIFO head-of-line semantics (intentional, mirrored bit-for-bit by
        engine.VectorChain): the mempool is walked in *submission* order and
        packing stops at the first tx whose ``submit_time`` is in the future
        or whose gas would overflow the block — later txs are never skipped
        ahead.  A future-timestamped tx submitted out of order therefore
        stalls everything behind it; producers (simulate_load, Workload)
        guard against that skew by submitting in sorted time order.
        """
        txs, gas_used = [], 0
        while self.mempool:
            tx = self.mempool[0]
            if tx.submit_time > now:
                break
            if gas_used + tx.gas > self.block_gas_limit:
                break
            self.mempool.popleft()
            handler = self._handlers.get(tx.fn)
            if handler is not None:
                handler(self.state, tx)
            tx.confirm_time = now
            txs.append(tx)
            gas_used += tx.gas
        # QBFT: 2/3 of validators sign; honest-majority assumption of the paper
        assert self.quorum(self.n_validators - self.n_validators // 3)
        blk = Block(len(self.blocks), now, txs, gas_used,
                    self.blocks[-1].block_hash)
        self.blocks.append(blk)
        self.total_gas += gas_used
        return blk

    def run_until(self, t_end: float):
        t = self.blocks[-1].time
        while t < t_end:
            t += self.block_time
            self.produce_block(t)


def simulate_load(fn: str, send_rate: float, duration: float = 30.0,
                  gas_table: GasTable = DEFAULT_GAS, seed: int = 0,
                  block_time: float = 1.0,
                  block_gas_limit: int = 9_000_000,
                  engine: str = "vector") -> Dict[str, float]:
    """Fig. 4 experiment: constant send rate of one function type.

    ``engine="vector"`` (default) runs the SoA engine (engine.VectorChain);
    ``engine="object"`` runs this module's per-Tx path.  Both draw the same
    arrival times from the same rng stream and implement identical FIFO
    packing semantics, so the metrics are numerically identical (pinned by
    tests/test_engine.py); times are pre-sorted as the head-of-line guard
    documented on ``Chain.produce_block``.
    """
    rng = np.random.default_rng(seed)
    n = int(send_rate * duration)
    times = np.sort(rng.uniform(0.0, duration, n))
    gas = gas_table.l1_per_call[fn]
    if engine == "vector":
        from repro.core.engine import TxArrays, VectorChain
        chain = VectorChain(block_time=block_time,
                            block_gas_limit=block_gas_limit,
                            gas_table=gas_table)
        chain.submit_arrays(TxArrays.homogeneous(fn, times, gas))
        chain.run_until(duration)
        return chain.load_metrics(send_rate, duration)
    assert engine == "object", f"unknown engine {engine!r}"
    chain = Chain(block_time=block_time, block_gas_limit=block_gas_limit,
                  gas_table=gas_table)
    for i, t in enumerate(times):
        chain.submit(Tx(fn, f"client{i % 64}", {}, gas, float(t)))
    # run long enough to drain what can be drained, then measure
    chain.run_until(duration)
    confirmed = [t for b in chain.blocks for t in b.txs
                 if t.confirm_time is not None]
    if not confirmed:
        return {"send_rate": send_rate, "throughput": 0.0, "latency": 0.0,
                "confirmed": 0, "submitted": n}
    thr = len(confirmed) / duration
    lat = float(np.mean([t.confirm_time - t.submit_time for t in confirmed]))
    return {"send_rate": send_rate, "throughput": thr, "latency": lat,
            "confirmed": len(confirmed), "submitted": n}


def simulate_workload(workload, block_time: float = 1.0,
                      block_gas_limit: int = 9_000_000,
                      gas_table: GasTable = DEFAULT_GAS,
                      engine: str = "vector") -> Dict[str, float]:
    """Run a workloads.Workload scenario through either engine and report
    the Fig. 4-style throughput/latency metrics."""
    duration = workload.duration
    if engine == "vector":
        from repro.core.engine import VectorChain
        chain = VectorChain(block_time=block_time,
                            block_gas_limit=block_gas_limit,
                            gas_table=gas_table, fns=workload.txs.fns)
        chain.submit_arrays(workload.txs)
        chain.run_until(duration)
        m = chain.load_metrics(len(workload) / max(duration, 1e-9), duration)
    else:
        assert engine == "object", f"unknown engine {engine!r}"
        chain = Chain(block_time=block_time,
                      block_gas_limit=block_gas_limit, gas_table=gas_table)
        for t in workload.to_txs():
            chain.submit(t)
        chain.run_until(duration)
        confirmed = [t for b in chain.blocks for t in b.txs
                     if t.confirm_time is not None]
        lat = (float(np.mean([t.confirm_time - t.submit_time
                              for t in confirmed])) if confirmed else 0.0)
        m = {"send_rate": len(workload) / max(duration, 1e-9),
             "throughput": len(confirmed) / duration, "latency": lat,
             "confirmed": len(confirmed), "submitted": len(workload)}
    m["scenario"] = workload.name
    return m
