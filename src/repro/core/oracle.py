"""Decentralized Oracle Network (DON, paper §III-C.5): automated contribution
evaluation and aggregation, off the chain's critical path.

Each oracle node independently scores every trainer's local model on its own
slice of the task publisher's validation set; the network aggregates by
median (robust to a minority of bad-mouthing oracles) and flags outlier
oracles for slashing.  The paper's 2/3-honest assumption maps to the quorum
check.  The same quorum machinery cross-verifies the aggregated global model.

Scoring is vectorized: the O(oracles x trainers) per-call Python loop is
replaced by a batched pass — trainers stacked on a leading axis and scored
with one vmapped ``eval_fn`` call per oracle slice (one double-vmapped call
when the slices are equal-sized).  ``mode="loop"`` keeps the per-call path
for eval_fns that cannot be vmapped; ``mode="auto"`` (default) falls back to
it automatically.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DONConfig:
    n_oracles: int = 5
    outlier_tol: float = 0.15      # |score - median| above this flags oracle
    quorum_frac: float = 2 / 3


def split_validation(val_batch: Dict[str, jnp.ndarray], n_oracles: int):
    """Disjoint per-oracle validation slices (keeps oracles independent)."""
    out = []
    n = len(jax.tree.leaves(val_batch)[0])
    per = max(1, n // n_oracles)
    for i in range(n_oracles):
        sl = slice(i * per, (i + 1) * per if i < n_oracles - 1 else n)
        out.append(jax.tree.map(lambda a: a[sl], val_batch))
    return out


class ValidationSlices:
    """Pre-split (and, when equal-sized, pre-stacked) per-oracle validation
    slices.  Splitting per quorum call costs ~ms of eager slicing on CPU;
    the scheduler round loop evaluates every round, so nodes build this
    once and pass it as ``evaluate_quorum(..., slices=...)``."""

    def __init__(self, val_batch, n_oracles: int):
        self.slices = split_validation(val_batch, n_oracles)
        sizes = {int(jax.tree.leaves(sl)[0].shape[0]) for sl in self.slices}
        self.stacked = (jax.tree.map(lambda *xs: jnp.stack(xs), *self.slices)
                        if len(sizes) == 1 else None)

    def __len__(self) -> int:
        return len(self.slices)


def stack_trainer_params(trainer_params):
    """Lift a list of per-trainer pytrees into one stacked tree (leading
    axis = trainer); a tree that already carries the axis passes through.
    Returns (stacked_tree, n_trainers)."""
    if isinstance(trainer_params, (list, tuple)):
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *trainer_params)
        return stacked, len(trainer_params)
    return trainer_params, int(jax.tree.leaves(trainer_params)[0].shape[0])


_BATCHED_EVAL_CACHE: OrderedDict = OrderedDict()
_BATCHED_EVAL_CACHE_SIZE = 32
_UNBATCHABLE = object()          # cached verdict: eval_fn cannot be vmapped


def _eval_cache_key(eval_fn: Callable):
    """Bound methods are fresh objects on every attribute access — key on
    (instance, underlying function) so repeated lookups hit.  Returns None
    for unhashable callables (no caching)."""
    key = eval_fn
    if hasattr(eval_fn, "__func__") and hasattr(eval_fn, "__self__"):
        key = (eval_fn.__self__, eval_fn.__func__)
    try:
        hash(key)
    except TypeError:
        return None
    return key


def _eval_cache_get(key):
    if key is None:
        return None
    hit = _BATCHED_EVAL_CACHE.get(key)
    if hit is not None:
        _BATCHED_EVAL_CACHE.move_to_end(key)
    return hit


def _eval_cache_put(key, value):
    if key is None:
        return
    _BATCHED_EVAL_CACHE[key] = value
    _BATCHED_EVAL_CACHE.move_to_end(key)
    while len(_BATCHED_EVAL_CACHE) > _BATCHED_EVAL_CACHE_SIZE:
        _BATCHED_EVAL_CACHE.popitem(last=False)


def _batched_eval(eval_fn: Callable):
    """Jitted (cohort-vmapped, oracle x cohort double-vmapped) forms of
    ``eval_fn``, cached per eval_fn so repeated quorum rounds dispatch one
    compiled program instead of re-tracing a fresh vmap every call.

    The jitted wrappers close over eval_fn, so a weak-keyed cache would
    never evict (the value resurrects its key); a small strong-ref LRU
    evicts oldest-first at ``_BATCHED_EVAL_CACHE_SIZE`` entries instead."""
    key = _eval_cache_key(eval_fn)
    hit = _eval_cache_get(key)
    if hit is not None and hit is not _UNBATCHABLE:
        return hit
    fns = (jax.jit(jax.vmap(eval_fn, in_axes=(0, None))),
           jax.jit(jax.vmap(jax.vmap(eval_fn, in_axes=(0, None)),
                            in_axes=(None, 0))))
    if hit is not _UNBATCHABLE:
        # don't clobber a memoized "not batchable" verdict (direct callers
        # only — evaluate_quorum pops the verdict before a forced retry,
        # so its rebuilt wrappers land in the cache via this put)
        _eval_cache_put(key, fns)
    return fns


def _score_table_batched(eval_fn: Callable, stacked,
                         val: ValidationSlices) -> np.ndarray:
    """(n_oracles, n_trainers) score table via vmapped eval_fn calls."""
    score_cohort, score_both = _batched_eval(eval_fn)
    if val.stacked is not None:
        # equal slices: one double-vmapped pass over (oracles, trainers)
        table = score_both(stacked, val.stacked)
    else:
        table = jnp.stack([score_cohort(stacked, sl) for sl in val.slices])
    return np.asarray(table, np.float64)


def _mega_eval(eval_fn: Callable):
    """Jitted task x oracle x trainer TRIPLE-vmapped form of ``eval_fn``
    (the cross-task megastep scoring pass), cached beside the per-task
    wrappers.  Per-trainer independence makes every (task, oracle,
    trainer) cell bit-exact equal to the per-task double-vmap's cell."""
    key = _eval_cache_key(eval_fn)
    mkey = None if key is None else ("mega", key)
    hit = _eval_cache_get(mkey)
    if hit is not None:
        return hit
    fn = jax.jit(jax.vmap(
        jax.vmap(jax.vmap(eval_fn, in_axes=(0, None)), in_axes=(None, 0)),
        in_axes=(0, None)))
    _eval_cache_put(mkey, fn)
    return fn


def mega_score_tables(eval_fn: Callable, mega_stacked,
                      val: ValidationSlices) -> np.ndarray:
    """(n_tasks, n_oracles, n_trainers) score tables for a whole stacked
    task batch in ONE dispatch.  Requires equal-sized oracle slices
    (``val.stacked``); the caller falls back to per-task quorum calls
    otherwise."""
    assert val.stacked is not None, "mega scoring needs stacked val slices"
    return np.asarray(_mega_eval(eval_fn)(mega_stacked, val.stacked),
                      np.float64)


def quorum_from_table(table: np.ndarray, cfg: DONConfig = DONConfig(),
                      adversarial_oracles: Optional[Dict[int, float]] =
                      None):
    """Median aggregation + outlier flagging over one (n_oracles,
    n_trainers) score table — the tail of ``evaluate_quorum``, shared so
    the megabatched path aggregates EXACTLY the same way."""
    table = np.asarray(table, np.float64)
    if adversarial_oracles:
        for o, forged in adversarial_oracles.items():
            table[o, :] = forged

    median = np.median(table, axis=0)                   # robust aggregate
    dev = np.abs(table - median[None, :]).mean(axis=1)  # per-oracle drift
    flagged = [o for o in range(cfg.n_oracles) if dev[o] > cfg.outlier_tol]
    honest = cfg.n_oracles - len(flagged)
    quorum_ok = honest >= cfg.quorum_frac * cfg.n_oracles
    report = {
        "table": table, "median": median, "oracle_deviation": dev,
        "flagged_oracles": flagged, "quorum_ok": bool(quorum_ok),
    }
    return jnp.asarray(median, jnp.float32), report


def _score_table_loop(eval_fn: Callable, stacked, n_trainers: int,
                      slices) -> np.ndarray:
    """Legacy per-(oracle, trainer) Python loop (non-vmappable eval_fns)."""
    table = np.zeros((len(slices), n_trainers), np.float64)
    for o, sl in enumerate(slices):
        for t in range(n_trainers):
            params = jax.tree.map(lambda l: l[t], stacked)
            table[o, t] = float(eval_fn(params, sl))
    return table


def evaluate_quorum(eval_fn: Callable, trainer_params,
                    val_batch: Optional[Dict[str, jnp.ndarray]],
                    cfg: DONConfig = DONConfig(),
                    adversarial_oracles: Optional[Dict[int, float]] = None,
                    mode: str = "auto",
                    slices: Optional[ValidationSlices] = None):
    """Score every trainer's model with every oracle; aggregate by median.

    eval_fn(params, batch) -> scalar score in [0, 1] (e.g. accuracy).
    trainer_params: list of per-trainer pytrees OR one stacked tree with a
    leading trainer axis (the scheduler/cohort hot path).
    adversarial_oracles: {oracle_idx: forged_score} for bad-mouthing tests.
    mode: "auto" | "batched" | "loop" (see module docstring).
    slices: pre-built ValidationSlices (otherwise split from val_batch).
    Returns (scores (n_trainers,), report).
    """
    val = slices or ValidationSlices(val_batch, cfg.n_oracles)
    assert len(val) == cfg.n_oracles
    stacked, n_trainers = stack_trainer_params(trainer_params)
    table = None
    key = _eval_cache_key(eval_fn)
    if mode == "batched" and _eval_cache_get(key) is _UNBATCHABLE:
        # forced retry: clear the stale verdict FIRST so the wrappers the
        # attempt builds get cached (a later auto call reuses them)
        _BATCHED_EVAL_CACHE.pop(key, None)
    if mode == "batched" or (mode == "auto"
                             and _eval_cache_get(key) is not _UNBATCHABLE):
        try:
            table = _score_table_batched(eval_fn, stacked, val)
        except Exception:
            if mode == "batched":
                raise
            # remember the verdict: "auto" must not pay a fresh vmap trace
            # + swallowed exception on every later quorum round.  Trade-off
            # (deliberate): a transient first-call failure also demotes the
            # eval_fn for the process lifetime — force mode="batched" once
            # to clear a stale verdict
            _eval_cache_put(key, _UNBATCHABLE)
    if table is None:
        table = _score_table_loop(eval_fn, stacked, n_trainers, val.slices)
    return quorum_from_table(table, cfg, adversarial_oracles)


def cross_verify_aggregate(agg_fn: Callable, stacked_params, scores,
                           cfg: DONConfig = DONConfig(), rtol: float = 1e-4,
                           seed: int = 0):
    """Bad-mouthing guard on aggregation: n_oracles independently recompute
    the Eq. 1 aggregate; accept iff a 2/3 quorum agrees elementwise.

    Each oracle o >= 1 recomputes over a seeded permutation of the trainer
    axis — algebraically the same aggregate, but a distinct floating-point
    reduction path — so agreement is a meaningful integrity check on the
    aggregation implementation rather than n identical replays of one
    result (a dishonest/buggy ``agg_fn`` whose output depends on trainer
    order or call history now loses the quorum)."""
    scores = jnp.asarray(scores)
    n = int(jax.tree.leaves(stacked_params)[0].shape[0])
    results = []
    for o in range(cfg.n_oracles):
        perm = (np.arange(n) if o == 0
                else np.random.default_rng(seed + o).permutation(n))
        results.append(agg_fn(
            jax.tree.map(lambda l: l[perm], stacked_params), scores[perm]))
    ref = results[0]
    agree = 0
    for r in results:
        ok = all(bool(jnp.allclose(a, b, rtol=rtol))
                 for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(r)))
        agree += ok
    if agree < cfg.quorum_frac * cfg.n_oracles:
        raise RuntimeError("oracle quorum failed on aggregation")
    return ref, agree
