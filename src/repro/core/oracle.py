"""Decentralized Oracle Network (DON, paper §III-C.5): automated contribution
evaluation and aggregation, off the chain's critical path.

Each oracle node independently scores every trainer's local model on its own
slice of the task publisher's validation set; the network aggregates by
median (robust to a minority of bad-mouthing oracles) and flags outlier
oracles for slashing.  The paper's 2/3-honest assumption maps to the quorum
check.  The same quorum machinery cross-verifies the aggregated global model.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DONConfig:
    n_oracles: int = 5
    outlier_tol: float = 0.15      # |score - median| above this flags oracle
    quorum_frac: float = 2 / 3


def split_validation(val_batch: Dict[str, jnp.ndarray], n_oracles: int):
    """Disjoint per-oracle validation slices (keeps oracles independent)."""
    out = []
    n = len(jax.tree.leaves(val_batch)[0])
    per = max(1, n // n_oracles)
    for i in range(n_oracles):
        sl = slice(i * per, (i + 1) * per if i < n_oracles - 1 else n)
        out.append(jax.tree.map(lambda a: a[sl], val_batch))
    return out


def evaluate_quorum(eval_fn: Callable, trainer_params: List,
                    val_batch: Dict[str, jnp.ndarray],
                    cfg: DONConfig = DONConfig(),
                    adversarial_oracles: Optional[Dict[int, float]] = None):
    """Score every trainer's model with every oracle; aggregate by median.

    eval_fn(params, batch) -> scalar score in [0, 1] (e.g. accuracy).
    adversarial_oracles: {oracle_idx: forged_score} for bad-mouthing tests.
    Returns (scores (n_trainers,), report).
    """
    slices = split_validation(val_batch, cfg.n_oracles)
    table = np.zeros((cfg.n_oracles, len(trainer_params)), np.float64)
    for o, sl in enumerate(slices):
        for t, params in enumerate(trainer_params):
            s = float(eval_fn(params, sl))
            if adversarial_oracles and o in adversarial_oracles:
                s = adversarial_oracles[o]
            table[o, t] = s

    median = np.median(table, axis=0)                       # robust aggregate
    dev = np.abs(table - median[None, :]).mean(axis=1)      # per-oracle drift
    flagged = [o for o in range(cfg.n_oracles) if dev[o] > cfg.outlier_tol]
    honest = cfg.n_oracles - len(flagged)
    quorum_ok = honest >= cfg.quorum_frac * cfg.n_oracles
    report = {
        "table": table, "median": median, "oracle_deviation": dev,
        "flagged_oracles": flagged, "quorum_ok": bool(quorum_ok),
    }
    return jnp.asarray(median, jnp.float32), report


def cross_verify_aggregate(agg_fn: Callable, stacked_params, scores,
                           cfg: DONConfig = DONConfig(), rtol: float = 1e-4):
    """Bad-mouthing guard on aggregation: n_oracles independently recompute
    the Eq. 1 aggregate; accept iff a 2/3 quorum agrees elementwise."""
    results = [agg_fn(stacked_params, scores) for _ in range(cfg.n_oracles)]
    ref = results[0]
    agree = 0
    for r in results:
        ok = all(bool(jnp.allclose(a, b, rtol=rtol))
                 for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(r)))
        agree += ok
    if agree < cfg.quorum_frac * cfg.n_oracles:
        raise RuntimeError("oracle quorum failed on aggregation")
    return ref, agree
