"""Pipelined prover: proof jobs, session proofs, recursive aggregation.

Before this module, every rollup face carried its own copy of the
settlement bookkeeping (``_unsettled_rows`` + an inlined amortization
pass in ``Rollup._settle_session`` / ``VectorRollup.settle_session``),
the "prover" was synchronous and invisible, and the verify/execute gas
could only amortize within one settle call.  ``ProverPipeline`` is the
ONE settlement engine all three rollup backends route through:

  1. **Proof jobs** — every sealed batch enqueues a job.  Jobs drain
     through a modeled prover with ``capacity`` concurrent workers and
     ``prove_time`` seconds per batch proof; ``pump(now)`` completes the
     jobs whose modeled completion is due on the shared window clock
     (``ProofGenerated`` events carry the drain times).
  2. **Session proofs** — ``close_session`` (the face's
     ``settle_session``) folds the session's batch digests into one
     session proof via the same xor-mix/chunk-fold primitive as the
     Pallas ``rollup_digest`` kernel (``core.state.chunk_fold_digests``).
  3. **Recursive aggregation** — ``agg_width`` session proofs fold into
     one *aggregate proof* (the same construction one level up; see
     ``kernels.rollup_digest.rollup_aggregate_digests`` for the device
     form), and the aggregate posts ONE verify + execute pair to the L1,
     amortized across every batch it covers — the paper's 20X gas lever,
     now tunable per node (``repro.api.ProverSpec``).

Finalization policy: ``"eager"`` posts an aggregate as soon as
``agg_width`` sessions have closed (width 1 therefore posts at every
``settle_session`` — **bit-equivalent to the pre-pipeline settlement
path**: same gas rows, same L1 transactions, same timestamps; pinned by
tests/test_prover.py on all three backends); ``"window"`` defers posting
to ``pump(now)`` window edges, releasing only aggregates whose proofs
have fully drained.  ``drain(force=True)`` (the face's ``flush``) always
pushes the remainder through.

The sharded fabric keeps this invariant too: ``ShardedRollup`` gives
every shard lane its own face but ONE shared pipeline, and the fused
window loop (core/fused.py) enqueues each window's jobs lane-by-lane in
shard order, so a fused fabric drains the exact proof/aggregate stream
the stepped fabric does — one pipeline across fused shard lanes.

Security caveat: session and aggregate digests are validity stand-ins
for recursive SNARK composition, not zk proofs — see core/rollup.py.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.events import (AggregateVerified, EventLog, ProofGenerated)
from repro.core.gas import DEFAULT_GAS, GasTable
from repro.core.state import chunk_fold_digests

#: finalization policies a pipeline (and repro.api.ProverSpec) accepts
FINALIZE_MODES = ("eager", "window")


def session_latency(n_calls: int, *, batch_size: int, prove_time: float,
                    per_tx_time: float, n_lanes: int = 1,
                    capacity: int = 1) -> float:
    """THE modeled L2 session latency (Table-II calibration).

    One formula for every face — ``Rollup.latency`` and
    ``VectorRollup.latency`` previously each carried their own copy
    (identical at n_lanes=1, but free to drift): sequencing is the
    slowest lane's ceil-split share, proving is the batch count drained
    through ``capacity`` concurrent workers.  ``capacity=1`` reproduces
    the pre-pipeline ``nb * prove_time`` model exactly (pinned by
    tests/test_prover.py).
    """
    per_lane = math.ceil(n_calls / max(1, n_lanes))
    nb = max(1, math.ceil(per_lane / batch_size))
    return math.ceil(nb / max(1, capacity)) * prove_time \
        + per_lane * per_tx_time


def _fold_digests(digests: np.ndarray, width: int) -> np.ndarray:
    """Vectorized recursive fold: (n,) u32 digests -> (ceil(n/width),)
    u32, one xor-mix fold per ``width`` inputs — ``chunk_fold_digests``
    (the NumPy mirror of the Pallas chunk kernel) applied one level up.
    ``kernels.rollup_digest.rollup_aggregate_digests`` is the bit-exact
    device form (pinned by tests/test_prover.py)."""
    return chunk_fold_digests(np.asarray(digests, np.uint32), chunk=width)


class ProverFace:
    """Shared rollup-face wiring for the pipeline (one copy, like
    ledger.ObjectLedgerFace): event-log adoption, pipeline construction,
    the per-seal WindowSettled emission and the ``pump``/
    ``settle_session`` delegation.  ``Rollup`` and ``VectorRollup`` mix
    this in; the sharded fabric shares one pipeline across its shards
    and emits its own (root-merged) window events instead.

    Subclasses call ``_init_prover_face`` from ``__init__`` and
    ``_emit_window(nb)`` at the end of ``seal()``; they must provide
    ``_last_time``, ``state_root()`` and ``_post_settlement``.
    """

    def _init_prover_face(self, l1, gas_table, prove_time: float,
                          agg_width: int, prover_capacity: int,
                          finalize: str, prover) -> None:
        # adopt the L1's typed event log so L1/L2 events share one total
        # order; a passed-in pipeline (the fabric's) wins over building
        # our own
        l1_events = getattr(l1, "events", None)
        self.events = l1_events if l1_events is not None else EventLog()
        self.prover = prover if prover is not None else ProverPipeline(
            gas_table, agg_width=agg_width, capacity=prover_capacity,
            prove_time=prove_time, finalize=finalize, events=self.events)
        self._window = 0                    # WindowSettled counter
        self._event_shard: Optional[int] = None   # fabric shard tag
        self._suppress_window_event = False       # fabric emits instead

    def _emit_window(self, nb: int) -> None:
        """One typed WindowSettled per ``seal()`` call — the window-clock
        commitment record (the fabric emits its own, root-merged form).
        The state root is (re)committed every window by design — the
        same per-seal commitment the fabric has always recorded; it is a
        chunked fold over the compact account arrays (sub-millisecond at
        benchmark scales)."""
        if self._suppress_window_event:
            return
        from repro.core.events import WindowSettled
        self.events.emit(WindowSettled, time=self._last_time,
                         shard=self._event_shard, window=self._window,
                         n_batches=nb, state_root=self.state_root())
        self._window += 1

    def pump(self, now: float) -> int:
        """Drain the modeled prover to ``now`` (shared window clock)."""
        return self.prover.pump(now)

    def settle_session(self) -> None:
        """Close the settle session through the shared prover pipeline
        (core/prover.py owns the bookkeeping that used to live on each
        face as ``_settle_session``, duplicated per backend)."""
        self.prover.close_session(self)


@dataclasses.dataclass
class ProofJob:
    """One sealed batch's proof work item."""

    job: int
    batch: int                   # owner-global batch id
    n_txs: int
    digest: int                  # the batch's tx xor-root
    sealed_at: float
    done_at: float               # modeled prove completion
    row: Dict[str, Any]          # the owner's gas_log row (by reference)
    proved: bool = False


@dataclasses.dataclass(frozen=True)
class SessionProof:
    """A closed settle-session: its batches' digests folded into one."""

    session: int
    jobs: Tuple[ProofJob, ...]
    n_txs: int
    digest: int
    closed_at: float


@dataclasses.dataclass(frozen=True)
class AggregateProof:
    """``n_sessions`` session proofs folded into one posted L1 verify."""

    aggregate: int
    sessions: Tuple[int, ...]
    batches: Tuple[int, ...]
    n_txs: int
    digest: int
    verify: int
    execute: int
    posted_at: float


class ProverPipeline:
    """Shared prover + aggregation stage for one or more rollup faces.

    Owners are the rollup faces themselves (a sharded fabric's shards
    share ONE pipeline, so job/session/aggregate ids are fabric-global);
    each owner provides ``_post_settlement(verify, execute, at,
    n_batches) -> refs``, a ``gas_log`` whose rows are handed over at
    ``enqueue``, and a ``batch_settle_ref`` dict the pipeline fills.
    """

    def __init__(self, gas_table: GasTable = DEFAULT_GAS, *,
                 agg_width: int = 1, capacity: int = 1,
                 prove_time: float = 0.9, finalize: str = "eager",
                 events: Optional[EventLog] = None):
        if agg_width < 1:
            raise ValueError("agg_width must be >= 1")
        if capacity < 1:
            raise ValueError("prover capacity must be >= 1")
        if finalize not in FINALIZE_MODES:
            raise ValueError(f"unknown finalize mode {finalize!r}; "
                             f"choose from {FINALIZE_MODES}")
        self.gas_table = gas_table
        self.agg_width = agg_width
        self.capacity = capacity
        self.prove_time = prove_time
        self.finalize = finalize
        self.events = events if events is not None else EventLog()
        self.aggregates: List[AggregateProof] = []
        self._workers = [0.0] * capacity          # min-heap of free times
        self._open: Dict[Any, List[ProofJob]] = {}     # sealed, unsettled
        self._closed: Dict[Any, List[SessionProof]] = {}  # awaiting agg
        self._jobs: Dict[Any, Dict[int, ProofJob]] = {}   # batch -> job
        # drain schedule: (done_at, job_id, owner, job) min-heap so pump
        # pops only the jobs that are actually due instead of scanning
        # every open job per call (job_id is unique, so owners are never
        # compared); settled jobs are skipped lazily via ``proved``
        self._due: List[Tuple[float, int, Any, ProofJob]] = []
        self._next_job = 0
        self._next_session = 0
        self._next_agg = 0

    # -- sealing side -----------------------------------------------------------
    def enqueue(self, owner, first_batch: int, digests, n_txs,
                sealed_at, rows: List[Dict[str, Any]]) -> None:
        """Enqueue one proof job per batch sealed by ``owner``.

        ``digests``/``n_txs``/``sealed_at`` are per-batch arrays in
        batch-id order starting at ``first_batch``; ``rows`` are the
        owner's freshly appended ``gas_log`` rows (held by reference —
        truncating ``gas_log`` between sessions can no longer skew the
        amortization, the old ``_unsettled_rows`` index hazard)."""
        queue = self._open.setdefault(owner, [])
        jobs = self._jobs.setdefault(owner, {})
        for j, row in enumerate(rows):
            free = heapq.heappop(self._workers)
            start = max(free, float(sealed_at[j]))
            done = start + self.prove_time
            heapq.heappush(self._workers, done)
            job = ProofJob(self._next_job, first_batch + j, int(n_txs[j]),
                           int(digests[j]), float(sealed_at[j]), done, row)
            row["job"] = job.job
            self._next_job += 1
            queue.append(job)
            jobs[job.batch] = job
            heapq.heappush(self._due, (done, job.job, owner, job))

    # -- modeled prover drain ---------------------------------------------------
    def _complete(self, owner, job: ProofJob,
                  at_most: Optional[float] = None) -> None:
        """Mark a proof done.  ``at_most`` clamps the event timestamp
        when posting forces a job through BEFORE its modeled drain (the
        eager path) — the stream must never show a proof generated
        after the aggregate that consumed it."""
        if job.proved:
            return
        job.proved = True
        t = job.done_at if at_most is None else min(job.done_at, at_most)
        self.events.emit(ProofGenerated, time=t,
                         shard=getattr(owner, "_event_shard", None),
                         job=job.job, batch=job.batch, n_txs=job.n_txs,
                         digest=job.digest, sealed_at=job.sealed_at)

    def pump(self, now: float) -> int:
        """Advance the prover to ``now`` on the shared window clock:
        complete every job whose modeled ``done_at`` is due, and (in
        ``"window"`` finalization) post the aggregates whose sessions
        have fully drained.  Returns the number of jobs completed."""
        due: List[Tuple[Any, ProofJob]] = []
        while self._due and self._due[0][0] <= now:
            _, _, owner, job = heapq.heappop(self._due)
            if not job.proved:
                due.append((owner, job))
        if due:
            # emit in the owner-then-job order the full scan produced
            # (owners by first-enqueue order — _jobs keeps every owner;
            # keyed by the owner itself, not id(), so the order is stable
            # across processes — rule R003)
            order = {o: i for i, o in enumerate(self._jobs)}
            due.sort(key=lambda oj: (order[oj[0]], oj[1].job))
            for owner, job in due:
                self._complete(owner, job)
        n_done = len(due)
        if self.finalize == "window":
            for owner in list(self._closed):
                self._post_ready(owner, force=False, drained_only=True)
        return n_done

    def n_unsettled(self, owner) -> int:
        """Batches sealed by ``owner`` whose aggregate has not posted."""
        return len(self._jobs.get(owner, {}))

    def phase_of(self, owner, batch: int) -> Optional[str]:
        """``"sealed"`` / ``"proved"`` while the batch is in flight;
        ``None`` once its aggregate posted (or for unknown batches)."""
        job = self._jobs.get(owner, {}).get(batch)
        if job is None:
            return None
        return "proved" if job.proved else "sealed"

    # -- session close (the faces' settle_session) ------------------------------
    def close_session(self, owner, at: Optional[float] = None) -> None:
        """Fold ``owner``'s open batches into one session proof.

        ``at`` defaults to the owner's ``_last_time`` (the last seal
        timestamp — where the pre-pipeline path posted its settlement).
        Eager finalization posts every full ``agg_width`` group of
        closed sessions immediately."""
        jobs = self._open.pop(owner, None)
        if not jobs:
            return
        if at is None:
            at = getattr(owner, "_last_time", jobs[-1].sealed_at)
        digest = int(_fold_digests(
            np.array([j.digest for j in jobs], np.uint32), len(jobs))[0])
        proof = SessionProof(self._next_session, tuple(jobs),
                             int(sum(j.n_txs for j in jobs)), digest,
                             float(at))
        self._next_session += 1
        self._closed.setdefault(owner, []).append(proof)
        if self.finalize == "eager":
            self._post_ready(owner, force=False, drained_only=False)

    def drain(self, owner=None, force: bool = True) -> None:
        """Push closed sessions through aggregation (the faces' flush
        tail).  ``force`` posts the final partial-width aggregate too."""
        owners = [owner] if owner is not None else list(self._closed)
        for o in owners:
            self._post_ready(o, force=force, drained_only=False)

    # -- recursive aggregation + L1 posting -------------------------------------
    def _post_ready(self, owner, *, force: bool,
                    drained_only: bool) -> None:
        sessions = self._closed.get(owner)
        if not sessions:
            return
        w = self.agg_width
        while sessions:
            group, partial = sessions[:w], len(sessions) < w
            if partial and not force:
                break
            if drained_only and any(not j.proved
                                    for s in group for j in s.jobs):
                break
            del sessions[:len(group)]
            self._post_aggregate(owner, group, forced=force)
        if not sessions:
            self._closed.pop(owner, None)

    def _post_aggregate(self, owner, group: List[SessionProof], *,
                        forced: bool = False) -> None:
        jobs = [j for s in group for j in s.jobs]
        nb = len(jobs)
        # same single/multi predicate as the pre-pipeline settlement: a
        # lone small batch verifies at the cheap single-proof price
        single = nb == 1 and jobs[0].n_txs <= 5
        gt = self.gas_table
        verify = gt.verify_single if single else gt.verify_multi
        execute = gt.execute_single if single else gt.execute_multi
        if self.finalize == "eager" or forced:
            # pre-pipeline posting time; a FORCED drain (flush) must not
            # stamp the settlement with a still-future modeled drain
            # time — a future tx at the L1 mempool head stalls everything
            # behind it (FIFO head-of-line rule, see Chain.produce_block)
            at = group[-1].closed_at
        else:
            # window-clock posting: pump() only releases fully drained
            # aggregates, so these times are <= the pumped ``now``
            at = max(max(s.closed_at for s in group),
                     max(j.done_at for j in jobs))
        for job in jobs:                    # proofs must exist to fold
            self._complete(owner, job, at_most=at)
        refs = owner._post_settlement(verify, execute, at, nb)
        digest = int(_fold_digests(
            np.array([s.digest for s in group], np.uint32), len(group))[0])
        agg = AggregateProof(
            self._next_agg, tuple(s.session for s in group),
            tuple(j.batch for j in jobs), int(sum(j.n_txs for j in jobs)),
            digest, int(verify), int(execute), float(at))
        self._next_agg += 1
        self.aggregates.append(agg)
        owner_jobs = self._jobs.get(owner, {})
        for job in jobs:
            row = job.row
            row["verify"] = verify / nb
            row["execute"] = execute / nb
            row["total"] = row["commit"] + row["verify"] + row["execute"]
            row["aggregate"] = agg.aggregate
            owner.batch_settle_ref[job.batch] = refs
            owner_jobs.pop(job.batch, None)
        self.events.emit(
            AggregateVerified, time=at,
            shard=getattr(owner, "_event_shard", None),
            aggregate=agg.aggregate, n_sessions=len(group),
            batches=agg.batches, n_txs=agg.n_txs, verify=int(verify),
            execute=int(execute), digest=digest)
        # legacy callback shim (string-keyed subscribe, one release)
        owner._emit("session_settled", {
            "n_batches": nb, "verify": verify, "execute": execute,
            "batches": [j.batch for j in jobs]})
