"""AutoDFL reputation model (paper §IV, Eq. 2-10), vectorised over trainers.

All functions are pure jnp and jit/vmap-friendly: the reputation update for a
whole trainer cohort is one fused kernel-sized computation, and the same code
runs inside the rollup round (core/rollup.py) and the oracle network
(core/oracle.py).

Symbols follow the paper:
  O_rep  objective reputation          (Eq. 2)
  ND_i   normalised model distance     (Eq. 3)
  D_i    L2 distance local vs global   (Eq. 4)
  S_rep  subjective reputation         (Eq. 5-7)
  L_rep  local reputation              (Eq. 8)
  R_i    overall reputation            (Eq. 9-10)
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ReputationParams:
    """Consortium-configured constants (paper defaults in parentheses)."""

    tau: float = -1.0        # distance-penalty threshold; <0 => use mean(ND)
    theta: float = 0.35      # good-behaviour weight (<0.5 punishes bad harder)
    sigma: float = 0.3       # uncertainty weight in S_rep
    gamma: float = 0.6       # O_rep vs S_rep blend
    lam: float = 0.35        # tanh tenure rate (omega = tanh_lam(N))
    r_min: float = 0.4       # critical trust line
    r_init: float = 0.5      # newcomer reputation
    recency_half_life: float = 8.0   # tasks; C_j recency weighting


# ---------------------------------------------------------------------------
# Objective reputation (Eq. 2-4)
# ---------------------------------------------------------------------------
def model_distances(local_flat: jnp.ndarray, global_flat: jnp.ndarray):
    """Eq. 4: D_i = ||w_i - w_g||_2.  local_flat: (n, P); global_flat: (P,)."""
    diff = local_flat.astype(jnp.float32) - global_flat.astype(jnp.float32)[None]
    return jnp.sqrt(jnp.sum(diff * diff, axis=-1))


def normalised_distances(d: jnp.ndarray):
    """Eq. 3: ND_i = D_i / max_j D_j."""
    return d / jnp.maximum(jnp.max(d), 1e-12)


def objective_reputation(score_auto: jnp.ndarray,
                         rounds_completed: jnp.ndarray,
                         rounds_total: jnp.ndarray,
                         nd: jnp.ndarray,
                         params: ReputationParams = ReputationParams()):
    """Eq. 2.  All inputs (n,) vectors over trainers; returns (n,) in [0,1]."""
    tau = jnp.where(params.tau < 0, jnp.mean(nd), params.tau)
    penalty = jnp.maximum((nd - tau) / jnp.maximum(1.0 - tau, 1e-9), 0.0)
    completeness = rounds_completed.astype(jnp.float32) / \
        jnp.maximum(rounds_total.astype(jnp.float32), 1.0)
    o = score_auto.astype(jnp.float32) * completeness * (1.0 - penalty)
    return jnp.clip(o, 0.0, 1.0)


# ---------------------------------------------------------------------------
# Subjective reputation (Eq. 5-7)
# ---------------------------------------------------------------------------
def recency_weights(task_ages: jnp.ndarray, half_life: float):
    """C_j: exponential recency, age 0 = most recent task."""
    return jnp.exp(-jnp.log(2.0) * task_ages.astype(jnp.float32) / half_life)


def subjective_opinion(good_mask: jnp.ndarray, task_ages: jnp.ndarray,
                       interactions_with: jnp.ndarray,
                       interactions_total: jnp.ndarray,
                       params: ReputationParams = ReputationParams()):
    """Eq. 5-6: returns the opinion (b, d, u) per trainer.

    good_mask: (n, T) 1.0 where task j was judged good (0 padded tasks must
    have weight 0 via task_ages = +inf).  task_ages: (n, T).
    """
    C = recency_weights(task_ages, params.recency_half_life)      # (n,T)
    alpha = jnp.sum(params.theta * C * good_mask, axis=-1)
    beta = jnp.sum((1.0 - params.theta) * C * (1.0 - good_mask), axis=-1)
    i_f = interactions_with.astype(jnp.float32) / \
        jnp.maximum(interactions_total.astype(jnp.float32), 1.0)
    u = 1.0 - jnp.clip(i_f, 0.0, 1.0)
    denom = jnp.maximum(alpha + beta, 1e-9)
    b = (1.0 - u) * alpha / denom
    d = (1.0 - u) * beta / denom
    return b, d, u


def subjective_reputation(b, u, params: ReputationParams = ReputationParams()):
    """Eq. 7: S_rep = b + sigma * u."""
    return jnp.clip(b + params.sigma * u, 0.0, 1.0)


# ---------------------------------------------------------------------------
# Local reputation + update (Eq. 8-10)
# ---------------------------------------------------------------------------
def local_reputation(o_rep, s_rep, params: ReputationParams = ReputationParams()):
    """Eq. 8."""
    return params.gamma * o_rep + (1.0 - params.gamma) * s_rep


def tenure_weight(n_tasks, params: ReputationParams = ReputationParams()):
    """Eq. 10: omega = (1 - e^{-lam N}) / (1 + e^{-lam N})."""
    e = jnp.exp(-params.lam * n_tasks.astype(jnp.float32))
    return (1.0 - e) / (1.0 + e)


def update_reputation(r_prev, l_rep, n_tasks,
                      params: ReputationParams = ReputationParams()):
    """Eq. 9: asymmetric tenure-weighted update."""
    w = tenure_weight(n_tasks, params)
    good = w * r_prev + (1.0 - w) * l_rep       # L_rep >= R_min branch
    bad = (1.0 - w) * r_prev + w * l_rep        # L_rep <  R_min branch
    return jnp.where(l_rep >= params.r_min, good, bad)


# ---------------------------------------------------------------------------
# Fused end-of-task update (what the RSC smart contract computes on-chain;
# here: one jit-able function over the whole cohort)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class TrainerBook:
    """Per-trainer running state (the on-chain record)."""

    reputation: jnp.ndarray         # (n,)
    n_tasks: jnp.ndarray            # (n,) tasks participated in
    good_history: jnp.ndarray       # (n, T) rolling good/bad bits
    age_history: jnp.ndarray        # (n, T) task ages (inf = empty slot)
    interactions_with: jnp.ndarray  # (n,) with this TP
    interactions_total: jnp.ndarray  # () total TP interactions


def end_of_task_update(book: TrainerBook,
                       score_auto: jnp.ndarray,
                       rounds_completed: jnp.ndarray,
                       rounds_total: jnp.ndarray,
                       distances: jnp.ndarray,
                       participated: jnp.ndarray,
                       params: ReputationParams = ReputationParams()):
    """One task completion: full Eq. 2-10 pipeline for the cohort.

    participated: (n,) 1.0 for trainers in this task (others unchanged).
    Returns (new_book, diagnostics dict).
    """
    nd = normalised_distances(distances)
    o_rep = objective_reputation(score_auto, rounds_completed, rounds_total,
                                 nd, params)

    good_now = (o_rep >= params.r_min).astype(jnp.float32)
    # roll histories: shift ages by one task, insert the new outcome at slot 0
    age_hist = jnp.where(book.age_history >= jnp.inf, jnp.inf,
                         book.age_history + 1.0)
    age_hist = jnp.concatenate(
        [jnp.where(participated[:, None] > 0, 0.0, jnp.inf),
         age_hist[:, :-1]], axis=1)
    good_hist = jnp.concatenate(
        [good_now[:, None], book.good_history[:, :-1]], axis=1)

    inter_with = book.interactions_with + participated
    inter_total = book.interactions_total + jnp.sum(participated)

    good_mask = jnp.where(jnp.isfinite(age_hist), good_hist, 0.0)
    # empty slots contribute 0 via C(inf)=0
    age_for_c = jnp.where(jnp.isfinite(age_hist), age_hist, 1e9)
    b, d, u = subjective_opinion(good_mask, age_for_c, inter_with,
                                 inter_total, params)
    s_rep = subjective_reputation(b, u, params)
    l_rep = local_reputation(o_rep, s_rep, params)

    n_tasks = book.n_tasks + participated
    r_new = update_reputation(book.reputation, l_rep, n_tasks, params)
    r_new = jnp.clip(r_new, 0.0, 1.0)
    reputation = jnp.where(participated > 0, r_new, book.reputation)

    new_book = TrainerBook(
        reputation=reputation,
        n_tasks=n_tasks,
        good_history=jnp.where(participated[:, None] > 0, good_hist,
                               book.good_history),
        age_history=jnp.where(participated[:, None] > 0, age_hist,
                              book.age_history),
        interactions_with=inter_with,
        interactions_total=inter_total,
    )
    diag = {"o_rep": o_rep, "s_rep": s_rep, "l_rep": l_rep, "nd": nd,
            "belief": b, "disbelief": d, "uncertainty": u}
    return new_book, diag


@functools.partial(jax.jit, static_argnames=("params",))
def _multitask_scan(book, score_auto, rounds_completed, rounds_total,
                    distances, participated, params):
    def step(b, xs):
        return end_of_task_update(b, *xs, params)
    return jax.lax.scan(step, book, (score_auto, rounds_completed,
                                     rounds_total, distances, participated))


def end_of_multitask_update(book: TrainerBook,
                            score_auto: jnp.ndarray,
                            rounds_completed: jnp.ndarray,
                            rounds_total: jnp.ndarray,
                            distances: jnp.ndarray,
                            participated: jnp.ndarray,
                            params: ReputationParams = ReputationParams()):
    """Fused settlement for K tasks closing in the same scheduler window.

    All inputs are (K, n) — row k holds task k's cohort arrays, with
    ``participated[k]`` masking that task's trainers (rows may overlap: a
    trainer can close several tasks in one window).  Applies the K Eq. 2-10
    updates in row order as ONE jitted ``lax.scan`` — identical results to K
    sequential ``end_of_task_update`` calls (pinned by tests), but a single
    dispatch per settlement window instead of per task.

    Returns (new_book, diagnostics) with diagnostic leaves stacked (K, n).
    """
    xs = tuple(jnp.asarray(a, jnp.float32) for a in
               (score_auto, rounds_completed, rounds_total, distances,
                participated))
    assert xs[0].ndim == 2, "multitask inputs are (K, n)"
    return _multitask_scan(book, *xs, params)


def sync_book_to_state(book: TrainerBook, state, account_ids) -> None:
    """Scatter the on-chain reputation record into the array-native L2
    account state (core/state.py StateArrays) — the cross-shard settlement
    write fl/server.py performs at end-of-window.  ``account_ids[i]`` is
    the ledger sender id (StateArrays row) of trainer i."""
    import numpy as np
    ids = np.asarray(account_ids, np.int64)
    state.ensure_ids(ids)
    state.reputation[ids] = np.asarray(book.reputation, np.float32)
    state.mark_dirty(ids)


def init_book(n: int, history: int = 16,
              params: ReputationParams = ReputationParams()) -> TrainerBook:
    return TrainerBook(
        reputation=jnp.full((n,), params.r_init, jnp.float32),
        n_tasks=jnp.zeros((n,), jnp.float32),
        good_history=jnp.zeros((n, history), jnp.float32),
        age_history=jnp.full((n, history), jnp.inf, jnp.float32),
        interactions_with=jnp.zeros((n,), jnp.float32),
        interactions_total=jnp.zeros((), jnp.float32),
    )


jax.tree_util.register_pytree_node(
    TrainerBook,
    lambda b: ((b.reputation, b.n_tasks, b.good_history, b.age_history,
                b.interactions_with, b.interactions_total), None),
    lambda _, xs: TrainerBook(*xs),
)
