"""zk-Rollup Layer-2 engine (paper §III-C.3) — and its TPU-native analogue.

Two faces of the same idea ("don't pay the expensive global medium per
transaction; batch locally, post one verified summary"):

1. **Chain face** (`Rollup`): batches FL transactions off-chain, executes
   them against the L2 state, produces a validity digest (stand-in for the
   zk proof — see DESIGN.md security note), and posts commit/verify/execute
   to the L1 chain with Table-I-calibrated gas.  Reproduces the paper's
   20x gas reduction and >3000 TPS.

2. **Mesh face** (`rollup_round`, fl/round.py): H local optimizer steps
   accumulate on-device ("off-chain"), then ONE reputation-weighted
   all-reduce (Eq. 1) + digest crosses the pod interconnect ("commit").
   Collective bytes drop ~H-fold — the gas story, re-materialised on ICI.

At scale the single sequencer saturates; the **sharded rollup fabric**
(core/shards.py `ShardedRollup`) runs K `VectorRollup` shards — each with
its own sequencer lanes and its own partition of the array-native account
state (core/state.py `StateArrays`) — all settling to ONE shared L1.  At
window boundaries each shard's partition root is merged into a *fabric
root* committing the whole fleet's state; the flat array state root itself
is shard-count invariant, so the same transactions commit to the same
state no matter how they were sharded.

Security caveat: every root in this simulator — `state_digest`, the batch
`word_digest`, the chunked `StateArrays` root and the fabric root — is a
validity *stand-in*, not a zk proof.  The digests are deterministic and
tamper-evident (replaying the batch from `pre_root` must reach
`post_root`), which is the soundness condition a zk-SNARK would prove
succinctly; no cryptographic succinctness or zero-knowledge property is
claimed.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Callable, Dict, List, Optional

from repro.core.events import BatchSealed
from repro.core.gas import DEFAULT_GAS, ROLLUP_BATCH, GasTable
from repro.core.ledger import Chain, EventHooks, ObjectLedgerFace, Tx
from repro.core.prover import ProverFace, ProverPipeline, session_latency
from repro.core.state import canonical_bytes


def state_digest(state: Dict[str, Any]) -> str:
    """Deterministic state-root stand-in (content hash of the L2 state).

    Built on ``core.state.canonical_bytes``: the old
    ``json.dumps(..., default=repr)`` fallback truncated ndarray reprs
    (two different 2000-element arrays share a repr, hence shared a
    digest) and collapsed dataclasses to their repr; the canonical
    encoding is total, type-tagged and collision-resistant
    (tests/test_state.py pins the regression)."""
    return hashlib.sha256(canonical_bytes(state)).hexdigest()[:32]


@dataclasses.dataclass
class BatchProof:
    batch_id: int
    n_txs: int
    pre_root: str
    post_root: str
    tx_root: str
    # xor-mix fold over the batch's transaction words — same construction
    # the Pallas rollup_digest kernel computes over merged update buffers
    # (engine.xor_fold_digest is the bit-exact CPU mirror)
    word_digest: int = 0

    def verify(self, pre_state: Dict[str, Any],
               replay: Callable[[Dict[str, Any]], Dict[str, Any]]) -> bool:
        """Validity check: replaying the batch from pre_root reaches
        post_root.  (A zk-SNARK proves this without replay; the simulator
        replays — same soundness condition, no cryptographic claim.)"""
        if state_digest(pre_state) != self.pre_root:
            return False
        return state_digest(replay(pre_state)) == self.post_root


class Rollup(ObjectLedgerFace, ProverFace, EventHooks):
    """L2 sequencer + prover + L1 settlement."""

    def __init__(self, l1: Chain, batch_size: int = ROLLUP_BATCH,
                 gas_table: GasTable = DEFAULT_GAS,
                 prove_time: float = 0.9, per_tx_time: float = 0.14,
                 agg_width: int = 1, prover_capacity: int = 1,
                 finalize: str = "eager",
                 prover: Optional[ProverPipeline] = None):
        self.l1 = l1
        self.batch_size = batch_size
        self.gas_table = gas_table
        self.prove_time = prove_time      # per-batch prover latency (s)
        self.per_tx_time = per_tx_time    # sequencer execution latency (s)
        self.state: Dict[str, Any] = {}
        self._handlers: Dict[str, Callable] = {}
        # LedgerBackend face: sender namespace, SoA-lowering adapter and
        # StateArrays handler plumbing shared with Chain (one copy of the
        # id-pinning invariant — see ledger.ObjectLedgerFace)
        self._init_object_face()
        self.pending: List[Tx] = []
        self.batches: List[BatchProof] = []
        self.gas_log: List[Dict[str, Any]] = []
        self._sealing = False
        self._last_time = 0.0
        # tx->batch provenance + per-batch L1 refs (receipts): mirrors
        # engine.VectorRollup's maps, keyed by tx_id on the object path
        self.tx_batch: Dict[str, int] = {}
        self.batch_commit_ref: Dict[int, Tx] = {}
        self.batch_settle_ref: Dict[int, tuple] = {}
        self._init_events()
        # event-log adoption + settlement-pipeline wiring (ONE copy for
        # both rollup faces — see prover.ProverFace; the verify/execute
        # bookkeeping that used to live here as _settle_session is the
        # pipeline's now)
        self._init_prover_face(l1, gas_table, prove_time, agg_width,
                               prover_capacity, finalize, prover)

    def register(self, fn: str, handler: Callable):
        self._handlers[fn] = handler

    # -- sequencing -------------------------------------------------------------
    def submit(self, tx: Tx):
        self.pending.append(tx)
        if len(self.pending) >= self.batch_size:
            self.seal_batch()

    def _execute(self, state: Dict[str, Any], txs: List[Tx]) -> Dict[str, Any]:
        # PURE (state, txs) -> state replay: BatchProof.verify's soundness
        # story replays batches through this function, so it must not
        # touch the live StateArrays (those handlers run in seal_batch)
        for tx in txs:
            handler = self._handlers.get(tx.fn)
            if handler is not None:
                handler(state, tx)
        return state

    def seal(self) -> int:
        """Seal every pending tx (LedgerBackend face shared with
        VectorRollup.seal / ShardedRollup.seal); returns #batches."""
        nb = 0
        while self.pending:
            if self.seal_batch() is None:
                break
            nb += 1
        self._emit_window(nb)
        return nb

    def seal_batch(self) -> Optional[BatchProof]:
        if not self.pending or self._sealing:
            # re-entrancy guard: a handler that submits back into the rollup
            # during _execute must not trigger a nested seal against a
            # half-executed state; the queued txs seal on the next
            # seal_batch/flush instead.
            return None
        self._sealing = True
        try:
            txs, self.pending = self.pending[: self.batch_size], \
                self.pending[self.batch_size:]
            pre_root = state_digest(self.state)
            self.state = self._execute(self.state, txs)
            if self._state_handlers:
                # SoA state handlers run at seal time, OUTSIDE the pure
                # replay function (1-row views, same handler code as the
                # vector/sharded faces)
                for tx in txs:
                    self._apply_state_tx(tx)
            post_root = state_digest(self.state)
            tx_root = hashlib.sha256(
                "".join(t.tx_id for t in txs).encode()).hexdigest()[:32]
            proof = BatchProof(len(self.batches), len(txs), pre_root,
                               post_root, tx_root,
                               word_digest=self._word_digest(txs))
            self.batches.append(proof)
            for t in txs:
                self.tx_batch[t.tx_id] = proof.batch_id
            row = self._settle(proof, txs)
            # one proof job per sealed batch (settlement lives in the
            # pipeline; see core/prover.py)
            self.prover.enqueue(self, proof.batch_id, [proof.word_digest],
                                [proof.n_txs], [self._last_time], [row])
            self.events.emit(BatchSealed, time=self._last_time,
                             shard=self._event_shard,
                             first_batch=proof.batch_id, n_batches=1,
                             n_txs=proof.n_txs, digest=proof.word_digest)
            self._emit("batch_sealed", {
                "first_batch": proof.batch_id, "n_batches": 1,
                "n_txs": proof.n_txs, "digest": proof.word_digest})
        finally:
            self._sealing = False
        return proof

    @staticmethod
    def _word_digest(txs: List[Tx]) -> int:
        """Batched digest over the merged tx-word buffer — the same
        xor-mix fold the Pallas rollup_digest kernel computes (see
        engine.xor_fold_digest for the mirror pinned against the kernel)."""
        from repro.core.engine import TxArrays, xor_fold_digest
        return xor_fold_digest(TxArrays.from_txs(txs).word_buffer())

    def flush(self):
        if self._sealing:
            # re-entrant flush from a handler: the outer seal/flush in
            # progress will drain pending and settle the session; settling
            # here would split the session in two (double verify/execute)
            # with the settlement timestamped before the outer commit.
            return
        self.seal()
        self.settle_session()
        self.prover.drain(self)

    # -- L1 settlement: commit per batch; verify+execute once per aggregate
    # (zkSync-style proof aggregation — matches Table I, where Verify and
    # Execute stay ~constant even at 5 batches) ---------------------------------
    def _settle(self, proof: BatchProof, txs: List[Tx]) -> Dict[str, Any]:
        by_fn: Dict[str, int] = {}
        for t in txs:
            by_fn[t.fn] = by_fn.get(t.fn, 0) + 1
        commit = sum(
            self.gas_table.commit_base.get(fn, 37000)
            + n * self.gas_table.commit_per_call.get(fn, 500)
            for fn, n in by_fn.items())
        now = max((t.submit_time for t in txs), default=0.0)
        commit_tx = Tx("rollup_commit", "sequencer",
                       {"batch": proof.batch_id,
                        "root": proof.post_root}, commit, now)
        self.l1.submit(commit_tx)
        self.batch_commit_ref[proof.batch_id] = commit_tx
        row = {"batch": proof.batch_id, "n_txs": proof.n_txs,
               "commit": commit, "verify": 0, "execute": 0,
               "total": commit}
        self.gas_log.append(row)
        self._last_time = now
        return row

    def _post_settlement(self, verify: int, execute: int, at: float,
                         n_batches: int):
        """Prover callback: post one verify + execute pair to the L1."""
        refs = []
        for phase, gas in (("verify", verify), ("execute", execute)):
            settle_tx = Tx(f"rollup_{phase}", "sequencer",
                           {"batches": n_batches}, gas, at)
            self.l1.submit(settle_tx)
            refs.append(settle_tx)
        return tuple(refs)

    # -- metrics ---------------------------------------------------------------
    def throughput(self, l1_tps: float) -> float:
        """Paper's method: L2 TPS = batch_size x L1 TPS."""
        return self.batch_size * l1_tps

    def latency(self, n_calls: int) -> float:
        """End-to-end L2 latency model calibrated to Table II
        (prover.session_latency — ONE formula shared with the vector
        face, so identical specs model identical prove/settle timing)."""
        return session_latency(n_calls, batch_size=self.batch_size,
                               prove_time=self.prove_time,
                               per_tx_time=self.per_tx_time,
                               capacity=self.prover.capacity)
