"""Sharded rollup fabric: K L2 sequencers over one L1, one array state.

``ShardedRollup`` horizontally scales the L2 layer past a single
sequencer's throughput: K ``VectorRollup`` shards each own

  * their own sequencer lanes (batches seal concurrently within a shard
    AND across shards — the fabric latency is the slowest shard's),
  * a partition of the SoA account state (``StateArrays`` rows, owner =
    account id mod K),

and all post commit / verify / execute transactions to ONE shared L1
``VectorChain``, so the consensus layer stays unified while sequencing
capacity scales linearly.

Routing: per-transaction ``hash`` routing (stable xor-mix of the sender
id — an account's txs always land on the shard that owns its state rows)
or ``least_loaded`` (whole submissions to the emptiest shard).  Task-level
routing for the FL protocol (fl/scheduler.py) pins every transaction of a
task to one shard via ``assign_task`` + ``submit_arrays(..., shard=k)``.

Commitment: every ``seal()`` (the scheduler calls it at window boundaries)
records a **fabric root** — one sha256 merging the K per-shard partition
roots (``StateArrays.partition_root``) — into ``fabric_roots``.  The flat
array state root (``state_root()``) is chunked independently of K, so the
same transaction set commits to the same state root at any shard count
(pinned by tests/test_shards.py); state handlers must therefore be
per-account commutative (see core/state.py).

``n_shards=1`` is bit-equivalent to a plain ``VectorRollup`` — same
gas_log rows, same L1 stream, same digests (pinned by tests).

Security caveat: roots here are validity stand-ins, not zk proofs — see
core/rollup.py.
"""
from __future__ import annotations

import hashlib
import math
from functools import reduce
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from repro.core.engine import FnRegistry, TxArrays, VectorRollup
from repro.core.events import EventLog, WindowSettled
from repro.core.gas import DEFAULT_GAS, ROLLUP_BATCH, GasTable
from repro.core.interconnect import InterconnectSpec
from repro.core.ledger import EventHooks
from repro.core.prover import ProverPipeline
from repro.core.state import StateArrays, account_owner


def _hash_route(sender_id: np.ndarray, n_shards: int) -> np.ndarray:
    """Stable per-tx shard assignment — ``state.account_owner``, the SAME
    partition function ``StateArrays.partition_root`` commits rows with,
    so every tx of a sender lands on the shard owning that account's
    state rows (account-aligned; deterministic, no ``hash`` salt)."""
    return account_owner(sender_id, n_shards)


class ShardedRollup(EventHooks):
    """K-shard L2 fabric over one shared L1 (LedgerBackend face)."""

    soa_native = True
    # the fused loop replays the fabric as one plan: routing decisions
    # (hash split / least-loaded argmin / task pins) are taken at RECORD
    # time against the live ``_submitted`` counters, and execute() seals
    # the K lanes per window in shard order before ``_finish_window`` —
    # bit-identical to the stepped path, so Scheduler(fused="auto")
    # takes the fused loop here too
    fused_capable = True

    def __init__(self, l1, n_shards: int = 1,
                 batch_size: int = ROLLUP_BATCH,
                 gas_table: GasTable = DEFAULT_GAS,
                 prove_time: float = 0.9, per_tx_time: float = 0.14,
                 n_lanes: int = 1, digest_backend: str = "auto",
                 route: str = "hash",
                 state: Optional[StateArrays] = None,
                 agg_width: int = 1, prover_capacity: int = 1,
                 finalize: str = "eager",
                 interconnect: Optional[InterconnectSpec] = None,
                 mesh: str = "auto"):
        assert n_shards >= 1
        assert route in ("hash", "least_loaded"), route
        assert mesh in ("auto", "on", "off"), mesh
        self.l1 = l1
        self.n_shards = n_shards
        self.route = route
        l1_fns = getattr(l1, "fns", None)
        self.fns: FnRegistry = l1_fns if l1_fns is not None else FnRegistry()
        # ONE typed event stream and ONE prover pipeline for the whole
        # fabric: shard events interleave in the L1's log under a single
        # seq, and job/session/aggregate ids are fabric-global (each
        # shard still closes its own sessions — the L1 sees K
        # independent proof aggregations, as before)
        l1_events = getattr(l1, "events", None)
        self.events = l1_events if l1_events is not None else EventLog()
        self.prover = ProverPipeline(
            gas_table, agg_width=agg_width, capacity=prover_capacity,
            prove_time=prove_time, finalize=finalize, events=self.events)
        self.shards: List[VectorRollup] = []
        for k in range(n_shards):
            s = VectorRollup(l1, batch_size=batch_size, gas_table=gas_table,
                             prove_time=prove_time, per_tx_time=per_tx_time,
                             n_lanes=n_lanes, digest_backend=digest_backend,
                             prover=self.prover)
            s.fns = self.fns          # one fn namespace across the fabric
            s._event_shard = k        # shard tag on the shard's events
            s._suppress_window_event = True   # the fabric's is the window
            self.shards.append(s)
        self.batch_size = batch_size
        self.gas_table = gas_table
        # ONE fabric-wide sender/account namespace: ids index StateArrays
        # rows AND drive hash routing, so they must not be per-shard
        self._sender_ids: Dict[str, int] = {}
        self.state = state
        self.task_shard: Dict[str, int] = {}
        self._task_counts = np.zeros(n_shards, np.int64)
        self._submitted = np.zeros(n_shards, np.int64)
        self.fabric_roots: List[Dict[str, Any]] = []
        self._window = 0
        # explicit wire-cost model (core/interconnect.py): a parallel,
        # deterministic ledger of what crossing the fabric would cost —
        # it NEVER feeds the Table-II latency()/throughput() numbers
        self.interconnect = (interconnect if interconnect is not None
                             else InterconnectSpec()).build(n_shards)
        # "auto"/"on"/"off": whether the fused loop folds the K lanes'
        # seal digests through the mesh-mapped shard_seal kernel
        # (kernels/shard_lanes.py) instead of the host-local impls
        self.mesh_mode = mesh
        self._init_events()

    # -- events (NodeClient subscription hook) ---------------------------------
    def subscribe(self, event: str, callback: Callable) -> None:
        """``"window_settled"`` fires once per fabric seal (payload = the
        fabric-root record); ``"batch_sealed"``/``"session_settled"``
        forward from every shard with a ``"shard"`` key added."""
        if event == "window_settled":
            self._subs.setdefault(event, []).append(callback)
            return
        for k, s in enumerate(self.shards):
            s.subscribe(event,
                        lambda payload, k=k: callback(dict(payload, shard=k)))

    # -- LedgerBackend surface -------------------------------------------------
    def sender_id(self, sender: str) -> int:
        return self._sender_ids.setdefault(sender, len(self._sender_ids))

    def register_state(self, fn: str, handler: Callable):
        """Attach a StateArrays handler to every shard, all writing the
        ONE shared fabric state.  Handlers must be per-account commutative
        (counters/accumulators): each shard executes only the txs routed
        to it, and the merged state must not depend on the partition."""
        if self.state is None:
            self.state = StateArrays()
            self.state.enable_dirty_tracking()
        for s in self.shards:
            s.state_arrays = self.state
            s.register_state(fn, handler)

    def submit(self, tx):
        """Object-Tx compatibility shim (fabric sender namespace)."""
        batch = TxArrays.from_txs([tx], self.fns)
        batch.sender_id = np.array([self.sender_id(tx.sender)], np.int32)
        return self.submit_arrays(batch)

    def submit_arrays(self, batch: TxArrays, shard: Optional[int] = None):
        """Route a SoA batch into the fabric.

        ``shard=k`` pins the whole batch (task-level routing); otherwise
        ``hash`` splits per tx by sender and ``least_loaded`` sends the
        batch to the shard with the fewest submitted txs.

        Returns per-tx provenance in input order: ``(shard_of, seq_of)``
        int64 arrays — the owning shard and the sequence number the shard
        assigned (``VectorRollup.submit_arrays`` ranges), which receipts
        resolve to batches via ``shards[k].batch_of_seq``."""
        if batch.fns is not self.fns:
            remap = np.array([self.fns.id(n) for n in batch.fns.names],
                             np.int32)
            batch = TxArrays(batch.submit_time, batch.gas,
                             remap[batch.fn_id] if len(batch) else
                             batch.fn_id, batch.sender_id, self.fns)
        n = len(batch)
        if shard is None and self.route == "least_loaded":
            shard = int(np.argmin(self._submitted))
        if shard is not None or self.n_shards == 1:
            k = int(shard or 0)
            self._submitted[k] += n
            pinned = np.zeros(self.n_shards, np.int64)
            pinned[k] = n
            self._wire_submit(pinned)
            lo, hi = self.shards[k].submit_arrays(batch)
            return (np.full(n, k, np.int64),
                    np.arange(lo, hi, dtype=np.int64))
        lanes = _hash_route(batch.sender_id, self.n_shards)
        self._wire_submit(np.bincount(lanes, minlength=self.n_shards))
        seq_of = np.empty(n, np.int64)
        for k in range(self.n_shards):
            m = lanes == k
            if m.any():
                self._submitted[k] += int(m.sum())
                lo, hi = self.shards[k].submit_arrays(TxArrays(
                    batch.submit_time[m], batch.gas[m], batch.fn_id[m],
                    batch.sender_id[m], self.fns))
                seq_of[m] = np.arange(lo, hi, dtype=np.int64)
        return lanes.astype(np.int64), seq_of

    def _wire_submit(self, counts) -> None:
        """Account the cohort->shard wire cost of one routed submission
        (``counts`` = txs per destination shard).  Called at ROUTING time
        on both the stepped and the fused path, so the wire logs match."""
        if int(np.sum(counts)):
            self.interconnect.record_submit(counts)

    # -- task-level routing (protocol layer) -----------------------------------
    def assign_task(self, task_id: str) -> int:
        """Pin a task to a shard: stable content hash of the task id, or
        the shard with the fewest assigned tasks (``least_loaded``)."""
        k = self.task_shard.get(task_id)
        if k is None:
            if self.route == "least_loaded":
                k = int(np.argmin(self._task_counts))
            else:
                h = hashlib.sha256(task_id.encode()).digest()
                k = int.from_bytes(h[:8], "big") % self.n_shards
            self.task_shard[task_id] = k
            self._task_counts[k] += 1
        return k

    # -- sequencing / settlement -----------------------------------------------
    def seal(self) -> int:
        """Seal every shard's pending txs; record the fabric root.

        Window-boundary contract (fl/scheduler.py): after all shards seal,
        the K partition roots are merged into one fabric root — the
        cross-shard commitment for this window."""
        return self._finish_window([s.seal() for s in self.shards])

    def _finish_window(self, shard_batches: List[int]) -> int:
        """Merge one window after every shard sealed: account the
        root-gather wire cost, record the fabric root and emit the
        ``WindowSettled`` event.  The fused loop (core/fused.py) calls
        this directly after applying the K precomputed lane seals —
        same record, same event, same window counter."""
        nb = int(sum(shard_batches))
        self.interconnect.record_root_gather(self._window, shard_batches)
        record: Dict[str, Any] = {"n_batches": nb}
        if self.state is not None:
            record = self._root_record(nb)
            self.fabric_roots.append(record)
        self.events.emit(
            WindowSettled,
            time=max((s._last_time for s in self.shards), default=0.0),
            window=self._window, n_batches=nb,
            state_root=record.get("state_root", ""),
            fabric_root=record.get("fabric_root", ""),
            shard_roots=tuple(record.get("shard_roots", ())))
        self._window += 1
        self._emit("window_settled", record)
        return nb

    @staticmethod
    def _merge_roots(shard_roots: List[str]) -> str:
        h = hashlib.sha256()
        for r in shard_roots:
            h.update(r.encode())
        return h.hexdigest()[:32]

    def _root_record(self, n_batches: int) -> Dict[str, Any]:
        shard_roots = self.state.partition_roots(self.n_shards)
        return {"window": len(self.fabric_roots), "n_batches": n_batches,
                "state_root": self.state.root(),
                "fabric_root": self._merge_roots(shard_roots),
                "shard_roots": shard_roots}

    def fabric_root(self) -> str:
        """Current merged commitment (computed on demand from the K
        partition roots alone; ``seal``/``flush`` append the fuller
        per-window records — including the flat state root — to
        ``fabric_roots``)."""
        if self.state is None:
            return ""
        return self._merge_roots(self.state.partition_roots(self.n_shards))

    def state_root(self) -> str:
        return self.state.root() if self.state is not None else ""

    def settle_session(self):
        """Per-shard zkSync-style settlement through the ONE shared
        prover pipeline: each shard closes its own session (the L1 sees
        K independent proof aggregations, folded per the fabric's
        aggregation width)."""
        for s in self.shards:
            s.settle_session()

    def pump(self, now: float) -> int:
        """Drain the fabric's modeled prover to ``now``."""
        return self.prover.pump(now)

    def flush(self):
        self.seal()
        self.settle_session()
        self.prover.drain()

    # -- merged views ----------------------------------------------------------
    @property
    def gas_log(self) -> List[Dict[str, Any]]:
        """Merged per-batch rows in (shard, row) order; n_shards=1 yields
        exactly the single shard's rows (plus the ``shard`` tag)."""
        out = []
        for k, s in enumerate(self.shards):
            for r in s.gas_log:
                row = dict(r)
                row["shard"] = k
                out.append(row)
        return out

    @property
    def n_batches(self) -> int:
        return sum(s.n_batches for s in self.shards)

    @property
    def batch_digests(self) -> List[int]:
        return [d for s in self.shards for d in s.batch_digests]

    @property
    def update_digest(self) -> int:
        return reduce(lambda a, b: a ^ b,
                      (s.update_digest for s in self.shards))

    # -- metrics ---------------------------------------------------------------
    def throughput(self, l1_tps: float) -> float:
        """Paper's method, scaled by concurrently sequencing shards."""
        return sum(s.throughput(l1_tps) for s in self.shards)

    def latency(self, n_calls: int) -> float:
        """Table-II latency model: shards sequence concurrently, so the
        fabric session latency is the slowest shard's share.

        The share is the fabric's ACTUAL routed distribution (observed
        ``_submitted`` counts, scaled to ``n_calls``) — a skewed router
        shows up as a slow fabric instead of being modeled away.  A fresh
        fabric with no observed traffic falls back to an even split."""
        total = int(self._submitted.sum())
        if total > 0:
            return max(s.latency(math.ceil(n_calls * int(c) / total))
                       for s, c in zip(self.shards, self._submitted) if c)
        per_shard = math.ceil(n_calls / self.n_shards)
        return max(s.latency(per_shard) for s in self.shards)

    def sealed_batch_throughput(self, n_calls: int) -> float:
        """Modeled sealed-batch throughput at a fixed workload: txs per
        modeled fabric-session second (benchmarks/bench_shards.py)."""
        return n_calls / max(self.latency(n_calls), 1e-12)
