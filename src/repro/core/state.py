"""Array-native L2 account state + chunked state commitment.

The rollup's L2 state used to be a free-form ``Dict[str, Any]`` digested
with ``json.dumps(..., default=repr)`` — slow, schema-less and
collision-prone (ndarray ``repr`` truncates, so two different large arrays
could share a digest).  This module replaces it with

  * ``canonical_bytes`` — a total, type-tagged byte encoding for the values
    the ledger actually stores (scalars, strings, ndarrays, dataclasses,
    nested containers).  Used by ``rollup.state_digest`` so dict-state
    digests stay available for the object path, now collision-resistant.
  * ``StateArrays`` — a fixed-schema structure-of-arrays account state
    (balances, stake, reputation, task counters) indexed by the ledger's
    integer sender ids.  Handlers are written ONCE against ``StateArrays``
    + a ``TxArrays`` view (see ledger.LedgerBackend); the object path lifts
    single transactions into 1-row views.
  * a chunked Merkle-style commitment: the state's canonical u32 word
    buffer is split into fixed-size chunks, each chunk folded with the same
    xor-mix as the Pallas ``rollup_digest`` kernel (``chunk_fold_digests``
    is the bit-exact NumPy mirror of ``kernels.rollup_digest.
    rollup_chunk_digests`` — pinned by tests/test_state.py), and the chunk
    digest vector is sealed with one sha256.  Chunking is independent of
    the shard count, so the same transactions produce the same root no
    matter how many shards executed them (core/shards.py).

Security note: like every digest in this simulator, the root is a validity
*stand-in* for a zk proof — deterministic and tamper-evident, but not a
cryptographic succinctness/soundness claim (see core/rollup.py).
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

# Mixing constants shared with core/engine.py and kernels/rollup_digest.py.
MIX_MULT = np.uint32(0x85EBCA6B)
MIX_SEED = np.uint32(0x9E3779B9)

# chunk size (u32 words) of the state commitment; lane-aligned for the
# Pallas path (kernels.rollup_digest.rollup_chunk_digests needs % 128 == 0)
STATE_CHUNK_WORDS = 2048


class Registry:
    """Stable name <-> integer-id mapping (append-only, insertion order).

    The generic form of the engine's ``FnRegistry``; also used for account
    namespaces.  Ids are dense and never reused, so they index SoA arrays.
    """

    def __init__(self, names: Sequence[str] = ()):
        self.names: List[str] = []
        self._ids: Dict[str, int] = {}
        for n in names:
            self.id(n)

    def id(self, name: str) -> int:
        i = self._ids.get(name)
        if i is None:
            i = len(self.names)
            self._ids[name] = i
            self.names.append(name)
        return i

    def get(self, name: str) -> Optional[int]:
        return self._ids.get(name)

    def __contains__(self, name: str) -> bool:
        return name in self._ids

    def __len__(self) -> int:
        return len(self.names)


def account_owner(account_ids, n_shards: int) -> np.ndarray:
    """Shard ownership of account ids: xor-mix of the id mod K.

    THE one partition function: core/shards.py routes transactions with it
    and ``StateArrays.partition_root`` commits rows with it, so a sender's
    txs always execute on the shard whose partition root covers its
    account rows.  Deterministic across runs/processes (no ``hash`` salt).
    """
    s = np.asarray(account_ids, np.uint32)
    mixed = (s ^ (s >> np.uint32(16))) * MIX_MULT
    return (mixed % np.uint32(n_shards)).astype(np.int64)


# ---------------------------------------------------------------------------
# canonical byte encoding (satellite of the dict-state digest fix)
# ---------------------------------------------------------------------------
def canonical_bytes(obj: Any) -> bytes:
    """Total, deterministic, type-tagged encoding of a state value.

    Every encoding is prefixed with a one-byte type tag and, where the
    payload is variable-length, a length header — so values of different
    types or shapes can never collide byte-wise.  ndarrays encode dtype,
    shape and the FULL buffer (``repr`` truncates at ~1000 elements, which
    is the collision the old ``json.dumps(..., default=repr)`` fallback
    had); dataclasses encode their field names and values recursively.
    """
    if obj is None:
        return b"N"
    if isinstance(obj, bool):                       # before int (bool is int)
        return b"B1" if obj else b"B0"
    if isinstance(obj, (int, np.integer)):
        b = str(int(obj)).encode()
        return b"I" + len(b).to_bytes(4, "big") + b
    if isinstance(obj, (float, np.floating)):
        # bit pattern, not repr: -0.0 vs 0.0 and precision stay distinct
        return b"F" + np.float64(obj).tobytes()
    if isinstance(obj, str):
        b = obj.encode()
        return b"S" + len(b).to_bytes(4, "big") + b
    if isinstance(obj, (bytes, bytearray)):
        return b"Y" + len(obj).to_bytes(4, "big") + bytes(obj)
    if isinstance(obj, np.ndarray):
        if obj.dtype == object:
            # object arrays hold PyObject POINTERS — tobytes() would be
            # process-random; encode shape + elements recursively instead
            head = str(obj.shape).encode()
            body = b"".join(canonical_bytes(v) for v in obj.ravel())
            return (b"P" + len(head).to_bytes(4, "big") + head
                    + len(body).to_bytes(8, "big") + body)
        a = np.ascontiguousarray(obj)
        head = repr(a.dtype.str).encode() + str(a.shape).encode()
        return (b"A" + len(head).to_bytes(4, "big") + head
                + len(a.tobytes()).to_bytes(8, "big") + a.tobytes())
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        items = [(f.name, getattr(obj, f.name))
                 for f in dataclasses.fields(obj)]
        body = b"".join(canonical_bytes(k) + canonical_bytes(v)
                        for k, v in items)
        name = type(obj).__name__.encode()
        return (b"C" + len(name).to_bytes(4, "big") + name
                + len(body).to_bytes(8, "big") + body)
    if isinstance(obj, dict):
        enc = sorted((canonical_bytes(k), canonical_bytes(v))
                     for k, v in obj.items())
        body = b"".join(k + v for k, v in enc)
        return b"D" + len(body).to_bytes(8, "big") + body
    if isinstance(obj, (list, tuple)):
        body = b"".join(canonical_bytes(v) for v in obj)
        tag = b"L" if isinstance(obj, list) else b"T"
        return tag + len(body).to_bytes(8, "big") + body
    if isinstance(obj, (set, frozenset)):
        body = b"".join(sorted(canonical_bytes(v) for v in obj))
        return b"E" + len(body).to_bytes(8, "big") + body
    # last resort: repr, tagged so it cannot collide with structured forms
    b = repr(obj).encode()
    return b"R" + len(b).to_bytes(4, "big") + b


# ---------------------------------------------------------------------------
# chunked xor-mix commitment (NumPy mirror of the Pallas chunk kernel)
# ---------------------------------------------------------------------------
_ON_TPU: Optional[bool] = None


def tpu_digest_backend() -> bool:
    """Whether the "auto" digest backend should route through Pallas.

    Probed ONCE per process: ``jax.default_backend()`` costs ~2ms per
    call, which dominated every ``state_root()``/seal digest on the hot
    path when probed inline (roots are per-window now — see
    prover.ProverFace._emit_window).  The device set cannot change
    mid-process, so caching is safe.
    """
    global _ON_TPU
    if _ON_TPU is None:
        try:
            import jax
            _ON_TPU = jax.default_backend() == "tpu"
        except Exception:  # pragma: no cover - jax is always in-tree
            _ON_TPU = False
    return _ON_TPU


def chunk_fold_digests(words: np.ndarray,
                       chunk: int = STATE_CHUNK_WORDS) -> np.ndarray:
    """Per-chunk xor-mix digests: (P,) u32 -> (ceil(P/chunk),) u32.

    Bit-exact NumPy mirror of ``kernels.rollup_digest.rollup_chunk_digests``
    (pinned by tests/test_state.py).  Zero padding folds away (zero words
    mix to zero), matching the kernel's padded tail chunk.
    """
    w = np.ascontiguousarray(words, dtype=np.uint32)
    if w.size == 0:
        return np.array([MIX_SEED], np.uint32)
    pad = (-w.size) % chunk
    if pad:
        w = np.concatenate([w, np.zeros(pad, np.uint32)])
    mixed = (w ^ (w >> np.uint32(16))) * MIX_MULT
    return MIX_SEED ^ np.bitwise_xor.reduce(mixed.reshape(-1, chunk), axis=1)


def _fold_digests(words: np.ndarray, chunk: int,
                  backend: str) -> np.ndarray:
    """Full per-chunk digest vector, routed by ``backend`` ("numpy" forces
    the mirror, "pallas" forces the kernel, "auto" probes the device)."""
    if backend != "numpy":
        use_pallas = backend == "pallas" or (backend == "auto"
                                             and tpu_digest_backend())
        if use_pallas and len(words):
            import jax.numpy as jnp
            from repro.kernels.rollup_digest import rollup_chunk_digests
            return np.asarray(rollup_chunk_digests(
                jnp.asarray(np.ascontiguousarray(words, np.uint32)),
                chunk_p=chunk))
    return chunk_fold_digests(words, chunk)


def _seal_digests(header: bytes, n_words: int, digests: np.ndarray) -> str:
    """One sha256 over the chunk digest vector + schema/length header."""
    h = hashlib.sha256()
    h.update(header)
    h.update(np.uint64(n_words).tobytes())
    h.update(np.ascontiguousarray(digests, np.uint32).tobytes())
    return h.hexdigest()[:32]


def chunked_root(words: np.ndarray, chunk: int = STATE_CHUNK_WORDS,
                 backend: str = "auto", header: bytes = b"") -> str:
    """Two-level commitment: per-chunk xor-mix digests (Pallas kernel on
    TPU, NumPy mirror elsewhere), sealed with one sha256 over the chunk
    digest vector + a schema/length header.  Returns a 32-hex root."""
    return _seal_digests(header, len(words), _fold_digests(words, chunk,
                                                           backend))


def _dirty_impl(backend: str) -> Optional[str]:
    """Map a digest-backend name onto a ``dirty_fold`` factory impl key
    (``None`` lets the factory's own auto/env selection decide)."""
    return backend if backend in ("numpy", "pallas") else None


# ---------------------------------------------------------------------------
# fixed-schema SoA account state
# ---------------------------------------------------------------------------
# (name, dtype) in commitment order — the schema IS part of the root header.
STATE_SCHEMA = (
    ("balances", np.float64),         # escrow-visible token balance
    ("stake", np.float64),            # locked collateral
    ("reputation", np.float32),       # R_i (Eq. 9-10), synced at settlement
    ("tasks_published", np.int64),    # publishTask count per account
    ("submissions", np.int64),        # submitLocalModel count per account
    ("rep_events", np.int64),         # calculate*Rep count per account
)


class StateArrays:
    """Fixed-schema SoA account state, indexed by ledger sender ids.

    Rows are accounts; the row index is the owning ledger's integer sender
    id (``LedgerBackend.sender_id``), so state handlers can scatter straight
    from a ``TxArrays`` view without any name lookups.  Arrays grow
    geometrically; only the filled prefix (``n``) is committed.

    Handler contract (see ledger.LedgerBackend.register_state): a handler
    is ``handler(state: StateArrays, txs: TxArrays-view)`` where the view
    holds ONLY the registered function's transactions, in confirmation
    order.  Handlers used under core/shards.py must be per-account
    commutative (counter/accumulator updates), so the merged state is
    independent of how transactions were partitioned across shards.
    """

    def __init__(self, n_accounts: int = 0):
        self.n = 0
        # incremental commitment (opt-in): caches of the committed word
        # buffer + per-chunk digest vector, refreshed by refolding only
        # the chunks covering rows marked dirty since the last seal.
        # OFF by default — engine faces opt in at register_state time, so
        # code that pokes the field arrays directly (tests, notebooks)
        # keeps the always-correct full refold.
        self._track_dirty = False
        self._commit_caches: Dict[Any, Dict[str, Any]] = {}
        cap = max(64, n_accounts)
        for name, dtype in STATE_SCHEMA:
            setattr(self, name, np.zeros(cap, dtype))
        if n_accounts:
            self.ensure(n_accounts)

    @property
    def capacity(self) -> int:
        return self.balances.shape[0]

    def ensure(self, n_accounts: int) -> None:
        """Grow the filled prefix to cover account ids < ``n_accounts``."""
        if n_accounts <= self.n:
            return
        if n_accounts > self.capacity:
            cap = max(n_accounts, 2 * self.capacity)
            for name, dtype in STATE_SCHEMA:
                old = getattr(self, name)
                new = np.zeros(cap, dtype)
                new[: self.n] = old[: self.n]
                setattr(self, name, new)
        # the commitment is field-major over the filled prefix: growing
        # ``n`` shifts every field's word offset, so cached buffers are
        # layout-stale — drop them and let the next root rebuild in full
        self._commit_caches.clear()
        self.n = n_accounts

    # -- dirty-row tracking ----------------------------------------------------
    def enable_dirty_tracking(self) -> None:
        """Opt this state into incremental commitment.  Callers take on
        the contract that EVERY write to the field arrays goes through a
        path that calls ``mark_dirty`` (the default handlers and the
        engine settlement paths do); direct array pokes after enabling
        would leave cached chunk digests stale."""
        self._track_dirty = True

    def mark_dirty(self, ids) -> None:
        """Record account rows whose fields changed since the last root.
        Cheap append; the unique/refold work happens at seal time."""
        if not self._track_dirty or not self._commit_caches:
            return
        ids = np.asarray(ids, np.int64)
        if ids.size:
            for cache in self._commit_caches.values():
                cache["pending"].append(ids)

    def ensure_ids(self, ids: np.ndarray) -> None:
        if len(ids):
            self.ensure(int(np.max(ids)) + 1)

    # -- commitment ------------------------------------------------------------
    def word_buffer(self) -> np.ndarray:
        """Canonical u32 word encoding of the filled prefix, schema order."""
        parts = []
        for name, _ in STATE_SCHEMA:
            a = np.ascontiguousarray(getattr(self, name)[: self.n])
            parts.append(a.view(np.uint8))
        blob = (np.concatenate(parts) if parts else
                np.zeros(0, np.uint8))
        pad = (-blob.size) % 4
        if pad:
            blob = np.concatenate([blob, np.zeros(pad, np.uint8)])
        return blob.view(np.uint32)

    def schema_header(self) -> bytes:
        return ";".join(f"{name}:{np.dtype(dt).str}"
                        for name, dt in STATE_SCHEMA).encode()

    def root(self, chunk: int = STATE_CHUNK_WORDS,
             backend: str = "auto") -> str:
        """Chunked Merkle-style state root (shard-count independent).

        With dirty tracking enabled the word buffer and per-chunk digest
        vector are cached; only the chunks covering rows touched since the
        last call are refolded (``kernels/dirty_fold``) before the sha256
        seal — O(touched) per window instead of O(state).  Pinned equal to
        the full refold by tests/test_state.py."""
        if not self._track_dirty:
            return chunked_root(self.word_buffer(), chunk, backend,
                                header=self.schema_header())
        cache = self._commit_caches.get(("flat", chunk))
        if cache is None:
            words = self.word_buffer()
            cache = {"words": words,
                     "digests": _fold_digests(words, chunk, backend),
                     "pending": []}
            self._commit_caches[("flat", chunk)] = cache
        elif cache["pending"]:
            rows = np.unique(np.concatenate(cache["pending"]))
            cache["pending"].clear()
            rows = rows[rows < self.n]
            if rows.size:
                touched = self._patch_rows(cache["words"], self.n,
                                           rows, rows)
                dirty = np.unique(touched // chunk)
                from repro.kernels.factory import get_kernel
                cache["digests"][dirty] = get_kernel(
                    "dirty_fold", _dirty_impl(backend))(
                        cache["words"], dirty, chunk)
        return _seal_digests(self.schema_header(), cache["words"].size,
                             cache["digests"])

    def _patch_rows(self, words: np.ndarray, m: int, rows: np.ndarray,
                    pos: np.ndarray) -> np.ndarray:
        """Overwrite the cached word buffer in place with the CURRENT
        field values of ``rows`` and return the touched word indices.

        ``words`` is a field-major encoding of ``m`` rows (``word_buffer``
        for the flat commitment, ``_rows_words`` for a partition);
        ``pos`` is each row's position within that row set.  Every schema
        dtype is 4- or 8-byte, so field blocks are word-aligned and a
        row's slot in field ``f`` is ``off_f + pos * itemsize//4``."""
        touched = []
        off = 0
        for name, dtype in STATE_SCHEMA:
            isw = np.dtype(dtype).itemsize // 4
            vals = np.ascontiguousarray(
                getattr(self, name)[rows]).view(np.uint32)
            idx = off + pos[:, None] * isw + np.arange(isw)
            words[idx] = vals.reshape(-1, isw)
            touched.append(idx.ravel())
            off += m * isw
        return np.concatenate(touched)

    def _rows_words(self, idx: np.ndarray) -> np.ndarray:
        """Canonical u32 words over the selected rows, schema order."""
        parts = []
        for name, _ in STATE_SCHEMA:
            parts.append(np.ascontiguousarray(
                getattr(self, name)[idx]).view(np.uint8))
        blob = np.concatenate(parts) if parts else np.zeros(0, np.uint8)
        pad = (-blob.size) % 4
        if pad:
            blob = np.concatenate([blob, np.zeros(pad, np.uint8)])
        return blob.view(np.uint32)

    def partition_roots(self, n_shards: int,
                        chunk: int = STATE_CHUNK_WORDS,
                        backend: str = "auto") -> List[str]:
        """All K per-shard roots in ONE ``account_owner`` pass.  Ownership
        is the same partition function hash routing uses — the shard that
        sequenced an account's txs is the shard whose root commits it.

        These are the per-shard commitments merged into the fabric root
        (core/shards.py); unlike ``root()`` they depend on the partition.
        With dirty tracking, each shard's word buffer + digest vector is
        cached and only its dirty chunks refold.
        """
        headers = [self.schema_header() + f"|shard={k}/{n_shards}".encode()
                   for k in range(n_shards)]
        if not self._track_dirty:
            owner = account_owner(np.arange(self.n), n_shards)
            return [chunked_root(
                self._rows_words(np.flatnonzero(owner == k)),
                chunk, backend, headers[k]) for k in range(n_shards)]
        cache = self._commit_caches.get(("part", n_shards, chunk))
        if cache is None:
            owner = account_owner(np.arange(self.n), n_shards)
            rows_k = [np.flatnonzero(owner == k) for k in range(n_shards)]
            words_k = [self._rows_words(r) for r in rows_k]
            cache = {"rows": rows_k, "words": words_k,
                     "digests": [_fold_digests(w, chunk, backend)
                                 for w in words_k],
                     "pending": []}
            self._commit_caches[("part", n_shards, chunk)] = cache
        elif cache["pending"]:
            rows = np.unique(np.concatenate(cache["pending"]))
            cache["pending"].clear()
            rows = rows[rows < self.n]
            if rows.size:
                from repro.kernels.factory import get_kernel
                fold = get_kernel("dirty_fold", _dirty_impl(backend))
                owner = account_owner(rows, n_shards)
                for k in range(n_shards):
                    rk = rows[owner == k]
                    if not rk.size:
                        continue
                    shard_rows = cache["rows"][k]
                    pos = np.searchsorted(shard_rows, rk)
                    touched = self._patch_rows(cache["words"][k],
                                               shard_rows.size, rk, pos)
                    dirty = np.unique(touched // chunk)
                    cache["digests"][k][dirty] = fold(
                        cache["words"][k], dirty, chunk)
        return [_seal_digests(headers[k], cache["words"][k].size,
                              cache["digests"][k])
                for k in range(n_shards)]

    def partition_root(self, shard: int, n_shards: int,
                       chunk: int = STATE_CHUNK_WORDS,
                       backend: str = "auto") -> str:
        """Single-shard form of ``partition_roots`` — folds ONLY the
        requested shard's rows (the K-root loop the old form paid for one
        answer), unless a tracked cache already amortizes all K."""
        if self._track_dirty and ("part", n_shards,
                                  chunk) in self._commit_caches:
            return self.partition_roots(n_shards, chunk, backend)[shard]
        owner = account_owner(np.arange(self.n), n_shards)
        return chunked_root(
            self._rows_words(np.flatnonzero(owner == shard)), chunk,
            backend,
            self.schema_header() + f"|shard={shard}/{n_shards}".encode())

    def copy(self) -> "StateArrays":
        out = StateArrays()
        out.ensure(self.n)
        for name, _ in STATE_SCHEMA:
            getattr(out, name)[: self.n] = getattr(self, name)[: self.n]
        return out


# ---------------------------------------------------------------------------
# default protocol state handlers (written once, run on every ledger face)
# ---------------------------------------------------------------------------
def _counter_handler(field: str):
    def handler(state: StateArrays, txs) -> None:
        state.ensure_ids(txs.sender_id)
        np.add.at(getattr(state, field), txs.sender_id, 1)
        state.mark_dirty(txs.sender_id)
    return handler


def default_state_handlers() -> Dict[str, Any]:
    """{fn: handler} for the Table-I protocol functions.

    Pure per-account accumulators — commutative, hence shard-count
    invariant (the core/shards.py handler contract).
    """
    return {
        "publishTask": _counter_handler("tasks_published"),
        "submitLocalModel": _counter_handler("submissions"),
        "calculateObjectiveRep": _counter_handler("rep_events"),
        "calculateSubjectiveRep": _counter_handler("rep_events"),
    }
