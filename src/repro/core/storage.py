"""IPFS-style content-addressed off-chain blob store (paper §III-C.4).

Model weights / task descriptions live off-chain; only their content ids
(hashes) go on the ledger.  Backed by an in-memory dict with an optional
on-disk spill directory (used by the checkpointer for model weights).
"""
from __future__ import annotations

import hashlib
import os
import pickle
from typing import Any, Dict, Optional


def content_id(blob: bytes) -> str:
    return "Qm" + hashlib.sha256(blob).hexdigest()[:44]


class BlobStore:
    def __init__(self, spill_dir: Optional[str] = None):
        self._mem: Dict[str, bytes] = {}
        self.spill_dir = spill_dir
        if spill_dir:
            os.makedirs(spill_dir, exist_ok=True)

    def put(self, obj: Any) -> str:
        blob = pickle.dumps(obj)
        cid = content_id(blob)
        if self.spill_dir:
            path = os.path.join(self.spill_dir, cid)
            if not os.path.exists(path):
                tmp = path + ".tmp"
                with open(tmp, "wb") as f:
                    f.write(blob)
                os.replace(tmp, path)     # atomic publish
        else:
            self._mem[cid] = blob
        return cid

    def get(self, cid: str) -> Any:
        if self.spill_dir:
            with open(os.path.join(self.spill_dir, cid), "rb") as f:
                blob = f.read()
        else:
            blob = self._mem[cid]
        assert content_id(blob) == cid, "content hash mismatch (tampering?)"
        return pickle.loads(blob)

    def has(self, cid: str) -> bool:
        if self.spill_dir:
            return os.path.exists(os.path.join(self.spill_dir, cid))
        return cid in self._mem
