"""FL task lifecycle smart contracts (TSC): publishTask (paper Algo. 1),
selectTrainers, submitLocalModel (Algo. 2) — executed against the chain or
rollup state dict, with role checks (ASC) and escrow hooks (DSC)."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import numpy as np

from repro.core.escrow import Escrow
from repro.core.ledger import AccessControl, Tx
from repro.core.storage import BlobStore


@dataclasses.dataclass
class Task:
    task_id: str
    model_cid: str          # IPFS-style content id of the model architecture
    description_cid: str
    publisher: str
    rounds_total: int
    required_accuracy: float
    reward: float
    trainers: List[str] = dataclasses.field(default_factory=list)
    current_round: int = 0
    state: str = "selection"     # selection -> training -> evaluated -> closed
    # per-round: {round: {trainer: model_cid}}
    models: Dict[int, Dict[str, str]] = dataclasses.field(default_factory=dict)
    scores: Dict[str, float] = dataclasses.field(default_factory=dict)


class TaskContract:
    """TSC bound to an access controller, escrow and blob store."""

    def __init__(self, acl: AccessControl, escrow: Escrow, store: BlobStore):
        self.acl = acl
        self.escrow = escrow
        self.store = store
        self.tasks: Dict[str, Task] = {}

    # Algo. 1 -------------------------------------------------------------------
    def publish_task(self, sender: str, task_id: str, model_cid: str,
                     description_cid: str, rounds_total: int,
                     required_accuracy: float, reward: float) -> Task:
        assert self.acl.has_role(sender, "task_publisher"), \
            "isTaskPublisher(msg.sender) failed"
        assert task_id not in self.tasks, "duplicate taskId"
        # false-reporting guard: reward locked up-front in the DSC
        self.escrow.deposit(sender, task_id, reward)
        task = Task(task_id, model_cid, description_cid, sender,
                    rounds_total, required_accuracy, reward)
        self.tasks[task_id] = task
        return task

    # trainer selection (reputation-ranked, on-chain) -----------------------------
    def select_trainers(self, task_id: str, reputations,
                        n_select: int, min_rep: float = 0.0,
                        trainer_ids: Optional[List[str]] = None) -> List[str]:
        """Rank trainers by reputation; ties break by stable trainer index
        (dict insertion / array position), never by id-string order.

        ``reputations`` is either {trainer_id: rep} or an array aligned with
        ``trainer_ids`` — the array form is the scheduler hot path (the
        reputation book is already a vector; no dict roundtrip).
        """
        task = self.tasks[task_id]
        assert task.state == "selection"
        if isinstance(reputations, dict):
            assert trainer_ids is None, "trainer_ids implied by the dict"
            trainer_ids = list(reputations)
            reps = np.asarray(list(reputations.values()), np.float64)
        else:
            reps = np.asarray(reputations, np.float64)
            assert trainer_ids is not None and len(trainer_ids) == len(reps)
        ok = np.array([self.acl.has_role(t, "trainer")
                       for t in trainer_ids], bool) & (reps >= min_rep)
        idx = np.flatnonzero(ok)
        # stable sort on -rep: equal reputations keep ascending index order
        order = idx[np.argsort(-reps[idx], kind="stable")]
        task.trainers = [trainer_ids[i] for i in order[:n_select]]
        task.state = "training"
        return task.trainers

    # Algo. 2 --------------------------------------------------------------------
    def submit_local_model(self, sender: str, task_id: str, round_: int,
                           local_model_cid: str):
        task = self.tasks[task_id]
        assert sender in task.trainers, "isTrainerInTask failed"
        assert task.state == "training"
        assert self.store.has(local_model_cid), "model blob not on IPFS"
        task.models.setdefault(round_, {})[sender] = local_model_cid

    def submitted(self, task_id: str, round_: int, trainer: str) -> bool:
        return trainer in self.tasks[task_id].models.get(round_, {})

    def advance_round(self, task_id: str):
        task = self.tasks[task_id]
        task.current_round += 1
        if task.current_round >= task.rounds_total:
            task.state = "evaluated"

    def record_scores(self, task_id: str, scores: Dict[str, float]):
        task = self.tasks[task_id]
        task.scores.update(scores)

    def close_task(self, task_id: str) -> Dict[str, float]:
        """Settle rewards proportionally to final scores (free-riding guard:
        zero-score trainers get nothing; their collateral is slashed)."""
        task = self.tasks[task_id]
        assert task.state == "evaluated"
        payouts = self.escrow.settle(task.task_id, task.scores)
        task.state = "closed"
        return payouts

    # chain-handler adapters (state-dict form used by Chain/Rollup) --------------
    @staticmethod
    def handler_publish(state: Dict[str, Any], tx: Tx):
        state.setdefault("tasks", {})[tx.payload.get("taskId", tx.tx_id)] = {
            "publisher": tx.sender, "state": "selection", "round": 0}

    @staticmethod
    def handler_submit(state: Dict[str, Any], tx: Tx):
        t = state.setdefault("models", {})
        key = (tx.payload.get("taskId", "t0"), tx.payload.get("round", 0))
        t.setdefault(str(key), {})[tx.sender] = tx.payload.get("cid", "")

    @staticmethod
    def handler_obj_rep(state: Dict[str, Any], tx: Tx):
        state.setdefault("o_rep", {})[tx.sender] = tx.payload.get("value", 0.0)

    @staticmethod
    def handler_subj_rep(state: Dict[str, Any], tx: Tx):
        state.setdefault("s_rep", {})[tx.sender] = tx.payload.get("value", 0.0)

    # batched adapters (vector engine, engine.VectorChain.register_batch):
    # one call per (block, fn) updating aggregate counters from the SoA view
    # instead of one Python call per tx.
    @staticmethod
    def batch_counter(fn: str):
        """Handler counting confirmed calls of ``fn`` per fn and per sender."""

        def handler(state: Dict[str, Any], n: int, view) -> None:
            calls = state.setdefault("calls", {})
            calls[fn] = calls.get(fn, 0) + n
            fid = view.fns.id(fn)
            senders = view.sender_id[view.fn_id == fid]
            per = state.setdefault("calls_by_sender", {}).setdefault(fn, {})
            for sid, cnt in zip(*np.unique(senders, return_counts=True)):
                per[int(sid)] = per.get(int(sid), 0) + int(cnt)
        return handler

    @classmethod
    def register_batch_handlers(cls, chain, fns=None) -> None:
        """Wire counting adapters for the Table-I functions (or ``fns``)
        onto a VectorChain."""
        from repro.core.gas import FUNCTIONS
        for fn in (fns or FUNCTIONS):
            chain.register_batch(fn, cls.batch_counter(fn))
