"""Scenario workload generator for the L1/L2 transaction engines.

Every generator is seedable and returns a ``Workload`` — a time-sorted
``TxArrays`` batch plus metadata — consumed by ``ledger.simulate_load`` /
``simulate_workload`` and by the benchmarks.  Sorting by submit time is the
documented guard against head-of-line blocking skew: both engines pack
blocks FIFO in *submission* order and stall at the first future-timestamped
tx (see engine.VectorChain.produce_block), so workloads always submit in
nondecreasing time order.

Catalog (`SCENARIOS`):
  poisson      — steady-state Poisson arrivals of one function type
  bursty       — baseline Poisson + flash-crowd burst windows
  diurnal      — sinusoidally modulated rate (day/night cycle), via thinning
  mixed        — Table-I function mix at one aggregate rate
  spam         — honest baseline + adversarial spam flood of the cheapest
                 function from a handful of senders
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import numpy as np

from repro.core.engine import FnRegistry, TxArrays
from repro.core.gas import DEFAULT_GAS, GasTable

# Table-I-flavoured function mix: model submissions dominate a round, with
# objective/subjective reputation updates trailing and rare task publishes.
TABLE_I_MIX: Dict[str, float] = {
    "publishTask": 0.02,
    "submitLocalModel": 0.55,
    "calculateObjectiveRep": 0.28,
    "calculateSubjectiveRep": 0.15,
}


@dataclasses.dataclass
class Workload:
    name: str
    txs: TxArrays               # sorted by submit_time
    duration: float
    seed: int
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def __len__(self) -> int:
        return len(self.txs)

    def to_txs(self):
        """Materialize object ``Tx``s for the compatibility engine path."""
        from repro.core.ledger import Tx
        a = self.txs
        return [Tx(a.fns.names[a.fn_id[i]], f"client{int(a.sender_id[i])}",
                   {}, int(a.gas[i]), float(a.submit_time[i]))
                for i in range(len(a))]


def _assemble(name: str, times: np.ndarray, fn_ids: np.ndarray,
              senders: np.ndarray, fns: FnRegistry, gas_table: GasTable,
              duration: float, seed: int, **meta) -> Workload:
    from repro.core.gas import l1_gas_vector
    order = np.argsort(times, kind="stable")
    gas_vec = l1_gas_vector(fns.names, gas_table)
    txs = TxArrays(times[order], gas_vec[fn_ids[order]],
                   fn_ids[order].astype(np.int32),
                   senders[order].astype(np.int32), fns)
    return Workload(name, txs, duration, seed, dict(meta))


def _poisson_times(rng, rate: float, duration: float) -> np.ndarray:
    n = rng.poisson(rate * duration)
    return rng.uniform(0.0, duration, n)


def poisson_workload(rate: float, duration: float = 30.0,
                     fn: str = "submitLocalModel", seed: int = 0,
                     n_senders: int = 64,
                     gas_table: GasTable = DEFAULT_GAS) -> Workload:
    """Steady-state Poisson arrivals of one function type."""
    rng = np.random.default_rng(seed)
    times = _poisson_times(rng, rate, duration)
    fns = FnRegistry([fn])
    return _assemble("poisson", times, np.zeros(len(times), np.int32),
                     rng.integers(0, n_senders, len(times)), fns, gas_table,
                     duration, seed, rate=rate, fn=fn)


def bursty_workload(base_rate: float, burst_rate: float,
                    duration: float = 30.0, burst_start: float = 10.0,
                    burst_len: float = 5.0, fn: str = "submitLocalModel",
                    seed: int = 0, n_senders: int = 64,
                    gas_table: GasTable = DEFAULT_GAS) -> Workload:
    """Flash crowd: Poisson baseline plus a burst window at burst_rate."""
    rng = np.random.default_rng(seed)
    t_base = _poisson_times(rng, base_rate, duration)
    burst_start = min(burst_start, duration)
    burst_len = min(burst_len, duration - burst_start)   # clip to window
    n_burst = rng.poisson(max(0.0, burst_rate - base_rate) * burst_len)
    t_burst = burst_start + rng.uniform(0.0, burst_len, n_burst)
    times = np.concatenate([t_base, t_burst])
    fns = FnRegistry([fn])
    return _assemble("bursty", times, np.zeros(len(times), np.int32),
                     rng.integers(0, n_senders, len(times)), fns, gas_table,
                     duration, seed, base_rate=base_rate,
                     burst_rate=burst_rate, burst_start=burst_start,
                     burst_len=burst_len, fn=fn)


def diurnal_workload(mean_rate: float, duration: float = 30.0,
                     period: Optional[float] = None, depth: float = 0.8,
                     fn: str = "submitLocalModel", seed: int = 0,
                     n_senders: int = 64,
                     gas_table: GasTable = DEFAULT_GAS) -> Workload:
    """Sinusoidal day/night rate via Poisson thinning:
    lambda(t) = mean_rate * (1 + depth * sin(2 pi t / period))."""
    assert 0.0 <= depth <= 1.0
    rng = np.random.default_rng(seed)
    period = period or duration
    peak = mean_rate * (1.0 + depth)
    cand = _poisson_times(rng, peak, duration)
    lam = mean_rate * (1.0 + depth * np.sin(2 * np.pi * cand / period))
    keep = cand[rng.uniform(0.0, peak, len(cand)) < lam]
    fns = FnRegistry([fn])
    return _assemble("diurnal", keep, np.zeros(len(keep), np.int32),
                     rng.integers(0, n_senders, len(keep)), fns, gas_table,
                     duration, seed, mean_rate=mean_rate, period=period,
                     depth=depth, fn=fn)


def mixed_function_workload(rate: float, duration: float = 30.0,
                            mix: Optional[Dict[str, float]] = None,
                            seed: int = 0, n_senders: int = 64,
                            gas_table: GasTable = DEFAULT_GAS) -> Workload:
    """Aggregate Poisson rate split across the Table-I function mix."""
    mix = mix or TABLE_I_MIX
    rng = np.random.default_rng(seed)
    times = _poisson_times(rng, rate, duration)
    fns = FnRegistry(mix.keys())
    p = np.array(list(mix.values()), np.float64)
    p = p / p.sum()
    fn_ids = rng.choice(len(p), size=len(times), p=p)
    return _assemble("mixed", times, fn_ids.astype(np.int32),
                     rng.integers(0, n_senders, len(times)), fns, gas_table,
                     duration, seed, rate=rate, mix=dict(mix))


def adversarial_spam_workload(honest_rate: float, spam_rate: float,
                              duration: float = 30.0,
                              spam_start: float = 5.0,
                              spam_len: float = 10.0,
                              fn: str = "submitLocalModel",
                              spam_fn: str = "calculateSubjectiveRep",
                              n_spammers: int = 4, seed: int = 0,
                              n_senders: int = 64,
                              gas_table: GasTable = DEFAULT_GAS) -> Workload:
    """Adversarial spam: a few senders flood the cheapest function during a
    window, racing honest traffic for block gas."""
    rng = np.random.default_rng(seed)
    t_h = _poisson_times(rng, honest_rate, duration)
    spam_start = min(spam_start, duration)
    spam_len = min(spam_len, duration - spam_start)      # clip to window
    n_s = rng.poisson(spam_rate * spam_len)
    t_s = spam_start + rng.uniform(0.0, spam_len, n_s)
    fns = FnRegistry([fn, spam_fn])
    times = np.concatenate([t_h, t_s])
    fn_ids = np.concatenate([np.zeros(len(t_h), np.int32),
                             np.full(n_s, fns.id(spam_fn), np.int32)])
    senders = np.concatenate([
        rng.integers(n_spammers, n_spammers + n_senders, len(t_h)),
        rng.integers(0, n_spammers, n_s)])
    return _assemble("spam", times, fn_ids, senders, fns, gas_table,
                     duration, seed, honest_rate=honest_rate,
                     spam_rate=spam_rate, spam_fn=spam_fn,
                     n_spammers=n_spammers)


SCENARIOS: Dict[str, Callable[..., Workload]] = {
    "poisson": poisson_workload,
    "bursty": lambda rate, **kw: bursty_workload(
        base_rate=rate, burst_rate=4.0 * rate, **kw),
    "diurnal": lambda rate, **kw: diurnal_workload(mean_rate=rate, **kw),
    "mixed": mixed_function_workload,
    "spam": lambda rate, **kw: adversarial_spam_workload(
        honest_rate=rate, spam_rate=4.0 * rate, **kw),
}


def make_workload(name: str, rate: float, duration: float = 30.0,
                  seed: int = 0, **kw) -> Workload:
    try:
        factory = SCENARIOS[name]
    except KeyError:
        raise KeyError(f"unknown scenario {name!r}; "
                       f"catalog: {sorted(SCENARIOS)}") from None
    return factory(rate, duration=duration, seed=seed, **kw)
