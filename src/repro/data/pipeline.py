"""Sharded input pipeline: host-side prefetch + device placement.

At pod scale each host feeds only its mesh addressable slice; here the same
code path runs with the degenerate single-host mesh.  Deterministic seeding
per (client, round) makes FL rounds reproducible across restarts — required
for the checkpoint/restart fault-tolerance contract.
"""
from __future__ import annotations

import collections
import threading
from typing import Callable, Dict, Iterator, Optional

import jax
import numpy as np


class Prefetcher:
    """Background-thread prefetch of host batches onto device."""

    def __init__(self, it: Iterator, depth: int = 2, sharding=None):
        self._it = it
        self._sharding = sharding
        self._q: collections.deque = collections.deque()
        self._depth = depth
        self._lock = threading.Lock()
        self._err: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._stop = False
        self._sem = threading.Semaphore(0)
        self._thread.start()

    def _fill(self):
        try:
            for batch in self._it:
                if self._stop:
                    return
                if self._sharding is not None:
                    batch = jax.device_put(batch, self._sharding)
                else:
                    batch = jax.device_put(batch)
                while len(self._q) >= self._depth and not self._stop:
                    threading.Event().wait(0.002)
                with self._lock:
                    self._q.append(batch)
                self._sem.release()
        except BaseException as e:  # noqa: BLE001 — surfaced on next()
            self._err = e
            self._sem.release()

    def __iter__(self):
        return self

    def __next__(self):
        self._sem.acquire()
        if self._err is not None:
            raise self._err
        with self._lock:
            return self._q.popleft()

    def close(self):
        self._stop = True


def client_batch_fn(xs: np.ndarray, ys: np.ndarray, parts,
                    batch_size: int) -> Callable[[int, int], Dict]:
    """Deterministic (client, round) -> batch selector over a partition."""
    def get(client: int, rnd: int) -> Dict[str, np.ndarray]:
        idx = parts[client]
        rng = np.random.default_rng(hash((client, rnd)) % (2 ** 32))
        pick = rng.choice(idx, size=min(batch_size, len(idx)), replace=False)
        return {"images": xs[pick], "labels": ys[pick]}
    return get
