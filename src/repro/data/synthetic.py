"""Synthetic data generators: token streams for LM smoke/bench runs and an
MNIST-like image set for the paper's LeNet-5 FL workload (offline container:
the real MNIST download is unavailable; the generator reproduces its format
and a learnable class structure)."""
from __future__ import annotations

from typing import Dict, Iterator, Tuple

import numpy as np


def token_batches(vocab_size: int, batch: int, seq: int, seed: int = 0
                  ) -> Iterator[Dict[str, np.ndarray]]:
    """Zipf-ish token stream with next-token labels (shifted inputs)."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, vocab_size + 1)
    probs = 1.0 / ranks ** 1.1
    probs /= probs.sum()
    while True:
        toks = rng.choice(vocab_size, size=(batch, seq + 1), p=probs)
        yield {"tokens": toks[:, :-1].astype(np.int32),
               "labels": toks[:, 1:].astype(np.int32)}


def gaussian_clusters(n: int, d: int = 64, n_classes: int = 10,
                      seed: int = 0, centers_seed: int = 0,
                      noise: float = 0.7) -> Tuple[np.ndarray, np.ndarray]:
    """Feature-vector classification data: one gaussian blob per class.

    Learnable by a tiny MLP within a handful of steps — the workload of the
    protocol-layer benchmark and scheduler tests, where FL compute must not
    mask protocol costs.  ``centers_seed`` fixes the class geometry so
    train/val splits drawn with different ``seed``s share it.
    """
    centers = np.random.default_rng(centers_seed).normal(
        0.0, 1.0, (n_classes, d)).astype(np.float32)
    g = np.random.default_rng(seed)
    labels = g.integers(0, n_classes, n).astype(np.int32)
    xs = centers[labels] + g.normal(0.0, noise, (n, d)).astype(np.float32)
    return xs.astype(np.float32), labels


def make_mnist_like(n: int = 4096, seed: int = 0,
                    image_size: int = 32) -> Tuple[np.ndarray, np.ndarray]:
    """10-class 'digit' dataset: class-dependent stroke patterns + noise.

    Learnable by LeNet-5 within a few hundred steps (validated in
    tests/test_fl_e2e.py) — serves as the MNIST stand-in for Fig. 3 and the
    end-to-end FL example.
    """
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 10, n).astype(np.int32)
    xs = rng.normal(0.0, 0.15, (n, image_size, image_size, 1)).astype(np.float32)
    yy, xx = np.mgrid[0:image_size, 0:image_size].astype(np.float32) / image_size
    for c in range(10):
        idx = np.where(labels == c)[0]
        ang = 2 * np.pi * c / 10.0
        # class-specific oriented stripe + offset blob
        stripe = np.sin(8.0 * (np.cos(ang) * xx + np.sin(ang) * yy))
        cx = 0.3 + 0.4 * np.cos(ang) * 0.5 + 0.2
        cy = 0.3 + 0.4 * np.sin(ang) * 0.5 + 0.2
        blob = np.exp(-(((xx - cx) ** 2 + (yy - cy) ** 2) / 0.02))
        pattern = (stripe * 0.6 + blob * 1.2)[None, :, :, None]
        jitter = rng.normal(1.0, 0.1, (len(idx), 1, 1, 1)).astype(np.float32)
        xs[idx] += (pattern * jitter).astype(np.float32)
    return xs, labels
