"""FL training agent (TA): local training + DP + submission via IPFS/ledger.

This is the host-orchestration face used by the paper-faithful LeNet-5/MNIST
example; the pod-scale face is the jitted fl/round.py.  Behaviour profiles
(good / malicious / lazy) implement the paper's §VI-C experiment.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.storage import BlobStore
from repro.fl.dp import DPConfig, privatize


@dataclasses.dataclass
class ClientConfig:
    client_id: str
    behavior: str = "good"            # good | malicious | lazy
    lazy_skip_range: tuple = (0.4, 0.6)  # fraction of rounds skipped
    local_steps: int = 4
    dp: DPConfig = dataclasses.field(default_factory=DPConfig)


class TrainingAgent:
    def __init__(self, cfg: ClientConfig, model, opt, store: BlobStore,
                 batch_fn: Callable[[int, int], Dict], seed: int = 0):
        self.cfg = cfg
        self.model = model
        self.opt = opt
        self.store = store
        self.batch_fn = batch_fn
        self.rng = np.random.default_rng(seed)
        self.key = jax.random.key(seed)

        def local_step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(
                lambda p: self.model.loss(p, batch))(params)
            params, opt_state, gn = self.opt.update(grads, opt_state, params)
            return params, opt_state, loss
        self._local_step = jax.jit(local_step)

    def participate(self, rnd: int) -> bool:
        if self.cfg.behavior == "lazy":
            lo, hi = self.cfg.lazy_skip_range
            return self.rng.random() > self.rng.uniform(lo, hi)
        return True

    def train_round(self, global_params, opt_state, client_idx: int,
                    rnd: int) -> Optional[Dict]:
        """One FL round: returns {cid, params, opt_state} or None if skipped."""
        if not self.participate(rnd):
            return None
        if self.cfg.behavior == "malicious":
            # free-riding: arbitrary weights, no actual training
            self.key, k = jax.random.split(self.key)
            fake = jax.tree.map(
                lambda p: jax.random.normal(k, p.shape, jnp.float32)
                .astype(p.dtype) * 0.1, global_params)
            cid = self.store.put(jax.tree.map(np.asarray, fake))
            return {"cid": cid, "params": fake, "opt_state": opt_state}

        params = global_params
        loss = None
        for s in range(self.cfg.local_steps):
            batch = self.batch_fn(client_idx, rnd * 1000 + s)
            params, opt_state, loss = self._local_step(params, opt_state,
                                                       batch)
        # differential privacy on the submitted update (w' = w + n)
        self.key, k = jax.random.split(self.key)
        update = jax.tree.map(lambda a, b: a - b, params, global_params)
        noised_update, _ = privatize(k, update, self.cfg.dp)
        submitted = jax.tree.map(lambda g, u: g + u, global_params,
                                 noised_update)
        cid = self.store.put(jax.tree.map(np.asarray, submitted))
        return {"cid": cid, "params": submitted, "opt_state": opt_state,
                "loss": None if loss is None else float(loss)}
