"""Trainer cohorts — the training face a TaskRuntime drives each round.

Two implementations of one interface:

  * ``AgentCohort`` wraps a list of ``TrainingAgent``s and preserves the
    legacy per-trainer Python loop exactly (object path; behaviour-rich
    small-N debugging and the equivalence baseline).
  * ``VectorCohort`` is the SoA hot path: the whole cohort trains in ONE
    vmapped dispatch per round (the ``local_steps`` scan idiom from
    fl/round.py), with behaviour profiles (malicious / lazy) applied as
    vectorized masks and DP noise drawn with per-trainer keys under one
    vmap.  This replaces the O(trainers) ``agent.train_round`` loop that
    dominated ``AutoDFL.run_task`` wall time.

Both return a ``CohortSubmissions`` whose params are STACKED (leading
trainer axis), so the DON scoring pass (core/oracle.py) and the Eq. 1
aggregation consume them without restacking.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.storage import BlobStore
from repro.fl.dp import DPConfig, privatize


@dataclasses.dataclass
class CohortSubmissions:
    """One round's submissions: sorted cohort indices + stacked params."""

    idxs: List[int]          # cohort indices that submitted, ascending
    stacked: Any             # pytree, leaves (len(idxs), ...) in idx order
    cids: Dict[int, str]     # per-idx content id of the submitted blob

    def tree_for(self, k: int):
        """Per-trainer view (k indexes ``idxs``, not the cohort)."""
        return jax.tree.map(lambda l: l[k], self.stacked)


class AgentCohort:
    """Legacy cohort: one ``TrainingAgent.train_round`` call per trainer.

    Semantics (participation RNG streams, DP keys, blob puts) are identical
    to the pre-scheduler ``AutoDFL.run_task`` loop — this path anchors the
    single-task equivalence test.
    """

    def __init__(self, agents: Sequence):
        self.agents = list(agents)
        self._opt: Dict[int, Any] = {}

    def __len__(self) -> int:
        return len(self.agents)

    def start_task(self, global_params, opt, sel_idx: Sequence[int]):
        self._opt = {i: opt.init(global_params) for i in sel_idx}

    def train(self, global_params, rnd: int,
              sel_idx: Sequence[int]) -> Optional[CohortSubmissions]:
        subs: Dict[int, Dict] = {}
        for i in sel_idx:
            out = self.agents[i].train_round(global_params, self._opt[i],
                                             i, rnd)
            if out is None:
                continue
            self._opt[i] = out["opt_state"]
            subs[i] = out
        if not subs:
            return None
        idxs = sorted(subs)
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs),
                               *[subs[i]["params"] for i in idxs])
        return CohortSubmissions(idxs, stacked,
                                 {i: subs[i]["cid"] for i in idxs})


def batched_batch_fn(raw_batch_fn: Callable[[int, int], Dict],
                     local_steps: int) -> Callable:
    """Adapt a per-(client, round) batch fn to the VectorCohort signature
    ``fn(sel_idx: ndarray, rnd) -> leaves (K, H, ...)`` by host-side
    stacking.  Convenience shim — pass a natively batched fn for the zero-
    Python-loop path."""
    def fn(sel_idx: np.ndarray, rnd: int) -> Dict:
        per = [[raw_batch_fn(int(i), rnd * 1000 + s)
                for s in range(local_steps)] for i in sel_idx]
        keys = per[0][0].keys()
        return {k: jnp.stack([jnp.stack([np.asarray(b[k]) for b in row])
                              for row in per]) for k in keys}
    return fn


def _bucket(n: int, floor: int = 1) -> int:
    """Round ``n`` up to its power-of-two bucket (the kernels/block_pack.py
    idiom) so one compiled mega program serves every nearby task count."""
    return max(floor, 1 << max(0, (int(n) - 1).bit_length()))


class CohortKernels:
    """Jitted cohort-step kernels, shared across every VectorCohort built on
    the same (model, opt, dp) — N concurrent tasks then compile ONCE (a
    per-cohort jit would recompile identical XLA programs N times)."""

    def __init__(self, model, opt, dp: DPConfig = DPConfig()):
        def local_steps_one(params, opt_state, trainer_batch):
            # the fl/round.py idiom: H sequential steps for ONE trainer,
            # lifted over the cohort by the vmap below
            def one(carry, batch):
                p, o = carry
                loss, grads = jax.value_and_grad(
                    lambda pp: model.loss(pp, batch))(p)
                p, o, _ = opt.update(grads, o, p)
                return (p, o), loss
            (params, opt_state), losses = jax.lax.scan(
                one, (params, opt_state), trainer_batch)
            return params, opt_state, jnp.mean(losses)

        def fake_one(k, params):
            return jax.tree.map(
                lambda p: (jax.random.normal(k, p.shape, jnp.float32)
                           .astype(p.dtype) * 0.1), params)

        def round_step(params, opt_state, batches, base_key, rnd,
                       mal_mask, keep_mask, use_fake):
            """The WHOLE round for a cohort as one fused program: H local
            steps per trainer (vmapped), DP on the submitted update,
            malicious-weight overwrite and opt-state keep masks — a single
            dispatch instead of ~10 eager ops per param leaf.  Per-round,
            per-trainer keys derive from (base_key, rnd) INSIDE the program
            (an eager ``random.split`` chain costs ~ms per round on CPU)."""
            n = jax.tree.leaves(opt_state)[0].shape[0]
            k_dp, k_fake = jax.random.split(
                jax.random.fold_in(base_key, rnd))
            dp_keys = jax.random.split(k_dp, n)
            fake_keys = jax.random.split(k_fake, n)
            new_p, new_o, loss = jax.vmap(
                local_steps_one, in_axes=(None, 0, 0))(params, opt_state,
                                                       batches)
            update = jax.tree.map(lambda a, b: a - b[None], new_p, params)
            noised = jax.vmap(lambda k, u: privatize(k, u, dp)[0])(
                dp_keys, update)
            submitted = jax.tree.map(lambda g, u: g[None] + u, params,
                                     noised)
            if use_fake:
                fake = jax.vmap(fake_one, in_axes=(0, None))(fake_keys,
                                                             params)
                submitted = jax.tree.map(
                    lambda f, s: jnp.where(
                        mal_mask.reshape((-1,) + (1,) * (s.ndim - 1)), f, s),
                    fake, submitted)
            new_o = jax.tree.map(
                lambda new, old: jnp.where(
                    keep_mask.reshape((-1,) + (1,) * (new.ndim - 1)), new,
                    old), new_o, opt_state)
            return submitted, new_o, loss
        self.round_step = jax.jit(round_step,
                                  static_argnames=("use_fake",))
        self._round_step_fn = round_step     # raw form for the mega vmap
        self._mega_step = None

    def mega_round_step(self):
        """``vmap(tasks) ∘ round_step`` — T whole cohort rounds as ONE
        jitted dispatch (MegaCohort).  Row t of every output is bit-exact
        equal to ``round_step`` on task t's inputs alone: the per-trainer
        programs are element-wise independent along the new task axis."""
        if self._mega_step is None:
            fn = self._round_step_fn

            def mega(params, opt_state, batches, base_keys, rnds,
                     mal_masks, keep_masks, use_fake):
                return jax.vmap(
                    lambda p, o, b, k, r, m, kp: fn(p, o, b, k, r, m, kp,
                                                    use_fake))(
                    params, opt_state, batches, base_keys, rnds,
                    mal_masks, keep_masks)
            self._mega_step = jax.jit(mega, static_argnames=("use_fake",))
        return self._mega_step


class VectorCohort:
    """Vectorized cohort: one jitted vmap(local_steps) dispatch per round.

    behaviors: per-trainer profile strings ("good" | "malicious" | "lazy"),
    matching fl/client.py semantics — malicious submits random weights
    without training, lazy skips a round with probability drawn from
    ``lazy_skip_range``.
    batch_fn(sel_idx, rnd) -> batch dict with leaves (K, H, local_B, ...)
    (H = local optimizer steps; see ``batched_batch_fn``).
    kernels: shared CohortKernels (pass one instance to all cohorts of a
    multi-task run; built on demand otherwise).
    """

    def __init__(self, model, opt, batch_fn: Callable, store: BlobStore,
                 behaviors: Optional[Sequence[str]] = None,
                 n_trainers: Optional[int] = None, local_steps: int = 4,
                 dp: DPConfig = DPConfig(),
                 lazy_skip_range=(0.4, 0.6), seed: int = 0,
                 kernels: Optional[CohortKernels] = None):
        if behaviors is None:
            assert n_trainers is not None, "need behaviors or n_trainers"
            behaviors = ["good"] * n_trainers
        self.behaviors = list(behaviors)
        self.model = model
        self.opt = opt
        self.batch_fn = batch_fn
        self.store = store
        self.local_steps = local_steps
        self.dp = dp
        self.lazy_skip_range = lazy_skip_range
        self.rng = np.random.default_rng(seed)
        self.key = jax.random.key(seed)
        self.is_lazy = np.array([b == "lazy" for b in self.behaviors])
        self.is_malicious = np.array(
            [b == "malicious" for b in self.behaviors])
        self.kernels = kernels or CohortKernels(model, opt, dp)
        self._opt = None           # stacked opt state over selected trainers
        self._opt_holder = None    # MegaCohort currently holding _opt
        self._round_counter = 0

    def __len__(self) -> int:
        return len(self.behaviors)

    def start_task(self, global_params, opt, sel_idx: Sequence[int]):
        if self._opt_holder is not None:
            self._opt_holder.flush_opt()
        k = len(sel_idx)
        o = opt.init(global_params)
        # one broadcast dispatch per leaf — jnp.stack([l] * k) built k
        # device arrays per leaf and dominated multi-task select windows
        self._opt = jax.tree.map(
            lambda l: jnp.broadcast_to(l, (k,) + l.shape), o)

    def _participation(self, sel_idx: np.ndarray) -> np.ndarray:
        lazy = self.is_lazy[sel_idx]
        r = self.rng.random(len(sel_idx))
        lo, hi = self.lazy_skip_range
        u = self.rng.uniform(lo, hi, len(sel_idx))
        return ~lazy | (r > u)

    def train(self, global_params, rnd: int,
              sel_idx: Sequence[int]) -> Optional[CohortSubmissions]:
        if self._opt_holder is not None:
            # a megastep holds this cohort's opt state stacked on its task
            # axis; reclaim it before stepping per-task
            self._opt_holder.flush_opt()
        sel = np.asarray(sel_idx)
        part = self._participation(sel)
        if not part.any():
            return None
        batches = self.batch_fn(sel, rnd)
        # malicious rows submit random weights without training (free-
        # riding); their opt state must not advance, nor must lazy skips'
        mal = self.is_malicious[sel]
        submitted, self._opt, _loss = self.kernels.round_step(
            global_params, self._opt, batches, self.key,
            np.uint32(self._round_counter), jnp.asarray(mal),
            jnp.asarray(part & ~mal), use_fake=bool(mal.any()))
        self._round_counter += 1

        if part.all():
            sub_pos = np.argsort(sel)             # CohortSubmissions order
            stacked = (submitted if np.array_equal(sub_pos,
                                                   np.arange(len(sel)))
                       else jax.tree.map(lambda l: l[sub_pos], submitted))
        else:
            sub_pos = np.flatnonzero(part)
            sub_pos = sub_pos[np.argsort(sel[sub_pos])]
            stacked = jax.tree.map(lambda l: l[sub_pos], submitted)
        cid = self.store.put(jax.tree.map(np.asarray, stacked))
        idxs = [int(i) for i in sel[sub_pos]]
        return CohortSubmissions(idxs, stacked, {i: cid for i in idxs})


@functools.lru_cache(maxsize=64)
def _stack_fn(n: int):
    """Jitted n-tree stack: ONE dispatch instead of an eager per-leaf
    ``jnp.stack`` fan-out (the megastep assembles stacks every window)."""
    return jax.jit(lambda *ts: jax.tree.map(lambda *xs: jnp.stack(xs), *ts))


@functools.lru_cache(maxsize=64)
def _unstack_fn(n: int):
    """Jitted inverse: one dispatch returning n row-slices of a stacked
    tree (eager ``l[i]`` per leaf per row costs hundreds of tiny ops)."""
    return jax.jit(lambda t: tuple(
        jax.tree.map(lambda l, i=i: l[i], t) for i in range(n)))


def _stack_trees(trees):
    return _stack_fn(len(trees))(*trees)


@jax.jit
def _gather_sorted(tree, rows, pos):
    """Row-select + per-row gather in ONE dispatch: leaves (B, K, ...)
    take rows ``rows`` then reorder each by its own index vector (the
    per-task ``sub_pos`` sort)."""
    return jax.tree.map(
        lambda l: jax.vmap(lambda x, p: x[p])(l[rows], pos), tree)


@dataclasses.dataclass
class MegaRound:
    """One megastep's outputs plus the row bookkeeping the scheduler needs
    to score/aggregate across tasks in the same stacked layout."""

    subs: List[Optional[CohortSubmissions]]  # per task (None = no cohort
                                             # member participated)
    raw: Any                  # device tree (B, K, ...), selection order —
                              # the scoring input (B = pow2 task bucket)
    sorted_full: Any          # device tree (Bf, K, ...) for the FULL-
                              # participation tasks, rows in sub_pos order
                              # (None when no task had full participation)
    active: List[int]         # task index of raw row a (first len(active))
    full_rows: List[int]      # task index of sorted_full row f
    pos: List["np.ndarray"]   # per active row: sub_pos into selection order


class MegaCohort:
    """Cross-task megastep over T same-kernel ``VectorCohort``s: stack the
    cohorts' round inputs on a leading task axis (padded to its pow2
    bucket) and advance every task with ONE ``vmap(tasks) ∘ vmap(trainers)``
    dispatch — replacing T per-task jit calls per round.

    Semantics are pinned bit-exact to stepping each ``VectorCohort.train``
    alone (tests/test_mega.py): participation draws come from each
    cohort's own rng in the same order, opt state / round counters advance
    per task, and blob cids are content-identical.  Ragged participation
    only changes the host-side gather — the kernel always trains all K
    selected trainers with per-task keep masks, exactly like the per-task
    path.
    """

    def __init__(self, cohorts: Sequence["VectorCohort"]):
        assert cohorts, "empty mega group"
        k0 = cohorts[0].kernels
        assert all(c.kernels is k0 for c in cohorts), \
            "mega group must share ONE CohortKernels (same model/opt/dp)"
        self.cohorts = list(cohorts)
        self.kernels = k0
        # opt-state residency: between consecutive megasteps over the SAME
        # row layout the stacked opt tree stays here (one (T, K, P) copy
        # each way per window otherwise).  While held, each active
        # cohort's ``_opt_holder`` points back so any per-task consumer
        # (VectorCohort.train / start_task) flushes before reading.
        self._opt_stacked = None
        self._opt_rows: Optional[List[int]] = None
        self._opt_active: Optional[List[int]] = None

    def flush_opt(self) -> None:
        """Hand the cached stacked opt state back to the cohorts (called
        before any per-task path touches ``cohort._opt``)."""
        if self._opt_stacked is None:
            return
        opts = _unstack_fn(len(self._opt_rows))(self._opt_stacked)
        for a, t in enumerate(self._opt_active):
            self.cohorts[t]._opt = opts[a]
            self.cohorts[t]._opt_holder = None
        self._opt_stacked = self._opt_rows = self._opt_active = None

    def _stacked_opt(self, rows: List[int], active: List[int]):
        if (self._opt_rows == rows
                and all(self.cohorts[t]._opt_holder is self
                        for t in active)):
            return self._opt_stacked
        self.flush_opt()
        for t in rows:
            holder = self.cohorts[t]._opt_holder
            if holder is not None and holder is not self:
                holder.flush_opt()
        return _stack_trees([self.cohorts[t]._opt for t in rows])

    def train(self, params_list: Sequence[Any], rnds: Sequence[int],
              sel_list: Sequence[Sequence[int]]) -> Optional[MegaRound]:
        cohorts = self.cohorts
        sels = [np.asarray(s) for s in sel_list]
        K = sels[0].size
        assert all(s.size == K for s in sels), "mega group needs uniform K"
        parts = [c._participation(s) for c, s in zip(cohorts, sels)]
        active = [t for t in range(len(cohorts)) if parts[t].any()]
        subs: List[Optional[CohortSubmissions]] = [None] * len(cohorts)
        if not active:
            return MegaRound(subs, None, None, [], [], [])
        # task-axis rows: active tasks padded to the pow2 bucket by
        # replicating row 0 (padded outputs are computed and dropped)
        rows = active + [active[0]] * (_bucket(len(active)) - len(active))
        batches = {t: cohorts[t].batch_fn(sels[t], rnds[t]) for t in active}
        mal = np.stack([cohorts[t].is_malicious[sels[t]] for t in rows])
        keep = np.stack([parts[t] & ~cohorts[t].is_malicious[sels[t]]
                         for t in rows])
        submitted, new_opt, _loss = self.kernels.mega_round_step()(
            _stack_trees([params_list[t] for t in rows]),
            self._stacked_opt(rows, active),
            _stack_trees([batches[t] for t in rows]),
            jnp.stack([cohorts[t].key for t in rows]),
            jnp.asarray([cohorts[t]._round_counter for t in rows],
                        jnp.uint32),
            jnp.asarray(mal), jnp.asarray(keep),
            use_fake=bool(any(mal[a].any()
                              for a in range(len(active)))))
        # keep the new opt stacked here; cohorts flush it back on demand.
        # Padded rows replicate row 0's inputs, so only the active slices
        # are authoritative — flush_opt hands back exactly those
        self._opt_stacked, self._opt_rows = new_opt, rows
        self._opt_active = active
        for t in active:
            cohorts[t]._opt_holder = self
            cohorts[t]._round_counter += 1
        # per-task submitted gather (the VectorCohort.train sub_pos logic)
        pos, full_rows = [], []
        for t in active:
            if parts[t].all():
                pos.append(np.argsort(sels[t]))
                full_rows.append(t)
            else:
                p = np.flatnonzero(parts[t])
                pos.append(p[np.argsort(sels[t][p])])
        # full tasks: one vmapped sorted gather + ONE host materialization
        sorted_full = None
        if full_rows:
            fa = [active.index(t) for t in full_rows]
            fb = fa + [fa[0]] * (_bucket(len(fa)) - len(fa))
            pos_mat = np.stack([pos[a] for a in fb])
            sorted_full = _gather_sorted(submitted, jnp.asarray(fb),
                                         jnp.asarray(pos_mat))
            host = jax.device_get(sorted_full)
            for f, t in enumerate(full_rows):
                stacked = jax.tree.map(lambda l, f=f: l[f], host)
                cid = cohorts[t].store.put(stacked)
                idxs = [int(i) for i in sels[t][pos[active.index(t)]]]
                subs[t] = CohortSubmissions(idxs, stacked,
                                            {i: cid for i in idxs})
        # ragged tasks: per-task device gather (K' differs per task)
        for a, t in enumerate(active):
            if subs[t] is not None:
                continue
            stacked = jax.tree.map(
                np.asarray,
                jax.tree.map(lambda l, a=a, p=pos[a]: l[a][p], submitted))
            cid = cohorts[t].store.put(stacked)
            idxs = [int(i) for i in sels[t][pos[a]]]
            subs[t] = CohortSubmissions(idxs, stacked,
                                        {i: cid for i in idxs})
        return MegaRound(subs, submitted, sorted_full, active, full_rows,
                         pos)
