"""Differential privacy for submitted model weights (paper §III-D.3):
w' = w + n, Gaussian mechanism with per-leaf calibrated sigma."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DPConfig:
    enabled: bool = True
    clip_norm: float = 1.0        # L2 sensitivity bound on the update
    noise_multiplier: float = 0.6  # sigma = multiplier * clip / sqrt(batch)
    batch_size: int = 32


def clip_update(update_tree, clip_norm: float):
    """Clip the whole update pytree to L2 norm <= clip_norm."""
    sq = sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
             for l in jax.tree.leaves(update_tree))
    norm = jnp.sqrt(sq)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(
        lambda l: (l.astype(jnp.float32) * scale).astype(l.dtype),
        update_tree), norm


def add_noise(key, update_tree, cfg: DPConfig):
    """Gaussian mechanism on the (clipped) update."""
    if not cfg.enabled:
        return update_tree
    sigma = cfg.noise_multiplier * cfg.clip_norm / max(cfg.batch_size, 1) ** 0.5
    leaves, treedef = jax.tree.flatten(update_tree)
    keys = jax.random.split(key, len(leaves))
    noised = [
        (l.astype(jnp.float32)
         + sigma * jax.random.normal(k, l.shape, jnp.float32)).astype(l.dtype)
        for l, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, noised)


def privatize(key, update_tree, cfg: DPConfig = DPConfig()):
    clipped, norm = clip_update(update_tree, cfg.clip_norm)
    return add_noise(key, clipped, cfg), norm
