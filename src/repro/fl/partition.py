"""Non-IID federated data partitioning (Dirichlet label skew) — the standard
cross-device FL data model for the paper's MNIST workload."""
from __future__ import annotations

from typing import Dict, List

import numpy as np


def dirichlet_partition(labels: np.ndarray, n_clients: int,
                        alpha: float = 0.5, seed: int = 0,
                        min_per_client: int = 8) -> List[np.ndarray]:
    """Returns per-client index arrays with Dirichlet(alpha) label skew."""
    rng = np.random.default_rng(seed)
    n_classes = int(labels.max()) + 1
    idx_by_class = [np.where(labels == c)[0] for c in range(n_classes)]
    for idx in idx_by_class:
        rng.shuffle(idx)
    client_idx: List[List[int]] = [[] for _ in range(n_clients)]
    for c, idx in enumerate(idx_by_class):
        props = rng.dirichlet(np.full(n_clients, alpha))
        cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
        for client, part in enumerate(np.split(idx, cuts)):
            client_idx[client].extend(part.tolist())
    # rebalance tiny clients (deterministic round-robin steal)
    for i in range(n_clients):
        while len(client_idx[i]) < min_per_client:
            donor = int(np.argmax([len(c) for c in client_idx]))
            client_idx[i].append(client_idx[donor].pop())
    return [np.asarray(sorted(ci), np.int64) for ci in client_idx]


def skew_report(labels: np.ndarray, parts: List[np.ndarray]) -> Dict:
    n_classes = int(labels.max()) + 1
    hist = np.stack([np.bincount(labels[p], minlength=n_classes)
                     for p in parts])
    frac = hist / np.maximum(hist.sum(1, keepdims=True), 1)
    return {"sizes": [len(p) for p in parts],
            "max_class_frac": frac.max(1).tolist()}
