"""The rollup round — the paper's technique as ONE jit-able, mesh-sharded
step (the TPU face of the zk-rollup, see core/rollup.py docstring).

Layout: trainers = mesh data(xpod)-axis groups.  Every param leaf gains a
leading trainer dim T sharded over "data" — each group's replica evolves
independently during H local steps ("off-chain"), then a single
reputation-weighted merge (Eq. 1) + distance pass (Eq. 4) + digest crosses
the interconnect ("commit/prove/execute").  Collective bytes per optimizer
step drop ~H-fold vs per-step DP sync — the paper's gas story on ICI.

The L1-baseline equivalent (`h_local_steps=1`, plain DP train_step) is built
by launch/steps.py; benchmarks compare the two rooflines.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.model import Model


class FLRoundSpec(NamedTuple):
    n_trainers: int         # == data axis size (x pod size on multi-pod)
    h_local_steps: int = 8
    local_batch: int = 16
    # commit payload compression: "none" | "int8"
    # int8: each trainer contributes a per-block-quantised DELTA vs the
    # round's starting params; the weighted merge runs over dequantised
    # deltas — commit collective bytes drop ~2x vs bf16 / 4x vs f32
    # (beyond-paper optimization; error bounded by the int8 step, see
    # tests/test_substrate.py::test_int8_quantization_error_bound).
    commit_compression: str = "none"


def trainerify_pspecs(pspecs, dp_axes=("data",)):
    """Prepend the trainer (dp-sharded) dim to every param spec.

    The dp axes now carry the trainer dim, so they are stripped from the
    inner per-param specs (params within one trainer shard over TP only)."""
    drop = set(dp_axes)

    def strip(entry):
        if entry is None:
            return None
        if isinstance(entry, tuple):
            kept = tuple(a for a in entry if a not in drop)
            return kept if kept else None
        return None if entry in drop else entry

    def one(s):
        return P(dp_axes, *(strip(e) for e in s))
    return jax.tree.map(one, pspecs, is_leaf=lambda x: isinstance(x, P))


def stack_shape(tree, n):
    return jax.tree.map(
        lambda l: jax.ShapeDtypeStruct((n,) + l.shape, l.dtype), tree)


def digest_tree(tree):
    """Rollup validity-digest stand-in: fold all updated leaves into one u32
    (chunked mix + wraparound-sum fold — cheap, fused, order-deterministic;
    sum instead of xor because XLA:CPU cannot lower u32-xor reductions under
    SPMD — the Pallas kernel (kernels/rollup_digest.py) keeps the xor form
    for TPU runs).  Mixing constants are shared with the kernel and the
    vector engine's CPU mirror (core/engine.py)."""
    from repro.core.engine import DIGEST_MULT, DIGEST_SEED
    acc = jnp.uint32(DIGEST_SEED)
    for leaf in jax.tree.leaves(tree):
        bits = jax.lax.bitcast_convert_type(
            leaf.astype(jnp.float32).reshape(-1), jnp.uint32)
        mixed = jnp.bitwise_xor(bits, bits >> 16) * jnp.uint32(DIGEST_MULT)
        acc = acc + jnp.sum(mixed, dtype=jnp.uint32)
    return acc


def build_fl_round(model: Model, opt, spec: FLRoundSpec):
    """Returns fl_round(params_T, opt_T, scores, batches) ->
    (merged_params_T, opt_T, metrics).

    params_T leaves: (T, ...) sharded P("data", ...).
    batches: per-trainer, per-local-step token batch
             {tokens/labels: (T, H, local_B, S)} sharded P("data", ...).
    scores: (T,) trainer reputation scores (from the DON / reputation book).
    """
    cfg = model.cfg
    # inside vmap-over-trainers, per-tensor sharding constraints land on
    # shifted dims and trigger involuntary full rematerialisation (measured:
    # pathological (T,1,S,1,dh) reshardings) — run the loss UNCONSTRAINED
    # and let GSPMD propagate from the in_shardings of params/batches.
    from repro.models.model import Model
    model = Model(cfg, None)

    def local_steps(params, opt_state, trainer_batch):
        """H sequential local optimizer steps for ONE trainer."""
        def one(carry, batch):
            p, o = carry
            loss, grads = jax.value_and_grad(
                lambda pp: model.loss(pp, batch))(p)
            p, o, gn = opt.update(grads, o, p)
            return (p, o), loss
        (params, opt_state), losses = jax.lax.scan(
            one, (params, opt_state), trainer_batch)
        return params, opt_state, jnp.mean(losses)

    def fl_round(params_T, opt_T, scores, batches):
        start_T = params_T
        # ---- off-chain: H local steps per trainer (vmapped over T) --------
        params_T, opt_T, loss_T = jax.vmap(local_steps)(params_T, opt_T,
                                                        batches)
        # ---- commit: Eq. 1 reputation-weighted merge over trainers --------
        s = scores.astype(jnp.float32)
        denom = jnp.maximum(jnp.sum(s), 1e-12)

        if spec.commit_compression == "int8":
            # quantise each trainer's DELTA to int8 (per-block scales);
            # the cross-trainer reduction then moves ~1 byte/param
            from repro.optim.compression import (dequantize_int8,
                                                 quantize_int8)

            def merge_q(new, start):
                delta = (new.astype(jnp.float32)
                         - start.astype(jnp.float32))
                q, scale = jax.vmap(quantize_int8)(
                    delta.reshape(delta.shape[0], -1))
                deq = jax.vmap(lambda qq, ss: dequantize_int8(
                    qq, ss, delta.shape[1:]))(q, scale)
                md = jnp.einsum("t...,t->...", deq, s) / denom
                m = start[0].astype(jnp.float32) + md
                return m.astype(new.dtype)
            merged = jax.tree.map(merge_q, params_T, start_T)
        else:
            def merge(leaf):
                m = jnp.einsum("t...,t->...",
                               leaf.astype(jnp.float32), s) / denom
                return m.astype(leaf.dtype)
            merged = jax.tree.map(merge, params_T)

        # ---- prove: Eq. 4 distances + integrity digest --------------------
        def dist(leaf_T, leaf_m):
            d = leaf_T.astype(jnp.float32) - leaf_m.astype(jnp.float32)[None]
            return jnp.sum(d * d, axis=tuple(range(1, d.ndim)))
        d2 = sum(jax.tree.leaves(jax.tree.map(dist, params_T, merged)))
        distances = jnp.sqrt(d2)                       # (T,)
        digest = digest_tree(merged)

        # ---- execute: broadcast merged state back to every trainer --------
        params_T = jax.tree.map(
            lambda m, t: jnp.broadcast_to(m[None], t.shape).astype(t.dtype),
            merged, params_T)
        metrics = {"loss": jnp.mean(loss_T), "distances": distances,
                   "digest": digest}
        return params_T, opt_T, metrics

    return fl_round


def build_fl_round_cell(model: Model, opt, spec: FLRoundSpec, mesh,
                        seq_len: int, trainer_axes=None):
    """Lowerable cell for the dry-run (ShapeDtypeStructs + shardings).

    trainer_axes: mesh axes carrying the trainer dim.  Default: the dp axes
    (TP-within-trainer).  Pass all mesh axes (e.g. ("data", "model")) for
    the paper's cross-device pure-DP regime: one trainer per chip, params
    replicated per trainer, and the ONLY collective is the rollup commit —
    whose cost the H local steps divide (the gas story on ICI).
    """
    cfg = model.cfg
    T, H, B = spec.n_trainers, spec.h_local_steps, spec.local_batch
    dp = trainer_axes or model.ctx.dp_axes or ("data",)
    pshape = model.params_shape()
    pspecs = model.params_pspecs(pshape)
    pspecs_T = trainerify_pspecs(pspecs, dp)
    params_T = stack_shape(pshape, T)

    oshape = jax.eval_shape(opt.init, pshape)
    from repro.launch.steps import opt_state_pspecs
    ospecs = opt_state_pspecs(cfg.optimizer, pspecs, pshape)
    ospecs_T = trainerify_pspecs(ospecs, dp)
    opt_T = stack_shape(oshape, T)

    batches = {
        "tokens": jax.ShapeDtypeStruct((T, H, B, seq_len), jnp.int32),
        "labels": jax.ShapeDtypeStruct((T, H, B, seq_len), jnp.int32),
    }
    b_spec = {k: P(dp, None, None, None) for k in batches}
    scores = jax.ShapeDtypeStruct((T,), jnp.float32)

    fl_round = build_fl_round(model, opt, spec)

    from repro.sharding.specs import sanitize_pspec_tree

    def sh(tree, shapes):
        tree = sanitize_pspec_tree(mesh, tree, shapes)
        return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                            is_leaf=lambda x: isinstance(x, P))

    metrics_spec = {"loss": P(), "distances": P(dp), "digest": P()}
    metrics_shape = {"loss": jax.ShapeDtypeStruct((), jnp.float32),
                     "distances": jax.ShapeDtypeStruct((T,), jnp.float32),
                     "digest": jax.ShapeDtypeStruct((), jnp.uint32)}
    jitted = jax.jit(
        fl_round,
        in_shardings=(sh(pspecs_T, params_T), sh(ospecs_T, opt_T),
                      NamedSharding(mesh, P(dp)), sh(b_spec, batches)),
        out_shardings=(sh(pspecs_T, params_T), sh(ospecs_T, opt_T),
                       sh(metrics_spec, metrics_shape)),
        donate_argnums=(0, 1))
    return jitted, (params_T, opt_T, scores, batches)
