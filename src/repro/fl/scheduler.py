"""Multi-task protocol scheduler: N concurrent FL tasks on one shared clock,
ledger and reputation book.

``AutoDFL.run_task`` (fl/server.py) used to be a monolithic loop; it is now
split into

  * ``TaskRuntime`` — the per-task state machine (paper Fig. 1 steps 1-16):
    select -> [train -> evaluate -> aggregate] x rounds -> settle.  Each
    ``step()`` advances one phase, so a scheduler can interleave many tasks
    at round granularity.
  * ``Scheduler`` — drives N TaskRuntimes on a shared window clock.  Every
    window, each active task steps once; all lifecycle/reputation
    transactions land in the node's ONE shared chain/rollup (the paper's
    congestion scenario), optionally racing a background ``Workload``
    (core/workloads.py) for block gas.  Tasks that finish in the same
    window settle TOGETHER through the fused multi-task reputation update
    (core/reputation.end_of_multitask_update) — one dispatch per window.

Single-task equivalence: a ``Scheduler`` with one task reproduces
``AutoDFL.run_task`` outputs exactly (tests/test_scheduler.py) — run_task
itself drives a TaskRuntime sequentially, and gas totals are invariant to
block/window timing.
"""
from __future__ import annotations

import logging
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import (tree_flat, tree_flat_stacked,
                                    weighted_average_tree_jit,
                                    weighted_average_tree_mega)
from repro.core.oracle import (_UNBATCHABLE, _eval_cache_get,
                               _eval_cache_key, evaluate_quorum,
                               mega_score_tables, quorum_from_table)
from repro.core.reputation import model_distances
from repro.fl.cohort import (AgentCohort, CohortSubmissions, MegaCohort,
                             VectorCohort, _unstack_fn)

_log = logging.getLogger(__name__)
# (chain type, rollup type) pairs already warned about falling back to
# the stepped path under fused="auto" — the log fires once per stack
# shape per process, not once per run (tests reset this set directly)
_FUSED_FALLBACK_WARNED: set = set()


@jax.jit
def _settle_distances(stacked_tree, global_tree):
    """Batched Eq. 4 distance pass for the final submissions (one fused
    dispatch per task at settlement)."""
    return model_distances(tree_flat_stacked(stacked_tree),
                           tree_flat(global_tree))


class TaskRuntime:
    """Per-task state machine over a shared protocol node (AutoDFL).

    Phases: "select" -> "round" (x rounds) -> "settle_ready" -> "done".
    ``step()`` advances one phase; settlement is performed by the node
    (``AutoDFL.settle_window``) so that tasks closing in the same scheduler
    window share one fused reputation update.
    """

    def __init__(self, node, task_id: str, cohort, *, rounds: int = 5,
                 reward: float = 10.0, n_select: Optional[int] = None,
                 init_seed: int = 0):
        if isinstance(cohort, (list, tuple)):
            cohort = AgentCohort(cohort)
        assert len(cohort) == len(node.trainer_ids), \
            "cohort must cover the node's trainer set"
        self.node = node
        self.task_id = task_id
        self.cohort = cohort
        self.rounds = rounds
        self.reward = reward
        self.n_select = n_select
        self.init_seed = init_seed
        # sharded fabric (core/shards.py): pin every emission of this task
        # to one shard — hash or least-loaded, decided at task creation
        rollup = getattr(node, "rollup", None)
        self.shard: Optional[int] = (rollup.assign_task(task_id)
                                     if hasattr(rollup, "assign_task")
                                     else None)
        self.phase = "select"
        self.rnd = 0
        self.start_window = 0
        n = len(cohort)
        self.completed = np.zeros(n, np.float32)
        self.sel_idx: List[int] = []
        self.params = None
        self.last_subs: Optional[CohortSubmissions] = None
        self.last_scores: Optional[np.ndarray] = None
        # settlement arrays, filled by _finalize
        self.score_auto = np.zeros(n, np.float32)
        self.dists = np.zeros(n, np.float32)
        self.participated = np.zeros(n, np.float32)
        self.result = None

    # -- lifecycle -------------------------------------------------------------
    def step(self):
        # every protocol tx emitted while this task steps is routed to the
        # task's shard (no-op when the L2 target is not a sharded fabric)
        self.node._route_shard = self.shard
        try:
            if self.phase == "select":
                self._select()
                self.phase = "round"
                if self.rounds == 0:
                    self._finalize()
            elif self.phase == "round":
                self._round()
                if self.rnd >= self.rounds:
                    self._finalize()
            else:
                raise RuntimeError(f"step() in phase {self.phase!r} "
                                   f"(task {self.task_id})")
        finally:
            self.node._route_shard = None

    # steps 1-2: publish + reputation-ranked selection --------------------------
    def _select(self):
        node = self.node
        model_cid = node.store.put({"arch": node.model.cfg.name})
        node.tsc.publish_task(node.publisher, self.task_id, model_cid,
                              model_cid, self.rounds, 0.5, self.reward)
        node._tx("publishTask", node.publisher, {"taskId": self.task_id})
        # array reputations straight from the book — no dict roundtrip
        selected = node.tsc.select_trainers(
            self.task_id, np.asarray(node.book.reputation),
            self.n_select or len(self.cohort), trainer_ids=node.trainer_ids)
        self.sel_idx = [node.trainer_index(t) for t in selected]
        for t in selected:
            node.escrow.lock_collateral(t, self.task_id, 1.0)
        self.params = node.model.init_params(jax.random.key(self.init_seed))
        self.cohort.start_task(self.params, node.opt, self.sel_idx)

    # steps 3-15: one round (local training -> DON -> Eq. 1 merge) --------------
    def _round(self):
        node = self.node
        subs = self.cohort.train(self.params, self.rnd, self.sel_idx)
        self.rnd += 1
        if subs is None:
            node.tsc.advance_round(self.task_id)
            return
        senders = []
        for i in subs.idxs:
            tid = node.trainer_ids[i]
            node.tsc.submit_local_model(tid, self.task_id, self.rnd - 1,
                                        subs.cids[i])
            senders.append(tid)
        node._tx_batch("submitLocalModel", senders,
                       lambda: [{"taskId": self.task_id,
                                 "round": self.rnd - 1, "cid": subs.cids[i]}
                                for i in subs.idxs])
        self.completed[subs.idxs] += 1.0
        scores, _report = evaluate_quorum(node.eval_fn, subs.stacked, None,
                                          node.don, slices=node.val_slices)
        scores_np = np.asarray(scores, np.float32)
        node._tx_batch("calculateObjectiveRep", senders,
                       lambda: [{"value": float(s)} for s in scores_np])
        self.params = weighted_average_tree_jit(subs.stacked, scores,
                                                use_pallas=node.use_pallas_agg)
        node.tsc.advance_round(self.task_id)
        self.last_subs = subs
        self.last_scores = scores_np

    # step 16 prep: cohort settlement arrays ------------------------------------
    def _finalize(self):
        """Distances + final scores for the end-of-task update.

        Final scores REUSE the last round's DON quorum medians instead of
        re-evaluating every final model (that double work was pure overlap
        with the round-loop quorum).  Distances are computed for submitters
        first in one batched Eq. 4 pass; every selected non-submitter then
        gets the max over SUBMITTED distances (the old in-loop fallback read
        a partially-filled array, so the penalty depended on iteration
        order)."""
        self.participated[self.sel_idx] = 1.0
        d = np.zeros(0, np.float32)
        if self.last_subs is not None:
            d = np.asarray(_settle_distances(self.last_subs.stacked,
                                             self.params), np.float32)
            self.dists[self.last_subs.idxs] = d
            self.score_auto[self.last_subs.idxs] = self.last_scores
        # degenerate case (no submitters, or every submitted distance is
        # exactly 0, e.g. a single submitter whose model IS the merge):
        # keep the legacy 1.0 penalty so free-riders never score best
        fallback = float(d.max()) if d.size and float(d.max()) > 0 else 1.0
        submitted = set(self.last_subs.idxs) if self.last_subs else set()
        for i in self.sel_idx:
            if i not in submitted:
                self.dists[i] = fallback
        self.phase = "settle_ready"


class Scheduler:
    """Interleave N TaskRuntimes on a shared window clock.

    window: simulated seconds per scheduling window; every active task
    advances one phase per window and the L1 produces blocks up to the
    window edge.  ``background`` (a core/workloads.py Workload) is injected
    into the shared L1 in time order, racing protocol traffic for block gas.
    ``seal_every``: seal rollup lane batches every k windows (0 = only the
    final flush, which preserves single-task batch-boundary equivalence
    with ``run_task``).
    ``fused``: drive the ledger hot path through the core/fused.py plan-
    then-execute loop — "auto" (on when the stack supports it), True
    (assert support), or False (always Python-stepped).  Fused and stepped
    runs are pinned to identical outputs (tests/test_fused.py).
    ``megabatch``: when every task stepping in a window is in its "round"
    phase and the cohorts share one compiled kernel set, run the whole
    window as ONE cross-task megastep — a (tasks, trainers) double-vmapped
    train/score/aggregate program plus one megabatched tx emission —
    instead of T per-task dispatches.  "auto" (on when eligible), True
    (assert eligibility on all-round windows), or False (always per-task).
    Megabatched and per-task windows are pinned to identical outputs
    (tests/test_mega.py); the per-task path remains the reference
    semantics.
    """

    def __init__(self, node, *, window: float = 1.0, seal_every: int = 0,
                 background=None, fused="auto", megabatch="auto"):
        self.node = node
        self.window = window
        self.seal_every = seal_every
        self.background = background
        self.fused = fused
        self.megabatch = megabatch
        self.mega_windows = 0       # windows driven by the megastep path
        self._mega = None           # (cohort-id key, cached MegaCohort)
        self._loop = None           # active FusedWindowLoop during run()
        self.runtimes: List[TaskRuntime] = []
        self._bg_pos = 0
        # typed-event records collected by run() through the node's
        # client (core/events.py) — the scheduler observes settlement
        # through the public stream instead of poking ledger internals
        self.window_records: List[object] = []
        self.settlement_records: List[object] = []

    def add_task(self, task, cohort, **task_kw) -> TaskRuntime:
        """Register a task: ``task`` is an ``repro.api.FLTaskSpec`` (the
        public form) or a task-id string with FLTaskSpec's fields as loose
        kwargs (``rounds=``, ``reward=``, ``n_select=``, ``start_window=``,
        ``init_seed=``) — defaults live on FLTaskSpec alone."""
        from repro.api.specs import as_task_spec
        task = as_task_spec(task, **task_kw)
        rt = TaskRuntime(self.node, task.task_id, cohort, rounds=task.rounds,
                         reward=task.reward, n_select=task.n_select,
                         init_seed=task.init_seed)
        rt.start_window = task.start_window
        self.runtimes.append(rt)
        return rt

    def _seal_rollup(self):
        """Seal every pending rollup tx: all LedgerBackend rollup faces
        (object Rollup, VectorRollup, ShardedRollup) expose ``seal()``;
        the sharded fabric also records its fabric root here — this call
        IS the window-boundary commitment."""
        if self._loop is not None:
            self._loop.seal()
        else:
            self.node.rollup.seal()

    def _submit_background(self, t_end: float):
        if self.background is None:
            return
        txs = self.background.txs
        i = self._bg_pos
        j = int(np.searchsorted(txs.submit_time, t_end, side="left"))
        if j <= i:
            return
        chain = self.node.chain
        if getattr(chain, "soa_native", False):
            from repro.core.engine import TxArrays
            # remap raw workload sender ids into the chain's namespace
            # (the same "client<k>" actors the object engine sees) — raw
            # ids would collide with protocol senders registered via
            # chain.sender_id()
            sid = txs.sender_id[i:j]
            uniq = np.unique(sid)
            lut = np.array([chain.sender_id(f"client{int(u)}")
                            for u in uniq], np.int32)
            batch = TxArrays(
                txs.submit_time[i:j], txs.gas[i:j], txs.fn_id[i:j],
                lut[np.searchsorted(uniq, sid)], txs.fns)
            if self._loop is not None:
                self._loop.submit(chain, batch)
            else:
                chain.submit_arrays(batch)
        else:
            from repro.core.ledger import Tx
            for k in range(i, j):
                chain.submit(Tx(txs.fns.names[txs.fn_id[k]],
                                f"client{int(txs.sender_id[k])}", {},
                                int(txs.gas[k]), float(txs.submit_time[k])))
        self._bg_pos = j

    # -- cross-task megastep ---------------------------------------------------
    def _mega_eligible(self, rts: List[TaskRuntime]) -> bool:
        """One megastep can replace this window's per-task loop iff every
        stepping task is mid-round on the SAME compiled cohort program and
        the node's L2 target takes SoA batches.  Mixed-phase windows
        (select/settle interleavings) fall back silently — they are
        inherently sequential; capability gaps raise under
        ``megabatch=True``."""
        if not self.megabatch or self.background is not None:
            return False
        if any(rt.phase != "round" for rt in rts):
            return False
        node = self.node
        cohorts = [rt.cohort for rt in rts]
        target = node._target()
        ok = (getattr(target, "soa_native", False)
              and node.val_slices is not None
              and node.val_slices.stacked is not None
              and all(isinstance(c, VectorCohort) for c in cohorts)
              and all(c.kernels is cohorts[0].kernels for c in cohorts)
              and len({len(rt.sel_idx) for rt in rts}) == 1
              # sharded fabric: megabatched emission needs explicit pins
              # (least-loaded routing is submit-call-granularity dependent)
              and (not hasattr(target, "shards")
                   or all(rt.shard is not None for rt in rts))
              and (_eval_cache_get(_eval_cache_key(node.eval_fn))
                   is not _UNBATCHABLE))
        if not ok and self.megabatch is True:
            raise RuntimeError(
                "Scheduler(megabatch=True): window is not megabatchable "
                "(needs a SoA-native target, stacked validation slices, "
                "VectorCohorts sharing one CohortKernels, uniform cohort "
                "size, and shard pins on a fabric)")
        return ok

    def _mega_window(self, rts: List[TaskRuntime]) -> List[TaskRuntime]:
        """Run one round for EVERY task in ``rts`` as a single megastep.

        Bit-exact to stepping each TaskRuntime._round in order: training,
        scoring and Eq. 1 aggregation are task-independent along the vmap
        axis, tx stamp times are order-preserving under one concatenated
        emission, and per-cohort participation rngs are independent streams
        (tests/test_mega.py pins all of it element-wise)."""
        node = self.node
        self.mega_windows += 1
        # the MegaCohort is cached across windows so its stacked opt state
        # stays resident between consecutive megasteps of the same group
        # (keyed by the cohort objects themselves, not id() — rule R003)
        key = tuple(rt.cohort for rt in rts)
        if self._mega is None or self._mega[0] != key:
            self._mega = (key, MegaCohort([rt.cohort for rt in rts]))
        mega = self._mega[1].train(
            [rt.params for rt in rts], [rt.rnd for rt in rts],
            [rt.sel_idx for rt in rts])
        for rt in rts:
            rt.rnd += 1
        groups = []
        for i, rt in enumerate(rts):
            subs = mega.subs[i]
            if subs is None:
                continue
            senders = []
            for j in subs.idxs:
                tid = node.trainer_ids[j]
                node.tsc.submit_local_model(tid, rt.task_id, rt.rnd - 1,
                                            subs.cids[j])
                senders.append(tid)
            groups.append(("submitLocalModel", senders, rt.shard))
            groups.append(("calculateObjectiveRep", senders, rt.shard))
            rt.completed[subs.idxs] += 1.0
        node._tx_batch_many(groups)
        scores_by_task: Dict[int, jnp.ndarray] = {}
        if mega.active:
            try:
                tables = mega_score_tables(node.eval_fn, mega.raw,
                                           node.val_slices)
            except Exception:
                # eval_fn turned out non-vmappable: score per task (the
                # auto-mode fallback caches the verdict, so later windows
                # skip the megastep entirely via _mega_eligible)
                tables = None
            for a, t in enumerate(mega.active):
                if tables is not None:
                    scores, _report = quorum_from_table(
                        tables[a][:, mega.pos[a]], node.don)
                else:
                    scores, _report = evaluate_quorum(
                        node.eval_fn, mega.subs[t].stacked, None, node.don,
                        slices=node.val_slices)
                scores_by_task[t] = scores
                rts[t].last_scores = np.asarray(scores, np.float32)
        # full-participation tasks merge in ONE vmapped Eq. 1 dispatch;
        # ragged tasks keep per-task reductions (a padded zero-weight lane
        # would reassociate the sum and break bit-exactness).  The Pallas
        # agg kernel is not vmap-audited — per-task covers it.
        full = [] if node.use_pallas_agg else mega.full_rows
        if full:
            n_rows = int(jax.tree.leaves(mega.sorted_full)[0].shape[0])
            pad_rows = full + [full[0]] * (n_rows - len(full))
            smat = jnp.stack([scores_by_task[t] for t in pad_rows])
            newp = _unstack_fn(n_rows)(
                weighted_average_tree_mega(mega.sorted_full, smat))
            for f, t in enumerate(full):
                rts[t].params = newp[f]
        for t in mega.active:
            if t not in full:
                rts[t].params = weighted_average_tree_jit(
                    mega.subs[t].stacked, scores_by_task[t],
                    use_pallas=node.use_pallas_agg)
            node.tsc.advance_round(rts[t].task_id)
            rts[t].last_subs = mega.subs[t]
        for i, rt in enumerate(rts):
            if mega.subs[i] is None:
                node.tsc.advance_round(rt.task_id)
        ready = []
        for rt in rts:
            if rt.rnd >= rt.rounds:
                rt._finalize()
                ready.append(rt)
        return ready

    def run(self) -> Dict[str, object]:
        """Drive every task to completion; returns {task_id: FLTaskResult}.

        Window/settlement provenance is consumed from the node's typed
        event stream (``client.events()``): after the run,
        ``self.window_records`` holds the ``WindowSettled`` commitments
        (fabric roots on a sharded node) and ``self.settlement_records``
        the ``AggregateVerified`` postings, in emission order.
        """
        node = self.node
        client = node.client()
        # this run's provenance only: fast-forward past events emitted
        # before the run (a fresh client's cursor starts at the stack's
        # genesis), and collect into fresh record lists
        client.events()
        self.window_records, self.settlement_records = [], []
        from repro.core.fused import FusedWindowLoop, supports_fused
        use_fused = (supports_fused(node.chain, node.rollup)
                     if self.fused == "auto" else bool(self.fused))
        if self.fused == "auto" and not use_fused:
            # the fallback used to be silent; say it once per stack shape
            # (NodeClient.capabilities() surfaces the chosen path too)
            key = (type(node.chain).__name__,
                   type(node.rollup).__name__
                   if node.rollup is not None else None)
            if key not in _FUSED_FALLBACK_WARNED:
                _FUSED_FALLBACK_WARNED.add(key)
                _log.info(
                    "Scheduler(fused='auto'): %s/%s is not fused-capable; "
                    "using the Python-stepped window loop", *key)
        if use_fused:
            self._loop = FusedWindowLoop(node.chain, node.rollup)
            node._fused = self._loop
        # keep the shared mempool time-sorted: before every protocol
        # emission, background txs stamped earlier than the clock are
        # drained in (both engines pack FIFO and head-of-line-stall on
        # out-of-order future stamps — see Chain.produce_block)
        node.pre_tx_hook = self._submit_background
        w = 0
        t = 0.0
        try:
            while any(rt.phase != "done" for rt in self.runtimes):
                # the window END tracks the protocol clock: emitting n txs
                # advances the clock by 0.01*n, and a window edge behind
                # the clock would strand late-stamped protocol txs across
                # block boundaries
                node._clock = max(node._clock, t)
                stepping = [rt for rt in self.runtimes
                            if rt.phase not in ("settle_ready", "done")
                            and rt.start_window <= w]
                if stepping and self._mega_eligible(stepping):
                    ready = self._mega_window(stepping)
                else:
                    ready = []
                    for rt in stepping:
                        rt.step()
                        if rt.phase == "settle_ready":
                            ready.append(rt)
                if ready:
                    node.settle_window(ready)
                if self.seal_every and node.rollup is not None and \
                        (w + 1) % self.seal_every == 0:
                    self._seal_rollup()
                t_end = max(t + self.window, node._clock)
                self._submit_background(t_end)
                if node.rollup is not None:
                    # proof jobs drain on the shared window clock; pump
                    # BEFORE block production so window-finalized
                    # settlements land in the blocks that pack this window
                    (self._loop or node.rollup).pump(t_end)
                (self._loop or node.chain).run_until(t_end)
                t = t_end
                w += 1
                assert w < 1_000_000, "scheduler failed to make progress"
            self._submit_background(float("inf"))
            if node.rollup is not None:
                (self._loop or node.rollup).flush()
            t_end = node._clock + 5.0
            if self.background is not None:
                t_end = max(t_end, self.background.duration + 5.0)
            (self._loop or node.chain).run_until(t_end)
            if self._loop is not None:
                # replay the whole recorded window loop as one pass:
                # vectorized multi-window seals + one block-pack kernel
                self._loop.execute()
        finally:
            node.pre_tx_hook = None
            node._fused = None
            self._loop = None
        for ev in client.events():
            if ev.kind == "window_settled":
                self.window_records.append(ev)
            elif ev.kind == "aggregate_verified":
                self.settlement_records.append(ev)
        return {rt.task_id: rt.result for rt in self.runtimes}
