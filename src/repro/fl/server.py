"""Decentralized FL protocol node: tasks + trainers + DON + reputation
+ escrow + rollup, wired together (the full paper workflow, steps 1-16 of
Fig. 1).  No central server: the 'orchestrator' here is the protocol state
machine every node can replay from the ledger.

``AutoDFL`` owns the SHARED protocol state (chain/rollup, escrow, blob
store, reputation book, clock); the per-task round logic lives in
``fl/scheduler.TaskRuntime``.  ``run_task`` drives one TaskRuntime to
completion sequentially; ``fl/scheduler.Scheduler`` interleaves many on the
same node — the paper's multi-task congestion scenario."""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core.escrow import Escrow
from repro.core.gas import DEFAULT_GAS
from repro.core.ledger import AccessControl, Tx
from repro.core.oracle import DONConfig, ValidationSlices
from repro.core.reputation import (ReputationParams, TrainerBook,
                                   end_of_multitask_update, init_book,
                                   sync_book_to_state)
from repro.core.state import default_state_handlers
from repro.core.storage import BlobStore
from repro.core.tasks import TaskContract


@dataclasses.dataclass
class FLTaskResult:
    global_params: object
    scores: np.ndarray
    reputations: np.ndarray
    payouts: Dict[str, float]
    diagnostics: List[Dict]


class AutoDFL:
    """End-to-end protocol harness (the PoC the paper evaluates).

    Construction is spec-driven (``spec=repro.api.NodeSpec(...)`` — the
    public path); the legacy flag kwargs (``engine=``, ``use_rollup=``,
    ``n_shards=``, ``shard_route=``) still work for one release through
    ``NodeSpec.from_legacy`` with a DeprecationWarning.  Both paths build
    the ledger through ``repro.api.build_stack`` and are pinned
    equivalent (same state root, same gas) by tests/test_api.py.
    """

    #: legacy ctor kwargs folded into NodeSpec.from_legacy, with defaults
    _LEGACY_DEFAULTS = {"engine": "object", "use_rollup": True,
                        "n_shards": 1, "shard_route": "hash",
                        "trainer_funds": 10.0, "publisher_funds": 1000.0}

    def __init__(self, model, opt, n_trainers: int,
                 eval_fn: Callable, val_batch,
                 rep_params: Optional[ReputationParams] = None,
                 don: Optional[DONConfig] = None,
                 use_rollup: Optional[bool] = None,
                 use_pallas_agg: Optional[bool] = None,
                 seed: Optional[int] = None,
                 engine: Optional[str] = None,
                 trainer_funds: Optional[float] = None,
                 publisher_funds: Optional[float] = None,
                 n_shards: Optional[int] = None,
                 shard_route: Optional[str] = None, *,
                 spec: Optional["NodeSpec"] = None):
        from repro.api.factory import build_stack
        from repro.api.specs import NodeSpec
        legacy = {k: v for k, v in {
            "engine": engine, "use_rollup": use_rollup, "n_shards": n_shards,
            "shard_route": shard_route, "trainer_funds": trainer_funds,
            "publisher_funds": publisher_funds}.items() if v is not None}
        if spec is None:
            # deprecation shim: ledger-shape flags map onto a NodeSpec
            # (rep_params/don/funds kwargs stay silent — they are protocol
            # constants, not the flag wiring this shim retires)
            flags = {k: v for k, v in legacy.items()
                     if k in ("engine", "use_rollup", "n_shards",
                              "shard_route")}
            if flags:
                import warnings
                warnings.warn(
                    f"AutoDFL kwargs {sorted(flags)} are deprecated; pass "
                    "spec=repro.api.NodeSpec(...) (see docs/MIGRATION.md)",
                    DeprecationWarning, stacklevel=2)
            spec = NodeSpec.from_legacy(
                rep_params=rep_params, don=don, seed=seed or 0,
                use_pallas_agg=bool(use_pallas_agg),
                **{**self._LEGACY_DEFAULTS, **legacy})
        else:
            # spec wins wholesale — reject every kwarg it would shadow so
            # nothing is silently dropped in a mixed call (ValueError, not
            # assert: the guard must survive python -O)
            if legacy or rep_params is not None or don is not None \
                    or use_pallas_agg is not None or seed is not None:
                raise ValueError(
                    "pass either spec= or legacy kwargs, not both")
            if spec.n_trainers not in (None, n_trainers):
                raise ValueError(
                    f"spec.n_trainers={spec.n_trainers} contradicts the "
                    f"positional n_trainers={n_trainers}")
        self.spec = spec
        self.model = model
        self.opt = opt
        self.eval_fn = eval_fn
        self.val_batch = val_batch
        # per-instance construction (a shared default ReputationParams()/
        # DONConfig() instance across all nodes was the old footgun)
        self.rep_params = spec.reputation.to_params()
        self.don = spec.don.to_config()
        trainer_funds = spec.trainer_funds
        publisher_funds = spec.publisher_funds
        self.val_slices = ValidationSlices(val_batch, self.don.n_oracles)
        self.use_pallas_agg = spec.use_pallas_agg

        self.store = BlobStore()
        self.acl = AccessControl(["admin0", "admin1", "admin2"])
        self.escrow = Escrow()
        self.tsc = TaskContract(self.acl, self.escrow, self.store)
        # ONE construction path for all five ledger backends
        self.chain, self.rollup = build_stack(spec)
        self.use_rollup = self.rollup is not None
        self.book: TrainerBook = init_book(n_trainers)
        self.trainer_ids = [f"trainer{i}" for i in range(n_trainers)]
        self._trainer_idx = {t: i for i, t in enumerate(self.trainer_ids)}
        for t in self.trainer_ids:
            self.acl.grant("admin0", t, "trainer")
            self.escrow.fund(t, trainer_funds)
        self.publisher = "tp0"
        self.acl.grant("admin0", self.publisher, "task_publisher")
        self.escrow.fund(self.publisher, publisher_funds)
        self._clock = 0.0
        # task-shard pin for the CURRENT emission (set by TaskRuntime.step
        # / settle_window when the L2 target is a ShardedRollup)
        self._route_shard: Optional[int] = None
        # array-native L2 account state (core/state.py): handlers written
        # once against StateArrays views run on every ledger face; rows
        # are indexed by the target's sender ids
        self.state_arrays = None
        self._wire_state()
        # protocol traffic accounting (the bench_protocol TPS numerator)
        self.protocol_calls: Dict[str, int] = {}
        # invoked with the current clock before every protocol emission;
        # the Scheduler uses it to drain background traffic in time order
        # (both engines pack FIFO and stall on out-of-order future stamps)
        self.pre_tx_hook: Optional[Callable[[float], None]] = None
        # active core/fused.py plan (set by Scheduler.run in fused mode):
        # protocol emissions and the end-of-window state sync route through
        # it so the whole window loop replays as one compiled pass
        self._fused = None

    def trainer_index(self, trainer_id: str) -> int:
        return self._trainer_idx[trainer_id]

    # -- ledger helpers -----------------------------------------------------------
    def _target(self):
        return self.rollup if self.rollup is not None else self.chain

    def client(self):
        """RPC-style façade over this node's ledger (repro.api.NodeClient):
        receipts (proof lifecycle), account views, state root, and the
        typed event stream (``client.events()``).  Shares the node's
        ledger and clock origin."""
        from repro.api.client import NodeClient
        return NodeClient(self._target(), self.chain,
                          gas_table=self.spec.chain.gas_table,
                          clock_start=self._clock)

    def _wire_state(self) -> None:
        """Attach the fixed-schema SoA account state + the default
        protocol counters to the L2 target (idempotent; tests that swap
        ``self.rollup`` for a ShardedRollup re-invoke it)."""
        target = self._target()
        if not hasattr(target, "register_state"):
            return
        for fn, handler in default_state_handlers().items():
            target.register_state(fn, handler)
        # the fabric keeps its StateArrays in ``state``; the single-rollup
        # faces in ``state_arrays`` (``state`` is their L2 dict there)
        from repro.core.state import StateArrays
        st = getattr(target, "state", None)
        self.state_arrays = st if isinstance(st, StateArrays) \
            else target.state_arrays

    def _sync_fabric_state(self) -> None:
        """Cross-shard end-of-window settlement: scatter the reputation
        book and escrow balances/stake into the fabric's StateArrays.
        These rows span every shard partition — the fabric root sealed at
        the next window boundary commits the merged result."""
        state = self.state_arrays
        if state is None:
            return
        target = self._target()
        ids = np.array([target.sender_id(t) for t in self.trainer_ids],
                       np.int64)
        locked = {}
        for per_task in self.escrow.collateral.values():
            for who, amount in per_task.items():
                locked[who] = locked.get(who, 0.0) + amount
        balances = [self.escrow.balances.get(t, 0.0)
                    for t in self.trainer_ids]
        stake = [locked.get(t, 0.0) for t in self.trainer_ids]
        # the scattered rows span every shard partition: account their
        # wire cost NOW (routing/record time — identical on the stepped
        # and fused paths) against the fabric's interconnect model
        ic = getattr(target, "interconnect", None)
        if ic is not None and len(ids):
            ic.record_settle_scatter(len(ids))
        if self._fused is not None:
            # window roots commit this scatter — journal it so the fused
            # replay applies it between the same seal points
            self._fused.sync_state(state, ids,
                                   np.asarray(self.book.reputation,
                                              np.float32), balances, stake)
            return
        sync_book_to_state(self.book, state, ids)
        state.balances[ids] = balances
        state.stake[ids] = stake
        state.mark_dirty(ids)

    def _tx(self, fn: str, sender: str, payload: Dict):
        self._tx_batch(fn, [sender], [payload])

    def _tx_batch(self, fn: str, senders: Sequence[str], payloads=None):
        """Emit one protocol tx per sender (clock-stamped 0.01s apart, same
        as sequential ``_tx`` calls) — one SoA append on the vector engine
        instead of a per-tx Python object.  ``payloads``: a list of dicts
        or a zero-arg callable producing one (only materialized on the
        object path; the SoA engine drops payloads by design)."""
        n = len(senders)
        if n == 0:
            return
        if self.pre_tx_hook is not None:
            self.pre_tx_hook(self._clock)
        target = self._target()
        gas = DEFAULT_GAS.l1_per_call.get(fn, 30000)
        times = self._clock + 0.01 * np.arange(1, n + 1)
        self._clock += 0.01 * n
        if getattr(target, "soa_native", False):
            from repro.core.engine import TxArrays
            # ids MUST come from the target's own namespace: _tx's submit
            # shim registers senders there, and mixing the chain's counter
            # into the rollup's stream would collide/misattribute ids
            sender_ids = np.array(
                [target.sender_id(s) for s in senders], np.int32)
            fid = target.fns.id(fn)
            batch = TxArrays(times, np.full(n, gas, np.int64),
                             np.full(n, fid, np.int32), sender_ids,
                             target.fns)
            if self._fused is not None and self._fused.covers(target):
                # the shard pin rides into the journaled plan — the fused
                # loop replays task-pinned routing at record time
                self._fused.submit(target, batch, shard=self._route_shard)
            elif self._route_shard is not None and hasattr(target, "shards"):
                # task-pinned shard routing (core/shards.py fabric)
                target.submit_arrays(batch, shard=self._route_shard)
            else:
                target.submit_arrays(batch)
        else:
            if callable(payloads):
                payloads = payloads()
            for k, s in enumerate(senders):
                target.submit(Tx(fn, s,
                                 payloads[k] if payloads else {}, gas,
                                 float(times[k])))
        self.protocol_calls[fn] = self.protocol_calls.get(fn, 0) + n

    def _tx_batch_many(self, groups) -> None:
        """Megabatched emission: ``groups`` is ``[(fn, senders, shard)]``
        in the order sequential ``_tx_batch`` calls would have run.  Times
        are stamped over the concatenation exactly as those calls would
        stamp them (clock + 0.01 per tx), and the whole window's protocol
        traffic lands in ONE ``submit_arrays`` per destination shard —
        per-shard tx streams are identical to the per-task calls (submit
        only stages; batches/blocks form at seal time), while the
        interconnect model sees the coalesced routing messages (same
        bytes, fewer transfers — the megabatching win).  SoA targets only
        (payload callables are never materialized there)."""
        groups = [(fn, s, shard) for fn, s, shard in groups if s]
        total = sum(len(s) for _, s, _ in groups)
        if total == 0:
            return
        if self.pre_tx_hook is not None:
            self.pre_tx_hook(self._clock)
        target = self._target()
        assert getattr(target, "soa_native", False), \
            "_tx_batch_many needs a SoA-native target"
        from repro.core.engine import TxArrays
        times = np.empty(total, np.float64)
        gas = np.empty(total, np.int64)
        fn_id = np.empty(total, np.int32)
        sender_id = np.empty(total, np.int32)
        shard_of = np.full(total, -1, np.int64)
        o = 0
        for fn, senders, shard in groups:
            n = len(senders)
            # advance the clock group-by-group with _tx_batch's exact
            # arithmetic — one flat arange over the concatenation drifts
            # by ulps and un-pins event timestamps
            times[o: o + n] = self._clock + 0.01 * np.arange(1, n + 1)
            self._clock += 0.01 * n
            gas[o: o + n] = DEFAULT_GAS.l1_per_call.get(fn, 30000)
            fn_id[o: o + n] = target.fns.id(fn)
            sender_id[o: o + n] = [target.sender_id(s) for s in senders]
            if shard is not None:
                shard_of[o: o + n] = shard
            self.protocol_calls[fn] = self.protocol_calls.get(fn, 0) + n
            o += n
        fused = self._fused if (self._fused is not None
                                and self._fused.covers(target)) else None
        sharded = hasattr(target, "shards")
        if sharded:
            assert (shard_of >= 0).all(), \
                "megabatched emission on a fabric needs per-task shard pins"
            dests = np.unique(shard_of)
        else:
            dests = np.array([-1])
        for k in dests:
            m = shard_of == k if sharded else slice(None)
            batch = TxArrays(times[m], gas[m], fn_id[m], sender_id[m],
                             target.fns)
            pin = int(k) if sharded else None
            if fused is not None:
                fused.submit(target, batch, shard=pin)
            elif pin is not None:
                target.submit_arrays(batch, shard=pin)
            else:
                target.submit_arrays(batch)

    # -- fused end-of-task settlement (step 16, Eq. 2-10) -------------------------
    def settle_window(self, runtimes) -> None:
        """Settle every task that reached "settle_ready" in this window:
        ONE fused reputation update over all K cohorts (batched
        participation masks), then per-task score recording, escrow payout
        and reputation txs.  Row order = runtime order (deterministic)."""
        if not runtimes:
            return
        n = len(self.trainer_ids)
        stack = lambda key: np.stack([getattr(rt, key) for rt in runtimes])
        rounds_total = np.stack([np.full(n, float(rt.rounds), np.float32)
                                 for rt in runtimes])
        self.book, diags = end_of_multitask_update(
            self.book, stack("score_auto"), stack("completed"), rounds_total,
            stack("dists"), stack("participated"), self.rep_params)
        reputations = np.asarray(self.book.reputation)
        s_rep = np.asarray(diags["s_rep"])
        for k, rt in enumerate(runtimes):
            self._route_shard = getattr(rt, "shard", None)
            try:
                self._tx_batch(
                    "calculateSubjectiveRep",
                    [self.trainer_ids[i] for i in rt.sel_idx],
                    lambda k=k, rt=rt: [{"value": float(s_rep[k, i])}
                                        for i in rt.sel_idx])
            finally:
                self._route_shard = None
            self.tsc.record_scores(rt.task_id, {
                self.trainer_ids[i]: float(rt.score_auto[i])
                for i in rt.sel_idx})
            payouts = self.tsc.close_task(rt.task_id)
            diag_k = {key: np.asarray(v[k]) for key, v in diags.items()}
            rt.result = FLTaskResult(rt.params, rt.score_auto, reputations,
                                     payouts, [diag_k])
            rt.phase = "done"
        # cross-shard reputation settlement: commit the merged book/escrow
        # into the array state; the next window-boundary seal roots it
        self._sync_fabric_state()

    # -- one full task (steps 1-16 of Fig. 1), driven sequentially ----------------
    def run_task(self, task, agents, batch_fn=None,
                 **task_kw) -> FLTaskResult:
        """Sequential single-task driver over the TaskRuntime state machine
        (``agents``: a list of TrainingAgents or a fl/cohort.py cohort).
        ``task`` is an ``repro.api.FLTaskSpec`` or a task-id string with
        FLTaskSpec's fields as loose kwargs (``rounds=``, ``reward=``,
        ``n_select=``, ...) — defaults live on FLTaskSpec alone.
        ``Scheduler`` with this one task produces identical outputs —
        pinned by tests/test_scheduler.py."""
        from repro.api.specs import as_task_spec
        from repro.fl.scheduler import TaskRuntime
        task = as_task_spec(task, **task_kw)
        rt = TaskRuntime(self, task.task_id, agents, rounds=task.rounds,
                         reward=task.reward, n_select=task.n_select,
                         init_seed=task.init_seed)
        while rt.phase not in ("settle_ready", "done"):
            rt.step()
        self.settle_window([rt])
        if self.rollup is not None:
            self.rollup.flush()
        self.chain.run_until(self._clock + 5.0)
        return rt.result
