"""Decentralized FL round orchestration: tasks + trainers + DON + reputation
+ escrow + rollup, wired together (the full paper workflow, steps 1-16 of
Fig. 1).  No central server: the 'orchestrator' here is the protocol state
machine every node can replay from the ledger."""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import weighted_average_tree
from repro.core.escrow import Escrow
from repro.core.ledger import AccessControl, Chain, Tx
from repro.core.oracle import DONConfig, evaluate_quorum
from repro.core.reputation import (ReputationParams, TrainerBook,
                                   end_of_task_update, init_book)
from repro.core.rollup import Rollup
from repro.core.storage import BlobStore
from repro.core.tasks import TaskContract
from repro.core.gas import DEFAULT_GAS


@dataclasses.dataclass
class FLTaskResult:
    global_params: object
    scores: np.ndarray
    reputations: np.ndarray
    payouts: Dict[str, float]
    diagnostics: List[Dict]


class AutoDFL:
    """End-to-end protocol harness (the PoC the paper evaluates)."""

    def __init__(self, model, opt, n_trainers: int,
                 eval_fn: Callable, val_batch,
                 rep_params: ReputationParams = ReputationParams(),
                 don: DONConfig = DONConfig(), use_rollup: bool = True,
                 use_pallas_agg: bool = False, seed: int = 0,
                 engine: str = "object"):
        self.model = model
        self.opt = opt
        self.eval_fn = eval_fn
        self.val_batch = val_batch
        self.rep_params = rep_params
        self.don = don
        self.use_rollup = use_rollup
        self.use_pallas_agg = use_pallas_agg

        self.store = BlobStore()
        self.acl = AccessControl(["admin0", "admin1", "admin2"])
        self.escrow = Escrow()
        self.tsc = TaskContract(self.acl, self.escrow, self.store)
        # engine="vector" swaps in the SoA hot path (core/engine.py); the
        # object path stays the default for handler-rich small-N debugging.
        if engine == "vector":
            from repro.core.engine import VectorChain, VectorRollup
            self.chain = VectorChain()
            self.rollup = VectorRollup(self.chain) if use_rollup else None
        else:
            assert engine == "object", f"unknown engine {engine!r}"
            self.chain = Chain()
            self.rollup = Rollup(self.chain) if use_rollup else None
        self.book: TrainerBook = init_book(n_trainers)
        self.trainer_ids = [f"trainer{i}" for i in range(n_trainers)]
        for t in self.trainer_ids:
            self.acl.grant("admin0", t, "trainer")
            self.escrow.fund(t, 10.0)
        self.acl.grant("admin0", "tp0", "task_publisher")
        self.escrow.fund("tp0", 1000.0)
        self._clock = 0.0

    # -- ledger helpers -----------------------------------------------------------
    def _tx(self, fn: str, sender: str, payload: Dict):
        self._clock += 0.01
        gas = DEFAULT_GAS.l1_per_call.get(fn, 30000)
        tx = Tx(fn, sender, payload, gas, self._clock)
        if self.rollup is not None:
            self.rollup.submit(tx)
        else:
            self.chain.submit(tx)

    # -- one full task (steps 1-16 of Fig. 1) -------------------------------------
    def run_task(self, task_id: str, agents, batch_fn, rounds: int = 5,
                 reward: float = 10.0, n_select: Optional[int] = None
                 ) -> FLTaskResult:
        n = len(agents)
        model_cid = self.store.put({"arch": self.model.cfg.name})
        # 1-2: publish (escrow locks the reward)
        self.tsc.publish_task("tp0", task_id, model_cid, model_cid,
                              rounds, 0.5, reward)
        self._tx("publishTask", "tp0", {"taskId": task_id})
        # select trainers by reputation
        reps = {t: float(r) for t, r in
                zip(self.trainer_ids, np.asarray(self.book.reputation))}
        selected = self.tsc.select_trainers(task_id, reps, n_select or n)
        sel_idx = [self.trainer_ids.index(t) for t in selected]
        for t in selected:
            self.escrow.lock_collateral(t, task_id, 1.0)

        params = self.model.init_params(jax.random.key(0))
        opt_states = {i: self.opt.init(params) for i in sel_idx}
        completed = np.zeros(n)
        diagnostics = []

        last_submissions: Dict[int, object] = {}
        for rnd in range(rounds):
            # 3-6: local training + submit
            submissions = {}
            for i in sel_idx:
                agent = agents[i]
                out = agent.train_round(params, opt_states[i], i, rnd)
                if out is None:
                    continue
                completed[i] += 1
                opt_states[i] = out["opt_state"]
                submissions[i] = out["params"]
                self.tsc.submit_local_model(self.trainer_ids[i], task_id,
                                            rnd, out["cid"])
                self._tx("submitLocalModel", self.trainer_ids[i],
                         {"taskId": task_id, "round": rnd, "cid": out["cid"]})
            if not submissions:
                self.tsc.advance_round(task_id)
                continue
            last_submissions = submissions
            # 7-10: DON evaluation
            idxs = sorted(submissions)
            scores, report = evaluate_quorum(
                self.eval_fn, [submissions[i] for i in idxs],
                self.val_batch, self.don)
            for i in idxs:
                self._tx("calculateObjectiveRep", self.trainer_ids[i],
                         {"value": float(scores[idxs.index(i)])})
            # 11-15: reputation-weighted aggregation (Eq. 1)
            stacked = jax.tree.map(
                lambda *xs: jnp.stack(xs), *[submissions[i] for i in idxs])
            params = weighted_average_tree(stacked, scores,
                                           self.use_pallas_agg)
            self.tsc.advance_round(task_id)

        # 16: end-of-task reputation refresh (Eq. 2-10)
        from repro.core.aggregation import tree_flat
        g_flat = tree_flat(params)
        dists = np.zeros(n, np.float32)
        score_auto = np.zeros(n, np.float32)
        participated = np.zeros(n, np.float32)
        for i in sel_idx:
            participated[i] = 1.0
            if i in last_submissions:
                l_flat = tree_flat(last_submissions[i])
                dists[i] = float(jnp.linalg.norm(l_flat - g_flat))
                score_auto[i] = float(self.eval_fn(last_submissions[i],
                                                   self.val_batch))
            else:
                dists[i] = float(np.max(dists)) if dists.any() else 1.0
        self.book, diag = end_of_task_update(
            self.book, jnp.asarray(score_auto), jnp.asarray(completed),
            jnp.full(n, float(rounds)), jnp.asarray(dists),
            jnp.asarray(participated), self.rep_params)
        for i in sel_idx:
            self._tx("calculateSubjectiveRep", self.trainer_ids[i],
                     {"value": float(diag["s_rep"][i])})
        diagnostics.append(jax.tree.map(np.asarray, diag))

        # settle: score-proportional rewards; zero-score slashed
        self.tsc.record_scores(task_id, {
            self.trainer_ids[i]: float(score_auto[i]) for i in sel_idx})
        payouts = self.tsc.close_task(task_id)
        if self.rollup is not None:
            self.rollup.flush()
        self.chain.run_until(self._clock + 5.0)
        return FLTaskResult(params, score_auto,
                            np.asarray(self.book.reputation), payouts,
                            diagnostics)
