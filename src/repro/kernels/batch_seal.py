"""Batch-seal kernel: per-batch xor-mix digests over a sealed tx stream.

``VectorRollup.seal`` folds the lane-sorted word buffer into one digest
per batch — ``[starts[i], starts[i+1])`` word segments through THE
xor-mix (core/engine._mix / kernels.rollup_digest).  This module is the
dedicated kernel for that inner fold, in three interchangeable impls
(kernels/factory.py op ``"batch_seal"``):

  * ``batch_seal_np`` — the bit-exact NumPy mirror (``reduceat``), and
    the implementation behind ``engine.xor_fold_digest_segments``.
  * ``batch_seal_jax`` — one jitted prefix-xor scan; segment digests are
    prefix differences (xor is its own inverse).
  * ``batch_seal_pallas`` — segments scattered into a zero-padded
    (n_batches, width) tile (zero words mix to zero and fold away, the
    same padding contract as ``rollup_chunk_digests``), then one Pallas
    grid pass folds each row — the ``_chunk_kernel`` pattern with a
    batch per grid step.

All three return identical u32 digests for every segmentation (pinned
by tests/test_kernels.py on the {x64 on/off} CPU matrix in CI).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.state import MIX_MULT, MIX_SEED


def batch_seal_np(words: np.ndarray, starts: np.ndarray) -> np.ndarray:
    """NumPy mirror: one digest per ``[starts[i], starts[i+1])`` word
    segment.  Segments must be non-empty (seal batches always are)."""
    w = np.ascontiguousarray(words, np.uint32)
    mixed = (w ^ (w >> np.uint32(16))) * MIX_MULT
    return MIX_SEED ^ np.bitwise_xor.reduceat(mixed, starts)


@jax.jit
def _seal_prefix(words, starts):
    mixed = (words ^ (words >> jnp.uint32(16))) * jnp.uint32(0x85EBCA6B)
    prefix = jax.lax.associative_scan(jnp.bitwise_xor, mixed)
    ends = jnp.concatenate([starts[1:], jnp.asarray(
        [words.shape[0]], starts.dtype)])
    lead = jnp.where(starts > 0, prefix[jnp.maximum(starts - 1, 0)],
                     jnp.uint32(0))
    return jnp.uint32(0x9E3779B9) ^ (prefix[ends - 1] ^ lead)


def batch_seal_jax(words: np.ndarray, starts: np.ndarray) -> np.ndarray:
    """XLA impl: one prefix-xor scan, segment digests by prefix xor."""
    return np.asarray(_seal_prefix(jnp.asarray(words, jnp.uint32),
                                   jnp.asarray(starts, jnp.int32)))


def _seal_kernel(x_ref, o_ref):
    x = x_ref[...]                                # (1, rows, 128)
    mixed = jnp.bitwise_xor(x, x >> 16) * jnp.uint32(0x85EBCA6B)
    o_ref[...] = jax.lax.reduce(mixed, jnp.uint32(0), jnp.bitwise_xor, (1,))


@functools.partial(jax.jit, static_argnames=("interpret",))
def _seal_pallas_call(tiles, *, interpret: bool):
    nb, rows, lanes = tiles.shape
    out = pl.pallas_call(
        _seal_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((1, rows, lanes), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((1, lanes), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, lanes), jnp.uint32),
        interpret=interpret,
    )(tiles)
    return jnp.uint32(0x9E3779B9) ^ jax.lax.reduce(
        out, jnp.uint32(0), jnp.bitwise_xor, (1,))


def batch_seal_pallas(words: np.ndarray, starts: np.ndarray, *,
                      interpret: bool | None = None) -> np.ndarray:
    """Pallas impl: scatter segments into a zero-padded row per batch
    (zero words fold away) and fold rows on a per-batch grid."""
    if interpret is None:
        from repro.kernels.ops import _interpret
        interpret = _interpret()
    w = np.ascontiguousarray(words, np.uint32)
    starts = np.asarray(starts, np.int64)
    nb = len(starts)
    lens = np.diff(np.concatenate([starts, [len(w)]]))
    width = max(128, int(-(-int(lens.max()) // 128)) * 128)
    tiles = np.zeros((nb, width), np.uint32)
    seg = np.repeat(np.arange(nb), lens)
    tiles[seg, np.arange(len(w)) - starts[seg]] = w
    lanes = 128
    out = _seal_pallas_call(
        jnp.asarray(tiles.reshape(nb, width // lanes, lanes)),
        interpret=bool(interpret))
    return np.asarray(out)
