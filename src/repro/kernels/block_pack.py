"""Block-packing kernel: N gas-limited FIFO blocks in one compiled scan.

``VectorChain.produce_block`` packs ONE block with two ``searchsorted``
calls (head-of-line eligibility on the running-max submit times, then the
gas cap on the gas cumsum).  The fused window loop (core/fused.py) needs
the SAME packing decision for every block of a run at once; the carried
mempool pointer makes the blocks sequentially dependent, so this module
lowers the whole loop into one ``lax.scan`` (jax impl) or one Pallas
program (pallas impl) instead of N Python round-trips.

Bit-exactness across backends: the eligibility compare is on float64
submit times and the gas cap on int64 cumsums — neither survives a
float32 downcast (JAX_ENABLE_X64=0) or a TPU (no f64).  Both device
impls therefore binary-search on a **monotone (hi, lo) u32 pair
encoding**: for non-negative IEEE doubles the raw bit pattern orders
exactly like the value, and a non-negative int64 splits into ordered
u32 halves, so the pair-lexicographic compare reproduces the NumPy
float64/int64 ``searchsorted`` decisions bit-for-bit on every backend.

``block_pack_np`` is the bit-exact NumPy mirror (the per-block
``produce_block`` semantics, pinned equal by tests/test_kernels.py);
all three impls are registered with ``kernels.factory`` under op
``"block_pack"``.
"""
from __future__ import annotations

import functools
import warnings

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def _split_f64(x: np.ndarray):
    """Monotone (hi, lo) u32 encoding of non-negative float64 values."""
    x = np.ascontiguousarray(x, np.float64)
    assert x.size == 0 or float(x.min()) >= 0.0, \
        "pair encoding requires non-negative times"
    bits = x.view(np.uint64)
    return (bits >> np.uint64(32)).astype(np.uint32), \
        (bits & np.uint64(0xFFFFFFFF)).astype(np.uint32)


def _split_i64(x: np.ndarray):
    """Monotone (hi, lo) u32 encoding of non-negative int64 values."""
    x = np.ascontiguousarray(x, np.int64)
    assert x.size == 0 or int(x.min()) >= 0, \
        "pair encoding requires non-negative gas"
    u = x.view(np.uint64)
    return (u >> np.uint64(32)).astype(np.uint32), \
        (u & np.uint64(0xFFFFFFFF)).astype(np.uint32)


def _bucket(n: int, floor: int = 16) -> int:
    """Next power-of-two size >= n (shape bucketing keeps the jit cache
    small: one compile per bucket, not one per run length)."""
    return max(floor, 1 << max(0, (int(n) - 1).bit_length()))


# -- NumPy mirror (THE reference semantics: produce_block per block) --------

def block_pack_np(tmax: np.ndarray, gcum: np.ndarray, times: np.ndarray,
                  n_vis: np.ndarray, gas_limit: int,
                  ptr0: int) -> np.ndarray:
    """Pack ``len(times)`` consecutive blocks; returns the per-block FIFO
    stop pointers (int64).

    tmax:  (N,) float64 running max of submit times (arrival order)
    gcum:  (N,) int64 gas cumsum (arrival order)
    times: (B,) float64 block timestamps, nondecreasing
    n_vis: (B,) int64 mempool length visible to each block (txs staged
           before that block's ``run_until`` call)
    Block b confirms ``[stop[b-1], stop[b])`` — exactly what B successive
    ``VectorChain.produce_block(times[b])`` calls would confirm.
    """
    times = np.asarray(times, np.float64)
    n_vis = np.asarray(n_vis, np.int64)
    stops = np.empty(len(times), np.int64)
    ptr = int(ptr0)
    for b in range(len(times)):
        n = int(n_vis[b])
        hi = int(np.searchsorted(tmax[:n], times[b], side="right"))
        hi = max(hi, ptr)
        base = int(gcum[ptr - 1]) if ptr > 0 else 0
        k = int(np.searchsorted(gcum[ptr:hi], base + int(gas_limit),
                                side="right"))
        ptr += k
        stops[b] = ptr
    return stops


# -- shared pair-compare binary search (jnp; used by the scan impl) ---------

def _pair_le(ah, al, bh, bl):
    return (ah < bh) | ((ah == bh) & (al <= bl))


def _search_right(hi_arr, lo_arr, vh, vl, lo0, hi0, iters: int):
    """First index i in [lo0, hi0) with arr[i] > (vh, vl); hi0 if none —
    the pair-encoded ``searchsorted(..., side="right")``."""
    n = hi_arr.shape[0]

    def body(_, lh):
        l, h = lh
        cont = l < h
        m = (l + h) // 2
        mi = jnp.minimum(m, n - 1)
        le = cont & _pair_le(hi_arr[mi], lo_arr[mi], vh, vl)
        return (jnp.where(le, m + 1, l),
                jnp.where(cont & ~le, m, h))
    l, _ = jax.lax.fori_loop(0, iters, body, (lo0, hi0))
    return l


@functools.partial(jax.jit, static_argnames=("iters",),
                   donate_argnums=(0, 1, 2, 3))
def _pack_scan(tmax_hi, tmax_lo, gcum_hi, gcum_lo, t_hi, t_lo, n_vis,
               lim_hi, lim_lo, ptr0, iters: int):
    """One ``lax.scan`` over blocks; the mempool SoA pair buffers are
    donated (consumed by this one fused program)."""
    def block(ptr, xs):
        th, tl, nv = xs
        hi_t = _search_right(tmax_hi, tmax_lo, th, tl,
                             jnp.int32(0), jnp.int32(tmax_hi.shape[0]),
                             iters)
        hi = jnp.maximum(jnp.minimum(hi_t, nv), ptr)
        pm = jnp.maximum(ptr - 1, 0)
        has = ptr > 0
        bh = jnp.where(has, gcum_hi[pm], jnp.uint32(0))
        bl = jnp.where(has, gcum_lo[pm], jnp.uint32(0))
        vl = bl + lim_lo
        vh = bh + lim_hi + (vl < bl).astype(jnp.uint32)
        stop = _search_right(gcum_hi, gcum_lo, vh, vl, ptr, hi, iters)
        return stop, stop
    _, stops = jax.lax.scan(block, jnp.asarray(ptr0, jnp.int32),
                            (t_hi, t_lo, n_vis))
    return stops


def _encode(tmax, gcum, times, n_vis, gas_limit, ptr0):
    """Host-side pair encoding + shape bucketing shared by jax/pallas."""
    n, b = len(tmax), len(times)
    np_, bp = _bucket(n), _bucket(b)
    tmh, tml = _split_f64(tmax)
    gch, gcl = _split_i64(gcum)
    if np_ > n:   # sentinel pad: never time-eligible, never under the cap
        pad = np.full(np_ - n, 0xFFFFFFFF, np.uint32)
        tmh, tml = np.concatenate([tmh, pad]), np.concatenate([tml, pad])
        gch, gcl = np.concatenate([gch, pad]), np.concatenate([gcl, pad])
    th, tl = _split_f64(np.asarray(times, np.float64))
    nv = np.asarray(n_vis, np.int32)
    if bp > b:    # n_vis=0 tail blocks pack nothing (dropped by caller)
        zpad = np.zeros(bp - b, np.uint32)
        th, tl = np.concatenate([th, zpad]), np.concatenate([tl, zpad])
        nv = np.concatenate([nv, np.zeros(bp - b, np.int32)])
    lim = int(gas_limit)
    lim_hi = np.uint32(lim >> 32)
    lim_lo = np.uint32(lim & 0xFFFFFFFF)
    iters = max(1, np_.bit_length() + 1)
    return (tmh, tml, gch, gcl, th, tl, nv, lim_hi, lim_lo,
            np.int32(ptr0), iters)


def block_pack_jax(tmax, gcum, times, n_vis, gas_limit, ptr0) -> np.ndarray:
    """XLA impl: the whole block loop as ONE jitted ``lax.scan``."""
    enc = _encode(tmax, gcum, times, n_vis, gas_limit, ptr0)
    with warnings.catch_warnings():
        # CPU XLA cannot alias these donations; on TPU they are taken
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable")
        stops = _pack_scan(*enc[:-1], iters=enc[-1])
    return np.asarray(stops, np.int64)[: len(times)]


# -- Pallas impl ------------------------------------------------------------

def _pack_kernel(tmh_ref, tml_ref, gch_ref, gcl_ref, th_ref, tl_ref,
                 nv_ref, p0_ref, o_ref, *, iters: int, lim_hi: int,
                 lim_lo: int):
    n = tmh_ref.shape[0]

    def load(ref, i):
        return pl.load(ref, (pl.ds(i, 1),))[0]

    def search(hi_ref, lo_ref, vh, vl, lo0, hi0):
        def body(_, lh):
            l, h = lh
            cont = l < h
            m = (l + h) // 2
            mi = jnp.minimum(m, n - 1)
            le = cont & _pair_le(load(hi_ref, mi), load(lo_ref, mi), vh, vl)
            return (jnp.where(le, m + 1, l),
                    jnp.where(cont & ~le, m, h))
        l, _ = jax.lax.fori_loop(0, iters, body, (lo0, hi0))
        return l

    def block(b, ptr):
        hi_t = search(tmh_ref, tml_ref, load(th_ref, b), load(tl_ref, b),
                      jnp.int32(0), jnp.int32(n))
        hi = jnp.maximum(jnp.minimum(hi_t, load(nv_ref, b)), ptr)
        pm = jnp.maximum(ptr - 1, 0)
        has = ptr > 0
        bh = jnp.where(has, load(gch_ref, pm), jnp.uint32(0))
        bl = jnp.where(has, load(gcl_ref, pm), jnp.uint32(0))
        vl = bl + jnp.uint32(lim_lo)
        vh = bh + jnp.uint32(lim_hi) + (vl < bl).astype(jnp.uint32)
        stop = search(gch_ref, gcl_ref, vh, vl, ptr, hi)
        pl.store(o_ref, (pl.ds(b, 1),), stop[None])
        return stop
    jax.lax.fori_loop(0, th_ref.shape[0], block, p0_ref[0])


@functools.partial(jax.jit, static_argnames=("iters", "lim_hi", "lim_lo",
                                             "interpret"))
def _pack_pallas_call(tmh, tml, gch, gcl, th, tl, nv, ptr0, *, iters,
                      lim_hi, lim_lo, interpret):
    return pl.pallas_call(
        functools.partial(_pack_kernel, iters=iters, lim_hi=lim_hi,
                          lim_lo=lim_lo),
        out_shape=jax.ShapeDtypeStruct(th.shape, jnp.int32),
        interpret=interpret,
    )(tmh, tml, gch, gcl, th, tl, nv, ptr0)


def block_pack_pallas(tmax, gcum, times, n_vis, gas_limit, ptr0, *,
                      interpret: bool | None = None) -> np.ndarray:
    """Pallas impl: one program, sequential blocks, in-kernel pair binary
    search (control-heavy by design — packing is a scalar decision chain,
    not a bandwidth kernel)."""
    if interpret is None:
        from repro.kernels.ops import _interpret
        interpret = _interpret()
    enc = _encode(tmax, gcum, times, n_vis, gas_limit, ptr0)
    tmh, tml, gch, gcl, th, tl, nv, lim_hi, lim_lo, ptr0_, iters = enc
    stops = _pack_pallas_call(
        tmh, tml, gch, gcl, th, tl, nv,
        np.asarray([ptr0_], np.int32), iters=iters, lim_hi=int(lim_hi),
        lim_lo=int(lim_lo), interpret=bool(interpret))
    return np.asarray(stops, np.int64)[: len(times)]


def fused_scan_lowering(n_txs: int, n_blocks: int,
                        gas_limit: int = 9_000_000) -> str:
    """Compiled HLO text of the fused packing scan at a given shape
    (analysis/hlo_cost.py cost assertions; math.inf-free synthetic
    stream)."""
    n_txs, n_blocks = _bucket(n_txs), _bucket(n_blocks)
    iters = max(1, n_txs.bit_length() + 1)
    args = (jnp.zeros(n_txs, jnp.uint32), jnp.zeros(n_txs, jnp.uint32),
            jnp.zeros(n_txs, jnp.uint32), jnp.zeros(n_txs, jnp.uint32),
            jnp.zeros(n_blocks, jnp.uint32), jnp.zeros(n_blocks, jnp.uint32),
            jnp.zeros(n_blocks, jnp.int32), np.uint32(gas_limit >> 32),
            np.uint32(gas_limit & 0xFFFFFFFF), np.int32(0))
    lowered = jax.jit(functools.partial(_pack_scan, iters=iters)).lower(*args)
    return lowered.compile().as_text()
