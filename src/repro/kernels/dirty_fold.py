"""Dirty-chunk refold kernel: per-chunk xor-mix digests of SELECTED chunks.

The chunked state commitment (core/state.py) folds the whole u32 word
buffer every window — O(state) even when a window touched a handful of
account rows.  ``StateArrays`` now caches the per-chunk digest vector and
only the chunks covering dirty rows are refolded before the sha256 seal;
this module is that refold: given the (patched) word buffer and the ids of
the dirty chunks, return one xor-mix digest per dirty chunk.

``dirty_fold_np`` is the bit-exact NumPy mirror — by construction it is
``core.state.chunk_fold_digests(words, chunk)[chunk_ids]``, so the
incremental root is pinned against the full refold (tests/test_state.py)
and every impl here is pinned against the mirror (tests/test_kernels.py).
All arithmetic is u32 (mix + xor), so bit-exactness cannot depend on
JAX_ENABLE_X64 — no pair encoding needed.

Registered with ``kernels.factory`` under op ``"dirty_fold"``:

  * ``numpy``  — reshape + reduce over the selected rows (CPU default:
    a window dirties few chunks, and dispatch overhead beats XLA there);
  * ``jax``    — ONE jitted gather-fold (shapes bucketed to powers of two
    so the jit cache holds one entry per bucket);
  * ``pallas`` — grid over dirty chunks, each program folds one
    lane-aligned chunk block (TPU default; ``interpret=True`` off-TPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

MIX_MULT = np.uint32(0x85EBCA6B)
MIX_SEED = np.uint32(0x9E3779B9)


def _padded(words: np.ndarray, chunk: int) -> np.ndarray:
    w = np.ascontiguousarray(words, dtype=np.uint32)
    pad = (-w.size) % chunk
    if pad:
        w = np.concatenate([w, np.zeros(pad, np.uint32)])
    return w


def _bucket(n: int, floor: int = 8) -> int:
    return max(floor, 1 << max(0, (int(n) - 1).bit_length()))


# -- NumPy mirror (THE reference semantics) ---------------------------------

def dirty_fold_np(words: np.ndarray, chunk_ids: np.ndarray,
                  chunk: int) -> np.ndarray:
    """Digests of the selected chunks: (P,) u32 words + (D,) chunk ids ->
    (D,) u32, where row d is ``MIX_SEED ^ xor-fold(mix(chunk chunk_ids[d]))``
    — exactly ``chunk_fold_digests(words, chunk)[chunk_ids]`` without
    folding the untouched chunks.  Zero padding folds away (zero words mix
    to zero), matching the full fold's padded tail."""
    ids = np.asarray(chunk_ids, np.int64)
    if ids.size == 0:
        return np.zeros(0, np.uint32)
    rows = _padded(words, chunk).reshape(-1, chunk)[ids]
    mixed = (rows ^ (rows >> np.uint32(16))) * MIX_MULT
    return MIX_SEED ^ np.bitwise_xor.reduce(mixed, axis=1)


# -- jax impl: one jitted gather-fold ---------------------------------------

@functools.partial(jax.jit, static_argnames=("chunk",))
def _gather_fold(words2d, ids, chunk: int):
    rows = words2d[ids]                              # (Db, chunk) gather
    mixed = (rows ^ (rows >> jnp.uint32(16))) * jnp.uint32(0x85EBCA6B)
    return jnp.uint32(0x9E3779B9) ^ jax.lax.reduce(
        mixed, jnp.uint32(0), jnp.bitwise_xor, (1,))


def _bucket_ids(ids: np.ndarray) -> np.ndarray:
    """Pad the dirty-id vector to its pow2 bucket (pad ids point at chunk
    0 — their folds are computed and dropped)."""
    db = _bucket(ids.size)
    out = np.zeros(db, np.int64)
    out[: ids.size] = ids
    return out


def dirty_fold_jax(words: np.ndarray, chunk_ids: np.ndarray,
                   chunk: int) -> np.ndarray:
    """XLA impl: one jitted gather + row fold; both the chunk-count and
    the dirty-count axes are bucketed to powers of two so the jit cache
    holds one entry per bucket, not one per state size."""
    ids = np.asarray(chunk_ids, np.int64)
    if ids.size == 0:
        return np.zeros(0, np.uint32)
    w = _padded(words, chunk)
    n_chunks = w.size // chunk
    cb = _bucket(n_chunks, floor=1)
    if cb > n_chunks:                   # zero rows fold to MIX_SEED, unused
        w = np.concatenate([w, np.zeros((cb - n_chunks) * chunk, np.uint32)])
    out = _gather_fold(jnp.asarray(w.reshape(-1, chunk)),
                       jnp.asarray(_bucket_ids(ids)), chunk)
    return np.asarray(out, np.uint32)[: ids.size]


# -- Pallas impl: grid over dirty chunks ------------------------------------

def _fold_kernel(x_ref, o_ref):
    x = x_ref[...]                                   # (1, rows, 128)
    mixed = jnp.bitwise_xor(x, x >> 16) * jnp.uint32(0x85EBCA6B)
    o_ref[...] = jax.lax.reduce(mixed, jnp.uint32(0), jnp.bitwise_xor, (1,))


@functools.partial(jax.jit, static_argnames=("interpret",))
def _fold_pallas_call(rows3d, interpret: bool):
    d, r, lanes = rows3d.shape
    out = pl.pallas_call(
        _fold_kernel,
        grid=(d,),
        in_specs=[pl.BlockSpec((1, r, lanes), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((1, lanes), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((d, lanes), jnp.uint32),
        interpret=interpret,
    )(rows3d)
    # per-chunk lane fold + seed on host-side jnp (d x 128, tiny)
    return jnp.uint32(0x9E3779B9) ^ jax.lax.reduce(
        out, jnp.uint32(0), jnp.bitwise_xor, (1,))


def dirty_fold_pallas(words: np.ndarray, chunk_ids: np.ndarray, chunk: int,
                      *, interpret: bool | None = None) -> np.ndarray:
    """Pallas impl: the device gathers the dirty chunk rows, then one
    program per chunk folds its lane-aligned block (the ``rollup_digest``
    chunk-kernel idiom).  ``chunk`` must be lane-aligned (% 128 == 0) —
    ``STATE_CHUNK_WORDS`` is."""
    assert chunk % 128 == 0, "chunk must be lane-aligned"
    if interpret is None:
        from repro.kernels.ops import _interpret
        interpret = _interpret()
    ids = np.asarray(chunk_ids, np.int64)
    if ids.size == 0:
        return np.zeros(0, np.uint32)
    w = _padded(words, chunk)
    ids_b = _bucket_ids(ids)
    rows = jnp.asarray(w.reshape(-1, chunk))[jnp.asarray(ids_b)]
    out = _fold_pallas_call(rows.reshape(ids_b.size, chunk // 128, 128),
                            bool(interpret))
    return np.asarray(out, np.uint32)[: ids.size]
