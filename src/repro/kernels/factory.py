"""Swappable kernel factory: one registry for every ledger hot-path op.

xformers-``block_factory`` shape: each op registers its interchangeable
implementations under string keys, and call sites ask the factory
instead of hard-wiring one backend:

    from repro.kernels.factory import get_kernel
    stops = get_kernel("block_pack")(tmax, gcum, times, n_vis, limit, p0)

Impl keys (per-op subsets of):

  * ``"numpy"``  — the bit-exact NumPy mirror.  This is also the object
    path's semantics: the per-tx Python engines (core/ledger.py,
    core/rollup.py) are pinned equal to the mirrors by tests.
  * ``"jax"``    — jitted XLA program (scan / prefix-scan forms).
  * ``"pallas"`` — the Pallas TPU kernel (``interpret=True`` off-TPU).

Selection: an explicit ``impl=`` wins; else the ``REPRO_KERNEL_IMPL``
env var; else ``"auto"`` — the op's registered TPU default on a TPU
backend, its CPU default otherwise.  Every impl of an op takes and
returns host NumPy values with identical semantics (bit-exact, pinned
by tests/test_kernels.py), so swapping is a pure performance choice.

Adding a kernel: implement the mirrors in ``kernels/<op>.py``, register
them here in ``_load()``, and pin all impls equal in
tests/test_kernels.py — see docs/KERNELS.md.
"""
from __future__ import annotations

import os
from typing import Callable, Dict, Tuple

_REGISTRY: Dict[str, Dict[str, Callable]] = {}
_DEFAULTS: Dict[str, Dict[str, str]] = {}      # op -> {"cpu": .., "tpu": ..}
_LOADED = False


def register_kernel(op: str, impl: str, fn: Callable, *,
                    cpu_default: bool = False,
                    tpu_default: bool = False) -> Callable:
    """Register ``fn`` as implementation ``impl`` of ``op``."""
    _REGISTRY.setdefault(op, {})[impl] = fn
    d = _DEFAULTS.setdefault(op, {})
    if cpu_default or "cpu" not in d:
        d["cpu"] = impl
    if tpu_default or "tpu" not in d:
        d["tpu"] = impl
    return fn


def _load() -> None:
    """Lazy one-shot registration of the built-in ledger ops (imports
    deferred so importing the factory costs nothing)."""
    global _LOADED
    if _LOADED:
        return
    _LOADED = True
    from repro.kernels import batch_seal as bs
    from repro.kernels import block_pack as bp

    # multi-block FIFO packing (core/fused.py window loop)
    register_kernel("block_pack", "numpy", bp.block_pack_np)
    register_kernel("block_pack", "jax", bp.block_pack_jax,
                    cpu_default=True, tpu_default=True)
    register_kernel("block_pack", "pallas", bp.block_pack_pallas)

    # per-batch seal digests (VectorRollup.seal segment fold)
    register_kernel("batch_seal", "numpy", bs.batch_seal_np,
                    cpu_default=True)
    register_kernel("batch_seal", "jax", bs.batch_seal_jax)
    register_kernel("batch_seal", "pallas", bs.batch_seal_pallas,
                    tpu_default=True)

    # K-lane segmented seal digests (core/fused.py over the sharded
    # fabric: every lane's per-batch roots / per-window update digests
    # fold in one call; "shard_map" runs the lanes over the 1-D "shard"
    # device mesh)
    from repro.kernels import shard_lanes as sl
    register_kernel("shard_seal", "numpy", sl.shard_seal_np,
                    cpu_default=True)
    register_kernel("shard_seal", "jax", sl.shard_seal_jax,
                    tpu_default=True)
    register_kernel("shard_seal", "shard_map", sl.shard_seal_shard_map)

    # merged update-buffer digest (seal commitment; scalar u32 out)
    def _digest_np(words):
        from repro.core.engine import xor_fold_digest
        return xor_fold_digest(words)

    def _digest_pallas(words):
        import jax.numpy as jnp

        import numpy as np
        from repro.kernels.ops import rollup_digest
        return int(rollup_digest(jnp.asarray(
            np.ascontiguousarray(words, np.uint32))))

    def _digest_jax(words):
        import jax.numpy as jnp

        import numpy as np
        from repro.kernels.rollup_digest import rollup_digest_jax
        return int(rollup_digest_jax(jnp.asarray(
            np.ascontiguousarray(words, np.uint32))))

    register_kernel("rollup_digest", "numpy", _digest_np, cpu_default=True)
    register_kernel("rollup_digest", "jax", _digest_jax)
    register_kernel("rollup_digest", "pallas", _digest_pallas,
                    tpu_default=True)

    # dirty-chunk refold (StateArrays incremental commitment): digests of
    # only the chunks a window touched, patched into the cached vector
    from repro.kernels import dirty_fold as df
    register_kernel("dirty_fold", "numpy", df.dirty_fold_np,
                    cpu_default=True)
    register_kernel("dirty_fold", "jax", df.dirty_fold_jax)
    register_kernel("dirty_fold", "pallas", df.dirty_fold_pallas,
                    tpu_default=True)


def available_impls(op: str) -> Tuple[str, ...]:
    _load()
    return tuple(sorted(_REGISTRY.get(op, {})))


def get_kernel(op: str, impl: str | None = None) -> Callable:
    """Resolve ``op`` to one implementation (see module docstring)."""
    _load()
    try:
        table = _REGISTRY[op]
    except KeyError:
        raise KeyError(f"unknown kernel op {op!r}; "
                       f"registered: {sorted(_REGISTRY)}") from None
    choice = impl or os.environ.get("REPRO_KERNEL_IMPL") or "auto"
    if choice == "auto":
        from repro.core.state import tpu_digest_backend
        choice = _DEFAULTS[op]["tpu" if tpu_digest_backend() else "cpu"]
    try:
        return table[choice]
    except KeyError:
        raise KeyError(f"kernel op {op!r} has no impl {choice!r}; "
                       f"available: {sorted(table)}") from None
