"""Pallas TPU kernel: causal GQA flash attention (online softmax).

The model-compute hot-spot for the prefill_32k cells: scores never leave
VMEM (the XLA blocked path materialises them in HBM — see EXPERIMENTS.md
§Perf for the measured delta).

Grid: (B*H, n_q_blocks, n_kv_blocks), kv innermost ("arbitrary" semantics so
the accumulator scratch carries across kv steps).  Causality is handled by
skipping fully-masked kv blocks via pl.when and edge-masking the diagonal
block.  GQA: kv head index = q head // (H // Hkv) via the BlockSpec index
map — no repeat materialisation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
            *, scale, block_q, block_k, causal):
    qi, ki = pl.program_id(1), pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    run = (not causal) or (ki * block_k <= qi * block_q + block_q - 1)

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                 # (bq, dh)
        k = k_ref[0].astype(jnp.float32)                 # (bk, dh)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            rows = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            cols = ki * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0].astype(jnp.float32)                 # (bk, dh)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * corr + pv
        m_ref[...] = m_new

    @pl.when(ki == pl.num_programs(2) - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "block_q", "block_k",
                                    "interpret"))
def flash_attention(q, k, v, causal: bool = True, block_q: int = 512,
                    block_k: int = 512, interpret: bool = False):
    """q: (B, S, H, dh); k, v: (B, S, Hkv, dh) -> (B, S, H, dh)."""
    B, S, H, dh = q.shape
    Hkv = k.shape[2]
    n_rep = H // Hkv
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0

    # (B, S, H, dh) -> (B*H, S, dh) layout for a flat batch-head grid
    qh = jnp.moveaxis(q, 2, 1).reshape(B * H, S, dh)
    kh = jnp.moveaxis(k, 2, 1).reshape(B * Hkv, S, dh)
    vh = jnp.moveaxis(v, 2, 1).reshape(B * Hkv, S, dh)

    def kv_index(bh, qi, ki):
        return (bh // n_rep, ki, 0)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=dh ** -0.5, block_q=block_q,
                          block_k=block_k, causal=causal),
        grid=(B * H, S // block_q, S // block_k),
        in_specs=[
            pl.BlockSpec((1, block_q, dh), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, dh), kv_index),
            pl.BlockSpec((1, block_k, dh), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, dh),
                               lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, dh), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qh, kh, vh)
    return jnp.moveaxis(out.reshape(B, H, S, dh), 1, 2)
