"""Pallas TPU kernel: expert-grouped matmul (MoE hot-spot).

Operates on the capacity-dispatched layout (E, C, d) x (E, d, f) -> (E, C, f)
— the megablox idea adapted to the framework's dispatch path: each grid step
multiplies one expert's token tile against that expert's weight tile, with
the expert index driving the weight BlockSpec index map (weights stream
through VMEM once per expert, not per token tile).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, o_ref):
    x = x_ref[0].astype(jnp.float32)      # (bc, d)
    w = w_ref[0].astype(jnp.float32)      # (d, bf)
    o_ref[0] = jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_c", "block_f",
                                             "interpret"))
def gmm(xe: jnp.ndarray, w: jnp.ndarray, block_c: int = 128,
        block_f: int = 512, interpret: bool = False) -> jnp.ndarray:
    """xe: (E, C, d); w: (E, d, f) -> (E, C, f)."""
    E, C, d = xe.shape
    _, _, f = w.shape
    block_c = min(block_c, C)
    block_f = min(block_f, f)
    pad_c = (-C) % block_c
    pad_f = (-f) % block_f
    if pad_c:
        xe = jnp.pad(xe, ((0, 0), (0, pad_c), (0, 0)))
    if pad_f:
        w = jnp.pad(w, ((0, 0), (0, 0), (0, pad_f)))
    Cp, fp = C + pad_c, f + pad_f

    out = pl.pallas_call(
        _kernel,
        grid=(E, Cp // block_c, fp // block_f),
        in_specs=[
            pl.BlockSpec((1, block_c, d), lambda e, ci, fi: (e, ci, 0)),
            pl.BlockSpec((1, d, block_f), lambda e, ci, fi: (e, 0, fi)),
        ],
        out_specs=pl.BlockSpec((1, block_c, block_f),
                               lambda e, ci, fi: (e, ci, fi)),
        out_shape=jax.ShapeDtypeStruct((E, Cp, fp), xe.dtype),
        interpret=interpret,
    )(xe, w)
    return out[:, :C, :f]
