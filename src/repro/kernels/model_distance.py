"""Pallas TPU kernel: per-trainer model distance (paper Eq. 4).

    D[i] = || w_local[i, :] - w_global[:] ||_2

Fused subtract-square-reduce over parameter tiles; per-trainer partial sums
accumulate in the output block across the (arbitrary-order) parameter grid
axis, initialised at the first step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(l_ref, g_ref, o_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    d = l_ref[...].astype(jnp.float32) - g_ref[...].astype(jnp.float32)
    o_ref[...] += jnp.sum(d * d, axis=1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("block_p", "interpret"))
def model_distance(local: jnp.ndarray, global_: jnp.ndarray,
                   block_p: int = 4096, interpret: bool = False):
    """local: (n, P); global_: (P,) -> (n,) L2 distances."""
    n, P = local.shape
    pad = (-P) % block_p
    if pad:
        local = jnp.pad(local, ((0, 0), (0, pad)))
        global_ = jnp.pad(global_, (0, pad))
    Pp = P + pad
    g2 = global_.reshape(1, Pp)

    sq = pl.pallas_call(
        _kernel,
        grid=(Pp // block_p,),
        in_specs=[
            pl.BlockSpec((n, block_p), lambda i: (0, i)),
            pl.BlockSpec((1, block_p), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((n, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 1), jnp.float32),
        interpret=interpret,
    )(local, g2)
    return jnp.sqrt(sq[:, 0])
