"""Jit'd public wrappers over the Pallas kernels.

On this container (CPU) the kernels execute via ``interpret=True``; on TPU
set ``REPRO_PALLAS_INTERPRET=0`` (the default when a TPU backend is
detected).  The XLA reference paths (ref.py) remain the numerics oracle and
the dry-run/roofline path (custom-calls hide FLOPs from cost analysis).
"""
from __future__ import annotations

import os

import jax

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.gmm import gmm as _gmm
from repro.kernels.model_distance import model_distance as _dist
from repro.kernels.rollup_digest import rollup_digest as _digest
from repro.kernels.slstm_scan import slstm_scan as _slstm
from repro.kernels.weighted_agg import weighted_agg as _wagg


def _interpret() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


def weighted_agg(stacked, scores, **kw):
    return _wagg(stacked, scores, interpret=_interpret(), **kw)


def model_distance(local, global_, **kw):
    return _dist(local, global_, interpret=_interpret(), **kw)


def flash_attention(q, k, v, causal=True, **kw):
    return _flash(q, k, v, causal=causal, interpret=_interpret(), **kw)


def gmm(xe, w, **kw):
    return _gmm(xe, w, interpret=_interpret(), **kw)


def rollup_digest(buf, **kw):
    return _digest(buf, interpret=_interpret(), **kw)


def slstm_scan(wx, r_expanded, h0, c0, n0, m0, nh, **kw):
    return _slstm(wx, r_expanded, h0, c0, n0, m0, nh,
                  interpret=_interpret(), **kw)


# re-export oracles for tests
weighted_agg_ref = ref.weighted_agg_ref
model_distance_ref = ref.model_distance_ref
flash_attention_ref = ref.flash_attention_ref
gmm_ref = ref.gmm_ref
rollup_digest_ref = ref.rollup_digest_ref
