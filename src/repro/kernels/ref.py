"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def weighted_agg_ref(stacked: jnp.ndarray, scores: jnp.ndarray) -> jnp.ndarray:
    """Eq. 1: (n, P), (n,) -> (P,) score-weighted average, f32 accumulation."""
    s = scores.astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(s), 1e-12)
    return (jnp.einsum("np,n->p", stacked.astype(jnp.float32), s)
            / denom).astype(stacked.dtype)


def model_distance_ref(local: jnp.ndarray, global_: jnp.ndarray) -> jnp.ndarray:
    """Eq. 4: (n, P), (P,) -> (n,) L2 distances, f32 accumulation."""
    d = local.astype(jnp.float32) - global_.astype(jnp.float32)[None]
    return jnp.sqrt(jnp.sum(d * d, axis=-1))


def flash_attention_ref(q, k, v, causal: bool = True):
    """(B, S, H, dh), (B, S, Hkv, dh) x2 -> (B, S, H, dh), GQA via repeat."""
    B, S, H, dh = q.shape
    n_rep = H // k.shape[2]
    k = jnp.repeat(k, n_rep, axis=2)
    v = jnp.repeat(v, n_rep, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * dh ** -0.5
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)


def gmm_ref(xe: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Expert-grouped matmul: (E, C, d) x (E, d, f) -> (E, C, f)."""
    return jnp.einsum("ecd,edf->ecf", xe.astype(jnp.float32),
                      w.astype(jnp.float32)).astype(xe.dtype)


def rollup_digest_ref(buf_u32: jnp.ndarray) -> jnp.ndarray:
    """Chunked XOR-mix fold over a u32 buffer -> scalar u32."""
    mixed = jnp.bitwise_xor(buf_u32, buf_u32 >> 16) * jnp.uint32(0x85EBCA6B)
    out = jnp.uint32(0x9E3779B9)
    return out ^ jax.lax.reduce(mixed, jnp.uint32(0), jnp.bitwise_xor, (0,))
