"""Pallas TPU kernel: rollup validity digest (chunked XOR-mix fold).

The 'prove' stand-in of the rollup commit (see core/rollup.py): a
deterministic integrity digest over the merged update buffer, computed
in-line with aggregation so the commit adds no extra HBM pass.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, o_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)   # seed applied in the wrapper

    x = x_ref[...]
    mixed = jnp.bitwise_xor(x, x >> 16) * jnp.uint32(0x85EBCA6B)
    # lane-wise fold, then fold the running lane vector into the out block
    o_ref[...] = jnp.bitwise_xor(
        o_ref[...],
        jax.lax.reduce(mixed, jnp.uint32(0), jnp.bitwise_xor, (0,))[None])


@functools.partial(jax.jit, static_argnames=("block_p", "interpret"))
def rollup_digest(buf: jnp.ndarray, block_p: int = 16384,
                  interpret: bool = False) -> jnp.ndarray:
    """buf: (P,) float32/uint32 buffer -> scalar u32 digest."""
    if buf.dtype != jnp.uint32:
        buf = jax.lax.bitcast_convert_type(buf.astype(jnp.float32), jnp.uint32)
    P = buf.shape[0]
    pad = (-P) % block_p
    if pad:
        buf = jnp.pad(buf, (0, pad))
    Pp = P + pad
    lanes = 128
    rows = Pp // lanes
    buf2 = buf.reshape(rows, lanes)
    block_r = max(1, min(rows, block_p // lanes))

    out = pl.pallas_call(
        _kernel,
        grid=(max(1, rows // block_r),),
        in_specs=[pl.BlockSpec((block_r, lanes), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, lanes), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, lanes), jnp.uint32),
        interpret=interpret,
    )(buf2)
    # final lane fold on host-side jnp (tiny); seed applied here so the
    # lane-broadcast in the kernel cannot cancel it (even lane count)
    return jnp.uint32(0x9E3779B9) ^ jax.lax.reduce(
        out[0], jnp.uint32(0), jnp.bitwise_xor, (0,))


@jax.jit
def rollup_digest_jax(buf: jnp.ndarray) -> jnp.ndarray:
    """Pure-jnp VPU form of ``rollup_digest`` (no pallas_call): the
    device-portable middle impl the kernel factory registers as
    ``("rollup_digest", "jax")``.  Bit-exact with the NumPy mirror
    ``core.engine.xor_fold_digest`` (semantics-of-record) and the Pallas
    form above — pinned by tests/test_kernels.py.  An empty buffer folds
    to the bare seed, matching the mirror."""
    if buf.dtype != jnp.uint32:
        buf = jax.lax.bitcast_convert_type(buf.astype(jnp.float32), jnp.uint32)
    mixed = jnp.bitwise_xor(buf, buf >> 16) * jnp.uint32(0x85EBCA6B)
    return jnp.uint32(0x9E3779B9) ^ jax.lax.reduce(
        mixed, jnp.uint32(0), jnp.bitwise_xor, (0,))


def _chunk_kernel(x_ref, o_ref):
    x = x_ref[...]                                # (1, rows_per_chunk, 128)
    mixed = jnp.bitwise_xor(x, x >> 16) * jnp.uint32(0x85EBCA6B)
    # fold the chunk's rows into one lane vector; this block IS the whole
    # chunk, so no cross-invocation accumulation is needed
    o_ref[...] = jax.lax.reduce(mixed, jnp.uint32(0), jnp.bitwise_xor, (1,))


@functools.partial(jax.jit, static_argnames=("chunk_p", "interpret"))
def rollup_chunk_digests(buf: jnp.ndarray, chunk_p: int = 2048,
                         interpret: bool = False) -> jnp.ndarray:
    """Per-chunk digests for the chunked state commitment (core/state.py).

    buf: (P,) float32/uint32 buffer -> (ceil(P/chunk_p),) u32, one xor-mix
    fold per ``chunk_p``-word chunk (zero-padded tail; zero words fold
    away).  ``core.state.chunk_fold_digests`` is the bit-exact NumPy
    mirror, pinned by tests/test_state.py.  chunk_p must be lane-aligned
    (% 128) so each chunk maps to whole VPU rows.
    """
    assert chunk_p % 128 == 0, "chunk must be lane-aligned"
    if buf.dtype != jnp.uint32:
        buf = jax.lax.bitcast_convert_type(buf.astype(jnp.float32), jnp.uint32)
    P = buf.shape[0]
    assert P > 0, "empty buffer has no chunks"
    pad = (-P) % chunk_p
    if pad:
        buf = jnp.pad(buf, (0, pad))
    lanes = 128
    n_chunks = (P + pad) // chunk_p
    rows = chunk_p // lanes
    buf3 = buf.reshape(n_chunks, rows, lanes)

    out = pl.pallas_call(
        _chunk_kernel,
        grid=(n_chunks,),
        in_specs=[pl.BlockSpec((1, rows, lanes), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((1, lanes), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_chunks, lanes), jnp.uint32),
        interpret=interpret,
    )(buf3)
    # per-chunk lane fold + seed on host-side jnp (n_chunks x 128, tiny)
    return jnp.uint32(0x9E3779B9) ^ jax.lax.reduce(
        out, jnp.uint32(0), jnp.bitwise_xor, (1,))


@functools.partial(jax.jit, static_argnames=("width",))
def rollup_aggregate_digests(digests: jnp.ndarray,
                             width: int) -> jnp.ndarray:
    """Recursive proof aggregation: (n,) u32 digests -> (ceil(n/width),)
    u32 aggregate digests.

    The prover pipeline's aggregation stage (core/prover.py) applies the
    SAME xor-mix fold the batch digests were built with, one level up:
    batch tx words -> batch digest -> session proof -> aggregate proof.
    The digest vector is tiny (one word per proof), so this is a plain
    jitted VPU fold rather than a pallas_call; ``core.state.
    chunk_fold_digests(digests, chunk=width)`` is the bit-exact NumPy
    mirror (pinned by tests/test_prover.py).  Zero padding folds away
    (zero words mix to zero), matching the chunk kernel's padded tail.
    """
    d = jnp.asarray(digests, jnp.uint32)
    pad = (-d.shape[0]) % width
    if pad:
        d = jnp.pad(d, (0, pad))
    d2 = d.reshape(-1, width)
    mixed = jnp.bitwise_xor(d2, d2 >> 16) * jnp.uint32(0x85EBCA6B)
    return jnp.uint32(0x9E3779B9) ^ jax.lax.reduce(
        mixed, jnp.uint32(0), jnp.bitwise_xor, (1,))
