"""Shard-lane seal kernel: K shards' segmented xor-fold digests at once.

The fused fabric loop (core/fused.py over core/shards.ShardedRollup)
precomputes every shard lane's seal structure, then needs each lane's
per-batch tx roots and per-window update digests — K independent
``batch_seal``-style segmented folds.  This module is the dedicated
multi-lane kernel (kernels/factory.py op ``"shard_seal"``): the K lanes
become the rows of one ``(K, W)`` SoA word grid, and ONE call folds
every lane's segments:

  * ``shard_seal_np``        — the bit-exact NumPy mirror (per-row
    ``reduceat``, THE semantics);
  * ``shard_seal_jax``       — one jitted program: a 2-D prefix-xor
    ``associative_scan`` over the row axis-1, segment digests by prefix
    difference (the ``batch_seal_jax`` form, vectorized over lanes);
  * ``shard_seal_shard_map`` — the same fold ``shard_map``-ped over a
    1-D ``"shard"`` mesh axis (launch/mesh.make_shard_mesh +
    sharding/specs.shard_lane_spec): each device owns a contiguous row
    block of lanes, the SoA starts grid is donated (it shares the
    output's byte layout, so XLA folds in place), and rows pad to the
    mesh size with empty lanes.  This is the shape real parallel shard
    execution takes — per-lane work with no cross-lane traffic until
    the fabric root merge (modeled by core/interconnect.py).

Call contract (shared by all impls, pinned bit-exact by
tests/test_shard_lanes.py on the CI ``kernel-parity`` + ``shard-mesh``
matrices):

    shard_seal(words, starts, n_seg, n_words) -> (K, B) uint32

  * ``words``   (K, W) u32 — row ``k``'s word buffer in its first
    ``n_words[k]`` columns, zero-padded after (zero words mix to zero
    and fold away — the ``batch_seal_pallas`` padding contract);
  * ``starts``  (K, B) int — row ``k``'s segment starts in its first
    ``n_seg[k]`` columns, strictly increasing and ``< n_words[k]``
    (segments are non-empty); padded columns MUST hold ``n_words[k]``;
  * output row ``k``: the segment digests in the first ``n_seg[k]``
    columns; every padded column holds ``MIX_SEED`` (the fold of an
    empty segment).  Real segments reproduce
    ``engine.xor_fold_digest_segments`` bit-for-bit.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.state import MIX_MULT, MIX_SEED


def _pow2(n: int, floor: int) -> int:
    """Smallest power of two >= max(n, floor) — jit-cache bucketing."""
    return 1 << max(n - 1, floor - 1, 1).bit_length()


# -- NumPy mirror (THE semantics) ---------------------------------------------
def shard_seal_np(words: np.ndarray, starts: np.ndarray,
                  n_seg: np.ndarray, n_words: np.ndarray) -> np.ndarray:
    """Per-row ``batch_seal_np``: fold row ``k``'s ``n_seg[k]`` segments
    over its ``n_words[k]`` live words; padded output cells = MIX_SEED."""
    words = np.asarray(words, np.uint32)
    starts = np.asarray(starts, np.int64)
    K, B = starts.shape
    out = np.full((K, B), MIX_SEED, np.uint32)
    for k in range(K):
        ns, nw = int(n_seg[k]), int(n_words[k])
        if ns == 0:
            continue
        w = words[k, :nw]
        mixed = (w ^ (w >> np.uint32(16))) * MIX_MULT
        out[k, :ns] = MIX_SEED ^ np.bitwise_xor.reduceat(
            mixed, starts[k, :ns])
    return out


# -- one jitted 2-D prefix-xor program ----------------------------------------
def _lane_fold(words, starts):
    """(K, W) u32 x (K, B) i32 -> (K, B) u32 — prefix-xor per row,
    segment digests by prefix difference.  Padded starts (== n_words)
    yield MIX_SEED because their lead and last prefixes coincide."""
    mixed = (words ^ (words >> jnp.uint32(16))) * jnp.uint32(0x85EBCA6B)
    prefix = jax.lax.associative_scan(jnp.bitwise_xor, mixed, axis=1)
    w = words.shape[1]
    # starts arrive as u32 (same element type as the output, so the
    # donated grid aliases it); index via i32 views — shapes are tiny
    ends = jnp.concatenate(
        [starts[:, 1:], jnp.full((starts.shape[0], 1), w, starts.dtype)],
        axis=1).astype(jnp.int32)
    s32 = starts.astype(jnp.int32)
    last = jnp.where(ends > 0, jnp.take_along_axis(
        prefix, jnp.maximum(ends - 1, 0), axis=1), jnp.uint32(0))
    lead = jnp.where(s32 > 0, jnp.take_along_axis(
        prefix, jnp.maximum(s32 - 1, 0), axis=1), jnp.uint32(0))
    return jnp.uint32(0x9E3779B9) ^ (last ^ lead)


# donate the starts grid: it is (K, B) i32 — the same byte layout as
# the (K, B) u32 output, so XLA reuses it in place (the larger word
# grid can never alias the output and is left alone)
@functools.partial(jax.jit, donate_argnums=(1,))
def _lane_fold_jit(words, starts):
    return _lane_fold(words, starts)


def _padded(words, starts, n_words):
    """Bucket (K, W)/(K, B) to power-of-two shapes, preserving the call
    contract: words pad with zeros, starts pad with each row's n_words."""
    K, W = words.shape
    B = starts.shape[1]
    Wp, Bp = _pow2(W, 128), _pow2(B, 8)
    wp = np.zeros((K, Wp), np.uint32)
    wp[:, :W] = words
    sp = np.repeat(np.asarray(n_words, np.uint32)[:, None], Bp, axis=1)
    sp[:, :B] = starts
    return wp, sp


def shard_seal_jax(words: np.ndarray, starts: np.ndarray,
                   n_seg: np.ndarray, n_words: np.ndarray) -> np.ndarray:
    """One compiled program for all K lanes (shapes bucketed to powers
    of two so the jit cache holds one entry per bucket; the starts grid
    is donated — it is consumed)."""
    B = starts.shape[1]
    wp, sp = _padded(np.asarray(words, np.uint32),
                     np.asarray(starts), n_words)
    out = _lane_fold_jit(jnp.asarray(wp), jnp.asarray(sp))
    return np.asarray(out)[:, :B]


# -- the same fold over a 1-D "shard" mesh ------------------------------------
@functools.lru_cache(maxsize=None)
def _lane_fold_mapped(mesh):
    """shard_map the fold over the mesh's "shard" axis: each device owns
    a contiguous block of lane rows; no cross-device collectives — the
    fabric-root merge is the only cross-lane step, and it happens on the
    host (with its wire cost modeled by core/interconnect.py)."""
    from jax.experimental.shard_map import shard_map

    from repro.sharding.specs import shard_lane_spec
    spec = shard_lane_spec()
    fn = shard_map(_lane_fold, mesh=mesh,
                   in_specs=(spec, spec), out_specs=spec)
    return jax.jit(fn, donate_argnums=(1,))


def shard_seal_shard_map(words: np.ndarray, starts: np.ndarray,
                         n_seg: np.ndarray, n_words: np.ndarray, *,
                         mesh=None) -> np.ndarray:
    """Mesh-mapped impl: lane rows pad to a multiple of the mesh size
    with empty lanes (n_words=0 -> a row of MIX_SEED, sliced off)."""
    from repro.launch.mesh import make_shard_mesh
    if mesh is None:
        mesh = make_shard_mesh()
    d = int(np.prod(list(mesh.shape.values())))
    K, B = starts.shape
    wp, sp = _padded(np.asarray(words, np.uint32),
                     np.asarray(starts), n_words)
    kp = -(-K // d) * d
    if kp != K:
        wp = np.concatenate([wp, np.zeros((kp - K, wp.shape[1]),
                                          np.uint32)])
        sp = np.concatenate([sp, np.zeros((kp - K, sp.shape[1]),
                                          sp.dtype)])
    out = _lane_fold_mapped(mesh)(jnp.asarray(wp), jnp.asarray(sp))
    return np.asarray(out)[:K, :B]
