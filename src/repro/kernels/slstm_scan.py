"""Pallas TPU kernel: fused sLSTM time scan (the xLSTM sequential hot-spot).

The XLA while-loop pays fixed loop-carry costs every timestep (measured in
EXPERIMENTS.md §Perf cell B); this kernel keeps the recurrent state (h, c,
n, m) AND the block-diagonal recurrent weights resident in VMEM scratch and
streams wx/h through HBM exactly once:

  grid = (S / block_t,)   "arbitrary" — state scratch carries across steps
  per step: read one (B, block_t, 4d) wx tile, run block_t recurrent steps
  in-register, write one (B, block_t, d) h tile.

Analytic HBM traffic: (B*S*4d + B*S*d) * bytes + weights once — ~3.2 GB per
xlstm-1.3b layer vs the ~1.5 TB/chip measured for the XLA loop path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(wx_ref, r_ref, h0_ref, c0_ref, n0_ref, m0_ref,
            y_ref, hN_ref, cN_ref, nN_ref, mN_ref,
            h_s, c_s, n_s, m_s, *, block_t, nh, dh):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        h_s[...] = h0_ref[...].astype(jnp.float32)
        c_s[...] = c0_ref[...].astype(jnp.float32)
        n_s[...] = n0_ref[...].astype(jnp.float32)
        m_s[...] = m0_ref[...].astype(jnp.float32)

    r = r_ref[...].astype(jnp.float32)              # (nh*dh, 4*dh)
    d = nh * dh

    def step(t, _):
        h = h_s[...]                                # (B, d)
        # recurrent matmul against the block-diag-expanded (d, 4d) weights
        rec = jax.lax.dot_general(h, r, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
        wx_t = wx_ref[:, t, :].astype(jnp.float32)  # (B, 4d)
        gates = wx_t + rec
        zi = gates[:, 0 * d:1 * d]
        ii = gates[:, 1 * d:2 * d]
        ff = gates[:, 2 * d:3 * d]
        oo = gates[:, 3 * d:4 * d]
        logf = jax.nn.log_sigmoid(ff)
        m_new = jnp.maximum(logf + m_s[...], ii)
        fw = jnp.exp(logf + m_s[...] - m_new)
        iw = jnp.exp(ii - m_new)
        c_new = fw * c_s[...] + iw * jnp.tanh(zi)
        n_new = fw * n_s[...] + iw
        h_new = jax.nn.sigmoid(oo) * c_new / jnp.maximum(n_new, 1e-6)
        h_s[...], c_s[...], n_s[...], m_s[...] = h_new, c_new, n_new, m_new
        y_ref[:, t, :] = h_new.astype(y_ref.dtype)
        return ()

    jax.lax.fori_loop(0, block_t, step, ())

    @pl.when(i == pl.num_programs(0) - 1)
    def _final():
        hN_ref[...] = h_s[...]
        cN_ref[...] = c_s[...]
        nN_ref[...] = n_s[...]
        mN_ref[...] = m_s[...]


@functools.partial(jax.jit, static_argnames=("nh", "block_t", "interpret"))
def slstm_scan(wx, r_expanded, h0, c0, n0, m0, nh: int, block_t: int = 64,
               interpret: bool = False):
    """wx: (B, S, 4d); r_expanded: (d, 4d) block-diag-expanded recurrent
    weights; state h0/c0/n0/m0: (B, d) f32.  Returns (y (B,S,d) f32,
    (hN, cN, nN, mN))."""
    B, S, d4 = wx.shape
    d = d4 // 4
    dh = d // nh
    block_t = min(block_t, S)
    assert S % block_t == 0

    out_shapes = (
        jax.ShapeDtypeStruct((B, S, d), jnp.float32),
        jax.ShapeDtypeStruct((B, d), jnp.float32),
        jax.ShapeDtypeStruct((B, d), jnp.float32),
        jax.ShapeDtypeStruct((B, d), jnp.float32),
        jax.ShapeDtypeStruct((B, d), jnp.float32),
    )
    grid = (S // block_t,)
    state_spec = pl.BlockSpec((B, d), lambda i: (0, 0))
    y, hN, cN, nN, mN = pl.pallas_call(
        functools.partial(_kernel, block_t=block_t, nh=nh, dh=dh),
        grid=grid,
        in_specs=[
            pl.BlockSpec((B, block_t, d4), lambda i: (0, i, 0)),
            pl.BlockSpec((d, d4), lambda i: (0, 0)),
            state_spec, state_spec, state_spec, state_spec,
        ],
        out_specs=(
            pl.BlockSpec((B, block_t, d), lambda i: (0, i, 0)),
            state_spec, state_spec, state_spec, state_spec,
        ),
        out_shape=out_shapes,
        scratch_shapes=[pltpu.VMEM((B, d), jnp.float32) for _ in range(4)],
        interpret=interpret,
    )(wx, r_expanded, h0, c0, n0, m0)
    return y, (hN, cN, nN, mN)


def expand_block_diag(r_gates):
    """(nh, dh, 4dh) block-diagonal weights -> dense (d, 4d) with the same
    action: rec[b] = h[b] @ R_expanded  ==  per-head h @ r."""
    nh, dh, dh4 = r_gates.shape
    d = nh * dh
    out = jnp.zeros((d, 4 * d), r_gates.dtype)
    for h in range(nh):
        for g in range(4):
            out = out.at[h * dh:(h + 1) * dh,
                         g * d + h * dh: g * d + (h + 1) * dh].set(
                r_gates[h, :, g * dh:(g + 1) * dh])
    return out
