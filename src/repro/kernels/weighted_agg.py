"""Pallas TPU kernel: reputation-weighted aggregation (paper Eq. 1).

    out[p] = sum_n s[n] * w[n, p] / sum_n s[n]

The aggregation hot-spot of the paper's DON/aggregator role: n trainers'
model shards are folded in one pass.  Tiling: the parameter axis is split
into lane-aligned tiles resident in VMEM; the (small) trainer axis stays
whole so the weighted reduction is a single (1, n) x (n, Pt) MXU matvec per
tile with f32 accumulation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128


def _kernel(s_ref, w_ref, denom_ref, o_ref):
    s = s_ref[...].astype(jnp.float32)           # (1, n)
    w = w_ref[...].astype(jnp.float32)           # (n, Pt)
    acc = jax.lax.dot_general(s, w, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (1, Pt)
    o_ref[...] = (acc / denom_ref[0, 0]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_p", "interpret"))
def weighted_agg(stacked: jnp.ndarray, scores: jnp.ndarray,
                 block_p: int = 4096, interpret: bool = False) -> jnp.ndarray:
    """stacked: (n, P) trainer weights; scores: (n,) -> (P,)."""
    n, P = stacked.shape
    pad = (-P) % block_p
    if pad:
        stacked = jnp.pad(stacked, ((0, 0), (0, pad)))
    Pp = P + pad
    s2 = scores.astype(jnp.float32).reshape(1, n)
    denom = jnp.maximum(jnp.sum(s2), 1e-12).reshape(1, 1)

    out = pl.pallas_call(
        _kernel,
        grid=(Pp // block_p,),
        in_specs=[
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((n, block_p), lambda i: (0, i)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_p), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, Pp), stacked.dtype),
        interpret=interpret,
    )(s2, stacked, denom)
    return out[0, :P]
