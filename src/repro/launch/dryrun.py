import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines: jax locks the device count on first init.
#   512 placeholder host devices let jax.make_mesh build the production mesh.
#   Never set this outside the dry-run (smoke tests / benches see 1 device).

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture x input shape x mesh) cell:
    lowered  = jax.jit(step, in_shardings=..., out_shardings=...).lower(*args)
    compiled = lowered.compile()
    print(compiled.memory_analysis())    # proves it fits
    print(compiled.cost_analysis())      # XLA's own numbers (scan-undercounted)
plus the loop-aware HLO walk (analysis/hlo_cost.py) that produces the honest
FLOP / byte / collective-byte roofline terms.

Usage:
    python -m repro.launch.dryrun --arch yi-6b --shape train_4k --mesh single
    python -m repro.launch.dryrun --all --mesh both --out results/dryrun
"""
import argparse
import json
import time
import traceback

from repro.analysis.hlo_cost import analyze
from repro.analysis.model_flops import model_flops
from repro.configs.base import SHAPES, cell_is_skipped
from repro.configs.registry import ASSIGNED, get_config, get_shape
from repro.launch.mesh import (HBM_BW, HBM_BYTES, ICI_BW, PEAK_FLOPS_BF16,
                               make_production_mesh)
from repro.launch.steps import build_cell


def run_cell(arch: str, shape_name: str, mesh_kind: str, verbose: bool = True):
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    skip = cell_is_skipped(cfg, shape)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind}
    if skip:
        rec.update(status="skipped", reason=skip)
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = mesh.devices.size
    t0 = time.time()
    try:
        cell = build_cell(cfg, shape, mesh)
        with mesh:
            lowered = cell.jitted.lower(*cell.args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        walk = analyze(compiled.as_text())
        mf = model_flops(cfg, shape, cell.model.params_shape())

        # Roofline terms (seconds, per chip; walker numbers are per-device)
        t_compute = walk.flops / PEAK_FLOPS_BF16
        t_memory = walk.bytes / HBM_BW
        t_collective = walk.collective_bytes / ICI_BW
        terms = {"compute_s": t_compute, "memory_s": t_memory,
                 "collective_s": t_collective}
        dominant = max(terms, key=terms.get)

        mem = {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_bytes_est": (ma.argument_size_in_bytes
                               + ma.output_size_in_bytes
                               + ma.temp_size_in_bytes
                               - ma.alias_size_in_bytes),
        }
        rec.update(
            status="ok",
            kind=cell.kind,
            n_chips=n_chips,
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            memory=mem,
            fits_hbm=mem["peak_bytes_est"] <= HBM_BYTES,
            xla_cost={k: ca.get(k) for k in ("flops", "bytes accessed",
                                             "transcendentals")},
            walk={
                "flops": walk.flops,
                "bytes": walk.bytes,
                "collective_bytes": walk.collective_bytes,
                "collective_wire_bytes": walk.collective_wire_bytes,
                "collectives": walk.collectives,
                "collective_counts": walk.collective_counts,
                "custom_calls": len(walk.custom_calls),
                "warnings": walk.warnings[:5],
            },
            roofline={
                **terms,
                "dominant": dominant,
                "step_time_lb_s": max(terms.values()),
                "model_flops_global": mf["model_flops_total"],
                "model_flops_per_chip": mf["model_flops_total"] / n_chips,
                "useful_flops_ratio": (mf["model_flops_total"] / n_chips)
                / max(walk.flops, 1.0),
                "roofline_fraction": min(
                    1.0, (mf["model_flops_total"] / n_chips / PEAK_FLOPS_BF16)
                    / max(max(terms.values()), 1e-30)),
            },
        )
        if verbose:
            print(f"== {arch} x {shape_name} x {mesh_kind} "
                  f"({cell.kind}, {n_chips} chips) ==")
            print(f"memory_analysis: {ma}")
            print(f"cost_analysis: flops={ca.get('flops')} "
                  f"bytes={ca.get('bytes accessed')}")
            print(f"walk: flops/chip={walk.flops:.3e} bytes/chip={walk.bytes:.3e} "
                  f"coll/chip={walk.collective_bytes:.3e} "
                  f"{dict(walk.collective_counts)}")
            print(f"roofline: compute={t_compute*1e3:.2f}ms "
                  f"memory={t_memory*1e3:.2f}ms coll={t_collective*1e3:.2f}ms "
                  f"dominant={dominant} "
                  f"frac={rec['roofline']['roofline_fraction']:.3f} "
                  f"peak_mem={mem['peak_bytes_est']/2**30:.2f}GiB "
                  f"fits={rec['fits_hbm']}")
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
        if verbose:
            print(f"== {arch} x {shape_name} x {mesh_kind} FAILED ==")
            print(rec["error"])
    return rec


def run_fl_round_cell(arch: str, mesh_kind: str, h_local_steps: int = 8,
                      seq_len: int = 4096, verbose: bool = True):
    """Dry-run the paper-technique cell: the rollup round (fl/round.py)."""
    from repro.fl.round import FLRoundSpec, build_fl_round_cell
    from repro.models.model import build_model
    from repro.optim.optimizers import make_optimizer, spec_for_config

    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = mesh.devices.size
    n_trainers = mesh.shape["data"] * mesh.shape.get("pod", 1)
    rec = {"arch": arch, "shape": f"fl_round_h{h_local_steps}",
           "mesh": mesh_kind}
    t0 = time.time()
    try:
        model = build_model(cfg, mesh)
        opt = make_optimizer(spec_for_config(cfg))
        spec = FLRoundSpec(n_trainers=n_trainers,
                           h_local_steps=h_local_steps)
        jitted, cell_args = build_fl_round_cell(model, opt, spec, mesh,
                                                seq_len)
        with mesh:
            lowered = jitted.lower(*cell_args)
            compiled = lowered.compile()
        ma = compiled.memory_analysis()
        walk = analyze(compiled.as_text())
        mf = model_flops(cfg, get_shape("train_4k"))
        terms = {"compute_s": walk.flops / PEAK_FLOPS_BF16,
                 "memory_s": walk.bytes / HBM_BW,
                 "collective_s": walk.collective_bytes / ICI_BW}
        rec.update(
            status="ok", kind="fl_round", n_chips=n_chips,
            h_local_steps=h_local_steps, n_trainers=n_trainers,
            compile_s=round(time.time() - t0, 2),
            memory={"argument_bytes": ma.argument_size_in_bytes,
                    "temp_bytes": ma.temp_size_in_bytes,
                    "peak_bytes_est": ma.argument_size_in_bytes
                    + ma.output_size_in_bytes + ma.temp_size_in_bytes
                    - ma.alias_size_in_bytes},
            walk={"flops": walk.flops, "bytes": walk.bytes,
                  "collective_bytes": walk.collective_bytes,
                  "collectives": walk.collectives,
                  "collective_counts": walk.collective_counts},
            roofline={**terms,
                      "dominant": max(terms, key=terms.get),
                      "collective_s_per_local_step":
                          terms["collective_s"] / h_local_steps,
                      "model_flops_global":
                          mf["model_flops_total"] * h_local_steps},
        )
        if verbose:
            print(f"== fl_round {arch} H={h_local_steps} x {mesh_kind} ==")
            print(f"memory_analysis: {ma}")
            print(f"walk: flops/chip={walk.flops:.3e} "
                  f"bytes/chip={walk.bytes:.3e} "
                  f"coll/chip={walk.collective_bytes:.3e} "
                  f"{dict(walk.collective_counts)}")
            print(f"roofline: compute={terms['compute_s']*1e3:.2f}ms "
                  f"memory={terms['memory_s']*1e3:.2f}ms "
                  f"coll={terms['collective_s']*1e3:.2f}ms "
                  f"coll/localstep={terms['collective_s']/h_local_steps*1e3:.2f}ms")
    except Exception as e:  # noqa: BLE001
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
        if verbose:
            print(f"== fl_round {arch} FAILED ==\n{rec['error']}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--fl-round", action="store_true",
                    help="dry-run the paper-technique rollup-round cell")
    ap.add_argument("--local-steps", type=int, default=8)
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    if args.fl_round:
        os.makedirs(args.out, exist_ok=True)
        meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
        fail = 0
        for mk in meshes:
            rec = run_fl_round_cell(args.arch or "yi-6b", mk,
                                    args.local_steps)
            fn = os.path.join(
                args.out,
                f"fl_round__{args.arch or 'yi-6b'}__h{args.local_steps}__{mk}.json")
            with open(fn, "w") as f:
                json.dump(rec, f, indent=1)
            fail += rec["status"] != "ok"
        raise SystemExit(1 if fail else 0)

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        cells = [(a, s) for a in ASSIGNED for s in SHAPES]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all required"
        cells = [(args.arch, args.shape)]

    os.makedirs(args.out, exist_ok=True)
    n_ok = n_fail = n_skip = 0
    for arch, shape in cells:
        for mk in meshes:
            rec = run_cell(arch, shape, mk)
            fn = os.path.join(args.out, f"{arch}__{shape}__{mk}.json")
            with open(fn, "w") as f:
                json.dump(rec, f, indent=1)
            n_ok += rec["status"] == "ok"
            n_fail += rec["status"] == "error"
            n_skip += rec["status"] == "skipped"
    print(f"\ndry-run summary: ok={n_ok} failed={n_fail} skipped={n_skip}")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
