"""Production mesh definitions (TPU v5e pods).

A FUNCTION, not a module-level constant: importing this module never touches
jax device state.
"""
from __future__ import annotations

import functools

import jax


def _axis_type_kwargs(n_axes: int):
    """jax.sharding.AxisType landed after 0.4.x; Auto is that jax's default
    anyway, so older versions simply omit the kwarg."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_host_mesh():
    """Degenerate 1x1 mesh for CPU smoke runs of the sharded code paths."""
    return jax.make_mesh((1, 1), ("data", "model"), **_axis_type_kwargs(2))


@functools.lru_cache(maxsize=None)
def n_local_devices() -> int:
    """Local device count, probed ONCE per process (jax.devices() is a
    platform-initialising call; callers gate mesh decisions on it every
    fabric seal)."""
    return len(jax.devices())


@functools.lru_cache(maxsize=None)
def make_shard_mesh(max_devices: int | None = None):
    """1-D ``("shard",)`` mesh over the local devices for the ledger
    fabric's K shard lanes (kernels/shard_lanes.py).  Lane rows pad to a
    multiple of the mesh size, so any K runs on any device count; under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the CI
    ``shard-mesh`` job) this is a real 8-device CPU mesh.  Cached — jax
    meshes hash by device assignment, and the fused loop asks for the
    mesh once per digest fold."""
    n = n_local_devices()
    if max_devices is not None:
        n = max(1, min(n, max_devices))
    return jax.make_mesh((n,), ("shard",), **_axis_type_kwargs(1))


# TPU v5e hardware constants used by the roofline analysis (per chip).
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # bytes/s
ICI_BW = 50e9                 # bytes/s per link (~per-chip effective)
HBM_BYTES = 16 * 1024 ** 3    # 16 GiB
