"""Production mesh definitions (TPU v5e pods).

A FUNCTION, not a module-level constant: importing this module never touches
jax device state.
"""
from __future__ import annotations

import jax


def _axis_type_kwargs(n_axes: int):
    """jax.sharding.AxisType landed after 0.4.x; Auto is that jax's default
    anyway, so older versions simply omit the kwarg."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_host_mesh():
    """Degenerate 1x1 mesh for CPU smoke runs of the sharded code paths."""
    return jax.make_mesh((1, 1), ("data", "model"), **_axis_type_kwargs(2))


# TPU v5e hardware constants used by the roofline analysis (per chip).
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # bytes/s
ICI_BW = 50e9                 # bytes/s per link (~per-chip effective)
HBM_BYTES = 16 * 1024 ** 3    # 16 GiB
