"""DEPRECATED shim: ``repro.launch.serve`` was two identities in one name.

The MODEL-inference launcher that lived here moved to
``repro.launch.serve_model`` (same ``main``, same flags); the LEDGER
node service is ``repro.launch.serve_node`` over ``repro.serve``.  This
module re-exports the model launcher for one release so existing
``from repro.launch.serve import main`` imports keep working — see
docs/MIGRATION.md.
"""
from __future__ import annotations

import warnings

from repro.launch.serve_model import main  # noqa: F401  (re-export)

warnings.warn(
    "repro.launch.serve is deprecated: the model-inference launcher moved "
    "to repro.launch.serve_model; the node service is "
    "repro.launch.serve_node (see docs/MIGRATION.md)",
    DeprecationWarning, stacklevel=2)

if __name__ == "__main__":
    main()
