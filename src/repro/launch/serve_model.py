"""MODEL-inference serving launcher: prefill + batched KV-cache decode
on the (pod,)data x model mesh, with the reputation gate on the request
path.  (Renamed from ``launch/serve.py``, which now shims here — the
LEDGER-node service lives in ``launch/serve_node.py`` / ``repro.serve``.)

On CPU use --host-mesh --reduced (the identical sharded code path on a 1x1
mesh); launch/dryrun.py proves the 256/512-chip lowering for the decode and
prefill cells.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import REGISTRY, get_config, reduced_config
from repro.core.reputation import ReputationParams, init_book
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models.model import build_model


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b", choices=sorted(REGISTRY))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--tokens", type=int, default=8)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--host-mesh", action="store_true")
    ap.add_argument("--reduced", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    assert cfg.input_mode == "tokens" and not cfg.enc_dec and \
        cfg.family != "conv", "token-LM serving path"

    mesh = make_host_mesh() if args.host_mesh \
        else make_production_mesh(multi_pod=args.multi_pod)
    model = build_model(cfg, mesh)

    # reputation gate: requests from identities below R_min are rejected
    book = init_book(args.batch)
    rp = ReputationParams()
    admitted = np.asarray(book.reputation) >= rp.r_min
    assert admitted.all(), "newcomers start above the trust line"

    with mesh:
        params = model.init_params(jax.random.key(0))
        B = args.batch
        max_len = args.prompt_len + args.tokens + 1
        state = model.init_decode_state(B, max_len)
        decode = jax.jit(model.decode, donate_argnums=(1,))

        rng = np.random.default_rng(0)
        prompts = rng.integers(0, cfg.vocab_size, (B, args.prompt_len))
        t0 = time.time()
        logits = None
        for t in range(args.prompt_len):
            logits, state = decode(params, state,
                                   {"tokens": jnp.asarray(
                                       prompts[:, t:t + 1], jnp.int32),
                                    "pos": jnp.int32(t)})
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        generated = []
        for t in range(args.prompt_len, args.prompt_len + args.tokens):
            generated.append(np.asarray(tok)[:, 0])
            logits, state = decode(params, state,
                                   {"tokens": tok, "pos": jnp.int32(t)})
            tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        dt = time.time() - t0
        n_steps = args.prompt_len + args.tokens
        print(f"served {B} x {n_steps} steps in {dt:.2f}s "
              f"({B * n_steps / dt:.1f} tok/s); sample: "
              f"{np.stack(generated, 1)[0, :8].tolist()}")


if __name__ == "__main__":
    main()
