"""Node-service launcher: boot the admission-controlled HTTP face.

    PYTHONPATH=src python -m repro.launch.serve_node \
        --port 8545 --shards 2 --window 1.0 --pool-cap 4096

Builds a ``ServeSpec`` from the flags, boots ``repro.serve``'s
``NodeService`` + ``HttpNodeServer`` and serves until interrupted
(``--serve-for`` bounds the run for smoke tests).  docs/SERVING.md
documents the endpoints and the admission knobs.
"""
from __future__ import annotations

import argparse
import asyncio
from typing import Optional, Sequence

from repro.api.specs import (AdmissionSpec, NodeSpec, RollupSpec, ServeSpec,
                             ShardSpec)


def build_spec(args: argparse.Namespace) -> ServeSpec:
    shards = (ShardSpec(count=args.shards, fabric=True)
              if args.shards > 1 else None)
    node = NodeSpec(rollup=None if args.no_rollup else RollupSpec(),
                    shards=shards)
    admission = AdmissionSpec(
        rate_limit=args.rate_limit, burst=args.burst,
        fee_floor=args.fee_floor, rep_gate=args.rep_gate,
        pool_cap=args.pool_cap, evict=not args.no_evict)
    return ServeSpec(node=node, admission=admission, host=args.host,
                     port=args.port, queue_cap=args.queue_cap,
                     window=args.window, event_cap=args.event_cap)


async def _serve(spec: ServeSpec, serve_for: Optional[float]) -> None:
    from repro.serve import HttpNodeServer, NodeService
    server = HttpNodeServer(NodeService(spec))
    host, port = await server.start()
    print(f"node service listening on http://{host}:{port}/rpc "
          f"(window={spec.window}s, pool_cap={spec.admission.pool_cap})",
          flush=True)
    try:
        if serve_for is not None:
            await asyncio.sleep(serve_for)
        else:
            assert server._server is not None
            await server._server.serve_forever()
    finally:
        await server.close()


def main(argv: Optional[Sequence[str]] = None) -> None:
    ap = argparse.ArgumentParser(
        description="admission-controlled node service (repro.serve)")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8545,
                    help="0 binds an ephemeral port")
    ap.add_argument("--shards", type=int, default=1)
    ap.add_argument("--no-rollup", action="store_true",
                    help="serve a chain-only (L1) node")
    ap.add_argument("--window", type=float, default=1.0,
                    help="modeled seconds between pool flushes")
    ap.add_argument("--queue-cap", type=int, default=1024)
    ap.add_argument("--event-cap", type=int, default=65536,
                    help="EventLog ring-buffer cap")
    ap.add_argument("--pool-cap", type=int, default=4096)
    ap.add_argument("--rate-limit", type=float, default=50.0)
    ap.add_argument("--burst", type=float, default=20.0)
    ap.add_argument("--fee-floor", type=int, default=0)
    ap.add_argument("--rep-gate", default="surcharge",
                    choices=("off", "surcharge", "reject"))
    ap.add_argument("--no-evict", action="store_true",
                    help="reject (429) at pool cap instead of evicting")
    ap.add_argument("--serve-for", type=float, default=None,
                    help="seconds to serve before a clean shutdown")
    args = ap.parse_args(argv)
    try:
        asyncio.run(_serve(build_spec(args), args.serve_for))
    except KeyboardInterrupt:
        pass


if __name__ == "__main__":
    main()
