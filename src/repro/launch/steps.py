"""Build lowerable, fully-sharded step functions for every dry-run cell.

A *cell* = (architecture x input shape x mesh).  This module returns the jit
object + ShapeDtypeStruct args so the dry-run can ``.lower().compile()``
without allocating anything.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.model import Model, build_model
from repro.optim.optimizers import (OptimizerSpec, make_optimizer,
                                    spec_for_config)


class Cell(NamedTuple):
    jitted: Any
    args: tuple
    model: Model
    kind: str


def _shardify(mesh, pspec_tree, shape_tree=None):
    if mesh is None:
        return None
    if shape_tree is not None:
        from repro.sharding.specs import sanitize_pspec_tree
        pspec_tree = sanitize_pspec_tree(mesh, pspec_tree, shape_tree)
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), pspec_tree,
        is_leaf=lambda x: isinstance(x, P))


def opt_state_pspecs(opt_name: str, pspecs, params_shape):
    """Optimizer-state PartitionSpecs mirroring the param specs."""
    if opt_name in ("adamw", "sgdm"):
        st = {"m": pspecs, "step": P()}
        if opt_name == "adamw":
            st["v"] = pspecs
        return st
    if opt_name == "adafactor":
        def leaf(spec, shape_leaf):
            shape = shape_leaf.shape
            from repro.optim.optimizers import _factored
            if _factored(shape, OptimizerSpec().factored_min):
                return {"vr": P(*spec[:-1]), "vc": P(*(spec[:-2] + spec[-1:]))}
            return {"v": spec}
        v = jax.tree.map(leaf, pspecs, params_shape,
                         is_leaf=lambda x: isinstance(x, P))
        return {"v": v, "step": P()}
    raise ValueError(opt_name)


def build_train_step(model: Model, opt):
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: model.loss(p, batch))(params)
        new_params, new_opt_state, gn = opt.update(grads, opt_state, params)
        return new_params, new_opt_state, {"loss": loss, "grad_norm": gn}
    return train_step


def build_cell(cfg: ModelConfig, shape: ShapeConfig, mesh) -> Cell:
    model = build_model(cfg, mesh)
    pshape = model.params_shape()
    pspecs = model.params_pspecs(pshape)
    p_shard = _shardify(mesh, pspecs, pshape)
    batch_struct = model.input_specs(shape)
    b_shard = _shardify(mesh, model.input_pspecs(shape), batch_struct)

    if shape.kind == "train":
        opt = make_optimizer(spec_for_config(cfg))
        oshape = jax.eval_shape(opt.init, pshape)
        ospecs = opt_state_pspecs(cfg.optimizer, pspecs, pshape)
        o_shard = _shardify(mesh, ospecs, oshape)
        step = build_train_step(model, opt)
        metrics_shard = (_shardify(mesh, {"loss": P(), "grad_norm": P()})
                         if mesh is not None else None)
        jitted = jax.jit(
            step,
            in_shardings=(p_shard, o_shard, b_shard) if mesh else None,
            out_shardings=(p_shard, o_shard, metrics_shard) if mesh else None,
            donate_argnums=(0, 1))
        args = (pshape, oshape, batch_struct)
        return Cell(jitted, args, model, "train")

    if shape.kind == "prefill":
        def step(params, batch):
            return model.prefill(params, batch)
        jitted = jax.jit(step, in_shardings=(p_shard, b_shard) if mesh else None)
        return Cell(jitted, (pshape, batch_struct), model, "prefill")

    # decode: one token against a seq_len-deep cache
    sshape = model.decode_state_shape(shape.global_batch, shape.seq_len)
    sspecs = model.decode_state_pspecs(shape.global_batch, shape.seq_len)
    s_shard = _shardify(mesh, sspecs, sshape)

    def step(params, state, batch):
        return model.decode(params, state, batch)

    if mesh is not None:
        from repro.sharding.specs import sanitize_spec
        logits_spec = sanitize_spec(
            mesh, P((model.ctx.dp_axes or None), model.ctx.tp_axis),
            (shape.global_batch, cfg.vocab_size))
        out_sh = (NamedSharding(mesh, logits_spec), s_shard)
    else:
        out_sh = None
    jitted = jax.jit(
        step,
        in_shardings=(p_shard, s_shard, b_shard) if mesh else None,
        out_shardings=out_sh,
        donate_argnums=(1,))
    # fill pos with a concrete struct
    return Cell(jitted, (pshape, sshape, batch_struct), model, "decode")
