"""Production training launcher: rollup-FL rounds on the (pod,)data x model
mesh, with checkpointing, resume-latest, straggler deadlines and reputation
updates — the full AutoDFL loop at pod scale.

On TPU pods this binary runs under the usual multi-host launcher (one process
per host; jax.distributed.initialize before the mesh is built).  On CPU it
runs the identical code path on a 1x1 host mesh (--host-mesh) with reduced
configs (--reduced) — used by tests and examples/train_multi_pod.py.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.registry import REGISTRY, get_config, reduced_config
from repro.core.reputation import (ReputationParams, end_of_task_update,
                                   init_book)
from repro.fl.round import FLRoundSpec, build_fl_round
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models.model import build_model
from repro.optim.optimizers import (OptimizerSpec, make_optimizer,
                                    spec_for_config)
from repro.runtime.fault_tolerance import HeartbeatRegistry, RoundDeadline


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b", choices=sorted(REGISTRY))
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--local-batch", type=int, default=2)
    ap.add_argument("--seq-len", type=int, default=16)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--host-mesh", action="store_true",
                    help="1x1 mesh (CPU smoke of the sharded path)")
    ap.add_argument("--reduced", action="store_true",
                    help="reduced same-family config (CPU)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    return ap.parse_args(argv)


def main(argv=None):
    args = parse_args(argv)
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    assert cfg.input_mode == "tokens" and not cfg.enc_dec and \
        cfg.family != "conv", "FL-LM launcher drives token-LM archs"

    mesh = make_host_mesh() if args.host_mesh \
        else make_production_mesh(multi_pod=args.multi_pod)
    model = build_model(cfg, mesh)
    opt = make_optimizer(spec_for_config(cfg) if not args.reduced
                         else OptimizerSpec(name="sgdm", lr=0.05))
    T = mesh.shape["data"] * mesh.shape.get("pod", 1)
    spec = FLRoundSpec(n_trainers=T, h_local_steps=args.local_steps,
                       local_batch=args.local_batch)
    fl_round = jax.jit(build_fl_round(model, opt, spec))

    ck = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
    book = init_book(T)
    rp = ReputationParams()
    registry = HeartbeatRegistry()
    deadline = RoundDeadline()

    start_round = 0
    with mesh:
        params = model.init_params(jax.random.key(0))
        params_T = jax.tree.map(lambda l: jnp.stack([l] * T), params)
        opt_T = jax.tree.map(lambda l: jnp.stack([l] * T), opt.init(params))
        if ck is not None and args.resume and ck.latest_step() is not None:
            restored, extra = ck.restore()
            params_T = jax.tree.map(jnp.asarray, restored["params_T"])
            opt_T = jax.tree.map(jnp.asarray, restored["opt_T"])
            from repro.core.reputation import TrainerBook
            book = TrainerBook(**{k: jnp.asarray(v)
                                  for k, v in restored["book"].items()})
            start_round = extra["round"] + 1
            print(f"resumed from round {extra['round']}")

        rng = np.random.default_rng(17)
        for rnd in range(start_round, args.rounds):
            t0 = time.time()
            for t in range(T):
                registry.beat(f"trainer{t}")
            toks = rng.integers(
                0, cfg.vocab_size,
                (T, spec.h_local_steps, spec.local_batch, args.seq_len + 1))
            batches = {"tokens": jnp.asarray(toks[..., :-1], jnp.int32),
                       "labels": jnp.asarray(toks[..., 1:], jnp.int32)}
            scores = jnp.asarray(book.reputation)
            params_T, opt_T, m = fl_round(params_T, opt_T, scores, batches)

            # end-of-round reputation refresh (oracle score ~ loss proxy)
            dist = m["distances"]
            score_auto = jnp.clip(1.5 - m["loss"] / 10.0, 0.0, 1.0)
            book, _ = end_of_task_update(
                book, jnp.full((T,), score_auto),
                jnp.full((T,), float(spec.h_local_steps)),
                jnp.full((T,), float(spec.h_local_steps)),
                dist, jnp.ones((T,)), rp)

            assert deadline.ready(T, T, elapsed=time.time() - t0)
            print(f"round {rnd}: loss={float(m['loss']):.4f} "
                  f"digest=0x{int(m['digest']):08x} "
                  f"mean_rep={float(jnp.mean(book.reputation)):.3f} "
                  f"({time.time() - t0:.1f}s)")
            if ck is not None:
                book_dict = {
                    "reputation": book.reputation, "n_tasks": book.n_tasks,
                    "good_history": book.good_history,
                    "age_history": book.age_history,
                    "interactions_with": book.interactions_with,
                    "interactions_total": book.interactions_total}
                ck.save_async(rnd, {"params_T": params_T, "opt_T": opt_T,
                                    "book": book_dict}, extra={"round": rnd})
        if ck is not None:
            ck.wait()
    print("training complete.")


if __name__ == "__main__":
    main()
