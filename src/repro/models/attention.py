"""GQA attention: blocked-causal prefill/train path, KV-cache decode path.

Design notes (TPU adaptation):
  * The train/prefill path never materialises the (S, S) score matrix.  It
    scans over (q_chunk, kv_chunk<=q_chunk) pairs — a flash-attention-shaped
    schedule expressed at the XLA level so the dry-run cost analysis stays
    causal-honest (~S^2/2, not S^2).  The Pallas `flash_attention` kernel
    (kernels/flash_attention.py) implements the same schedule for real TPU
    runs (cfg-gated via use_pallas).
  * Decode reads a (B, S_max, Hkv, dh) KV cache; for long-context cells the
    cache seq dim is sharded over the `model` axis (KV-SP) and the softmax
    normaliser is combined across shards by GSPMD-inserted collectives.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models.layers import apply_mrope, apply_rope, dense_init, rms_norm

NEG_INF = -1e30


def init_attn_params(key, cfg, dtype, cross=False):
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, qd), dtype),
        "wk": dense_init(ks[1], (d, kvd), dtype),
        "wv": dense_init(ks[2], (d, kvd), dtype),
        "wo": dense_init(ks[3], (qd, d), dtype),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((qd,), dtype)
        p["bk"] = jnp.zeros((kvd,), dtype)
        p["bv"] = jnp.zeros((kvd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((cfg.head_dim,), dtype)
        p["k_norm"] = jnp.ones((cfg.head_dim,), dtype)
    return p


def _project_qkv(cfg, p, x, positions, rope: bool):
    B, S, _ = x.shape
    H, Hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,de->bse", x, p["wq"])
    k = jnp.einsum("bsd,de->bse", x, p["wk"])
    v = jnp.einsum("bsd,de->bse", x, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, H, dh)
    k = k.reshape(B, S, Hkv, dh)
    v = v.reshape(B, S, Hkv, dh)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    if rope and cfg.rope_variant == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    elif rope and cfg.rope_variant == "mrope":
        q = apply_mrope(q, positions, cfg.rope_theta)
        k = apply_mrope(k, positions, cfg.rope_theta)
    return q, k, v


def _repeat_kv(k, n_rep):
    if n_rep == 1:
        return k
    B, S, Hkv, dh = k.shape
    return jnp.repeat(k, n_rep, axis=2)


# ---------------------------------------------------------------------------
# Blocked causal attention (train / prefill)
# ---------------------------------------------------------------------------
def blocked_causal_attention(q, k, v, chunk: int, ctx=None):
    """Online-softmax attention over (q_chunk, kv_chunk<=q_chunk) pairs.

    q: (B, S, H, dh); k, v: (B, S, Hkv, dh).  Returns (B, S, H, dh).
    FLOPs ~ B*H*S^2*dh (causal half counted exactly: T*(T+1)/2 chunk pairs).
    """
    B, S, H, dh = q.shape
    Hkv = k.shape[2]
    n_rep = H // Hkv
    if S % chunk != 0:
        chunk = S  # degenerate small-seq fallback
    T = S // chunk
    scale = dh ** -0.5

    qc = q.reshape(B, T, chunk, H, dh)
    kc = k.reshape(B, T, chunk, Hkv, dh)
    vc = v.reshape(B, T, chunk, Hkv, dh)

    # enumerate the lower-triangular chunk pairs statically
    pairs = [(qi, ki) for qi in range(T) for ki in range(qi + 1)]
    pairs = jnp.asarray(pairs, jnp.int32)  # (n_pairs, 2)

    # accumulators carried across the scan: per q-chunk online softmax state
    acc = jnp.zeros((B, T, chunk, H, dh), jnp.float32)
    row_max = jnp.full((B, T, chunk, H), NEG_INF, jnp.float32)
    row_sum = jnp.zeros((B, T, chunk, H), jnp.float32)

    local_mask = jnp.tril(jnp.ones((chunk, chunk), bool))

    @jax.checkpoint
    def body(carry, pair):
        acc, row_max, row_sum = carry
        qi, ki = pair[0], pair[1]
        qb = jax.lax.dynamic_index_in_dim(qc, qi, 1, keepdims=False)
        kb = jax.lax.dynamic_index_in_dim(kc, ki, 1, keepdims=False)
        vb = jax.lax.dynamic_index_in_dim(vc, ki, 1, keepdims=False)
        kb = _repeat_kv(kb, n_rep)
        vb = _repeat_kv(vb, n_rep)
        s = jnp.einsum("bqhd,bkhd->bqhk", qb.astype(jnp.float32),
                       kb.astype(jnp.float32)) * scale
        diag = qi == ki
        s = jnp.where(jnp.logical_or(~diag, local_mask[None, :, None, :]),
                      s, NEG_INF)
        m_prev = jax.lax.dynamic_index_in_dim(row_max, qi, 1, keepdims=False)
        l_prev = jax.lax.dynamic_index_in_dim(row_sum, qi, 1, keepdims=False)
        a_prev = jax.lax.dynamic_index_in_dim(acc, qi, 1, keepdims=False)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=-1)
        a_new = a_prev * corr[..., None] + jnp.einsum(
            "bqhk,bkhd->bqhd", p, vb.astype(jnp.float32))
        acc = jax.lax.dynamic_update_index_in_dim(acc, a_new, qi, 1)
        row_max = jax.lax.dynamic_update_index_in_dim(row_max, m_new, qi, 1)
        row_sum = jax.lax.dynamic_update_index_in_dim(row_sum, l_new, qi, 1)
        return (acc, row_max, row_sum), None

    (acc, row_max, row_sum), _ = jax.lax.scan(body, (acc, row_max, row_sum), pairs)
    out = acc / jnp.maximum(row_sum[..., None], 1e-30)
    return out.reshape(B, S, H, dh).astype(q.dtype)


def full_causal_attention(q, k, v):
    """Reference dense path for tiny smoke shapes."""
    B, S, H, dh = q.shape
    n_rep = H // k.shape[2]
    k = _repeat_kv(k, n_rep)
    v = _repeat_kv(v, n_rep)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * dh ** -0.5
    mask = jnp.tril(jnp.ones((S, S), bool))
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def attention_block(cfg, p, x, positions, ctx=None, chunk=1024,
                    return_cache=False):
    """Full attention sub-block (projections + mixing + output).

    Returns out, or (out, (k, v)) when ``return_cache`` (prefill path —
    avoids re-projecting K/V a second time for the cache).
    """
    q, k, v = _project_qkv(cfg, p, x, positions, rope=True)
    if ctx is not None:
        # SP->TP transition happens HERE, once per layer: q/k/v become
        # heads-sharded and seq-replicated BEFORE the chunk reshape.
        # Without this, GSPMD re-gathers the seq-sharded tensors inside
        # every (q_chunk, kv_chunk) scan step — measured 2.06 TB/chip of
        # a 2.82 TB total on moonshot train_4k (see EXPERIMENTS.md §Perf).
        q = ctx.act_heads(q)
        if ctx.sp_axis is not None:
            # only needed when the residual stream is seq-sharded; on
            # non-SP archs with few KV heads it forces padding gathers
            # (measured -8% on qwen2-0.5b, GQA kv=2 over 16-way TP)
            k, v = ctx.act_heads(k), ctx.act_heads(v)
    S = x.shape[1]
    if S <= 2 * chunk:
        o = full_causal_attention(q, k, v)
    else:
        o = blocked_causal_attention(q, k, v, chunk, ctx)
    if ctx is not None:
        o = ctx.act_heads(o)
    B = x.shape[0]
    o = o.reshape(B, S, cfg.q_dim)
    out = jnp.einsum("be,ed->bd", o.reshape(-1, cfg.q_dim), p["wo"]).reshape(
        B, S, cfg.d_model)
    if return_cache:
        return out, (k, v)
    return out


# ---------------------------------------------------------------------------
# Decode path (KV cache)
# ---------------------------------------------------------------------------
def init_kv_cache(cfg, batch, max_len, n_layers, dtype):
    Hkv, dh = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((n_layers, batch, max_len, Hkv, dh), dtype),
        "v": jnp.zeros((n_layers, batch, max_len, Hkv, dh), dtype),
    }


def decode_attention_block(cfg, p, x, cache_k, cache_v, pos, ctx=None):
    """One-token decode: x (B, 1, d); cache_{k,v} (B, S_max, Hkv, dh).

    ``pos`` is the current write index (scalar int32).  Returns
    (out (B,1,d), new_k, new_v).
    """
    B = x.shape[0]
    H, Hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    positions = jnp.broadcast_to(pos, (B, 1)).astype(jnp.int32)
    if cfg.rope_variant == "mrope":
        positions = jnp.broadcast_to(pos, (3, B, 1)).astype(jnp.int32)
    q, k, v = _project_qkv(cfg, p, x, positions, rope=True)
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k, pos, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v, pos, axis=1)
    if ctx is not None:
        cache_k = ctx.constrain(cache_k, ctx.kv_cache_spec())
        cache_v = ctx.constrain(cache_v, ctx.kv_cache_spec())

    n_rep = H // Hkv
    S = cache_k.shape[1]
    qh = q.reshape(B, H, dh)
    kk = cache_k.reshape(B, S, Hkv, 1, dh)
    s = jnp.einsum("bskrd,bkrd->bskr",
                   jnp.broadcast_to(kk, (B, S, Hkv, n_rep, dh)).astype(jnp.float32),
                   qh.reshape(B, Hkv, n_rep, dh).astype(jnp.float32)) * dh ** -0.5
    valid = (jnp.arange(S, dtype=jnp.int32) <= pos)[None, :, None, None]
    s = jnp.where(valid, s, NEG_INF)
    pattn = jax.nn.softmax(s, axis=1)
    vv = jnp.broadcast_to(cache_v.reshape(B, S, Hkv, 1, dh),
                          (B, S, Hkv, n_rep, dh)).astype(jnp.float32)
    o = jnp.einsum("bskr,bskrd->bkrd", pattn, vv).reshape(B, 1, cfg.q_dim)
    out = jnp.einsum("bsd,de->bse", o.astype(x.dtype), p["wo"])
    return out, cache_k, cache_v


# ---------------------------------------------------------------------------
# Cross attention (whisper decoder)
# ---------------------------------------------------------------------------
def cross_attention_block(cfg, p, x, enc_k, enc_v, ctx=None):
    """x: (B, S, d); enc_{k,v}: (B, S_enc, Hkv, dh) precomputed from encoder."""
    B, S, _ = x.shape
    H, dh = cfg.n_heads, cfg.head_dim
    q = jnp.einsum("bsd,de->bse", x, p["wq"]).reshape(B, S, H, dh)
    n_rep = H // enc_k.shape[2]
    k = _repeat_kv(enc_k, n_rep)
    v = _repeat_kv(enc_v, n_rep)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * dh ** -0.5
    pattn = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", pattn, v.astype(jnp.float32))
    o = o.reshape(B, S, cfg.q_dim).astype(x.dtype)
    return jnp.einsum("bse,ed->bsd", o, p["wo"])


def encode_cross_kv(cfg, p, enc_out):
    """Precompute decoder cross-attn K/V from encoder output."""
    B, S, _ = enc_out.shape
    k = jnp.einsum("bsd,de->bse", enc_out, p["wk"]).reshape(
        B, S, cfg.n_kv_heads, cfg.head_dim)
    v = jnp.einsum("bsd,de->bse", enc_out, p["wv"]).reshape(
        B, S, cfg.n_kv_heads, cfg.head_dim)
    return k, v


def bidir_attention_block(cfg, p, x, ctx=None):
    """Encoder self-attention (no mask, no rope for whisper)."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(cfg, p, x, None, rope=False)
    n_rep = cfg.n_heads // cfg.n_kv_heads
    k, v = _repeat_kv(k, n_rep), _repeat_kv(v, n_rep)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * cfg.head_dim ** -0.5
    pattn = jax.nn.softmax(s, axis=-1)
    o = pattn @ jnp.moveaxis(v.astype(jnp.float32), 1, 2)  # (B,h,q,dh)
    o = jnp.moveaxis(o, 1, 2).reshape(B, S, cfg.q_dim).astype(x.dtype)
    return jnp.einsum("bse,ed->bsd", o, p["wo"])
