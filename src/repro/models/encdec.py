"""Whisper-style encoder-decoder backbone (audio family).

The conv/mel frontend is a STUB per the assignment: inputs are precomputed
frame embeddings (B, enc_seq, d).  Learned absolute positions on both sides.
Decoder blocks = self-attn + cross-attn + dense FFN.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models.layers import apply_norm, dense_init
from repro.models.transformer import _maybe_remat


def init_params(cfg, key, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    d = cfg.d_model
    ks = jax.random.split(key, 10)

    def enc_block(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {
            "ln": jnp.ones((d,), dtype),
            "attn": attn.init_attn_params(k1, cfg, dtype),
            "ln2": jnp.ones((d,), dtype),
            "wi_gate": dense_init(k2, (d, cfg.d_ff), dtype),
            "wi_up": dense_init(k2, (d, cfg.d_ff), dtype),
            "w_down": dense_init(k3, (cfg.d_ff, d), dtype),
        }

    def dec_block(k):
        k1, k2, k3, k4 = jax.random.split(k, 4)
        return {
            "ln": jnp.ones((d,), dtype),
            "attn": attn.init_attn_params(k1, cfg, dtype),
            "ln_x": jnp.ones((d,), dtype),
            "xattn": attn.init_attn_params(k2, cfg, dtype, cross=True),
            "ln2": jnp.ones((d,), dtype),
            "wi_gate": dense_init(k3, (d, cfg.d_ff), dtype),
            "wi_up": dense_init(k3, (d, cfg.d_ff), dtype),
            "w_down": dense_init(k4, (cfg.d_ff, d), dtype),
        }

    def stack(fn, n, base_key):
        blocks = [fn(jax.random.split(base_key, n)[i]) for i in range(n)]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *blocks)

    return {
        "enc_pos": dense_init(ks[0], (cfg.enc_seq, d), dtype),
        "enc_periods": {"b0": stack(enc_block, cfg.n_enc_layers, ks[1])},
        "enc_final_norm": jnp.ones((d,), dtype),
        "dec_pos": dense_init(ks[2], (32_768, d), dtype),
        "embed": {"table": dense_init(ks[3], (cfg.vocab_size, d), dtype)},
        "periods": {"b0": stack(dec_block, cfg.n_layers, ks[4])},
        "final_norm": jnp.ones((d,), dtype),
        "head_w": dense_init(ks[5], (d, cfg.vocab_size), dtype),
    }


def init_params_shape(cfg, dtype=None):
    return jax.eval_shape(lambda: init_params(cfg, jax.random.key(0), dtype))


def encode(cfg, params, audio_embeds, ctx=None):
    x = audio_embeds + params["enc_pos"][None]
    if ctx:
        x = ctx.act_btd(x)

    def body(x, bp):
        h = apply_norm(cfg, x, bp["ln"])
        x = x + attn.bidir_attention_block(cfg, bp["attn"], h, ctx)
        h = apply_norm(cfg, x, bp["ln2"])
        f = jax.nn.gelu(jnp.einsum("bsd,df->bsf", h, bp["wi_gate"]))
        x = x + jnp.einsum("bsf,fd->bsd", f, bp["w_down"])
        if ctx:
            x = ctx.act_btd(x)
        return x, None

    x, _ = jax.lax.scan(body, x, params["enc_periods"]["b0"])
    return apply_norm(cfg, x, params["enc_final_norm"])


def _dec_block(cfg, bp, x, positions, enc_out, ctx, return_cache=False):
    h = apply_norm(cfg, x, bp["ln"])
    if return_cache:
        delta, (k, v) = attn.attention_block(cfg, bp["attn"], h, positions, ctx,
                                             return_cache=True)
    else:
        delta = attn.attention_block(cfg, bp["attn"], h, positions, ctx)
        k = v = None
    x = x + delta
    h = apply_norm(cfg, x, bp["ln_x"])
    ek, ev = attn.encode_cross_kv(cfg, bp["xattn"], enc_out)
    x = x + attn.cross_attention_block(cfg, bp["xattn"], h, ek, ev, ctx)
    h = apply_norm(cfg, x, bp["ln2"])
    f = jax.nn.gelu(jnp.einsum("bsd,df->bsf", h, bp["wi_gate"]))
    x = x + jnp.einsum("bsf,fd->bsd", f, bp["w_down"])
    if ctx:
        x = ctx.act_btd(x)
    return (x, (k, v, ek, ev)) if return_cache else x


def forward(cfg, params, batch, ctx=None, remat=None):
    """Training forward: audio embeds + decoder tokens -> logits."""
    enc_out = encode(cfg, params, batch["audio_embeds"], ctx)
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = jnp.take(params["embed"]["table"], tokens, axis=0)
    x = x + jax.lax.dynamic_slice_in_dim(params["dec_pos"], 0, S, 0)[None]
    if ctx:
        x = ctx.act_btd(x)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def body(x, bp):
        return _dec_block(cfg, bp, x, positions, enc_out, ctx), None

    rb = _maybe_remat(body, remat if remat is not None else cfg.sharding.remat)
    x, _ = jax.lax.scan(rb, x, params["periods"]["b0"])
    x = apply_norm(cfg, x, params["final_norm"])
    return jnp.einsum("bsd,dv->bsv", x, params["head_w"])


def loss_fn(cfg, params, batch, ctx=None, remat=None):
    logits = forward(cfg, params, batch, ctx, remat).astype(jnp.float32)
    labels = batch["labels"]
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None].astype(jnp.int32),
                             axis=-1)[..., 0]
    return jnp.mean(lse - ll)


def init_decode_state(cfg, batch, max_len, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    L = cfg.n_layers
    Hkv, dh = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((L, batch, max_len, Hkv, dh), dtype),
        "v": jnp.zeros((L, batch, max_len, Hkv, dh), dtype),
        "ek": jnp.zeros((L, batch, cfg.enc_seq, Hkv, dh), dtype),
        "ev": jnp.zeros((L, batch, cfg.enc_seq, Hkv, dh), dtype),
    }


def decode_step(cfg, params, state, batch, ctx=None):
    """One-token decode against self-attn KV cache + cached cross KV."""
    pos = batch["pos"]
    x = jnp.take(params["embed"]["table"], batch["tokens"], axis=0)
    x = x + jax.lax.dynamic_slice_in_dim(params["dec_pos"], pos, 1, axis=0)[None]

    def body(x, inp):
        bp, st = inp
        h = apply_norm(cfg, x, bp["ln"])
        delta, ck, cv = attn.decode_attention_block(
            cfg, bp["attn"], h, st["k"], st["v"], pos, ctx)
        x = x + delta
        h = apply_norm(cfg, x, bp["ln_x"])
        x = x + attn.cross_attention_block(cfg, bp["xattn"], h,
                                           st["ek"], st["ev"], ctx)
        h = apply_norm(cfg, x, bp["ln2"])
        f = jax.nn.gelu(jnp.einsum("bsd,df->bsf", h, bp["wi_gate"]))
        x = x + jnp.einsum("bsf,fd->bsd", f, bp["w_down"])
        return x, {"k": ck, "v": cv, "ek": st["ek"], "ev": st["ev"]}

    x, new_state = jax.lax.scan(body, x, (params["periods"]["b0"], state))
    x = apply_norm(cfg, x, params["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", x, params["head_w"])[:, 0]
    return logits, new_state
