"""Common layers: norms, MLP, rotary embeddings (RoPE + M-RoPE), initializers."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def truncated_normal_init(key, shape, scale, dtype):
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * scale).astype(dtype)


def dense_init(key, shape, dtype):
    """Fan-in scaled init for (in, out)-style matrices (last-2 dims)."""
    fan_in = shape[-2]
    return truncated_normal_init(key, shape, fan_in ** -0.5, dtype)


# -- norms --------------------------------------------------------------------
def rms_norm(x, scale, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(dt)


def layer_norm(x, scale, bias=None, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return y.astype(dt)


def apply_norm(cfg, x, scale):
    if cfg.norm == "layernorm":
        return layer_norm(x, scale)
    return rms_norm(x, scale)


# -- SwiGLU MLP -----------------------------------------------------------------
def swiglu(x, wg, wu, wd, ctx=None):
    h = jnp.einsum("bsd,df->bsf", x, wg)
    u = jnp.einsum("bsd,df->bsf", x, wu)
    h = jax.nn.silu(h) * u
    if ctx is not None:
        h = ctx.act_ffn(h)
    return jnp.einsum("bsf,fd->bsd", h, wd)


# -- rotary embeddings -----------------------------------------------------------
def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32) / head_dim))


def apply_rope(x, positions, theta):
    """x: (B, S, H, dh); positions: (B, S) int32."""
    dh = x.shape[-1]
    inv = jnp.asarray(rope_freqs(dh, theta))
    ang = positions.astype(jnp.float32)[..., None] * inv  # (B,S,dh/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, theta, sections=(16, 24, 24)):
    """M-RoPE (qwen2-vl): 3 position streams (temporal, height, width).

    x: (B, S, H, dh); positions3: (3, B, S) int32.  ``sections`` are the
    per-stream halves of dh/2 — scaled to the actual head_dim.
    """
    dh = x.shape[-1]
    half = dh // 2
    base = sum(sections)
    sec = [max(1, (s * half) // base) for s in sections]
    sec[2] = half - sec[0] - sec[1]
    inv = jnp.asarray(rope_freqs(dh, theta))  # (half,)
    # choose which position stream drives each frequency band
    stream = jnp.concatenate([jnp.full((sec[i],), i, jnp.int32) for i in range(3)])
    # gather per band — pos_sel: (B, S, half)
    pos_sel = positions3.astype(jnp.float32)[stream, :, :]  # (half, B, S)
    pos_sel = jnp.moveaxis(pos_sel, 0, -1)                  # (B, S, half)
    ang = pos_sel * inv[None, None, :]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def positions_for(cfg, batch, seq, offset=0):
    pos = jnp.arange(seq, dtype=jnp.int32)[None, :] + offset
    pos = jnp.broadcast_to(pos, (batch, seq))
    if cfg.rope_variant == "mrope":
        return jnp.broadcast_to(pos[None], (3, batch, seq))
    return pos
