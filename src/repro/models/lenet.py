"""LeNet-5 — the paper's own FL workload (MNIST, §VI-B)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


def init_params(cfg, key, dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    return {
        "conv1": {"w": dense_init(ks[0], (5, 5, 1, 6), dtype).reshape(5, 5, 1, 6),
                  "b": jnp.zeros((6,), dtype)},
        "conv2": {"w": dense_init(ks[1], (5, 5, 6, 16), dtype).reshape(5, 5, 6, 16),
                  "b": jnp.zeros((16,), dtype)},
        "fc1": {"w": dense_init(ks[2], (400, 120), dtype),
                "b": jnp.zeros((120,), dtype)},
        "fc2": {"w": dense_init(ks[3], (120, 84), dtype),
                "b": jnp.zeros((84,), dtype)},
        "fc3": {"w": dense_init(ks[4], (84, 10), dtype),
                "b": jnp.zeros((10,), dtype)},
    }


def init_params_shape(cfg, dtype=jnp.float32):
    return jax.eval_shape(lambda: init_params(cfg, jax.random.key(0), dtype))


def _conv(x, w, b):
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return jax.nn.tanh(y + b)


def _pool(x):
    return jax.lax.reduce_window(x, 0.0, jax.lax.add, (1, 2, 2, 1),
                                 (1, 2, 2, 1), "VALID") / 4.0


def forward(cfg, params, batch, ctx=None, remat=None):
    """batch["images"]: (B, 32, 32, 1) -> logits (B, 10)."""
    x = batch["images"]
    x = _pool(_conv(x, params["conv1"]["w"], params["conv1"]["b"]))
    x = _pool(_conv(x, params["conv2"]["w"], params["conv2"]["b"]))
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.tanh(x @ params["fc1"]["w"] + params["fc1"]["b"])
    x = jax.nn.tanh(x @ params["fc2"]["w"] + params["fc2"]["b"])
    return x @ params["fc3"]["w"] + params["fc3"]["b"]


def loss_fn(cfg, params, batch, ctx=None, remat=None):
    logits = forward(cfg, params, batch).astype(jnp.float32)
    labels = batch["labels"]
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[:, None].astype(jnp.int32),
                             axis=-1)[..., 0]
    return jnp.mean(lse - ll)


def accuracy(cfg, params, batch):
    logits = forward(cfg, params, batch)
    return jnp.mean((jnp.argmax(logits, -1) == batch["labels"]).astype(jnp.float32))
