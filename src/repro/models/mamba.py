"""Mamba (selective SSM) block — Jamba's recurrent token mixer.

Training path: chunked scan over the sequence (chunk-local associative scan,
state carried across chunks) — memory stays O(chunk * di * ds) instead of
O(S * di * ds).  Decode path: O(1) single-step state update — this is what
makes the hybrid archs runnable at the 500k-context cell.

TP: d_inner is sharded over the `model` axis (every SSM channel is
independent), in_proj columns / out_proj rows sharded accordingly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


def dt_rank(cfg) -> int:
    return max(1, (cfg.d_model * cfg.mamba_expand) // 16)


def init_mamba_params(key, cfg, dtype):
    d = cfg.d_model
    di = d * cfg.mamba_expand
    ds = cfg.mamba_d_state
    r = dt_rank(cfg)
    ks = jax.random.split(key, 8)
    # S4D-real initialisation for A
    a_init = jnp.broadcast_to(jnp.arange(1, ds + 1, dtype=jnp.float32), (di, ds))
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di), dtype),
        "conv_w": dense_init(ks[1], (di, cfg.mamba_d_conv), dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_dt": dense_init(ks[2], (di, r), dtype),
        "dt_proj": dense_init(ks[3], (r, di), dtype),
        "dt_bias": jnp.zeros((di,), dtype),
        "x_B": dense_init(ks[4], (di, ds), dtype),
        "x_C": dense_init(ks[5], (di, ds), dtype),
        "A_log": jnp.log(a_init),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[6], (di, d), dtype),
    }


def _ssm_chunk(h0, dA, dBx):
    """Associative scan within a chunk.

    h_t = dA_t * h_{t-1} + dBx_t;  h0: (B, di, ds); dA, dBx: (B, c, di, ds).
    Returns (h_all (B, c, di, ds), h_last).
    """
    def combine(a, b):
        a1, b1 = a
        a2, b2 = b
        return a2 * a1, a2 * b1 + b2
    aa, bb = jax.lax.associative_scan(combine, (dA, dBx), axis=1)
    h_all = aa * h0[:, None] + bb
    return h_all, h_all[:, -1]


def mamba_mix(cfg, p, xz, state=None, chunk=128):
    """Core selective SSM on the already-projected stream.

    xz: (B, S, di) post-conv activations; returns (y (B, S, di), last state).
    """
    B, S, di = xz.shape
    ds = cfg.mamba_d_state
    x32 = xz.astype(jnp.float32)

    dt = jax.nn.softplus(
        (x32 @ p["x_dt"].astype(jnp.float32)) @ p["dt_proj"].astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32))                      # (B,S,di)
    Bmat = jnp.einsum("bsd,dn->bsn", x32, p["x_B"].astype(jnp.float32))
    Cmat = jnp.einsum("bsd,dn->bsn", x32, p["x_C"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                 # (di,ds)

    if state is None:
        state = jnp.zeros((B, di, ds), jnp.float32)

    if S == 1:
        dA1 = jnp.exp(dt[:, 0, :, None] * A[None])
        dBx1 = (dt[:, 0] * x32[:, 0])[..., None] * Bmat[:, 0, None, :]
        h = dA1 * state + dBx1
        y = jnp.einsum("bdn,bn->bd", h, Cmat[:, 0])[:, None]
        out = y + p["D"].astype(jnp.float32)[None, None] * x32
        return out.astype(xz.dtype), h

    if S % chunk != 0:
        chunk = S
    T = S // chunk

    def reshape_c(a):
        return jnp.moveaxis(a.reshape((B, T, chunk) + a.shape[2:]), 1, 0)

    @jax.checkpoint
    def body(h, inp):
        # build the (B, c, di, ds) transition tensors INSIDE the chunk:
        # never materialise (B, S, di, ds)
        dt_c, x_c, b_c, cm = inp
        da = jnp.exp(dt_c[..., None] * A[None, None])            # (B,c,di,ds)
        dbx = (dt_c * x_c)[..., None] * b_c[:, :, None, :]
        h_all, h_last = _ssm_chunk(h, da, dbx)
        y = jnp.einsum("bcdn,bcn->bcd", h_all, cm)
        return h_last, y

    last, y_seq = jax.lax.scan(
        body, state,
        (reshape_c(dt), reshape_c(x32), reshape_c(Bmat), reshape_c(Cmat)))
    y = jnp.moveaxis(y_seq, 0, 1).reshape(B, S, di)
    out = y + p["D"].astype(jnp.float32)[None, None] * x32
    return out.astype(xz.dtype), last


def _causal_conv(p, x, conv_state=None):
    """Depthwise causal conv1d, kernel k.  x: (B, S, di).

    conv_state: (B, k-1, di) trailing context for decode; returns (y, new_state).
    """
    k = p["conv_w"].shape[-1]
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = conv_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)                # (B, S+k-1, di)
    w = p["conv_w"].astype(jnp.float32)                   # (di, k)
    y = sum(xp[:, i:i + x.shape[1], :].astype(jnp.float32) * w[:, i][None, None, :]
            for i in range(k))
    y = y + p["conv_b"].astype(jnp.float32)
    new_state = xp[:, -(k - 1):, :] if k > 1 else None
    return jax.nn.silu(y).astype(x.dtype), new_state


def mamba_block(cfg, p, x, state=None, ctx=None):
    """Full Mamba block.  x: (B, S, d) -> (B, S, d).

    state: None (train) or {"conv": (B,k-1,di), "ssm": (B,di,ds)} (decode).
    Returns (out, new_state).
    """
    B, S, d = x.shape
    di = d * cfg.mamba_expand
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    xs, z = jnp.split(xz, 2, axis=-1)
    if ctx is not None:
        xs = ctx.constrain(xs, jax.sharding.PartitionSpec(
            ctx.dp_axes or None, None, ctx.tp_axis))
    conv_state = None if state is None else state["conv"]
    xs, new_conv = _causal_conv(p, xs, conv_state)
    ssm_state = None if state is None else state["ssm"]
    y, new_ssm = mamba_mix(cfg, p, xs, ssm_state)
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    new_state = None
    if state is not None:
        new_state = {"conv": new_conv.astype(state["conv"].dtype), "ssm": new_ssm}
    return out, new_state


def init_mamba_state(cfg, batch, dtype):
    di = cfg.d_model * cfg.mamba_expand
    return {
        "conv": jnp.zeros((batch, cfg.mamba_d_conv - 1, di), dtype),
        "ssm": jnp.zeros((batch, di, cfg.mamba_d_state), jnp.float32),
    }
