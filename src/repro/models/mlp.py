"""Tiny MLP duck-typing the Model facade (cfg / init_params / loss).

Used by the protocol-layer benchmark and scheduler tests, where per-trainer
FL compute must stay negligible so protocol costs dominate (the paper's own
TPS experiments flood transactions rather than train models).  Operates on
feature-vector batches: {"x": (B, d_in) float32, "labels": (B,) int32}.
"""
from __future__ import annotations

import types

import jax
import jax.numpy as jnp


class TinyMLP:
    def __init__(self, d_in: int = 64, d_h: int = 32, n_classes: int = 10,
                 name: str = "tiny-mlp"):
        self.cfg = types.SimpleNamespace(name=name)
        self.d_in, self.d_h, self.n_classes = d_in, d_h, n_classes

    def init_params(self, key):
        k1, k2 = jax.random.split(key)
        s1 = (2.0 / self.d_in) ** 0.5
        return {"w1": jax.random.normal(k1, (self.d_in, self.d_h),
                                        jnp.float32) * s1,
                "b1": jnp.zeros((self.d_h,)),
                "w2": jax.random.normal(k2, (self.d_h, self.n_classes),
                                        jnp.float32) * 0.2,
                "b2": jnp.zeros((self.n_classes,))}

    def logits(self, p, batch):
        h = jax.nn.relu(batch["x"] @ p["w1"] + p["b1"])
        return h @ p["w2"] + p["b2"]

    def loss(self, p, batch):
        lo = self.logits(p, batch).astype(jnp.float32)
        lse = jax.nn.logsumexp(lo, axis=-1)
        ll = jnp.take_along_axis(
            lo, batch["labels"][:, None].astype(jnp.int32), axis=-1)[..., 0]
        return jnp.mean(lse - ll)

    def accuracy_fn(self):
        """Jitted eval_fn(params, batch) -> accuracy scalar (DON scoring)."""
        return jax.jit(lambda p, b: jnp.mean(
            (jnp.argmax(self.logits(p, b), -1) == b["labels"])
            .astype(jnp.float32)))
