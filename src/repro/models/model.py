"""Model facade: one uniform interface over all families.

    model = build_model(cfg, mesh=None)
    params = model.init_params(key)           # smoke tests
    shapes = model.params_shape()             # dry-run (no allocation)
    loss   = model.loss(params, batch)
    logits, state = model.decode(params, state, batch)
    batch  = model.input_specs(shape_cfg)     # ShapeDtypeStruct stand-ins
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec, lenet, transformer
from repro.sharding.specs import (MeshCtx, params_pspec_tree,
                                  state_pspec_tree)


class Model:
    def __init__(self, cfg: ModelConfig, mesh=None):
        self.cfg = cfg
        self.ctx = MeshCtx(mesh, cfg.sharding)
        if cfg.family == "conv":
            self._mod = lenet
        elif cfg.enc_dec:
            self._mod = encdec
        else:
            self._mod = transformer

    # -- params ---------------------------------------------------------------
    def init_params(self, key, dtype=None):
        if self._mod is lenet:
            return lenet.init_params(self.cfg, key)
        return self._mod.init_params(self.cfg, key, dtype)

    def params_shape(self):
        return self._mod.init_params_shape(self.cfg)

    def params_pspecs(self, params_shape=None):
        ps = params_shape if params_shape is not None else self.params_shape()
        return params_pspec_tree(self.ctx, ps)

    # -- steps ----------------------------------------------------------------
    def loss(self, params, batch, remat=None):
        ctx = self.ctx if self.ctx.mesh is not None else None
        return self._mod.loss_fn(self.cfg, params, batch, ctx, remat)

    def forward(self, params, batch):
        ctx = self.ctx if self.ctx.mesh is not None else None
        return self._mod.forward(self.cfg, params, batch, ctx)

    def prefill(self, params, batch):
        ctx = self.ctx if self.ctx.mesh is not None else None
        if self._mod is transformer:
            return transformer.prefill(self.cfg, params, batch, ctx)
        if self._mod is encdec:
            # enc-dec prefill: encode + full decoder forward, last logits
            logits = encdec.forward(self.cfg, params, batch, ctx, remat="none")
            return logits[:, -1], None
        raise NotImplementedError(self.cfg.family)

    def decode(self, params, state, batch):
        ctx = self.ctx if self.ctx.mesh is not None else None
        return self._mod.decode_step(self.cfg, params, state, batch, ctx)

    def init_decode_state(self, batch_size, max_len):
        return self._mod.init_decode_state(self.cfg, batch_size, max_len)

    def decode_state_shape(self, batch_size, max_len):
        return jax.eval_shape(
            lambda: self._mod.init_decode_state(self.cfg, batch_size, max_len))

    def decode_state_pspecs(self, batch_size, max_len):
        ss = self.decode_state_shape(batch_size, max_len)
        return state_pspec_tree(self.ctx, ss)

    # -- dry-run input stand-ins ------------------------------------------------
    def input_specs(self, shape: ShapeConfig):
        """ShapeDtypeStruct batch for one assigned shape (no allocation)."""
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        bf16 = jnp.dtype(cfg.dtype)
        sds = jax.ShapeDtypeStruct

        if cfg.family == "conv":
            return {"images": sds((B, 32, 32, 1), jnp.float32),
                    "labels": sds((B,), i32)}

        if shape.kind in ("train", "prefill"):
            if cfg.input_mode == "embeds":
                batch = {"embeds": sds((B, S, cfg.d_model), bf16),
                         "positions": sds((3, B, S), i32)}
            elif cfg.input_mode == "audio":
                batch = {"audio_embeds": sds((B, cfg.enc_seq, cfg.d_model), bf16),
                         "tokens": sds((B, S), i32)}
            else:
                batch = {"tokens": sds((B, S), i32)}
            if shape.kind == "train":
                batch["labels"] = sds((B, S), i32)
            return batch

        # decode: one new token against a seq_len-deep cache/state
        if cfg.input_mode == "embeds":
            return {"embeds": sds((B, 1, cfg.d_model), bf16),
                    "pos": sds((), i32)}
        return {"tokens": sds((B, 1), i32), "pos": sds((), i32)}

    def input_pspecs(self, shape: ShapeConfig):
        """PartitionSpecs matching input_specs."""
        ctx = self.ctx
        dp = ctx.dp_axes or None
        sp = ctx.sp_axis

        def leaf_spec(name, leaf):
            nd = len(leaf.shape)
            if name == "positions":
                return P(None, dp, sp)
            if name == "pos":
                return P()
            if name == "embeds":
                return P(dp, sp, None) if nd == 3 else P(dp, None)
            if name == "audio_embeds":
                return P(dp, None, None)
            if name in ("tokens", "labels"):
                return P(*([dp] + [None] * (nd - 1)))
            if name == "images":
                return P(dp, None, None, None)
            return P(*([None] * nd))

        specs = self.input_specs(shape)
        return {k: leaf_spec(k, v) for k, v in specs.items()}


def build_model(cfg: ModelConfig, mesh=None) -> Model:
    return Model(cfg, mesh)
