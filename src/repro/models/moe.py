"""Mixture-of-Experts FFN: top-k routing + capacity-based grouped matmul.

TPU adaptation notes:
  * Dispatch is per batch row (tokens only move within their own row), so the
    gather stays local to each data shard.
  * Dispatch indices are materialised as (B, E, C) and sharded E over the
    `model` axis (expert parallelism): each chip gathers only its experts'
    tokens, runs a grouped matmul against its expert shard, and the combine
    scatter-add is reduced over the model axis by GSPMD (one per-layer
    all-reduce, same as the TP attention output reduction).
  * FLOPs are honest: E*C = S*top_k*cf, so compiled compute is
    ~capacity_factor x the active-param ideal (no dense-all-experts waste).
  * The Pallas `gmm` kernel (kernels/gmm.py) provides the sorted-token
    megablox-style path for real TPU runs (cfg-gated via use_pallas).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from jax.sharding import PartitionSpec as P

from repro.models.layers import dense_init


def init_moe_params(key, cfg, dtype):
    m = cfg.moe
    d, ff, E = cfg.d_model, m.expert_d_ff, m.n_experts
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], (d, E), jnp.float32),
        "moe_wg": dense_init(ks[1], (E, d, ff), dtype),
        "moe_wu": dense_init(ks[2], (E, d, ff), dtype),
        "moe_wo": dense_init(ks[3], (E, ff, d), dtype),
    }


def capacity(cfg, seq_len: int) -> int:
    m = cfg.moe
    c = int(seq_len * m.top_k * m.capacity_factor / m.n_experts)
    return max(8, ((c + 7) // 8) * 8)  # pad to lanes


def route_topk(router_logits, top_k):
    """router_logits: (..., E) -> (weights (..., k), idx (..., k) int32)."""
    gates = jax.nn.softmax(router_logits.astype(jnp.float32), axis=-1)
    w, idx = jax.lax.top_k(gates, top_k)
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    return w, idx.astype(jnp.int32)


def build_dispatch(idx, w, n_experts: int, cap: int):
    """Per-row dispatch tables.

    idx, w: (S, k).  Returns (slot_token (E, C) int32 token ids,
    slot_weight (E, C) f32, token->slot validity folded into slot_weight).
    Overflowing tokens (beyond capacity) are dropped (capacity-factor path).
    """
    S, k = idx.shape
    flat_expert = idx.reshape(-1)                       # (S*k,)
    flat_token = jnp.repeat(jnp.arange(S, dtype=jnp.int32), k)
    flat_w = w.reshape(-1).astype(jnp.float32)

    # position of each (token, expert) pair within its expert's queue
    order = jnp.argsort(flat_expert, stable=True)       # group by expert
    sorted_expert = flat_expert[order]
    sorted_token = flat_token[order]
    sorted_w = flat_w[order]
    # rank within group = position - first position of the group
    positions = jnp.arange(S * k, dtype=jnp.int32)
    seg_start = jnp.full((n_experts,), S * k, jnp.int32).at[sorted_expert].min(
        positions, mode="drop")
    rank = positions - seg_start[sorted_expert]

    keep = rank < cap
    slot = sorted_expert * cap + jnp.where(keep, rank, cap * n_experts)
    slot_token = jnp.full((n_experts * cap + 1,), 0, jnp.int32).at[slot].set(
        sorted_token, mode="drop")
    slot_w = jnp.zeros((n_experts * cap + 1,), jnp.float32).at[slot].set(
        jnp.where(keep, sorted_w, 0.0), mode="drop")
    return (slot_token[:-1].reshape(n_experts, cap),
            slot_w[:-1].reshape(n_experts, cap))


def moe_ffn(cfg, p, x, ctx=None):
    """x: (B, S, d) -> (B, S, d)."""
    m = cfg.moe
    B, S, d = x.shape
    E, cap = m.n_experts, capacity(cfg, S)

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    # NOTE: constraining logits to (dp,None,None) here was tried and REFUTED
    # (kimi train collective 54.4->58.8 s): the batch-gather it removes is
    # cheaper than the extra reshards it forces around top_k/dispatch.
    # See EXPERIMENTS.md §Perf iteration A3.
    w, idx = route_topk(logits, m.top_k)                  # (B,S,k)

    slot_token, slot_w = jax.vmap(
        lambda i, ww: build_dispatch(i, ww, E, cap))(idx, w)   # (B,E,C)
    if ctx is not None:
        dp = ctx.dp_axes or None
        slot_token = ctx.constrain(slot_token, P(dp, ctx.ep_axis, None))
        slot_w = ctx.constrain(slot_w, P(dp, ctx.ep_axis, None))

    # gather tokens into expert slots: (B, E, C, d)
    xe = jnp.take_along_axis(
        x[:, None, :, :],                                  # (B,1,S,d)
        slot_token[..., None].astype(jnp.int32),           # (B,E,C,1)
        axis=2)
    if ctx is not None:
        xe = ctx.constrain(xe, P(ctx.dp_axes or None, ctx.ep_axis, None, None))

    h = jnp.einsum("becd,edf->becf", xe, p["moe_wg"])
    u = jnp.einsum("becd,edf->becf", xe, p["moe_wu"])
    h = jax.nn.silu(h) * u
    ye = jnp.einsum("becf,efd->becd", h, p["moe_wo"])      # (B,E,C,d)
    ye = ye * slot_w[..., None].astype(ye.dtype)

    # combine: scatter-add back to token positions (B, S, d).  Keep the
    # cross-expert reduction payload in bf16: the psum over the model axis
    # otherwise travels in f32 (measured 51 GB/chip on moonshot train_4k).
    ye = ye.astype(x.dtype)
    def combine_row(y_row, tok_row):
        flat_y = y_row.reshape(E * cap, d)
        flat_t = tok_row.reshape(E * cap)
        return jnp.zeros((S, d), flat_y.dtype).at[flat_t].add(flat_y)
    y = jax.vmap(combine_row)(ye, slot_token)
    if ctx is not None:
        y = ctx.act_btd(y)
    return y.astype(x.dtype)


def moe_ffn_single(cfg, p, x, ctx=None):
    """Decode-time MoE for (B, 1, d) — reuse the dispatch path with the batch
    acting as the token row: (B, 1, d) -> (1, B, d).  Weight reads amortise
    over the whole decode batch (a batched-serving essential for MoE)."""
    B = x.shape[0]
    y = moe_ffn(cfg, p, x.reshape(1, B, -1), ctx=None)
    return y.reshape(B, 1, -1)
