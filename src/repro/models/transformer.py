"""Decoder-only LM assembly for all LM-family architectures.

The stack is a lax.scan over ``n_periods`` repetitions of the config's block
pattern (HLO size is independent of depth).  Each pattern position is a
(mixer, ffn) pair:

  mixer: attn | mamba | mlstm | slstm
  ffn:   dense | moe | none           (xLSTM blocks carry their own FFN)

Params live in ``params["periods"]["b{i}_*"]`` with a stacked leading
period dim.  Decode state (KV cache / SSM state / LSTM state) mirrors that
layout so the same scan drives both training and serving.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ATTN, MAMBA, MLSTM, SLSTM
from repro.models import attention as attn
from repro.models import mamba as mamba_mod
from repro.models import moe as moe_mod
from repro.models import xlstm as xlstm_mod
from repro.models.layers import apply_norm, dense_init, positions_for, swiglu


# ---------------------------------------------------------------------------
# Pattern specs
# ---------------------------------------------------------------------------
def block_specs(cfg):
    """[(mixer, ffn_kind)] for one period."""
    specs = []
    for i, kind in enumerate(cfg.pattern):
        if kind in (MLSTM, SLSTM):
            specs.append((kind, "none"))
            continue
        ffn = "dense" if cfg.moe is None else (
            "moe" if (cfg.moe.period == 1 or i % cfg.moe.period == cfg.moe.period - 1)
            else "dense")
        specs.append((kind, ffn))
    return specs


# ---------------------------------------------------------------------------
# Parameter init (leading period dim handled by stacking)
# ---------------------------------------------------------------------------
def _init_ffn(key, cfg, kind, dtype):
    if kind == "none":
        return {}
    if kind == "moe":
        return {"ln2": jnp.ones((cfg.d_model,), dtype),
                **moe_mod.init_moe_params(key, cfg, dtype)}
    ks = jax.random.split(key, 3)
    return {
        "ln2": jnp.ones((cfg.d_model,), dtype),
        "wi_gate": dense_init(ks[0], (cfg.d_model, cfg.d_ff), dtype),
        "wi_up": dense_init(ks[1], (cfg.d_model, cfg.d_ff), dtype),
        "w_down": dense_init(ks[2], (cfg.d_ff, cfg.d_model), dtype),
    }


def _init_block(key, cfg, spec, dtype):
    mixer, ffn = spec
    k1, k2 = jax.random.split(key)
    if mixer == ATTN:
        p = {"ln": jnp.ones((cfg.d_model,), dtype),
             "attn": attn.init_attn_params(k1, cfg, dtype)}
    elif mixer == MAMBA:
        p = {"ln": jnp.ones((cfg.d_model,), dtype),
             "mamba": mamba_mod.init_mamba_params(k1, cfg, dtype)}
    elif mixer == MLSTM:
        p = {"mlstm": xlstm_mod.init_mlstm_params(k1, cfg, dtype)}
    elif mixer == SLSTM:
        p = {"slstm": xlstm_mod.init_slstm_params(k1, cfg, dtype)}
    else:
        raise ValueError(mixer)
    p.update(_init_ffn(k2, cfg, ffn, dtype))
    return p


def init_params(cfg, key, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    specs = block_specs(cfg)
    keys = jax.random.split(key, len(specs) * cfg.n_periods + 3)

    def stack_block(i):
        per = [_init_block(keys[j * len(specs) + i], cfg, specs[i], dtype)
               for j in range(cfg.n_periods)]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *per)

    params = {
        "periods": {f"b{i}": stack_block(i) for i in range(len(specs))},
        "final_norm": jnp.ones((cfg.d_model,), dtype),
        "head_w": dense_init(keys[-1], (cfg.d_model, cfg.vocab_size), dtype),
    }
    if cfg.input_mode == "tokens":
        params["embed"] = {"table": dense_init(keys[-2],
                                               (cfg.vocab_size, cfg.d_model), dtype)}
    return params


def init_params_shape(cfg, dtype=None):
    """Shape-only init (no allocation) for the dry-run."""
    return jax.eval_shape(lambda: init_params(cfg, jax.random.key(0), dtype))


# ---------------------------------------------------------------------------
# Block application
# ---------------------------------------------------------------------------
def _apply_ffn(cfg, spec, bp, x, ctx, single=False):
    _, ffn = spec
    if ffn == "none":
        return x
    h = apply_norm(cfg, x, bp["ln2"])
    if ffn == "moe":
        if single:
            delta = moe_mod.moe_ffn_single(cfg, bp, h, ctx)
        else:
            delta = moe_mod.moe_ffn(cfg, bp, h, ctx)
    else:
        delta = swiglu(h, bp["wi_gate"], bp["wi_up"], bp["w_down"], ctx)
    if ctx:
        # constrain the TP-partial output to the SP layout BEFORE the
        # residual add so GSPMD emits reduce-scatter, not all-reduce+slice
        delta = ctx.act_btd(delta)
    x = x + delta
    return ctx.act_btd(x) if ctx else x


def apply_block_train(cfg, spec, bp, x, positions, ctx, return_cache=False):
    mixer, _ = spec
    cache = None
    if mixer == ATTN:
        h = apply_norm(cfg, x, bp["ln"])
        if return_cache:
            delta, (k, v) = attn.attention_block(cfg, bp["attn"], h, positions,
                                                 ctx, return_cache=True)
            cache = {"k": k, "v": v}
        else:
            delta = attn.attention_block(cfg, bp["attn"], h, positions, ctx)
        if ctx:
            delta = ctx.act_btd(delta)
    elif mixer == MAMBA:
        h = apply_norm(cfg, x, bp["ln"])
        delta, _ = mamba_mod.mamba_block(cfg, bp["mamba"], h, None, ctx)
    elif mixer == MLSTM:
        delta, _ = xlstm_mod.mlstm_block(cfg, bp["mlstm"], x, None, ctx)
    elif mixer == SLSTM:
        delta, _ = xlstm_mod.slstm_block(cfg, bp["slstm"], x, None, ctx)
    x = x + delta
    if ctx:
        x = ctx.act_btd(x)
    x = _apply_ffn(cfg, spec, bp, x, ctx)
    if return_cache:
        return x, cache
    return x


def apply_block_decode(cfg, spec, bp, x, state, pos, ctx):
    mixer, _ = spec
    if mixer == ATTN:
        h = apply_norm(cfg, x, bp["ln"])
        delta, ck, cv = attn.decode_attention_block(
            cfg, bp["attn"], h, state["k"], state["v"], pos, ctx)
        new_state = {"k": ck, "v": cv}
    elif mixer == MAMBA:
        h = apply_norm(cfg, x, bp["ln"])
        delta, new_state = mamba_mod.mamba_block(cfg, bp["mamba"], h, state, ctx)
    elif mixer == MLSTM:
        delta, new_state = xlstm_mod.mlstm_block(cfg, bp["mlstm"], x, state, ctx)
    elif mixer == SLSTM:
        delta, new_state = xlstm_mod.slstm_block(cfg, bp["slstm"], x, state, ctx)
    x = x + delta
    return _apply_ffn(cfg, spec, bp, x, ctx, single=True), new_state


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------
def embed_inputs(cfg, params, batch, ctx):
    if cfg.input_mode == "embeds":
        x = batch["embeds"]
        positions = batch["positions"]
    else:
        tokens = batch["tokens"]
        x = jnp.take(params["embed"]["table"], tokens, axis=0)
        positions = positions_for(cfg, tokens.shape[0], tokens.shape[1])
    if ctx:
        x = ctx.act_btd(x)
    return x, positions


def forward(cfg, params, batch, ctx=None, remat=None):
    """Training/prefill forward -> logits (B, S, V)."""
    specs = block_specs(cfg)
    x, positions = embed_inputs(cfg, params, batch, ctx)

    def period_body(x, period_params):
        for i, spec in enumerate(specs):
            x = apply_block_train(cfg, spec, period_params[f"b{i}"],
                                  x, positions, ctx)
        return x, None

    body = _maybe_remat(period_body, remat if remat is not None
                        else cfg.sharding.remat)
    x, _ = jax.lax.scan(body, x, params["periods"])
    x = apply_norm(cfg, x, params["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", x, params["head_w"])
    if ctx:
        logits = ctx.logits(logits)
    return logits


def _maybe_remat(fn, policy):
    if policy == "none":
        return fn
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


def loss_fn(cfg, params, batch, ctx=None, remat=None):
    logits = forward(cfg, params, batch, ctx, remat)
    labels = batch["labels"]
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None].astype(jnp.int32),
                             axis=-1)[..., 0]
    return jnp.mean(lse - ll)


# ---------------------------------------------------------------------------
# Decode (serving)
# ---------------------------------------------------------------------------
def init_decode_state(cfg, batch, max_len, dtype=None):
    """Stacked per-period decode state matching params['periods'] layout."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    specs = block_specs(cfg)
    P = cfg.n_periods

    def one(spec):
        mixer, _ = spec
        if mixer == ATTN:
            return {"k": jnp.zeros((P, batch, max_len, cfg.n_kv_heads,
                                    cfg.head_dim), dtype),
                    "v": jnp.zeros((P, batch, max_len, cfg.n_kv_heads,
                                    cfg.head_dim), dtype)}
        if mixer == MAMBA:
            st = mamba_mod.init_mamba_state(cfg, batch, dtype)
            return jax.tree.map(lambda a: jnp.broadcast_to(a, (P,) + a.shape), st)
        if mixer == MLSTM:
            st = xlstm_mod.init_mlstm_state(cfg, batch)
            return jax.tree.map(lambda a: jnp.broadcast_to(a, (P,) + a.shape), st)
        if mixer == SLSTM:
            st = xlstm_mod.init_slstm_state(cfg, batch)
            return jax.tree.map(lambda a: jnp.broadcast_to(a, (P,) + a.shape), st)
        raise ValueError(mixer)

    return {f"b{i}": one(spec) for i, spec in enumerate(specs)}


def decode_step(cfg, params, state, batch, ctx=None):
    """One-token decode.  batch: {"tokens": (B, 1) or "embeds": (B,1,d),
    "pos": scalar int32 current position}.  Returns (logits (B, V), state)."""
    specs = block_specs(cfg)
    pos = batch["pos"]
    if cfg.input_mode == "embeds":
        x = batch["embeds"]
    else:
        x = jnp.take(params["embed"]["table"], batch["tokens"], axis=0)

    def period_body(x, inp):
        period_params, period_state = inp
        new_states = {}
        for i, spec in enumerate(specs):
            x, ns = apply_block_decode(cfg, spec, period_params[f"b{i}"],
                                       x, period_state[f"b{i}"], pos, ctx)
            new_states[f"b{i}"] = ns
        return x, new_states

    x, new_state = jax.lax.scan(period_body, x, (params["periods"], state))
    x = apply_norm(cfg, x, params["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", x, params["head_w"])[:, 0]
    if ctx:
        logits = ctx.constrain(logits, jax.sharding.PartitionSpec(
            ctx.dp_axes or None, ctx.tp_axis))
    return logits, new_state


def prefill(cfg, params, batch, ctx=None):
    """Prefill pass: forward + emit per-layer KV caches (attention blocks).

    K/V projections are shared with the attention compute (no double
    projection) via ``return_cache``.
    """
    specs = block_specs(cfg)
    x, positions = embed_inputs(cfg, params, batch, ctx)

    def period_body(x, period_params):
        caches = {}
        for i, spec in enumerate(specs):
            x, cache = apply_block_train(cfg, spec, period_params[f"b{i}"],
                                         x, positions, ctx, return_cache=True)
            if cache is not None:
                if ctx:
                    cache = {kk: ctx.constrain(vv, ctx.kv_cache_spec())
                             for kk, vv in cache.items()}
                caches[f"b{i}"] = cache
        return x, caches

    x, caches = jax.lax.scan(period_body, x, params["periods"])
    x = apply_norm(cfg, x, params["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", x[:, -1:], params["head_w"])[:, 0]
    return logits, caches
