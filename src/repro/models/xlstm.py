"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel) and sLSTM
(scalar memory, strictly sequential recurrence).

Simplifications vs the reference CUDA implementation (recorded per DESIGN.md
hardware-adaptation mandate):
  * mLSTM uses the stabilised exponential-gate chunkwise form with a running
    per-head max stabiliser carried across chunks (m-state), matching the
    paper's numerics; q/k/v are per-head block-diagonal projections.
  * sLSTM keeps the exact sequential semantics via lax.scan over time — on
    TPU this is latency-bound (the original work ships fused CUDA kernels;
    the TPU-native answer is the chunkwise mLSTM path carrying most layers,
    with sLSTM at 1-in-8 per the xLSTM[7:1] recipe).

Decode for both is an O(1) state update => the long_500k cell runs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, layer_norm


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------
def init_mlstm_params(key, cfg, dtype):
    d = cfg.d_model
    di = int(d * cfg.mlstm_proj_factor)
    nh = cfg.n_heads
    dh = di // nh
    ks = jax.random.split(key, 8)
    return {
        "ln": jnp.ones((d,), dtype),
        "up_proj": dense_init(ks[0], (d, 2 * di), dtype),
        "m_wq": dense_init(ks[1], (nh, dh, dh), dtype),
        "m_wk": dense_init(ks[2], (nh, dh, dh), dtype),
        "m_wv": dense_init(ks[3], (nh, dh, dh), dtype),
        "w_ig": dense_init(ks[4], (d, nh), dtype),   # input gate (pre-act)
        "w_fg": dense_init(ks[5], (d, nh), dtype),   # forget gate (pre-act)
        "b_ig": jnp.zeros((nh,), dtype),
        "b_fg": jnp.full((nh,), 3.0, dtype),         # bias toward remembering
        "w_og": dense_init(ks[6], (d, di), dtype),   # output gate
        "gn": jnp.ones((di,), dtype),                # per-head group norm scale
        "down_proj": dense_init(ks[7], (di, d), dtype),
    }


def init_mlstm_state(cfg, batch):
    di = int(cfg.d_model * cfg.mlstm_proj_factor)
    nh = cfg.n_heads
    dh = di // nh
    return {
        "C": jnp.zeros((batch, nh, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, nh, dh), jnp.float32),
        "m": jnp.full((batch, nh), -1e30, jnp.float32),
    }


def _mlstm_qkv(p, xs, nh, dh):
    B, S, di = xs.shape
    xh = xs.reshape(B, S, nh, dh)
    q = jnp.einsum("bshd,hde->bshe", xh, p["m_wq"])
    k = jnp.einsum("bshd,hde->bshe", xh, p["m_wk"]) * dh ** -0.5
    v = jnp.einsum("bshd,hde->bshe", xh, p["m_wv"])
    return q, k, v


def mlstm_mix(p, x, xs, state, chunk=256):
    """Chunkwise-parallel stabilised mLSTM.

    x: (B, S, d) block input (drives the gates); xs: (B, S, di) up-projected
    stream.  Returns (y (B, S, di), new_state).
    """
    B, S, di = xs.shape
    nh = p["m_wq"].shape[0]
    dh = di // nh
    q, k, v = _mlstm_qkv(p, xs, nh, dh)
    x32 = x.astype(jnp.float32)
    ig = (x32 @ p["w_ig"].astype(jnp.float32) + p["b_ig"].astype(jnp.float32))
    fg = (x32 @ p["w_fg"].astype(jnp.float32) + p["b_fg"].astype(jnp.float32))
    logf = jax.nn.log_sigmoid(fg)                                  # (B,S,nh)

    if S == 1:
        return _mlstm_step(p, q, k, v, ig, fg, state)

    if S % chunk != 0:
        chunk = S
    T = S // chunk

    def reshape_c(a):
        return a.reshape((B, T, chunk) + a.shape[2:])
    qc, kc, vc = map(reshape_c, (q, k, v))
    igc, logfc = map(reshape_c, (ig, logf))

    @jax.checkpoint
    def body(carry, inp):
        C, n, m = carry
        qb, kb, vb, igb, logfb = inp       # (B,c,nh,dh)... gates (B,c,nh)
        c = qb.shape[1]
        # cumulative log forget within chunk: F_t = sum_{u<=t} logf_u
        F = jnp.cumsum(logfb, axis=1)                              # (B,c,nh)
        Ftot = F[:, -1]
        # stabiliser: max over (inter: m + F_t) and (intra: F_t - F_u + ig_u)
        # log "a" coefficients for inter-chunk contribution
        log_inter = m[:, None] + F                                 # (B,c,nh)
        # intra-chunk pair logits: d_{tu} = F_t - F_u + ig_u  (u <= t)
        dmat = F[:, :, None] - F[:, None, :] + igb[:, None, :]     # (B,c,c,nh) t,u
        tri = jnp.tril(jnp.ones((c, c), bool))
        dmat = jnp.where(tri[None, :, :, None], dmat, -jnp.inf)
        m_intra = jnp.max(dmat, axis=2)                            # (B,c,nh)
        m_new_t = jnp.maximum(log_inter, m_intra)                  # (B,c,nh)
        # normalised weights
        inter_w = jnp.exp(log_inter - m_new_t)                     # (B,c,nh)
        intra_w = jnp.exp(dmat - m_new_t[:, :, None])              # (B,c,c,nh)
        # intra attention-style contribution
        scores = jnp.einsum("bthd,buhd->btuh", qb.astype(jnp.float32),
                            kb.astype(jnp.float32))
        num_intra = jnp.einsum("btuh,buhd->bthd", scores * intra_w,
                               vb.astype(jnp.float32))
        den_intra = jnp.sum(scores * intra_w, axis=2)
        # inter contribution via carried state
        qf = qb.astype(jnp.float32) * inter_w[..., None]
        num_inter = jnp.einsum("bthd,bhde->bthe", qf, C)
        den_inter = jnp.einsum("bthd,bhd->bth", qf, n)
        num = num_intra + num_inter
        den = jnp.abs(den_intra + den_inter)
        y = num / jnp.maximum(den, jnp.exp(-m_new_t))[..., None]
        # update carried state to end of chunk
        m_next = jnp.maximum(m + Ftot, jnp.max(Ftot[:, None] - F + igb, axis=1))
        decay = jnp.exp(m + Ftot - m_next)                         # (B,nh)
        kv_w = jnp.exp(Ftot[:, None] - F + igb - m_next[:, None])  # (B,c,nh)
        kw = kb.astype(jnp.float32) * kv_w[..., None]
        C_next = C * decay[..., None, None] + jnp.einsum(
            "buhd,buhe->bhde", kw, vb.astype(jnp.float32))
        n_next = n * decay[..., None] + jnp.sum(kw, axis=1)
        return (C_next, n_next, m_next), y

    (C, n, m), ys = jax.lax.scan(
        body, (state["C"], state["n"], state["m"]),
        tuple(jnp.moveaxis(a, 1, 0) for a in (qc, kc, vc, igc, logfc)))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, di)
    return y.astype(xs.dtype), {"C": C, "n": n, "m": m}


def _mlstm_step(p, q, k, v, ig, fg, state):
    """Single-token decode update."""
    B = q.shape[0]
    q1, k1, v1 = q[:, 0], k[:, 0], v[:, 0]            # (B,nh,dh)
    ig1, logf1 = ig[:, 0], jax.nn.log_sigmoid(fg[:, 0])  # (B,nh)
    C, n, m = state["C"], state["n"], state["m"]
    m_new = jnp.maximum(logf1 + m, ig1)
    fw = jnp.exp(logf1 + m - m_new)[..., None, None]
    iw = jnp.exp(ig1 - m_new)[..., None, None]
    C = C * fw + iw * jnp.einsum("bhd,bhe->bhde", k1.astype(jnp.float32),
                                 v1.astype(jnp.float32))
    n = n * fw[..., 0] + iw[..., 0] * k1.astype(jnp.float32)
    num = jnp.einsum("bhd,bhde->bhe", q1.astype(jnp.float32), C)
    den = jnp.abs(jnp.einsum("bhd,bhd->bh", q1.astype(jnp.float32), n))
    y = num / jnp.maximum(den, jnp.exp(-m_new))[..., None]
    di = y.shape[1] * y.shape[2]
    return y.reshape(B, 1, di).astype(q.dtype), {"C": C, "n": n, "m": m_new}


def mlstm_block(cfg, p, x, state=None, ctx=None):
    """Full mLSTM residual block.  x: (B, S, d)."""
    B, S, d = x.shape
    di = int(d * cfg.mlstm_proj_factor)
    nh = cfg.n_heads
    h = layer_norm(x, p["ln"])
    uz = jnp.einsum("bsd,de->bse", h, p["up_proj"])
    u, z = jnp.split(uz, 2, axis=-1)
    if state is None:
        st = init_mlstm_state(cfg, B)
    else:
        st = state
    y, new_state = mlstm_mix(p, h, u, st)
    # per-head group norm + output gate
    y = layer_norm(y.reshape(B, S, nh, di // nh),
                   p["gn"].reshape(nh, di // nh)).reshape(B, S, di)
    og = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", h, p["w_og"]))
    y = y * og * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["down_proj"])
    return out, (new_state if state is not None else None)


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------
def init_slstm_params(key, cfg, dtype):
    d = cfg.d_model
    nh = cfg.n_heads
    dh = d // nh
    dff = int(d * cfg.slstm_proj_factor)
    ks = jax.random.split(key, 5)
    return {
        "ln": jnp.ones((d,), dtype),
        "w_gates": dense_init(ks[0], (d, 4 * d), dtype),
        "r_gates": dense_init(ks[1], (nh, dh, 4 * dh), dtype),
        "b_gates": jnp.concatenate([jnp.zeros((2 * d,), dtype),
                                    jnp.full((d,), 3.0, dtype),
                                    jnp.zeros((d,), dtype)]),
        "ln2": jnp.ones((d,), dtype),
        "ff_up": dense_init(ks[2], (d, dff), dtype),
        "ff_down": dense_init(ks[3], (dff, d), dtype),
    }


def init_slstm_state(cfg, batch):
    d = cfg.d_model
    return {
        "h": jnp.zeros((batch, d), jnp.float32),
        "c": jnp.zeros((batch, d), jnp.float32),
        "nn": jnp.zeros((batch, d), jnp.float32),
        "mm": jnp.full((batch, d), -1e30, jnp.float32),
    }


def _slstm_cell(cfg, r, carry, wx_t):
    """One recurrent step.  carry: 4 x (B, d) f32; wx_t: (B, 4d)."""
    nh = cfg.n_heads
    d = cfg.d_model
    dh = d // nh
    h, c, n, m = carry
    hh = h.reshape(-1, nh, dh)
    # per-head block-diagonal recurrence; r's last dim is [zi|ii|ff|oo] per
    # head (dh each) — rearrange to wx's layout (4 gate blocks of d) before
    # the gate split
    rec = jnp.einsum("bhd,hde->bhe", hh, r)          # (B, nh, 4*dh)
    rec = rec.reshape(-1, nh, 4, dh).transpose(0, 2, 1, 3).reshape(-1, 4 * d)
    zi, ii, ff, oo = jnp.split(wx_t.astype(jnp.float32) + rec, 4, axis=-1)
    logf = jax.nn.log_sigmoid(ff)
    m_new = jnp.maximum(logf + m, ii)
    fw = jnp.exp(logf + m - m_new)
    iw = jnp.exp(ii - m_new)
    c_new = fw * c + iw * jnp.tanh(zi)
    n_new = fw * n + iw
    h_new = jax.nn.sigmoid(oo) * c_new / jnp.maximum(n_new, 1e-6)
    return (h_new, c_new, n_new, m_new), h_new


def _slstm_scan(cfg, p, wx, state, chunk=16):
    """wx: (B, S, 4d) precomputed input contributions.  Sequential over S.

    The time loop is CHUNKED: an outer scan over S/chunk iterations with
    `chunk` unrolled recurrent steps per body.  A per-timestep while loop
    pays fixed loop-carry costs (copies, stacked-output update patterns)
    every step — measured ~9 TB/chip of loop overhead on the train_4k cell;
    unrolling 16 steps per iteration amortises it ~16x (EXPERIMENTS.md
    §Perf, xlstm iteration B1)."""
    B, S, _ = wx.shape
    r = p["r_gates"].astype(jnp.float32)                 # (nh, dh, 4dh)
    carry0 = (state["h"], state["c"], state["nn"], state["mm"])

    if S % chunk != 0 or S <= chunk:
        @jax.checkpoint
        def step(carry, wx_t):
            return _slstm_cell(cfg, r, carry, wx_t)
        carry, hs = jax.lax.scan(step, carry0, jnp.moveaxis(wx, 1, 0))
        y = jnp.moveaxis(hs, 0, 1)
    else:
        T = S // chunk
        wx_c = jnp.moveaxis(
            wx.reshape(B, T, chunk, wx.shape[-1]), 1, 0)  # (T,B,chunk,4d)

        @jax.checkpoint
        def block(carry, wx_blk):
            hs = []
            for t in range(chunk):                        # unrolled
                carry, h = _slstm_cell(cfg, r, carry, wx_blk[:, t])
                hs.append(h)
            return carry, jnp.stack(hs, axis=1)           # (B,chunk,d)
        carry, ys = jax.lax.scan(block, carry0, wx_c)
        y = jnp.moveaxis(ys, 0, 1).reshape(B, S, -1)
    h, c, n, m = carry
    return y, {"h": h, "c": c, "nn": n, "mm": m}


def slstm_block(cfg, p, x, state=None, ctx=None):
    B, S, d = x.shape
    h = layer_norm(x, p["ln"])
    wx = jnp.einsum("bsd,de->bse", h, p["w_gates"]) + p["b_gates"]
    st = state if state is not None else init_slstm_state(cfg, B)
    y, new_state = _slstm_scan(cfg, p, wx, st)
    y = y.astype(x.dtype)
    # post-FFN (GeLU), per xLSTM block recipe.  Block returns a residual
    # delta (caller adds x): delta = y + ffn(ln2(x + y)).
    mid = x + y
    hf = layer_norm(mid, p["ln2"])
    f = jnp.einsum("bsd,df->bsf", hf, p["ff_up"])
    delta = y + jnp.einsum("bsf,fd->bsd", jax.nn.gelu(f), p["ff_down"])
    return delta, (new_state if state is not None else None)
