"""Update/gradient compression for the rollup commit payload.

Distributed-optimization tricks (DESIGN.md §7):
  * int8 stochastic-rounding quantization with per-block scales — shrinks
    the commit's all-reduce payload ~2x vs bf16 / 4x vs f32;
  * top-k sparsification with error feedback — residuals accumulate locally
    and re-enter the next commit, preserving convergence (Stich et al.).
Both are pure-jnp pytree transforms usable inside the jitted fl_round.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


def quantize_int8(x: jnp.ndarray, key=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-block symmetric int8 quantization; optional stochastic rounding."""
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    y = blocks / scale
    if key is not None:
        y = y + jax.random.uniform(key, y.shape, minval=-0.5, maxval=0.5)
    q = jnp.clip(jnp.round(y), -127, 127).astype(jnp.int8)
    return q, scale[:, 0]


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray, shape,
                    dtype=jnp.float32) -> jnp.ndarray:
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape).astype(dtype)


def quantize_tree(tree, key=None):
    leaves, treedef = jax.tree.flatten(tree)
    keys = (jax.random.split(key, len(leaves)) if key is not None
            else [None] * len(leaves))
    qs = [quantize_int8(l, k) for l, k in zip(leaves, keys)]
    meta = [(l.shape, l.dtype) for l in leaves]
    return {"q": treedef.unflatten([q for q, _ in qs]),
            "scale": treedef.unflatten([s for _, s in qs])}, (treedef, meta)


def dequantize_tree(packed, info):
    treedef, meta = info
    qs = treedef.flatten_up_to(packed["q"])
    ss = treedef.flatten_up_to(packed["scale"])
    out = [dequantize_int8(q, s, shape, dtype)
           for q, s, (shape, dtype) in zip(qs, ss, meta)]
    return treedef.unflatten(out)


# -- top-k + error feedback ------------------------------------------------------
def topk_sparsify(x: jnp.ndarray, frac: float = 0.01):
    """Keep the largest-|.| frac entries; return (sparse_x, kept_mask)."""
    flat = x.astype(jnp.float32).reshape(-1)
    k = max(1, int(flat.shape[0] * frac))
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    mask = jnp.abs(flat) >= thresh
    return (flat * mask).reshape(x.shape).astype(x.dtype), mask.reshape(x.shape)


def ef_compress_tree(update_tree, residual_tree, frac: float = 0.01):
    """Error-feedback top-k: compress (update + residual), carry the rest."""
    def one(u, r):
        tot = u.astype(jnp.float32) + r.astype(jnp.float32)
        kept, mask = topk_sparsify(tot, frac)
        new_resid = tot - kept.astype(jnp.float32)
        return kept.astype(u.dtype), new_resid.astype(r.dtype)
    out = jax.tree.map(one, update_tree, residual_tree)
    kept = jax.tree.map(lambda t: t[0], out,
                        is_leaf=lambda t: isinstance(t, tuple))
    resid = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return kept, resid


def init_residual(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
