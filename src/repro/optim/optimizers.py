"""Optimizers (pure JAX, pytree-native, sharding-friendly).

* adamw     — bf16 moments by default (halves optimizer HBM vs fp32).
* adafactor — factored second moment (beta1=0): the memory-fitting choice
              for the 398B/1T archs (see DESIGN.md memory notes).
* sgdm      — plain momentum.

States mirror param sharding (factored adafactor states drop the factored
dim's spec) so FSDP/ZeRO-3 covers optimizer memory automatically.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerSpec:
    name: str = "adamw"
    lr: float = 1e-3
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    moment_dtype: str = "bfloat16"
    # adafactor
    factored_min: int = 128     # factor only dims >= this


class Optimizer(NamedTuple):
    init: Callable
    update: Callable          # (grads, state, params) -> (new_params, new_state)


def _global_norm(tree):
    leaves = [jnp.sum(jnp.square(l.astype(jnp.float32)))
              for l in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def _clip_by_global_norm(grads, max_norm):
    gn = _global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), gn


def make_optimizer(spec: OptimizerSpec) -> Optimizer:
    if spec.name == "adamw":
        return _adamw(spec)
    if spec.name == "adafactor":
        return _adafactor(spec)
    if spec.name == "sgdm":
        return _sgdm(spec)
    raise ValueError(spec.name)


# -- AdamW ---------------------------------------------------------------------
def _adamw(spec: OptimizerSpec) -> Optimizer:
    mdt = jnp.dtype(spec.moment_dtype)

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, mdt)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        grads, gn = _clip_by_global_norm(grads, spec.grad_clip)
        step = state["step"] + 1
        b1, b2 = spec.beta1, spec.beta2
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            g32 = g.astype(jnp.float32)
            m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
            v32 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g32)
            mhat = m32 / c1
            vhat = v32 / c2
            delta = mhat / (jnp.sqrt(vhat) + spec.eps)
            p32 = p.astype(jnp.float32)
            p32 = p32 - spec.lr * (delta + spec.weight_decay * p32)
            return p32.astype(p.dtype), m32.astype(mdt), v32.astype(mdt)

        out = jax.tree.map(upd, params, grads, state["m"], state["v"])
        new_params = jax.tree.map(lambda t: t[0], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        new_v = jax.tree.map(lambda t: t[2], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        return new_params, {"m": new_m, "v": new_v, "step": step}, gn

    return Optimizer(init, update)


# -- Adafactor --------------------------------------------------------------------
def _factored(shape, min_dim) -> bool:
    return len(shape) >= 2 and shape[-1] >= min_dim and shape[-2] >= min_dim


def _adafactor(spec: OptimizerSpec) -> Optimizer:
    def init(params):
        def vstate(p):
            if _factored(p.shape, spec.factored_min):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"v": jax.tree.map(vstate, params,
                                  is_leaf=lambda x: hasattr(x, "shape")),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        grads, gn = _clip_by_global_norm(grads, spec.grad_clip)
        step = state["step"] + 1
        decay = 1.0 - step.astype(jnp.float32) ** -0.8  # beta2 schedule

        def upd(p, g, v):
            g32 = g.astype(jnp.float32)
            g2 = jnp.square(g32) + 1e-30
            if "vr" in v:
                vr = decay * v["vr"] + (1 - decay) * jnp.mean(g2, axis=-1)
                vc = decay * v["vc"] + (1 - decay) * jnp.mean(g2, axis=-2)
                denom = (vr[..., None] * vc[..., None, :]
                         / jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True)[..., None],
                                       1e-30))
                newv = {"vr": vr, "vc": vc}
            else:
                newv = {"v": decay * v["v"] + (1 - decay) * g2}
                denom = newv["v"]
            delta = g32 * jax.lax.rsqrt(denom + 1e-30)
            # update clipping (adafactor rms-1 rule)
            rms = jnp.sqrt(jnp.mean(jnp.square(delta)) + 1e-30)
            delta = delta / jnp.maximum(1.0, rms)
            p32 = p.astype(jnp.float32)
            p32 = p32 - spec.lr * (delta + spec.weight_decay * p32)
            return p32.astype(p.dtype), newv

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_v = tdef.flatten_up_to(state["v"])
        outs = [upd(p, g, v) for p, g, v in zip(flat_p, flat_g, flat_v)]
        new_params = tdef.unflatten([o[0] for o in outs])
        new_v = tdef.unflatten([o[1] for o in outs])
        return new_params, {"v": new_v, "step": step}, gn

    return Optimizer(init, update)


# -- SGD + momentum -----------------------------------------------------------------
def _sgdm(spec: OptimizerSpec) -> Optimizer:
    mdt = jnp.dtype(spec.moment_dtype)

    def init(params):
        return {"m": jax.tree.map(lambda p: jnp.zeros(p.shape, mdt), params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        grads, gn = _clip_by_global_norm(grads, spec.grad_clip)

        def upd(p, g, m):
            m32 = spec.beta1 * m.astype(jnp.float32) + g.astype(jnp.float32)
            p32 = p.astype(jnp.float32) - spec.lr * m32
            return p32.astype(p.dtype), m32.astype(mdt)

        out = jax.tree.map(upd, params, grads, state["m"])
        new_params = jax.tree.map(lambda t: t[0], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        return new_params, {"m": new_m, "step": state["step"] + 1}, gn

    return Optimizer(init, update)


def spec_for_config(cfg) -> OptimizerSpec:
    return OptimizerSpec(name=cfg.optimizer)
