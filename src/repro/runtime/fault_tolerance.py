"""Fault tolerance & straggler mitigation for 1000+-node FL fleets.

Design (DESIGN.md §7):
  * Rollup rounds are the natural sync/recovery points: the committed global
    state (+ digest) is the only thing that must survive; per-trainer local
    state is reconstructible from it.
  * Failure detection: heartbeat registry with deadline sweep.
  * Straggler mitigation: (a) round deadline — aggregate whatever subset
    submitted, reweighting by score mass (Eq. 1 is subset-closed);
    (b) the reputation completeness term (Eq. 2) economically punishes
    chronic stragglers so selection avoids them next task.
  * Elastic re-mesh: on membership change pick the nearest valid
    (pod, data, model) factorisation and resume from the last commit.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass
class NodeState:
    node_id: str
    last_heartbeat: float
    status: str = "alive"          # alive | suspect | dead
    missed_rounds: int = 0


class HeartbeatRegistry:
    def __init__(self, suspect_after: float = 5.0, dead_after: float = 15.0):
        self.nodes: Dict[str, NodeState] = {}
        self.suspect_after = suspect_after
        self.dead_after = dead_after

    def beat(self, node_id: str, now: Optional[float] = None):
        now = time.monotonic() if now is None else now
        n = self.nodes.get(node_id)
        if n is None:
            self.nodes[node_id] = NodeState(node_id, now)
        else:
            n.last_heartbeat = now
            n.status = "alive"

    def sweep(self, now: Optional[float] = None) -> List[str]:
        """Update statuses; return newly-dead node ids."""
        now = time.monotonic() if now is None else now
        died = []
        for n in self.nodes.values():
            dt = now - n.last_heartbeat
            if dt > self.dead_after and n.status != "dead":
                n.status = "dead"
                died.append(n.node_id)
            elif dt > self.suspect_after and n.status == "alive":
                n.status = "suspect"
        return died

    def alive(self) -> List[str]:
        return [n.node_id for n in self.nodes.values() if n.status != "dead"]


@dataclasses.dataclass
class RoundDeadline:
    """Straggler cutoff: proceed with the submitted subset once either the
    deadline passes or a quorum fraction has submitted."""

    deadline_s: float = 30.0
    quorum_frac: float = 2 / 3

    def ready(self, n_submitted: int, n_expected: int, elapsed: float) -> bool:
        if n_expected == 0:
            return False
        if n_submitted == n_expected:
            return True
        return (elapsed >= self.deadline_s
                and n_submitted >= self.quorum_frac * n_expected)


def subset_aggregate_ok(n_submitted: int, n_expected: int,
                        quorum_frac: float = 2 / 3) -> bool:
    """Eq. 1 is subset-closed: the weighted mean over submitters is still the
    correct estimator; require the chain's 2/3 quorum for commit validity."""
    return n_submitted >= quorum_frac * n_expected


def factorize_mesh(n_nodes: int, prefer_model: int = 16
                   ) -> Tuple[int, int, int]:
    """Elastic re-mesh: nearest valid (pod, data, model) for n_nodes chips.

    Keeps the model axis at the largest power-of-two <= prefer_model that
    divides n_nodes (TP degree changes force a resharded restore, so prefer
    keeping it); splits the rest into pod x data.
    """
    assert n_nodes >= 1
    model = 1
    m = prefer_model
    while m > 1:
        if n_nodes % m == 0:
            model = m
            break
        m //= 2
    rest = n_nodes // model
    pod = 1
    for cand in (8, 4, 2):
        if rest % cand == 0 and rest // cand >= cand:
            pod = cand
            break
    data = rest // pod
    return pod, data, model


class ElasticController:
    """Drives re-mesh + restore-from-commit on membership change."""

    def __init__(self, registry: HeartbeatRegistry, checkpointer,
                 prefer_model: int = 16):
        self.registry = registry
        self.checkpointer = checkpointer
        self.prefer_model = prefer_model
        self.current_mesh: Optional[Tuple[int, int, int]] = None
        self.events: List[Dict] = []

    def reconcile(self, now: Optional[float] = None) -> Optional[Tuple]:
        died = self.registry.sweep(now)
        n = len(self.registry.alive())
        target = factorize_mesh(n, self.prefer_model) if n else None
        if target != self.current_mesh:
            step = self.checkpointer.latest_step()
            self.events.append({
                "died": died, "alive": n, "new_mesh": target,
                "resume_step": step})
            self.current_mesh = target
            return target
        return None
