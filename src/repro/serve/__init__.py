"""Concurrent node service: admission-controlled serving of one stack.

The serving face of the reproduction (docs/SERVING.md): a stdlib-only
asyncio JSON-RPC/HTTP server (``HttpNodeServer``) over a single-writer
``NodeService`` that owns one ``repro.api`` stack, with the mempool
admission layer (``AdmissionController``/``PendingPool``) in front —
per-sender token buckets, a fee floor, reputation-gated admission and
lowest-fee-first spam eviction, all pure functions of modeled time
(rule R008).  Configure with ``repro.api.ServeSpec``/``AdmissionSpec``;
launch with ``python -m repro.launch.serve_node``.

    from repro.api import ServeSpec
    from repro.serve import HttpNodeServer, NodeService

    server = HttpNodeServer(NodeService(ServeSpec()), port=0)
    host, port = await server.start()
"""
from repro.serve.admission import (REJECT_REASONS, AdmissionController,
                                   Decision, PendingPool, PoolEntry)
from repro.serve.http import HttpNodeServer, http_rpc
from repro.serve.service import NodeService, ServeMetrics, replay_ops

__all__ = [
    "AdmissionController", "Decision", "PendingPool", "PoolEntry",
    "REJECT_REASONS",
    "HttpNodeServer", "http_rpc",
    "NodeService", "ServeMetrics", "replay_ops",
]
