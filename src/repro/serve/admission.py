"""Mempool admission: pure decision layer ahead of the ledger.

``AdmissionController.admit`` applies the ``AdmissionSpec`` rules in a
fixed order — fee floor, reputation gate, per-sender token bucket, pool
capacity — and either places the transaction in the ``PendingPool`` or
rejects it with a machine-readable reason.  Every decision is a pure
function of (spec, sender state, pool state) and the transaction's
MODELED submit time: nothing here may read the wall clock (rule R008 —
the static checker seeds its reachability walk on these two classes),
so a recorded admission log replays to the identical admitted set.

Rejection reasons (``REJECT_REASONS``):

  * ``fee_floor``    — offered fee below ``AdmissionSpec.fee_floor``
  * ``reputation``   — sender below ``r_min`` under ``rep_gate="reject"``
  * ``surcharge``    — sender below ``r_min`` under ``"surcharge"`` and
    the offered fee does not cover ``rep_surcharge x intrinsic`` gas
  * ``rate_limited`` — the sender's token bucket is empty
  * ``overloaded``   — the pool is at cap and the arrival's fee does not
    beat the cheapest pooled entry (or eviction is disabled); the
    serving layer maps this to HTTP 429

The trust line and newcomer prior come from the node's own
``ReputationParams`` (``r_min``/``r_init``): a sender with no on-ledger
reputation history is treated at ``r_init`` — the paper's newcomers
start above the trust line, not at zero.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Tuple

from repro.api.specs import AdmissionSpec
from repro.core.reputation import ReputationParams

#: every reason ``Decision.reason`` can carry (order = rule order)
REJECT_REASONS = ("fee_floor", "reputation", "surcharge", "rate_limited",
                  "overloaded")


@dataclasses.dataclass(frozen=True)
class PoolEntry:
    """One admitted-but-not-yet-flushed transaction."""

    ref: int                     # service-assigned submission ref
    fn: str
    sender: str
    fee: int                     # offered gas (what the ledger meters)
    at: float                    # modeled submit time


@dataclasses.dataclass(frozen=True)
class Decision:
    """Outcome of one admission check."""

    admitted: bool
    reason: Optional[str] = None     # one of REJECT_REASONS when rejected
    evicted: Optional[int] = None    # ref displaced to make room, if any


class PendingPool:
    """Bounded pending pool with lowest-fee-first eviction.

    A min-heap on ``(fee, ref)`` finds the cheapest entry in O(log n);
    ``ref`` ties the ordering so equal-fee entries never compare
    ``PoolEntry`` objects and eviction is deterministic (oldest ref
    first among equal fees).  Entries leave either by ``drain`` (the
    service's window flush) or by ``evict_cheapest``; the heap removes
    stale refs lazily.
    """

    def __init__(self, cap: int):
        self.cap = int(cap)
        self.entries: Dict[int, PoolEntry] = {}
        self._heap: List[Tuple[int, int]] = []      # (fee, ref)

    def __len__(self) -> int:
        return len(self.entries)

    @property
    def full(self) -> bool:
        return len(self.entries) >= self.cap

    def place(self, entry: PoolEntry) -> None:
        self.entries[entry.ref] = entry
        heapq.heappush(self._heap, (entry.fee, entry.ref))

    def cheapest_fee(self) -> Optional[int]:
        """Fee of the cheapest live entry (None on an empty pool)."""
        while self._heap and self._heap[0][1] not in self.entries:
            heapq.heappop(self._heap)               # lazily drop drained refs
        return self._heap[0][0] if self._heap else None

    def evict_cheapest(self) -> Optional[int]:
        """Remove and return the ref of the cheapest live entry."""
        if self.cheapest_fee() is None:
            return None
        _fee, ref = heapq.heappop(self._heap)
        del self.entries[ref]
        return ref

    def drain(self) -> List[PoolEntry]:
        """Remove every entry, ordered by (modeled time, ref) — the
        deterministic flush order the service commits to the ledger."""
        out = sorted(self.entries.values(), key=lambda e: (e.at, e.ref))
        self.entries.clear()
        self._heap.clear()
        return out


class AdmissionController:
    """Applies one ``AdmissionSpec`` over one ``PendingPool``.

    Keeps the per-sender token buckets, the admission log (every
    decision, in ref order) and per-reason counters.  All time is the
    modeled submit time the caller passes in.
    """

    def __init__(self, spec: AdmissionSpec, rep: ReputationParams,
                 pool: Optional[PendingPool] = None):
        self.spec = spec
        self.rep = rep
        self.pool = pool if pool is not None else PendingPool(spec.pool_cap)
        # sender -> (tokens, last refill time); buckets start full
        self._buckets: Dict[str, Tuple[float, float]] = {}
        self.log: List[Tuple[int, str, str, int, float, str]] = []
        self.n_admitted = 0
        self.n_evicted = 0
        self.rejected: Dict[str, int] = {r: 0 for r in REJECT_REASONS}

    # -- rules, in order --------------------------------------------------------
    def _take_token(self, sender: str, at: float) -> bool:
        spec = self.spec
        tokens, last = self._buckets.get(sender, (float(spec.burst), at))
        tokens = min(float(spec.burst),
                     tokens + max(0.0, at - last) * spec.rate_limit)
        ok = tokens >= 1.0
        if ok:
            tokens -= 1.0
        self._buckets[sender] = (tokens, max(last, at))
        return ok

    def admit(self, *, ref: int, fn: str, sender: str, fee: int,
              intrinsic: int, at: float, reputation: float) -> Decision:
        """Run the rule ladder for one transaction; on admission the
        entry is placed in the pool (possibly displacing the cheapest).

        ``intrinsic`` is the function's schedule gas, ``fee`` the gas
        the sender actually offers (what the ledger will meter),
        ``reputation`` the sender's resolved modeled reputation."""
        spec = self.spec
        if fee < spec.fee_floor:
            return self._reject(ref, fn, sender, fee, at, "fee_floor")
        if spec.rep_gate != "off" and reputation < self.rep.r_min:
            if spec.rep_gate == "reject":
                return self._reject(ref, fn, sender, fee, at, "reputation")
            if fee < spec.rep_surcharge * intrinsic:
                return self._reject(ref, fn, sender, fee, at, "surcharge")
        if not self._take_token(sender, at):
            return self._reject(ref, fn, sender, fee, at, "rate_limited")
        evicted = None
        if self.pool.full:
            cheapest = self.pool.cheapest_fee()
            # strict >: an equal-fee arrival must not churn pooled peers
            if not spec.evict or cheapest is None or fee <= cheapest:
                return self._reject(ref, fn, sender, fee, at, "overloaded")
            evicted = self.pool.evict_cheapest()
            self.n_evicted += 1
        self.pool.place(PoolEntry(ref, fn, sender, int(fee), float(at)))
        self.n_admitted += 1
        self.log.append((ref, sender, fn, int(fee), float(at), "admitted"))
        return Decision(True, evicted=evicted)

    def _reject(self, ref: int, fn: str, sender: str, fee: int, at: float,
                reason: str) -> Decision:
        self.rejected[reason] += 1
        self.log.append((ref, sender, fn, int(fee), float(at), reason))
        return Decision(False, reason=reason)

    def counters(self) -> Dict[str, int]:
        out = {"admitted": self.n_admitted, "evicted": self.n_evicted}
        out.update({f"rejected_{k}": v for k, v in self.rejected.items()})
        return out
