"""Stdlib asyncio HTTP/1.1 front end for ``NodeService``.

One endpoint, JSON-RPC shaped: ``POST /rpc`` with a body of
``{"method": ..., "params": {...}, "id": ...}``; responses echo ``id``
and carry either ``result`` or ``error``.  ``GET /health`` answers
liveness probes.  No dependencies beyond asyncio + json on purpose —
the serving face must boot in the same minimal environments the rest of
the stack runs in.

Methods (docs/SERVING.md is the contract):

  submit        {fn, sender, fee?, at?}      -> {ref, status[, reason]}
  receipt       {ref}                        -> receipt record
  get_account   {address}                    -> AccountView fields
  state_root    {}                           -> {state_root}
  capabilities  {}                           -> {capabilities: [...]}
  events        {cursor?, kinds?, limit?}    -> {events, next_cursor,
                                                 dropped}
  flush         {}                           -> {status, flushed}
  metrics       {}                           -> live counters

Backpressure: an ``overloaded`` result (full writer queue, or a pool
rejection with reason ``overloaded``) is returned with HTTP status 429
so well-behaved clients can back off on the status code alone; every
other admission rejection is a 200 with the machine-readable reason —
the request was handled, the transaction was refused.
"""
from __future__ import annotations

import asyncio
import json
from typing import Any, Dict, Optional, Tuple

from repro.serve.service import NodeService

_MAX_BODY = 1 << 20          # 1 MiB: no submit needs more
_STATUS_TEXT = {200: "OK", 400: "Bad Request", 404: "Not Found",
                405: "Method Not Allowed", 413: "Payload Too Large",
                429: "Too Many Requests", 500: "Internal Server Error"}


def _overloaded(payload: Any) -> bool:
    return (isinstance(payload, dict)
            and (payload.get("error") == "overloaded"
                 or payload.get("reason") == "overloaded"))


class HttpNodeServer:
    """Serves one ``NodeService`` over HTTP (asyncio.start_server)."""

    def __init__(self, service: NodeService, host: Optional[str] = None,
                 port: Optional[int] = None):
        self.service = service
        self.host = host if host is not None else service.spec.host
        self.port = port if port is not None else service.spec.port
        self._server: Optional[asyncio.base_events.Server] = None

    async def start(self) -> Tuple[str, int]:
        """Start service + listener; returns the bound (host, port)
        (pass ``port=0`` to bind an ephemeral port)."""
        await self.service.start()
        self._server = await asyncio.start_server(
            self._handle, self.host, self.port)
        sock = self._server.sockets[0].getsockname()
        self.host, self.port = sock[0], sock[1]
        return self.host, self.port

    async def close(self) -> None:
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
            self._server = None
        await self.service.close()

    async def serve_forever(self) -> None:
        await self.start()
        assert self._server is not None
        async with self._server:
            await self._server.serve_forever()

    # -- one connection ---------------------------------------------------------
    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        try:
            while True:
                request = await self._read_request(reader)
                if request is None:
                    break
                method, path, headers, body = request
                status, payload = await self._route(method, path, body)
                keep = headers.get("connection", "keep-alive") != "close"
                await self._respond(writer, status, payload, keep)
                if not keep:
                    break
        except (ConnectionError, asyncio.IncompleteReadError):
            pass
        finally:
            writer.close()
            try:
                await writer.wait_closed()
            except ConnectionError:
                pass

    async def _read_request(self, reader: asyncio.StreamReader):
        line = await reader.readline()
        if not line:
            return None
        parts = line.decode("latin-1").split()
        if len(parts) < 2:
            return None
        method, path = parts[0].upper(), parts[1]
        headers: Dict[str, str] = {}
        while True:
            raw = await reader.readline()
            if raw in (b"\r\n", b"\n", b""):
                break
            k, _, v = raw.decode("latin-1").partition(":")
            headers[k.strip().lower()] = v.strip()
        n = int(headers.get("content-length", "0") or "0")
        if n > _MAX_BODY:
            return method, path, headers, None
        body = await reader.readexactly(n) if n else b""
        return method, path, headers, body

    async def _route(self, method: str, path: str,
                     body: Optional[bytes]) -> Tuple[int, Any]:
        if body is None:
            return 413, {"error": "payload too large"}
        if method == "GET" and path == "/health":
            return 200, {"ok": True}
        if path != "/rpc":
            return 404, {"error": f"unknown path {path!r}"}
        if method != "POST":
            return 405, {"error": "POST /rpc only"}
        try:
            req = json.loads(body.decode("utf-8") or "{}")
            name = req["method"]
            params = req.get("params", {}) or {}
            if not isinstance(params, dict):
                raise TypeError("params must be an object")
        except (ValueError, KeyError, TypeError) as err:
            return 400, {"error": f"bad request: {err}"}
        try:
            result = await self._dispatch(name, params)
        except (TypeError, ValueError, KeyError) as err:
            return 400, {"id": req.get("id"),
                         "error": f"{type(err).__name__}: {err}"}
        status = 429 if _overloaded(result) else 200
        return status, {"id": req.get("id"), "result": result}

    async def _dispatch(self, name: str, p: Dict[str, Any]) -> Any:
        svc = self.service
        if name == "submit":
            return await svc.submit(p["fn"], p["sender"],
                                    fee=p.get("fee"), at=p.get("at"))
        if name == "receipt":
            return svc.receipt(int(p["ref"]))
        if name == "get_account":
            return svc.get_account(p["address"])
        if name == "state_root":
            return {"state_root": svc.state_root()}
        if name == "capabilities":
            return {"capabilities": svc.capabilities()}
        if name == "events":
            limit = p.get("limit")
            return svc.events(cursor=int(p.get("cursor", 0)),
                              kinds=p.get("kinds"),
                              limit=None if limit is None else int(limit))
        if name == "flush":
            return await svc.finalize()
        if name == "metrics":
            return svc.stats()
        raise ValueError(f"unknown method {name!r}")

    async def _respond(self, writer: asyncio.StreamWriter, status: int,
                       payload: Any, keep: bool) -> None:
        body = json.dumps(payload, default=str).encode("utf-8")
        head = (f"HTTP/1.1 {status} {_STATUS_TEXT.get(status, 'OK')}\r\n"
                f"Content-Type: application/json\r\n"
                f"Content-Length: {len(body)}\r\n"
                f"Connection: {'keep-alive' if keep else 'close'}\r\n"
                f"\r\n").encode("latin-1")
        writer.write(head + body)
        await writer.drain()


async def http_rpc(host: str, port: int, method: str,
                   params: Optional[Dict[str, Any]] = None,
                   req_id: int = 1) -> Tuple[int, Any]:
    """Minimal asyncio HTTP client for one RPC call — the test suite,
    quickstart and load harness drive the real wire format with it.
    Returns ``(http_status, parsed_body)``."""
    reader, writer = await asyncio.open_connection(host, port)
    try:
        body = json.dumps({"method": method, "params": params or {},
                           "id": req_id}).encode("utf-8")
        writer.write((f"POST /rpc HTTP/1.1\r\nHost: {host}\r\n"
                      f"Content-Length: {len(body)}\r\n"
                      f"Connection: close\r\n\r\n").encode("latin-1")
                     + body)
        await writer.drain()
        raw = await reader.read()
    finally:
        writer.close()
        try:
            await writer.wait_closed()
        except ConnectionError:
            pass
    head, _, payload = raw.partition(b"\r\n\r\n")
    status = int(head.split(b" ", 2)[1])
    return status, json.loads(payload.decode("utf-8"))
