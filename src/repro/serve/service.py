"""The concurrent node service: one stack, one writer, many clients.

``NodeService`` fronts a single ``repro.api`` stack (one ``build_stack``
per process, owned by a ``NodeClient``) and serializes every ledger
mutation through ONE asyncio writer task: submissions from any number of
concurrent clients funnel into a bounded op queue, the writer applies
them in arrival order, and because the ledger operations themselves
never await, each op is atomic under cooperative scheduling — the
fused/stepped semantics and state roots are exactly the single-threaded
ones.  Reads (receipts, accounts, events, state root) are served
directly on the event loop for the same reason.

Admission happens in the writer, ahead of the ledger (repro/serve/
admission.py): admitted transactions collect in the ``PendingPool`` and
are flushed to the ledger in (modeled-time, ref) order at every
``ServeSpec.window`` boundary the modeled clock crosses — drain pool ->
seal -> ``run_until`` the boundary.  A full op queue is the
backpressure signal: the submit gets an explicit ``overloaded`` reply
(HTTP 429 at the serving edge) instead of unbounded buffering.

Determinism contract (pinned by tests/test_serve.py): the service
records an op log — the exact batches it flushed plus every
seal/run_until/flush — and ``replay_ops`` replaying that log serially
through a fresh ``NodeClient`` reproduces the same final state root and
gas totals, on the vector and fabric backends alike.  Concurrency
changes WHICH transactions are admitted (the admission log says which),
never what the admitted history computes.
"""
from __future__ import annotations

import asyncio
import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.api.client import NodeClient
from repro.api.specs import NodeSpec, ServeSpec
from repro.core.gas import L1_DEFAULT_GAS
from repro.serve.admission import AdmissionController, PoolEntry

#: ops the writer understands / the op log records
_OPS = ("batch", "seal", "run_until", "flush")


@dataclasses.dataclass
class ServeMetrics:
    """Live counters the metrics endpoint reports."""

    submitted: int = 0
    flushed: int = 0                 # txs committed to the ledger
    windows: int = 0
    queue_rejections: int = 0        # op-queue backpressure 429s


class NodeService:
    """One served node: admission + single-writer ledger loop."""

    def __init__(self, spec: ServeSpec,
                 client: Optional[NodeClient] = None):
        self.spec = spec
        self.client = client if client is not None \
            else NodeClient.from_spec(spec.node)
        log = self.client._event_log()
        if spec.event_cap is not None:
            log.cap = spec.event_cap
        self.admission = AdmissionController(
            spec.admission, spec.node.reputation)
        self.metrics = ServeMetrics()
        # ref -> {"status": queued|evicted|rejected|submitted, ...}
        self.receipts: Dict[int, Dict[str, Any]] = {}
        self._ledger_receipts: Dict[int, Any] = {}      # ref -> TxReceipt
        self._next_ref = 0
        self._clock = 0.0                # modeled time, high-water
        self._next_window = spec.window
        self.ops: List[Tuple] = []       # the replayable op log
        self._queue: Optional[asyncio.Queue] = None
        self._writer: Optional[asyncio.Task] = None

    # -- lifecycle --------------------------------------------------------------
    async def start(self) -> "NodeService":
        if self._queue is None:
            self._queue = asyncio.Queue(maxsize=self.spec.queue_cap)
        if self._writer is None:
            self._writer = asyncio.get_running_loop().create_task(
                self._writer_loop())
        return self

    async def close(self) -> None:
        """Flush everything pending and stop the writer."""
        await self.finalize()
        if self._writer is not None:
            self._writer.cancel()
            try:
                await self._writer
            except asyncio.CancelledError:
                pass
            self._writer = None

    async def finalize(self) -> Dict[str, Any]:
        """Commit the pool, settle the open session and drain the
        modeled prover past the last submission (recorded in the op
        log, so replays settle identically)."""
        return await self._enqueue(("finalize",))

    # -- the single writer ------------------------------------------------------
    async def _enqueue(self, op: Tuple) -> Any:
        if self._queue is None:
            await self.start()
        fut = asyncio.get_running_loop().create_future()
        try:
            self._queue.put_nowait((op, fut))
        except asyncio.QueueFull:
            self.metrics.queue_rejections += 1
            return {"error": "overloaded", "detail": "op queue full"}
        return await fut

    async def _writer_loop(self) -> None:
        while True:
            op, fut = await self._queue.get()
            try:
                if op[0] == "submit":
                    out = self._do_submit(*op[1:])
                elif op[0] == "finalize":
                    out = self._do_finalize()
                else:
                    raise ValueError(f"unknown writer op {op[0]!r}")
                if not fut.done():
                    fut.set_result(out)
            except Exception as err:               # surface, don't kill loop
                if not fut.done():
                    fut.set_exception(err)

    # -- submission path --------------------------------------------------------
    def _stamp(self, at: Optional[float]) -> float:
        if at is None:
            self._clock += 0.01
            return self._clock
        self._clock = max(self._clock, float(at))
        return float(at)

    def _intrinsic(self, fn: str) -> int:
        return int(self.client.gas_table.l1_per_call.get(fn,
                                                         L1_DEFAULT_GAS))

    def _reputation(self, sender: str) -> float:
        """Sender's modeled reputation: the on-ledger value once any
        reputation event touched the account, the newcomer prior
        ``r_init`` before that (paper: newcomers start above r_min)."""
        acct = self.client.get_account(sender)
        if acct.account_id is None or acct.rep_events == 0:
            return float(self.spec.node.reputation.r_init)
        return float(acct.reputation)

    async def submit(self, fn: str, sender: str, fee: Optional[int] = None,
                     at: Optional[float] = None) -> Dict[str, Any]:
        """Admission-checked submit; returns a JSON-shaped summary with
        the tx ``ref`` to poll (or the rejection reason)."""
        return await self._enqueue(("submit", fn, sender, fee, at))

    def _do_submit(self, fn: str, sender: str, fee: Optional[int],
                   at: Optional[float]) -> Dict[str, Any]:
        t = self._stamp(at)
        ref = self._next_ref
        self._next_ref += 1
        self.metrics.submitted += 1
        intrinsic = self._intrinsic(fn)
        offered = intrinsic if fee is None else int(fee)
        decision = self.admission.admit(
            ref=ref, fn=fn, sender=sender, fee=offered,
            intrinsic=intrinsic, at=t, reputation=self._reputation(sender))
        if decision.admitted:
            self.receipts[ref] = {"status": "queued", "fn": fn,
                                  "sender": sender, "fee": offered, "at": t}
            if decision.evicted is not None:
                self.receipts[decision.evicted] = {
                    "status": "evicted",
                    "detail": "displaced by a higher-fee arrival at pool "
                              "cap"}
            out = {"ref": ref, "status": "queued"}
        else:
            self.receipts[ref] = {"status": "rejected",
                                  "reason": decision.reason}
            out = {"ref": ref, "status": "rejected",
                   "reason": decision.reason}
        self._roll_windows()
        return out

    # -- window flushing --------------------------------------------------------
    def _roll_windows(self) -> None:
        while self._clock >= self._next_window:
            boundary = self._next_window
            self._commit_pool()
            self.client.seal()
            self.ops.append(("seal",))
            self.client.run_until(boundary)
            self.ops.append(("run_until", boundary))
            self.metrics.windows += 1
            self._next_window = boundary + self.spec.window

    def _commit_pool(self) -> None:
        entries = self.admission.pool.drain()
        if not entries:
            return
        receipts = self._submit_entries(entries)
        self.ops.append(("batch", [(e.fn, e.sender, e.fee, e.at)
                                   for e in entries]))
        for e, r in zip(entries, receipts):
            self._ledger_receipts[e.ref] = r
            self.receipts[e.ref] = {"status": "submitted"}
        self.metrics.flushed += len(entries)

    def _submit_entries(self, entries: List[PoolEntry]):
        target = self.client.target
        if getattr(target, "soa_native", False):
            from repro.core.engine import TxArrays
            batch = TxArrays(
                np.array([e.at for e in entries], np.float64),
                np.array([e.fee for e in entries], np.int64),
                np.array([target.fns.id(e.fn) for e in entries], np.int32),
                np.array([target.sender_id(e.sender) for e in entries],
                         np.int32),
                target.fns)
            receipts = self.client.submit_arrays(batch)
            for e, r in zip(entries, receipts):
                r.sender = e.sender        # real addresses, not acct labels
            return receipts
        return [self.client.submit(e.fn, e.sender, gas=e.fee, at=e.at)
                for e in entries]

    def _do_finalize(self) -> Dict[str, Any]:
        self._commit_pool()
        self.client.flush()
        self.ops.append(("flush",))
        block_time = self.spec.node.chain.block_time
        t_end = self._clock + 2.0 * block_time
        self.client.run_until(t_end)
        self.ops.append(("run_until", t_end))
        return {"status": "finalized", "flushed": self.metrics.flushed}

    # -- read path (direct: ledger reads never await) ---------------------------
    def receipt(self, ref: int) -> Dict[str, Any]:
        rec = self.receipts.get(ref)
        if rec is None:
            return {"error": "unknown ref", "ref": ref}
        if rec.get("status") != "submitted":
            return {"ref": ref, **rec}
        rcpt = self.client.refresh(self._ledger_receipts[ref])
        d = dataclasses.asdict(rcpt)
        d.pop("tx", None)                     # object handle, not JSON
        return {"ref": ref, **d}

    def get_account(self, addr: str) -> Dict[str, Any]:
        return dataclasses.asdict(self.client.get_account(addr))

    def state_root(self) -> str:
        return self.client.state_root()

    def capabilities(self) -> List[str]:
        return sorted(self.client.capabilities())

    def events(self, cursor: int = 0, kinds=None,
               limit: Optional[int] = None) -> Dict[str, Any]:
        evs, next_cursor, n_dropped = self.client.events_page(
            cursor, kinds=kinds, limit=limit)
        return {"events": [{"kind": e.kind, **dataclasses.asdict(e)}
                           for e in evs],
                "next_cursor": next_cursor, "dropped": n_dropped}

    def stats(self) -> Dict[str, Any]:
        out = dataclasses.asdict(self.metrics)
        out.update(self.admission.counters())
        out["pool_depth"] = len(self.admission.pool)
        out["clock"] = self._clock
        return out


def replay_ops(node_spec: NodeSpec, ops: List[Tuple]) -> NodeClient:
    """Replay a service op log serially through a fresh ``NodeClient``.

    The equivalence oracle: submits every recorded batch one transaction
    at a time (no batching, no concurrency) and repeats the recorded
    seal/run_until/flush schedule; the resulting state root and gas
    totals must match the served stack's (tests/test_serve.py pins it on
    the vector and fabric backends)."""
    client = NodeClient.from_spec(node_spec)
    for op in ops:
        if op[0] == "batch":
            for fn, sender, fee, at in op[1]:
                client.submit(fn, sender, gas=fee, at=at)
        elif op[0] == "seal":
            client.seal()
        elif op[0] == "run_until":
            client.run_until(op[1])
        elif op[0] == "flush":
            client.flush()
        else:
            raise ValueError(f"unknown op {op[0]!r} in op log")
    return client
