"""Sharding policies: map params / activations / caches onto the mesh.

Axis conventions (launch/mesh.py):
  single-pod : (16, 16)      -> ("data", "model")
  multi-pod  : (2, 16, 16)   -> ("pod", "data", "model")
  ledger     : (K,)          -> ("shard",)   [make_shard_mesh]

Policies:
  DP    batch over ("pod","data")        (FL trainers = data-axis groups)
  FSDP  params / opt state over "data"
  TP    matmul contract/output dims over "model"
  EP    MoE experts over "model"
  SP    residual-stream seq dim over "model" (big archs)
  KV-SP decode KV-cache seq dim over "model"
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


#: the ledger fabric's 1-D mesh axis (launch/mesh.make_shard_mesh): K
#: shard lanes as rows, one contiguous row block per device
SHARD_LANE_AXIS = "shard"


def shard_lane_spec() -> P:
    """Partition spec for ``(K, W)`` shard-lane SoA buffers
    (kernels/shard_lanes.py): lane rows over the ``"shard"`` axis, the
    per-lane word/segment dim replicated — each device folds its own
    lanes with no cross-device collectives."""
    return P(SHARD_LANE_AXIS, None)


def shard_lane_sharding(mesh) -> NamedSharding:
    """NamedSharding form of ``shard_lane_spec`` for donated buffers."""
    return NamedSharding(mesh, shard_lane_spec())


class MeshCtx:
    """Carries the mesh + the architecture's ShardingPolicy.

    When ``mesh is None`` every helper degrades to a no-op so the same model
    code runs in single-device smoke tests.
    """

    def __init__(self, mesh: Optional[jax.sharding.Mesh], policy):
        self.mesh = mesh
        self.policy = policy
        if mesh is not None:
            names = mesh.axis_names
            self.has_pod = "pod" in names
            self.dp_axes = ("pod", "data") if self.has_pod else ("data",)
            self.fsdp_axis = "data" if policy.fsdp else None
            self.tp_axis = "model" if policy.tensor_parallel else None
            self.ep_axis = "model" if policy.expert_parallel else None
            self.sp_axis = "model" if policy.sequence_parallel else None
            self.model_size = mesh.shape["model"]
            self.data_size = mesh.shape["data"]
        else:
            self.has_pod = False
            self.dp_axes = ()
            self.fsdp_axis = self.tp_axis = self.ep_axis = self.sp_axis = None
            self.model_size = self.data_size = 1

    # -- helpers -------------------------------------------------------------
    def sharding(self, spec: P) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, spec)

    def constrain(self, x, spec: P):
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, spec))

    # -- activation specs ----------------------------------------------------
    def act_btd(self, x):
        """Residual stream (B, S, d): DP on batch, SP on seq if enabled."""
        return self.constrain(x, P(self.dp_axes or None, self.sp_axis, None))

    def act_heads(self, x):
        """Per-head activations (B, S, H, dh): TP on heads."""
        return self.constrain(x, P(self.dp_axes or None, None, self.tp_axis, None))

    def act_ffn(self, x):
        """FFN hidden (B, S, ff): TP on ff."""
        return self.constrain(x, P(self.dp_axes or None, None, self.tp_axis))

    def logits(self, x):
        """LM logits (B, S, V): vocab over model (keeps 150k-vocab local)."""
        return self.constrain(x, P(self.dp_axes or None, None, self.tp_axis))

    # -- batch specs -----------------------------------------------------------
    def batch_spec(self) -> P:
        return P(self.dp_axes or None)

    def kv_cache_spec(self) -> P:
        """(B, S, Hkv, dh) — batch over DP; seq over model if kv_seq_shard."""
        if self.policy.kv_seq_shard:
            return P(self.dp_axes or None, "model" if self.mesh is not None else None,
                     None, None)
        return P(self.dp_axes or None, None, self.tp_axis, None)


# -----------------------------------------------------------------------------
# Parameter partition rules.  Params are nested dicts; leaves are stacked with
# a leading period dim (never sharded).  Rules match on the leaf's path names.
# -----------------------------------------------------------------------------
def param_spec(ctx: MeshCtx, path: tuple, shape: tuple) -> P:
    """PartitionSpec for one parameter leaf given its tree path."""
    if ctx.mesh is None:
        return P()
    fsdp, tp = ctx.fsdp_axis, ctx.tp_axis
    name = path[-1]
    joined = "/".join(str(p) for p in path)
    stacked = "periods" in joined or "enc_periods" in joined
    lead = (None,) if stacked else ()

    def spec(*dims):
        out = lead + tuple(dims)
        assert len(out) == len(shape), (joined, shape, out)
        return P(*out)

    ndim = len(shape) - len(lead)

    # embeddings ------------------------------------------------------------
    if name == "table":            # (V, d) input embedding
        return P(tp, fsdp)
    if name == "head_w":           # (d, V) output head
        return P(fsdp, tp)
    if name in ("pos", "dec_pos"):  # learned positions (S, d)
        return P(None, fsdp)

    # norms / biases / small vectors -----------------------------------------
    if ndim == 1:
        return spec(None)

    # MoE expert stacks (E, d, f) / (E, f, d) ---------------------------------
    if name in ("moe_wg", "moe_wu"):   # (E, d, ff_e)
        return spec(ctx.ep_axis, fsdp, None)
    if name == "moe_wo":               # (E, ff_e, d)
        return spec(ctx.ep_axis, None, fsdp)
    if name == "router":               # (d, E)
        return spec(fsdp, None)

    # attention --------------------------------------------------------------
    if name in ("wq", "wk", "wv"):     # (d, H*dh)
        return spec(fsdp, tp)
    if name == "wo":                   # (H*dh, d)
        return spec(tp, fsdp)

    # dense mlp ---------------------------------------------------------------
    if name in ("wi_gate", "wi_up"):   # (d, ff)
        return spec(fsdp, tp)
    if name == "w_down":               # (ff, d)
        return spec(tp, fsdp)

    # mamba -------------------------------------------------------------------
    if name == "in_proj":              # (d, 2*di)
        return spec(fsdp, tp)
    if name == "out_proj":             # (di, d)
        return spec(tp, fsdp)
    if name in ("x_dt", "x_B", "x_C"):  # (di, r/ds)
        return spec(tp, None)
    if name == "dt_proj":              # (r, di)
        return spec(None, tp)
    if name in ("A_log", "conv_w"):    # (di, ds) / (di, k)
        return spec(tp, None)

    # xLSTM -------------------------------------------------------------------
    if name == "up_proj":              # (d, 2*di)
        return spec(fsdp, tp)
    if name == "down_proj":            # (di, d)
        return spec(tp, fsdp)
    if name in ("m_wq", "m_wk", "m_wv"):  # (nh, dh, dh) block-diag per head
        return spec(tp, None, None) if shape[len(lead)] % max(ctx.model_size, 1) == 0 \
            else spec(None, tp, None)
    if name in ("w_gates",):           # (d, n*d) sLSTM input gates
        return spec(fsdp, tp)
    if name == "r_gates":              # (nh, dh, 4*dh) sLSTM recurrent
        return spec(None, None, tp)
    if name in ("ff_up",):             # (d, dff)
        return spec(fsdp, tp)
    if name == "ff_down":              # (dff, d)
        return spec(tp, fsdp)

    # conv / lenet / fallback ---------------------------------------------------
    if ndim == 2:
        return spec(fsdp, tp)
    return P(*([None] * len(shape)))


def sanitize_spec(mesh, spec: P, shape) -> P:
    """Drop spec entries whose mesh-axis product doesn't divide the dim.

    pjit rejects *argument* shardings with non-divisible dims (unlike
    internal with_sharding_constraint, which pads).  Centralised here so
    nh=4-over-16-TP, vocab=51865, B=1-decode etc. degrade to replication
    instead of erroring.
    """
    if mesh is None:
        return spec
    sizes = dict(mesh.shape)
    entries = tuple(spec) + (None,) * (len(shape) - len(spec))
    out = []
    for dim, entry in zip(shape, entries):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        prod = 1
        for a in axes:
            prod *= sizes[a]
        out.append(entry if dim % prod == 0 else None)
    return P(*out)


def sanitize_pspec_tree(mesh, pspec_tree, shape_tree):
    return jax.tree.map(
        lambda s, l: sanitize_spec(mesh, s, l.shape), pspec_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, P))


def state_spec(ctx: MeshCtx, path: tuple, shape: tuple) -> P:
    """PartitionSpec for a decode-state leaf (leading stacked layer dim)."""
    if ctx.mesh is None:
        return P()
    dp, tp = (ctx.dp_axes or None), ctx.tp_axis
    name = str(path[-1])
    kv_seq = "model" if ctx.policy.kv_seq_shard else None
    table = {
        "k": P(None, dp, kv_seq, None, None),
        "v": P(None, dp, kv_seq, None, None),
        "ek": P(None, dp, None, None, None),
        "ev": P(None, dp, None, None, None),
        "conv": P(None, dp, None, tp),
        "ssm": P(None, dp, tp, None),
        "C": P(None, dp, None, tp, None),
        "n": P(None, dp, None, tp),
        "m": P(None, dp, None),
        "h": P(None, dp, tp),
        "c": P(None, dp, tp),
        "nn": P(None, dp, tp),
        "mm": P(None, dp, tp),
    }
    spec = table.get(name)
    if spec is None or len(spec) != len(shape):
        return P(*([None] * len(shape)))
    return spec


def state_pspec_tree(ctx: MeshCtx, state_shape):
    def _walk(path, node):
        if isinstance(node, dict):
            return {k: _walk(path + (k,), v) for k, v in node.items()}
        return state_spec(ctx, path, node.shape)
    return _walk((), state_shape)


def params_pspec_tree(ctx: MeshCtx, params_shape):
    """Pytree of PartitionSpecs matching a params shape-tree."""
    def _walk(path, node):
        if isinstance(node, dict):
            return {k: _walk(path + (k,), v) for k, v in node.items()}
        return param_spec(ctx, path, node.shape)
    return _walk((), params_shape)


def params_sharding_tree(ctx: MeshCtx, params_shape):
    if ctx.mesh is None:
        return None
    return jax.tree.map(lambda s: NamedSharding(ctx.mesh, s),
                        params_pspec_tree(ctx, params_shape),
                        is_leaf=lambda x: isinstance(x, P))
