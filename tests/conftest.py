"""Shared test config.

hypothesis is a dev-extra (requirements-dev.txt); a fresh checkout without
it must not fail collection (the seed repo died with ModuleNotFoundError
before running a single test).  Modules that use hypothesis fall back to
these stubs, which skip ONLY the property tests — every example-based test
in the same module still runs.  CI installs hypothesis, so nothing is
skipped there.
"""
import pytest

try:
    import hypothesis  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def given(*_a, **_k):
    """Stand-in for hypothesis.given: replaces the test with a skip."""
    def deco(_f):
        def _skipper():
            pytest.skip("hypothesis not installed (see requirements-dev.txt)")
        _skipper.__name__ = _f.__name__
        _skipper.__doc__ = _f.__doc__
        return _skipper
    return deco


def settings(*_a, **_k):
    """Stand-in for hypothesis.settings: identity decorator."""
    return lambda f: f


class _Strategies:
    """Stand-in for hypothesis.strategies: any strategy constructor resolves
    to an inert placeholder (never drawn from — the test is skipped)."""

    def __getattr__(self, name):
        return lambda *a, **k: None


st = _Strategies()
