"""R001 fixture: StateArrays column writes with no mark_dirty pairing."""
import numpy as np


def credit(state, ids, amount):
    state.balances[ids] += amount           # store without mark_dirty
    np.add.at(state.submissions, ids, 1)    # scatter without mark_dirty
    return state
