"""R002 fixture: a kernel op registered without the full impl family."""


def register_kernel(op, impl, fn, **kw):
    """Stand-in with the factory's signature; the rule is AST-driven."""


def _impl_jax(x):
    return x


register_kernel("frobnicate_fold", "jax", _impl_jax)
