"""R003 fixture: nondeterminism on the fused replay path."""
import time


class FusedWindowLoop:
    """Name-seeds the determinism sweep, like the real loop."""

    def execute(self, jobs):
        start = time.time()                             # wall clock
        order = {id(j): i for i, j in enumerate(jobs)}  # id()-keyed dict
        return start, order
