"""R004 fixture: host syncs / traced branching / donated-buffer reuse."""
import jax


@jax.jit
def traced_step(x):
    if x > 0:                   # Python branch on a traced value
        x = x + 1
    return x.item()             # host sync inside the traced function


step2 = jax.jit(lambda y: y * 2.0, donate_argnums=(0,))


def run(buf):
    out = step2(buf)
    return out + buf            # buf was donated to step2
