"""R005 fixture: EventLog internals mutated outside core/events.py."""


def rewrite_history(log, ev):
    log._events.append(ev)              # direct append past the log
    evs = log._events
    evs[:] = evs[:-1]                   # alias mutation
    object.__setattr__(ev, "seq", 0)    # renumbering a frozen event
