"""Known-bad fixture: R008 — the admission path reads the wall clock.

The token-bucket refill below uses ``time.time()`` instead of the
transaction's modeled submit time, so the admitted set depends on host
scheduling and the recorded admission log stops replaying."""
import time


class AdmissionController:
    def __init__(self, rate, burst):
        self.rate, self.burst = rate, burst
        self.tokens, self.last = burst, 0.0

    def admit(self, fee):
        now = time.time()                     # wall clock in a decision
        self.tokens = min(self.burst,
                          self.tokens + (now - self.last) * self.rate)
        self.last = now
        if self.tokens < 1.0:
            return False
        self.tokens -= 1.0
        return fee > 0
