"""Aggregation (Eq. 1) + rollup engine: equivalence and integrity tests."""
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # degrade: property tests skip, the rest still run
    from conftest import given, settings, st  # noqa: F401

from repro.core.aggregation import (weighted_average_flat,
                                    weighted_average_tree)
from repro.core.gas import ROLLUP_BATCH, l1_gas, l2_gas
from repro.core.ledger import Chain, Tx
from repro.core.rollup import BatchProof, Rollup, state_digest


# -- Eq. 1 -----------------------------------------------------------------------
@settings(max_examples=50, deadline=None)
@given(st.integers(1, 8), st.integers(1, 64))
def test_weighted_average_properties(n, p):
    rng = np.random.default_rng(n * 100 + p)
    w = jnp.asarray(rng.normal(size=(n, p)), jnp.float32)
    s = jnp.asarray(rng.uniform(0.01, 1.0, n), jnp.float32)
    out = weighted_average_flat(w, s)
    # convexity: within [min, max] per coordinate
    assert np.all(np.asarray(out) <= np.asarray(jnp.max(w, 0)) + 1e-5)
    assert np.all(np.asarray(out) >= np.asarray(jnp.min(w, 0)) - 1e-5)
    # scale invariance of scores
    out2 = weighted_average_flat(w, s * 7.3)
    np.testing.assert_allclose(out, out2, rtol=1e-5, atol=1e-6)


def test_weighted_average_equal_scores_is_fedavg():
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(4, 33)), jnp.float32)
    out = weighted_average_flat(w, jnp.ones(4))
    np.testing.assert_allclose(out, jnp.mean(w, 0), rtol=1e-6)


def test_weighted_average_tree_matches_flat():
    rng = np.random.default_rng(1)
    tree = {"a": jnp.asarray(rng.normal(size=(3, 4, 5)), jnp.float32),
            "b": {"c": jnp.asarray(rng.normal(size=(3, 7)), jnp.float32)}}
    s = jnp.array([0.2, 0.5, 0.9])
    out = weighted_average_tree(tree, s)
    want_a = weighted_average_flat(tree["a"].reshape(3, -1), s).reshape(4, 5)
    np.testing.assert_allclose(out["a"], want_a, rtol=1e-6)


def test_pallas_agg_matches_xla_tree_path():
    rng = np.random.default_rng(2)
    tree = {"w": jnp.asarray(rng.normal(size=(5, 300)), jnp.float32)}
    s = jnp.asarray(rng.uniform(0.1, 1, 5), jnp.float32)
    a = weighted_average_tree(tree, s, use_pallas=False)
    b = weighted_average_tree(tree, s, use_pallas=True)
    np.testing.assert_allclose(a["w"], b["w"], rtol=1e-5, atol=1e-6)


# -- rollup engine ------------------------------------------------------------------
def _mk_rollup(batch=ROLLUP_BATCH):
    chain = Chain()
    ru = Rollup(chain, batch_size=batch)
    return chain, ru


def test_rollup_state_equals_sequential_l1():
    """Replaying the same txs through L1 directly and through the rollup
    must produce the same final contract state (zk-rollup soundness)."""
    def handler(state, tx):
        state.setdefault("count", 0)
        state["count"] += 1
        state.setdefault("by_sender", {})
        state["by_sender"][tx.sender] = \
            state["by_sender"].get(tx.sender, 0) + tx.payload.get("v", 1)

    chain1 = Chain()
    chain1.register("f", handler)
    chain2, ru = _mk_rollup(batch=8)
    ru.register("f", handler)
    txs = [Tx("f", f"s{i % 3}", {"v": i}, 1000, i * 0.01) for i in range(30)]
    for t in txs:
        chain1.submit(t)
        ru.submit(t)
    chain1.run_until(10.0)
    ru.flush()
    assert state_digest(chain1.state) == state_digest(ru.state)


def test_batch_proof_verifies_and_rejects_tamper():
    chain, ru = _mk_rollup(batch=4)
    def handler(state, tx):
        state["x"] = state.get("x", 0) + 1
    ru.register("f", handler)
    pre = dict(ru.state)
    for i in range(4):
        ru.submit(Tx("f", "s", {}, 10, i * 0.1))
    proof = ru.batches[-1]
    def replay(s):
        for _ in range(4):
            handler(s, None)
        return s
    assert proof.verify(dict(pre), replay)
    bad = BatchProof(proof.batch_id, proof.n_txs, proof.pre_root,
                     "deadbeef" * 4, proof.tx_root)
    assert not bad.verify(dict(pre), replay)


def test_rollup_gas_reduction_headline():
    """Live engine reproduces the paper's 'up to 20x' at 100 publishTask."""
    chain, ru = _mk_rollup()
    for i in range(100):
        ru.submit(Tx("publishTask", f"p{i}", {}, 0, i * 0.01))
    ru.flush()
    live_l2 = sum(b["total"] for b in ru.gas_log)
    assert l1_gas("publishTask", 100) / live_l2 > 20


def test_rollup_batch_boundaries():
    chain, ru = _mk_rollup(batch=20)
    for i in range(50):
        ru.submit(Tx("submitLocalModel", "s", {}, 0, i * 0.01))
    ru.flush()
    assert [b.n_txs for b in ru.batches] == [20, 20, 10]
    assert l2_gas("submitLocalModel", 50)["batches"] == 3
