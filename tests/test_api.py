"""Public node API tests (src/repro/api/).

Pins the PR-4 contracts:
  * ``build_ledger`` maps every spec combination to the right backend and
    rejects invalid combinations;
  * spec-built protocol nodes are EQUIVALENT to the legacy kwarg path on
    every backend (same state root, same gas totals, same outputs);
  * ``TxReceipt`` gas equals the ledger's accounted gas — the per-batch
    breakdown matches ``gas_log`` rows and the amortized per-tx shares
    sum back to the total;
  * receipts on a 1-shard ``ShardedRollup`` match ``VectorRollup``
    receipts bit-for-bit (extends the PR-3 equivalence pins);
  * event subscriptions fire for sealed batches / settled sessions /
    fabric windows;
  * the deprecation shim still accepts the old kwargs (with a warning).
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (ChainSpec, DONSpec, FLTaskSpec, NodeClient, NodeSpec,
                       ReputationSpec, RollupSpec, ShardSpec, WorkloadSpec,
                       build_ledger, l1_of, preset)
from repro.core.engine import VectorChain, VectorRollup
from repro.core.ledger import Chain, LedgerBackend, simulate_load
from repro.core.rollup import Rollup
from repro.core.shards import ShardedRollup

GAS_KEYS = ("n_txs", "commit", "verify", "execute", "total")


# -- factory mapping -----------------------------------------------------------
def test_build_ledger_maps_specs_to_backends():
    assert isinstance(build_ledger(NodeSpec(rollup=None)), VectorChain)
    assert isinstance(build_ledger(ChainSpec(backend="object")), Chain)
    assert isinstance(build_ledger(NodeSpec()), VectorRollup)
    obj = build_ledger(NodeSpec(chain=ChainSpec(backend="object")))
    assert isinstance(obj, Rollup)
    fab = build_ledger(NodeSpec(shards=ShardSpec(count=2)))
    assert isinstance(fab, ShardedRollup) and fab.n_shards == 2
    one = build_ledger(NodeSpec(shards=ShardSpec(count=1, fabric=True)))
    assert isinstance(one, ShardedRollup) and one.n_shards == 1
    plain = build_ledger(NodeSpec(shards=ShardSpec(count=1)))
    assert isinstance(plain, VectorRollup)
    # every face satisfies the one LedgerBackend protocol
    for backend in (obj, fab, one, plain):
        assert isinstance(backend, LedgerBackend)
        assert l1_of(backend) is backend.l1


def test_spec_validation_rejects_bad_combinations():
    with pytest.raises(ValueError):
        ChainSpec(backend="quantum")
    with pytest.raises(ValueError):
        ShardSpec(count=0)
    with pytest.raises(ValueError):
        NodeSpec(chain=ChainSpec(backend="object"),
                 shards=ShardSpec(count=2))
    with pytest.raises(ValueError):
        NodeSpec(rollup=None, shards=ShardSpec(count=2))
    # object Rollup has no lanes/digest routing: reject, don't drop
    with pytest.raises(ValueError):
        NodeSpec(chain=ChainSpec(backend="object"),
                 rollup=RollupSpec(n_lanes=8))
    with pytest.raises(KeyError):
        preset("no-such-preset")


def test_rollup_spec_fields_reach_the_backend():
    spec = NodeSpec(chain=ChainSpec(block_time=0.5, block_gas_limit=10**6),
                    rollup=RollupSpec(batch_size=7, n_lanes=3))
    ru = build_ledger(spec)
    assert ru.batch_size == 7 and ru.n_lanes == 3
    assert ru.l1.block_time == 0.5 and ru.l1.block_gas_limit == 10**6


def test_workload_spec_is_make_workload_as_data():
    from repro.core.workloads import make_workload
    ws = WorkloadSpec.make("bursty", 50.0, duration=5.0, seed=3)
    a, b = ws.build(), make_workload("bursty", 50.0, duration=5.0, seed=3)
    np.testing.assert_array_equal(a.txs.submit_time, b.txs.submit_time)
    np.testing.assert_array_equal(a.txs.gas, b.txs.gas)
    assert a.name == b.name


# -- receipts ------------------------------------------------------------------
def _drive(spec, n=50):
    client = NodeClient.from_spec(spec)
    receipts = [client.submit("submitLocalModel", f"t{i % 8}")
                for i in range(n)]
    client.flush()
    client.run_until(10.0)
    return client, [client.refresh(r) for r in receipts]


@pytest.mark.parametrize("spec", [
    NodeSpec(),                                         # VectorRollup
    NodeSpec(chain=ChainSpec(backend="object")),        # object Rollup
    NodeSpec(shards=ShardSpec(count=2)),                # fabric
], ids=["vector-rollup", "object-rollup", "fabric-2"])
def test_receipt_gas_equals_ledger_accounted_gas(spec):
    """Satellite pin: receipt gas == the ledger's accounted gas."""
    client, receipts = _drive(spec)
    target = client.target
    assert all(r.status == "finalized" for r in receipts)
    # per-batch breakdown equals the ledger's own gas_log row
    log = target.gas_log
    for r in receipts:
        row = [x for x in log
               if x["batch"] == r.batch
               and (r.shard is None or x.get("shard", r.shard) == r.shard)]
        assert len(row) == 1
        row = row[0]
        assert r.gas_breakdown["batch_commit"] == row["commit"]
        assert r.gas_breakdown["batch_verify"] == row["verify"]
        assert r.gas_breakdown["batch_execute"] == row["execute"]
        assert r.gas_breakdown["batch_total"] == row["total"]
    # amortized per-tx shares sum back to the ledger total (receipts
    # cover every sealed tx exactly once)
    total = sum(row["total"] for row in log)
    assert sum(row["n_txs"] for row in log) == len(receipts)
    assert np.isclose(sum(r.gas_breakdown["amortized"] for r in receipts),
                      total)
    # the commit landed in a real L1 block
    assert all(r.block is not None and r.block_hash for r in receipts)


def test_single_shard_fabric_receipts_match_vector_rollup_bit_for_bit():
    """Satellite pin: receipts on ShardedRollup(count=1) == VectorRollup
    receipts, field for field (the fabric only adds the shard tag)."""
    _, plain = _drive(NodeSpec(), n=64)
    _, fab = _drive(NodeSpec(shards=ShardSpec(count=1, fabric=True)), n=64)
    assert len(plain) == len(fab)
    for a, b in zip(plain, fab):
        assert b.shard == 0
        assert a == dataclasses.replace(b, shard=None)


def test_chain_only_receipts_confirm_and_account_all_gas():
    spec = NodeSpec(rollup=None)
    client = NodeClient.from_spec(spec)
    receipts = [client.submit("publishTask", f"p{i}") for i in range(20)]
    assert all(r.status == "pending" for r in receipts)
    client.run_until(5.0)
    for r in receipts:
        client.refresh(r)
    assert all(r.status == "confirmed" for r in receipts)
    chain = client.chain
    assert sum(r.gas_breakdown["intrinsic"] for r in receipts) == \
        chain.total_gas
    for r in receipts:
        assert r.block_hash == chain.blocks[r.block].block_hash
        assert r.confirm_time is not None


def test_submit_arrays_receipts_cover_a_workload():
    wl = WorkloadSpec.make("poisson", 40.0, duration=4.0, seed=1).build()
    client = NodeClient.from_spec(NodeSpec(shards=ShardSpec(count=4)))
    receipts = client.submit_arrays(wl.txs)
    assert len(receipts) == len(wl)
    client.flush()
    client.run_until(10.0)
    for r in receipts:
        client.refresh(r)
    assert all(r.status == "finalized" for r in receipts)
    # conservation across shards: every tx in exactly one sealed batch
    total = sum(row["total"] for row in client.target.gas_log)
    assert np.isclose(sum(r.gas_breakdown["amortized"] for r in receipts),
                      total)
    assert {r.shard for r in receipts} <= {0, 1, 2, 3}


# -- events --------------------------------------------------------------------
def test_typed_event_stream_covers_the_proof_lifecycle():
    client = NodeClient.from_spec(NodeSpec(shards=ShardSpec(count=2)))
    for i in range(30):
        client.submit("submitLocalModel", f"t{i}")
    client.flush()
    client.run_until(5.0)
    evs = client.events()
    kinds = [e.kind for e in evs]
    for kind in ("batch_sealed", "proof_generated", "aggregate_verified",
                 "window_settled", "block_packed"):
        assert kind in kinds, kinds
    sealed = [e for e in evs if e.kind == "batch_sealed"]
    assert sum(e.n_txs for e in sealed) == 30
    assert all(e.shard in (0, 1) for e in sealed)
    windows = [e for e in evs if e.kind == "window_settled"]
    assert windows[-1].fabric_root and len(windows[-1].shard_roots) == 2
    # the stream is a drain: a second call yields only what's new
    assert client.events() == []
    client.flush()
    assert [e.kind for e in client.events()] == ["window_settled"]
    # events are a total order under one monotonic seq
    assert [e.seq for e in evs] == sorted(e.seq for e in evs)


def test_legacy_subscribe_shim_still_fires_with_a_warning():
    client = NodeClient.from_spec(NodeSpec(shards=ShardSpec(count=2)))
    sealed, settled, windows = [], [], []
    with pytest.warns(DeprecationWarning, match="events"):
        client.subscribe("batch_sealed", sealed.append)
    with pytest.warns(DeprecationWarning):
        client.subscribe("session_settled", settled.append)
    with pytest.warns(DeprecationWarning):
        client.subscribe("window_settled", windows.append)
    for i in range(30):
        client.submit("submitLocalModel", f"t{i}")
    client.flush()
    assert sealed and settled and windows
    assert all("shard" in e for e in sealed + settled)
    assert sum(e["n_txs"] for e in sealed) == 30
    assert "fabric_root" in windows[-1]


def test_chain_only_nodes_emit_block_events_and_report_capabilities():
    """Satellite pin: a chain-only node is a smaller event surface, not
    an error — block_packed flows through events(), capabilities() says
    what the backend supports, and only unsupported callback hooks
    raise."""
    bare = NodeClient.from_spec(NodeSpec(rollup=None))
    # vector chain-only: block production + the fused-loop path marker
    assert bare.capabilities() == frozenset({"block_packed",
                                             "fused_window_loop"})
    full = NodeClient.from_spec(NodeSpec())
    assert "aggregate_verified" in full.capabilities()
    assert "block_packed" in full.capabilities()
    for i in range(10):
        bare.submit("publishTask", f"p{i}")
    bare.run_until(3.0)
    blocks = bare.events(kinds=("block_packed",))
    assert blocks and sum(e.n_txs for e in blocks) == 10
    assert all(e.block_hash for e in blocks)
    # the legacy shim works for the chain's own hook...
    seen = []
    with pytest.warns(DeprecationWarning):
        bare.subscribe("block_packed", seen.append)
    bare.run_until(4.0)
    assert seen
    # ...and still rejects rollup-only hooks (with the capabilities)
    with pytest.warns(DeprecationWarning):
        with pytest.raises(ValueError, match="capabilities"):
            bare.subscribe("batch_sealed", lambda e: None)


def test_object_rollup_events_and_provenance():
    client = NodeClient.from_spec(
        NodeSpec(chain=ChainSpec(backend="object")))
    receipts = [client.submit("calculateObjectiveRep", "t0")
                for _ in range(25)]
    client.flush()
    client.run_until(5.0)
    for r in receipts:
        client.refresh(r)
    sealed = client.events(kinds=("batch_sealed",))
    assert [e.n_txs for e in sealed] == [20, 5]
    assert [r.batch for r in receipts] == [0] * 20 + [1] * 5
    assert all(r.l1_ref for r in receipts)      # commit tx ids
    # proof lifecycle provenance rides on the receipt
    assert all(r.proof_ref is not None and r.aggregate_ref is not None
               for r in receipts)


# -- protocol-node equivalence: spec path == legacy kwarg path -----------------
@pytest.fixture(scope="module")
def tiny_world():
    from repro.data.synthetic import gaussian_clusters
    from repro.models.mlp import TinyMLP
    from repro.optim.optimizers import OptimizerSpec, make_optimizer
    model = TinyMLP(16, 8, 4)
    opt = make_optimizer(OptimizerSpec(name="sgdm", lr=0.1, grad_clip=5.0))
    tr_x, tr_y = gaussian_clusters(256, 16, 4, seed=1, noise=0.5)
    vx, vy = gaussian_clusters(64, 16, 4, seed=2, noise=0.5)
    val = {"x": jnp.asarray(vx), "labels": jnp.asarray(vy)}

    def bf(c, r):
        g = np.random.default_rng((c * 9973 + r) % 2**31)
        idx = g.integers(0, len(tr_x), 8)
        return {"x": jnp.asarray(tr_x[idx]), "labels": jnp.asarray(tr_y[idx])}

    return model, opt, val, bf, model.accuracy_fn()


def _agents(model, opt, store, bf, n=3):
    from repro.fl.client import ClientConfig, TrainingAgent
    from repro.fl.dp import DPConfig
    behaviors = ["good", "good", "malicious"]
    return [TrainingAgent(
        ClientConfig(f"trainer{i}", behaviors[i], local_steps=2,
                     dp=DPConfig(noise_multiplier=0.05)),
        model, opt, store, bf, seed=i) for i in range(n)]


def _run_protocol(world, node):
    model, opt, val, bf, eval_fn = world
    res = node.run_task(FLTaskSpec("t0", rounds=2),
                        _agents(model, opt, node.store, bf), bf)
    if node.rollup is not None:
        node.rollup.flush()
    return res


LEGACY_CONFIGS = [
    ({"engine": "object"}, NodeSpec(chain=ChainSpec(backend="object"))),
    ({"engine": "object", "use_rollup": False},
     NodeSpec(chain=ChainSpec(backend="object"), rollup=None)),
    ({"engine": "vector"}, NodeSpec()),
    ({"engine": "vector", "use_rollup": False}, NodeSpec(rollup=None)),
    ({"engine": "vector", "n_shards": 2},
     NodeSpec(shards=ShardSpec(count=2))),
]


@pytest.mark.parametrize("legacy,spec", LEGACY_CONFIGS,
                         ids=["obj", "obj-l1", "vec", "vec-l1", "fabric"])
def test_spec_node_equivalent_to_legacy_node(tiny_world, legacy, spec):
    """Acceptance pin: NodeSpec/build_ledger construction produces the
    same state root and total gas as the legacy constructor path."""
    from repro.fl.server import AutoDFL
    model, opt, val, bf, eval_fn = tiny_world
    with pytest.warns(DeprecationWarning):
        node_a = AutoDFL(model, opt, 3, eval_fn, val, **legacy)
    res_a = _run_protocol(tiny_world, node_a)
    node_b = AutoDFL(model, opt, 3, eval_fn, val, spec=spec)
    res_b = _run_protocol(tiny_world, node_b)

    assert node_a.chain.total_gas == node_b.chain.total_gas
    assert node_a.protocol_calls == node_b.protocol_calls
    assert node_a._target().state_root() == node_b._target().state_root()
    np.testing.assert_array_equal(res_a.scores, res_b.scores)
    np.testing.assert_array_equal(res_a.reputations, res_b.reputations)
    assert res_a.payouts == res_b.payouts
    if node_a.rollup is not None:
        assert [tuple(r[k] for k in GAS_KEYS)
                for r in node_a.rollup.gas_log] == \
            [tuple(r[k] for k in GAS_KEYS) for r in node_b.rollup.gas_log]


def test_node_client_reads_protocol_account_state(tiny_world):
    from repro.fl.server import AutoDFL
    model, opt, val, bf, eval_fn = tiny_world
    node = AutoDFL(model, opt, 3, eval_fn, val, spec=NodeSpec())
    _run_protocol(tiny_world, node)
    client = node.client()
    acct = client.get_account("trainer0")
    assert acct.account_id == node._target().sender_id("trainer0")
    assert acct.submissions > 0
    np.testing.assert_allclose(acct.reputation,
                               float(np.asarray(node.book.reputation)[0]))
    np.testing.assert_allclose(acct.balance,
                               node.escrow.balances["trainer0"])
    assert client.state_root() == node._target().state_root()
    # unknown addresses are a read, not a mint
    before = dict(node._target()._sender_ids)
    assert client.get_account("nobody").account_id is None
    assert node._target()._sender_ids == before


# -- deprecation shim ----------------------------------------------------------
def test_legacy_kwargs_warn_but_work(tiny_world):
    from repro.fl.server import AutoDFL
    model, opt, val, bf, eval_fn = tiny_world
    with pytest.warns(DeprecationWarning, match="NodeSpec"):
        node = AutoDFL(model, opt, 3, eval_fn, val, engine="vector",
                       n_shards=2, shard_route="least_loaded")
    assert isinstance(node.rollup, ShardedRollup)
    assert node.rollup.route == "least_loaded"
    with pytest.warns(DeprecationWarning, match="ChainSpec"):
        m = simulate_load("publishTask", 10.0, duration=2.0, engine="object")
    assert m["submitted"] == 20
    # spec= and legacy kwargs are mutually exclusive — including the
    # defaulted ones a mixed call would otherwise silently shadow
    with pytest.raises(ValueError):
        AutoDFL(model, opt, 3, eval_fn, val, engine="vector",
                spec=NodeSpec())
    with pytest.raises(ValueError):
        AutoDFL(model, opt, 3, eval_fn, val, use_pallas_agg=True,
                spec=NodeSpec())
    with pytest.raises(ValueError):              # contradicting trainer count
        AutoDFL(model, opt, 3, eval_fn, val, spec=NodeSpec(n_trainers=8))
    with pytest.raises(ValueError):
        simulate_load("publishTask", 10.0, block_time=0.5, spec=ChainSpec())
    # loose task kwargs conflict with an explicit FLTaskSpec
    node = AutoDFL(model, opt, 3, eval_fn, val, spec=NodeSpec())
    with pytest.raises(ValueError):
        node.run_task(FLTaskSpec("t0", rounds=2), [], rounds=3)
    # payloads are an object-backend feature; SoA engines drop them by
    # design, so the client refuses instead of diverging per backend
    with pytest.raises(ValueError):
        NodeClient.from_spec(NodeSpec()).submit(
            "publishTask", "p0", payload={"reward": 5})


def test_per_instance_reputation_and_don_defaults(tiny_world):
    """Satellite pin: no shared mutable default ReputationParams/DONConfig
    instances across nodes."""
    from repro.fl.server import AutoDFL
    model, opt, val, bf, eval_fn = tiny_world
    a = AutoDFL(model, opt, 2, eval_fn, val, spec=NodeSpec())
    b = AutoDFL(model, opt, 2, eval_fn, val, spec=NodeSpec())
    assert a.rep_params == b.rep_params and a.rep_params is not b.rep_params
    assert a.don == b.don and a.don is not b.don
    # spec-level constants flow through to the node
    c = AutoDFL(model, opt, 2, eval_fn, val, spec=NodeSpec(
        reputation=ReputationSpec(gamma=0.7), don=DONSpec(n_oracles=3)))
    assert c.rep_params.gamma == 0.7 and c.don.n_oracles == 3
    assert len(c.val_slices) == 3
