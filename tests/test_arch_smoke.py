"""Per-architecture smoke tests: reduced same-family configs, one forward +
one train step + one decode step on CPU, asserting shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES, cell_is_skipped
from repro.configs.registry import ASSIGNED, REGISTRY, reduced_config
from repro.models.model import build_model
from repro.optim.optimizers import OptimizerSpec, make_optimizer

ALL_ARCHS = list(REGISTRY)


def _batch(cfg, B=2, S=16):
    if cfg.family == "conv":
        return {"images": jnp.ones((B, 32, 32, 1), jnp.float32),
                "labels": jnp.zeros((B,), jnp.int32)}
    if cfg.input_mode == "embeds":
        return {"embeds": jnp.ones((B, S, cfg.d_model), jnp.bfloat16) * 0.02,
                "positions": jnp.broadcast_to(
                    jnp.arange(S, dtype=jnp.int32), (3, B, S)),
                "labels": jnp.zeros((B, S), jnp.int32)}
    if cfg.input_mode == "audio":
        return {"audio_embeds": jnp.ones((B, cfg.enc_seq, cfg.d_model),
                                         jnp.bfloat16) * 0.02,
                "tokens": jnp.zeros((B, S), jnp.int32),
                "labels": jnp.zeros((B, S), jnp.int32)}
    return {"tokens": jnp.zeros((B, S), jnp.int32),
            "labels": jnp.zeros((B, S), jnp.int32)}


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_and_train_step(arch):
    cfg = reduced_config(REGISTRY[arch])
    model = build_model(cfg)
    params = model.init_params(jax.random.key(0))
    batch = _batch(cfg)
    loss = jax.jit(model.loss)(params, batch)
    assert np.isfinite(float(loss)), f"{arch} loss NaN/inf"

    opt = make_optimizer(OptimizerSpec(name=cfg.optimizer, lr=1e-3))
    ostate = opt.init(params)

    def step(p, o, b):
        l, g = jax.value_and_grad(lambda pp: model.loss(pp, b))(p)
        p, o, gn = opt.update(g, o, p)
        return p, o, l, gn

    p2, o2, l2, gn = jax.jit(step)(params, ostate, batch)
    assert np.isfinite(float(l2)) and np.isfinite(float(gn))
    # params actually changed and stayed finite
    changed = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        params, p2)
    assert max(jax.tree.leaves(changed)) > 0
    for leaf in jax.tree.leaves(p2):
        assert np.all(np.isfinite(np.asarray(leaf, np.float32))), arch


@pytest.mark.parametrize("arch", [a for a in ALL_ARCHS
                                  if REGISTRY[a].family != "conv"])
def test_decode_step(arch):
    cfg = reduced_config(REGISTRY[arch])
    model = build_model(cfg)
    params = model.init_params(jax.random.key(0))
    B, maxlen = 2, 32
    state = model.init_decode_state(B, maxlen)
    if cfg.input_mode == "embeds":
        batch = {"embeds": jnp.ones((B, 1, cfg.d_model), jnp.bfloat16) * 0.02,
                 "pos": jnp.int32(3)}
    else:
        batch = {"tokens": jnp.zeros((B, 1), jnp.int32), "pos": jnp.int32(3)}
    logits, state2 = jax.jit(model.decode)(params, state, batch)
    assert logits.shape == (B, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, np.float32))), arch
    assert jax.tree.structure(state) == jax.tree.structure(state2)


@pytest.mark.parametrize("arch", [a for a in ALL_ARCHS
                                  if REGISTRY[a].family not in
                                  ("conv", "audio")])
def test_prefill_matches_decode(arch):
    """Prefill-then-decode must equal one-shot forward (KV-cache soundness)."""
    cfg = reduced_config(REGISTRY[arch])
    if cfg.input_mode == "embeds":
        pytest.skip("embeds-mode prefill equivalence covered via forward")
    model = build_model(cfg)
    params = model.init_params(jax.random.key(0))
    B, S = 1, 8
    toks = jax.random.randint(jax.random.key(1), (B, S + 1), 0,
                              cfg.vocab_size)
    # one-shot forward logits at position S-1 predict token S
    logits_full = model.forward(params, {"tokens": toks[:, :S + 1]})
    want = logits_full[:, S - 1]
    # decode path: feed tokens one at a time
    state = model.init_decode_state(B, S + 4)
    got = None
    for t in range(S):
        got, state = model.decode(params, state,
                                  {"tokens": toks[:, t:t + 1],
                                   "pos": jnp.int32(t)})
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=0.15, atol=0.15)


def test_skip_matrix():
    """long_500k skips exactly the pure full-attention archs."""
    skipped = {a for a in ASSIGNED
               if cell_is_skipped(REGISTRY[a], SHAPES["long_500k"])}
    assert skipped == {"yi-6b", "qwen1.5-0.5b", "qwen2-0.5b", "qwen3-32b",
                       "whisper-medium", "qwen2-vl-72b",
                       "moonshot-v1-16b-a3b", "kimi-k2-1t-a32b"}
    assert "xlstm-1.3b" not in skipped and "jamba-1.5-large-398b" not in skipped
