"""Vectorized engine tests: object/vector equivalence, multi-lane rollup,
settlement amortization invariants, Table-I regression pins, digests."""
import numpy as np
import pytest

from repro.core.engine import (TxArrays, VectorChain, VectorRollup,
                               xor_fold_digest)
from repro.core.gas import DEFAULT_GAS, FUNCTIONS, ROLLUP_BATCH, l1_gas
from repro.core.ledger import Chain, Tx, simulate_load
from repro.core.rollup import Rollup
from repro.core.tasks import TaskContract
from repro.core.workloads import make_workload, mixed_function_workload


def _random_workload(rng, n):
    """Random mixed-fn workload in sorted submit order (the documented FIFO
    contract; see test_head_of_line_stall for the out-of-order case)."""
    fns = list(FUNCTIONS)
    times = np.sort(rng.uniform(0.0, 10.0, n))
    return [Tx(fns[int(rng.integers(len(fns)))], f"c{int(rng.integers(8))}",
               {}, int(DEFAULT_GAS.l1_per_call[fns[0]]
                       if rng.uniform() < 0.1
                       else rng.integers(20_000, 200_000)), float(t))
            for t in times]


def _run_object(txs, block_gas_limit, block_time, t_end):
    ch = Chain(block_gas_limit=block_gas_limit, block_time=block_time)
    for t in txs:
        ch.submit(t)
    ch.run_until(t_end)
    return ch


def _run_vector(txs, block_gas_limit, block_time, t_end):
    vc = VectorChain(block_gas_limit=block_gas_limit, block_time=block_time)
    vc.submit_arrays(TxArrays.from_txs(txs, vc.fns))
    vc.run_until(t_end)
    return vc


# -- property: vector == object on random workloads ----------------------------
@pytest.mark.parametrize("seed", range(8))
def test_chain_equivalence_random_workloads(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(50, 800))
    limit = int(rng.integers(500_000, 9_000_000))
    bt = float(rng.uniform(0.3, 2.0))
    txs = _random_workload(rng, n)
    oc = _run_object(txs, limit, bt, 12.0)
    vc = _run_vector(txs, limit, bt, 12.0)
    assert len(oc.blocks) == len(vc.blocks)
    for ob, vb in zip(oc.blocks, vc.blocks):
        assert (ob.height, ob.time) == (vb.height, vb.time)
        assert len(getattr(ob, "txs", [])) == getattr(vb, "n_txs", 0) \
            or ob.height == 0
        assert ob.gas_used == vb.gas_used
    assert oc.total_gas == vc.total_gas
    obj_conf = [t.confirm_time for b in oc.blocks for t in b.txs]
    np.testing.assert_array_equal(np.asarray(obj_conf), vc.confirm_times())


def test_simulate_load_engines_identical():
    for fn in FUNCTIONS:
        for rate in (40, 320):
            a = simulate_load(fn, rate, duration=8.0, engine="object")
            b = simulate_load(fn, rate, duration=8.0, engine="vector")
            assert set(a) == set(b)
            for k in a:
                assert np.isclose(a[k], b[k]), (fn, rate, k)


def test_head_of_line_stall_identical():
    """Documented FIFO semantics: a future-timestamped tx submitted out of
    order stalls everything behind it — identically in both engines."""
    txs = [Tx("submitLocalModel", "a", {}, 50_000, 0.5),
           Tx("submitLocalModel", "b", {}, 50_000, 99.0),   # future head
           Tx("submitLocalModel", "c", {}, 50_000, 1.0)]
    oc = _run_object(txs, 9_000_000, 1.0, 5.0)
    vc = _run_vector(txs, 9_000_000, 1.0, 5.0)
    assert sum(len(b.txs) for b in oc.blocks) == 1     # only tx "a"
    assert vc.n_confirmed == 1
    assert oc.total_gas == vc.total_gas == 50_000


def test_oversized_tx_blocks_queue_identically():
    txs = [Tx("submitLocalModel", "a", {}, 10_000_000, 0.1),  # > block limit
           Tx("submitLocalModel", "b", {}, 1_000, 0.2)]
    oc = _run_object(txs, 9_000_000, 1.0, 5.0)
    vc = _run_vector(txs, 9_000_000, 1.0, 5.0)
    assert sum(len(b.txs) for b in oc.blocks) == 0 == vc.n_confirmed


def test_batch_handlers_match_per_tx_handlers():
    rng = np.random.default_rng(7)
    wl = mixed_function_workload(150.0, duration=6.0, seed=11)
    oc = Chain()
    counts = {}
    for fn in FUNCTIONS:
        oc.register(fn, lambda s, tx, fn=fn: counts.__setitem__(
            fn, counts.get(fn, 0) + 1))
    for t in wl.to_txs():
        oc.submit(t)
    oc.run_until(6.0)
    vc = VectorChain(fns=wl.txs.fns)
    TaskContract.register_batch_handlers(vc)
    vc.submit_arrays(wl.txs)
    vc.run_until(6.0)
    assert vc.state.get("calls", {}) == {k: v for k, v in counts.items() if v}
    del rng


def test_interleaved_submit_produce_matches_object():
    """Incremental consolidation: streaming submits between blocks must
    match the object chain (and the one-shot vector submission)."""
    rng = np.random.default_rng(21)
    txs = _random_workload(rng, 400)
    oc = Chain(block_gas_limit=2_000_000)
    vc = VectorChain(block_gas_limit=2_000_000)
    i, t = 0, 0.0
    while t < 12.0:
        while i < len(txs) and txs[i].submit_time <= t + 1.0:
            oc.submit(txs[i])
            vc.submit(txs[i])
            i += 1
        t += 1.0
        oc.produce_block(t)
        vc.produce_block(t)
    assert oc.total_gas == vc.total_gas
    obj_conf = [x.confirm_time for b in oc.blocks for x in b.txs]
    np.testing.assert_array_equal(np.asarray(obj_conf), vc.confirm_times())


def test_submit_shim_preserves_sender_identity():
    """Regression: the object-Tx shim collapsed every sender to id 0."""
    vc = VectorChain()
    TaskContract.register_batch_handlers(vc)
    for sender, n in (("t3", 2), ("t7", 3)):
        for j in range(n):
            vc.submit(Tx("submitLocalModel", sender, {"j": j}, 1000,
                         0.1 * (j + 1)))
    vc.run_until(2.0)
    per = vc.state["calls_by_sender"]["submitLocalModel"]
    assert sorted(per.values()) == [2, 3]
    assert len(per) == 2
    assert vc.sender_id("t3") != vc.sender_id("t7")


def test_vector_rollup_shares_fresh_chain_registry():
    """Regression: `or FnRegistry()` dropped an empty-but-present registry
    (FnRegistry defines __len__, so a fresh one is falsy)."""
    vc = VectorChain()
    assert VectorRollup(vc).fns is vc.fns


def test_reentrant_flush_single_settlement():
    """Regression: a handler calling flush() mid-seal split the session,
    posting verify/execute twice."""
    ch = Chain()
    ru = Rollup(ch, batch_size=4)

    def handler(state, tx):
        ru.flush()                       # must be a no-op mid-seal
    ru.register("f", handler)
    for i in range(6):
        ru.submit(Tx("f", "s", {"i": i}, 0, float(i)))
    ru.flush()
    posted = [t.fn for t in list(ch.mempool)]
    assert posted.count("rollup_verify") == 1
    assert posted.count("rollup_execute") == 1
    rows = ru.gas_log
    assert np.isclose(sum(r["verify"] for r in rows),
                      DEFAULT_GAS.verify_multi)


# -- rollup equivalence + multi-lane -------------------------------------------
@pytest.mark.parametrize("fn,n_calls,batch", [
    ("publishTask", 100, ROLLUP_BATCH), ("submitLocalModel", 50, 20),
    ("calculateSubjectiveRep", 7, 4), ("calculateObjectiveRep", 3, 8)])
def test_rollup_gas_log_equivalence(fn, n_calls, batch):
    oc, vc = Chain(), VectorChain()
    oru = Rollup(oc, batch_size=batch)
    vru = VectorRollup(vc, batch_size=batch, n_lanes=1)
    for i in range(n_calls):
        tx = Tx(fn, f"c{i}", {}, 0, i * 0.01)
        oru.submit(tx)
        vru.submit(tx)
    oru.flush()
    vru.flush()
    assert len(oru.gas_log) == len(vru.gas_log)
    for a, b in zip(oru.gas_log, vru.gas_log):
        for k in ("n_txs", "commit", "verify", "execute", "total"):
            assert np.isclose(a[k], b[k]), (k, a, b)
    oc.run_until(n_calls * 0.01 + 2.0)
    vc.run_until(n_calls * 0.01 + 2.0)
    assert oc.total_gas == vc.total_gas


@pytest.mark.parametrize("lanes", [2, 4])
def test_multi_lane_settlement_invariants(lanes):
    vc = VectorChain()
    vru = VectorRollup(vc, batch_size=10, n_lanes=lanes)
    wl = make_workload("poisson", 60.0, duration=5.0, seed=3)
    vru.submit_arrays(wl.txs)
    vru.flush()
    rows = vru.gas_log
    assert sorted(set(r["lane"] for r in rows)) == list(range(lanes))
    # every submitted tx landed in exactly one batch
    assert sum(r["n_txs"] for r in rows) == len(wl)
    assert all(r["n_txs"] <= 10 for r in rows)
    # amortization invariant: per-row shares sum back to one verify+execute
    verify = DEFAULT_GAS.verify_multi
    execute = DEFAULT_GAS.execute_multi
    assert np.isclose(sum(r["verify"] for r in rows), verify)
    assert np.isclose(sum(r["execute"] for r in rows), execute)
    assert np.isclose(sum(r["total"] for r in rows),
                      sum(r["commit"] for r in rows) + verify + execute)
    # lanes seal concurrently -> strictly better modeled session latency
    assert vru.latency(100) < VectorRollup(VectorChain()).latency(100)


def test_settlement_amortization_rollup_invariants():
    """Rollup (object path): amortized shares sum to the posted proof gas,
    per session, across re-entrant flushes."""
    ch = Chain()
    ru = Rollup(ch, batch_size=5)
    for sess, n in enumerate((12, 7)):
        start = len(ru.gas_log)
        for i in range(n):
            ru.submit(Tx("submitLocalModel", "s", {}, 0, sess + i * 0.01))
        ru.flush()
        rows = ru.gas_log[start:]
        assert np.isclose(sum(r["verify"] for r in rows),
                          DEFAULT_GAS.verify_multi)
        assert np.isclose(sum(r["execute"] for r in rows),
                          DEFAULT_GAS.execute_multi)
    # verify/execute posted exactly once per session
    posted = [t.fn for t in list(ch.mempool)]
    assert posted.count("rollup_verify") == 2
    assert posted.count("rollup_execute") == 2


def test_settlement_survives_gas_log_truncation():
    """Regression: gas_log[-n:] amortization overwrote a PREVIOUS session's
    settled rows when the current session's rows had been removed; indexed
    tracking must leave settled rows untouched."""
    ch = Chain()
    ru = Rollup(ch, batch_size=5)
    for i in range(10):
        ru.submit(Tx("submitLocalModel", "s", {}, 0, i * 0.01))
    ru.flush()
    settled = [dict(r) for r in ru.gas_log]
    # session 2: one batch committed, then its row is dropped (e.g. a
    # memory-bounding truncation) before settlement
    for i in range(5):
        ru.submit(Tx("submitLocalModel", "s", {}, 0, 1.0 + i * 0.01))
    del ru.gas_log[-1]
    ru.flush()
    assert [dict(r) for r in ru.gas_log] == settled   # no misattribution
    assert ru.prover.n_unsettled(ru) == 0


def test_reentrant_handler_submit_defers_seal():
    """A handler submitting back into the rollup during execution must not
    trigger a nested seal against half-executed state; queued txs drain on
    the same flush."""
    ch = Chain()
    ru = Rollup(ch, batch_size=4)
    executed = []

    def handler(state, tx):
        executed.append(tx.tx_id)
        if tx.payload.get("spawn"):
            for j in range(4):
                ru.submit(Tx("f", "child", {"p": (tx.submit_time, j)}, 0,
                             tx.submit_time + 1 + j))
    ru.register("f", handler)
    for i in range(4):
        ru.submit(Tx("f", "root", {"spawn": True}, 0, float(i)))
    ru.flush()
    assert len(executed) == len(set(executed)) == 4 + 16
    assert sum(b.n_txs for b in ru.batches) == 20
    assert all(b.n_txs <= 4 for b in ru.batches)
    rows = ru.gas_log
    assert np.isclose(sum(r["verify"] for r in rows),
                      DEFAULT_GAS.verify_multi)


# -- Table-I regression pins ---------------------------------------------------
def test_table1_gas_pins_and_20x_ratio():
    """Pin Table-I gas totals (both engines) and the 20X headline ratio."""
    pins = {("publishTask", 100): 742115, ("submitLocalModel", 50): 241568}
    for (fn, n), paper_total in pins.items():
        for make in (lambda: Rollup(Chain()),
                     lambda: VectorRollup(VectorChain())):
            ru = make()
            for i in range(n):
                ru.submit(Tx(fn, f"c{i}", {}, 0, i * 0.01))
            ru.flush()
            live = sum(r["total"] for r in ru.gas_log)
            assert abs(live - paper_total) / paper_total < 0.15, \
                (fn, n, live, paper_total)
    for make in (lambda: Rollup(Chain()),
                 lambda: VectorRollup(VectorChain())):
        ru = make()
        for i in range(100):
            ru.submit(Tx("publishTask", f"p{i}", {}, 0, i * 0.01))
        ru.flush()
        live = sum(r["total"] for r in ru.gas_log)
        assert l1_gas("publishTask", 100) / live > 20.0


# -- digests -------------------------------------------------------------------
@pytest.mark.parametrize("n", [1, 100, 5000])
def test_numpy_digest_matches_pallas_kernel(n):
    import jax.numpy as jnp
    from repro.kernels.rollup_digest import rollup_digest
    rng = np.random.default_rng(n)
    words = rng.integers(0, 2**32, n, dtype=np.uint32)
    want = int(rollup_digest(jnp.asarray(words), block_p=2048,
                             interpret=True))
    assert xor_fold_digest(words) == want


def test_rollup_word_digests_deterministic_and_tamper_evident():
    def digests(times):
        ru = Rollup(Chain(), batch_size=8)
        for i, t in enumerate(times):
            ru.submit(Tx("submitLocalModel", f"c{i}", {}, 0, t))
        ru.flush()
        return [b.word_digest for b in ru.batches]
    base = [i * 0.01 for i in range(8)]
    d0, d1 = digests(base), digests(base)
    assert d0 == d1 and d0[0] != 0
    tampered = list(base)
    tampered[3] += 0.5
    assert digests(tampered) != d0
    # vector engine seals the same txs -> same per-batch xor-root family
    vru = VectorRollup(VectorChain(), batch_size=8)
    for i, t in enumerate(base):
        vru.submit(Tx("submitLocalModel", f"c{i}", {}, 0, t))
    vru.flush()
    assert vru.batch_digests and all(isinstance(d, int)
                                     for d in vru.batch_digests)
    assert vru.update_digest != 0
