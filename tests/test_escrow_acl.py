"""Direct coverage for core/escrow.py (deposit / release / forfeit) and
ledger.AccessControl.vote_readmit quorum edge cases — previously only
exercised indirectly through the protocol e2e tests."""
import numpy as np
import pytest

from repro.core.escrow import Escrow, InsufficientFunds
from repro.core.ledger import AccessControl


# -- escrow: deposit path ------------------------------------------------------
def test_deposit_locks_reward_and_checks_funds():
    e = Escrow()
    e.fund("tp0", 10.0)
    e.deposit("tp0", "t0", 7.0)
    assert e.balances["tp0"] == 3.0
    assert e.locked["t0"] == {"tp0": 7.0}
    with pytest.raises(InsufficientFunds):
        e.deposit("tp0", "t1", 5.0)               # only 3.0 left
    assert "t1" not in e.locked                   # failed deposit locks nothing
    with pytest.raises(AssertionError):
        e.fund("tp0", -1.0)


def test_collateral_lock_checks_funds():
    e = Escrow()
    e.fund("tr0", 2.0)
    e.lock_collateral("tr0", "t0", 1.5)
    assert e.balances["tr0"] == 0.5
    assert e.collateral["t0"] == {"tr0": 1.5}
    with pytest.raises(InsufficientFunds):
        e.lock_collateral("tr0", "t0", 1.0)


# -- escrow: release path (score-proportional payout + collateral return) ------
def test_settle_releases_proportionally_and_returns_collateral():
    e = Escrow()
    e.fund("tp0", 100.0)
    e.deposit("tp0", "t0", 12.0)
    for tr, coll in (("a", 1.0), ("b", 2.0)):
        e.fund(tr, 5.0)
        e.lock_collateral(tr, "t0", coll)
    payouts = e.settle("t0", {"a": 0.75, "b": 0.25})
    assert np.isclose(payouts["a"], 9.0) and np.isclose(payouts["b"], 3.0)
    # balance = initial - collateral + payout + returned collateral
    assert np.isclose(e.balances["a"], 5.0 + 9.0)
    assert np.isclose(e.balances["b"], 5.0 + 3.0)
    assert e.slashed_pool == 0.0
    assert "t0" not in e.locked                   # reward pot fully released


# -- escrow: forfeit path (free-riders slashed) --------------------------------
def test_settle_forfeits_zero_score_collateral_to_slash_pool():
    e = Escrow()
    e.fund("tp0", 50.0)
    e.deposit("tp0", "t0", 10.0)
    for tr in ("good", "rider"):
        e.fund(tr, 4.0)
        e.lock_collateral(tr, "t0", 2.0)
    payouts = e.settle("t0", {"good": 0.5, "rider": 0.0})
    assert np.isclose(payouts["good"], 10.0)      # whole pot
    assert payouts["rider"] == 0.0
    assert np.isclose(e.slashed_pool, 2.0)        # rider's collateral gone
    assert np.isclose(e.balances["rider"], 2.0)   # only the unlocked rest
    assert np.isclose(e.balances["good"], 2.0 + 10.0 + 2.0)


def test_settle_all_zero_scores_slashes_everyone_and_strands_no_pot():
    e = Escrow()
    e.fund("tp0", 20.0)
    e.deposit("tp0", "t0", 8.0)
    for tr in ("x", "y"):
        e.fund(tr, 3.0)
        e.lock_collateral(tr, "t0", 1.0)
    payouts = e.settle("t0", {"x": 0.0, "y": 1e-9})   # both under min_score
    assert payouts == {"x": 0.0, "y": 0.0}
    assert np.isclose(e.slashed_pool, 2.0)
    # the pot was popped (publisher cannot repudiate, nor double-settle)
    assert "t0" not in e.locked


def test_settle_unknown_task_pays_nothing():
    e = Escrow()
    e.fund("a", 1.0)
    assert e.settle("ghost", {"a": 1.0}) == {"a": 0.0}
    assert e.balances["a"] == 1.0


# -- AccessControl.vote_readmit quorum edge cases ------------------------------
def _acl(n_admins):
    return AccessControl([f"admin{i}" for i in range(n_admins)])


def test_vote_readmit_exact_majority_boundary():
    # 3 admins: strict majority is 2 — the 2nd vote readmits, not the 1st
    acl = _acl(3)
    acl.ban("admin0", "user")
    assert not acl.vote_readmit("admin0", "user")
    assert acl.vote_readmit("admin1", "user")
    assert "user" not in acl.banned
    # 4 admins: 2 votes is NOT a strict majority (2*2 == 4); 3 are needed
    acl = _acl(4)
    acl.ban("admin0", "user")
    assert not acl.vote_readmit("admin0", "user")
    assert not acl.vote_readmit("admin1", "user")
    assert "user" in acl.banned
    assert acl.vote_readmit("admin2", "user")


def test_vote_readmit_double_vote_is_idempotent():
    acl = _acl(4)
    acl.ban("admin0", "user")
    for _ in range(5):                             # one admin spamming votes
        assert not acl.vote_readmit("admin0", "user")
    assert "user" in acl.banned
    assert not acl.vote_readmit("admin1", "user")
    assert acl.vote_readmit("admin2", "user")


def test_vote_readmit_rejects_self_vote():
    """A banned admin stays in the consortium set (ban strips roles, not
    membership) — their self-vote must not count toward their own quorum."""
    acl = _acl(3)
    acl.ban("admin1", "admin0")
    with pytest.raises(PermissionError):
        acl.vote_readmit("admin0", "admin0")
    assert "admin0" in acl.banned
    # the two OTHER admins still form a majority
    assert not acl.vote_readmit("admin1", "admin0")
    assert acl.vote_readmit("admin2", "admin0")


def test_vote_readmit_nonadmin_cannot_vote_and_state_resets():
    acl = _acl(3)
    acl.ban("admin0", "user")
    with pytest.raises(AssertionError):
        acl.vote_readmit("stranger", "user")
    assert not acl.vote_readmit("admin1", "user")
    assert acl.vote_readmit("admin2", "user")
    # vote tally is cleared after readmission: a later re-ban needs a
    # fresh majority, old votes must not linger
    acl.ban("admin0", "user")
    assert not acl.vote_readmit("admin0", "user")
    assert "user" in acl.banned


def test_readmitted_user_can_be_granted_roles_again():
    acl = _acl(3)
    acl.grant("admin0", "user", "trainer")
    acl.ban("admin0", "user")
    with pytest.raises(PermissionError):
        acl.grant("admin0", "user", "trainer")     # banned: no direct grant
    acl.vote_readmit("admin0", "user")
    acl.vote_readmit("admin1", "user")
    acl.grant("admin0", "user", "trainer")
    assert acl.has_role("user", "trainer")
