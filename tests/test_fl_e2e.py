"""End-to-end FL protocol tests (the paper's PoC): full task lifecycle with
behavior profiles, oracle quorum, rollup settlement, convergence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.core.oracle import DONConfig, evaluate_quorum
from repro.data.pipeline import client_batch_fn
from repro.data.synthetic import make_mnist_like
from repro.fl.client import ClientConfig, TrainingAgent
from repro.fl.dp import DPConfig
from repro.fl.partition import dirichlet_partition
from repro.fl.server import AutoDFL
from repro.models import lenet
from repro.models.model import build_model
from repro.optim.optimizers import OptimizerSpec, make_optimizer


@pytest.fixture(scope="module")
def fl_world():
    cfg = get_config("lenet5")
    model = build_model(cfg)
    opt = make_optimizer(OptimizerSpec(name="sgdm", lr=0.05, grad_clip=5.0))
    xs, ys = make_mnist_like(1536, seed=1)
    val = {"images": jnp.asarray(xs[:256]), "labels": jnp.asarray(ys[:256])}
    parts = dirichlet_partition(ys[256:], 4, alpha=2.0, seed=0)
    raw = client_batch_fn(xs[256:], ys[256:], parts, 64)
    bf = lambda c, r: {k: jnp.asarray(v) for k, v in raw(c, r).items()}
    eval_fn = jax.jit(lambda p, b: lenet.accuracy(cfg, p, b))
    return cfg, model, opt, val, bf, eval_fn


def test_full_protocol_and_convergence(fl_world):
    cfg, model, opt, val, bf, eval_fn = fl_world
    sys = AutoDFL(model, opt, 4, eval_fn, val, use_rollup=True)
    behaviors = ["good", "good", "malicious", "lazy"]
    agents = [TrainingAgent(
        ClientConfig(f"trainer{i}", behaviors[i],
                     dp=DPConfig(noise_multiplier=0.05)),
        model, opt, sys.store, bf, seed=i) for i in range(4)]
    res = None
    for t in range(3):
        res = sys.run_task(f"task{t}", agents, bf, rounds=4)
    reps = res.reputations
    # paper Fig. 3 phenomenology
    assert reps[0] > 0.7 and reps[1] > 0.7        # good trainers rise
    assert reps[2] < 0.35                         # malicious collapses
    assert reps[2] < reps[3] < reps[0]            # lazy in between
    # global model converges despite the attacker (Eq. 1 downweights it)
    assert float(eval_fn(res.global_params, val)) > 0.9
    # free-rider got (almost) nothing; good trainers paid
    assert res.payouts["trainer2"] < 0.2 * res.payouts["trainer0"]
    # ledger settled rollup batches with Table-I-shaped gas
    assert sys.rollup.gas_log and all(
        b["verify"] > 0 and b["execute"] > 0 for b in sys.rollup.gas_log)


def test_oracle_quorum_resists_badmouthing(fl_world):
    cfg, model, opt, val, bf, eval_fn = fl_world
    params = [model.init_params(jax.random.key(i)) for i in range(3)]
    honest, _ = evaluate_quorum(eval_fn, params, val, DONConfig(n_oracles=5))
    # two colluding oracles forge perfect scores (reputation-boosting) —
    # the median aggregate stays with the honest majority
    attacked, report = evaluate_quorum(
        eval_fn, params, val, DONConfig(n_oracles=5),
        adversarial_oracles={0: 1.0, 1: 1.0})
    np.testing.assert_allclose(np.asarray(attacked), np.asarray(honest),
                               atol=0.15)
    assert set(report["flagged_oracles"]) == {0, 1}
    # 3/5 honest violates the paper's 2/3 assumption -> quorum must FAIL
    assert not report["quorum_ok"]
    # a single forger (4/5 honest) keeps the quorum
    _, rep1 = evaluate_quorum(eval_fn, params, val, DONConfig(n_oracles=5),
                              adversarial_oracles={0: 1.0})
    assert rep1["quorum_ok"] and rep1["flagged_oracles"] == [0]


def test_access_control_sybil_whitewash(fl_world):
    cfg, model, opt, val, bf, eval_fn = fl_world
    sys = AutoDFL(model, opt, 2, eval_fn, val)
    acl = sys.acl
    # non-admin cannot grant
    with pytest.raises(AssertionError):
        acl.grant("trainer0", "sybil", "trainer")
    # banned identity cannot re-enter without majority vote (whitewashing)
    acl.ban("admin0", "trainer1")
    with pytest.raises(PermissionError):
        acl.grant("admin0", "trainer1", "trainer")
    assert not acl.vote_readmit("admin0", "trainer1")
    assert acl.vote_readmit("admin1", "trainer1")   # 2/3 majority reached
    acl.grant("admin0", "trainer1", "trainer")
