"""Pure-DP fl_round (the paper's cross-device regime, §Perf cell C3):
CPU-correctness of the trainer-per-chip configuration + serve launcher."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import REGISTRY, reduced_config
from repro.fl.round import FLRoundSpec, build_fl_round, trainerify_pspecs
from repro.models.model import build_model
from repro.optim.optimizers import OptimizerSpec, make_optimizer
from jax.sharding import PartitionSpec as P


def test_trainerify_strips_dp_axes():
    specs = {"w": P("data", "model"), "e": P(("pod", "data"), None)}
    out = trainerify_pspecs(specs, dp_axes=("pod", "data"))
    assert out["w"] == P(("pod", "data"), None, "model")
    assert out["e"] == P(("pod", "data"), None, None)


def test_pure_dp_round_semantics():
    """T trainers, replicated params, H>1: the commit equals the weighted
    mean of independently-evolved replicas (computed on CPU, T=3)."""
    cfg = reduced_config(REGISTRY["qwen2-0.5b"])
    model = build_model(cfg)
    opt = make_optimizer(OptimizerSpec(name="sgdm", lr=0.05, grad_clip=1e9))
    T, H, B, S = 3, 2, 2, 16
    fl_round = build_fl_round(model, opt, FLRoundSpec(T, H, B))
    params = model.init_params(jax.random.key(0))
    params_T = jax.tree.map(lambda l: jnp.stack([l] * T), params)
    opt_T = jax.tree.map(lambda l: jnp.stack([l] * T), opt.init(params))
    rng = np.random.default_rng(5)
    toks = rng.integers(0, cfg.vocab_size, (T, H, B, S + 1))
    batches = {"tokens": jnp.asarray(toks[..., :-1], jnp.int32),
               "labels": jnp.asarray(toks[..., 1:], jnp.int32)}
    scores = jnp.array([0.9, 0.5, 0.2])
    out_T, _, m = jax.jit(fl_round)(params_T, opt_T, scores, batches)

    # reference: evolve each trainer independently H steps, weighted-mean
    def run_trainer(i):
        p, o = params, opt.init(params)
        for h in range(H):
            b = jax.tree.map(lambda x: x[i, h], batches)
            loss, g = jax.value_and_grad(lambda pp: model.loss(pp, b))(p)
            p, o, _ = opt.update(g, o, p)
        return p
    locals_ = [run_trainer(i) for i in range(T)]
    s = np.asarray(scores)
    want = jax.tree.map(
        lambda *xs: (sum(w * x.astype(jnp.float32)
                         for w, x in zip(s, xs)) / s.sum()),
        *locals_)
    for g, w in zip(jax.tree.leaves(out_T), jax.tree.leaves(want)):
        np.testing.assert_allclose(np.asarray(g[0], np.float32),
                                   np.asarray(w, np.float32),
                                   rtol=5e-2, atol=5e-3)
    assert np.all(np.asarray(m["distances"]) >= 0)


def test_serve_launcher_host_mesh(capsys):
    from repro.launch.serve import main
    main(["--arch", "qwen2-0.5b", "--host-mesh", "--reduced",
          "--batch", "2", "--prompt-len", "4", "--tokens", "3"])
    out = capsys.readouterr().out
    assert "served 2 x 7 steps" in out
