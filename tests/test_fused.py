"""Fused compiled window loop (core/fused.py): equivalence contract.

A fused Scheduler run and a Python-stepped run of the same schedule must
be bit-identical: typed event streams, state roots, gas logs, blocks,
confirm times, rollup provenance and task results.  Pinned here at two
levels:

  * FL end-to-end: full Scheduler runs (multi-task cohorts, background
    traffic, rollup on/off) with ``fused=True`` vs ``fused=False``;
  * ledger property: hypothesis-driven random window schedules (task
    counts, lane counts, batch sizes, prover capacities, seal cadence,
    gas mixes) on the raw VectorChain/VectorRollup pair.

Plus the fused program's shape: one ``lax.scan`` while-loop in the
packing kernel's HLO, cost ~linear in block count (analysis/hlo_cost).
"""
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from conftest import given, settings, st  # noqa: F401

from repro.core.engine import FnRegistry, TxArrays, VectorChain, VectorRollup
from repro.core.fused import FusedWindowLoop, supports_fused
from repro.core.workloads import make_workload
from repro.data.synthetic import gaussian_clusters
from repro.fl.cohort import CohortKernels, VectorCohort, batched_batch_fn
from repro.fl.dp import DPConfig
from repro.fl.scheduler import Scheduler
from repro.fl.server import AutoDFL
from repro.models.mlp import TinyMLP
from repro.optim.optimizers import OptimizerSpec, make_optimizer

D_IN, D_H, N_CLS = 32, 16, 10
BEHAVIORS = ["good", "good", "malicious", "lazy"]


@pytest.fixture(scope="module")
def tiny_world():
    model = TinyMLP(D_IN, D_H, N_CLS)
    opt = make_optimizer(OptimizerSpec(name="sgdm", lr=0.1, grad_clip=5.0))
    tr_x, tr_y = gaussian_clusters(1024, D_IN, N_CLS, seed=1, noise=0.5)
    vx, vy = gaussian_clusters(100, D_IN, N_CLS, seed=2, noise=0.5)
    val = {"x": jnp.asarray(vx), "labels": jnp.asarray(vy)}

    def bf(c, r):
        g = np.random.default_rng((c * 9973 + r) % 2**31)
        idx = g.integers(0, len(tr_x), 8)
        return {"x": jnp.asarray(tr_x[idx]), "labels": jnp.asarray(tr_y[idx])}

    kern = CohortKernels(model, opt, DPConfig(noise_multiplier=0.05))
    return model, opt, val, bf, model.accuracy_fn(), kern


def _run_schedule(world, fused, seal_every=2, bg=True, use_rollup=True,
                  n_tasks=3, n_lanes=1):
    model, opt, val, bf, eval_fn, kern = world
    n = len(BEHAVIORS)
    node = AutoDFL(model, opt, n, eval_fn, val, engine="vector",
                   use_rollup=use_rollup, trainer_funds=50.0)
    if use_rollup and n_lanes > 1:
        node.rollup.n_lanes = n_lanes
    background = make_workload("poisson", 20.0, duration=10.0, seed=3,
                               fn="bgPing") if bg else None
    sch = Scheduler(node, seal_every=seal_every, background=background,
                    fused=fused)
    for t in range(n_tasks):
        cohort = VectorCohort(model, opt, batched_batch_fn(bf, 2),
                              node.store, behaviors=BEHAVIORS,
                              local_steps=2,
                              dp=DPConfig(noise_multiplier=0.05), seed=t,
                              kernels=kern)
        sch.add_task(f"task{t}", cohort, rounds=3, start_window=t % 2)
    res = sch.run()
    return node, sch, res


def _assert_ledgers_equal(na, nb):
    """chain+rollup state equality down to provenance and event streams."""
    ea, eb = na.chain.events._events, nb.chain.events._events
    assert len(ea) == len(eb), (len(ea), len(eb))
    for x, y in zip(ea, eb):
        assert x == y, f"\nstepped {x}\nfused   {y}"
    assert na.chain.total_gas == nb.chain.total_gas
    assert na.chain.blocks == nb.chain.blocks
    np.testing.assert_array_equal(na.chain.confirm_times(),
                                  nb.chain.confirm_times())
    ra, rb = na.rollup, nb.rollup
    if ra is None:
        assert rb is None
        return
    assert ra.gas_log == rb.gas_log
    assert ra.batch_digests == rb.batch_digests
    assert ra.update_digest == rb.update_digest
    assert ra.batch_commit_ref == rb.batch_commit_ref
    assert ra.batch_settle_ref == rb.batch_settle_ref
    assert ra._prov_starts == rb._prov_starts
    for x, y in zip(ra._prov_batches, rb._prov_batches):
        np.testing.assert_array_equal(x, y)
    assert (ra.n_batches, ra._next_seq, ra._sealed_seq) == \
        (rb.n_batches, rb._next_seq, rb._sealed_seq)


# -- FL end-to-end: fused Scheduler == stepped Scheduler -----------------------
@pytest.mark.parametrize("cfg", [
    dict(seal_every=2, bg=True),
    dict(seal_every=0, bg=True),                  # seal only at flush
    dict(seal_every=1, bg=False, n_lanes=2, n_tasks=2),
    dict(seal_every=2, bg=True, use_rollup=False),    # chain-only node
], ids=["seal2-bg", "seal0-bg", "lanes2", "no-rollup"])
def test_fused_scheduler_bit_identical(tiny_world, cfg):
    na, sa, ra = _run_schedule(tiny_world, fused=False, **cfg)
    nb, sb, rb = _run_schedule(tiny_world, fused=True, **cfg)
    _assert_ledgers_equal(na, nb)
    assert na.state_arrays.root() == nb.state_arrays.root()
    for t in ra:
        np.testing.assert_array_equal(ra[t].scores, rb[t].scores)
        np.testing.assert_array_equal(ra[t].reputations, rb[t].reputations)
        assert ra[t].payouts == rb[t].payouts
    assert [repr(w) for w in sa.window_records] == \
        [repr(w) for w in sb.window_records]
    assert [repr(s) for s in sa.settlement_records] == \
        [repr(s) for s in sb.settlement_records]


def test_fused_auto_routes_vector_and_falls_back(tiny_world):
    """fused='auto' (the default) engages on VectorChain nodes; explicit
    fused=False never constructs a loop; supports_fused gates on types."""
    model, opt, val, bf, eval_fn, kern = tiny_world
    node = AutoDFL(model, opt, len(BEHAVIORS), eval_fn, val,
                   engine="vector", trainer_funds=50.0)
    assert supports_fused(node.chain, node.rollup)
    obj = AutoDFL(model, opt, len(BEHAVIORS), eval_fn, val,
                  engine="object", trainer_funds=50.0)
    assert not supports_fused(obj.chain, obj.rollup)
    # object engine under the default 'auto' must run the stepped path
    from repro.fl.client import ClientConfig, TrainingAgent
    agents = [TrainingAgent(
        ClientConfig(f"trainer{i}", BEHAVIORS[i], local_steps=2,
                     dp=DPConfig(noise_multiplier=0.05)),
        model, opt, obj.store, bf, seed=i) for i in range(len(BEHAVIORS))]
    sch = Scheduler(obj, seal_every=2)
    sch.add_task("t0", agents, rounds=2)
    res = sch.run()
    assert sch._loop is None and "t0" in res


# -- ledger property: random window schedules ---------------------------------
def _ledger_traffic(rng, n_tasks, n_windows, fns, max_txs):
    for f in ("publishTask", "submitLocalModel", "calculateObjectiveRep",
              "updateReputation"):
        fns.id(f)
    out, t = [], 0.0
    for _w in range(n_windows):
        row = []
        for _m in range(n_tasks):
            k = int(rng.integers(1, max_txs + 1))
            times = t + 0.01 * np.arange(1, k + 1)
            t = float(times[-1])
            row.append(TxArrays(
                times, rng.integers(21_000, 60_000, k).astype(np.int64),
                rng.integers(0, 4, k).astype(np.int32),
                rng.integers(0, 64, k).astype(np.int32), fns))
        out.append(row)
    return out


def _drive(chain, rollup, loop, traffic, seal_every, use_rollup):
    target = rollup if use_rollup else chain
    face = loop if loop is not None else target
    t = 0.0
    for w, row in enumerate(traffic):
        for b in row:
            loop.submit(target, b) if loop is not None \
                else target.submit_arrays(b)
        if use_rollup and seal_every and (w + 1) % seal_every == 0:
            face.seal()
        t_end = max(t + 1.0, float(row[-1].submit_time[-1]))
        if use_rollup:
            face.pump(t_end)
        (loop or chain).run_until(t_end)
        t = t_end
    if use_rollup:
        face.flush()
    (loop or chain).run_until(t + 3.0)
    if loop is not None:
        loop.execute()


class _N:
    """Minimal node shim for _assert_ledgers_equal."""

    def __init__(self, chain, rollup):
        self.chain, self.rollup = chain, rollup


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 8), st.integers(2, 8),
       st.sampled_from([1, 2, 4]), st.sampled_from([0, 1, 2, 3]),
       st.sampled_from([2, 4, 8]), st.booleans())
def test_fused_ledger_property(seed, n_tasks, n_windows, n_lanes,
                               seal_every, batch_size, use_rollup):
    """Random task/lane/prover/seal configs: the fused plan replay leaves
    the ledger bit-identical to the stepped calls it journals."""
    def build():
        chain = VectorChain()
        rollup = None
        if use_rollup:
            rollup = VectorRollup(chain, n_lanes=n_lanes,
                                  batch_size=batch_size, agg_width=4,
                                  prover_capacity=2)
        return chain, rollup

    rng = np.random.default_rng(seed)
    fns = FnRegistry()
    raw = _ledger_traffic(rng, n_tasks, n_windows, fns, max_txs=6)

    ca, ra_ = build()
    _drive(ca, ra_, None, raw, seal_every, use_rollup)
    cb, rb_ = build()
    loop = FusedWindowLoop(cb, rb_)
    _drive(cb, rb_, loop, raw, seal_every, use_rollup)
    _assert_ledgers_equal(_N(ca, ra_), _N(cb, rb_))


def test_fused_loop_single_use():
    chain = VectorChain()
    loop = FusedWindowLoop(chain)
    loop.run_until(1.0)
    loop.execute()
    with pytest.raises(AssertionError):
        loop.execute()


def test_fused_adopts_preexisting_pending():
    """Txs staged on the rollup BEFORE the loop exists are covered by the
    loop's first planned seal, exactly like a stepped seal would."""
    def build():
        chain = VectorChain()
        return chain, VectorRollup(chain, n_lanes=2, agg_width=4)

    fns = FnRegistry()
    early = TxArrays(np.array([0.01, 0.02]), np.array([30_000, 30_000]),
                     np.array([fns.id("publishTask")] * 2, np.int32),
                     np.array([0, 1], np.int32), fns)
    late = TxArrays(np.array([0.5]), np.array([30_000]),
                    np.array([fns.id("publishTask")], np.int32),
                    np.array([2], np.int32), fns)

    ca, ra = build()
    ra.submit_arrays(early)
    ra.submit_arrays(late)
    ra.seal()
    ra.pump(2.0)
    ca.run_until(2.0)
    ra.flush()

    cb, rb = build()
    rb.submit_arrays(early)          # staged pre-loop
    loop = FusedWindowLoop(cb, rb)
    loop.submit(rb, late)
    loop.seal()
    loop.pump(2.0)
    loop.run_until(2.0)
    loop.flush()
    loop.execute()
    _assert_ledgers_equal(_N(ca, ra), _N(cb, rb))


# -- fused program shape: HLO cost of the packing scan ------------------------
def test_block_pack_scan_hlo_cost():
    from repro.analysis.hlo_cost import analyze
    from repro.kernels.block_pack import fused_scan_lowering
    small = analyze(fused_scan_lowering(1024, 16))
    big = analyze(fused_scan_lowering(1024, 64))
    # one sequential while-loop over blocks, cost ~linear in block count:
    # 4x the blocks => ~4x the flops (same mempool, same search depth)
    assert small.flops > 0
    ratio = big.flops / small.flops
    assert 2.0 <= ratio <= 8.0, ratio
    hlo = fused_scan_lowering(1024, 64)
    assert hlo.count("while(") + hlo.count("while (") >= 1
