"""Fused shard-parallel fabric (core/fused.py x core/shards.py).

The fused window loop runs a ``ShardedRollup`` as K shard lanes: routing
at record time, per-lane seal precompute, one batched ``shard_seal``
digest fold, every window closed through ``_finish_window``.  Pinned
here — a fused fabric run is bit-identical to the stepped fabric:

  * typed event streams, blocks, confirm times, L1 gas;
  * fabric gas logs, digests, fabric roots, flat state root;
  * per-shard provenance (commit/settle refs, prov batches, seq counters)
    and the per-tx ``(shard, seq)`` receipts ``submit`` returns;
  * the interconnect wire log per kind (the fused loop defers window
    merges to ``execute()``, so only the interleaving may differ);

across shard counts x routing policy x seal cadence x random traffic
(hypothesis), plus: the one-shard fused fabric vs a plain VectorRollup,
the mesh-mapped ``shard_seal`` path (``mesh="on"``), a full Scheduler
end-to-end run, the ``fused="auto"`` fallback log, and the
``capabilities()`` path marker.
"""
import logging

import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from conftest import given, settings, st  # noqa: F401

from repro.core.engine import FnRegistry, TxArrays, VectorChain, VectorRollup
from repro.core.fused import FusedWindowLoop, supports_fused
from repro.core.shards import ShardedRollup
from repro.core.state import default_state_handlers

BEHAVIORS = ["good", "good", "malicious", "lazy"]


def _build_fabric(k, route="hash", mesh="off"):
    chain = VectorChain()
    fabric = ShardedRollup(chain, n_shards=k, batch_size=4, n_lanes=2,
                           agg_width=4, prover_capacity=2, route=route,
                           mesh=mesh)
    for fn, handler in default_state_handlers().items():
        fabric.register_state(fn, handler)
    return chain, fabric


def _fabric_traffic(rng, n_windows, n_tasks, fns, pin_tasks, n_shards,
                    max_txs=6):
    """Windows of (batch, shard-pin) pairs; even tasks pin to a random
    shard when ``pin_tasks`` (the protocol's task-level routing), odd
    tasks route by policy."""
    for f in ("publishTask", "submitLocalModel", "calculateObjectiveRep",
              "updateReputation"):
        fns.id(f)
    out, t = [], 0.0
    for _w in range(n_windows):
        row = []
        for m in range(n_tasks):
            k = int(rng.integers(1, max_txs + 1))
            times = t + 0.01 * np.arange(1, k + 1)
            t = float(times[-1])
            pin = int(rng.integers(0, n_shards)) \
                if pin_tasks and m % 2 == 0 else None
            row.append((TxArrays(
                times, rng.integers(21_000, 60_000, k).astype(np.int64),
                rng.integers(0, 4, k).astype(np.int32),
                rng.integers(0, 64, k).astype(np.int32), fns), pin))
        out.append(row)
    return out


def _drive(chain, fabric, loop, traffic, seal_every):
    """One window schedule, stepped (loop=None) or fused; returns the
    per-submission (shard_of, seq_of) provenance."""
    face = loop if loop is not None else fabric
    prov, t = [], 0.0
    for w, row in enumerate(traffic):
        for batch, pin in row:
            prov.append(face.submit(fabric, batch, shard=pin)
                        if loop is not None
                        else fabric.submit_arrays(batch, shard=pin))
        if seal_every and (w + 1) % seal_every == 0:
            face.seal()
        t_end = max(t + 1.0, float(row[-1][0].submit_time[-1]))
        face.pump(t_end)
        (loop if loop is not None else chain).run_until(t_end)
        t = t_end
    face.flush()
    (loop if loop is not None else chain).run_until(t + 3.0)
    if loop is not None:
        loop.execute()
    return prov


def _wire_by_kind(ic):
    out = {}
    for r in ic.log:
        out.setdefault(r["kind"], []).append(r)
    return out


def _assert_fabrics_equal(ca, fa, cb, fb):
    ea, eb = ca.events._events, cb.events._events
    assert len(ea) == len(eb), (len(ea), len(eb))
    for x, y in zip(ea, eb):
        assert x == y, f"\nstepped {x}\nfused   {y}"
    assert ca.total_gas == cb.total_gas
    assert ca.blocks == cb.blocks
    np.testing.assert_array_equal(ca.confirm_times(), cb.confirm_times())
    assert fa.gas_log == fb.gas_log
    assert fa.batch_digests == fb.batch_digests
    assert fa.update_digest == fb.update_digest
    assert fa.state_root() == fb.state_root()
    assert fa.fabric_root() == fb.fabric_root()
    assert fa.fabric_roots == fb.fabric_roots
    np.testing.assert_array_equal(fa._submitted, fb._submitted)
    for sa, sb in zip(fa.shards, fb.shards):
        assert sa.batch_commit_ref == sb.batch_commit_ref
        assert sa.batch_settle_ref == sb.batch_settle_ref
        assert sa._prov_starts == sb._prov_starts
        for x, y in zip(sa._prov_batches, sb._prov_batches):
            np.testing.assert_array_equal(x, y)
        assert (sa.n_batches, sa._next_seq, sa._sealed_seq) == \
            (sb.n_batches, sb._next_seq, sb._sealed_seq)
    # wire logs match per kind and in total; only the interleaving may
    # differ (the fused loop defers window merges to execute())
    assert _wire_by_kind(fa.interconnect) == _wire_by_kind(fb.interconnect)
    assert fa.interconnect.summary() == fb.interconnect.summary()


def _assert_provenance_equal(pa, pb):
    for (sa, qa), (sb, qb) in zip(pa, pb):
        np.testing.assert_array_equal(sa, sb)
        np.testing.assert_array_equal(qa, qb)


# -- pinned: fused fabric == stepped fabric ------------------------------------
@pytest.mark.parametrize("route", ["hash", "least_loaded"])
@pytest.mark.parametrize("k", [1, 2, 4, 8])
def test_fused_fabric_bit_identical(k, route):
    fns = FnRegistry()
    traffic = _fabric_traffic(np.random.default_rng(42 + k), 5, 3, fns,
                              pin_tasks=True, n_shards=k)
    ca, fa = _build_fabric(k, route)
    pa = _drive(ca, fa, None, traffic, seal_every=2)
    cb, fb = _build_fabric(k, route)
    pb = _drive(cb, fb, FusedWindowLoop(cb, fb), traffic, seal_every=2)
    _assert_provenance_equal(pa, pb)
    _assert_fabrics_equal(ca, fa, cb, fb)


def test_fused_fabric_mesh_on_bit_identical():
    """mesh="on" routes the digest fold through the shard_map kernel —
    still bit-identical to the stepped fabric (mesh is a pure
    performance knob)."""
    fns = FnRegistry()
    traffic = _fabric_traffic(np.random.default_rng(77), 4, 3, fns,
                              pin_tasks=True, n_shards=4)
    ca, fa = _build_fabric(4, "hash", mesh="off")
    _drive(ca, fa, None, traffic, seal_every=2)
    cb, fb = _build_fabric(4, "hash", mesh="on")
    loop = FusedWindowLoop(cb, fb)
    assert loop._shard_seal_impl() == "shard_map"
    _drive(cb, fb, loop, traffic, seal_every=2)
    _assert_fabrics_equal(ca, fa, cb, fb)


def test_mesh_mode_selects_shard_seal_impl():
    from repro.launch.mesh import n_local_devices
    for mode, want in [("on", "shard_map"), ("off", "numpy"),
                       ("auto", "shard_map" if n_local_devices() > 1
                        else "numpy")]:
        chain, fabric = _build_fabric(2, mesh=mode)
        assert FusedWindowLoop(chain, fabric)._shard_seal_impl() == want


def test_one_shard_fused_fabric_matches_vector_rollup():
    """n_shards=1 through the fused loop == a plain stepped VectorRollup
    (the fabric's one-lane degenerate case, modulo the shard tag)."""
    fns = FnRegistry()
    traffic = _fabric_traffic(np.random.default_rng(7), 4, 2, fns,
                              pin_tasks=False, n_shards=1)
    chain_a = VectorChain()
    ru = VectorRollup(chain_a, batch_size=4, n_lanes=2, agg_width=4,
                      prover_capacity=2)
    for fn, handler in default_state_handlers().items():
        ru.register_state(fn, handler)
    t = 0.0
    for w, row in enumerate(traffic):
        for batch, _ in row:
            ru.submit_arrays(batch)
        if (w + 1) % 2 == 0:
            ru.seal()
        t_end = max(t + 1.0, float(row[-1][0].submit_time[-1]))
        ru.pump(t_end)
        chain_a.run_until(t_end)
        t = t_end
    ru.flush()
    chain_a.run_until(t + 3.0)

    cb, fb = _build_fabric(1, "hash")
    _drive(cb, fb, FusedWindowLoop(cb, fb), traffic, seal_every=2)
    assert [{k: v for k, v in r.items() if k != "shard"}
            for r in fb.gas_log] == ru.gas_log
    assert fb.batch_digests == ru.batch_digests
    assert fb.update_digest == ru.update_digest
    assert fb.shards[0].batch_commit_ref == ru.batch_commit_ref
    assert fb.state_root() == ru.state_arrays.root()


# -- property: shard counts x routing x cadence x random traffic ---------------
@settings(max_examples=12, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.sampled_from([1, 2, 4, 8]),
       st.sampled_from(["hash", "least_loaded"]),
       st.sampled_from([0, 1, 2, 3]), st.booleans())
def test_fused_fabric_property(seed, n_shards, route, seal_every,
                               pin_tasks):
    rng = np.random.default_rng(seed)
    fns = FnRegistry()
    traffic = _fabric_traffic(rng, int(rng.integers(2, 6)),
                              int(rng.integers(1, 4)), fns,
                              pin_tasks=pin_tasks, n_shards=n_shards)
    ca, fa = _build_fabric(n_shards, route)
    pa = _drive(ca, fa, None, traffic, seal_every)
    cb, fb = _build_fabric(n_shards, route)
    pb = _drive(cb, fb, FusedWindowLoop(cb, fb), traffic, seal_every)
    _assert_provenance_equal(pa, pb)
    _assert_fabrics_equal(ca, fa, cb, fb)


# -- FL end-to-end: Scheduler over a fabric node -------------------------------
@pytest.fixture(scope="module")
def tiny_world():
    from repro.data.synthetic import gaussian_clusters
    from repro.fl.cohort import CohortKernels
    from repro.fl.dp import DPConfig
    from repro.models.mlp import TinyMLP
    from repro.optim.optimizers import OptimizerSpec, make_optimizer
    model = TinyMLP(32, 16, 10)
    opt = make_optimizer(OptimizerSpec(name="sgdm", lr=0.1, grad_clip=5.0))
    tr_x, tr_y = gaussian_clusters(1024, 32, 10, seed=1, noise=0.5)
    vx, vy = gaussian_clusters(100, 32, 10, seed=2, noise=0.5)
    val = {"x": jnp.asarray(vx), "labels": jnp.asarray(vy)}

    def bf(c, r):
        g = np.random.default_rng((c * 9973 + r) % 2 ** 31)
        idx = g.integers(0, len(tr_x), 8)
        return {"x": jnp.asarray(tr_x[idx]),
                "labels": jnp.asarray(tr_y[idx])}

    kern = CohortKernels(model, opt, DPConfig(noise_multiplier=0.05))
    return model, opt, val, bf, model.accuracy_fn(), kern


def _run_fabric_schedule(world, fused, n_shards=2, route="hash"):
    from repro.api.specs import ChainSpec, NodeSpec, ShardSpec
    from repro.fl.cohort import VectorCohort, batched_batch_fn
    from repro.fl.dp import DPConfig
    from repro.fl.scheduler import Scheduler
    from repro.fl.server import AutoDFL
    model, opt, val, bf, eval_fn, kern = world
    spec = NodeSpec(chain=ChainSpec(backend="vector"),
                    shards=ShardSpec(count=n_shards, fabric=True,
                                     route=route, mesh="off"),
                    trainer_funds=50.0)
    node = AutoDFL(model, opt, len(BEHAVIORS), eval_fn, val, spec=spec)
    sch = Scheduler(node, seal_every=2, fused=fused)
    for i in range(2):
        cohort = VectorCohort(model, opt, batched_batch_fn(bf, 2),
                              node.store, behaviors=BEHAVIORS,
                              local_steps=2,
                              dp=DPConfig(noise_multiplier=0.05), seed=i,
                              kernels=kern)
        sch.add_task(f"task{i}", cohort, rounds=2, start_window=i % 2)
    res = sch.run()
    return node, sch, res


def test_fused_fabric_scheduler_end_to_end(tiny_world, monkeypatch):
    """Full protocol runs (fused='auto' engages the loop on the fabric)
    match the stepped runs: ledgers, fabric roots, results, records."""
    executed = []
    orig = FusedWindowLoop.execute
    monkeypatch.setattr(
        FusedWindowLoop, "execute",
        lambda self: (executed.append(type(self.rollup).__name__),
                      orig(self))[1])
    na, sa, ra = _run_fabric_schedule(tiny_world, fused=False)
    assert executed == []
    nb, sb, rb = _run_fabric_schedule(tiny_world, fused="auto")
    assert executed == ["ShardedRollup"]
    _assert_fabrics_equal(na.chain, na.rollup, nb.chain, nb.rollup)
    assert na.state_arrays.root() == nb.state_arrays.root()
    for t in ra:
        np.testing.assert_array_equal(ra[t].scores, rb[t].scores)
        np.testing.assert_array_equal(ra[t].reputations,
                                      rb[t].reputations)
        assert ra[t].payouts == rb[t].payouts
    assert [repr(w) for w in sa.window_records] == \
        [repr(w) for w in sb.window_records]
    assert [repr(s) for s in sa.settlement_records] == \
        [repr(s) for s in sb.settlement_records]


# -- fused="auto" fallback: one-time log + capability marker -------------------
def test_fused_auto_fallback_logs_once(tiny_world, caplog):
    import repro.fl.scheduler as sched_mod
    from repro.api.specs import ChainSpec, NodeSpec
    from repro.fl.client import ClientConfig, TrainingAgent
    from repro.fl.dp import DPConfig
    from repro.fl.scheduler import Scheduler
    from repro.fl.server import AutoDFL
    model, opt, val, bf, eval_fn, kern = tiny_world
    obj = AutoDFL(model, opt, len(BEHAVIORS), eval_fn, val,
                  spec=NodeSpec(chain=ChainSpec(backend="object"),
                                trainer_funds=50.0))
    assert not supports_fused(obj.chain, obj.rollup)

    def agents(seed0):
        return [TrainingAgent(
            ClientConfig(f"trainer{i}", BEHAVIORS[i], local_steps=2,
                         dp=DPConfig(noise_multiplier=0.05)),
            model, opt, obj.store, bf, seed=seed0 + i)
            for i in range(len(BEHAVIORS))]

    sched_mod._FUSED_FALLBACK_WARNED.clear()
    with caplog.at_level(logging.INFO, logger="repro.fl.scheduler"):
        sch = Scheduler(obj, seal_every=2)
        sch.add_task("t0", agents(0), rounds=2)
        sch.run()
        assert sch._loop is None
        # a second run on the same stack shape stays silent
        sch2 = Scheduler(obj, seal_every=2)
        sch2.add_task("t1", agents(10), rounds=1)
        sch2.run()
    msgs = [r for r in caplog.records if "not fused-capable" in r.message]
    assert len(msgs) == 1
    assert "Chain/Rollup" in msgs[0].getMessage()


def test_fused_auto_engaged_stays_silent(tiny_world, caplog):
    import repro.fl.scheduler as sched_mod
    sched_mod._FUSED_FALLBACK_WARNED.clear()
    with caplog.at_level(logging.INFO, logger="repro.fl.scheduler"):
        node, _, _ = _run_fabric_schedule(tiny_world, fused="auto")
    assert supports_fused(node.chain, node.rollup)
    assert not [r for r in caplog.records
                if "not fused-capable" in r.message]


def test_capabilities_surface_fused_path():
    from repro.api import NodeClient
    from repro.api.specs import ChainSpec, NodeSpec, ShardSpec
    fab = NodeClient.from_spec(NodeSpec(
        chain=ChainSpec(backend="vector"),
        shards=ShardSpec(count=2, fabric=True)))
    assert "fused_window_loop" in fab.capabilities()
    vec = NodeClient.from_spec(NodeSpec(chain=ChainSpec(backend="vector")))
    assert "fused_window_loop" in vec.capabilities()
    obj = NodeClient.from_spec(NodeSpec(chain=ChainSpec(backend="object")))
    assert "fused_window_loop" not in obj.capabilities()
