"""HLO cost walker: validated against XLA's cost_analysis on loop-free
programs and against hand-computed costs on scanned programs."""
import jax
import jax.numpy as jnp
import pytest

from repro.analysis.hlo_cost import analyze, parse_shapes


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def _xla_cost(compiled):
    """compiled.cost_analysis() returns list[dict] on jax 0.4.x, a dict on
    newer releases; normalize to the dict."""
    ca = compiled.cost_analysis()
    return ca[0] if isinstance(ca, (list, tuple)) else ca


def test_matches_xla_on_loop_free():
    d = 128
    def f(x, w):
        return jnp.tanh(x @ w) @ w
    c = _compile(f, jax.ShapeDtypeStruct((d, d), jnp.float32),
                 jax.ShapeDtypeStruct((d, d), jnp.float32))
    got = analyze(c.as_text())
    xla = _xla_cost(c)
    assert abs(got.flops - xla["flops"]) / xla["flops"] < 0.05
    assert abs(got.bytes - xla["bytes accessed"]) / xla["bytes accessed"] < 0.3


def test_scan_trip_count_multiplies():
    d, L = 64, 16
    def body(x, w):
        return jnp.tanh(x @ w), None
    def f(x, ws):
        return jax.lax.scan(body, x, ws)[0]
    c = _compile(f, jax.ShapeDtypeStruct((d, d), jnp.float32),
                 jax.ShapeDtypeStruct((L, d, d), jnp.float32))
    got = analyze(c.as_text())
    expect = 2 * d * d * d * L          # matmul flops only (tanh adds ~d*d*L)
    assert expect <= got.flops <= expect * 1.2
    # XLA undercounts by ~L (this is WHY the walker exists)
    assert _xla_cost(c)["flops"] < expect / 2


def test_nested_scan_multiplies_twice():
    d, L1, L2 = 32, 4, 6
    def inner(x, w):
        return x @ w, None
    def outer(x, ws):
        def body(x, _):
            return jax.lax.scan(inner, x, ws)[0], None
        return jax.lax.scan(body, x, None, length=L1)[0]
    c = _compile(outer, jax.ShapeDtypeStruct((d, d), jnp.float32),
                 jax.ShapeDtypeStruct((L2, d, d), jnp.float32))
    got = analyze(c.as_text())
    expect = 2 * d ** 3 * L1 * L2
    assert expect * 0.9 <= got.flops <= expect * 1.3


def test_collectives_counted_with_loop_multiplier():
    import os
    if jax.device_count() < 4:
        pytest.skip("needs multi-device (run under dryrun env)")


def test_shape_parse():
    shapes = parse_shapes("(s32[], f32[8,16]{1,0}, bf16[2,3,4]{2,1,0})")
    assert [s.dtype for s in shapes] == ["s32", "f32", "bf16"]
    assert shapes[1].bytes == 8 * 16 * 4
    assert shapes[2].bytes == 24 * 2


def test_dot_flops_with_batch_dims():
    def f(x, y):
        return jnp.einsum("bij,bjk->bik", x, y)
    c = _compile(f, jax.ShapeDtypeStruct((4, 8, 16), jnp.float32),
                 jax.ShapeDtypeStruct((4, 16, 8), jnp.float32))
    got = analyze(c.as_text())
    assert got.flops >= 2 * 4 * 8 * 16 * 8


def test_remat_scan_counts_recompute():
    """checkpointed scan body: bwd re-runs fwd — walker must see ~4x fwd."""
    d, L = 32, 8
    def body(x, w):
        return jnp.tanh(x @ w), None
    def loss(x, ws):
        y = jax.lax.scan(jax.checkpoint(body), x, ws)[0]
        return jnp.sum(y)
    g = jax.grad(loss)
    c = _compile(g, jax.ShapeDtypeStruct((d, d), jnp.float32),
                 jax.ShapeDtypeStruct((L, d, d), jnp.float32))
    got = analyze(c.as_text())
    fwd = 2 * d ** 3 * L
    assert got.flops > 2.5 * fwd        # fwd + recompute + 2 bwd matmuls
