"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.flash_attention import flash_attention
from repro.kernels.gmm import gmm
from repro.kernels.model_distance import model_distance
from repro.kernels.rollup_digest import rollup_digest
from repro.kernels.weighted_agg import weighted_agg

RNG = np.random.default_rng(42)


def _tol(dt):
    return dict(rtol=2e-2, atol=2e-2) if dt == jnp.bfloat16 \
        else dict(rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("n,P,dt,block", [
    (2, 256, jnp.float32, 128),
    (4, 1000, jnp.float32, 512),       # padded tail
    (16, 8192, jnp.bfloat16, 2048),
    (64, 4096, jnp.bfloat16, 4096),
    (3, 130, jnp.float32, 512),        # P < block
])
def test_weighted_agg_sweep(n, P, dt, block):
    w = jnp.asarray(RNG.normal(size=(n, P)), dt)
    s = jnp.asarray(RNG.uniform(0.05, 1.0, n), jnp.float32)
    got = weighted_agg(w, s, block_p=block, interpret=True)
    want = ops.weighted_agg_ref(w, s)
    np.testing.assert_allclose(got.astype(np.float32),
                               want.astype(np.float32), **_tol(dt))


def test_weighted_agg_zero_score_trainer_excluded():
    w = jnp.stack([jnp.ones(256), 100.0 * jnp.ones(256)])
    s = jnp.array([1.0, 0.0])
    out = weighted_agg(w.astype(jnp.float32), s, block_p=128, interpret=True)
    np.testing.assert_allclose(out, jnp.ones(256), rtol=1e-6)


@pytest.mark.parametrize("n,P,dt", [
    (4, 1000, jnp.float32),
    (8, 5000, jnp.bfloat16),
    (1, 128, jnp.float32),
])
def test_model_distance_sweep(n, P, dt):
    l = jnp.asarray(RNG.normal(size=(n, P)), dt)
    g = jnp.asarray(RNG.normal(size=(P,)), dt)
    got = model_distance(l, g, block_p=512, interpret=True)
    want = ops.model_distance_ref(l, g)
    np.testing.assert_allclose(got, want, rtol=3e-2 if dt == jnp.bfloat16
                               else 1e-4)


@pytest.mark.parametrize("B,S,H,Hkv,dh,dt", [
    (2, 256, 4, 2, 64, jnp.float32),
    (1, 512, 8, 8, 32, jnp.float32),
    (2, 256, 8, 2, 64, jnp.bfloat16),
    (1, 128, 4, 1, 128, jnp.float32),      # MQA
])
def test_flash_attention_sweep(B, S, H, Hkv, dh, dt):
    q = jnp.asarray(RNG.normal(size=(B, S, H, dh)), dt)
    k = jnp.asarray(RNG.normal(size=(B, S, Hkv, dh)), dt)
    v = jnp.asarray(RNG.normal(size=(B, S, Hkv, dh)), dt)
    got = flash_attention(q, k, v, block_q=128, block_k=128, interpret=True)
    want = ops.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(got.astype(np.float32),
                               want.astype(np.float32), **_tol(dt))


def test_flash_attention_non_causal():
    q = jnp.asarray(RNG.normal(size=(1, 256, 2, 32)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(1, 256, 2, 32)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(1, 256, 2, 32)), jnp.float32)
    got = flash_attention(q, k, v, causal=False, block_q=128, block_k=128,
                          interpret=True)
    want = ops.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("E,C,d,f,dt", [
    (8, 96, 64, 200, jnp.float32),
    (4, 128, 128, 512, jnp.bfloat16),
    (1, 8, 32, 64, jnp.float32),
])
def test_gmm_sweep(E, C, d, f, dt):
    xe = jnp.asarray(RNG.normal(size=(E, C, d)), dt)
    w = jnp.asarray(RNG.normal(size=(E, d, f)), dt)
    got = gmm(xe, w, block_c=32, block_f=64, interpret=True)
    want = ops.gmm_ref(xe, w)
    np.testing.assert_allclose(got.astype(np.float32),
                               want.astype(np.float32), **_tol(dt))


@pytest.mark.parametrize("P", [128, 10000, 65536])
def test_rollup_digest_sweep(P):
    buf = jnp.asarray(RNG.normal(size=(P,)), jnp.float32)
    got = rollup_digest(buf, block_p=2048, interpret=True)
    want = ops.rollup_digest_ref(
        jax.lax.bitcast_convert_type(buf, jnp.uint32))
    assert got == want


def test_rollup_digest_detects_tampering():
    buf = jnp.asarray(RNG.normal(size=(4096,)), jnp.float32)
    d0 = rollup_digest(buf, interpret=True)
    d1 = rollup_digest(buf.at[1234].add(1e-6), interpret=True)
    assert d0 != d1


# -- ledger hot-path kernels: numpy / jax / pallas pinned BIT-EXACT -----------
# (these are integer/bit-pattern kernels — no tolerance, any backend, any
# JAX_ENABLE_X64 setting; CI runs this module on the {x64 on, x64 off}
# matrix with JAX_PLATFORMS=cpu pinned)

def _pack_stream(n_txs, n_blocks, seed, gas_limit):
    """Random mempool + block grid in produce_block's representation."""
    g = np.random.default_rng(seed)
    submit = np.cumsum(g.exponential(0.02, n_txs))
    tmax = np.maximum.accumulate(submit)
    gcum = np.cumsum(g.integers(21_000, 120_000, n_txs).astype(np.int64))
    times = np.cumsum(g.uniform(0.05, 1.5, n_blocks))
    # nondecreasing visibility: txs stage between block edges
    n_vis = np.sort(g.integers(0, n_txs + 1, n_blocks)).astype(np.int64)
    return tmax, gcum, times, n_vis, gas_limit


@pytest.mark.parametrize("n_txs,n_blocks,seed,gas_limit", [
    (1, 1, 0, 9_000_000),
    (100, 7, 1, 9_000_000),
    (1000, 33, 2, 300_000),            # gas-capped: head-of-line carry
    (513, 16, 3, 2**40),               # limit above any cumsum: time-bound
    (64, 5, 4, 21_000),                # ~one tx per block
])
def test_block_pack_impls_bit_exact(n_txs, n_blocks, seed, gas_limit):
    from repro.kernels.block_pack import (block_pack_jax, block_pack_np,
                                          block_pack_pallas)
    args = _pack_stream(n_txs, n_blocks, seed, gas_limit)
    want = block_pack_np(*args, 0)
    assert want.dtype == np.int64
    np.testing.assert_array_equal(block_pack_jax(*args, 0), want)
    np.testing.assert_array_equal(
        block_pack_pallas(*args, 0, interpret=True), want)
    # nonzero start pointer (mid-run mempool state)
    p0 = int(want[0])
    want_p = block_pack_np(*args, p0)
    np.testing.assert_array_equal(block_pack_jax(*args, p0), want_p)


def test_block_pack_matches_stepped_produce_block():
    """The kernel IS produce_block's packing decision, N blocks at once."""
    from repro.core.engine import FnRegistry, TxArrays, VectorChain
    from repro.kernels.block_pack import block_pack_np
    g = np.random.default_rng(11)
    n = 200
    fns = FnRegistry()
    fid = fns.id("bgPing")
    batch = TxArrays(np.cumsum(g.exponential(0.05, n)),
                     g.integers(21_000, 90_000, n).astype(np.int64),
                     np.full(n, fid, np.int32), np.zeros(n, np.int32), fns)
    chain = VectorChain()
    chain.submit_arrays(batch)
    chain.run_until(float(batch.submit_time[-1]) + 2.0)
    stepped = [(b.start, b.stop) for b in chain.blocks[1:]]
    times = np.array([b.time for b in chain.blocks[1:]])
    chain2 = VectorChain()
    chain2.submit_arrays(batch)
    chain2._consolidate()
    stops = block_pack_np(chain2._tmax[:n], chain2._gcum[:n], times,
                          np.full(len(times), n, np.int64),
                          chain2.block_gas_limit, 0)
    starts = np.concatenate([[0], stops[:-1]])
    assert list(zip(starts.tolist(), stops.tolist())) == stepped


@pytest.mark.parametrize("n_words,n_segs,seed", [
    (4, 1, 0),
    (4096, 17, 1),
    (100_000, 257, 2),
    (128, 128, 3),                     # one word per segment
])
def test_batch_seal_impls_bit_exact(n_words, n_segs, seed):
    from repro.kernels.batch_seal import (batch_seal_jax, batch_seal_np,
                                          batch_seal_pallas)
    g = np.random.default_rng(seed)
    words = g.integers(0, 2**32, n_words, dtype=np.uint64).astype(np.uint32)
    cuts = np.sort(g.choice(np.arange(1, n_words), n_segs - 1,
                            replace=False)) if n_segs > 1 else \
        np.empty(0, np.int64)
    starts = np.concatenate([[0], cuts]).astype(np.int64)
    want = batch_seal_np(words, starts)
    assert want.dtype == np.uint32 and want.shape == (n_segs,)
    np.testing.assert_array_equal(batch_seal_jax(words, starts), want)
    np.testing.assert_array_equal(
        batch_seal_pallas(words, starts, interpret=True), want)


def test_batch_seal_matches_single_digest():
    """One segment == the scalar xor_fold_digest the object path uses."""
    from repro.core.engine import xor_fold_digest
    from repro.kernels.batch_seal import batch_seal_np
    g = np.random.default_rng(5)
    words = g.integers(0, 2**32, 777, dtype=np.uint64).astype(np.uint32)
    out = batch_seal_np(words, np.array([0], np.int64))
    assert int(out[0]) == xor_fold_digest(words)


@pytest.mark.parametrize("n_words,n_dirty,seed", [
    (1, 1, 0),
    (100, 1, 1),                       # single sub-chunk buffer
    (5_000, 2, 2),                     # padded tail chunk dirty
    (70_000, 7, 3),
    (300_000, 146, 4),                 # every chunk dirty (dup ids too)
])
def test_dirty_fold_impls_bit_exact(n_words, n_dirty, seed):
    from repro.core.state import STATE_CHUNK_WORDS, chunk_fold_digests
    from repro.kernels.dirty_fold import (dirty_fold_jax, dirty_fold_np,
                                          dirty_fold_pallas)
    g = np.random.default_rng(seed)
    words = g.integers(0, 2**32, n_words, dtype=np.uint64).astype(np.uint32)
    n_chunks = -(-n_words // STATE_CHUNK_WORDS)
    ids = g.integers(0, n_chunks, n_dirty)
    # the mirror IS the full fold restricted to the dirty ids
    want = chunk_fold_digests(words, STATE_CHUNK_WORDS)[ids]
    got = dirty_fold_np(words, ids, STATE_CHUNK_WORDS)
    assert got.dtype == np.uint32
    np.testing.assert_array_equal(got, want)
    np.testing.assert_array_equal(
        dirty_fold_jax(words, ids, STATE_CHUNK_WORDS), want)
    np.testing.assert_array_equal(
        dirty_fold_pallas(words, ids, STATE_CHUNK_WORDS, interpret=True),
        want)


def test_dirty_fold_empty_ids():
    from repro.core.state import STATE_CHUNK_WORDS
    from repro.kernels.dirty_fold import (dirty_fold_jax, dirty_fold_np,
                                          dirty_fold_pallas)
    words = np.arange(4096, dtype=np.uint32)
    none = np.empty(0, np.int64)
    for impl in (dirty_fold_np, dirty_fold_jax, dirty_fold_pallas):
        out = impl(words, none, STATE_CHUNK_WORDS)
        assert out.shape == (0,) and out.dtype == np.uint32


@pytest.mark.parametrize("n", [0, 1, 7, 513, 4096])
def test_rollup_digest_factory_impls_bit_exact(n):
    """The factory's three rollup_digest impls agree bit-for-bit with the
    NumPy semantics-of-record mirror (R002's machine-checked contract).
    The pallas impl runs un-interpreted only on TPU, so parity for it is
    pinned at the kernel level (test_rollup_digest_sweep); here the
    portable numpy/jax pair must match on any backend."""
    from repro.kernels import factory
    rng = np.random.default_rng(2024 + n)
    words = rng.integers(0, 2**32, n, dtype=np.uint32)
    want = factory.get_kernel("rollup_digest", "numpy")(words)
    got = factory.get_kernel("rollup_digest", "jax")(words)
    assert got == want


def test_kernel_factory_selection():
    from repro.kernels import factory
    from repro.kernels.block_pack import block_pack_np
    assert factory.get_kernel("block_pack", "numpy") is block_pack_np
    assert set(factory.available_impls("block_pack")) == \
        {"numpy", "jax", "pallas"}
    assert set(factory.available_impls("batch_seal")) == \
        {"numpy", "jax", "pallas"}
    assert set(factory.available_impls("dirty_fold")) == \
        {"numpy", "jax", "pallas"}
    assert set(factory.available_impls("rollup_digest")) == \
        {"numpy", "jax", "pallas"}
    with pytest.raises(KeyError, match="unknown kernel op"):
        factory.get_kernel("no_such_op")
    with pytest.raises(KeyError, match="no impl"):
        factory.get_kernel("block_pack", "cuda")
    # env-var override is honored by the default resolution path
    import os
    old = os.environ.get("REPRO_KERNEL_IMPL")
    os.environ["REPRO_KERNEL_IMPL"] = "numpy"
    try:
        assert factory.get_kernel("batch_seal") is \
            factory.get_kernel("batch_seal", "numpy")
    finally:
        if old is None:
            del os.environ["REPRO_KERNEL_IMPL"]
        else:
            os.environ["REPRO_KERNEL_IMPL"] = old
