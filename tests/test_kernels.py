"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.flash_attention import flash_attention
from repro.kernels.gmm import gmm
from repro.kernels.model_distance import model_distance
from repro.kernels.rollup_digest import rollup_digest
from repro.kernels.weighted_agg import weighted_agg

RNG = np.random.default_rng(42)


def _tol(dt):
    return dict(rtol=2e-2, atol=2e-2) if dt == jnp.bfloat16 \
        else dict(rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("n,P,dt,block", [
    (2, 256, jnp.float32, 128),
    (4, 1000, jnp.float32, 512),       # padded tail
    (16, 8192, jnp.bfloat16, 2048),
    (64, 4096, jnp.bfloat16, 4096),
    (3, 130, jnp.float32, 512),        # P < block
])
def test_weighted_agg_sweep(n, P, dt, block):
    w = jnp.asarray(RNG.normal(size=(n, P)), dt)
    s = jnp.asarray(RNG.uniform(0.05, 1.0, n), jnp.float32)
    got = weighted_agg(w, s, block_p=block, interpret=True)
    want = ops.weighted_agg_ref(w, s)
    np.testing.assert_allclose(got.astype(np.float32),
                               want.astype(np.float32), **_tol(dt))


def test_weighted_agg_zero_score_trainer_excluded():
    w = jnp.stack([jnp.ones(256), 100.0 * jnp.ones(256)])
    s = jnp.array([1.0, 0.0])
    out = weighted_agg(w.astype(jnp.float32), s, block_p=128, interpret=True)
    np.testing.assert_allclose(out, jnp.ones(256), rtol=1e-6)


@pytest.mark.parametrize("n,P,dt", [
    (4, 1000, jnp.float32),
    (8, 5000, jnp.bfloat16),
    (1, 128, jnp.float32),
])
def test_model_distance_sweep(n, P, dt):
    l = jnp.asarray(RNG.normal(size=(n, P)), dt)
    g = jnp.asarray(RNG.normal(size=(P,)), dt)
    got = model_distance(l, g, block_p=512, interpret=True)
    want = ops.model_distance_ref(l, g)
    np.testing.assert_allclose(got, want, rtol=3e-2 if dt == jnp.bfloat16
                               else 1e-4)


@pytest.mark.parametrize("B,S,H,Hkv,dh,dt", [
    (2, 256, 4, 2, 64, jnp.float32),
    (1, 512, 8, 8, 32, jnp.float32),
    (2, 256, 8, 2, 64, jnp.bfloat16),
    (1, 128, 4, 1, 128, jnp.float32),      # MQA
])
def test_flash_attention_sweep(B, S, H, Hkv, dh, dt):
    q = jnp.asarray(RNG.normal(size=(B, S, H, dh)), dt)
    k = jnp.asarray(RNG.normal(size=(B, S, Hkv, dh)), dt)
    v = jnp.asarray(RNG.normal(size=(B, S, Hkv, dh)), dt)
    got = flash_attention(q, k, v, block_q=128, block_k=128, interpret=True)
    want = ops.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(got.astype(np.float32),
                               want.astype(np.float32), **_tol(dt))


def test_flash_attention_non_causal():
    q = jnp.asarray(RNG.normal(size=(1, 256, 2, 32)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(1, 256, 2, 32)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(1, 256, 2, 32)), jnp.float32)
    got = flash_attention(q, k, v, causal=False, block_q=128, block_k=128,
                          interpret=True)
    want = ops.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("E,C,d,f,dt", [
    (8, 96, 64, 200, jnp.float32),
    (4, 128, 128, 512, jnp.bfloat16),
    (1, 8, 32, 64, jnp.float32),
])
def test_gmm_sweep(E, C, d, f, dt):
    xe = jnp.asarray(RNG.normal(size=(E, C, d)), dt)
    w = jnp.asarray(RNG.normal(size=(E, d, f)), dt)
    got = gmm(xe, w, block_c=32, block_f=64, interpret=True)
    want = ops.gmm_ref(xe, w)
    np.testing.assert_allclose(got.astype(np.float32),
                               want.astype(np.float32), **_tol(dt))


@pytest.mark.parametrize("P", [128, 10000, 65536])
def test_rollup_digest_sweep(P):
    buf = jnp.asarray(RNG.normal(size=(P,)), jnp.float32)
    got = rollup_digest(buf, block_p=2048, interpret=True)
    want = ops.rollup_digest_ref(
        jax.lax.bitcast_convert_type(buf, jnp.uint32))
    assert got == want


def test_rollup_digest_detects_tampering():
    buf = jnp.asarray(RNG.normal(size=(4096,)), jnp.float32)
    d0 = rollup_digest(buf, interpret=True)
    d1 = rollup_digest(buf.at[1234].add(1e-6), interpret=True)
    assert d0 != d1
