"""Self-check for the repro-lint static pass (analysis/lint.py).

Pins the ISSUE-9 acceptance contract: the CLI exits nonzero on each
known-bad fixture (one per static rule, R001-R005 and R008), zero on the shipped
``src/repro`` tree, suppression comments work, and the findings are
machine-readable.  Fixtures are referenced by file name only — naming a
fixture's kernel op here would satisfy R002's parity-test scan and
defeat the fixture.
"""
import json
import os
import subprocess
import sys

import pytest

from repro.analysis import lint
from repro.analysis.invariants import CATALOG

HERE = os.path.dirname(os.path.abspath(__file__))
FIXTURES = os.path.join(HERE, "lint_fixtures")
SRC_REPRO = os.path.normpath(os.path.join(HERE, os.pardir, "src", "repro"))

#: one known-bad fixture per static rule
RULE_FIXTURES = {
    "R001": "bad_r001.py",
    "R002": "bad_r002.py",
    "R003": "bad_r003.py",
    "R004": "bad_r004.py",
    "R005": "bad_r005.py",
    "R008": "bad_r008.py",
}


def _run_cli(*args):
    env = dict(os.environ)
    src = os.path.join(HERE, os.pardir, "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint", *args],
        capture_output=True, text=True, env=env)


@pytest.mark.parametrize("rule", sorted(RULE_FIXTURES))
def test_fixture_triggers_exactly_its_rule(rule):
    findings, n_sup = lint.scan([os.path.join(FIXTURES, RULE_FIXTURES[rule])])
    assert findings, f"fixture for {rule} produced no findings"
    assert {f.rule for f in findings} == {rule}
    assert n_sup == 0
    for f in findings:
        assert f.hint == CATALOG[rule].fix_hint
        assert f.line > 0


def test_catalog_covers_every_rule():
    static = {r for r, inv in CATALOG.items() if inv.static}
    assert static == set(RULE_FIXTURES)
    dynamic = {r for r, inv in CATALOG.items() if inv.dynamic}
    assert dynamic == {"R001", "R005", "R006", "R007"}


def test_shipped_tree_is_clean():
    findings, _ = lint.scan([SRC_REPRO])
    assert findings == [], "\n".join(f.render() for f in findings)


def test_cli_nonzero_per_fixture_and_zero_on_src(tmp_path):
    for rule, name in sorted(RULE_FIXTURES.items()):
        out = tmp_path / f"{rule}.json"
        res = _run_cli(os.path.join(FIXTURES, name), "--json", str(out))
        assert res.returncode == 1, (rule, res.stdout, res.stderr)
        payload = json.loads(out.read_text())
        assert payload["n_findings"] >= 1
        assert {f["rule"] for f in payload["findings"]} == {rule}
        for f in payload["findings"]:
            assert set(f) == {"file", "line", "col", "rule", "message",
                              "hint"}
    res = _run_cli(SRC_REPRO, "--quiet")
    assert res.returncode == 0, res.stdout + res.stderr


def test_line_suppression_and_file_suppression(tmp_path):
    body = ("def f(state, ids):\n"
            "    state.balances[ids] += 1.0{}\n")
    bad = tmp_path / "bad.py"
    bad.write_text(body.format(""))
    findings, n_sup = lint.scan([str(bad)])
    assert [f.rule for f in findings] == ["R001"] and n_sup == 0

    sup = tmp_path / "sup.py"
    sup.write_text(body.format("  # repro-lint: disable=R001"))
    findings, n_sup = lint.scan([str(sup)])
    assert findings == [] and n_sup == 1

    supf = tmp_path / "supf.py"
    supf.write_text("# repro-lint: disable-file=R001\n" + body.format(""))
    findings, n_sup = lint.scan([str(supf)])
    assert findings == [] and n_sup == 1


def test_syntax_error_is_a_hard_finding(tmp_path):
    broken = tmp_path / "broken.py"
    broken.write_text("def f(:\n")
    findings, _ = lint.scan([str(broken)])
    assert [f.rule for f in findings] == ["R000"]
    res = _run_cli(str(broken), "--quiet")
    assert res.returncode == 1


def test_r001_pairing_is_accepted(tmp_path):
    ok = tmp_path / "ok.py"
    ok.write_text(
        "import numpy as np\n\n\n"
        "def f(state, ids):\n"
        "    state.balances[ids] += 1.0\n"
        "    np.add.at(state.submissions, ids, 1)\n"
        "    state.mark_dirty(ids)\n")
    findings, _ = lint.scan([str(ok)])
    assert findings == []


def test_r005_splice_owner_is_exempt():
    events = os.path.join(SRC_REPRO, "core", "events.py")
    findings, _ = lint.scan([events])
    assert findings == []
