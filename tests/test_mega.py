"""Cross-task megabatched scheduler rounds (PR 8).

Property pins: a Scheduler window driven as ONE ``(tasks, trainers)``
megastep — ``MegaCohort`` train, triple-vmapped DON scoring, vmapped Eq. 1
aggregation, one megabatched tx emission — is element-wise identical to
stepping every task through the per-task reference path:

  * per-task params / quorum scores / submitted updates / cids;
  * the emitted tx stream (per-fn call counts, chain + rollup gas, state
    roots, typed window/settlement events);
  * across random task counts x trainer counts x behavior masks x
    backends (plain rollup and sharded fabric).

The deterministic seeds below always run; the hypothesis variant widens
the search in CI (it skips when hypothesis is absent, see conftest.py).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # degrade: property tests skip, the rest still run
    from conftest import given, settings, st  # noqa: F401

from repro.data.synthetic import gaussian_clusters
from repro.fl.cohort import CohortKernels, VectorCohort, batched_batch_fn
from repro.fl.dp import DPConfig
from repro.fl.scheduler import Scheduler
from repro.fl.server import AutoDFL
from repro.models.mlp import TinyMLP
from repro.optim.optimizers import OptimizerSpec, make_optimizer

D_IN, D_H, N_CLS = 8, 8, 4
BEHAVIOR_POOL = ["good", "good", "malicious", "lazy"]


@pytest.fixture(scope="module")
def world():
    model = TinyMLP(D_IN, D_H, N_CLS)
    opt = make_optimizer(OptimizerSpec(name="sgdm", lr=0.1, grad_clip=5.0))
    tr_x, tr_y = gaussian_clusters(256, D_IN, N_CLS, seed=1, noise=0.5)
    vx, vy = gaussian_clusters(40, D_IN, N_CLS, seed=2, noise=0.5)
    val = {"x": jnp.asarray(vx), "labels": jnp.asarray(vy)}

    def bf(c, r):
        g = np.random.default_rng((c * 9973 + r) % 2**31)
        idx = g.integers(0, len(tr_x), 8)
        return {"x": jnp.asarray(tr_x[idx]), "labels": jnp.asarray(tr_y[idx])}

    return model, opt, val, bf, model.accuracy_fn()


def _draw_case(seed: int):
    """Random scheduler shape from one seed (shared by both pair runs)."""
    g = np.random.default_rng(seed)
    n = int(g.integers(3, 7))
    return {
        "n_trainers": n,
        "n_tasks": int(g.integers(1, 5)),
        "behaviors": [BEHAVIOR_POOL[i]
                      for i in g.integers(0, len(BEHAVIOR_POOL), n)],
        "rounds": int(g.integers(1, 4)),
        "n_select": int(g.integers(2, n + 1)),
        "stagger": bool(g.integers(0, 2)),
    }


def _run(world, case, megabatch, n_shards=1):
    model, opt, val, bf, eval_fn = world
    node_kw = {"trainer_funds": 50.0}
    if n_shards > 1:
        node_kw.update(n_shards=n_shards, shard_route="hash")
    with pytest.warns(DeprecationWarning):
        node = AutoDFL(model, opt, case["n_trainers"], eval_fn, val,
                       engine="vector", **node_kw)
    kern = CohortKernels(model, opt, DPConfig(noise_multiplier=0.05))
    vbf = batched_batch_fn(bf, local_steps=2)
    sch = Scheduler(node, seal_every=2, megabatch=megabatch)
    for t in range(case["n_tasks"]):
        cohort = VectorCohort(model, opt, vbf, node.store,
                              behaviors=case["behaviors"], local_steps=2,
                              dp=DPConfig(noise_multiplier=0.05), seed=t,
                              kernels=kern)
        sch.add_task(f"task{t}", cohort, rounds=case["rounds"],
                     n_select=case["n_select"],
                     start_window=(t % 2) if case["stagger"] else 0)
    out = sch.run()
    return node, sch, out


def _assert_pair_equal(ref, mega):
    (na, sa, oa), (nb, sb, ob) = ref, mega
    assert set(oa) == set(ob)
    for rta, rtb in zip(sa.runtimes, sb.runtimes):
        ra, rb = oa[rta.task_id], ob[rtb.task_id]
        np.testing.assert_array_equal(ra.scores, rb.scores)
        np.testing.assert_array_equal(ra.reputations, rb.reputations)
        assert ra.payouts == rb.payouts
        for la, lb in zip(jax.tree.leaves(ra.global_params),
                          jax.tree.leaves(rb.global_params)):
            np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
        # last round's submissions element-wise: order, update bits, cids
        assert (rta.last_subs is None) == (rtb.last_subs is None)
        if rta.last_subs is not None:
            assert rta.last_subs.idxs == rtb.last_subs.idxs
            assert rta.last_subs.cids == rtb.last_subs.cids
            for la, lb in zip(jax.tree.leaves(rta.last_subs.stacked),
                              jax.tree.leaves(rtb.last_subs.stacked)):
                np.testing.assert_array_equal(np.asarray(la),
                                              np.asarray(lb))
            np.testing.assert_array_equal(rta.last_scores, rtb.last_scores)
    # the emitted tx stream: same calls, same gas, same commitments
    assert na.protocol_calls == nb.protocol_calls
    assert na.chain.total_gas == nb.chain.total_gas
    assert na.chain.state_root() == nb.chain.state_root()
    assert na.rollup.state_root() == nb.rollup.state_root()
    tot = lambda s: round(sum(r["total"] for r in s.rollup.gas_log), 6)
    assert tot(na) == tot(nb)
    key = lambda w: (w.window, w.n_batches, w.state_root, w.fabric_root,
                     w.shard_roots)
    assert [key(w) for w in sa.window_records] == \
        [key(w) for w in sb.window_records]
    assert len(sa.settlement_records) == len(sb.settlement_records)


def _check_seed(world, seed, n_shards):
    case = _draw_case(seed)
    ref = _run(world, case, megabatch=False, n_shards=n_shards)
    mega = _run(world, case, megabatch="auto", n_shards=n_shards)
    assert ref[1].mega_windows == 0
    assert mega[1].mega_windows > 0, "mega path never engaged"
    _assert_pair_equal(ref, mega)


# -- always-run deterministic draws (hypothesis-free fallback coverage) --------
@pytest.mark.parametrize("seed,n_shards", [(0, 1), (1, 2), (2, 1)])
def test_mega_window_matches_per_task_reference(world, seed, n_shards):
    _check_seed(world, seed, n_shards)


# -- hypothesis widens the same property in CI ---------------------------------
@settings(max_examples=4, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**20),
       fabric=st.booleans())
def test_mega_property_random_shapes(world, seed, fabric):
    _check_seed(world, seed, 2 if fabric else 1)


# -- strict knob + graceful ineligibility --------------------------------------
def test_megabatch_true_asserts_on_ineligible_stack(world):
    model, opt, val, bf, eval_fn = world
    with pytest.warns(DeprecationWarning):
        node = AutoDFL(model, opt, 3, eval_fn, val, engine="object")
    sch = Scheduler(node, megabatch=True)
    from repro.fl.client import ClientConfig, TrainingAgent
    agents = [TrainingAgent(ClientConfig(f"trainer{i}", "good",
                                         local_steps=1),
                            model, opt, node.store, bf, seed=i)
              for i in range(3)]
    sch.add_task("t0", agents, rounds=1)
    with pytest.raises(RuntimeError, match="megabatch"):
        sch.run()


def test_megabatch_auto_falls_back_on_object_engine(world):
    model, opt, val, bf, eval_fn = world
    with pytest.warns(DeprecationWarning):
        node = AutoDFL(model, opt, 3, eval_fn, val, engine="object")
    sch = Scheduler(node, megabatch="auto")
    from repro.fl.client import ClientConfig, TrainingAgent
    agents = [TrainingAgent(ClientConfig(f"trainer{i}", "good",
                                         local_steps=1),
                            model, opt, node.store, bf, seed=i)
              for i in range(3)]
    sch.add_task("t0", agents, rounds=1)
    out = sch.run()
    assert sch.mega_windows == 0
    assert out["t0"] is not None
