"""Preset catalog tests (repro/api/presets.py).

Satellite pin: every preset in the catalog builds through
``build_stack``, validates/serializes as data, runs a small workload
end to end through the public client, and produces a REPRODUCIBLE state
root (same preset + same drive -> same root).  Presets were previously
only exercised indirectly by the benchmarks that consume them.
"""
import json

import pytest

from repro.api import (PRESETS, NodeClient, build_ledger, build_stack,
                       describe_presets, l1_of, preset)
from repro.core.ledger import LedgerBackend


def _drive(spec):
    client = NodeClient.from_spec(spec)
    receipts = [client.submit("submitLocalModel", f"t{i % 4}")
                for i in range(12)]
    client.flush()
    client.run_until(8.0)
    return client, [client.refresh(r) for r in receipts]


@pytest.mark.parametrize("name", sorted(PRESETS))
def test_preset_builds_runs_and_reproduces_its_state_root(name):
    spec = preset(name)
    # specs are data: serializable and rebuildable
    json.dumps(spec.describe())
    chain, rollup = build_stack(spec)
    target = rollup if rollup is not None else chain
    assert isinstance(target, LedgerBackend)
    assert l1_of(build_ledger(spec)) is not None
    # a small workload runs end to end through the public client
    client, receipts = _drive(spec)
    want = "finalized" if spec.rollup is not None else "confirmed"
    assert all(r.status == want for r in receipts), name
    root = client.state_root()
    assert root, f"preset {name!r} must commit account state"
    # reproducible: an identical drive reaches the identical root
    client2, _ = _drive(spec)
    assert client2.state_root() == root


def test_describe_presets_is_json_serializable_and_complete():
    catalog = describe_presets()
    assert sorted(catalog) == sorted(PRESETS)
    json.dumps(catalog)


def test_preset_overrides_replace_fields():
    from repro.api import ProverSpec, ShardSpec
    spec = preset("shard-fabric", shards=ShardSpec(count=2))
    assert spec.shards.count == 2
    assert preset("prover-pipeline").prover.agg_width == 8
    assert preset("prover-pipeline",
                  prover=ProverSpec(agg_width=2)).prover.agg_width == 2
    with pytest.raises(KeyError):
        preset("nope")
