"""Prover pipeline tests (core/prover.py + the proof-lifecycle API).

Pins the PR-5 contracts:
  * width-1 aggregation is BIT-EQUIVALENT to the pre-pipeline settlement
    path on all three rollup backends — same gas rows (the pre-PR
    per-session amortization reimplemented here as a reference), same
    state root, finalized receipts whose shares still sum to the ledger
    total;
  * aggregation width W amortizes ONE L1 verify across W sessions (the
    paper's gas lever) without touching the committed state;
  * ``client.events()`` yields the same typed sequence for a 1-shard
    ``ShardedRollup`` and a plain ``VectorRollup`` under the same spec
    and workload (modulo shard tags / fabric root fields);
  * identical specs model identical prove/settle timing on the object
    and vector faces (one ``session_latency`` formula);
  * windowed finalization drains proof jobs on the shared window clock
    (receipts walk pending -> sealed -> proved -> finalized);
  * the recursive aggregation fold is the same xor-mix at every level
    (jnp kernel helper == NumPy chunk-fold mirror).
"""
import dataclasses

import numpy as np
import pytest

from repro.api import (ChainSpec, NodeClient, NodeSpec, ProverSpec,
                       RollupSpec, ShardSpec)
from repro.core.gas import DEFAULT_GAS
from repro.core.state import chunk_fold_digests

BACKENDS = [
    NodeSpec(),                                         # VectorRollup
    NodeSpec(chain=ChainSpec(backend="object")),        # object Rollup
    NodeSpec(shards=ShardSpec(count=1, fabric=True)),   # 1-shard fabric
]
BACKEND_IDS = ["vector", "object", "fabric-1"]


def _drive_sessions(spec, n_txs=90, chunk=30, senders=6):
    """Submit ``n_txs`` in ``chunk``-sized settle sessions (seal + close
    per chunk — the window cadence; sessions feed the aggregation stage)
    and force the final flush."""
    client = NodeClient.from_spec(spec)
    receipts = []
    for i in range(n_txs):
        receipts.append(client.submit("submitLocalModel",
                                      f"t{i % senders}"))
        if (i + 1) % chunk == 0:
            client.seal()
            client.target.settle_session()
    client.flush()
    client.run_until(10.0)
    return client, [client.refresh(r) for r in receipts]


def _prepr_reference(session_sizes, gas=DEFAULT_GAS):
    """The pre-pipeline settlement, reimplemented: ONE amortized verify +
    execute per session (old Rollup._settle_session semantics).  Returns
    the expected per-batch (verify, execute) shares, row order."""
    shares = []
    for batches in session_sizes:           # list of per-batch n_txs
        nb = len(batches)
        single = nb == 1 and batches[0] <= 5
        verify = gas.verify_single if single else gas.verify_multi
        execute = gas.execute_single if single else gas.execute_multi
        shares.extend((verify / nb, execute / nb) for _ in batches)
    return shares


@pytest.mark.parametrize("spec", BACKENDS, ids=BACKEND_IDS)
def test_width1_is_bit_equivalent_to_the_prepr_settlement_path(spec):
    """Acceptance pin: default ProverSpec (width 1, eager) reproduces the
    pre-pipeline per-session settlement exactly."""
    client, receipts = _drive_sessions(spec)
    rows = client.target.gas_log
    # session structure: 3 chunks of 30 at batch_size 20 -> [20, 10] x 3
    assert [r["n_txs"] for r in rows] == [20, 10] * 3
    expected = _prepr_reference([[20, 10]] * 3)
    got = [(r["verify"], r["execute"]) for r in rows]
    assert got == expected
    for r in rows:
        assert r["total"] == r["commit"] + r["verify"] + r["execute"]
    # one verify + execute posted per session, timestamped at the
    # session's last seal (the pre-PR posting point)
    aggs = [e for e in client.events() if e.kind == "aggregate_verified"]
    assert len(aggs) == 3
    assert all(a.n_sessions == 1 for a in aggs)
    # receipts walked the full lifecycle and the shares conserve gas
    assert all(r.status == "finalized" for r in receipts)
    total = sum(r["total"] for r in rows)
    assert np.isclose(sum(r.gas_breakdown["amortized"] for r in receipts),
                      total)
    assert np.isclose(sum(r.gas_breakdown["verify_share"]
                          for r in receipts),
                      3 * DEFAULT_GAS.verify_multi)
    assert client.state_root()


def test_same_spec_same_state_root_and_commits_on_every_backend():
    """The settlement redesign must not move the committed state or the
    commit gas: all three backends agree, width makes no difference."""
    roots, commits = set(), set()
    for spec in BACKENDS + [NodeSpec(prover=ProverSpec(agg_width=3))]:
        client, _ = _drive_sessions(spec)
        roots.add(client.state_root())
        commits.add(sum(r["commit"] for r in client.target.gas_log))
    assert len(roots) == 1 and len(commits) == 1


@pytest.mark.parametrize("spec", BACKENDS, ids=BACKEND_IDS)
def test_aggregation_width_amortizes_the_l1_verify(spec):
    """The gas lever: width W folds W sessions into ONE posted verify."""
    spec_w = dataclasses.replace(spec, prover=ProverSpec(agg_width=3))
    base, _ = _drive_sessions(spec)
    wide, receipts = _drive_sessions(spec_w)
    v_base = sum(r["verify"] for r in base.target.gas_log)
    v_wide = sum(r["verify"] for r in wide.target.gas_log)
    assert np.isclose(v_base, 3 * DEFAULT_GAS.verify_multi)
    assert np.isclose(v_wide, DEFAULT_GAS.verify_multi)
    aggs = [e for e in wide.events() if e.kind == "aggregate_verified"]
    assert len(aggs) == 1 and aggs[0].n_sessions == 3
    assert base.state_root() == wide.state_root()
    assert all(r.status == "finalized" for r in receipts)
    # recursive digest: the aggregate folds the session digests with the
    # same xor-mix the batch digests were built with
    prover = getattr(wide.target, "prover")
    sess = [s for a in prover.aggregates for s in a.sessions]
    assert len(sess) == 3
    agg = prover.aggregates[0]
    assert agg.n_txs == 90 and len(agg.batches) == 6


def test_flush_forces_the_partial_aggregate_through():
    spec = NodeSpec(prover=ProverSpec(agg_width=4))
    client, receipts = _drive_sessions(spec)          # only 3 sessions
    assert all(r.status == "finalized" for r in receipts)
    aggs = [e for e in client.events() if e.kind == "aggregate_verified"]
    assert len(aggs) == 1 and aggs[0].n_sessions == 3


def test_single_run_until_confirms_window_finalized_settlements():
    """Regression: run_until must pump the prover BEFORE producing
    blocks — posting the aggregate's verify/execute after the blocks
    that should pack them left the settlement unconfirmed forever."""
    spec = NodeSpec(prover=ProverSpec(agg_width=1, finalize="window",
                                      prove_time=2.0))
    client = NodeClient.from_spec(spec)
    receipts = [client.submit("submitLocalModel", f"t{i}")
                for i in range(20)]
    client.seal()
    client.target.settle_session()
    client.run_until(30.0)                  # ONE call: drain + pack
    assert all(client.refresh(r).status == "finalized" for r in receipts)
    assert client.chain.n_confirmed == client.chain.n_submitted


def test_forced_drain_never_posts_future_settlements():
    """A flush before the modeled proofs drain must post at the session
    close time, not the future drain time — a future-stamped settle tx
    at the L1 mempool head would stall every later submission (FIFO
    head-of-line rule)."""
    spec = NodeSpec(prover=ProverSpec(agg_width=2, finalize="window",
                                      prove_time=50.0))
    client = NodeClient.from_spec(spec)
    receipts = [client.submit("submitLocalModel", f"t{i}")
                for i in range(20)]
    client.flush()              # proofs would drain at ~50s; force now
    aggs = [e for e in client.events() if e.kind == "aggregate_verified"]
    assert len(aggs) == 1 and aggs[0].time <= 1.0
    assert all(client.refresh(r).status == "finalized" for r in receipts)
    client.run_until(5.0)       # nothing stalls behind the settlement
    assert client.chain.n_confirmed == client.chain.n_submitted


# -- typed event stream: fabric == vector (acceptance) -------------------------
def _normalize(ev):
    strip = {"shard": None}
    if ev.kind == "window_settled":
        strip.update(fabric_root="", shard_roots=())
    return dataclasses.replace(ev, **strip)


def test_one_shard_fabric_yields_the_same_event_sequence_as_vector():
    """Acceptance pin: client.events() is uniform across backends — a
    1-shard ShardedRollup and a plain VectorRollup emit the SAME typed
    sequence under the same spec and workload, modulo the shard tags
    (and the fabric-root decoration on WindowSettled)."""
    def drive(spec):
        client, _ = _drive_sessions(spec)
        return client.events()

    plain = drive(NodeSpec())
    fabric = drive(NodeSpec(shards=ShardSpec(count=1, fabric=True)))
    assert len(plain) == len(fabric)
    for a, b in zip(plain, fabric):
        assert _normalize(a) == _normalize(b), (a, b)
    # the fabric's shard tags are the only decoration
    assert {e.shard for e in fabric if e.kind == "batch_sealed"} == {0}
    assert {e.shard for e in plain if e.kind == "batch_sealed"} == {None}


# -- modeled prover latency ----------------------------------------------------
def test_latency_parity_object_vs_vector_and_prepr_formula():
    """Satellite pin: identical specs model identical prove/settle
    timing on both faces — one session_latency formula — and the
    default capacity-1 model equals the pre-pipeline ``nb * prove_time +
    n * per_tx_time``."""
    from repro.api import build_ledger
    ru_spec = RollupSpec(batch_size=20, prove_time=0.9, per_tx_time=0.14)
    obj = build_ledger(NodeSpec(chain=ChainSpec(backend="object"),
                                rollup=ru_spec))
    vec = build_ledger(NodeSpec(rollup=ru_spec))
    for n in (1, 5, 20, 99, 1000):
        nb = max(1, -(-n // 20))
        prepr = nb * 0.9 + n * 0.14
        assert obj.latency(n) == vec.latency(n) == pytest.approx(prepr)
    # more modeled prover workers -> faster drain, never slower
    fast = build_ledger(NodeSpec(rollup=ru_spec,
                                 prover=ProverSpec(capacity=4)))
    assert fast.latency(1000) < vec.latency(1000)
    assert fast.latency(1) == vec.latency(1)


# -- windowed finalization on the shared clock ---------------------------------
def test_windowed_finalization_walks_the_full_receipt_lifecycle():
    spec = NodeSpec(prover=ProverSpec(agg_width=2, finalize="window",
                                      prove_time=5.0))
    client = NodeClient.from_spec(spec)
    receipts = [client.submit("submitLocalModel", f"t{i}")
                for i in range(20)]
    r = receipts[0]
    assert client.refresh(r).status == "pending"
    client.seal()
    client.target.settle_session()            # session 1 closed
    assert client.refresh(r).status == "sealed"   # proof still in flight
    client.run_until(2.0)                     # before the modeled drain
    assert client.refresh(r).status == "sealed"
    client.run_until(30.0)                    # proof drained on the clock
    assert client.refresh(r).status == "proved"
    evs = client.events()
    assert [e.kind for e in evs].count("proof_generated") == 1
    assert not any(e.kind == "aggregate_verified" for e in evs)
    # second session completes the width-2 aggregate at the next pump
    for i in range(20):
        client.submit("submitLocalModel", f"u{i}", at=30.0 + i)
    client.seal()
    client.target.settle_session()
    client.run_until(80.0)
    assert client.refresh(r).status == "finalized"
    aggs = [e for e in client.events() if e.kind == "aggregate_verified"]
    assert len(aggs) == 1 and aggs[0].n_sessions == 2
    # the posting time models the proof drain, not the seal
    assert aggs[0].time >= 35.0


# -- recursive digest fold -----------------------------------------------------
def test_aggregate_digest_fold_matches_the_numpy_mirror():
    from repro.kernels.rollup_digest import rollup_aggregate_digests
    rng = np.random.default_rng(7)
    digests = rng.integers(0, 2**32, 37, dtype=np.uint32)
    for width in (1, 2, 8, 37, 64):
        dev = np.asarray(rollup_aggregate_digests(digests, width))
        mirror = chunk_fold_digests(digests, chunk=width)
        np.testing.assert_array_equal(dev, mirror)
    # and the pipeline's aggregate digest IS that construction applied
    # recursively: batch digests -> session proofs -> aggregate proof
    client, _ = _drive_sessions(NodeSpec(prover=ProverSpec(agg_width=3)))
    prover = client.target.prover
    evs = client.events()
    proofs = {e.batch: e.digest for e in evs
              if e.kind == "proof_generated"}
    assert len(proofs) == 6     # every batch proof drained exactly once
    session_digests = [
        int(chunk_fold_digests(
            np.array([proofs[2 * k], proofs[2 * k + 1]], np.uint32),
            chunk=2)[0])
        for k in range(3)]      # sessions were [batch 2k, batch 2k+1]
    expected = int(chunk_fold_digests(
        np.array(session_digests, np.uint32), chunk=3)[0])
    assert prover.aggregates[0].digest == expected
