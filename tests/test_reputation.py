"""Reputation model (Eq. 2-10): unit + hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # degrade: property tests skip, the rest still run
    from conftest import given, settings, st  # noqa: F401

from repro.core.reputation import (ReputationParams, end_of_multitask_update,
                                   end_of_task_update, init_book,
                                   model_distances, normalised_distances,
                                   objective_reputation, subjective_opinion,
                                   tenure_weight, update_reputation)

P = ReputationParams()


# -- Eq. 4 / Eq. 3 --------------------------------------------------------------
def test_model_distance_matches_numpy():
    rng = np.random.default_rng(0)
    local = rng.normal(size=(5, 257)).astype(np.float32)
    glob = rng.normal(size=(257,)).astype(np.float32)
    got = model_distances(jnp.asarray(local), jnp.asarray(glob))
    want = np.linalg.norm(local - glob[None], axis=1)
    np.testing.assert_allclose(got, want, rtol=1e-5)


def test_normalised_distance_unit_max():
    d = jnp.array([1.0, 2.0, 4.0])
    nd = normalised_distances(d)
    assert float(jnp.max(nd)) == pytest.approx(1.0)
    np.testing.assert_allclose(nd, [0.25, 0.5, 1.0])


# -- Eq. 2 -----------------------------------------------------------------------
@settings(max_examples=100, deadline=None)
@given(score=st.floats(0, 1), vc=st.integers(0, 10),
       nd=st.floats(0, 1))
def test_objective_reputation_bounds(score, vc, nd):
    o = objective_reputation(jnp.array([score]), jnp.array([float(vc)]),
                             jnp.array([10.0]), jnp.array([nd, 0.1]))
    assert 0.0 <= float(o[0]) <= 1.0


def test_objective_reputation_penalties():
    # below-threshold distance: no penalty
    full = objective_reputation(jnp.array([0.9, 0.9]), jnp.array([10., 10.]),
                                jnp.array([10., 10.]),
                                jnp.array([0.1, 1.0]),
                                ReputationParams(tau=0.5))
    assert float(full[0]) == pytest.approx(0.9, abs=1e-6)   # nd < tau
    assert float(full[1]) < 0.9                              # nd = 1 -> max penalty
    # missing rounds scales linearly
    half = objective_reputation(jnp.array([0.9]), jnp.array([5.0]),
                                jnp.array([10.0]), jnp.array([0.0, 1.0])[:1],
                                ReputationParams(tau=0.5))
    assert float(half[0]) == pytest.approx(0.45, abs=1e-6)


# -- Eq. 5-7 ---------------------------------------------------------------------
@settings(max_examples=100, deadline=None)
@given(st.lists(st.booleans(), min_size=1, max_size=12),
       st.floats(0.01, 1.0))
def test_opinion_simplex(goods, i_f):
    """b + d + u == 1 and all components in [0, 1]."""
    n = len(goods)
    good = jnp.asarray([[1.0 if g else 0.0 for g in goods]])
    ages = jnp.asarray([[float(i) for i in range(n)]])
    b, d, u = subjective_opinion(good, ages, jnp.array([i_f * 10]),
                                 jnp.array([10.0]))
    for v in (b, d, u):
        assert 0.0 - 1e-6 <= float(v[0]) <= 1.0 + 1e-6
    assert float(b[0] + d[0] + u[0]) == pytest.approx(1.0, abs=1e-5)


def test_bad_weighs_more_than_good():
    """theta < 0.5: with an even good/bad history, disbelief outweighs
    belief (the paper's anti-malice asymmetry, Eq. 6)."""
    ages = jnp.asarray([[0.0, 1.0]])
    inter = jnp.array([10.0]), jnp.array([10.0])
    # recent bad, older good — and the symmetric opposite
    b1, d1, _ = subjective_opinion(jnp.asarray([[0.0, 1.0]]), ages, *inter)
    b2, d2, _ = subjective_opinion(jnp.asarray([[1.0, 0.0]]), ages, *inter)
    assert float(d1[0]) > float(b1[0])   # bad outweighs good at equal count
    assert float(d2[0]) > 0.0
    # even when the good interaction is the recent one, theta<0.5 keeps
    # disbelief competitive
    assert float(d2[0]) > float(b2[0]) * 0.5


# -- Eq. 9-10 --------------------------------------------------------------------
@settings(max_examples=100, deadline=None)
@given(st.floats(0, 1), st.floats(0, 1), st.integers(0, 100))
def test_update_bounds_and_asymmetry(r_prev, l_rep, n_tasks):
    r = update_reputation(jnp.array([r_prev]), jnp.array([l_rep]),
                          jnp.array([float(n_tasks)]))
    assert 0.0 - 1e-6 <= float(r[0]) <= 1.0 + 1e-6
    # convexity: result between r_prev and l_rep
    lo, hi = min(r_prev, l_rep), max(r_prev, l_rep)
    assert lo - 1e-5 <= float(r[0]) <= hi + 1e-5


def test_tenure_monotone():
    n = jnp.arange(0, 50, dtype=jnp.float32)
    w = tenure_weight(n)
    assert float(w[0]) == pytest.approx(0.0)
    assert np.all(np.diff(np.asarray(w)) >= 0)
    assert float(w[-1]) < 1.0


def test_bad_behaviour_amplified_below_rmin():
    """Below R_min the update weighs L_rep harder (mistakes punished)."""
    params = ReputationParams(r_min=0.4, lam=0.5)
    n = jnp.array([20.0])
    up = update_reputation(jnp.array([0.8]), jnp.array([0.41]), n, params)
    down = update_reputation(jnp.array([0.8]), jnp.array([0.39]), n, params)
    # the 0.02 drop in L_rep crossing R_min causes a discontinuous plunge
    assert float(up[0]) - float(down[0]) > 0.2


# -- full pipeline -----------------------------------------------------------------
def test_end_of_task_profiles():
    book = init_book(3)
    rng = np.random.default_rng(0)
    for _ in range(12):
        score = jnp.array([0.92, 0.03, 0.7])
        completed = jnp.array([10.0, 10.0, 5.0])
        dist = jnp.array([0.5, 5.0, 1.0])
        book, diag = end_of_task_update(book, score, completed,
                                        jnp.full(3, 10.0), dist, jnp.ones(3))
    rep = np.asarray(book.reputation)
    assert rep[0] > 0.7 and rep[1] < 0.25 and rep[1] < rep[2] < rep[0]
    for v in jax.tree.leaves(diag):
        assert np.all(np.isfinite(np.asarray(v)))


def _random_task_rows(rng, k, n):
    score = rng.uniform(0.0, 1.0, (k, n)).astype(np.float32)
    completed = rng.integers(0, 11, (k, n)).astype(np.float32)
    dist = rng.uniform(0.1, 5.0, (k, n)).astype(np.float32)
    part = (rng.random((k, n)) > 0.3).astype(np.float32)
    part[:, 0] = 1.0                       # overlap: trainer0 in every task
    return score, completed, np.full((k, n), 10.0, np.float32), dist, part


def test_multitask_update_matches_sequential():
    """Fused K-task settlement == K sequential end_of_task_update calls
    (same row order), including overlapping participation masks."""
    rng = np.random.default_rng(7)
    k, n = 4, 6
    score, completed, total, dist, part = _random_task_rows(rng, k, n)

    seq_book = init_book(n)
    seq_diags = []
    for j in range(k):
        seq_book, d = end_of_task_update(
            seq_book, jnp.asarray(score[j]), jnp.asarray(completed[j]),
            jnp.asarray(total[j]), jnp.asarray(dist[j]),
            jnp.asarray(part[j]))
        seq_diags.append(d)

    fused_book, diags = end_of_multitask_update(
        init_book(n), score, completed, total, dist, part)

    for a, b in zip(jax.tree.leaves(seq_book), jax.tree.leaves(fused_book)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                                   atol=1e-6)
    for key in ("o_rep", "s_rep", "l_rep"):
        want = np.stack([np.asarray(d[key]) for d in seq_diags])
        np.testing.assert_allclose(np.asarray(diags[key]), want, rtol=1e-5,
                                   atol=1e-6)


def test_multitask_update_single_row_matches_single_task():
    rng = np.random.default_rng(3)
    n = 5
    score, completed, total, dist, part = _random_task_rows(rng, 1, n)
    book_a, diag_a = end_of_task_update(
        init_book(n), jnp.asarray(score[0]), jnp.asarray(completed[0]),
        jnp.asarray(total[0]), jnp.asarray(dist[0]), jnp.asarray(part[0]))
    book_b, diag_b = end_of_multitask_update(
        init_book(n), score, completed, total, dist, part)
    for a, b in zip(jax.tree.leaves(book_a), jax.tree.leaves(book_b)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6,
                                   atol=1e-7)
    np.testing.assert_allclose(np.asarray(diag_a["s_rep"]),
                               np.asarray(diag_b["s_rep"][0]), rtol=1e-6,
                               atol=1e-7)


def test_non_participants_unchanged():
    book = init_book(4)
    before = np.asarray(book.reputation).copy()
    part = jnp.array([1.0, 1.0, 0.0, 0.0])
    book, _ = end_of_task_update(book, jnp.full(4, 0.9), jnp.full(4, 10.0),
                                 jnp.full(4, 10.0),
                                 jnp.array([1.0, 1.0, 1.0, 1.0]), part)
    after = np.asarray(book.reputation)
    np.testing.assert_allclose(after[2:], before[2:])
    assert after[0] != before[0]
