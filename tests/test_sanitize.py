"""Runtime sanitizer (analysis/sanitize.py): injection + clean-run pins.

Each dynamic invariant class is proven *actually caught*: a toy stack is
poisoned with one violation per rule (skipped ``mark_dirty`` -> R001,
out-of-band event seq -> R005, gas leak -> R006, illegal receipt
lifecycle -> R007) and the sanitizer must raise ``SanitizeViolation``
with the matching rule id — while clean stepped, fused and fabric runs
stay silent with the checks demonstrably executed (``n_checks``).
Property-based forms randomize the traffic ahead of the injection;
they degrade to skips where hypothesis is absent (see conftest.py).
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from conftest import given, settings, st

from repro.analysis.sanitize import (ENV_FLAG, SanitizeViolation,
                                     install_stack)
from repro.api import ChainSpec, NodeClient, NodeSpec, ShardSpec
from repro.core.events import BlockPacked, ProofGenerated

DYNAMIC_RULES = ("R001", "R005", "R006", "R007")


def _fresh_stack(spec=None):
    client = NodeClient.from_spec(spec or NodeSpec())
    san = install_stack(client.chain, client.target)
    return client, san


def _seed_traffic(client, n=3):
    for i in range(n):
        client.submit("submitLocalModel", f"s{i}")
    client.seal()


def _inject(rule, client):
    """Introduce exactly one violation of ``rule`` into a primed stack."""
    log = client.chain.events
    if rule == "R001":
        # column write that skips mark_dirty; the next window carries a
        # no-state-handler tx so nothing re-dirties the poked chunk
        client.target.state_arrays.balances[0] += 7.0
        client.submit("bgPing", "s0")
        client.seal()
    elif rule == "R005":
        # out-of-band append desynchronizes seq == position
        log._events.append(BlockPacked(
            seq=len(log._events) + 5, time=0.0, shard=None, height=99,
            n_txs=0, gas_used=0, block_hash="bogus"))
        log.emit(BlockPacked, time=1.0, height=100, n_txs=0, gas_used=0,
                 block_hash="next")
    elif rule == "R006":
        client.chain.total_gas += 12345          # gas leaked out of band
        client.chain.produce_block(1e6)          # BlockPacked runs the audit
    elif rule == "R007":
        log.emit(ProofGenerated, time=0.5, shard=None, job=0, batch=777,
                 n_txs=1, digest=0, sealed_at=0.0)
    else:                                        # pragma: no cover
        raise AssertionError(rule)


@pytest.mark.parametrize("rule", DYNAMIC_RULES)
def test_injected_violation_raises_matching_rule(rule):
    client, san = _fresh_stack()
    _seed_traffic(client)
    before = san.n_checks
    assert before > 0, "sanitizer saw no events during clean traffic"
    with pytest.raises(SanitizeViolation) as exc:
        _inject(rule, client)
    assert exc.value.rule == rule
    assert rule in str(exc.value)


@settings(max_examples=12, deadline=None)
@given(rule=st.sampled_from(DYNAMIC_RULES), n_txs=st.integers(1, 6),
       n_windows=st.integers(1, 3))
def test_property_injection_caught_under_randomized_traffic(
        rule, n_txs, n_windows):
    client, san = _fresh_stack()
    for _ in range(n_windows):
        _seed_traffic(client, n=n_txs)
    with pytest.raises(SanitizeViolation) as exc:
        _inject(rule, client)
    assert exc.value.rule == rule


def test_double_proof_is_illegal():
    client, _ = _fresh_stack()
    _seed_traffic(client)
    log = client.chain.events
    proofs = [e for e in log.since(0) if e.kind == "proof_generated"]
    if not proofs:                       # force one through the pipeline
        client.target.settle_session()
        proofs = [e for e in log.since(0) if e.kind == "proof_generated"]
    assert proofs, "seeding produced no proofs to duplicate"
    p = proofs[0]
    with pytest.raises(SanitizeViolation) as exc:
        log.emit(ProofGenerated, time=p.time, shard=p.shard, job=p.job,
                 batch=p.batch, n_txs=p.n_txs, digest=p.digest,
                 sealed_at=p.sealed_at)
    assert exc.value.rule == "R007"


@pytest.mark.parametrize("spec", [
    NodeSpec(),
    NodeSpec(chain=ChainSpec(backend="object")),
    NodeSpec(shards=ShardSpec(count=2, fabric=True)),
], ids=["vector", "object", "fabric-2"])
def test_clean_session_run_stays_silent(spec):
    client, san = _fresh_stack(spec)
    for i in range(60):
        client.submit("submitLocalModel", f"t{i % 5}")
        if (i + 1) % 20 == 0:
            client.seal()
            client.target.settle_session()
    client.flush()
    client.run_until(10.0)
    assert san.n_checks > 0
    # the committed incremental root matches a full refold (R001 path pin)
    st_arrays = san._state()
    if st_arrays is not None:
        assert st_arrays.root() == st_arrays.copy().root()


def test_env_flag_wires_sanitizer_through_build_stack(monkeypatch):
    monkeypatch.setenv(ENV_FLAG, "1")
    client = NodeClient.from_spec(NodeSpec())
    san = getattr(client.chain.events, "_sanitizer", None)
    assert san is not None, "REPRO_SANITIZE=1 did not install the sanitizer"
    _seed_traffic(client)
    assert san.n_checks > 0
    monkeypatch.setenv(ENV_FLAG, "0")
    client2 = NodeClient.from_spec(NodeSpec())
    assert getattr(client2.chain.events, "_sanitizer", None) is None


def test_clean_fused_scheduler_run_stays_silent(monkeypatch):
    """A fused Scheduler run (splice path included) under the sanitizer:
    no violations, and the spliced stream keeps seq == position."""
    import jax.numpy as jnp

    from repro.data.synthetic import gaussian_clusters
    from repro.fl.cohort import VectorCohort, batched_batch_fn
    from repro.fl.dp import DPConfig
    from repro.fl.scheduler import Scheduler
    from repro.fl.server import AutoDFL
    from repro.models.mlp import TinyMLP
    from repro.optim.optimizers import OptimizerSpec, make_optimizer

    monkeypatch.setenv(ENV_FLAG, "1")
    model = TinyMLP(16, 8, 4)
    opt = make_optimizer(OptimizerSpec(name="sgdm", lr=0.1, grad_clip=5.0))
    tr_x, tr_y = gaussian_clusters(256, 16, 4, seed=1, noise=0.5)
    vx, vy = gaussian_clusters(64, 16, 4, seed=2, noise=0.5)
    val = {"x": jnp.asarray(vx), "labels": jnp.asarray(vy)}

    def bf(c, r):
        g = np.random.default_rng((c * 9973 + r) % 2**31)
        idx = g.integers(0, len(tr_x), 8)
        return {"x": jnp.asarray(tr_x[idx]), "labels": jnp.asarray(tr_y[idx])}

    node = AutoDFL(model, opt, 4, model.accuracy_fn(), val, spec=NodeSpec())
    san = getattr(node.chain.events, "_sanitizer", None)
    assert san is not None
    cohort = VectorCohort(model, opt, batched_batch_fn(bf, local_steps=2),
                          node.store, behaviors=["good"] * 4, local_steps=2,
                          dp=DPConfig(noise_multiplier=0.05), seed=0)
    sch = Scheduler(node, seal_every=2, fused=True)
    sch.add_task("t0", cohort, rounds=2)
    out = sch.run()
    assert out["t0"] is not None
    assert san.n_checks > 0
    evs = node.chain.events.since(0)
    assert [e.seq for e in evs] == list(range(len(evs)))
